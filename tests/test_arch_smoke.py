"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, assert output shapes + finiteness; plus prefill/decode consistency."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.models import get_model
from repro import optim

SMOKES = {aid: mod.SMOKE for aid, mod in ARCHS.items()}


def _batch(cfg, B=2, S=64, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_len, cfg.d_model)), jnp.bfloat16
        )
    if cfg.family == "vlm":
        batch["img"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_img_tokens, cfg.d_model)), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch_id", sorted(SMOKES))
class TestArchSmoke:
    def test_forward_loss_finite(self, arch_id):
        cfg = SMOKES[arch_id]
        model = get_model(cfg)
        params = model.init_params(jax.random.key(0))
        batch = _batch(cfg)
        loss, metrics = jax.jit(model.loss_fn)(params, batch)
        assert loss.shape == ()
        assert np.isfinite(float(loss))
        # random init near ln(V)
        assert 0.5 * np.log(cfg.vocab) < float(metrics["loss"]) < 3.0 * np.log(cfg.vocab)

    def test_train_step_updates_and_finite(self, arch_id):
        cfg = SMOKES[arch_id]
        model = get_model(cfg)
        params = model.init_params(jax.random.key(0))
        ocfg = optim.AdamWConfig(lr=1e-3)
        ostate = optim.init(params, ocfg)
        batch = _batch(cfg)

        @jax.jit
        def step(params, ostate, batch):
            (loss, m), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(params, batch)
            params, ostate, om = optim.apply_updates(params, grads, ostate, ocfg)
            return params, ostate, loss, om

        p1, o1, loss1, om = step(params, ostate, batch)
        _, _, loss2, _ = step(p1, o1, batch)
        assert np.isfinite(float(loss1)) and np.isfinite(float(loss2))
        assert float(loss2) < float(loss1)  # one step on same batch must improve
        assert np.isfinite(float(om["grad_norm"]))
        # params actually changed
        diff = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))), params, p1)
        assert max(jax.tree.leaves(diff)) > 0

    def test_prefill_then_decode_matches_full_forward(self, arch_id):
        """Greedy decode consistency: prefill(S) + decode_step(S) logits must
        match prefill(S+1)'s last-token logits."""
        cfg = SMOKES[arch_id]
        model = get_model(cfg)
        params = model.init_params(jax.random.key(0))
        B, S = 2, 32
        batch = _batch(cfg, B=B, S=S + 1)
        toks = batch["tokens"]

        b1 = dict(batch, tokens=toks[:, :S])
        logits_pre, cache = jax.jit(lambda p, b: model.prefill(p, b, cache_len=S + 1))(params, b1)
        step_batch = {"token": toks[:, S : S + 1], "pos": jnp.asarray(S, jnp.int32)}
        logits_dec, _ = jax.jit(model.decode_step)(params, step_batch, cache)

        logits_full, _ = jax.jit(lambda p, b: model.prefill(p, b))(params, batch)
        np.testing.assert_allclose(
            np.asarray(logits_dec), np.asarray(logits_full), rtol=0.15, atol=0.15
        )

    def test_decode_cache_shapes_stable(self, arch_id):
        cfg = SMOKES[arch_id]
        model = get_model(cfg)
        params = model.init_params(jax.random.key(0))
        B, S = 2, 32
        cache = model.init_cache(B, S)
        step_batch = {
            "token": jnp.zeros((B, 1), jnp.int32),
            "pos": jnp.asarray(3, jnp.int32),
        }
        logits, new_cache = jax.jit(model.decode_step)(params, step_batch, cache)
        assert logits.shape == (B, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))
        s1 = jax.tree.map(lambda a: a.shape, cache)
        s2 = jax.tree.map(lambda a: a.shape, new_cache)
        assert s1 == s2


def test_param_count_smoke_consistency():
    """Analytic param_count matches actual init within 2% for full-ish smokes."""
    for aid, cfg in SMOKES.items():
        if cfg.family in ("audio",):  # analytic formula covers enc+dec approx
            continue
        model = get_model(cfg)
        params = model.init_params(jax.random.key(0))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        expect = cfg.param_count()
        assert abs(actual - expect) / max(actual, 1) < 0.1, (
            aid, actual, expect,
        )
