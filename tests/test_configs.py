"""Config faithfulness: analytic parameter counts of the FULL assigned
configs must land near the published model sizes, and every (arch, shape)
cell must produce valid input specs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.configs.shapes import SHAPES, input_specs, supports

# published totals (approximate, from the model cards / papers)
PUBLISHED_PARAMS = {
    "mamba2-130m": (0.13e9, 0.35),
    "deepseek-v3-671b": (671e9, 0.10),
    "olmoe-1b-7b": (6.9e9, 0.15),
    "qwen2-1.5b": (1.54e9, 0.15),
    "smollm-360m": (0.36e9, 0.15),
    "starcoder2-3b": (3.0e9, 0.15),
    "qwen2.5-3b": (3.1e9, 0.15),
    "whisper-small": (0.244e9, 0.25),
    "zamba2-7b": (7.4e9, 0.20),
    "llama-3.2-vision-11b": (9.8e9, 0.25),  # text side + cross layers (tower is stub)
}

ACTIVE_PARAMS = {
    "deepseek-v3-671b": (37e9, 0.30),   # published: 37B activated
    "olmoe-1b-7b": (1.3e9, 0.40),       # published: 1B active
}


class TestPublishedSizes:
    @pytest.mark.parametrize("arch_id", sorted(PUBLISHED_PARAMS))
    def test_total_params_near_published(self, arch_id):
        target, tol = PUBLISHED_PARAMS[arch_id]
        got = ARCHS[arch_id].CONFIG.param_count()
        assert abs(got - target) / target < tol, (
            f"{arch_id}: {got/1e9:.2f}B vs published {target/1e9:.2f}B"
        )

    @pytest.mark.parametrize("arch_id", sorted(ACTIVE_PARAMS))
    def test_active_params_near_published(self, arch_id):
        target, tol = ACTIVE_PARAMS[arch_id]
        got = ARCHS[arch_id].CONFIG.active_param_count()
        assert abs(got - target) / target < tol, (
            f"{arch_id}: active {got/1e9:.2f}B vs published {target/1e9:.2f}B"
        )

    def test_param_count_matches_abstract_init(self):
        """Analytic formula == eval_shape of the real init (full configs,
        no allocation)."""
        import numpy as np
        from repro.models import get_model

        for arch_id in ("qwen2-1.5b", "olmoe-1b-7b", "mamba2-130m"):
            cfg = ARCHS[arch_id].CONFIG
            model = get_model(cfg)
            shapes = jax.eval_shape(lambda m=model: m.init_params(jax.random.key(0)))
            actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
            assert abs(actual - cfg.param_count()) / actual < 0.02, arch_id


class TestInputSpecs:
    @pytest.mark.parametrize("arch_id", sorted(ARCHS))
    @pytest.mark.parametrize("shape_name", sorted(SHAPES))
    def test_specs_well_formed(self, arch_id, shape_name):
        cfg = ARCHS[arch_id].CONFIG
        if not supports(cfg, shape_name):
            pytest.skip("long_500k x full attention")
        spec = SHAPES[shape_name]
        out = input_specs(cfg, shape_name)
        if spec.kind in ("train", "prefill"):
            assert out["tokens"].shape == (spec.batch, spec.seq)
            assert out["tokens"].dtype == jnp.int32
            if cfg.family == "audio":
                assert out["frames"].shape[:2] == (spec.batch, cfg.enc_len)
            if cfg.family == "vlm":
                assert out["img"].shape[:2] == (spec.batch, cfg.n_img_tokens)
        else:
            batch, cache = out
            assert batch["token"].shape == (spec.batch, 1)
            leaves = jax.tree.leaves(cache)
            assert leaves, "decode cache must not be empty"
            import math
            total = sum(math.prod(l.shape) * l.dtype.itemsize for l in leaves)
            assert total > 0

    def test_skip_matrix_matches_design(self):
        """Exactly the SSM/hybrid archs run long_500k."""
        runners = {a for a in ARCHS if supports(ARCHS[a].CONFIG, "long_500k")}
        assert runners == {"mamba2-130m", "zamba2-7b"}
