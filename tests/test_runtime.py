"""Fault-tolerance tests: checkpoint roundtrip, restart-exactness,
preemption handling, async checkpointing, optimizer behavior."""
import os
import signal
import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import checkpoint, optim
from repro.data import TokenStream
from repro.runtime import TrainLoopConfig, train_loop


def _tiny_problem(seed=0):
    """2-layer MLP regression on a fixed function: fast, deterministic."""
    key = jax.random.key(seed)
    k1, k2 = jax.random.split(key)
    params = {
        "w1": jax.random.normal(k1, (8, 32)) * 0.3,
        "w2": jax.random.normal(k2, (32, 1)) * 0.3,
        "b": jnp.zeros((1,)),
    }
    ocfg = optim.AdamWConfig(lr=1e-2, weight_decay=0.0)

    def batch_fn(step):
        rng = np.random.default_rng(step)
        x = rng.standard_normal((16, 8)).astype(np.float32)
        y = np.sin(x.sum(axis=1, keepdims=True)).astype(np.float32)
        return {"x": jnp.asarray(x), "y": jnp.asarray(y)}

    def loss_fn(p, b):
        h = jnp.tanh(b["x"] @ p["w1"])
        pred = h @ p["w2"] + p["b"]
        l = jnp.mean((pred - b["y"]) ** 2)
        return l, {"loss": l}

    @jax.jit
    def step_fn(p, o, b):
        (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(p, b)
        p, o, om = optim.apply_updates(p, g, o, ocfg)
        return p, o, {**m, **om}

    return params, optim.init(params, ocfg), step_fn, batch_fn


class TestCheckpoint:
    def test_roundtrip_bf16_and_nested(self, tmp_path):
        tree = {
            "a": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
            "nested": {"b": jnp.ones((2, 2), jnp.float32), "step": jnp.asarray(7)},
        }
        checkpoint.save(tmp_path, 3, tree)
        step, out = checkpoint.restore(tmp_path, tree)
        assert step == 3
        assert out["a"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(out["a"], np.float32),
                                      np.asarray(tree["a"], np.float32))
        np.testing.assert_array_equal(np.asarray(out["nested"]["b"]),
                                      np.asarray(tree["nested"]["b"]))

    def test_latest_and_atomicity(self, tmp_path):
        tree = {"w": jnp.zeros((4,))}
        checkpoint.save(tmp_path, 1, tree)
        checkpoint.save(tmp_path, 5, tree)
        assert checkpoint.latest_step(tmp_path) == 5
        # a stale tmp dir must not break anything
        (tmp_path / "tmp.9.123").mkdir()
        assert checkpoint.latest_step(tmp_path) == 5

    def test_async_checkpointer(self, tmp_path):
        c = checkpoint.AsyncCheckpointer(tmp_path)
        c.save(10, {"w": jnp.ones((128, 128))})
        c.wait()
        step, out = checkpoint.restore(tmp_path, {"w": jnp.zeros((128, 128))})
        assert step == 10 and float(out["w"][0, 0]) == 1.0


class TestTrainLoop:
    def test_loss_decreases(self, tmp_path):
        params, opt, step_fn, batch_fn = _tiny_problem()
        cfg = TrainLoopConfig(steps=300, ckpt_every=1000, ckpt_dir=None,
                              log_every=50, handle_signals=False)
        _, _, rep = train_loop(step_fn, params, opt, batch_fn, cfg,
                               log_fn=lambda s: None)
        assert rep["history"][-1]["loss"] < rep["history"][0]["loss"] * 0.8

    def test_restart_is_exact(self, tmp_path):
        """Run 60 steps straight vs 30 + crash + resume 30: same params."""
        params, opt, step_fn, batch_fn = _tiny_problem()
        cfg_a = TrainLoopConfig(steps=60, ckpt_every=1000, ckpt_dir=None,
                                log_every=100, handle_signals=False)
        pa, _, _ = train_loop(step_fn, params, opt, batch_fn, cfg_a,
                              log_fn=lambda s: None)

        d = tmp_path / "ck"
        cfg_b1 = TrainLoopConfig(steps=30, ckpt_every=30, ckpt_dir=str(d),
                                 log_every=100, handle_signals=False,
                                 async_ckpt=False)
        train_loop(step_fn, params, opt, batch_fn, cfg_b1, log_fn=lambda s: None)
        # "crash": fresh process state; loop must restore step 30 checkpoint
        cfg_b2 = TrainLoopConfig(steps=60, ckpt_every=1000, ckpt_dir=str(d),
                                 log_every=100, handle_signals=False,
                                 async_ckpt=False)
        pb, _, rep = train_loop(step_fn, params, opt, batch_fn, cfg_b2,
                                log_fn=lambda s: None)
        assert rep["final_step"] == 60
        for ka in pa:
            np.testing.assert_allclose(
                np.asarray(pa[ka]), np.asarray(pb[ka]), rtol=1e-6, atol=1e-7
            )

    def test_preemption_checkpoints_and_exits(self, tmp_path):
        params, opt, step_fn, batch_fn = _tiny_problem()
        d = tmp_path / "ck"
        cfg = TrainLoopConfig(steps=10_000, ckpt_every=10_000, ckpt_dir=str(d),
                              log_every=10_000, handle_signals=True,
                              async_ckpt=False)

        def fire():
            os.kill(os.getpid(), signal.SIGTERM)

        t = threading.Timer(1.0, fire)
        t.start()
        _, _, rep = train_loop(step_fn, params, opt, batch_fn, cfg,
                               log_fn=lambda s: None)
        t.join()
        assert rep["preempted"]
        assert rep["final_step"] < 10_000
        assert checkpoint.latest_step(d) == rep["final_step"]

    @pytest.mark.skipif(
        not hasattr(jax.sharding, "AxisType"),
        reason="mesh AxisType API unavailable in this jax version",
    )
    def test_elastic_restore_resharding(self, tmp_path):
        """Checkpoint written unsharded restores onto a live mesh sharding."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        params = {"w": jnp.arange(16.0).reshape(4, 4)}
        checkpoint.save(tmp_path, 1, params)
        mesh = jax.make_mesh((1,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        sh = {"w": NamedSharding(mesh, P("data", None))}
        step, out = checkpoint.restore(tmp_path, params, shardings=sh)
        assert out["w"].sharding == sh["w"]
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(params["w"]))


class TestOptim:
    def test_adamw_converges_quadratic(self):
        p = {"x": jnp.asarray([5.0, -3.0])}
        cfg = optim.AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=None)
        s = optim.init(p, cfg)
        for _ in range(500):
            g = jax.grad(lambda p: jnp.sum((p["x"] - 1.0) ** 2))(p)
            p, s, _ = optim.apply_updates(p, g, s, cfg)
        np.testing.assert_allclose(np.asarray(p["x"]), [1.0, 1.0], atol=2e-2)

    def test_clip_norm_bounds_update(self):
        p = {"x": jnp.zeros((4,))}
        cfg = optim.AdamWConfig(lr=1.0, clip_norm=1e-3, weight_decay=0.0)
        s = optim.init(p, cfg)
        g = {"x": jnp.full((4,), 1e6)}
        _, _, m = optim.apply_updates(p, g, s, cfg)
        assert float(m["grad_norm"]) > 1e5  # reported pre-clip

    def test_bf16_state_dtype(self):
        p = {"x": jnp.zeros((4,), jnp.bfloat16)}
        cfg = optim.AdamWConfig(lr=1e-3, state_dtype="bfloat16")
        s = optim.init(p, cfg)
        assert s["mu"]["x"]["m"].dtype == jnp.bfloat16

    def test_data_stream_deterministic(self):
        s1 = TokenStream(vocab=100, seq=16, global_batch=4, seed=1)
        s2 = TokenStream(vocab=100, seq=16, global_batch=4, seed=1)
        np.testing.assert_array_equal(
            np.asarray(s1.batch(7)["tokens"]), np.asarray(s2.batch(7)["tokens"])
        )
        assert not np.array_equal(
            np.asarray(s1.batch(7)["tokens"]), np.asarray(s1.batch(8)["tokens"])
        )
