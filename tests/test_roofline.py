"""Tests for the scan-aware HLO cost model and collective parser."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.roofline import analysis
from repro.roofline.hlo_cost import hlo_costs


class TestHloCost:
    def test_plain_matmul_flops_exact(self):
        A = jax.ShapeDtypeStruct((256, 512), jnp.float32)
        B = jax.ShapeDtypeStruct((512, 128), jnp.float32)
        c = jax.jit(lambda a, b: a @ b).lower(A, B).compile()
        costs = hlo_costs(c.as_text())
        assert costs["flops"] == pytest.approx(2 * 256 * 512 * 128, rel=1e-6)

    def test_scan_flops_scaled_by_trip_count(self):
        """THE reason this parser exists: cost_analysis counts loop bodies
        once; the parser must multiply by the trip count."""
        L = 10
        w = jax.ShapeDtypeStruct((L, 128, 128), jnp.float32)
        x = jax.ShapeDtypeStruct((64, 128), jnp.float32)

        def f(w, x):
            def body(c, wi):
                return c @ wi, None
            y, _ = jax.lax.scan(body, x, w)
            return y

        c = jax.jit(f).lower(w, x).compile()
        costs = hlo_costs(c.as_text())
        expect = L * 2 * 64 * 128 * 128
        ca = c.cost_analysis()
        # older jax returns a one-element list of per-device dicts
        xla_once = (ca[0] if isinstance(ca, (list, tuple)) else ca)["flops"]
        assert costs["flops"] == pytest.approx(expect, rel=0.05)
        assert xla_once == pytest.approx(expect / L, rel=0.05)  # the undercount

    def test_nested_scan_multiplies(self):
        w = jax.ShapeDtypeStruct((3, 4, 64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

        def f(w, x):
            def outer(c, wo):
                def inner(ci, wi):
                    return ci @ wi, None
                c, _ = jax.lax.scan(inner, c, wo)
                return c, None
            y, _ = jax.lax.scan(outer, x, w)
            return y

        c = jax.jit(f).lower(w, x).compile()
        costs = hlo_costs(c.as_text())
        expect = 3 * 4 * 2 * 8 * 64 * 64
        assert costs["flops"] == pytest.approx(expect, rel=0.05)

    def test_triangular_solve_counted(self):
        A = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        B = jax.ShapeDtypeStruct((64, 32), jnp.float32)
        c = jax.jit(
            lambda a, b: jax.scipy.linalg.solve_triangular(a, b, lower=True)
        ).lower(A, B).compile()
        costs = hlo_costs(c.as_text())
        assert costs["flops"] >= 64 * 64 * 32  # ~M^2 N


class TestRooflineTerms:
    def test_dominant_selection(self):
        t = analysis.roofline_terms(197e12, 819e9, 0.0)  # 1s compute, 1s memory
        assert t["dominant"] in ("compute", "memory")
        t = analysis.roofline_terms(0.0, 0.0, 50e9)
        assert t["dominant"] == "collective" and t["bound_s"] == pytest.approx(1.0)

    def test_collective_parse_with_tuple_result(self):
        txt = """
ENTRY %main (p: f32[8,128]) -> f32[8,128] {
  %ag = f32[16,128]{1,0} all-gather(%p), replica_groups={}
  %ar = (f32[8,128]{1,0}, f32[8,128]{1,0}) all-reduce(%p, %p), to_apply=%add
  ROOT %r = f32[8,128]{1,0} get-tuple-element(%ar), index=0
}
"""
        c = analysis.collective_bytes(txt)
        assert c["all-gather"]["bytes"] == 16 * 128 * 4
        assert c["all-reduce"]["bytes"] == 2 * 8 * 128 * 4
        assert c["all-reduce"]["wire_bytes"] == 2 * c["all-reduce"]["bytes"]
