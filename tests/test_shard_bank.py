"""Sharded mega-bank tests on 8 virtual devices (subprocess-isolated:
XLA device count is locked at first jax init, so each test body runs in
its own python with XLA_FLAGS=--xla_force_host_platform_device_count=8).

Covers the ShardedGPBank contract: sharded-vs-resident serving parity on
both backends, cross-shard insert/evict/rebalance churn with the jit
cache-miss pin (zero new executables per shard once the shape ladder is
warm), deterministic placement (round-robin fit, least-loaded insert,
fullest-donor rebalance), the 2-D (bank, data) mesh composition with the
v2 row-sharded fit, and the router/engine/tiered integration."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core import shardspec

# mirror of test_distributed's AxisType/set_mesh version guard, but on the
# (older, wider) shard_map availability the sharded bank actually needs
pytestmark = pytest.mark.skipif(
    not shardspec.has_shard_map(),
    reason="no shard_map API in this jax version",
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# shared subprocess preamble: a 16-tenant fleet, a resident bank, and its
# 4-shard twin serving the identical states
FLEET = """
    import jax, numpy as np, jax.numpy as jnp
    from repro.bank import GPBank, ShardedGPBank
    from repro.core.gp import GPSpec
    from repro.data import make_gp_dataset
    from repro.launch.mesh import make_bank_mesh

    B, N_ROWS, P, S = 16, 8, 2, 4
    BACKEND = {backend!r}
    spec = GPSpec.create(8, eps=[0.8] * P, rho=2.0, noise=0.05,
                         backend=BACKEND)
    Xb = np.zeros((B, N_ROWS, P), np.float32)
    yb = np.zeros((B, N_ROWS), np.float32)
    for s in range(B):
        X, y, *_ = make_gp_dataset(N_ROWS, P, seed=s)
        Xb[s], yb[s] = np.asarray(X), np.asarray(y)
    Xb, yb = jnp.asarray(Xb), jnp.asarray(yb)
    rng = np.random.default_rng(0)
    nq = 64
    Xq = jnp.asarray(rng.uniform(-1, 1, size=(nq, P)).astype(np.float32))
    tenants = [int(t) for t in rng.integers(0, B, nq)]

    mesh = make_bank_mesh(S)
    resident = GPBank.fit(Xb, yb, spec)
    sharded = ShardedGPBank.from_bank(resident, mesh)
"""


def run_sub(body: str, *, backend: str = "jnp", timeout=420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    src = textwrap.dedent(FLEET).format(backend=backend) \
        + textwrap.dedent(body)
    r = subprocess.run(
        [sys.executable, "-c", src],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


class TestShardedParity:
    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_fit_mean_var_update_match_resident(self, backend):
        run_sub("""
            # serving the SAME states: sharded answers must match the
            # resident bank's to f32 noise
            mu_r, var_r = resident.mean_var(tenants, Xq)
            mu_s, var_s = sharded.mean_var(tenants, Xq)
            np.testing.assert_allclose(np.asarray(mu_s), np.asarray(mu_r),
                                       atol=1e-5)
            np.testing.assert_allclose(np.asarray(var_s), np.asarray(var_r),
                                       atol=1e-5)

            # an independent sharded FIT of the same data serves the same
            # posterior; the fit is a different lowering of the same
            # moments (B/S vs B leading dim changes XLA's f32 reduction
            # order), so agreement is looser than the exact serving parity
            fitted = ShardedGPBank.fit(Xb, yb, spec, mesh)
            mu_f, var_f = fitted.mean_var(tenants, Xq)
            np.testing.assert_allclose(np.asarray(mu_f), np.asarray(mu_r),
                                       rtol=0, atol=1e-4)
            np.testing.assert_allclose(np.asarray(var_f), np.asarray(var_r),
                                       rtol=0, atol=1e-4)

            # rank-k update on a mixed-tenant batch tracks the resident
            # update (pallas interpret kernels round differently per
            # scatter-group shape, so that backend gets f32 headroom)
            upd = [0, 3, 7, 12]
            Xk = jnp.asarray(rng.uniform(-1, 1, (len(upd), 2, 2))
                             .astype(np.float32))
            yk = jnp.asarray(rng.normal(size=(len(upd), 2))
                             .astype(np.float32))
            res2 = resident.update(upd, Xk, yk)
            sh2 = sharded.update(upd, Xk, yk)
            mu_r2, _ = res2.mean_var(tenants, Xq)
            mu_s2, _ = sh2.mean_var(tenants, Xq)
            atol = 1e-5 if BACKEND == "jnp" else 1e-4
            np.testing.assert_allclose(np.asarray(mu_s2),
                                       np.asarray(mu_r2), rtol=0, atol=atol)

            # round-trip: to_bank() hands back a resident bank with
            # identical answers
            back = sharded.to_bank()
            mu_b, _ = back.mean_var(tenants, Xq)
            np.testing.assert_allclose(np.asarray(mu_b), np.asarray(mu_r),
                                       atol=1e-5)
        """, backend=backend)

    def test_2d_bank_data_mesh_fit(self):
        run_sub("""
            # (bank, data) mesh: the fit row-shards each shard's N axis
            # (one psum over 'data'), serving stays bank-only
            mesh2 = make_bank_mesh(4, 2)
            fitted = ShardedGPBank.fit(Xb, yb, spec, mesh2)
            mu_r, var_r = resident.mean_var(tenants, Xq)
            mu_f, var_f = fitted.mean_var(tenants, Xq)
            # row-sharding splits each tenant's moment sums across the
            # 'data' axis (psum changes the f32 summation order feeding
            # the solve), so the fit agreement is looser than the exact
            # 1-D serving parity
            np.testing.assert_allclose(np.asarray(mu_f), np.asarray(mu_r),
                                       rtol=0, atol=1e-4)
            np.testing.assert_allclose(np.asarray(var_f), np.asarray(var_r),
                                       rtol=0, atol=1e-4)
        """)

    def test_homogeneous_only_and_capacity_guards(self):
        run_sub("""
            import dataclasses, pytest
            het = dataclasses.replace(resident,
                                      hypers=resident._stacked_hypers())
            try:
                ShardedGPBank.from_bank(het, mesh)
            except ValueError as e:
                assert "heterogeneous" in str(e)
            else:
                raise AssertionError("hetero bank must be rejected")
            try:
                ShardedGPBank.create(spec, 10, mesh)   # not a multiple of S
            except ValueError as e:
                assert "multiple" in str(e)
            else:
                raise AssertionError("capacity % S != 0 must be rejected")
        """)


class TestShardedChurn:
    def test_insert_evict_rebalance_zero_recompiles(self):
        run_sub("""
            from repro.bank import sharded as sh_mod

            def churn_cycle(bank, tag):
                # evict two tenants off shard 0, insert two fresh ones
                # (least-loaded placement routes them back), rebalance,
                # then serve + read a state — the full churn surface
                victims = [t for t in bank.tenants
                           if bank.shard_of(t) == 0][:2]
                for t in victims:
                    st = bank.state(t)
                    bank = bank.evict(t)
                for i, t in enumerate(victims):
                    bank = bank.insert((tag, i), st)
                bank, moves = bank.rebalance()
                tl = list(bank.tenants)      # every tenant exactly once:
                mu, var = bank.mean_var(tl, Xq[:len(tl)])
                jax.block_until_ready(mu)
                bank.state(bank.tenants[0])
                return bank

            # warm: one full cycle compiles the shape ladder (per-shard
            # pow2 buckets + this capacity), exactly like the resident
            # bank's bucket warmup
            bank = churn_cycle(sharded, "warm")
            sizes0 = {
                name: fn._cache_size()
                for name, fn in [
                    ("write", sh_mod._sh_write_slot),
                    ("read", sh_mod._sh_read_slot),
                    ("serve", sh_mod._sh_mean_var),
                    ("update", sh_mod._sh_update_scatter),
                ]
            }
            # pin: an identical-shape churn cycle must compile NOTHING
            bank = churn_cycle(bank, "pin")
            for name, fn in [
                ("write", sh_mod._sh_write_slot),
                ("read", sh_mod._sh_read_slot),
                ("serve", sh_mod._sh_mean_var),
                ("update", sh_mod._sh_update_scatter),
            ]:
                assert fn._cache_size() == sizes0[name], (
                    name, fn._cache_size(), sizes0[name]
                )
        """)

    def test_placement_determinism(self):
        run_sub("""
            # round-robin FIT placement: tenant i -> shard i mod S, packed
            # from each shard's lowest local slot (from_bank instead
            # preserves the resident slot layout)
            fitted = ShardedGPBank.fit(Xb, yb, spec, mesh)
            C_l = fitted.shard_capacity
            for i in range(B):
                assert fitted.shard_of(i) == i % S
                assert fitted.slot_of(i) == (i % S) * C_l + i // S

            # least-loaded insert, ties broken by lowest shard id
            st = fitted.state(0)
            b = fitted.evict(1).evict(5)         # shard 1 now lightest
            b = b.insert("a", st)
            assert b.shard_of("a") == 1
            b = b.insert("b", st)                # shard 1 still one short
            assert b.shard_of("b") == 1

            # deterministic rebalance: fullest shard donates its highest
            # occupied local slot until spread <= 1; identical runs give
            # identical assignments
            def scenario():
                bb = fitted
                for t in [0, 4, 8, 12]:          # empty shard 0
                    bb = bb.evict(t)
                bb, moves = bb.rebalance()
                return moves, {t: bb.shard_of(t) for t in bb.tenants}
            m1, a1 = scenario()
            m2, a2 = scenario()
            assert m1 == m2 and a1 == a2
            assert m1 > 0
        """)


class TestShardedIntegration:
    def test_router_engine_tiered(self):
        run_sub("""
            import tempfile
            from repro.bank import BankRouter, FleetEngine, TieredBank
            from repro.obs import MetricsRegistry, Tracer

            reg = MetricsRegistry()
            tracer = Tracer()
            router = BankRouter(sharded, microbatch=8,
                                metrics=reg, tracer=tracer)
            eng = FleetEngine(router, metrics=reg, tracer=tracer)

            # engine drain parity vs direct resident serving
            tickets = [eng.submit(t, np.asarray(Xq[i]))
                       for i, t in enumerate(tenants)]
            results = eng.drain()
            mu_r, _ = resident.mean_var(tenants, Xq)
            mu_e = np.array([results[tk].mu for tk in tickets])
            np.testing.assert_allclose(mu_e, np.asarray(mu_r), atol=1e-5)

            # sharded ingest parity: observe + ingest, compare against the
            # resident bank updated with the same rows
            obs_t = [2, 9]
            xr = rng.uniform(-1, 1, (len(obs_t), P)).astype(np.float32)
            yr = rng.normal(size=len(obs_t)).astype(np.float32)
            for i, t in enumerate(obs_t):
                eng.observe(t, xr[i], yr[i])
            eng.ingest()
            res2 = resident.update(
                obs_t, jnp.asarray(xr[:, None, :]), jnp.asarray(yr[:, None])
            )
            mu_r2, _ = res2.mean_var(tenants, Xq)
            mu_s2, _ = router.bank.mean_var(tenants, Xq)
            # resident vs shard-local rank-1 lowering: per-tenant
            # conditioning (n_rows=8 << M=64) amplifies the f32 path
            # difference on the worst element; the dedicated parity test
            # pins the like-for-like update at 1e-5
            np.testing.assert_allclose(np.asarray(mu_s2),
                                       np.asarray(mu_r2), rtol=0, atol=1e-4)

            # per-shard telemetry: occupancy/backlog gauges + shard ids on
            # the dispatch/ingest trace events
            snap = reg.snapshot()
            gnames = {k.split("{")[0] for k in snap["gauges"]}
            assert "bank_shard_occupancy" in gnames
            names = {ev.get("name") for ev in tracer.events()}
            assert "shard_dispatch" in names and "shard_ingest" in names

            # router rebalance swaps the bank and counts moves
            for t in [t for t in router.bank.tenants
                      if router.bank.shard_of(t) == 0]:
                router.bank = router.bank.evict(t)
            router.rebalance(threshold=1)
            occ = router.bank.shard_occupancy()
            assert occ.max() - occ.min() <= 1
            snap = reg.snapshot()
            moves = [v for k, v in snap["counters"].items()
                     if k.startswith("bank_rebalance_total")]
            assert sum(moves) > 0

            # tiered paging: page-out then page-in lands the tenant on the
            # least-loaded shard through the recompile-free insert
            with tempfile.TemporaryDirectory() as cold:
                tb = TieredBank(router.bank, cold)
                t0 = tb.hot_tenants[0]
                tb.evict_to_cold(t0)
                assert t0 not in tb.bank.tenants
                least = int(np.argmin(tb.bank.shard_occupancy()))
                tb.page_in(t0)
                assert tb.bank.shard_of(t0) == least
        """)
