"""Tests for the elastic tenant lifecycle (PR 7).

Pins the contracts of the TieredBank tentpole and its checkpoint layer:
  1. versioned GP-session serialization round-trips BIT-exactly for all
     three expansions (omega leaf included), heterogeneous banks restore
     per-slot hyperparameter/eigenvalue rows, and restoring into a
     mismatched spec raises (like ``with_spec``);
  2. the checkpoint store survives interrupted writes: stray
     ``tmp.<step>.<pid>`` staging dirs from dead writers are ignored AND
     reaped by ``latest_step``/``restore``, live writers' dirs are not
     touched, and ``AsyncCheckpointer`` surfaces worker-thread failures
     on ``wait()`` (exactly once);
  3. hot/cold paging: evict -> cold -> warm-restore ``mean_var`` matches
     the never-evicted bank to <= 1e-5 on BOTH backends (hetero hypers
     included), arbitrary paging churn compiles ZERO new executables
     (jit cache-miss counts, the test_gp_bank idiom), and LRU/pinning
     semantics hold;
  4. sliding-window forgetting: the batched rank-k Cholesky downdate
     matches a refit on the retained window to <= 1e-5, lost positive
     definiteness leaves the slot untouched and routes through the
     masked-refit fallback, and ``FleetEngine`` pages cold tenants in
     without stalling in-flight blocks.
"""
import os
import subprocess

import numpy as np
import jax.numpy as jnp
import pytest

from repro.bank import BankRouter, FleetEngine, GPBank, TieredBank
from repro.bank import bank as bank_mod
from repro.checkpoint import gpstate, store
from repro.core import fagp
from repro.core.gp import GP, GPSpec
from repro.data import make_gp_dataset

SEED = 0


def _data(N, p, seed=SEED):
    X, y, *_ = make_gp_dataset(N, p, seed=seed)
    return jnp.asarray(X), jnp.asarray(y)


def _fleet(B, N, p, n, *, seed=SEED, backend="jnp", noise=0.1):
    spec = GPSpec.create(n, eps=[0.8] * p, rho=2.0, noise=noise,
                         backend=backend)
    Xb = np.zeros((B, N, p), np.float32)
    yb = np.zeros((B, N), np.float32)
    for s in range(B):
        X, y = _data(N, p, seed=seed + s)
        Xb[s], yb[s] = np.asarray(X), np.asarray(y)
    return jnp.asarray(Xb), jnp.asarray(yb), spec


def _dead_pid():
    """A pid guaranteed not to be running: a just-reaped child's."""
    proc = subprocess.Popen(["true"])
    proc.wait()
    return proc.pid


# ---------------------------------------------------------------------------
# satellite 1+2: store crash-safety + async failure surfacing
# ---------------------------------------------------------------------------


class TestStoreCrashSafety:
    def test_latest_step_ignores_and_reaps_dead_writer_tmp(self, tmp_path):
        """An interrupted write (killed writer) leaves tmp.<step>.<pid>;
        latest_step must not count it as a checkpoint AND must clean it
        up once the writer is verifiably gone."""
        store.save(tmp_path, 2, {"a": np.arange(3.0)})
        stale = tmp_path / f"tmp.7.{_dead_pid()}"
        stale.mkdir()
        (stale / "arrays.npz").write_bytes(b"partial garbage")
        assert store.latest_step(tmp_path) == 2
        assert not stale.exists()

    def test_live_writer_tmp_is_preserved(self, tmp_path):
        """Our own pid's staging dir may belong to an in-flight
        AsyncCheckpointer worker — never reap it."""
        store.save(tmp_path, 0, {"a": np.arange(3.0)})
        mine = tmp_path / f"tmp.9.{os.getpid()}"
        mine.mkdir()
        assert store.latest_step(tmp_path) == 0
        assert mine.exists()

    def test_restore_with_explicit_step_sweeps(self, tmp_path):
        tree = {"a": np.arange(4.0)}
        store.save(tmp_path, 5, tree)
        stale = tmp_path / f"tmp.5.{_dead_pid()}"
        stale.mkdir()
        step, out = store.restore(tmp_path, tree, step=5)
        assert step == 5
        np.testing.assert_array_equal(np.asarray(out["a"]), tree["a"])
        assert not stale.exists()

    def test_non_step_dirs_ignored(self, tmp_path):
        store.save(tmp_path, 1, {"a": np.zeros(2)})
        (tmp_path / "step_notanumber").mkdir()
        (tmp_path / "unrelated").mkdir()
        assert store.latest_step(tmp_path) == 1

    def test_interrupted_write_never_corrupts_previous(self, tmp_path):
        """The atomic-rename contract end to end: a stray staging dir for
        the SAME step does not shadow the committed version."""
        tree = {"a": np.arange(6.0)}
        store.save(tmp_path, 3, tree)
        stale = tmp_path / f"tmp.3.{_dead_pid()}"
        stale.mkdir()
        (stale / "manifest.json").write_text("{corrupt")
        step, out = store.restore(tmp_path, tree)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(out["a"]), tree["a"])


class TestAsyncCheckpointerFailure:
    def test_worker_error_surfaces_on_wait(self, tmp_path, monkeypatch):
        ac = store.AsyncCheckpointer(tmp_path)
        boom = RuntimeError("disk exploded")

        def failing_save(*a, **k):
            raise boom

        monkeypatch.setattr(store, "save", failing_save)
        ac.save(4, {"a": np.zeros(2)})
        with pytest.raises(RuntimeError, match="disk exploded") as ei:
            ac.wait()
        assert ei.value is boom
        # raised exactly once: a later wait is clean
        ac.wait()

    def test_failure_cannot_be_skipped_by_next_save(self, tmp_path,
                                                    monkeypatch):
        """save() waits first, so scheduling the next checkpoint cannot
        silently swallow a prior failure."""
        ac = store.AsyncCheckpointer(tmp_path)
        monkeypatch.setattr(
            store, "save",
            lambda *a, **k: (_ for _ in ()).throw(OSError("enospc")),
        )
        ac.save(0, {"a": np.zeros(2)})
        with pytest.raises(OSError, match="enospc"):
            ac.save(1, {"a": np.zeros(2)})


# ---------------------------------------------------------------------------
# satellite 3: versioned spec-validated round trips
# ---------------------------------------------------------------------------


def _spec_for(expansion, p):
    if expansion == "hermite":
        return GPSpec.create(5, eps=[0.8] * p, rho=2.0, noise=0.1)
    kernel = {"rff_se": "se", "rff_matern52": "matern52"}[expansion]
    return GPSpec.create_rff([0.8] * p, kernel=kernel, num_features=32,
                             noise=0.1, seed=3)


class TestGPStateRoundTrip:
    @pytest.mark.parametrize("expansion",
                             ["hermite", "rff_se", "rff_matern52"])
    def test_bit_exact_round_trip(self, tmp_path, expansion):
        """Every state leaf AND every spec data leaf (omega included)
        round-trips bit-exactly through save/load."""
        p = 2
        spec = _spec_for(expansion, p)
        X, y = _data(48, p)
        gp = GP.fit(X, y, spec)
        ver = gp.save(tmp_path)
        assert ver == 0
        gp2 = GP.load(tmp_path)
        for f in ("lam", "sqrtlam", "chol", "u", "b"):
            np.testing.assert_array_equal(
                np.asarray(getattr(gp.state, f)),
                np.asarray(getattr(gp2.state, f)), err_msg=f,
            )
        for f in ("eps", "rho", "noise", "omega"):
            a, b = getattr(gp.spec, f), getattr(gp2.spec, f)
            if a is None:
                assert b is None
            else:
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                              err_msg=f)
        for f in fagp._STRUCTURAL_FIELDS:
            assert getattr(gp.spec, f) == getattr(gp2.spec, f)
        # the restored session answers identically
        Xq, _ = _data(8, p, seed=9)
        np.testing.assert_array_equal(
            np.asarray(gp.mean_var(Xq)[0]), np.asarray(gp2.mean_var(Xq)[0])
        )

    def test_versions_accumulate_and_address(self, tmp_path):
        p = 2
        spec = _spec_for("hermite", p)
        X, y = _data(40, p)
        gp = GP.fit(X, y, spec)
        assert gp.save(tmp_path) == 0
        gp_up = gp.update(*_data(8, p, seed=5))
        assert gp_up.save(tmp_path) == 1
        assert gpstate.latest_version(tmp_path) == 1
        old = GP.load(tmp_path, step=0)
        new = GP.load(tmp_path)
        np.testing.assert_array_equal(np.asarray(old.state.u),
                                      np.asarray(gp.state.u))
        np.testing.assert_array_equal(np.asarray(new.state.u),
                                      np.asarray(gp_up.state.u))

    def test_wrong_spec_restore_raises(self, tmp_path):
        p = 2
        X, y = _data(40, p)
        GP.fit(X, y, _spec_for("hermite", p)).save(tmp_path / "h")
        GP.fit(X, y, _spec_for("rff_se", p)).save(tmp_path / "r")
        # expansion mismatch
        with pytest.raises(ValueError, match="structural"):
            GP.load(tmp_path / "h", spec=_spec_for("rff_se", p))
        # truncation mismatch within one family
        with pytest.raises(ValueError, match="structural"):
            GP.load(tmp_path / "h",
                    spec=GPSpec.create(7, eps=[0.8] * p, noise=0.1))
        # same family, different spectral draws
        other = GPSpec.create_rff([0.8] * p, kernel="se", num_features=32,
                                  noise=0.1, seed=99)
        with pytest.raises(ValueError, match="omega"):
            GP.load(tmp_path / "r", spec=other)
        # hyperparameter mismatch is rejected when required (GP.load)
        with pytest.raises(ValueError, match="hyperparameter"):
            GP.load(tmp_path / "h",
                    spec=GPSpec.create(5, eps=[0.5] * p, noise=0.1))

    def test_hetero_bank_slots_round_trip_per_slot_rows(self, tmp_path):
        """A heterogeneous bank's unstacked states carry per-slot
        (eps, rho, noise) AND per-slot lam/sqrtlam rows; paging one out
        and back must restore all of them bit-exactly."""
        Xb, yb, spec = _fleet(3, 32, 2, 4)
        bank = GPBank.fit(Xb, yb, spec).optimize(
            Xb, yb, steps=6, restarts=1
        )
        tb = TieredBank(bank, tmp_path / "cold")
        st_before = bank.state(1)
        tb.evict_to_cold(1)
        tb.page_in(1)
        st_after = tb.bank.state(1)
        for f in ("lam", "sqrtlam", "chol", "u", "b"):
            np.testing.assert_array_equal(
                np.asarray(getattr(st_before, f)),
                np.asarray(getattr(st_after, f)), err_msg=f,
            )
        for f in ("eps", "rho", "noise"):
            np.testing.assert_array_equal(
                np.asarray(getattr(st_before.spec, f)),
                np.asarray(getattr(st_after.spec, f)), err_msg=f,
            )

    def test_cold_checkpoint_from_other_structure_raises(self, tmp_path):
        """A cold tier written under one expansion cannot page into a bank
        of another: the manifest check fires before any array load."""
        p = 2
        X, y = _data(32, p)
        cold = tmp_path / "cold"
        gpstate.save_state(cold / "i0",
                           GP.fit(X, y, _spec_for("rff_se", p)).state)
        Xb, yb, spec = _fleet(2, 32, p, 4)
        bank = GPBank.fit(Xb, yb, spec, tenant_ids=[1, 2], capacity=3)
        tb = TieredBank(bank, cold)
        assert 0 in tb.cold_tenants
        with pytest.raises(ValueError, match="structural"):
            tb.page_in(0)


# ---------------------------------------------------------------------------
# tentpole: hot/cold paging
# ---------------------------------------------------------------------------


class TestTieredPaging:
    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_evict_cold_restore_parity(self, tmp_path, backend):
        """evict -> cold -> warm-restore mean_var == never-evicted bank
        to <= 1e-5 (the acceptance gate), on both backends."""
        B, N, p, n = 6, 32, 2, 5
        Xb, yb, spec = _fleet(B, N, p, n, backend=backend)
        ref = GPBank.fit(Xb, yb, spec)
        tb = TieredBank.fit(Xb, yb, spec, cold_dir=tmp_path / "cold",
                            capacity=3)
        assert tb.cold_tenants == [3, 4, 5]
        rng = np.random.default_rng(7)
        Xq = jnp.asarray(rng.uniform(-1, 1, (9, p)).astype(np.float32))
        ids = [4, 0, 4, 3, 3, 0, 4, 3, 0]      # mixed tiers, 3 distinct
        mu, var = tb.mean_var(ids, Xq)
        mur, varr = ref.mean_var(ids, Xq)
        np.testing.assert_allclose(np.asarray(mu), np.asarray(mur),
                                   atol=1e-5, rtol=0)
        np.testing.assert_allclose(np.asarray(var), np.asarray(varr),
                                   atol=1e-5, rtol=0)

    def test_hetero_evict_restore_parity(self, tmp_path):
        """Per-slot learned hypers ride the cold tier: a tenant optimized,
        evicted and restored serves <= 1e-5 of never-evicted."""
        Xb, yb, spec = _fleet(3, 32, 2, 4)
        bank = GPBank.fit(Xb, yb, spec).optimize(Xb, yb, steps=6,
                                                 restarts=1)
        tb = TieredBank(bank, tmp_path / "cold")
        rng = np.random.default_rng(8)
        Xq = jnp.asarray(rng.uniform(-1, 1, (6, p := 2)).astype(np.float32))
        mu0, var0 = bank.mean_var([2] * 6, Xq)
        tb.evict_to_cold(2)
        mu1, var1 = tb.mean_var([2] * 6, Xq)     # pages back in
        np.testing.assert_allclose(np.asarray(mu1), np.asarray(mu0),
                                   atol=1e-5, rtol=0)
        np.testing.assert_allclose(np.asarray(var1), np.asarray(var0),
                                   atol=1e-5, rtol=0)

    def test_paging_churn_zero_recompiles(self, tmp_path):
        """Arbitrary evict/restore churn reuses the warm executables:
        zero jit cache misses across 30 paging cycles (same mechanism as
        tests/test_gp_bank.py)."""
        B, N, p, n = 8, 32, 2, 5
        Xb, yb, spec = _fleet(B, N, p, n)
        tb = TieredBank.fit(Xb, yb, spec, cold_dir=tmp_path / "cold",
                            capacity=4)
        rng = np.random.default_rng(9)
        Xq = jnp.asarray(rng.uniform(-1, 1, (4, p)).astype(np.float32))
        for t in range(B):                      # warm every path once
            tb.mean_var([t] * 4, Xq)
        writes0 = bank_mod._write_slot._cache_size()
        serve0 = fagp._bank_gathered_posterior._cache_size()
        for r in range(30):
            tb.mean_var([(3 * r + 1) % B] * 4, Xq)
        assert bank_mod._write_slot._cache_size() == writes0
        assert fagp._bank_gathered_posterior._cache_size() == serve0
        assert tb.stats["warm_restores"] >= 20

    def test_lru_eviction_and_pinning(self, tmp_path):
        Xb, yb, spec = _fleet(4, 32, 2, 4)
        tb = TieredBank.fit(Xb, yb, spec, cold_dir=tmp_path / "cold",
                            capacity=2)
        assert tb.hot_tenants == [0, 1]
        Xq = jnp.zeros((1, 2), jnp.float32)
        tb.mean_var([0], Xq)                    # 0 is now most-recent
        tb.page_in(2)                           # evicts LRU = 1
        assert not tb.is_hot(1) and tb.is_hot(0) and tb.is_hot(2)
        tb.page_in(3, pinned=[2])               # 2 pinned -> victim is 0
        assert tb.is_hot(2) and tb.is_hot(3) and not tb.is_hot(0)
        with pytest.raises(RuntimeError, match="pinned"):
            tb.page_in(0, pinned=[2, 3])
        with pytest.raises(ValueError, match="split the batch"):
            tb.ensure_hot([0, 1, 2])
        with pytest.raises(KeyError):
            tb.page_in("never-seen")

    def test_durable_across_instances(self, tmp_path):
        """The cold tier is directory state: a NEW TieredBank over the
        same dir sees the same cold tenants and serves identically."""
        Xb, yb, spec = _fleet(4, 32, 2, 4)
        cold = tmp_path / "cold"
        tb = TieredBank.fit(Xb, yb, spec, cold_dir=cold, capacity=2)
        Xq = jnp.asarray(
            np.random.default_rng(3).uniform(-1, 1, (4, 2)).astype(np.float32)
        )
        mu0, _ = tb.mean_var([3] * 4, Xq)
        bank2 = GPBank.create(spec, capacity=2)
        tb2 = TieredBank(bank2, cold)
        assert set(tb2.cold_tenants) >= {2, 3}
        mu1, _ = tb2.mean_var([3] * 4, Xq)
        np.testing.assert_array_equal(np.asarray(mu0), np.asarray(mu1))

    def test_string_and_bad_tenant_ids(self, tmp_path):
        Xb, yb, spec = _fleet(2, 32, 2, 4)
        tb = TieredBank.fit(Xb, yb, spec, cold_dir=tmp_path / "cold",
                            capacity=2, tenant_ids=["alpha", "b/../c"])
        tb.evict_to_cold("b/../c")              # quoted: path-safe
        assert (tb.cold_dir / "sb%2F..%2Fc").exists()
        tb.page_in("b/../c")
        assert tb.is_hot("b/../c")
        with pytest.raises(TypeError, match="int or str"):
            tb.insert((1, 2), (Xb[0], yb[0]))


# ---------------------------------------------------------------------------
# tentpole: sliding-window forgetting
# ---------------------------------------------------------------------------


class TestForgetting:
    def test_downdate_matches_refit_on_retained_window(self):
        """The rank-k downdate == refit on the retained rows to <= 1e-5
        (mu and var), batched over several tenants at once."""
        B, N, p, n, k = 4, 40, 2, 6, 8
        Xb, yb, spec = _fleet(B, N, p, n, noise=0.1)
        bank = GPBank.fit(Xb, yb, spec)
        down, ok = bank.downdate(
            list(range(B)), Xb[:, :k], yb[:, :k]
        )
        assert ok.all()
        refit = bank.refit_window(list(range(B)), Xb[:, k:], yb[:, k:])
        rng = np.random.default_rng(11)
        Xq = jnp.asarray(rng.uniform(-1, 1, (12, p)).astype(np.float32))
        ids = [int(t) for t in rng.integers(0, B, 12)]
        mu_d, var_d = down.mean_var(ids, Xq)
        mu_r, var_r = refit.mean_var(ids, Xq)
        np.testing.assert_allclose(np.asarray(mu_d), np.asarray(mu_r),
                                   atol=1e-5, rtol=0)
        np.testing.assert_allclose(np.asarray(var_d), np.asarray(var_r),
                                   atol=1e-5, rtol=0)

    def test_pd_loss_leaves_slot_untouched_and_flags(self):
        """Downdating rows that were never absorbed loses positive
        definiteness: ok=False and the slot is BIT-exactly unchanged."""
        B, N, p, n = 2, 40, 2, 6
        Xb, yb, spec = _fleet(B, N, p, n, noise=0.1)
        bank = GPBank.fit(Xb, yb, spec)
        bogus_X = jnp.full((1, 8, p), 0.3, jnp.float32)
        bogus_y = jnp.full((1, 8), 50.0, jnp.float32)
        new, ok = bank.downdate([0], bogus_X, bogus_y)
        assert not ok[0]
        s = bank.slot_of(0)
        for f in ("chol", "u", "b"):
            np.testing.assert_array_equal(
                np.asarray(getattr(new.stack, f)[s]),
                np.asarray(getattr(bank.stack, f)[s]), err_msg=f,
            )

    def test_age_window_and_refit_fallback(self, tmp_path):
        """age() forgets rows beyond the window via the downdate, and a
        PD-losing tenant falls back to the masked refit from its retained
        window — landing within 1e-5 of a fresh fit on those rows."""
        B, N, p, n, W = 2, 40, 2, 6, 32
        Xb, yb, spec = _fleet(B, N, p, n, noise=0.1)
        tb = TieredBank.fit(Xb, yb, spec, cold_dir=tmp_path / "cold",
                            window=W)
        # tenant 1's excess is poisoned with never-absorbed rows -> the
        # downdate must fail and the refit fallback take over
        bogus = [(np.full(p, 0.3, np.float32), 50.0)] * 8
        tb._rows[1] = bogus + tb._rows[1][-W:]
        out = tb.age()
        assert set(out["aged"]) == {0, 1}
        assert out["refit"] == [1]
        assert tb.stats["refit_fallbacks"] == 1
        assert all(len(tb._rows[t]) == W for t in (0, 1))
        # both tenants now factorize exactly their retained windows
        ref = GPBank.fit(Xb[:, N - W:], yb[:, N - W:], spec)
        rng = np.random.default_rng(13)
        Xq = jnp.asarray(rng.uniform(-1, 1, (8, p)).astype(np.float32))
        for t in (0, 1):
            mu, var = tb.mean_var([t] * 8, Xq)
            mur, varr = ref.mean_var([t] * 8, Xq)
            np.testing.assert_allclose(np.asarray(mu), np.asarray(mur),
                                       atol=1e-5, rtol=0)
            np.testing.assert_allclose(np.asarray(var), np.asarray(varr),
                                       atol=1e-5, rtol=0)

    def test_window_rides_cold_checkpoints(self, tmp_path):
        """Eviction persists the window buffer; restore resumes forgetting
        where it left off."""
        Xb, yb, spec = _fleet(2, 40, 2, 5)
        tb = TieredBank.fit(Xb, yb, spec, cold_dir=tmp_path / "cold",
                            window=36)
        rows_before = [tuple(map(np.asarray, r)) for r in tb._rows[0]]
        tb.evict_to_cold(0)
        tb._rows.pop(0, None)
        tb.page_in(0)
        assert len(tb._rows[0]) == len(rows_before)
        np.testing.assert_array_equal(
            np.stack([x for x, _ in tb._rows[0]]),
            np.stack([x for x, _ in rows_before]),
        )


# ---------------------------------------------------------------------------
# tentpole: engine integration
# ---------------------------------------------------------------------------


class TestEnginePaging:
    def _tiered_engine(self, tmp_path, *, capacity=3, window=0, B=6):
        Xb, yb, spec = _fleet(B, 32, 2, 5)
        tb = TieredBank.fit(Xb, yb, spec, cold_dir=tmp_path / "cold",
                            capacity=capacity, window=window)
        router = BankRouter(tb.bank, microbatch=8)
        eng = FleetEngine(router, max_in_flight=2, tiered=tb,
                          auto_pump=False)
        ref = GPBank.fit(Xb, yb, spec)
        return tb, eng, ref

    def test_submit_pages_in_without_stalling_in_flight(self, tmp_path):
        """A cold tenant's submit pages it in while another tenant's
        dispatched block stays in flight (immutable banks: the old stack
        keeps computing), and every ticket lands within 1e-5 of the
        resident reference."""
        tb, eng, ref = self._tiered_engine(tmp_path)
        rng = np.random.default_rng(17)
        xs = rng.uniform(-1, 1, (16, 2)).astype(np.float32)
        hot = tb.hot_tenants[0]
        t_hot = [eng.submit(hot, xs[i]) for i in range(8)]
        eng.pump(max_blocks=1)
        assert eng.in_flight_blocks == 1
        cold = tb.cold_tenants[0]
        t_cold = [eng.submit(cold, xs[8 + i]) for i in range(8)]
        assert eng.in_flight_blocks == 1        # page-in did not stall it
        assert tb.is_hot(cold)
        assert tb.is_hot(hot)                   # pinned by in-flight work
        res = eng.drain()
        for i, tk in enumerate(t_hot + t_cold):
            t = hot if i < 8 else cold
            mur, _ = ref.mean_var([t], xs[i][None])
            assert abs(res[tk].mu - float(mur[0])) <= 1e-5

    def test_full_pin_coverage_drains_and_succeeds(self, tmp_path):
        """When pending queries pin EVERY hot slot, the engine drains to
        completion (results stay redeemable) and then pages in."""
        tb, eng, ref = self._tiered_engine(tmp_path, capacity=2, B=4)
        rng = np.random.default_rng(19)
        xs = rng.uniform(-1, 1, (12, 2)).astype(np.float32)
        tickets, expect = [], []
        for i in range(12):
            t = int(rng.integers(0, 4))
            tickets.append(eng.submit(t, xs[i]))
            expect.append(t)
        res = eng.drain()
        for i, tk in enumerate(tickets):
            mur, _ = ref.mean_var([expect[i]], xs[i][None])
            assert abs(res[tk].mu - float(mur[0])) <= 1e-5

    def test_observe_and_ingest_record_window_rows(self, tmp_path):
        tb, eng, ref = self._tiered_engine(tmp_path, window=40)
        cold = tb.cold_tenants[0]
        rng = np.random.default_rng(23)
        xs = rng.uniform(-1, 1, (3, 2)).astype(np.float32)
        for i in range(3):
            eng.observe(cold, xs[i], float(i) * 0.1)
        assert tb.is_hot(cold)
        before = len(tb._rows.get(cold, []))
        assert eng.ingest() == 3
        assert len(tb._rows[cold]) == before + 3
        assert eng.router.bank is tb.bank       # adopted back
        ref2 = ref.update([cold], xs[None],
                          jnp.asarray([[0.0, 0.1, 0.2]], jnp.float32))
        Xq = jnp.asarray(xs)
        mu, _ = tb.mean_var([cold] * 3, Xq)
        mur, _ = ref2.mean_var([cold] * 3, Xq)
        np.testing.assert_allclose(np.asarray(mu), np.asarray(mur),
                                   atol=1e-5, rtol=0)

    def test_router_staleness_retained_for_cold_tenants(self, tmp_path):
        """A tenant's drift counter survives an evict -> restore cycle
        when retained (TieredBank fleets), and resets without retain."""
        Xb, yb, spec = _fleet(3, 32, 2, 4)
        tb = TieredBank.fit(Xb, yb, spec, cold_dir=tmp_path / "cold")
        router = BankRouter(tb.bank)
        router._since_reopt[0] = 20
        tb.evict_to_cold(0)
        router.bank = tb.bank
        assert router.stale_tenants(10, retain=tb.tenants) == []  # cold
        tb.page_in(0)
        router.bank = tb.bank
        assert router.stale_tenants(10, retain=tb.tenants) == [0]
        # without retain the eviction would have dropped the counter
        router._since_reopt[1] = 20
        tb.evict_to_cold(1)
        router.bank = tb.bank
        router.stale_tenants(10)
        tb.page_in(1)
        router.bank = tb.bank
        assert router.stale_tenants(10) == [0]   # 1's counter was dropped
