"""Layer-level tests: chunked (flash) attention oracle equivalence, RoPE,
SSD chunking invariance, MoE dispatch invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypcompat import given, settings, st  # hypothesis, or fixed examples

from repro.models import layers, ssm


def _qkv(B, Sq, Skv, H, K, D, Dv=None, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, Sq, H, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, Skv, K, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, Skv, K, Dv or D)).astype(np.float32))
    return q, k, v


class TestFlashAttention:
    @pytest.mark.parametrize(
        "causal,window,Skv",
        [(True, 0, 4096), (True, 1024, 4096), (False, 0, 4096),
         (True, 0, 3000), (False, 0, 1500)],  # ragged kv exercises padding
    )
    def test_flash_matches_simple(self, causal, window, Skv):
        B, Sq, H, K, D = 2, 2048, 4, 2, 32
        q, k, v = _qkv(B, Sq, Skv, H, K, D)
        qg = q.reshape(B, Sq, K, H // K, D)
        out_f = layers._attention_flash(
            qg, k, v, causal=causal, window=window, kv_valid_len=None, softcap=0.0,
            q_chunk=512, kv_chunk=1024,
        )
        out_s = layers._attention_simple(
            qg, k, v, causal=causal, window=window, q_offset=0,
            kv_valid_len=None, softcap=0.0,
        )
        np.testing.assert_allclose(
            np.asarray(out_f, np.float32), np.asarray(out_s, np.float32),
            rtol=2e-4, atol=2e-5,
        )

    def test_flash_with_valid_len_and_softcap(self):
        B, Sq, H, K, D = 1, 2048, 2, 2, 16
        q, k, v = _qkv(B, Sq, 2048, H, K, D, seed=3)
        qg = q.reshape(B, Sq, K, 1, D)
        out_f = layers._attention_flash(
            qg, k, v, causal=True, window=0, kv_valid_len=1500, softcap=30.0,
        )
        out_s = layers._attention_simple(
            qg, k, v, causal=True, window=0, q_offset=0,
            kv_valid_len=1500, softcap=30.0,
        )
        np.testing.assert_allclose(
            np.asarray(out_f, np.float32), np.asarray(out_s, np.float32),
            rtol=2e-4, atol=2e-5,
        )

    def test_mixed_value_dim(self):
        B, Sq, H, K, D, Dv = 2, 2048, 4, 4, 24, 16
        q, k, v = _qkv(B, Sq, 2048, H, K, D, Dv=Dv, seed=5)
        out = layers.gqa_attention(q, k, v, causal=True)
        assert out.shape == (B, Sq, H, Dv)
        out_small = layers.gqa_attention(q[:, :256], k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out[:, :256]), np.asarray(out_small), rtol=2e-4, atol=2e-5
        )


class TestRope:
    def test_rope_relative_property(self):
        """<rope(q,i), rope(k,j)> depends only on i - j."""
        D = 32
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.standard_normal((1, 1, 1, D)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((1, 1, 1, D)).astype(np.float32))

        def dot_at(i, j):
            qi = layers.rope(q, jnp.array([i]), 10000.0)
            kj = layers.rope(k, jnp.array([j]), 10000.0)
            return float(jnp.sum(qi * kj))

        assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3
        assert abs(dot_at(0, 0) - float(jnp.sum(q * k))) < 1e-4

    def test_rope_norm_preserved(self):
        D = 64
        x = jnp.ones((1, 4, 2, D), jnp.float32)
        y = layers.rope(x, jnp.arange(4), 10000.0)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(y), axis=-1),
            np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5,
        )


class TestSSD:
    @given(chunk=st.sampled_from([8, 16, 32, 64]), seed=st.integers(0, 20))
    @settings(max_examples=10, deadline=None)
    def test_chunk_size_invariance(self, chunk, seed):
        """SSD output must not depend on the chunk decomposition."""
        b, l, h, p, g, n = 2, 64, 4, 8, 1, 16
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((b, l, h, p)).astype(np.float32))
        dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, l, h)).astype(np.float32))
        A = -jnp.asarray(rng.uniform(0.1, 2.0, (h,)).astype(np.float32))
        B = jnp.asarray(rng.standard_normal((b, l, g, n)).astype(np.float32))
        C = jnp.asarray(rng.standard_normal((b, l, g, n)).astype(np.float32))
        y1, s1 = ssm.ssd_chunked(x, dt, A, B, C, chunk)
        y2, s2 = ssm.ssd_chunked(x, dt, A, B, C, l)  # single chunk = reference
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-3, atol=2e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-3, atol=2e-4)

    def test_ssd_matches_naive_recurrence(self):
        """Chunked SSD == direct per-step state recurrence."""
        b, l, h, p, g, n = 1, 32, 2, 4, 1, 8
        rng = np.random.default_rng(7)
        x = rng.standard_normal((b, l, h, p)).astype(np.float32)
        dt = rng.uniform(0.01, 0.2, (b, l, h)).astype(np.float32)
        A = -rng.uniform(0.1, 1.0, (h,)).astype(np.float32)
        B = rng.standard_normal((b, l, g, n)).astype(np.float32)
        C = rng.standard_normal((b, l, g, n)).astype(np.float32)

        y_ref = np.zeros((b, l, h, p), np.float32)
        state = np.zeros((b, h, p, n), np.float32)
        for t in range(l):
            dA = np.exp(dt[:, t] * A[None, :])                      # (b,h)
            Bh = np.repeat(B[:, t], h // g, axis=1)                 # (b,h,n)
            Ch = np.repeat(C[:, t], h // g, axis=1)
            state = state * dA[..., None, None] + np.einsum(
                "bhp,bhn->bhpn", x[:, t] * dt[:, t][..., None], Bh
            )
            y_ref[:, t] = np.einsum("bhpn,bhn->bhp", state, Ch)

        y, s_fin = ssm.ssd_chunked(
            jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
            jnp.asarray(B), jnp.asarray(C), 8,
        )
        np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-4)
        np.testing.assert_allclose(np.asarray(s_fin), state, rtol=2e-3, atol=2e-4)


class TestMoEDispatch:
    @given(T=st.sampled_from([32, 64, 96]), seed=st.integers(0, 30))
    @settings(max_examples=10, deadline=None)
    def test_combine_preserves_gate_weighted_sum(self, T, seed):
        """With identity experts (wg=wu=0 trick unavailable) — instead check:
        no token appears twice in one expert's slots, and gates of kept
        assignments sum to <= 1 per token."""
        from repro.models import moe as M
        import dataclasses
        from repro.configs import ARCHS

        cfg = dataclasses.replace(ARCHS["olmoe-1b-7b"].SMOKE, capacity_factor=1.0)
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((T, cfg.d_model)).astype(np.float32))
        p = M.moe_init(jax.random.key(seed), cfg, jnp.float32)
        topv, topi, aux = M._route(p, x, cfg)
        C = M.capacity(T, cfg)
        tok, w = M._dispatch_tables(topi, topv, T, cfg.top_k, C, 0, cfg.n_experts, x.dtype)
        tok = np.asarray(tok).reshape(cfg.n_experts, C)
        for e in range(cfg.n_experts):
            kept = tok[e][tok[e] < T]
            assert len(set(kept.tolist())) == len(kept)  # no dup token per expert
        w = np.asarray(w)
        assert float(aux) > 0
        # per-token kept gate mass <= 1 + eps
        sums = np.zeros(T + 1)
        np.add.at(sums, np.asarray(tok).reshape(-1), w)
        assert sums[:T].max() <= 1.0 + 1e-4
