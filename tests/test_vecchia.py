"""Vecchia nearest-neighbor conditioning — accuracy, memory, and protocol.

Four claims are pinned here:
  1. the blocked streaming k-NN (repro.kernels.knn) matches a dense O(N^2)
     numpy oracle (as index SETS per row — ties may be broken either way)
     and never materializes a Q x N distance matrix (jaxpr sweep, same
     methodology as tests/test_streaming_fit.py);
  2. vecchia converges to exact_gp as k -> N for BOTH reference kernels:
     prediction agrees to <= 1e-4 at full conditioning sets, the ordered-
     factorization NLML telescopes to the exact joint, and the error is
     (weakly) decreasing in k;
  3. on clustered 2-D spatial data (the regime it exists for) vecchia beats
     every registered global expansion at matched hyperparameters;
  4. the Approximation protocol: capability refusals are the structured
     UnsupportedError, checkpoints round-trip bit-exactly, update is an
     exact concatenation, and the facade dispatches both families.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import exact_gp, fagp, vecchia
from repro.core.approximation import (
    UnsupportedError,
    available_approximations,
    get_approximation,
)
from repro.core.gp import GP, GPSpec
from repro.core.mercer import SEKernelParams
from repro.data.gp_synthetic import make_clustered_dataset, make_gp_dataset
from repro.kernels import knn


def _points(N, p=2, seed=0, lo=-2.0, hi=2.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(lo, hi, (N, p)).astype(np.float32))


def _vecchia_problem(N=160, p=2, k=16, kernel="se", seed=0, noise=0.05):
    X, y, Xs, ys = make_gp_dataset(N, p, seed=seed)
    spec = GPSpec.create_vecchia([0.8] * p, noise, kernel=kernel,
                                 neighbors=k)
    return X, y, Xs, ys, spec


def _exact_params(spec):
    return SEKernelParams(eps=spec.eps, rho=spec.rho, noise=spec.noise)


# ---------------------------------------------------------------------------
# 1. the k-NN kernel
# ---------------------------------------------------------------------------


class TestKnnParity:
    @pytest.mark.parametrize("k,block_q,block_t", [
        (1, 128, 512), (7, 16, 32), (16, 33, 17), (40, 128, 512),
    ])
    def test_matches_dense_numpy_oracle(self, k, block_q, block_t):
        """Index SETS per row equal the O(Q x N) argsort (ties in distance
        may resolve to either index; the conditioning set is what matters)."""
        Xq, Xt = _points(57, seed=1), _points(143, seed=2)
        d, i = knn.knn_search(Xq, Xt, k, block_q=block_q, block_t=block_t)
        D = np.sum(
            (np.asarray(Xq)[:, None, :] - np.asarray(Xt)[None, :, :]) ** 2,
            axis=-1,
        )
        ref = np.argsort(D, axis=1, kind="stable")[:, :k]
        got_d = np.asarray(d)
        for r in range(Xq.shape[0]):
            assert set(np.asarray(i)[r]) == set(ref[r]), f"row {r}"
            np.testing.assert_allclose(
                got_d[r], np.sort(D[r])[:k], rtol=1e-4, atol=1e-5
            )
        # distances ascending per row
        assert np.all(np.diff(got_d, axis=1) >= -1e-7)

    def test_k_equals_n(self):
        Xq, Xt = _points(20, seed=3), _points(12, seed=4)
        _, i = knn.knn_search(Xq, Xt, 12, block_t=5)
        for r in range(20):
            assert set(np.asarray(i)[r]) == set(range(12))

    def test_bad_k_raises(self):
        X = _points(10)
        with pytest.raises(ValueError, match="1 <= k <= N"):
            knn.knn_search(X, X, 0)
        with pytest.raises(ValueError, match="1 <= k <= N"):
            knn.knn_search(X, X, 11)

    @pytest.mark.parametrize("block_q,block_t", [(128, 512), (13, 7)])
    def test_ordered_topk_matches_oracle(self, block_q, block_t):
        """Row i conditions on the nearest among j < i only; rows with
        fewer than k predecessors have exactly min(i, k) valid slots."""
        X = _points(71, seed=5)
        k = 9
        idx, mask = knn.ordered_topk(X, k, block_q=block_q, block_t=block_t)
        Xn = np.asarray(X)
        D = np.sum((Xn[:, None, :] - Xn[None, :, :]) ** 2, axis=-1)
        idx_n, mask_n = np.asarray(idx), np.asarray(mask)
        for r in range(71):
            nvalid = int(mask_n[r].sum())
            assert nvalid == min(r, k), f"row {r}"
            valid = set(idx_n[r][mask_n[r] > 0])
            ref = set(np.argsort(D[r, :r], kind="stable")[:k]) if r else set()
            assert valid == ref, f"row {r}"
            # masked slots are clamped in-bounds for safe gathers
            assert np.all(idx_n[r] >= 0) and np.all(idx_n[r] < 71)


class TestNoDenseDistanceMatrix:
    """The memory claim, pinned exactly like the streaming-fit tests: no
    intermediate in the whole jaxpr (scan/map bodies included) carries two
    axes that are both data-sized."""

    N, Q, k, LIMIT = 600, 400, 8, 256

    @staticmethod
    def _big_intermediate(fn, args, limit):
        from tests.test_streaming_fit import _iter_eqns

        jaxpr = jax.make_jaxpr(fn)(*args)
        for eqn in _iter_eqns(jaxpr.jaxpr):
            for var in eqn.outvars:
                shape = getattr(var.aval, "shape", ())
                big = [s for s in shape if s >= limit]
                if len(big) >= 2:
                    return eqn, shape
        return None

    def test_knn_search_streams(self):
        Xq, Xt = _points(self.Q, seed=0), _points(self.N, seed=1)
        hit = self._big_intermediate(
            lambda a, b: knn.knn_search(
                a, b, self.k, block_q=128, block_t=128
            ),
            (Xq, Xt), self.LIMIT,
        )
        assert hit is None, f"dense intermediate {hit[1]} in {hit[0]}"

    def test_checker_catches_dense_path(self):
        """Self-test: the sweep DOES flag a materialized Q x N matrix."""
        Xq, Xt = _points(self.Q, seed=0), _points(self.N, seed=1)
        hit = self._big_intermediate(
            lambda a, b: jnp.argsort(knn.sq_dists(a, b), axis=1)[:, :self.k],
            (Xq, Xt), self.LIMIT,
        )
        assert hit is not None

    def test_mean_var_streams(self):
        X, y = _points(self.N, seed=2), jnp.ones((self.N,))
        Xs = _points(self.Q, seed=3)
        spec = GPSpec.create_vecchia([0.8, 0.8], 0.05, neighbors=self.k,
                                     block_rows=128)
        g = GP.fit(X, y, spec)
        hit = self._big_intermediate(
            lambda a: g.mean_var(a), (Xs,), self.LIMIT
        )
        assert hit is None, f"dense intermediate {hit[1]} in {hit[0]}"

    def test_nlml_streams(self):
        X, y = _points(self.N, seed=4), jnp.ones((self.N,))
        spec = GPSpec.create_vecchia([0.8, 0.8], 0.05, neighbors=self.k,
                                     block_rows=128)
        hit = self._big_intermediate(
            lambda a, b: GP.fit(a, b, spec).nlml(a, b), (X, y), self.LIMIT
        )
        assert hit is None, f"dense intermediate {hit[1]} in {hit[0]}"


# ---------------------------------------------------------------------------
# 2. convergence to the exact GP
# ---------------------------------------------------------------------------


class TestExactAgreement:
    @pytest.mark.parametrize("kernel", ["se", "matern52"])
    def test_full_conditioning_matches_exact(self, kernel):
        """At k = N every query conditions on the whole training set: the
        prediction IS the exact GP's (<= 1e-4, the acceptance gate; noise
        0.1 keeps the f32 Cholesky well-conditioned — both sides factorize
        the same matrix under different row orders)."""
        X, y, Xs, _, spec = _vecchia_problem(N=160, k=160, kernel=kernel,
                                             noise=0.1)
        mu, var = GP.fit(X, y, spec).mean_var(Xs)
        st = exact_gp.fit(X, y, _exact_params(spec), kernel)
        mu_e, var_e = exact_gp.mean_var(st, Xs)
        np.testing.assert_allclose(np.asarray(mu), np.asarray(mu_e),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(var), np.asarray(var_e),
                                   atol=1e-4)

    @pytest.mark.parametrize("kernel", ["se", "matern52"])
    def test_nlml_telescopes_to_exact(self, kernel):
        """At k >= N-1 the ordered conditionals multiply back to the exact
        joint density (chain rule), so the NLMLs agree."""
        X, y, _, _, spec = _vecchia_problem(N=120, k=119, kernel=kernel)
        v = float(GP.fit(X, y, spec).nlml(X, y))
        e = float(exact_gp.nlml(X, y, _exact_params(spec), kernel))
        assert abs(v - e) <= 1e-3 * max(1.0, abs(e))

    def test_prediction_error_decreases_in_k(self):
        """|mu_k - mu_exact| is (weakly) decreasing along a k ladder."""
        X, y, Xs, _, spec = _vecchia_problem(N=200, k=4, noise=0.1)
        st = exact_gp.fit(X, y, _exact_params(spec), "se")
        mu_e, _ = exact_gp.mean_var(st, Xs)
        errs = []
        for k in (4, 16, 64, 200):
            mu, _ = GP.fit(X, y, spec.replace(neighbors=k)).mean_var(Xs)
            errs.append(float(jnp.max(jnp.abs(mu - mu_e))))
        assert errs[-1] <= 1e-4
        assert all(b <= a + 1e-6 for a, b in zip(errs, errs[1:])), errs

    def test_nlml_partial_conditioning_is_finite_and_ordered(self):
        """Small-k NLML is a valid (higher-entropy) bound-ish surrogate:
        finite, and moving k toward N moves it toward the exact value."""
        X, y, _, _, spec = _vecchia_problem(N=150, k=4)
        e = float(exact_gp.nlml(X, y, _exact_params(spec), "se"))
        gaps = []
        for k in (4, 32, 149):
            v = float(GP.fit(X, y, spec.replace(neighbors=k)).nlml(X, y))
            assert np.isfinite(v)
            gaps.append(abs(v - e))
        assert gaps[2] <= gaps[0]


# ---------------------------------------------------------------------------
# 3. the clustered-spatial regime
# ---------------------------------------------------------------------------


class TestClusteredAccuracy:
    def _data(self):
        return make_clustered_dataset(
            1500, extent=6.0, length_scale=0.15, noise=0.02, n_bumps=120,
            seed=0,
        )

    def test_beats_every_global_expansion(self):
        """The headline claim (benchmarks/vecchia.py measures the same
        thing at scale with wall-clock): short-lengthscale clustered data
        defeats every global basis at matched hyperparameters, while
        nearest-neighbor conditioning tracks the local structure."""
        X, y, Xs, ys = self._data()
        eps = [4.714, 4.714]

        def rmse(mu):
            return float(jnp.sqrt(jnp.mean((mu - ys) ** 2)))

        v = GP.fit(X, y, GPSpec.create_vecchia(eps, 0.02, neighbors=32))
        r_v = rmse(v.mean_var(Xs)[0])
        globals_ = {
            "hermite": GPSpec.create(12, eps, noise=0.02),
            "rff_se": GPSpec.create_rff(eps, noise=0.02, num_features=256,
                                        seed=0),
            "rff_matern52": GPSpec.create_rff(
                eps, noise=0.02, kernel="matern52", num_features=256, seed=0
            ),
        }
        for name, spec in globals_.items():
            r_g = rmse(GP.fit(X, y, spec).mean_var(Xs)[0])
            assert r_v < r_g, f"vecchia {r_v:.4f} !< {name} {r_g:.4f}"

    def test_clustered_generator_contract(self):
        X, y, Xs, ys = make_clustered_dataset(300, seed=1)
        assert X.shape == (300, 2) and y.shape == (300,)
        assert Xs.shape == (30, 2) and ys.shape == (30,)
        # deterministic in seed
        X2, y2, *_ = make_clustered_dataset(300, seed=1)
        np.testing.assert_array_equal(np.asarray(X), np.asarray(X2))
        np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))


# ---------------------------------------------------------------------------
# 4. the Approximation protocol
# ---------------------------------------------------------------------------


class TestProtocol:
    def test_both_families_registered(self):
        assert available_approximations() == ["fagp", "vecchia"]
        assert get_approximation("vecchia") is vecchia.VECCHIA
        assert get_approximation("fagp").capabilities >= {
            "fit", "predict", "mean_var", "update", "nlml", "optimize",
        }

    def test_refusals_are_structured(self):
        X, y, Xs, _, spec = _vecchia_problem(N=60, k=8)
        g = GP.fit(X, y, spec)
        with pytest.raises(UnsupportedError, match="does not support") as ei:
            g.predict(Xs)
        assert (ei.value.layer, ei.value.capability) == (
            "approximation", "predict",
        )
        assert ei.value.spec is spec
        with pytest.raises(UnsupportedError, match="does not support") as ei:
            GP.optimize(X, y, spec)
        assert ei.value.capability == "optimize"
        with pytest.raises(UnsupportedError, match="n_features"):
            g.n_features

    def test_fagp_entry_points_refuse_vecchia_specs(self):
        """The module-level fagp functions run ONE family; a vecchia spec
        is bounced toward the facade with a structured error."""
        X, y, _, _, spec = _vecchia_problem(N=60, k=8)
        with pytest.raises(UnsupportedError, match="does not support") as ei:
            fagp.fit(X, y, spec)
        assert (ei.value.layer, ei.value.capability) == (
            "approximation", "fagp",
        )

    def test_bank_refuses_vecchia_specs(self):
        from repro.bank import GPBank

        _, _, _, _, spec = _vecchia_problem(N=60, k=8)
        with pytest.raises(UnsupportedError, match="does not support"):
            GPBank.create(spec, capacity=4)

    def test_spec_validation(self):
        with pytest.raises(ValueError, match="kernel must be one of"):
            GPSpec.create_vecchia([0.8], 0.05, kernel="rbf")
        with pytest.raises(ValueError, match="neighbors >= 1"):
            GPSpec.create_vecchia([0.8], 0.05, neighbors=0)
        with pytest.raises(ValueError, match="unknown approximation"):
            GPSpec.create(6, eps=[0.8], approximation="svgp")

    def test_fit_input_validation(self):
        X, y, _, _, spec = _vecchia_problem(N=40, k=8)
        with pytest.raises(ValueError, match="p="):
            GP.fit(jnp.concatenate([X, X[:, :1]], axis=1), y, spec)
        with pytest.raises(ValueError, match="exceeds"):
            GP.fit(X[:4], y[:4], spec)  # k=8 > N=4

    def test_describe_names_the_family(self):
        _, _, _, _, spec = _vecchia_problem(k=24, kernel="matern52")
        d = spec.describe()
        assert "vecchia" in d and "matern52" in d and "24" in d


class TestSessionLifecycle:
    def test_update_equals_refit_exactly(self):
        """Vecchia's update is concatenation — the updated session is
        BIT-identical to a refit on the union (no approximation drift)."""
        X, y, _, _, spec = _vecchia_problem(N=80, k=12)
        Xn, yn, *_ = make_gp_dataset(20, 2, seed=7)
        up = GP.fit(X, y, spec).update(Xn, yn)
        re = GP.fit(jnp.concatenate([X, Xn]), jnp.concatenate([y, yn]), spec)
        Xs = _points(25, seed=8)
        np.testing.assert_array_equal(np.asarray(up.mean_var(Xs)[0]),
                                      np.asarray(re.mean_var(Xs)[0]))
        assert up.state.n_train == 100

    def test_update_task_mismatch_raises(self):
        X, y, _, _, spec = _vecchia_problem(N=40, k=8)
        g = GP.fit(X, jnp.stack([y, -y], axis=1), spec)
        with pytest.raises(ValueError, match="task"):
            g.update(X[:4], y[:4])

    def test_multioutput_matches_per_task(self):
        X, y, Xs, _, spec = _vecchia_problem(N=90, k=10)
        Y = jnp.stack([y, 2.0 * y, y - 0.5], axis=1)
        g = GP.fit(X, Y, spec)
        assert g.n_tasks == 3
        mu, var = g.mean_var(Xs)
        assert mu.shape == (Xs.shape[0], 3) and var.shape == (Xs.shape[0],)
        for t, yt in enumerate([y, 2.0 * y, y - 0.5]):
            mu_t, var_t = GP.fit(X, yt, spec).mean_var(Xs)
            np.testing.assert_allclose(np.asarray(mu[:, t]),
                                       np.asarray(mu_t), atol=1e-4)
            np.testing.assert_allclose(np.asarray(var), np.asarray(var_t),
                                       atol=1e-6)

    def test_checkpoint_roundtrip_bit_exact(self, tmp_path):
        X, y, Xs, _, spec = _vecchia_problem(N=70, k=9, kernel="matern52")
        g = GP.fit(X, y, spec)
        g.save(tmp_path)
        re = GP.load(tmp_path)
        assert isinstance(re.state, vecchia.VecchiaState)
        assert re.spec.approximation == "vecchia"
        assert re.spec.kernel == "matern52" and re.spec.neighbors == 9
        np.testing.assert_array_equal(np.asarray(re.state.X),
                                      np.asarray(g.state.X))
        np.testing.assert_array_equal(np.asarray(re.state.y),
                                      np.asarray(g.state.y))
        np.testing.assert_array_equal(np.asarray(re.mean_var(Xs)[0]),
                                      np.asarray(g.mean_var(Xs)[0]))

    def test_load_with_mismatched_spec_raises(self, tmp_path):
        X, y, _, _, spec = _vecchia_problem(N=50, k=6)
        GP.fit(X, y, spec).save(tmp_path)
        with pytest.raises(ValueError, match="mismatch"):
            GP.load(tmp_path, spec=spec.replace(neighbors=12))

    def test_with_spec_swaps_knobs_rejects_structure(self):
        X, y, _, _, spec = _vecchia_problem(N=50, k=6)
        g = GP.fit(X, y, spec)
        assert g.with_spec(block_rows=64).spec.block_rows == 64
        with pytest.raises(ValueError, match="mismatch"):
            g.with_spec(neighbors=12)
        with pytest.raises(ValueError, match="mismatch"):
            g.with_spec(kernel="matern52")
