"""Telemetry battery: metrics registry, span tracer, recompile watchdog,
exporters, and the claims the instrumented serving stack makes about them.

The subsystem's contracts, each pinned here:

* Counters / gauges / histograms follow Prometheus semantics (monotonic
  counters, ``le``-inclusive cumulative buckets), reject type conflicts
  and bucket redefinitions, and survive concurrent recording exactly.
* ``snapshot()`` and ``render_prometheus()`` expose the SAME series —
  every counter/gauge series in the snapshot appears verbatim in the
  text exposition with the same value.
* Collectors hold bound methods weakly: a dead engine's flush callback
  is pruned instead of pinning the engine (and its bank) forever.
* ANY interleaving of nested spans + instants across threads produces
  JSONL that ``tools/check_trace.py`` accepts: schema keys present,
  phases valid, durations non-negative, spans properly nested per
  (pid, tid) track (property-based via tests/hypcompat).
* The recompile watchdog catches a shape-polymorphic call through a
  registered executable (raise mode) and stays SILENT across arbitrary
  submit/observe/ingest churn on a warmed engine.
* The no-op defaults allocate nothing on the record path (tracemalloc).
* ``LatencyStats`` memory is bounded: the reservoir never exceeds its
  bound while true counts keep counting, and stays a uniform sample.
* The checkpoint store counts reaped dead-writer staging dirs and async
  worker failures on the process-default registry.
* ``tools/check_bench.py`` hard-rejects a BENCH_obs.json whose recorded
  overhead ratio or recompile count is out of contract.
"""
from __future__ import annotations

import gc
import importlib.util
import json
import os
import subprocess
import sys
import tempfile
import threading
import urllib.request
from pathlib import Path
from random import Random

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.bank import BankRouter, FleetEngine, GPBank, LatencyStats
from repro.core.gp import GPSpec
from repro.data import make_gp_dataset
from repro.obs import (
    NULL,
    NULL_TRACER,
    MetricsRegistry,
    NullTracer,
    RecompileError,
    RecompileWatchdog,
    SPAN_SCHEMA_KEYS,
    Tracer,
    serving_watchdog,
    set_default,
    start_metrics_server,
)
from repro.obs.metrics import _NULL_INSTRUMENT

from hypcompat import given, settings, st  # hypothesis, or fixed examples

ROOT = Path(__file__).resolve().parents[1]

_spec = importlib.util.spec_from_file_location(
    "check_trace", ROOT / "tools" / "check_trace.py")
check_trace_mod = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_trace_mod)


def _fleet(B, N, p, n, *, seed=0):
    spec = GPSpec.create(n, eps=[0.8] * p, rho=2.0, noise=0.05,
                         backend="jnp")
    Xb = np.zeros((B, N, p), np.float32)
    yb = np.zeros((B, N), np.float32)
    for s in range(B):
        X, y, *_ = make_gp_dataset(N, p, seed=seed + s)
        Xb[s], yb[s] = np.asarray(X), np.asarray(y)
    return GPBank.fit(jnp.asarray(Xb), jnp.asarray(yb), spec)


# --------------------------------------------------------------------------
# registry: instrument semantics
# --------------------------------------------------------------------------


class TestInstruments:
    def test_counter_monotone_and_labelled_series(self):
        reg = MetricsRegistry()
        c = reg.counter("reqs_total", "requests", tenant="a")
        c.inc()
        c.inc(4)
        assert c.value == 5
        assert c.series == 'reqs_total{tenant="a"}'
        # same (name, labels) -> same instrument; new labels -> new series
        assert reg.counter("reqs_total", tenant="a") is c
        other = reg.counter("reqs_total", tenant="b")
        assert other is not c and other.value == 0

    def test_gauge_set_inc_dec(self):
        g = MetricsRegistry().gauge("depth")
        g.set(7.0)
        g.inc(2.0)
        g.dec()
        assert g.value == 8.0

    def test_histogram_buckets_are_le_inclusive(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat", buckets=(1.0, 2.0, 4.0))
        for v in (0.5, 2.0, 3.0, 100.0):
            h.record(v)
        snap = reg.snapshot()["histograms"]["lat"]
        # 2.0 lands in le=2.0 (inclusive), 100.0 only in +Inf
        assert snap["buckets"] == {"1.0": 1, "2.0": 2, "4.0": 3, "+Inf": 4}
        assert snap["count"] == 4
        assert snap["sum"] == pytest.approx(105.5)

    def test_record_many_matches_loop_of_records(self):
        vals = list(np.random.default_rng(0).exponential(0.01, 200))
        r1, r2 = MetricsRegistry(), MetricsRegistry()
        h1, h2 = r1.histogram("h"), r2.histogram("h")
        for v in vals:
            h1.record(v)
        h2.record_many(vals)
        assert h1.counts == h2.counts
        assert h1.sum == pytest.approx(h2.sum)
        assert h1.count == h2.count

    def test_type_conflict_and_bucket_redefinition_rejected(self):
        reg = MetricsRegistry()
        reg.counter("x_total")
        with pytest.raises(TypeError, match="already registered"):
            reg.gauge("x_total")
        reg.histogram("h", buckets=(1.0, 2.0))
        with pytest.raises(ValueError, match="different"):
            reg.histogram("h", buckets=(1.0, 2.0, 3.0))
        with pytest.raises(ValueError, match="sorted"):
            reg.histogram("h2", buckets=(2.0, 1.0))

    def test_concurrent_recording_is_exact(self):
        reg = MetricsRegistry()
        c = reg.counter("hits_total")
        h = reg.histogram("work", buckets=(0.5,))

        def pound():
            for _ in range(5000):
                c.inc()
                h.record(0.25)

        threads = [threading.Thread(target=pound) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert c.value == 20000
        assert h.count == 20000 and h.counts[0] == 20000


# --------------------------------------------------------------------------
# exporters: one schema, two views
# --------------------------------------------------------------------------


def _populated_registry():
    reg = MetricsRegistry()
    reg.counter("served_total", "queries served", tenant="a").inc(3)
    reg.counter("served_total", tenant="b").inc(5)
    reg.gauge("queue_depth").set(11)
    h = reg.histogram("latency_seconds", buckets=(0.01, 0.1))
    for v in (0.005, 0.05, 0.5):
        h.record(v)
    return reg


class TestExporters:
    def test_snapshot_schema(self):
        snap = _populated_registry().snapshot()
        assert set(snap) == {"counters", "gauges", "histograms"}
        assert snap["counters"]['served_total{tenant="a"}'] == 3
        assert snap["gauges"]["queue_depth"] == 11
        json.dumps(snap)                     # JSON-serializable, always

    def test_prometheus_round_trip_matches_snapshot(self):
        reg = _populated_registry()
        snap = reg.snapshot()
        text = reg.render_prometheus()
        values = {}
        for line in text.splitlines():
            if line.startswith("#") or not line:
                continue
            series, val = line.rsplit(" ", 1)
            values[series] = float(val)
        for series, v in snap["counters"].items():
            assert values[series] == v
        for series, v in snap["gauges"].items():
            assert values[series] == v
        # histogram expands to cumulative _bucket + _sum + _count
        assert values['latency_seconds_bucket{le="0.01"}'] == 1
        assert values['latency_seconds_bucket{le="0.1"}'] == 2
        assert values['latency_seconds_bucket{le="+Inf"}'] == 3
        assert values["latency_seconds_count"] == 3
        assert "# TYPE served_total counter" in text
        assert "# TYPE latency_seconds histogram" in text

    def test_http_endpoint_serves_both_formats(self):
        reg = _populated_registry()
        server = start_metrics_server(reg, port=0)
        try:
            with urllib.request.urlopen(server.url, timeout=5) as r:
                body = r.read().decode()
            assert 'served_total{tenant="a"} 3' in body
            with urllib.request.urlopen(
                server.url + ".json", timeout=5
            ) as r:
                snap = json.loads(r.read())
            assert snap == reg.snapshot()
        finally:
            server.shutdown()

    def test_collectors_flush_at_scrape_and_die_with_owner(self):
        reg = MetricsRegistry()

        class Engine:
            def __init__(self):
                self.flushes = 0

            def flush(self):
                self.flushes += 1
                reg.counter("flushes_total").inc()

        eng = Engine()
        reg.add_collector(eng.flush)
        reg.snapshot()
        reg.render_prometheus()
        assert eng.flushes == 2
        del eng
        gc.collect()
        # dead owner: collector pruned silently, scrape unaffected
        snap = reg.snapshot()
        assert snap["counters"]["flushes_total"] == 2
        assert len(reg._collectors) == 0
        # plain closures are held strongly
        hits = []
        reg.add_collector(lambda: hits.append(1))
        gc.collect()
        reg.snapshot()
        assert hits == [1]


# --------------------------------------------------------------------------
# tracer: valid Chrome-trace JSONL under any interleaving
# --------------------------------------------------------------------------


def _emit_random_tree(tracer, rng, depth=0):
    for i in range(rng.randrange(0, 4 - depth)):
        with tracer.span(f"d{depth}_{i}", depth=depth):
            if depth < 3 and rng.random() < 0.7:
                _emit_random_tree(tracer, rng, depth + 1)
            if rng.random() < 0.4:
                tracer.instant("tick", i=i)


class TestTracer:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_random_interleavings_validate(self, seed):
        rng = Random(seed)
        tracer = Tracer()
        worker = threading.Thread(
            target=_emit_random_tree, args=(tracer, Random(seed + 1)))
        worker.start()
        _emit_random_tree(tracer, rng)
        worker.join()
        with tracer.span("outer"):
            with tracer.span("inner"):
                pass
        for ev in tracer.events():
            assert all(k in ev for k in SPAN_SCHEMA_KEYS)
            assert ev["ph"] in ("X", "i")
            if ev["ph"] == "X":
                assert ev["dur"] >= 0
        with tempfile.TemporaryDirectory() as td:
            path = Path(td) / "t.jsonl"
            n = tracer.write_jsonl(path)
            assert n == len(tracer)
            errors = check_trace_mod.check_trace(
                path, expect=("outer", "inner"))
            assert errors == []

    def test_buffer_bound_counts_drops(self):
        tracer = Tracer(limit=3)
        for i in range(5):
            tracer.instant(f"e{i}")
        assert len(tracer) == 3 and tracer.dropped == 2
        tracer.clear()
        assert len(tracer) == 0 and tracer.dropped == 0

    def test_to_chrome_envelope(self):
        tracer = Tracer()
        with tracer.span("s", bucket=8):
            pass
        doc = tracer.to_chrome()
        assert doc["traceEvents"][0]["args"] == {"bucket": 8}
        assert doc["displayTimeUnit"] == "ms"

    def test_null_tracer_writes_empty_file(self, tmp_path):
        p = tmp_path / "empty.jsonl"
        assert NullTracer().write_jsonl(p) == 0
        assert p.read_text() == ""


class TestCheckTraceValidator:
    def _errs(self, tmp_path, lines, **kw):
        p = tmp_path / "t.jsonl"
        p.write_text("\n".join(lines) + "\n" if lines else "")
        return check_trace_mod.check_trace(p, **kw)

    def _ev(self, **over):
        ev = {"name": "s", "ph": "X", "ts": 0, "dur": 10, "pid": 1,
              "tid": 1}
        ev.update(over)
        return json.dumps(ev)

    def test_rejects_malformed_lines(self, tmp_path):
        assert any("empty" in e for e in self._errs(tmp_path, []))
        assert any("not JSON" in e
                   for e in self._errs(tmp_path, ["{oops"]))
        assert any("missing keys" in e
                   for e in self._errs(tmp_path, ['{"name": "x"}']))
        assert any("unknown phase" in e
                   for e in self._errs(tmp_path, [self._ev(ph="B")]))
        assert any("bad dur" in e
                   for e in self._errs(tmp_path, [self._ev(dur=-1)]))

    def test_rejects_overlapping_non_nested_spans(self, tmp_path):
        bad = [self._ev(name="a", ts=0, dur=100),
               self._ev(name="b", ts=50, dur=100)]
        assert any("without nesting" in e for e in self._errs(tmp_path, bad))
        ok = [self._ev(name="a", ts=0, dur=100),
              self._ev(name="b", ts=10, dur=20),
              self._ev(name="c", ts=40, dur=20),
              self._ev(name="d", ts=200, dur=5, tid=2)]
        assert self._errs(tmp_path, ok) == []

    def test_expect_flags_missing_stage(self, tmp_path):
        lines = [self._ev(name="dispatch")]
        assert self._errs(tmp_path, lines, expect=("dispatch",)) == []
        assert any("never traced" in e
                   for e in self._errs(tmp_path, lines,
                                       expect=("harvest",)))


# --------------------------------------------------------------------------
# recompile watchdog
# --------------------------------------------------------------------------


class TestWatchdog:
    def test_catches_shape_polymorphic_call(self):
        f = jax.jit(lambda x: x * 2.0)
        f(jnp.zeros(4, jnp.float32))
        wd = RecompileWatchdog(mode="raise").register("f", f)
        wd.arm()
        assert wd.check("steady") == {}
        f(jnp.zeros(8, jnp.float32))        # new shape -> new executable
        with pytest.raises(RecompileError, match=r"f \+1"):
            wd.check("leak")
        assert wd.recompiles == 1 and wd.events[0][0] == "leak"
        # baseline advanced: the same compile is reported once
        assert wd.check("after") == {}

    def test_warn_and_count_modes(self):
        f = jax.jit(lambda x: x + 1.0)
        f(jnp.zeros(2, jnp.float32))
        reg = MetricsRegistry()
        wd = RecompileWatchdog(
            mode="warn", counter=reg.counter("recompiles_total"))
        wd.register("f", f).arm()
        f(jnp.zeros(3, jnp.float32))
        with pytest.warns(RuntimeWarning, match="recompile detected"):
            wd.check("churn")
        assert reg.snapshot()["counters"]["recompiles_total"] == 1
        wd.mode = "count"
        f(jnp.zeros(5, jnp.float32))
        assert wd.check() == {"f": 1}       # silent, still counted
        assert wd.recompiles == 2

    def test_register_rejects_non_jitted(self):
        with pytest.raises(TypeError, match="_cache_size"):
            RecompileWatchdog().register("f", lambda x: x)
        with pytest.raises(ValueError, match="mode"):
            RecompileWatchdog(mode="explode")

    def test_serving_watchdog_covers_the_serving_path(self):
        reg = MetricsRegistry()
        wd = serving_watchdog(mode="count", metrics=reg)
        assert {
            "bank_write_slot", "bank_update_scatter",
            "bank_gathered_posterior", "bank_downdate_scatter",
            "bank_refit_scatter", "hyperopt_lane_step",
        } <= set(wd.sizes())
        # the counter series exists even before any growth
        assert "serve_recompiles_total" in reg.snapshot()["counters"]

    def test_silent_across_engine_churn(self):
        bank = _fleet(4, 8, 2, 4)
        wd = serving_watchdog(mode="count")
        router = BankRouter(bank, microbatch=8, ingest_chunk=4)
        eng = FleetEngine(router, auto_pump=False, max_coalesce=2,
                          watchdog=wd)
        rng = np.random.default_rng(7)
        # warm each dispatch rung plus one ingest round, then arm
        for rung in eng.buckets:
            for _ in range(rung):
                eng.submit(int(rng.integers(0, 4)),
                           rng.uniform(-1, 1, 2).astype(np.float32))
            eng.pump(max_blocks=1)
            eng.drain()
        for t in range(4):
            eng.observe(t, rng.uniform(-1, 1, 2).astype(np.float32),
                        float(rng.normal()))
        eng.ingest()
        wd.arm()
        wd.recompiles, wd.events = 0, []
        wd.mode = "raise"                   # any growth now fails loudly
        for _ in range(6):
            for _ in range(int(rng.integers(1, 17))):
                eng.submit(int(rng.integers(0, 4)),
                           rng.uniform(-1, 1, 2).astype(np.float32))
            for t in range(4):
                eng.observe(t, rng.uniform(-1, 1, 2).astype(np.float32),
                            float(rng.normal()))
            eng.drain()
            eng.ingest()
        wd.check("churn-final")
        assert wd.recompiles == 0 and wd.events == []


# --------------------------------------------------------------------------
# the off switch: no-op defaults allocate nothing
# --------------------------------------------------------------------------


class TestNullPath:
    def test_null_registry_hands_out_the_shared_singleton(self):
        assert NULL.counter("a") is _NULL_INSTRUMENT
        assert NULL.gauge("b") is NULL.histogram("c")
        assert NULL.snapshot() == {"counters": {}, "gauges": {},
                                   "histograms": {}}

    def test_record_path_is_allocation_free(self):
        import tracemalloc
        from repro.obs import metrics as m, trace as tr
        c = NULL.counter("x")
        h = NULL.histogram("y")
        span = NULL_TRACER.span("s")
        obs_files = {m.__file__, tr.__file__}
        tracemalloc.start()
        try:
            s0 = tracemalloc.take_snapshot()
            for _ in range(2000):
                c.inc()
                c.inc(3)
                h.record(0.5)
                h.record_many((0.1, 0.2))
                with span:
                    pass
                NULL_TRACER.instant("i")
            s1 = tracemalloc.take_snapshot()
        finally:
            tracemalloc.stop()
        leaked = [
            stat for stat in s1.compare_to(s0, "lineno")
            if stat.size_diff > 0
            and any(fr.filename in obs_files for fr in stat.traceback)
        ]
        assert leaked == [], [str(s) for s in leaked]


# --------------------------------------------------------------------------
# LatencyStats: bounded reservoir
# --------------------------------------------------------------------------


class TestLatencyReservoir:
    def test_exact_below_the_bound(self):
        stats = LatencyStats(bound=8)
        for i in range(8):
            stats.record("t", float(i))
        assert stats.samples["t"] == [float(i) for i in range(8)]
        assert stats.count("t") == 8

    def test_memory_bounded_counts_unbounded(self):
        stats = LatencyStats(bound=64, seed=1)
        n = 6400
        for i in range(n):
            stats.record("t", float(i))
        buf = stats.samples["t"]
        assert len(buf) == 64
        assert stats.count("t") == n
        # Algorithm R keeps a uniform sample of the WHOLE stream: the
        # reservoir mean sits near the stream mean, not near the tail
        assert abs(np.mean(buf) - (n - 1) / 2) < 900
        p50, _ = stats.percentiles("t")
        assert abs(p50 - n / 2) < 1500

    def test_bound_validation_and_timeouts_separate(self):
        with pytest.raises(ValueError):
            LatencyStats(bound=0)
        stats = LatencyStats(bound=4)
        stats.record("t", 0.01)
        stats.record_timeout("t")
        assert stats.count("t") == 1 and stats.timeouts["t"] == 1


# --------------------------------------------------------------------------
# instrumented engine end-to-end
# --------------------------------------------------------------------------


class TestEngineTelemetry:
    def test_engine_publishes_counters_and_spans(self):
        bank = _fleet(4, 8, 2, 4)
        reg, tracer = MetricsRegistry(), Tracer()
        router = BankRouter(bank, microbatch=8, metrics=reg, tracer=tracer)
        eng = FleetEngine(router, auto_pump=False, metrics=reg,
                          tracer=tracer)
        for i in range(16):
            eng.submit(i % 4, np.zeros(2, np.float32))
        eng.pump(max_blocks=1)
        out = eng.drain()
        assert len(out) == 16 and all(r.ok for r in out.values())
        m = eng.metrics()
        snap = m["registry"]
        assert snap["counters"]["serve_admitted_total"] == 16
        assert sum(
            v for k, v in snap["counters"].items()
            if k.startswith("serve_dispatch_blocks_total")
        ) >= 1
        names = {e["name"] for e in tracer.events()}
        assert {"bucket_select", "coalesce", "dispatch", "device_wait",
                "harvest"} <= names

    def test_unwired_engine_reports_empty_registry(self):
        bank = _fleet(4, 8, 2, 4)
        eng = FleetEngine(BankRouter(bank, microbatch=8))
        eng.submit(0, np.zeros(2, np.float32))
        eng.drain()
        assert eng.metrics()["registry"] == {
            "counters": {}, "gauges": {}, "histograms": {}}


# --------------------------------------------------------------------------
# checkpoint store telemetry (process-default registry)
# --------------------------------------------------------------------------


class TestStoreTelemetry:
    def test_dead_writer_staging_dirs_reaped_and_counted(self, tmp_path):
        from repro.checkpoint import store
        reg = MetricsRegistry()
        prev = set_default(reg)
        try:
            d = tmp_path / "ck"
            d.mkdir()
            child = subprocess.Popen([sys.executable, "-c", "pass"])
            child.wait()
            (d / f"tmp.3.{child.pid}").mkdir()     # verifiably dead writer
            (d / f"tmp.4.{os.getpid()}").mkdir()   # OUR pid: never touched
            assert store.latest_step(d) is None
            assert not (d / f"tmp.3.{child.pid}").exists()
            assert (d / f"tmp.4.{os.getpid()}").exists()
            snap = reg.snapshot()
            assert snap["counters"][
                "checkpoint_stale_tmp_reaped_total"] == 1
        finally:
            set_default(prev)

    def test_async_failure_counted_at_failure_time(self, tmp_path):
        from repro.checkpoint.store import AsyncCheckpointer
        reg = MetricsRegistry()
        prev = set_default(reg)
        try:
            blocked = tmp_path / "ckpt"
            blocked.write_text("a file where the dir should go")
            ac = AsyncCheckpointer(blocked)
            ac.save(1, {"w": np.zeros(2, np.float32)})
            if ac._thread is not None:
                ac._thread.join()              # failure already counted...
            assert reg.snapshot()["counters"][
                "checkpoint_async_failures_total"] == 1
            with pytest.raises(Exception):
                ac.wait()                      # ...and raised exactly once
            ac.wait()
        finally:
            set_default(prev)


# --------------------------------------------------------------------------
# check_bench gates BENCH_obs.json claims
# --------------------------------------------------------------------------


def _good_obs_payload():
    return {
        "schema": 1,
        "smoke": True,
        "config": {"B": 64, "microbatch": 64, "queries": 4096},
        "results": [
            {"name": "obs-null", "seconds": 0.030,
             "derived": "B=64;mb=64;nq=4096"},
            {"name": "obs-instrumented", "seconds": 0.031,
             "derived": "B=64;mb=64;nq=4096"},
            {"name": "obs-churn-watchdog", "seconds": 0.2,
             "derived": "B=16;cap=8;rounds=4"},
        ],
        "overhead_ratio": 1.02,
        "recompiles": 0,
        "trace_events": 1490,
    }


def _run_check(tmp_path, payload):
    path = tmp_path / "BENCH_obs.json"
    path.write_text(json.dumps(payload))
    return subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_bench.py"), str(path)],
        capture_output=True, text=True, cwd=ROOT,
    )


class TestCheckBenchObsGate:
    def test_accepts_in_contract_payload(self, tmp_path):
        r = _run_check(tmp_path, _good_obs_payload())
        assert r.returncode == 0, r.stdout + r.stderr

    def test_rejects_overhead_above_contract(self, tmp_path):
        bad = _good_obs_payload()
        bad["overhead_ratio"] = 1.2
        r = _run_check(tmp_path, bad)
        assert r.returncode == 1
        assert "above allowed maximum" in r.stdout

    def test_rejects_any_recompile(self, tmp_path):
        bad = _good_obs_payload()
        bad["recompiles"] = 1
        r = _run_check(tmp_path, bad)
        assert r.returncode == 1
        assert "recompiles" in r.stdout

    def test_rejects_missing_claims(self, tmp_path):
        bad = _good_obs_payload()
        del bad["overhead_ratio"]
        r = _run_check(tmp_path, bad)
        assert r.returncode == 1
