"""Distribution-correctness tests on 8 virtual devices (subprocess-isolated:
XLA device count is locked at first jax init, so each test body runs in its
own python with XLA_FLAGS=--xla_force_host_platform_device_count=8)."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

# every body builds a mesh via launch.mesh.make_local_mesh and runs under
# jax.set_mesh; skip (not fail) on jax versions predating that API, same as
# the shard_map guard in test_compress
pytestmark = pytest.mark.skipif(
    not hasattr(jax.sharding, "AxisType") or not hasattr(jax, "set_mesh"),
    reason="mesh AxisType/set_mesh API unavailable in this jax version",
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, timeout=420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


class TestDistributedFAGP:
    def test_fit_distributed_matches_single(self):
        run_sub("""
            import jax, numpy as np, jax.numpy as jnp
            from repro.core import fagp, mercer, distributed as dgp
            from repro.data import make_gp_dataset
            from repro.launch.mesh import make_local_mesh

            X, y, Xs, ys = make_gp_dataset(512, 2, seed=0)
            spec = fagp.GPSpec.create(8, eps=[0.8, 0.8], rho=2.0, noise=0.05)
            st = fagp.fit(X, y, spec)
            mu_ref, var_ref = fagp.predict_mean_var(st, Xs)

            mesh = make_local_mesh(data=2, model=4)
            dst = dgp.fit_distributed(X, y, spec, mesh)
            np.testing.assert_allclose(np.asarray(dst.u), np.asarray(st.u),
                                       rtol=5e-3, atol=1e-4)
            mu, var = dgp.predict_distributed(Xs, dst, mesh)
            np.testing.assert_allclose(np.asarray(mu), np.asarray(mu_ref),
                                       rtol=1e-3, atol=1e-4)
            np.testing.assert_allclose(np.asarray(var), np.asarray(var_ref),
                                       rtol=5e-3, atol=1e-6)
            # the distributed state is a full session: serving entry points
            # accept it directly, nothing re-passed
            mu2, var2 = fagp.predict_mean_var(dst, Xs)
            np.testing.assert_allclose(np.asarray(mu2), np.asarray(mu_ref),
                                       rtol=1e-3, atol=1e-4)
            print("OK fit_distributed")
        """)

    def test_collectives_present_in_fit_hlo(self):
        """The distributed fit must actually contain the M x M all-reduce."""
        run_sub("""
            import jax
            from repro.configs import fagp as fcfg
            from repro.core import distributed as dgp
            from repro.core.fagp import FAGPConfig
            from repro.launch.mesh import make_local_mesh
            from repro.parallel import hints
            import dataclasses

            wl = dataclasses.replace(
                fcfg.SHAPES["fit_10k"], N=4096, p=2,
                cfg=FAGPConfig(n=6, store_train=False))
            mesh = make_local_mesh(data=2, model=4)
            with jax.set_mesh(mesh), hints.activate(mesh):
                txt = dgp.lower_fit(wl, mesh).compile().as_text()
            assert "all-reduce" in txt, "expected Gram all-reduce in HLO"
            print("OK collectives")
        """)


class TestDistributedTrainStep:
    @pytest.mark.parametrize("arch_id", ["smollm-360m", "olmoe-1b-7b", "mamba2-130m"])
    def test_sharded_train_step_matches_single_device(self, arch_id):
        run_sub(f"""
            import dataclasses, numpy as np, jax, jax.numpy as jnp
            from repro.configs import ARCHS
            from repro.models import get_model
            from repro.parallel import hints, sharding
            from repro.launch.mesh import make_local_mesh
            from repro.launch.steps import make_train_step
            from repro import optim

            cfg = ARCHS["{arch_id}"].SMOKE
            # make dims divide the small mesh (model axis = 2)
            model = get_model(cfg)
            params = model.init_params(jax.random.key(0))
            ocfg = optim.AdamWConfig(lr=1e-3)
            opt = optim.init(params, ocfg)
            rng = np.random.default_rng(0)
            batch = {{"tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, size=(8, 64)), jnp.int32)}}

            step = make_train_step(model, ocfg)
            p1, o1, m1 = jax.jit(step)(params, opt, batch)

            mesh = make_local_mesh(data=4, model=2)
            p_sh = sharding.param_shardings(params, cfg, mesh)
            o_sh = sharding.opt_state_shardings(opt, params, cfg, mesh)
            b_sh = sharding.batch_shardings(batch, mesh)
            with jax.set_mesh(mesh), hints.activate(mesh):
                params_d = jax.device_put(params, p_sh)
                opt_d = jax.device_put(opt, o_sh)
                batch_d = jax.device_put(batch, b_sh)
                p2, o2, m2 = jax.jit(
                    step, in_shardings=(p_sh, o_sh, b_sh),
                    out_shardings=(p_sh, o_sh, None),
                )(params_d, opt_d, batch_d)

            l1, l2 = float(m1["loss"]), float(m2["loss"])
            assert abs(l1 - l2) < 5e-2 * max(1.0, abs(l1)), (l1, l2)
            # spot-check a parameter after one update
            fa = jax.tree_util.tree_leaves(p1)[0]
            fb = jax.tree_util.tree_leaves(p2)[0]
            np.testing.assert_allclose(
                np.asarray(fa, np.float32), np.asarray(fb, np.float32),
                rtol=5e-2, atol=5e-3)
            print("OK", l1, l2)
        """)

    def test_decode_step_sharded_cache(self):
        run_sub("""
            import numpy as np, jax, jax.numpy as jnp
            from repro.configs import ARCHS
            from repro.models import get_model
            from repro.parallel import hints, sharding
            from repro.launch.mesh import make_local_mesh

            cfg = ARCHS["qwen2-1.5b"].SMOKE
            model = get_model(cfg)
            params = model.init_params(jax.random.key(0))
            B, S = 8, 32
            cache = model.init_cache(B, S)
            batch = {"token": jnp.zeros((B, 1), jnp.int32),
                     "pos": jnp.asarray(3, jnp.int32)}
            logits_ref, _ = jax.jit(model.decode_step)(params, batch, cache)

            mesh = make_local_mesh(data=4, model=2)
            p_sh = sharding.param_shardings(params, cfg, mesh)
            c_sh = sharding.cache_shardings(cache, cfg, mesh)
            b_sh = sharding.batch_shardings(batch, mesh)
            with jax.set_mesh(mesh), hints.activate(mesh):
                out = jax.jit(model.decode_step,
                              in_shardings=(p_sh, b_sh, c_sh),
                              out_shardings=(None, c_sh))(
                    jax.device_put(params, p_sh),
                    jax.device_put(batch, b_sh),
                    jax.device_put(cache, c_sh))
            np.testing.assert_allclose(
                np.asarray(out[0], np.float32), np.asarray(logits_ref, np.float32),
                rtol=2e-2, atol=2e-2)
            print("OK decode")
        """)


class TestServeModeMoE:
    def test_serve_mode_matches_dense(self):
        """Tiny-T (decode) path: sharded weights + token slicing must equal
        the dense reference bit-for-bit (modulo f32 reduction order)."""
        run_sub("""
            import dataclasses, numpy as np, jax, jax.numpy as jnp
            from repro.models import moe as M
            from repro.models.config import ModelConfig
            from repro.parallel import hints
            from repro.launch.mesh import make_local_mesh

            cfg = ModelConfig(
                arch_id="t", family="moe", n_layers=1, d_model=64, n_heads=4,
                n_kv_heads=4, d_ff=32, vocab=64, n_experts=8, top_k=2,
                d_expert=32, n_shared_experts=1, capacity_factor=8.0, fsdp=True)
            p = M.moe_init(jax.random.key(0), cfg, jnp.float32)
            rng = np.random.default_rng(0)
            x = jnp.asarray(rng.standard_normal((16, 64)).astype(np.float32))
            y_ref, _ = M.moe_apply(p, x, cfg)
            mesh = make_local_mesh(data=2, model=4)
            with hints.activate(mesh), jax.set_mesh(mesh):
                T_l = 16 // 2
                assert (T_l * cfg.top_k) // cfg.n_experts <= 64  # serve mode on
                y_s, _ = jax.jit(lambda p, x: M.moe_apply_sharded(p, x, cfg))(p, x)
            np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_ref),
                                       rtol=2e-5, atol=2e-5)
            print("OK serve-mode moe")
        """)


class TestPipeline:
    def test_gpipe_matches_sequential(self):
        """4-stage pipeline over 'model' == sequential stage application."""
        run_sub("""
            import numpy as np, jax, jax.numpy as jnp
            from repro.parallel.pipeline import gpipe
            from repro.launch.mesh import make_local_mesh

            S, M, mb, d = 4, 8, 4, 32
            rng = np.random.default_rng(0)
            W = jnp.asarray(rng.standard_normal((S, d, d)).astype(np.float32) / np.sqrt(d))
            b = jnp.asarray(rng.standard_normal((S, d)).astype(np.float32) * 0.1)
            x = jnp.asarray(rng.standard_normal((M, mb, d)).astype(np.float32))

            def stage(p, x):
                return jnp.tanh(x @ p["w"] + p["b"])

            params = {"w": W, "b": b}
            # sequential reference
            y_ref = x
            for s in range(S):
                y_ref = jnp.tanh(y_ref @ W[s] + b[s])

            mesh = make_local_mesh(data=2, model=4)
            with jax.set_mesh(mesh):
                y = gpipe(stage, params, x, mesh, axis="model")
            np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                       rtol=2e-5, atol=2e-5)
            print("OK gpipe fwd")
        """)

    def test_gpipe_differentiable(self):
        run_sub("""
            import numpy as np, jax, jax.numpy as jnp
            from repro.parallel.pipeline import gpipe
            from repro.launch.mesh import make_local_mesh

            S, M, mb, d = 4, 4, 2, 16
            rng = np.random.default_rng(1)
            W = jnp.asarray(rng.standard_normal((S, d, d)).astype(np.float32) / np.sqrt(d))
            x = jnp.asarray(rng.standard_normal((M, mb, d)).astype(np.float32))
            mesh = make_local_mesh(data=2, model=4)

            def stage(p, xin):
                return jnp.tanh(xin @ p)

            def loss_pp(W):
                y = gpipe(stage, W, x, mesh, axis="model")
                return jnp.sum(y ** 2)

            def loss_seq(W):
                y = x
                for s in range(S):
                    y = jnp.tanh(y @ W[s])
                return jnp.sum(y ** 2)

            with jax.set_mesh(mesh):
                g_pp = jax.grad(loss_pp)(W)
            g_seq = jax.grad(loss_seq)(W)
            np.testing.assert_allclose(np.asarray(g_pp), np.asarray(g_seq),
                                       rtol=1e-4, atol=1e-5)
            print("OK gpipe grad")
        """)


class TestElasticScaling:
    def test_resume_on_bigger_mesh(self, tmp_path):
        """Train on 1 device, checkpoint, resume the SAME run on an 8-device
        mesh: the loop restores, reshards, and continues — elastic scaling
        end-to-end."""
        ckpt = tmp_path / "ck"
        body = f"""
            import numpy as np, jax, jax.numpy as jnp
            from repro.configs import ARCHS
            from repro.models import get_model
            from repro.parallel import hints, sharding
            from repro.launch.mesh import make_local_mesh
            from repro.launch.steps import make_train_step
            from repro.runtime import TrainLoopConfig, train_loop
            from repro.data import TokenStream
            from repro import optim

            cfg = ARCHS["smollm-360m"].SMOKE
            model = get_model(cfg)
            params = model.init_params(jax.random.key(0))
            ocfg = optim.AdamWConfig(lr=1e-3)
            opt = optim.init(params, ocfg)
            stream = TokenStream(vocab=cfg.vocab, seq=32, global_batch=8, seed=0)

            n_dev = len(jax.devices())
            if n_dev == 1:
                step = jax.jit(make_train_step(model, ocfg))
                sh = None
                ctx = None
            else:
                mesh = make_local_mesh(data=4, model=2)
                p_sh = sharding.param_shardings(params, cfg, mesh)
                o_sh = sharding.opt_state_shardings(opt, params, cfg, mesh)
                params = jax.device_put(params, p_sh)
                opt = jax.device_put(opt, o_sh)
                step = jax.jit(make_train_step(model, ocfg),
                               in_shardings=(p_sh, o_sh, None),
                               out_shardings=(p_sh, o_sh, None))
                sh = (p_sh, o_sh)

            loop = TrainLoopConfig(steps=STEPS, ckpt_every=10, log_every=100,
                                   ckpt_dir={str(ckpt)!r}, handle_signals=False,
                                   async_ckpt=False)
            p, o, rep = train_loop(step, params, opt, lambda s: stream.batch(s),
                                   loop, shardings=sh, log_fn=lambda s: None)
            print("FINAL", rep["final_step"], rep["history"][-1]["loss"])
        """
        # phase 1: single device, 10 steps
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        r1 = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(body.replace("STEPS", "10"))],
            capture_output=True, text=True, timeout=420, env=env)
        assert r1.returncode == 0, r1.stdout + r1.stderr[-2000:]
        assert "FINAL 10" in r1.stdout
        # phase 2: resume same ckpt dir on 8 virtual devices to step 20
        out = run_sub(body.replace("STEPS", "20"))
        assert "FINAL 20" in out
