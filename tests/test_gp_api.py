"""Redesign-hazard tests for the self-describing GP session API.

Pins the contracts of the `GP` facade / `GPSpec` redesign:
  1. spec/state mismatches raise (never silently evaluate wrong features);
  2. the removed (params, cfg) shims raise TypeError naming the
     replacement (they were deprecated for two releases, then removed);
  3. multi-output (N, T) fits share one factorization and match T
     independent single-output fits on both backends;
  4. the public surface of `repro.core.gp` is snapshot so future PRs cannot
     change it silently;
  5. backends declare capabilities: an unsupported spec is refused with the
     structured UnsupportedError at dispatch, not a crash deep in kernel
     preparation;
  6. the approximation field is backward compatible: pre-protocol specs and
     checkpoints (no ``approximation`` anywhere) are the ``"fagp"`` family,
     bit-exactly, and an unknown family name raises at spec construction.
"""
import dataclasses
import json
import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import fagp, mercer
from repro.core.approximation import UnsupportedError
from repro.core.gp import GP, GPSpec
from repro.data import make_gp_dataset


def _problem(N=200, p=2, n=6, seed=0, **kw):
    X, y, Xs, ys = make_gp_dataset(N, p, seed=seed)
    spec = GPSpec.create(n, eps=[0.8] * p, rho=2.0, noise=0.05, **kw)
    return X, y, Xs, spec


class TestPublicSurface:
    def test_public_api_snapshot(self):
        """The session API is GP + GPSpec plus the approximation-protocol
        types; widening or renaming it is a deliberate act, not a drive-by."""
        import repro.core.gp as gpmod

        assert sorted(gpmod.__all__) == [
            "Approximation", "GP", "GPSpec", "UnsupportedError",
        ]

    def test_facade_method_surface(self):
        expected = {"fit", "from_state", "optimize", "predict", "mean_var",
                    "update", "nlml", "with_spec"}
        assert expected <= {m for m in dir(GP) if not m.startswith("_")}

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_fit_predict_roundtrip(self, backend):
        """The acceptance gate: GP.fit(...).predict(Xs) round-trips with
        nothing re-passed, on both backends."""
        X, y, Xs, spec = _problem(backend=backend)
        gp = GP.fit(X, y, spec)
        mu, cov = gp.predict(Xs)
        mu2, var = gp.mean_var(Xs)
        assert mu.shape == (Xs.shape[0],) and cov.shape == (Xs.shape[0],) * 2
        np.testing.assert_allclose(np.asarray(mu2), np.asarray(mu),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(var), np.diag(np.asarray(cov)),
                                   rtol=1e-3, atol=1e-5)


class TestSpecStateMismatch:
    def test_cfg_passing_is_removed(self):
        """The (params, cfg) shims were deprecated for two releases; passing
        any cfg now raises TypeError instead of warning."""
        X, y, Xs, spec = _problem(n=6)
        st = fagp.fit(X, y, spec)
        with pytest.raises(TypeError, match="removed"):
            fagp.predict_mean_var(st, Xs, fagp.FAGPConfig(n=8))
        with pytest.raises(TypeError, match="removed"):
            fagp.predict(st, Xs, fagp.FAGPConfig(n=8))
        with pytest.raises(TypeError, match="removed"):
            fagp.fit_update(st, Xs, jnp.zeros(Xs.shape[0]),
                            fagp.FAGPConfig(n=8))

    def test_with_spec_rejects_structural_change(self):
        X, y, _, spec = _problem()
        gp = GP.fit(X, y, spec)
        with pytest.raises(ValueError, match="spec/state mismatch"):
            gp.with_spec(n=spec.n + 2)
        with pytest.raises(ValueError, match="spec/state mismatch"):
            gp.with_spec(index_set="hyperbolic_cross")
        with pytest.raises(ValueError, match="spec/state mismatch"):
            gp.with_spec(noise=jnp.asarray(0.5, jnp.float32))

    def test_with_spec_rejects_enabling_store_train(self):
        X, y, _, spec = _problem()
        gp = GP.fit(X, y, spec)  # store_train defaults to False
        with pytest.raises(ValueError, match="store_train"):
            gp.with_spec(store_train=True)

    def test_with_spec_backend_swap_is_valid_and_agrees(self):
        """The one legitimate serve-time use: swap execution backends."""
        X, y, Xs, spec = _problem()
        gp = GP.fit(X, y, spec)
        mu_j, var_j = gp.mean_var(Xs)
        gp_p = gp.with_spec(backend="pallas")
        assert gp_p.spec.backend == "pallas"
        mu_p, var_p = gp_p.mean_var(Xs)
        np.testing.assert_allclose(np.asarray(mu_p), np.asarray(mu_j),
                                   rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(var_p), np.asarray(var_j),
                                   rtol=5e-3, atol=1e-6)

    def test_wrong_input_dim_raises(self):
        X, y, _, spec = _problem(p=2)
        X3 = jnp.concatenate([X, X[:, :1]], axis=1)
        with pytest.raises(ValueError, match="p=2"):
            fagp.fit(X3, y, spec)
        with pytest.raises(ValueError, match="p=2"):
            fagp.nlml(X3, y, spec)

    def test_specless_state_with_wrong_spec_raises(self):
        """An internal spec-less state still validates on attach: a spec
        whose n cannot regenerate the fitted index set raises instead of
        evaluating garbage features."""
        X, y, Xs, spec = _problem(n=6)
        st = fagp._fit(X, y, spec, jnp.asarray(spec.indices(2)))
        assert st.spec is None
        with pytest.raises(ValueError, match="spec/state mismatch"):
            st.with_spec(spec.replace(n=8))

    def test_spec_plus_cfg_is_a_type_error(self):
        """Passing BOTH a GPSpec and a cfg must not silently merge them."""
        X, y, _, spec = _problem()
        with pytest.raises(TypeError, match="removed"):
            fagp.fit(X, y, spec, fagp.FAGPConfig(n=4))
        with pytest.raises(TypeError, match="removed"):
            fagp.nlml(X, y, spec, jnp.asarray(spec.indices(2)), 4)

    def test_specless_state_needs_explicit_attach(self):
        """Internal states without a baked spec are rejected by the
        spec-first entry points and accepted after with_spec."""
        X, y, Xs, spec = _problem()
        st = fagp._fit(X, y, spec, jnp.asarray(spec.indices(2)))
        assert st.spec is None
        with pytest.raises(ValueError, match="no baked GPSpec"):
            fagp.predict_mean_var(st, Xs)
        mu, _ = fagp.predict_mean_var(st.with_spec(spec), Xs)
        mu_ref, _ = GP.fit(X, y, spec).mean_var(Xs)
        np.testing.assert_allclose(np.asarray(mu), np.asarray(mu_ref),
                                   rtol=1e-5, atol=1e-6)

    def test_specless_state_rejects_spec_with_draws(self):
        """Cross-family aliasing guard: an RFF spec whose arange(2R) index
        table happens to equal a 1-D hermite full grid must NOT attach to a
        spec-less hermite state — the spectral draws cannot be verified, so
        the attach is refused outright."""
        X, y, *_ = make_gp_dataset(40, 1, seed=0)
        spec = GPSpec.create(8, eps=[0.8], noise=0.05)
        st = fagp._fit(X, y, spec, jnp.asarray(spec.indices(1)))
        assert st.spec is None
        alias = GPSpec.create_rff([0.8], noise=0.05, num_features=4, seed=0)
        assert alias.indices().shape == np.asarray(st.idx).shape
        with pytest.raises(ValueError, match="omega"):
            st.with_spec(alias)

    def test_create_rejects_rff_args_on_hermite(self):
        """A forgotten expansion= must not silently drop num_features."""
        with pytest.raises(ValueError, match="num_features"):
            GPSpec.create(8, eps=[0.8], num_features=64)
        with pytest.raises(ValueError, match="no omega"):
            GPSpec.create(8, eps=[0.8], omega=jnp.ones((4, 1)))

    def test_expansion_is_structural(self):
        """Two specs with the same-shaped index table but different
        expansion families must not interchange on a fitted state (an
        rff_se factorization is not an rff_matern52 factorization)."""
        X, y, _, _ = _problem()
        spec = GPSpec.create_rff([0.8, 0.8], noise=0.05, num_features=32,
                                 seed=3)
        gp = GP.fit(X, y, spec)
        with pytest.raises(ValueError, match="spec/state mismatch"):
            gp.with_spec(expansion="rff_matern52")


class TestRemovedShims:
    """The PR-2 (params, cfg) shims are two releases old and REMOVED: every
    legacy call shape raises TypeError naming the replacement (the tests
    that used to assert exactly-one-DeprecationWarning now assert the
    raise)."""

    def _legacy(self):
        X, y, Xs, spec = _problem()
        return X, y, Xs, spec, spec.params, spec.cfg

    @pytest.mark.parametrize("call", ["fit", "predict", "predict_mean_var",
                                      "fit_update", "nlml"])
    def test_shim_raises_typeerror(self, call):
        X, y, Xs, spec, params, cfg = self._legacy()
        st_new = fagp.fit(X, y, spec)
        with pytest.raises(TypeError, match="removed"):
            if call == "fit":
                fagp.fit(X, y, params, cfg)
            elif call == "predict":
                fagp.predict(st_new, Xs, cfg)
            elif call == "predict_mean_var":
                fagp.predict_mean_var(st_new, Xs, cfg)
            elif call == "fit_update":
                fagp.fit_update(st_new, Xs, jnp.zeros(Xs.shape[0]), cfg)
            else:
                idx = jnp.asarray(spec.indices(2))
                fagp.nlml(X, y, params, idx, spec.n)

    def test_distributed_shims_raise(self):
        from repro.core import distributed

        X, y, Xs, spec, params, cfg = self._legacy()
        with pytest.raises(TypeError, match="removed"):
            distributed.fit_distributed(X, y, params, cfg, None)
        st = fagp.fit(X, y, spec)
        with pytest.raises(TypeError, match="removed"):
            distributed.predict_distributed(
                Xs, (st.u, st.chol, st.sqrtlam), params, cfg, None
            )

    def test_new_api_is_warning_free(self):
        X, y, Xs, spec = _problem()
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            gp = GP.fit(X, y, spec)
            gp.predict(Xs)
            gp.mean_var(Xs)
            gp.update(Xs, jnp.zeros(Xs.shape[0]))
            gp.nlml(X, y)
        ours = [w for w in rec if "will be removed in the next release"
                in str(w.message)]
        assert ours == []


class TestMultiOutput:
    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_matches_per_task_fits(self, backend):
        """(N, T) fit == T independent fits (shared Cholesky, per-task u)."""
        X, y, Xs, spec = _problem(backend=backend)
        tasks = [y, 2.0 * y, y - 0.5]
        Y = jnp.stack(tasks, axis=1)
        gp = GP.fit(X, Y, spec)
        assert gp.n_tasks == 3
        mu, var = gp.mean_var(Xs)
        assert mu.shape == (Xs.shape[0], 3) and var.shape == (Xs.shape[0],)
        for t, yt in enumerate(tasks):
            mu_t, var_t = GP.fit(X, yt, spec).mean_var(Xs)
            np.testing.assert_allclose(np.asarray(mu[:, t]), np.asarray(mu_t),
                                       rtol=1e-3, atol=1e-4)
            # variance is task-independent (one kernel, one noise level)
            np.testing.assert_allclose(np.asarray(var), np.asarray(var_t),
                                       rtol=1e-4, atol=1e-6)

    def test_update_matches_refit(self):
        X, y, Xs, spec = _problem()
        Y = jnp.stack([y, -y], axis=1)
        Xn, yn, *_ = make_gp_dataset(32, 2, seed=9)
        Yn = jnp.stack([yn, -yn], axis=1)
        up = GP.fit(X, Y, spec).update(Xn, Yn)
        re = GP.fit(jnp.concatenate([X, Xn]), jnp.concatenate([Y, Yn]), spec)
        np.testing.assert_allclose(np.asarray(up.state.u),
                                   np.asarray(re.state.u),
                                   rtol=5e-3, atol=1e-4)

    def test_update_task_count_mismatch_raises(self):
        X, y, _, spec = _problem()
        gp = GP.fit(X, jnp.stack([y, -y], axis=1), spec)
        Xn, yn, *_ = make_gp_dataset(8, 2, seed=3)
        with pytest.raises(ValueError, match="task"):
            gp.update(Xn, yn)

    def test_nlml_sums_per_task(self):
        X, y, _, spec = _problem()
        Y = jnp.stack([y, 1.5 * y], axis=1)
        total = float(fagp.nlml(X, Y, spec))
        per = sum(float(fagp.nlml(X, Y[:, t], spec)) for t in range(2))
        assert abs(total - per) < 1e-2 * max(1.0, abs(per))

    def test_full_cov_predict_shares_cov(self):
        X, y, Xs, spec = _problem()
        Y = jnp.stack([y, 2.0 * y], axis=1)
        mu, cov = GP.fit(X, Y, spec).predict(Xs)
        _, cov_single = GP.fit(X, y, spec).predict(Xs)
        assert mu.shape == (Xs.shape[0], 2)
        np.testing.assert_allclose(np.asarray(cov), np.asarray(cov_single),
                                   rtol=1e-5, atol=1e-6)


class TestApproximationField:
    """Satellite: the pluggable-family spec field is backward compatible."""

    def test_default_spec_is_fagp(self):
        """Every pre-protocol construction path yields the fagp family with
        the vecchia-only fields unset — old code is untouched."""
        _, _, _, spec = _problem()
        assert spec.approximation == "fagp"
        assert spec.kernel is None and spec.neighbors is None
        rff = GPSpec.create_rff([0.8, 0.8], noise=0.05, num_features=16,
                                seed=0)
        assert rff.approximation == "fagp"

    def test_unknown_approximation_raises_at_construction(self):
        """A typo'd family name fails at GPSpec.create, listing the
        registry — not at fit time deep in dispatch."""
        with pytest.raises(ValueError, match="unknown approximation"):
            GPSpec.create(6, eps=[0.8, 0.8], approximation="vechia")

    def test_vecchia_only_fields_rejected_on_fagp(self):
        with pytest.raises(ValueError, match="vecchia-only"):
            GPSpec.create(6, eps=[0.8, 0.8], kernel="se")
        with pytest.raises(ValueError, match="vecchia-only"):
            GPSpec.create(6, eps=[0.8, 0.8], neighbors=16)

    def test_old_style_checkpoint_loads_as_fagp_bit_exactly(self, tmp_path):
        """A manifest written before the approximation protocol (no
        approximation/kernel/neighbors keys) restores as an fagp session
        with identical leaves."""
        X, y, Xs, spec = _problem()
        gp = GP.fit(X, y, spec)
        gp.save(tmp_path)
        # age the manifest: strip every protocol-era key, as a pre-PR-10
        # writer would have produced
        mf = tmp_path / "step_0000000000" / "manifest.json"
        m = json.loads(mf.read_text())
        for k in ("approximation", "kernel", "neighbors"):
            m["metadata"]["spec"].pop(k, None)
        mf.write_text(json.dumps(m))
        re = GP.load(tmp_path)
        assert re.spec.approximation == "fagp"
        assert re.spec.kernel is None and re.spec.neighbors is None
        for leaf in ("lam", "sqrtlam", "chol", "u", "b"):
            np.testing.assert_array_equal(
                np.asarray(getattr(re.state, leaf)),
                np.asarray(getattr(gp.state, leaf)),
            )
        np.testing.assert_array_equal(np.asarray(re.mean_var(Xs)[0]),
                                      np.asarray(gp.mean_var(Xs)[0]))


class TestBackendCapabilities:
    def test_pallas_refuses_deep_recurrence(self):
        """supports() refuses at dispatch with the structured
        UnsupportedError instead of crashing inside kernel preparation."""
        from repro.core import expansions

        X, y, _, _ = _problem(p=1, n=4)
        deep = GPSpec.create(expansions._PALLAS_MAX_N + 1, eps=[0.8],
                             backend="pallas")
        with pytest.raises(ValueError, match="does not support"):
            fagp.fit(X, y, deep)
        # the refusal is one structured type across the whole codebase,
        # carrying where it came from and what was missing
        with pytest.raises(UnsupportedError) as ei:
            fagp.fit(X, y, deep)
        assert ei.value.layer == "backend"
        assert ei.value.capability == "pallas"
        assert ei.value.spec is deep
        assert isinstance(ei.value, ValueError)  # old handlers keep working

    def test_restricted_plugin_refused_cleanly(self):
        """A third-party backend declaring a capability limit is refused at
        the call boundary (the registry honours supports())."""
        base = fagp.get_backend("jnp")
        limited = dataclasses.replace(
            base, name="limited",
            supports=lambda spec: (
                None if spec.index_set == "full"
                else f"index_set={spec.index_set!r} not implemented"
            ),
        )
        fagp.register_backend(limited)
        try:
            X, y, _, _ = _problem()
            ok = GPSpec.create(4, eps=[0.8, 0.8], backend="limited")
            fagp.fit(X, y, ok)  # full grid: accepted
            bad = ok.replace(index_set="hyperbolic_cross", degree=4)
            with pytest.raises(ValueError, match="not implemented"):
                fagp.fit(X, y, bad)
        finally:
            fagp._BACKENDS.pop("limited", None)

    def test_unknown_backend_lists_registered(self):
        X, y, _, spec = _problem()
        with pytest.raises(ValueError, match="unknown backend"):
            fagp.fit(X, y, spec.replace(backend="cuda"))


class TestPaperModeErrorPath:
    def test_message_names_fitted_spec(self):
        """Satellite fix: the error validates on the *state* and reports the
        fitted spec, not a hardcoded FAGPConfig hint."""
        X, y, Xs, spec = _problem()
        st = fagp.fit(X, y, spec)  # store_train=False
        with pytest.raises(ValueError) as ei:
            fagp.predict(st, Xs, mode="paper")
        msg = str(ei.value)
        assert "store_train=True" in msg and "GPSpec" in msg
        assert "FAGPConfig" not in msg

    def test_paper_mode_works_when_stored(self):
        # N=50 keeps the paper chain's N x N f32 rounding inside tolerance
        # (same scale as test_fagp's paper-vs-fused comparison)
        X, y, Xs, spec = _problem(N=50, n=8)
        st = fagp.fit(X, y, spec.replace(store_train=True))
        mu_p, _ = fagp.predict(st, Xs, mode="paper")
        mu_f, _ = fagp.predict(st, Xs)
        np.testing.assert_allclose(np.asarray(mu_p), np.asarray(mu_f),
                                   atol=5e-3)


class TestOptimize:
    def test_optimize_recovers_noise_scale(self):
        """GP.optimize moves badly-initialized hyperparameters toward the
        truth and returns a fitted session at the learned values."""
        X, y, Xs, _ = _problem(N=300, seed=2)
        spec0 = GPSpec.create(6, eps=[2.5, 2.5], rho=2.0, noise=0.5)
        seen = []
        gp = GP.optimize(X, y, spec0, steps=60, lr=8e-2,
                         callback=lambda s, v, sp: seen.append(v))
        assert len(seen) >= 2 and seen[-1] < seen[0]  # NLML decreased
        assert float(gp.spec.noise) < 0.5  # moved off the bad init
        mu, _ = gp.mean_var(Xs)
        assert np.all(np.isfinite(np.asarray(mu)))
