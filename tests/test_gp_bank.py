"""Tests for the GPBank multi-tenant subsystem (repro.bank).

Pins the contracts of the bank tentpole:
  1. batched == loop-of-singles: GPBank.fit / mean_var / update agree with
     per-tenant single-model GP calls on BOTH backends (pallas in interpret
     mode on CPU) — serving the same states matches to <= 1e-5 abs (the
     acceptance gate), refitting matches to f32-fit tolerance;
  2. the bank Pallas kernel (bank grid axis in kernels/phi_gram) == the
     vmapped jnp moments, including ragged per-tenant row masks;
  3. membership churn (insert / evict / slot reuse) never recompiles the
     serving executable — pinned via jax.jit cache-miss counts;
  4. the router preserves per-ticket association for mixed-tenant traffic
     regardless of arrival order, microbatch packing, and tail padding,
     and its ingest path equals direct batched updates.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.bank import BankRouter, GPBank
from repro.bank import bank as bank_mod
from repro.core import fagp
from repro.core.gp import GP, GPSpec
from repro.data import make_gp_dataset


def _fleet(B, N, p, n, *, seed=0, backend="jnp", capacity=None):
    rng = np.random.default_rng(seed)
    spec = GPSpec.create(n, eps=[0.8] * p, rho=2.0, noise=0.05,
                         backend=backend)
    Xb = np.zeros((B, N, p), np.float32)
    yb = np.zeros((B, N), np.float32)
    for s in range(B):
        X, y, *_ = make_gp_dataset(N, p, seed=seed + s)
        Xb[s], yb[s] = np.asarray(X), np.asarray(y)
    bank = GPBank.fit(jnp.asarray(Xb), jnp.asarray(yb), spec,
                      capacity=capacity)
    Xq = jnp.asarray(rng.uniform(-1, 1, size=(3 * B, p)).astype(np.float32))
    tenants = [int(t) for t in rng.integers(0, B, 3 * B)]
    return bank, Xb, yb, spec, Xq, tenants


class TestBankMoments:
    @pytest.mark.parametrize("ragged", [False, True])
    def test_pallas_bank_kernel_matches_jnp_vmap(self, ragged):
        """The new bank grid axis in kernels/phi_gram == vmapped jnp scan
        moments, with and without per-slot row masks."""
        B, N, p, n = 5, 40, 2, 6
        rng = np.random.default_rng(3)
        spec = GPSpec.create(n, eps=[0.8] * p, rho=2.0, noise=0.05)
        Xb = jnp.asarray(rng.uniform(-1, 1, (B, N, p)).astype(np.float32))
        yb = jnp.asarray(rng.standard_normal((B, N)).astype(np.float32))
        mask = jnp.asarray(
            (rng.uniform(size=(B, N)) > 0.4).astype(np.float32)
        ) if ragged else jnp.ones((B, N), jnp.float32)
        idx_np = spec.indices(p)
        idx = jnp.asarray(idx_np)
        out = {}
        for name in ("jnp", "pallas"):
            be = fagp.get_backend(name)
            aux = be.prepare(idx_np, spec)
            out[name] = be.bank_moments(Xb, yb, spec, idx, aux, 64, mask)
        np.testing.assert_allclose(
            np.asarray(out["pallas"][0]), np.asarray(out["jnp"][0]),
            rtol=1e-3, atol=1e-3,
        )
        np.testing.assert_allclose(
            np.asarray(out["pallas"][1]), np.asarray(out["jnp"][1]),
            rtol=1e-3, atol=1e-3,
        )


class TestBatchedVsLoop:
    """The acceptance gate: a B=64 bank of small tenants (n=8, p=2) serves
    a mixed-tenant batch identically (<= 1e-5 abs) to a Python loop of
    single-model GP.mean_var over the same per-tenant sessions."""

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_mean_var_matches_loop_b64(self, backend):
        bank, *_ , Xq, tenants = _fleet(64, 8, 2, 8, backend=backend)
        mu, var = bank.mean_var(tenants, Xq)
        mu, var = np.asarray(mu), np.asarray(var)
        for t in sorted(set(tenants)):
            rows = np.flatnonzero(np.asarray(tenants) == t)
            gp = GP.from_state(bank.state(t))
            m1, v1 = gp.mean_var(Xq[jnp.asarray(rows)])
            np.testing.assert_allclose(mu[rows], np.asarray(m1), atol=1e-5)
            np.testing.assert_allclose(var[rows], np.asarray(v1), atol=1e-5)

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_bank_fit_matches_single_fits(self, backend):
        """Batched fit == per-tenant fit to f32-fit tolerance (independent
        factorizations, different reduction orders)."""
        bank, Xb, yb, spec, Xq, _ = _fleet(6, 24, 2, 6, backend=backend)
        for t in range(6):
            st = fagp.fit(jnp.asarray(Xb[t]), jnp.asarray(yb[t]), spec)
            m1, v1 = fagp.predict_mean_var(st, Xq[:8])
            m2, v2 = bank.mean_var([t] * 8, Xq[:8])
            np.testing.assert_allclose(
                np.asarray(m2), np.asarray(m1), rtol=5e-3, atol=2e-4
            )
            np.testing.assert_allclose(
                np.asarray(v2), np.asarray(v1), rtol=5e-3, atol=2e-4
            )

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_batched_update_matches_loop(self, backend):
        """GPBank.update == per-tenant fit_update on the same states,
        including a ragged (row-masked) ingest group."""
        bank, *_, Xq, _ = _fleet(6, 24, 2, 6, backend=backend)
        rng = np.random.default_rng(11)
        ids = [1, 4, 5]
        k = 8
        Xk = rng.uniform(-1, 1, size=(3, k, 2)).astype(np.float32)
        yk = rng.standard_normal((3, k)).astype(np.float32)
        mask = np.ones((3, k), np.float32)
        mask[2, 3:] = 0.0  # tenant 5 ingests only 3 real rows
        before = {t: bank.state(t) for t in ids}
        up = bank.update(ids, jnp.asarray(Xk), jnp.asarray(yk),
                         jnp.asarray(mask))
        for g, t in enumerate(ids):
            kept = int(mask[g].sum())
            st = fagp.fit_update(
                before[t], jnp.asarray(Xk[g, :kept]), jnp.asarray(yk[g, :kept])
            )
            m1, v1 = fagp.predict_mean_var(st, Xq[:6])
            m2, v2 = up.mean_var([t] * 6, Xq[:6])
            np.testing.assert_allclose(
                np.asarray(m2), np.asarray(m1), atol=1e-5
            )
            np.testing.assert_allclose(
                np.asarray(v2), np.asarray(v1), atol=1e-5
            )
        # untouched tenants keep their exact posterior
        m0a, _ = bank.mean_var([0] * 4, Xq[:4])
        m0b, _ = up.mean_var([0] * 4, Xq[:4])
        np.testing.assert_array_equal(np.asarray(m0a), np.asarray(m0b))

    def test_update_rejects_duplicate_tenants(self):
        bank, *_ = _fleet(4, 16, 2, 5)
        Xk = jnp.zeros((2, 3, 2))
        yk = jnp.zeros((2, 3))
        with pytest.raises(ValueError, match="duplicate tenant"):
            bank.update([2, 2], Xk, yk)

    def test_update_rejects_misshapen_mask(self):
        """A (1, k) mask would broadcast over every group and silently
        drop rows fleet-wide; the shape is validated like fit's."""
        bank, *_ = _fleet(4, 16, 2, 5)
        Xk = jnp.zeros((2, 3, 2))
        yk = jnp.zeros((2, 3))
        with pytest.raises(ValueError, match="mask must be"):
            bank.update([0, 1], Xk, yk, mask=jnp.ones((1, 3)))

    def test_incremental_binv_carry_matches_fresh_cache(self):
        """A bank whose serving cache was carried through update / insert /
        evict answers exactly like one that rebuilds the cache from
        scratch."""
        bank, *_, Xq, tenants = _fleet(5, 16, 2, 5, capacity=6)
        bank.mean_var(tenants[:6], Xq[:6])  # populate the parent cache
        rng = np.random.default_rng(8)
        Xk = jnp.asarray(rng.uniform(-1, 1, (2, 4, 2)).astype(np.float32))
        yk = jnp.asarray(rng.standard_normal((2, 4)).astype(np.float32))
        Xn, yn, *_ = make_gp_dataset(16, 2, seed=70)
        mutate = lambda b: (
            b.update([1, 3], Xk, yk).evict(0).insert("n", (Xn, yn))
        )
        carried = mutate(bank)
        assert "_binv_cache" in carried.__dict__  # cache rode along
        fresh = mutate(GPBank.from_states(bank.states(), capacity=6))
        assert "_binv_cache" not in fresh.__dict__
        q = ["n", 1, 3, 2, "n", 4]
        m1, v1 = carried.mean_var(q, Xq[:6])
        m2, v2 = fresh.mean_var(q, Xq[:6])
        np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
        np.testing.assert_allclose(
            np.asarray(v1), np.asarray(v2), rtol=1e-6, atol=1e-9
        )


class TestFallbackHooks:
    def test_backend_without_bank_hooks_falls_back_to_vmap(self):
        """A third-party backend that never heard of banks still works:
        GPBank vmaps its single-model moments and gathers over its feature
        map — and matches the native-hook result exactly."""
        base = fagp.get_backend("jnp")
        plain = dataclasses.replace(
            base, name="plain", bank_moments=None, bank_mean_var=None
        )
        fagp.register_backend(plain)
        try:
            bank, Xb, yb, spec, Xq, tenants = _fleet(4, 16, 2, 5)
            bank2 = GPBank.fit(
                jnp.asarray(Xb), jnp.asarray(yb),
                spec.replace(backend="plain"),
            )
            m1, v1 = bank.mean_var(tenants, Xq)
            m2, v2 = bank2.mean_var(tenants, Xq)
            np.testing.assert_allclose(
                np.asarray(m2), np.asarray(m1), atol=1e-6
            )
            np.testing.assert_allclose(
                np.asarray(v2), np.asarray(v1), atol=1e-6
            )
        finally:
            fagp._BACKENDS.pop("plain", None)


class TestRaggedFit:
    def test_masked_fit_equals_unpadded_fits(self):
        """Tenants with different true N on one fixed (B, N, p) stack: the
        row mask must make padding mathematically invisible."""
        B, N, p, n = 5, 32, 2, 6
        bank_full, Xb, yb, spec, Xq, _ = _fleet(B, N, p, n)
        true_n = [32, 20, 7, 32, 1]
        mask = np.zeros((B, N), np.float32)
        for t, cut in enumerate(true_n):
            mask[t, :cut] = 1.0
        bank = GPBank.fit(jnp.asarray(Xb), jnp.asarray(yb), spec,
                          mask=jnp.asarray(mask))
        for t, cut in enumerate(true_n):
            st = fagp.fit(
                jnp.asarray(Xb[t, :cut]), jnp.asarray(yb[t, :cut]), spec
            )
            m1, v1 = fagp.predict_mean_var(st, Xq[:6])
            m2, v2 = bank.mean_var([t] * 6, Xq[:6])
            np.testing.assert_allclose(
                np.asarray(m2), np.asarray(m1), rtol=5e-3, atol=2e-4
            )
            np.testing.assert_allclose(
                np.asarray(v2), np.asarray(v1), rtol=5e-3, atol=2e-4
            )

    def test_fully_masked_slot_serves_the_prior(self):
        """A reserved (capacity > B) slot holds the prior state (chol = I,
        u = b = 0) — exactly what create() builds."""
        bank, *_, spec, Xq, _ = _fleet(3, 16, 2, 5, capacity=5)
        st = dataclasses.replace(
            bank.stack,
            lam=bank.stack.lam[3], sqrtlam=bank.stack.sqrtlam[3],
            chol=bank.stack.chol[3], u=bank.stack.u[3], b=bank.stack.b[3],
        )
        np.testing.assert_allclose(
            np.asarray(st.chol), np.eye(bank.n_features), atol=1e-6
        )
        np.testing.assert_array_equal(np.asarray(st.u), 0.0)


class TestMembershipChurn:
    def test_insert_evict_reuse_slot_without_recompile(self):
        """The serving executable and the slot-write executable are keyed
        on the stack shapes only: churning tenants through a fixed-capacity
        bank must not add a single jit cache entry."""
        bank, Xb, yb, spec, Xq, _ = _fleet(3, 16, 2, 5, capacity=4)
        q = [0, 1, 2, 0]
        bank.mean_var(q, Xq[:4])  # warm every executable once
        X4, y4, *_ = make_gp_dataset(16, 2, seed=50)
        bank.insert("warm", (X4, y4))  # warm insert's fit+write path
        writes0 = bank_mod._write_slot._cache_size()
        serve0 = fagp._bank_gathered_posterior._cache_size()

        b = bank
        for r in range(3):  # churn: insert -> serve -> evict -> reinsert
            Xn, yn, *_ = make_gp_dataset(16, 2, seed=60 + r)
            b = b.insert(f"tenant-{r}", (Xn, yn))
            assert b.slot_of(f"tenant-{r}") == 3  # slot reused every round
            mu, var = b.mean_var([f"tenant-{r}", 0, 1, f"tenant-{r}"], Xq[:4])
            assert np.all(np.isfinite(np.asarray(mu)))
            b = b.evict(f"tenant-{r}")

        assert bank_mod._write_slot._cache_size() == writes0
        assert fagp._bank_gathered_posterior._cache_size() == serve0

    def test_insert_validates_spec_and_capacity(self):
        bank, Xb, yb, spec, *_ = _fleet(2, 16, 2, 5)
        X, y, *_ = make_gp_dataset(16, 2, seed=9)
        with pytest.raises(ValueError, match="bank is full"):
            bank.insert("t", (X, y))
        bank4 = GPBank.create(spec, 4)
        other = fagp.fit(X, y, spec.replace(n=4))
        with pytest.raises(ValueError, match="spec/state mismatch"):
            bank4.insert("t", other)
        hyper = fagp.fit(X, y, spec.replace(noise=jnp.float32(0.5)))
        with pytest.raises(ValueError, match="noise differs"):
            bank4.insert("t", hyper)
        with pytest.raises(ValueError, match="already in the bank"):
            bank.insert(0, (X, y))

    def test_store_train_is_downgraded_not_contradicted(self):
        """Banks never store per-tenant Phi; a store_train=True spec is
        normalized so unstacked states stay self-consistent (a state whose
        spec claims stored features while Phi is None would turn the
        paper-mode 'refit with store_train=True' guidance into a loop)."""
        B, N, p, n = 3, 16, 2, 5
        spec = GPSpec.create(n, eps=[0.8] * p, rho=2.0, noise=0.05,
                             store_train=True)
        Xb = np.zeros((B, N, p), np.float32)
        yb = np.zeros((B, N), np.float32)
        for s in range(B):
            X, y, *_ = make_gp_dataset(N, p, seed=s)
            Xb[s], yb[s] = np.asarray(X), np.asarray(y)
        bank = GPBank.fit(jnp.asarray(Xb), jnp.asarray(yb), spec)
        assert bank.spec.store_train is False
        st = bank.state(0)
        assert st.spec.store_train is False and st.Phi is None
        with pytest.raises(ValueError, match="store_train=True"):
            fagp.predict(st, jnp.asarray(Xb[0][:4]), mode="paper")

    def test_evicted_tenant_is_gone_and_states_roundtrip(self):
        bank, *_ = _fleet(3, 16, 2, 5)
        b = bank.evict(1)
        assert 1 not in b and len(b) == 2
        with pytest.raises(KeyError, match="not in this bank"):
            b.slot_of(1)
        rebuilt = GPBank.from_states(b.states(), capacity=3)
        Xq = jnp.asarray(
            np.random.default_rng(1).uniform(-1, 1, (4, 2)).astype(np.float32)
        )
        m1, v1 = b.mean_var([0, 2, 0, 2], Xq)
        m2, v2 = rebuilt.mean_var([0, 2, 0, 2], Xq)
        np.testing.assert_allclose(np.asarray(m2), np.asarray(m1), atol=1e-6)
        np.testing.assert_allclose(np.asarray(v2), np.asarray(v1), atol=1e-6)


class TestRouter:
    def test_mixed_tenant_order_preservation(self):
        """Tickets map back to the right (tenant, query) no matter how the
        batcher packs them: interleaved arrival, microbatch smaller than
        the backlog, padded tail."""
        bank, *_ = _fleet(4, 16, 2, 5)
        router = BankRouter(bank, microbatch=5)
        order = [0, 3, 1, 0, 2, 3, 3, 1, 0, 2, 1, 2, 0]  # 13 rows -> 3 blocks
        Xq = jnp.asarray(
            np.random.default_rng(7)
            .uniform(-1, 1, (len(order), 2))
            .astype(np.float32)
        )
        tickets = [
            (router.submit(t, np.asarray(Xq[i])), t, i)
            for i, t in enumerate(order)
        ]
        assert router.pending == len(order)
        results = router.flush()
        assert router.pending == 0
        assert set(results) == {tk for tk, _, _ in tickets}
        for tk, t, i in tickets:
            m1, v1 = bank.mean_var([t], Xq[i : i + 1])
            assert results[tk][0] == pytest.approx(float(m1[0]), abs=1e-6)
            assert results[tk][1] == pytest.approx(float(v1[0]), abs=1e-6)

    def test_flush_empty_is_noop(self):
        bank, *_ = _fleet(2, 16, 2, 5)
        assert BankRouter(bank).flush() == {}

    def test_ingest_equals_direct_updates(self):
        """Router ingest (grouped, padded, masked, multi-round) == direct
        batched updates with the same rows."""
        bank, *_, Xq, _ = _fleet(3, 16, 2, 5)
        rng = np.random.default_rng(21)
        rows = {0: 5, 2: 2}  # tenant 0 spans 2 chunks of 4 -> 2 rounds
        router = BankRouter(bank, ingest_chunk=4)
        direct = {t: bank.state(t) for t in rows}
        for t, cnt in rows.items():
            X = rng.uniform(-1, 1, (cnt, 2)).astype(np.float32)
            y = rng.standard_normal(cnt).astype(np.float32)
            for i in range(cnt):
                router.observe(t, X[i], y[i])
            direct[t] = fagp.fit_update(
                direct[t], jnp.asarray(X), jnp.asarray(y)
            )
        assert router.ingest() == 7
        for t in rows:
            m1, v1 = fagp.predict_mean_var(direct[t], Xq[:5])
            m2, v2 = router.bank.mean_var([t] * 5, Xq[:5])
            np.testing.assert_allclose(
                np.asarray(m2), np.asarray(m1), rtol=1e-4, atol=2e-5
            )
            np.testing.assert_allclose(
                np.asarray(v2), np.asarray(v1), rtol=1e-4, atol=2e-5
            )

    def test_ingest_buckets_group_axis_no_recompile(self):
        """Rounds with different tenant-mix sizes inside one power-of-two
        bucket reuse the same update executable, and the masked identity
        pad groups leave their pad-target slots bit-identical."""
        bank, *_ = _fleet(6, 16, 2, 5, capacity=8)
        rng = np.random.default_rng(33)
        router = BankRouter(bank, ingest_chunk=4)

        def observe(tenants):
            for t in tenants:
                router.observe(
                    t, rng.uniform(-1, 1, 2).astype(np.float32),
                    float(rng.standard_normal()),
                )

        spare = bank.state(3)   # slot 3 = first free slot -> pad target
        observe([0, 1, 2])      # G=3 -> bucket 4 (one pad group on slot 3)
        router.ingest()
        size0 = bank_mod._bank_update_scatter._cache_size()
        after = router.bank.state(3)
        np.testing.assert_array_equal(
            np.asarray(spare.chol), np.asarray(after.chol)
        )
        np.testing.assert_array_equal(
            np.asarray(spare.u), np.asarray(after.u)
        )
        observe([0, 2, 4, 5])   # G=4 -> same bucket, same executable
        router.ingest()
        assert bank_mod._bank_update_scatter._cache_size() == size0

    def test_failed_flush_restores_whole_backlog(self):
        """A mid-flush failure (tenant evicted from a bank swapped in
        behind the router) must not destroy the backlog: queries are
        idempotent reads, so EVERY ticket — including blocks that were
        served before the failure, whose results die with the exception —
        stays redeemable once the bank is repaired."""
        bank, *_ = _fleet(3, 16, 2, 5)
        router = BankRouter(bank, microbatch=2)
        x = np.zeros(2, np.float32)
        tickets = [router.submit(t, x) for t in (0, 1, 2, 0)]
        router.bank = bank.evict(2)  # breaks the second block only
        with pytest.raises(KeyError, match="not in this bank"):
            router.flush()
        assert router.pending == 4
        router.bank = bank  # repair
        results = router.flush()
        assert set(results) == set(tickets)

    def test_failed_ingest_restores_observations(self):
        """Same contract on the ingest path: a failing round restores its
        own rows and everything still queued; earlier rounds stay
        absorbed."""
        bank, *_ = _fleet(3, 16, 2, 5)
        router = BankRouter(bank, ingest_chunk=4)
        x = np.zeros(2, np.float32)
        for t in (0, 1):
            router.observe(t, x, 0.5)
        router.bank = bank.evict(1)
        with pytest.raises(KeyError, match="not in this bank"):
            router.ingest()
        router.bank = bank  # repair: both observations still queued
        assert router.ingest() == 2

    def test_unknown_tenant_rejected_at_submit(self):
        bank, *_ = _fleet(2, 16, 2, 5)
        router = BankRouter(bank)
        with pytest.raises(KeyError, match="not in this bank"):
            router.submit("ghost", np.zeros(2, np.float32))
        with pytest.raises(KeyError, match="not in this bank"):
            router.observe("ghost", np.zeros(2, np.float32), 0.0)
