"""FleetEngine battery: property-based interleavings, fault injection,
deadline semantics, bucket-shape pinning, latency metrics, bench gating.

The serving pipeline's correctness claims, each pinned here:

* ANY interleaving of submit / observe / drain / ingest preserves the
  ticket -> result association and matches direct ``GPBank.mean_var`` /
  ``GPBank.update`` calls to <= 1e-5 (property-based via tests/hypcompat —
  real `hypothesis` when installed, fixed examples otherwise; both
  backends).
* A dispatch that raises mid-flight restores the router backlog in order
  and leaves the bank state bit-identical; every ticket is redeemable
  after the fault is repaired.
* A deadline-expired ticket yields the documented sentinel
  (``mu = NaN``, ``var = inf``, ``timed_out=True``) and never blocks or
  corrupts tickets behind it.
* Bucket autotuning never mints a new executable: the serving jit cache
  is warmed once per ladder rung and stays FIXED across arbitrary
  traffic/bucket churn.
* Engine percentiles are exactly ``numpy.percentile`` over the recorded
  samples, and ``tools/check_bench.py`` hard-rejects a BENCH_serve.json
  whose recorded claims (speedup, dropped tickets, parity) are out of
  contract.
"""
from __future__ import annotations

import json
import math
import subprocess
import sys
from pathlib import Path

import numpy as np
import jax.numpy as jnp
import pytest

from repro.bank import (
    BankRouter, FleetEngine, GPBank, LatencyStats, QueueFull,
    TIMEOUT_MU, TIMEOUT_VAR,
)
from repro.core import fagp
from repro.core.gp import GPSpec
from repro.data import make_gp_dataset

from hypcompat import given, settings, st  # hypothesis, or fixed examples

ROOT = Path(__file__).resolve().parents[1]


def _fleet(B, N, p, n, *, seed=0, backend="jnp"):
    spec = GPSpec.create(n, eps=[0.8] * p, rho=2.0, noise=0.05,
                         backend=backend)
    Xb = np.zeros((B, N, p), np.float32)
    yb = np.zeros((B, N), np.float32)
    for s in range(B):
        X, y, *_ = make_gp_dataset(N, p, seed=seed + s)
        Xb[s], yb[s] = np.asarray(X), np.asarray(y)
    return GPBank.fit(jnp.asarray(Xb), jnp.asarray(yb), spec)


def _engine(bank, *, microbatch=8, ingest_chunk=4, **kw):
    router = BankRouter(bank, microbatch=microbatch,
                        ingest_chunk=ingest_chunk)
    return FleetEngine(router, **kw), router


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# --------------------------------------------------------------------------
# property-based interleavings vs a direct-call shadow model
# --------------------------------------------------------------------------


def _shadow_ingest(bank, queues, chunk):
    """Replicate BankRouter.ingest's decomposition with DIRECT
    ``GPBank.update`` calls: per-tenant chunks of ``chunk`` rows, padded +
    masked, distinct tenants per round."""
    p = bank.spec.p
    queues = {t: list(rows) for t, rows in queues.items() if rows}
    while queues:
        ids, Xg, yg, mg = [], [], [], []
        for t in list(queues):
            rows, rest = queues[t][:chunk], queues[t][chunk:]
            if rest:
                queues[t] = rest
            else:
                del queues[t]
            X = np.zeros((chunk, p), np.float32)
            y = np.zeros((chunk,), np.float32)
            m = np.zeros((chunk,), np.float32)
            for i, (x, yv) in enumerate(rows):
                X[i], y[i], m[i] = x, yv, 1.0
            ids.append(t)
            Xg.append(X)
            yg.append(y)
            mg.append(m)
        bank = bank.update(ids, jnp.asarray(np.stack(Xg)),
                           jnp.asarray(np.stack(yg)),
                           mask=jnp.asarray(np.stack(mg)))
    return bank


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
class TestInterleavingProperty:
    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 63),
           microbatch=st.sampled_from([3, 4, 8]),
           ingest_chunk=st.sampled_from([2, 5]))
    def test_any_interleaving_matches_direct_calls(
            self, backend, seed, microbatch, ingest_chunk):
        B, N, p, n = 4, 8, 2, 4
        bank = _fleet(B, N, p, n, backend=backend)
        shadow = bank
        eng, router = _engine(bank, microbatch=microbatch,
                              ingest_chunk=ingest_chunk)
        rng = np.random.default_rng(seed)

        sent = {}            # ticket -> (tenant, x)
        shadow_obs = {}      # tenant -> [(x, y)] not yet shadow-ingested
        got = {}             # ticket -> TicketResult
        expected = {}        # ticket -> (mu, var) from the shadow bank

        def do_drain():
            fresh = eng.drain()
            if fresh:
                ids = [sent[t][0] for t in fresh]
                X = np.stack([sent[t][1] for t in fresh])
                mu, var = shadow.mean_var(ids, jnp.asarray(X))
                mu, var = np.asarray(mu), np.asarray(var)
                for i, t in enumerate(fresh):
                    expected[t] = (mu[i], var[i])
            got.update(fresh)

        ops = rng.choice(["submit", "observe", "drain", "ingest"],
                         size=28, p=[0.55, 0.2, 0.15, 0.1])
        for op in ops:
            tenant = int(rng.integers(0, B))
            if op == "submit":
                x = rng.uniform(-1, 1, p).astype(np.float32)
                sent[eng.submit(tenant, x)] = (tenant, x)
            elif op == "observe":
                x = rng.uniform(-1, 1, p).astype(np.float32)
                y = float(rng.normal())
                eng.observe(tenant, x, y)
                shadow_obs.setdefault(tenant, []).append((x, y))
            elif op == "drain":
                do_drain()
            else:  # ingest: results already in flight belong to the OLD
                # bank, so the pipeline is drained first (same barrier the
                # serving loop uses between rounds)
                do_drain()
                eng.ingest()
                shadow = _shadow_ingest(shadow, shadow_obs, ingest_chunk)
                shadow_obs = {}
        do_drain()
        eng.ingest()

        # every ticket answered exactly once, against its own submission
        assert set(got) == set(sent)
        for t, r in got.items():
            assert r.ok
            mu_s, var_s = expected[t]
            assert abs(r.mu - mu_s) <= 1e-5, (t, r.mu, mu_s)
            assert abs(r.var - var_s) <= 1e-5, (t, r.var, var_s)


# --------------------------------------------------------------------------
# fault injection
# --------------------------------------------------------------------------


class TestFaultInjection:
    def test_dispatch_failure_restores_backlog_and_bank(self):
        bank = _fleet(4, 8, 2, 4)
        eng, router = _engine(bank, auto_pump=False)
        tks = [eng.submit(i % 4, np.full(2, 0.1 * i, np.float32))
               for i in range(6)]
        before = [(t, x.copy()) for _, t, x in router._pending]
        stack0 = {f: np.asarray(getattr(router.bank.stack, f)).copy()
                  for f in ("chol", "u", "b", "lam", "sqrtlam")}

        real = eng._dispatch
        calls = []

        def boom(entries, bucket):
            calls.append(len(entries))
            raise RuntimeError("injected mid-flight fault")

        eng._dispatch = boom
        with pytest.raises(RuntimeError, match="injected"):
            eng.pump()
        # backlog restored in arrival order, bank bit-identical
        assert [(t, tuple(x)) for _, t, x in router._pending] \
            == [(t, tuple(x)) for t, x in before]
        for f, v in stack0.items():
            assert np.array_equal(
                np.asarray(getattr(router.bank.stack, f)), v
            ), f
        assert eng.in_flight_blocks == 0 and eng.in_flight_rows == 0

        # after repair every ticket is still redeemable
        eng._dispatch = real
        out = eng.drain()
        assert set(out) == set(tks) and all(out[t].ok for t in tks)

    def test_failed_ingest_restores_queue_and_serving_continues(self):
        bank = _fleet(4, 8, 2, 4)
        eng, router = _engine(bank)
        eng.observe(1, np.zeros(2, np.float32), 0.5)
        orig = router.bank
        # swap in a bank that has never seen tenant 1: ingest must fail,
        # restore the observation queue, and succeed after repair
        router.bank = GPBank.create(orig.spec, capacity=orig.capacity)
        with pytest.raises(KeyError):
            eng.ingest()
        assert router._observations[1], "queued observation was dropped"
        router.bank = orig
        assert eng.ingest() == 1
        t = eng.submit(1, np.zeros(2, np.float32))
        assert eng.drain()[t].ok

    def test_expired_ticket_never_blocks_later_tickets(self):
        clock = _FakeClock()
        bank = _fleet(4, 8, 2, 4)
        eng, router = _engine(bank, auto_pump=False, clock=clock)
        doomed = eng.submit(0, np.zeros(2, np.float32), deadline_s=1.0)
        clock.t = 0.5
        live1 = eng.submit(1, np.ones(2, np.float32))
        clock.t = 2.0  # doomed expired, live1 has no deadline
        live2 = eng.submit(2, np.full(2, -0.5, np.float32), deadline_s=10.0)
        out = eng.drain()
        assert out[doomed].timed_out
        assert math.isnan(out[doomed].mu) and out[doomed].var == TIMEOUT_VAR
        assert math.isnan(TIMEOUT_MU) and TIMEOUT_VAR == float("inf")
        assert out[live1].ok and out[live2].ok
        assert np.isfinite(out[live1].mu) and np.isfinite(out[live2].mu)
        # the sentinel is recorded as a timeout, not a completion
        m = eng.metrics()
        assert m["overall"]["expired"] == 1
        assert m["overall"]["completed"] == 2
        assert m["tenants"][0]["timeouts"] == 1

    def test_queue_budget_backpressure(self):
        bank = _fleet(4, 8, 2, 4)
        eng, _ = _engine(bank, queue_budget=3, auto_pump=False)
        for i in range(3):
            eng.submit(0, np.zeros(2, np.float32))
        with pytest.raises(QueueFull):
            eng.submit(0, np.zeros(2, np.float32))
        # draining frees the budget
        eng.drain()
        assert eng.depth == 0
        eng.submit(0, np.zeros(2, np.float32))


# --------------------------------------------------------------------------
# bucket autotuning: shapes are pinned, churn mints no executables
# --------------------------------------------------------------------------


class TestBucketShapes:
    def test_ladder_is_fixed_powers_of_two(self):
        from repro.bank.engine import _pow2_buckets
        assert _pow2_buckets(8) == (1, 2, 4, 8)
        assert _pow2_buckets(8, 4) == (1, 2, 4, 8, 16, 32)
        assert _pow2_buckets(1, 1) == (1,)
        assert _pow2_buckets(64, 4) == (1, 2, 4, 8, 16, 32, 64, 128, 256)

    def test_backlog_coalesces_up_the_ladder(self):
        bank = _fleet(4, 8, 2, 4)
        eng, _ = _engine(bank, microbatch=4, auto_pump=False,
                         max_coalesce=4)
        for i in range(11):
            eng.submit(i % 4, np.full(2, 0.05 * i, np.float32))
        eng.pump(max_blocks=1)
        # 11 pending -> one padded 16-row block, not three 4-row blocks
        assert eng.bucket_uses == {16: 1}
        out = eng.drain()
        assert len(out) == 11

    def test_traffic_churn_mints_no_new_executables(self):
        bank = _fleet(4, 8, 2, 4)
        eng, _ = _engine(bank, microbatch=8, auto_pump=False,
                         max_coalesce=2)
        rng = np.random.default_rng(0)
        # warm every rung of the ladder once
        for rung in eng.buckets:
            for i in range(rung):
                eng.submit(int(rng.integers(0, 4)),
                           rng.uniform(-1, 1, 2).astype(np.float32))
            eng.pump(max_blocks=1)
            eng.drain()
        serve0 = fagp._bank_gathered_posterior._cache_size()
        # arbitrary churn: every dispatch reuses a warmed rung
        for _ in range(12):
            for _ in range(int(rng.integers(1, 17))):
                eng.submit(int(rng.integers(0, 4)),
                           rng.uniform(-1, 1, 2).astype(np.float32))
            eng.drain()
        assert fagp._bank_gathered_posterior._cache_size() == serve0

    def test_ingest_donation_matches_non_donated(self):
        bank = _fleet(4, 8, 2, 4)
        rng = np.random.default_rng(3)
        rows = [(int(rng.integers(0, 4)),
                 rng.uniform(-1, 1, 2).astype(np.float32),
                 float(rng.normal())) for _ in range(6)]
        plain = BankRouter(bank, microbatch=8, ingest_chunk=4)
        donated = BankRouter(bank, microbatch=8, ingest_chunk=4,
                             donate_updates=True)
        for router in (plain, donated):
            for t, x, y in rows:
                router.observe(t, x, y)
            assert router.ingest() == 6
        xq = np.full(2, 0.2, np.float32)
        for t in range(4):
            mu_a, var_a = plain.bank.mean_var([t], jnp.asarray(xq[None]))
            mu_b, var_b = donated.bank.mean_var([t], jnp.asarray(xq[None]))
            assert abs(float(mu_a[0]) - float(mu_b[0])) <= 1e-6
            assert abs(float(var_a[0]) - float(var_b[0])) <= 1e-6


# --------------------------------------------------------------------------
# latency metrics: numpy.percentile reference semantics
# --------------------------------------------------------------------------


class TestLatencyMetrics:
    def test_percentiles_match_numpy_reference(self):
        rng = np.random.default_rng(11)
        stats = LatencyStats()
        ref = {}
        for tenant in range(3):
            samples = rng.exponential(0.01, size=rng.integers(5, 40))
            for s in samples:
                stats.record(tenant, float(s))
            ref[tenant] = samples
        for tenant, samples in ref.items():
            p50, p99 = stats.percentiles(tenant)
            assert p50 == pytest.approx(
                float(np.percentile(samples, 50)), abs=0, rel=0)
            assert p99 == pytest.approx(
                float(np.percentile(samples, 99)), abs=0, rel=0)
        pooled = np.concatenate(list(ref.values()))
        p50, p99 = stats.percentiles(None)
        assert p50 == float(np.percentile(pooled, 50))
        assert p99 == float(np.percentile(pooled, 99))
        assert all(math.isnan(v) for v in stats.percentiles("nobody"))

    def test_engine_metrics_are_percentiles_of_recorded_samples(self):
        bank = _fleet(4, 8, 2, 4)
        eng, _ = _engine(bank)
        rng = np.random.default_rng(5)
        tks = [eng.submit(int(rng.integers(0, 4)),
                          rng.uniform(-1, 1, 2).astype(np.float32))
               for _ in range(40)]
        out = eng.drain()
        assert all(out[t].ok for t in tks)
        m = eng.metrics()
        pooled = [s for lst in eng.stats.samples.values() for s in lst]
        assert m["overall"]["p50_s"] == float(np.percentile(pooled, 50))
        assert m["overall"]["p99_s"] == float(np.percentile(pooled, 99))
        assert m["overall"]["completed"] == 40
        assert sum(v["count"] for v in m["tenants"].values()) == 40
        # every completed ticket carried its own latency
        assert all(out[t].latency_s >= 0.0 for t in tks)
        assert m["overall"]["sustained_qps"] > 0


# --------------------------------------------------------------------------
# check_bench gates BENCH_serve.json claims
# --------------------------------------------------------------------------


def _good_serve_payload():
    return {
        "schema": 1,
        "smoke": True,
        "config": {"B": 64, "microbatch": 64},
        "results": [
            {"name": "jnp-sync-loop", "seconds": 0.05,
             "derived": "B=64;mb=64;nq=2048"},
            {"name": "jnp-pipelined", "seconds": 0.03,
             "derived": "B=64;mb=64;nq=2048"},
        ],
        "parity_abs": {"pipelined_vs_direct":
                       {"mean_abs": 0.0, "var_abs": 0.0}},
        "qps": {"sync/jnp": 40000.0, "pipelined/jnp": 80000.0},
        "speedup_pipelined_vs_sync": 2.0,
        "latency": {"p50_s": 0.01, "p99_s": 0.02},
        "timeouts": 256,
        "dropped_non_expired": 0,
    }


def _run_check(tmp_path, payload):
    path = tmp_path / "BENCH_serve.json"
    path.write_text(json.dumps(payload))
    return subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_bench.py"), str(path)],
        capture_output=True, text=True, cwd=ROOT,
    )


class TestCheckBenchGate:
    def test_accepts_in_contract_payload(self, tmp_path):
        r = _run_check(tmp_path, _good_serve_payload())
        assert r.returncode == 0, r.stdout + r.stderr

    def test_rejects_speedup_below_contract(self, tmp_path):
        bad = _good_serve_payload()
        bad["speedup_pipelined_vs_sync"] = 1.2
        r = _run_check(tmp_path, bad)
        assert r.returncode == 1
        assert "below required minimum" in r.stdout

    def test_rejects_dropped_tickets(self, tmp_path):
        bad = _good_serve_payload()
        bad["dropped_non_expired"] = 3
        r = _run_check(tmp_path, bad)
        assert r.returncode == 1
        assert "above allowed maximum" in r.stdout

    def test_rejects_parity_breach_and_missing_rows(self, tmp_path):
        bad = _good_serve_payload()
        bad["parity_abs"] = {"pipelined_vs_direct": {"mean_abs": 1e-3}}
        r = _run_check(tmp_path, bad)
        assert r.returncode == 1 and "parity" in r.stdout

        bad = _good_serve_payload()
        bad["results"] = []
        r = _run_check(tmp_path, bad)
        assert r.returncode == 1 and "no results rows" in r.stdout

        bad = _good_serve_payload()
        del bad["speedup_pipelined_vs_sync"]
        r = _run_check(tmp_path, bad)
        assert r.returncode == 1 and "below required minimum" in r.stdout

    def test_rejects_malformed_json(self, tmp_path):
        path = tmp_path / "BENCH_serve.json"
        path.write_text("{not json")
        r = subprocess.run(
            [sys.executable, str(ROOT / "tools" / "check_bench.py"),
             str(path)],
            capture_output=True, text=True, cwd=ROOT,
        )
        assert r.returncode == 1 and "unreadable" in r.stdout
