"""System tests for the FAGP posterior (paper Eqs. 8-12), spec-first API."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import exact_gp, fagp, mercer


def _data(N=60, p=1, seed=0, noise=0.05):
    rng = np.random.default_rng(seed)
    X = rng.uniform(-1, 1, size=(N, p)).astype(np.float32)
    y = np.sum(np.cos(X), axis=1) + noise * rng.standard_normal(N)  # paper Eq. 21
    return jnp.asarray(X), jnp.asarray(y.astype(np.float32))


def _params(p, eps=0.8, rho=2.0, noise=0.05):
    return mercer.SEKernelParams.create(jnp.full((p,), eps), jnp.full((p,), rho), noise)


def _spec(p, n, eps=0.8, rho=2.0, noise=0.05, **kw):
    return fagp.GPSpec.create(
        n, eps=jnp.full((p,), eps), rho=jnp.full((p,), rho), noise=noise, **kw
    )


class TestPosterior:
    def test_fagp_matches_exact_gp_1d(self):
        """FAGP -> exact GP as n grows (the Joukov-Kulic claim FAGP rests on)."""
        X, y = _data(N=80, p=1)
        Xs = jnp.linspace(-0.9, 0.9, 33)[:, None]
        mu_e, cov_e = exact_gp.predict(exact_gp.fit(X, y, _params(1)), Xs)
        st = fagp.fit(X, y, _spec(1, 40))
        mu_a, cov_a = fagp.predict(st, Xs)
        np.testing.assert_allclose(np.asarray(mu_a), np.asarray(mu_e), atol=2e-3)
        np.testing.assert_allclose(np.asarray(cov_a), np.asarray(cov_e), atol=2e-3)

    def test_fagp_matches_exact_gp_2d(self):
        X, y = _data(N=120, p=2)
        Xs, _ = _data(N=25, p=2, seed=7)
        mu_e, cov_e = exact_gp.predict(exact_gp.fit(X, y, _params(2)), Xs)
        st = fagp.fit(X, y, _spec(2, 16))
        mu_a, cov_a = fagp.predict(st, Xs)
        np.testing.assert_allclose(np.asarray(mu_a), np.asarray(mu_e), atol=5e-3)
        np.testing.assert_allclose(np.asarray(cov_a), np.asarray(cov_e), atol=5e-3)

    def test_paper_mode_equals_fused_mode(self):
        """Literal Eq. 11-12 GEMM chain == weight-space simplification."""
        X, y = _data(N=50, p=2)
        Xs, _ = _data(N=17, p=2, seed=3)
        st = fagp.fit(X, y, _spec(2, 8, store_train=True))
        mu_f, cov_f = fagp.predict(st, Xs, mode="fused")
        mu_p, cov_p = fagp.predict(st, Xs, mode="paper")
        # paper mode forms the N x N approximate inverse in f32; a few ulps of
        # extra rounding vs the fused path is expected (part of why fused wins)
        np.testing.assert_allclose(np.asarray(mu_p), np.asarray(mu_f), atol=5e-3)
        np.testing.assert_allclose(np.asarray(cov_p), np.asarray(cov_f), atol=5e-3)

    def test_woodbury_against_direct_inverse(self):
        """Posterior == direct inversion of (Phi Lam Phi^T + sig2 I) (Eqs. 8-9)."""
        X, y = _data(N=40, p=1)
        Xs = jnp.linspace(-0.8, 0.8, 9)[:, None]
        params = _params(1)
        spec = _spec(1, 12)
        st = fagp.fit(X, y, spec)
        mu_a, cov_a = fagp.predict(st, Xs)

        Phi = np.asarray(mercer.phi_nd(X, st.idx, params, spec.n))
        Phis = np.asarray(mercer.phi_nd(Xs, st.idx, params, spec.n))
        lam = np.asarray(st.lam)
        sig2 = float(params.noise) ** 2
        Kapprox = Phi * lam @ Phi.T + sig2 * np.eye(X.shape[0])
        Kinv = np.linalg.inv(Kapprox)
        Ks = Phis * lam @ Phi.T
        mu_d = Ks @ Kinv @ np.asarray(y)
        cov_d = Phis * lam @ Phis.T - Ks @ Kinv @ Ks.T
        np.testing.assert_allclose(np.asarray(mu_a), mu_d, atol=2e-3)
        np.testing.assert_allclose(np.asarray(cov_a), cov_d, atol=2e-3)

    def test_streaming_blocks_invariant(self):
        """Moment accumulation is block-size independent."""
        X, y = _data(N=100, p=2)
        outs = []
        for block in (7, 32, 100, 256):
            st = fagp.fit(X, y, _spec(2, 6, block_rows=block))
            outs.append(np.asarray(st.u))
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=2e-4, atol=1e-5)

    def test_predictive_cov_psd_and_symmetric(self):
        X, y = _data(N=70, p=2)
        Xs, _ = _data(N=20, p=2, seed=5)
        st = fagp.fit(X, y, _spec(2, 8))
        _, cov = fagp.predict(st, Xs)
        cov = np.asarray(cov)
        np.testing.assert_allclose(cov, cov.T, atol=1e-5)
        assert np.linalg.eigvalsh(cov).min() > -1e-4

    def test_truncated_index_sets_track_full(self):
        """Hyperbolic-cross with far fewer columns stays close to full grid."""
        X, y = _data(N=150, p=3)
        Xs, _ = _data(N=20, p=3, seed=9)
        spec_full = _spec(3, 6, eps=0.6, index_set="full")
        spec_hc = _spec(3, 6, eps=0.6, index_set="hyperbolic_cross", degree=12)
        mu_full, _ = fagp.predict(fagp.fit(X, y, spec_full), Xs)
        mu_hc, _ = fagp.predict(fagp.fit(X, y, spec_hc), Xs)
        M_full = spec_full.indices(3).shape[0]
        M_hc = spec_hc.indices(3).shape[0]
        assert M_hc < M_full / 3  # 56 vs 216 columns at n=6, p=3
        np.testing.assert_allclose(np.asarray(mu_hc), np.asarray(mu_full), atol=0.05)


class TestNLML:
    def test_fagp_nlml_matches_exact(self):
        X, y = _data(N=60, p=1)
        v_fagp = float(fagp.nlml(X, y, _spec(1, 40)))
        v_exact = float(exact_gp.nlml(X, y, _params(1)))
        assert abs(v_fagp - v_exact) < 0.05 * max(1.0, abs(v_exact))

    def test_nlml_differentiable(self):
        """Gradients flow through the spec's hyperparameter leaves."""
        X, y = _data(N=50, p=2)
        spec0 = _spec(2, 6)

        def loss(log_eps, log_rho, log_noise):
            spec = dataclasses.replace(
                spec0, eps=jnp.exp(log_eps), rho=jnp.exp(log_rho),
                noise=jnp.exp(log_noise),
            )
            return fagp.nlml(X, y, spec)

        g = jax.grad(loss, argnums=(0, 1, 2))(
            jnp.zeros(2), jnp.log(jnp.full((2,), 2.0)), jnp.log(jnp.asarray(0.05))
        )
        for gi in g:
            assert np.all(np.isfinite(np.asarray(gi)))

    def test_nlml_prefers_true_noise_scale(self):
        X, y = _data(N=120, p=1, noise=0.1)
        vals = {
            s: float(fagp.nlml(X, y, _spec(1, 24, noise=s)))
            for s in (0.01, 0.1, 1.0)
        }
        assert vals[0.1] == min(vals.values())


class TestPallasBackend:
    def test_pallas_fit_matches_jnp(self):
        X, y = _data(N=150, p=2)
        Xs, _ = _data(N=30, p=2, seed=11)
        st_j = fagp.fit(X, y, _spec(2, 8, backend="jnp"))
        st_p = fagp.fit(X, y, _spec(2, 8, backend="pallas"))
        np.testing.assert_allclose(np.asarray(st_p.u), np.asarray(st_j.u), rtol=5e-3, atol=1e-4)
        mu_j, var_j = fagp.predict_mean_var(st_j, Xs)
        mu_p, var_p = fagp.predict_mean_var(st_p, Xs)
        np.testing.assert_allclose(np.asarray(mu_p), np.asarray(mu_j), rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(var_p), np.asarray(var_j), rtol=5e-3, atol=1e-6)

    def test_mean_var_consistent_with_full_cov(self):
        X, y = _data(N=90, p=2)
        Xs, _ = _data(N=21, p=2, seed=13)
        st = fagp.fit(X, y, _spec(2, 8))
        mu_a, cov = fagp.predict(st, Xs)
        mu_b, var = fagp.predict_mean_var(st, Xs)
        np.testing.assert_allclose(np.asarray(mu_b), np.asarray(mu_a), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(var), np.diag(np.asarray(cov)), rtol=1e-4, atol=1e-7)
