"""Property tests for the sharding rule engine (no compilation needed):
every arch × mode must produce specs whose sharded dims divide the mesh,
with every parameter covered by a rule."""
import numpy as np
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.models import get_model
from repro.parallel import sharding

MESH_SHAPE = {"data": 16, "model": 16}


class _FakeMesh:
    """Duck-typed mesh: _leaf_spec only reads .shape and axis names."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
@pytest.mark.parametrize("serving", [False, True])
def test_specs_divide_and_cover(arch_id, serving):
    cfg = ARCHS[arch_id].CONFIG
    model = get_model(cfg)
    shapes = jax.eval_shape(lambda: model.init_params(jax.random.key(0)))
    mesh = _FakeMesh(MESH_SHAPE)
    specs = sharding.param_specs(shapes, cfg, mesh, serving=serving)

    flat_s, _ = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P)
    )
    flat_l = jax.tree_util.tree_leaves(shapes)
    assert len(flat_s) == len(flat_l)          # every param got a rule
    n_sharded = 0
    for leaf, spec in zip(flat_l, flat_s):
        assert isinstance(spec, P)
        assert len(spec) <= leaf.ndim
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([MESH_SHAPE[a] for a in axes]))
            assert dim % size == 0, (arch_id, leaf.shape, spec)
            n_sharded += 1
    # something must actually be sharded for every full-size arch
    assert n_sharded > 0, arch_id


@pytest.mark.parametrize("arch_id", ["zamba2-7b", "mamba2-130m"])
def test_serving_flag_changes_ssm_placement_only_when_divisible(arch_id):
    cfg = ARCHS[arch_id].CONFIG
    model = get_model(cfg)
    shapes = jax.eval_shape(lambda: model.init_params(jax.random.key(0)))
    mesh = _FakeMesh(MESH_SHAPE)
    train = jax.tree_util.tree_leaves(
        sharding.param_specs(shapes, cfg, mesh, serving=False),
        is_leaf=lambda x: isinstance(x, P))
    serve = jax.tree_util.tree_leaves(
        sharding.param_specs(shapes, cfg, mesh, serving=True),
        is_leaf=lambda x: isinstance(x, P))
    differs = any(a != b for a, b in zip(train, serve))
    if arch_id == "zamba2-7b":      # 112 heads % 16 == 0: TP available
        assert differs
    else:                            # 24 heads: no TP either way
        assert not differs


def test_opt_state_inherits_param_specs():
    from repro import optim

    cfg = ARCHS["qwen2-1.5b"].CONFIG
    model = get_model(cfg)
    shapes = jax.eval_shape(lambda: model.init_params(jax.random.key(0)))
    mesh = _FakeMesh(MESH_SHAPE)
    pspecs = sharding.param_specs(shapes, cfg, mesh)
    # spot-check one TP'd tensor: its m/v must carry the same spec
    flat_p, _ = jax.tree_util.tree_flatten_with_path(
        pspecs, is_leaf=lambda x: isinstance(x, P))
    name_to_spec = {
        "/".join(str(getattr(k, "key", k)) for k in path): s
        for path, s in flat_p
    }
    wd_specs = [v for k, v in name_to_spec.items() if k.endswith("wg")]
    assert any(s != P(*([None] * len(s))) for s in wd_specs)
