"""Property tests for the Mercer eigensystem of the SE kernel.

These pin down the math of paper Eqs. 13-20, including the delta^2 typo fix
(only delta^2 = rho^2/2 (beta^2-1) reconstructs the kernel).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypcompat import given, settings, st  # hypothesis, or fixed examples

from repro.core import mercer

jax.config.update("jax_enable_x64", False)


def _params(eps, rho, noise=1e-2, p=1):
    return mercer.SEKernelParams.create(jnp.full((p,), eps), jnp.full((p,), rho), noise)


class TestReconstruction:
    def test_mercer_reconstruction_1d(self):
        """sum_i lambda_i phi_i(x) phi_i(x') -> k_SE(x, x')  (Eq. 6)."""
        eps, rho, n = 0.7, 2.0, 60
        x = jnp.linspace(-1.0, 1.0, 23)
        phi = mercer.eigenfunctions_1d(x, n, jnp.float32(eps), jnp.float32(rho))
        lam = mercer.eigenvalues_1d(n, jnp.float32(eps), jnp.float32(rho))
        K_approx = (phi * lam[None, :]) @ phi.T
        K_exact = np.exp(-(eps**2) * (np.asarray(x)[:, None] - np.asarray(x)[None, :]) ** 2)
        np.testing.assert_allclose(np.asarray(K_approx), K_exact, atol=2e-4)

    def test_mercer_reconstruction_ard_2d(self):
        """Tensor-product expansion reconstructs the ARD kernel (Eqs. 17-20)."""
        p, n = 2, 24
        params = mercer.SEKernelParams.create(jnp.array([0.6, 0.9]), jnp.array([2.0, 2.5]))
        rng = np.random.default_rng(0)
        X = jnp.asarray(rng.uniform(-1, 1, size=(40, p)).astype(np.float32))
        idx = jnp.asarray(mercer.full_grid(n, p))
        Phi = mercer.phi_nd(X, idx, params, n)
        lam = mercer.eigenvalues_nd(idx, params)
        K_approx = (Phi * lam[None, :]) @ Phi.T
        K_exact = mercer.k_se_ard(X, X, params.eps)
        np.testing.assert_allclose(np.asarray(K_approx), np.asarray(K_exact), atol=5e-4)

    def test_paper_delta2_variant_fails_reconstruction(self):
        """The paper's printed delta^2 = rho/2 (beta^2-1) does NOT reconstruct
        the kernel (except when rho == 1 where both coincide) — evidence the
        printed formula is a typo for the F&M rho^2/2 form we implement."""
        eps, rho, n = 0.7, 2.0, 60
        x = np.linspace(-1.0, 1.0, 23).astype(np.float32)
        beta = (1 + (2 * eps / rho) ** 2) ** 0.25
        delta2_paper = 0.5 * rho * (beta**2 - 1)  # paper's printed variant
        # reconstruct with the variant eigensystem
        z = rho * beta * x
        psis = [np.full_like(x, np.sqrt(beta))]
        psis.append(z * np.sqrt(2.0) * psis[0])
        for i in range(2, n):
            psis.append(z * np.sqrt(2.0 / i) * psis[-1] - np.sqrt((i - 1) / i) * psis[-2])
        phi = np.stack(psis, -1) * np.exp(-delta2_paper * x * x)[:, None]
        denom = rho**2 + delta2_paper + eps**2
        lam = np.sqrt(rho**2 / denom) * (eps**2 / denom) ** np.arange(n)
        K_approx = (phi * lam[None, :]) @ phi.T
        K_exact = np.exp(-(eps**2) * (x[:, None] - x[None, :]) ** 2)
        assert np.abs(K_approx - K_exact).max() > 1e-2  # clearly wrong

    def test_orthonormality_under_gaussian_measure(self):
        """F&M: phi_i orthonormal w.r.t. w(x) = rho/sqrt(pi) exp(-rho^2 x^2).
        Checked with Gauss-Hermite quadrature; also exercises recurrence
        stability at degrees far past classical-Hermite f32 overflow."""
        eps, rho, n = 0.8, 1.5, 40
        nodes, weights = np.polynomial.hermite.hermgauss(160)
        x = jnp.asarray((nodes / rho).astype(np.float32))
        phi = np.asarray(mercer.eigenfunctions_1d(x, n, jnp.float32(eps), jnp.float32(rho)))
        # int phi_i phi_j w dx = sum_k w_k/sqrt(pi) phi_i(x_k) phi_j(x_k)
        G = np.einsum("k,ki,kj->ij", weights / np.sqrt(np.pi), phi, phi)
        np.testing.assert_allclose(G, np.eye(n), atol=5e-3)

    def test_high_degree_no_overflow(self):
        phi = mercer.eigenfunctions_1d(
            jnp.linspace(-3, 3, 11), 200, jnp.float32(0.5), jnp.float32(1.0)
        )
        assert np.all(np.isfinite(np.asarray(phi)))


class TestEigenvalues:
    def test_positive_decreasing(self):
        """lambda_i > 0 and strictly decreasing — asserted in log space, since
        f32 lambda underflows to 0 near i~40 (expected; consumers use logs)."""
        loglam = np.asarray(
            mercer.log_eigenvalues_1d(64, jnp.float32(0.7), jnp.float32(2.0))
        )
        assert np.all(np.isfinite(loglam))
        assert np.all(np.diff(loglam) < 0)
        lam = np.asarray(mercer.eigenvalues_1d(12, jnp.float32(0.7), jnp.float32(2.0)))
        assert np.all(lam > 0)

    def test_nd_product_structure(self):
        params = mercer.SEKernelParams.create(jnp.array([0.6, 0.9]), jnp.array([2.0, 2.5]))
        idx = jnp.asarray(mercer.full_grid(5, 2))
        lam_nd = np.asarray(mercer.eigenvalues_nd(idx, params))
        l0 = np.asarray(mercer.eigenvalues_1d(5, params.eps[0], params.rho[0]))
        l1 = np.asarray(mercer.eigenvalues_1d(5, params.eps[1], params.rho[1]))
        expect = (l0[:, None] * l1[None, :]).reshape(-1)
        np.testing.assert_allclose(lam_nd, expect, rtol=1e-5)


class TestIndexSets:
    @given(n=st.integers(1, 6), p=st.integers(1, 4))
    @settings(max_examples=25, deadline=None)
    def test_full_grid_count(self, n, p):
        idx = mercer.full_grid(n, p)
        assert idx.shape == (n**p, p)
        assert idx.min() >= 0 and idx.max() <= n - 1
        assert len(np.unique(idx, axis=0)) == n**p

    @given(n=st.integers(2, 6), p=st.integers(1, 4))
    @settings(max_examples=25, deadline=None)
    def test_truncations_are_subsets_of_full(self, n, p):
        full = {tuple(r) for r in mercer.full_grid(n, p)}
        for kind in ("total_degree", "hyperbolic_cross"):
            sub = mercer.make_index_set(kind, n, p, None)
            rows = {tuple(r) for r in sub}
            assert rows <= full
            assert (0,) * p in rows  # constant term always kept

    def test_hyperbolic_much_smaller_than_full(self):
        n, p = 11, 4
        assert mercer.full_grid(n, p).shape[0] == 14641
        hc = mercer.hyperbolic_cross(n, p, degree=11)
        assert hc.shape[0] < 200  # near-linear vs 14641

    @given(n=st.integers(2, 8), p=st.integers(1, 3), d=st.integers(0, 8))
    @settings(max_examples=25, deadline=None)
    def test_total_degree_invariant(self, n, p, d):
        idx = mercer.total_degree(n, p, d)
        assert np.all(idx.sum(axis=1) <= d)

    @given(n=st.integers(2, 8), p=st.integers(1, 3), d=st.integers(1, 12))
    @settings(max_examples=25, deadline=None)
    def test_hyperbolic_invariant(self, n, p, d):
        idx = mercer.hyperbolic_cross(n, p, d)
        assert np.all(np.prod(idx + 1, axis=1) <= d)
