"""Mercer-feature linear attention vs exact softmax attention.

The approximation claim: for norm-bounded q/k the degree-2 Mercer truncation
reproduces softmax attention closely, in O(S·M) instead of O(S²).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.models.mercer_attention import (
    mercer_features_deg2,
    mercer_linear_attention,
)


def _softmax_attention(q, k, v, causal=True):
    B, S, H, D = q.shape
    logits = np.einsum("bqhd,bkhd->bhqk", q, k)
    if causal:
        mask = np.tril(np.ones((S, S), bool))
        logits = np.where(mask[None, None], logits, -1e30)
    p = np.exp(logits - logits.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return np.einsum("bhqk,bkhd->bqhd", p, v)


def _norm_clamp(x, target=1.0):
    n = np.linalg.norm(x, axis=-1, keepdims=True)
    return x * (target / np.maximum(n, 1e-6))


class TestMercerFeatures:
    def test_kernel_reconstruction(self):
        """φ(x)·φ(y) ≈ exp(-|x-y|²/2) · e^{-...} — i.e. the feature inner
        product approximates exp(x·y) x envelopes for bounded norms."""
        rng = np.random.default_rng(0)
        d = 8
        x = _norm_clamp(rng.standard_normal((50, d)).astype(np.float32))
        y = _norm_clamp(rng.standard_normal((50, d)).astype(np.float32))
        fx = np.asarray(mercer_features_deg2(jnp.asarray(x)))
        fy = np.asarray(mercer_features_deg2(jnp.asarray(y)))
        approx = np.einsum("nm,nm->n", fx, fy)
        exact = np.exp(-0.5 * np.sum((x - y) ** 2, axis=1))
        np.testing.assert_allclose(approx, exact, rtol=0.05, atol=0.01)

    @pytest.mark.parametrize("causal", [True, False])
    def test_attention_close_to_softmax(self, causal):
        rng = np.random.default_rng(1)
        B, S, H, D = 2, 64, 2, 8
        q = _norm_clamp(rng.standard_normal((B, S, H, D)).astype(np.float32))
        k = _norm_clamp(rng.standard_normal((B, S, H, D)).astype(np.float32))
        v = rng.standard_normal((B, S, H, D)).astype(np.float32)
        out = np.asarray(mercer_linear_attention(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=causal))
        ref = _softmax_attention(q, k, v, causal=causal)
        # relative error of the attention-weighted value averages
        err = np.abs(out - ref).max()
        scale = np.abs(ref).max()
        assert err < 0.08 * scale, (err, scale)

    def test_no_quadratic_intermediate(self):
        """Smoke that long sequences work (S=4096 would need 16M×... under
        softmax; linear path stays O(S·M))."""
        rng = np.random.default_rng(2)
        B, S, H, D = 1, 4096, 1, 8
        q = jnp.asarray(_norm_clamp(rng.standard_normal((B, S, H, D)).astype(np.float32)))
        k = jnp.asarray(_norm_clamp(rng.standard_normal((B, S, H, D)).astype(np.float32)))
        v = jnp.asarray(rng.standard_normal((B, S, H, D)).astype(np.float32))
        out = mercer_linear_attention(q, k, v, causal=True)
        assert out.shape == (B, S, H, D)
        assert np.all(np.isfinite(np.asarray(out)))
