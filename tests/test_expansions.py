"""Tests for the pluggable KernelExpansion layer (core/expansions.py).

Pins the contracts of the expansion tentpole:
  1. correctness: Phi @ diag(lam) @ Phi^T approximates the expansion's
     exact kernel within a STATED bound (truncation bound for the Hermite
     eigen-expansion, Monte-Carlo bound 4/sqrt(R) for the RFF families),
     with features from EVERY registered expansion on BOTH backends
     (pallas in interpret mode on CPU);
  2. the Hermite recurrence has ONE home (mercer.hermite_psi_rows): the jnp
     path (mercer.phi_nd), the Pallas tile path (ops.hermite_phi) and the
     deliberately-independent oracle (ref.ref_phi) agree three ways;
  3. capability x kernel-family matrix: GP.fit/predict/update/nlml and
     GPBank are parity-pinned across backends for all three expansions;
  4. RFF lengthscales are differentiable through nlml (the spectral draws
     are data; the sqrt(2)*eps scaling is applied inside the feature map);
  5. spec plumbing: omega rides the spec, is frozen into the factorization
     (with_spec rejects a different draw), and malformed RFF specs are
     refused at dispatch.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.bank import GPBank
from repro.core import expansions, fagp, mercer
from repro.core.gp import GP, GPSpec
from repro.data import make_gp_dataset
from repro.kernels import ops, ref

EXPANSIONS = ["hermite", "rff_se", "rff_matern52"]
R_MC = 512  # RFF draw count for the reconstruction bound tests


def _spec(expansion, p=2, *, num_features=64, seed=0, **kw):
    if expansion == "hermite":
        return GPSpec.create(8, eps=[0.8] * p, rho=2.0, noise=0.05, **kw)
    return GPSpec.create_rff(
        [0.8] * p, noise=0.05, kernel=expansion[4:],
        num_features=num_features, seed=seed, **kw,
    )


class TestRegistry:
    def test_builtin_expansions_registered(self):
        assert set(EXPANSIONS) <= set(expansions.available_expansions())

    def test_unknown_expansion_raises(self):
        with pytest.raises(ValueError, match="unknown kernel expansion"):
            expansions.get_expansion("karhunen-loeve")

    def test_spec_m_comes_from_expansion(self):
        """M is the expansion's answer, not the index-set formula: an RFF
        spec with R draws has M = 2R regardless of n/index_set."""
        sp = _spec("rff_se", num_features=19)
        assert sp.n_features() == 38
        assert sp.indices().shape == (38, 1)
        assert _spec("hermite").n_features() == 8**2


class TestReconstruction:
    """Phi diag(lam) Phi^T -> k within a stated truncation / MC bound."""

    def _points(self, p, n_pts=40, seed=0):
        rng = np.random.default_rng(seed)
        return jnp.asarray(rng.uniform(-1, 1, (n_pts, p)).astype(np.float32))

    def _bound(self, expansion, spec):
        if expansion == "hermite":
            # geometric truncation decay: n=20 per dim is well past the
            # point where the 1-D tail is < 1e-4 at eps=0.8, rho=2
            return 5e-4
        return 4.0 / np.sqrt(np.shape(spec.omega)[0])  # Monte-Carlo O(R^-1/2)

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    @pytest.mark.parametrize("expansion", EXPANSIONS)
    def test_kernel_reconstruction(self, expansion, backend):
        p = 2
        if expansion == "hermite":
            spec = GPSpec.create(20, eps=[0.8] * p, rho=2.0, noise=0.05,
                                 backend=backend)
        else:
            spec = _spec(expansion, p, num_features=R_MC, seed=7,
                         backend=backend)
        exp = expansions.get_expansion(expansion)
        X = self._points(p)
        idx = jnp.asarray(spec.indices(p))
        be = fagp.get_backend(backend)
        aux = be.prepare(np.asarray(idx), spec)
        Phi = be.features(X, spec, idx, aux)
        lam = jnp.exp(exp.log_eigenvalues(idx, spec))
        K_approx = (Phi * lam[None, :]) @ Phi.T
        K_exact = exp.exact_kernel(X, X, spec)
        err = float(jnp.max(jnp.abs(K_approx - K_exact)))
        assert err <= self._bound(expansion, spec), (
            f"{expansion}/{backend}: reconstruction error {err} above bound"
        )

    @pytest.mark.parametrize("expansion", EXPANSIONS)
    def test_unit_prior_variance(self, expansion):
        """Every shipped expansion decomposes a unit-variance kernel:
        sum_m lam_m phi_m(x)^2 == k(x, x) == 1 (RFF: exactly, by the cos^2
        + sin^2 pairing; Hermite: up to truncation)."""
        spec = _spec(expansion, num_features=R_MC)
        exp = expansions.get_expansion(expansion)
        X = self._points(2, 16)
        idx = jnp.asarray(spec.indices(2))
        Phi = exp.features(X, idx, spec)
        lam = jnp.exp(exp.log_eigenvalues(idx, spec))
        diag = jnp.sum(Phi * Phi * lam[None, :], axis=1)
        np.testing.assert_allclose(np.asarray(diag), 1.0, atol=5e-3)

    def test_matern_exact_kernel_shape(self):
        """The new exact Matern-5/2 oracle: unit diagonal, monotone decay,
        heavier tail than SE at matched eps."""
        x = jnp.linspace(0.0, 3.0, 31)[:, None]
        eps = jnp.asarray([0.8], jnp.float32)
        km = np.asarray(mercer.k_matern52_ard(x[:1], x, eps))[0]
        ks = np.asarray(mercer.k_se_ard(x[:1], x, eps))[0]
        assert abs(km[0] - 1.0) < 1e-6
        assert np.all(np.diff(km) < 1e-7)         # non-increasing in distance
        assert np.all(km[-8:] >= ks[-8:])         # heavier FAR tail than SE


class TestHermiteSingleHome:
    """Satellite: the scaled Hermite recurrence lives in ONE place
    (mercer.hermite_psi_rows) — jnp path, Pallas tile path, and the
    independent oracle agree three ways."""

    def test_three_way_parity(self):
        N, p, n_max = 96, 2, 12
        rng = np.random.default_rng(3)
        X = jnp.asarray(rng.uniform(-2, 2, (N, p)).astype(np.float32))
        eps = jnp.asarray([0.7, 1.1], jnp.float32)
        rho = jnp.asarray([2.0, 2.5], jnp.float32)
        params = mercer.SEKernelParams.create(eps, rho)
        idx = mercer.full_grid(n_max, p)
        consts = ref.phi_consts(eps, rho)
        S = jnp.asarray(ref.one_hot_selection(idx, n_max))

        jnp_path = mercer.phi_nd(X, jnp.asarray(idx), params, n_max)
        tile_path = ops.hermite_phi(X, consts, S, n_max=n_max)
        oracle = ref.ref_phi(X.T, consts, S, n_max)
        np.testing.assert_allclose(np.asarray(jnp_path), np.asarray(oracle),
                                   rtol=2e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(tile_path), np.asarray(oracle),
                                   rtol=2e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(tile_path), np.asarray(jnp_path),
                                   rtol=2e-4, atol=1e-5)

    def test_psi_rows_matches_eigenfunctions(self):
        """hermite_psi_rows IS eigenfunctions_1d minus the envelope."""
        x = jnp.linspace(-2, 2, 17)
        eps, rho, n = jnp.float32(0.8), jnp.float32(2.0), 9
        beta, delta2 = mercer.mercer_constants(eps, rho)
        rows = jnp.stack(
            mercer.hermite_psi_rows(rho * beta * x, beta, n), axis=-1
        )
        full = mercer.eigenfunctions_1d(x, n, eps, rho)
        np.testing.assert_allclose(
            np.asarray(rows * jnp.exp(-delta2 * x * x)[:, None]),
            np.asarray(full), rtol=1e-6, atol=1e-7,
        )


class TestCapabilityMatrix:
    """The capability x kernel-family matrix: every session entry point is
    parity-pinned across backends for all three expansions."""

    @pytest.mark.parametrize("expansion", EXPANSIONS)
    def test_gp_session_backend_parity(self, expansion):
        N, p = 300, 2
        X, y, Xs, ys = make_gp_dataset(N, p, seed=1)
        spec = _spec(expansion, p, num_features=64, seed=4)
        gp_j = GP.fit(X, y, spec)
        gp_p = GP.fit(X, y, spec.replace(backend="pallas"))
        mu_j, var_j = gp_j.mean_var(Xs)
        mu_p, var_p = gp_p.mean_var(Xs)
        np.testing.assert_allclose(np.asarray(mu_p), np.asarray(mu_j),
                                   rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(var_p), np.asarray(var_j),
                                   rtol=5e-3, atol=1e-6)
        nl_j = float(gp_j.nlml(X, y))
        nl_p = float(gp_p.nlml(X, y))
        assert abs(nl_j - nl_p) < 1e-2 * max(1.0, abs(nl_j))

    @pytest.mark.parametrize("expansion", EXPANSIONS)
    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_update_equals_refit(self, expansion, backend):
        N, p, k = 200, 2, 16
        X, y, Xs, _ = make_gp_dataset(N, p, seed=2)
        Xn, yn, *_ = make_gp_dataset(k, p, seed=11)
        spec = _spec(expansion, p, num_features=48, seed=5, backend=backend)
        up = GP.fit(X, y, spec).update(Xn, yn)
        re = GP.fit(jnp.concatenate([X, Xn]), jnp.concatenate([y, yn]), spec)
        # the RFF scaled system is stiffer than the Hermite one (flat 1/R
        # weights put every column at full magnitude), so the f32 rank-1
        # sweep carries a little more rounding than in the Hermite tests
        np.testing.assert_allclose(np.asarray(up.state.u),
                                   np.asarray(re.state.u),
                                   rtol=1e-2, atol=2e-3)
        mu_u, var_u = up.mean_var(Xs)
        mu_r, var_r = re.mean_var(Xs)
        np.testing.assert_allclose(np.asarray(mu_u), np.asarray(mu_r),
                                   rtol=2e-3, atol=2e-4)
        np.testing.assert_allclose(np.asarray(var_u), np.asarray(var_r),
                                   rtol=5e-3, atol=1e-6)

    @pytest.mark.parametrize("expansion", EXPANSIONS)
    def test_multi_output_matches_per_task(self, expansion):
        N, p = 180, 2
        X, y, Xs, _ = make_gp_dataset(N, p, seed=3)
        spec = _spec(expansion, p, num_features=48, seed=6)
        Y = jnp.stack([y, 2.0 * y], axis=1)
        mu, var = GP.fit(X, Y, spec).mean_var(Xs)
        for t, yt in enumerate([y, 2.0 * y]):
            mu_t, var_t = GP.fit(X, yt, spec).mean_var(Xs)
            np.testing.assert_allclose(np.asarray(mu[:, t]),
                                       np.asarray(mu_t),
                                       rtol=1e-3, atol=1e-4)
            np.testing.assert_allclose(np.asarray(var), np.asarray(var_t),
                                       rtol=1e-4, atol=1e-6)

    @pytest.mark.parametrize("expansion", EXPANSIONS)
    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_bank_matches_loop_of_singles(self, expansion, backend):
        """A bank whose shared static spec names any expansion serves a
        mixed-tenant batch identically to per-tenant single-model calls."""
        B, N, p = 5, 16, 2
        rng = np.random.default_rng(9)
        spec = _spec(expansion, p, num_features=24, seed=8, backend=backend)
        Xb = np.zeros((B, N, p), np.float32)
        yb = np.zeros((B, N), np.float32)
        for s in range(B):
            Xt, yt, *_ = make_gp_dataset(N, p, seed=20 + s)
            Xb[s], yb[s] = np.asarray(Xt), np.asarray(yt)
        bank = GPBank.fit(jnp.asarray(Xb), jnp.asarray(yb), spec)
        Xq = jnp.asarray(rng.uniform(-1, 1, (3 * B, p)).astype(np.float32))
        tenants = [int(t) for t in rng.integers(0, B, 3 * B)]
        mu, var = bank.mean_var(tenants, Xq)
        mu, var = np.asarray(mu), np.asarray(var)
        for t in sorted(set(tenants)):
            rows = np.flatnonzero(np.asarray(tenants) == t)
            m1, v1 = GP.from_state(bank.state(t)).mean_var(
                Xq[jnp.asarray(rows)]
            )
            np.testing.assert_allclose(mu[rows], np.asarray(m1), atol=1e-5)
            np.testing.assert_allclose(var[rows], np.asarray(v1), atol=1e-5)

    @pytest.mark.parametrize("expansion", ["rff_se", "rff_matern52"])
    def test_bank_rejects_foreign_draws(self, expansion):
        """A tenant fitted under a different omega cannot join the bank —
        the spectral draws are part of the shared feature map."""
        p = 2
        spec = _spec(expansion, p, num_features=16, seed=1)
        other = _spec(expansion, p, num_features=16, seed=2)
        X, y, *_ = make_gp_dataset(24, p, seed=0)
        bank = GPBank.fit(jnp.asarray(np.stack([np.asarray(X)])),
                          jnp.asarray(np.stack([np.asarray(y)])),
                          spec, capacity=2)
        foreign = GP.fit(X, y, other)
        with pytest.raises(ValueError, match="omega"):
            bank.insert("t2", foreign)


class TestRFFDifferentiability:
    def test_nlml_grad_flows_through_lengthscales(self):
        """The acceptance criterion 'differentiable through RFF
        lengthscales': d nlml / d eps is finite and nonzero (the draws are
        constants; eps scales the frequencies inside the feature map)."""
        X, y, *_ = make_gp_dataset(120, 2, seed=4)
        spec0 = _spec("rff_se", num_features=64, seed=3)

        def loss(log_eps):
            spec = dataclasses.replace(spec0, eps=jnp.exp(log_eps))
            return fagp.nlml(X, y, spec)

        g = np.asarray(jax.grad(loss)(jnp.zeros(2)))
        assert np.all(np.isfinite(g)) and np.all(np.abs(g) > 1e-6)

    def test_optimize_improves_rff_nlml(self):
        X, y, Xs, _ = make_gp_dataset(200, 2, seed=5)
        spec0 = GPSpec.create_rff([2.5, 2.5], noise=0.5, num_features=64,
                                  seed=0)
        seen = []
        gp = GP.optimize(X, y, spec0, steps=40, lr=8e-2,
                         callback=lambda s, v, sp: seen.append(v))
        assert len(seen) >= 2 and seen[-1] < seen[0]
        assert np.all(np.isfinite(np.asarray(gp.mean_var(Xs)[0])))


class TestSpecPlumbing:
    def test_rff_spec_without_omega_refused(self):
        bad = GPSpec(
            eps=jnp.ones(2), rho=jnp.full((2,), 2.0),
            noise=jnp.asarray(0.05), n=1, expansion="rff_se",
        )
        X, y, *_ = make_gp_dataset(16, 2, seed=0)
        with pytest.raises(ValueError, match="spectral base draws"):
            fagp.fit(X, y, bad)

    def test_omega_frozen_into_factorization(self):
        """with_spec rejects a spec with different spectral draws — they
        are hyperparameters of the fitted system."""
        X, y, *_ = make_gp_dataset(64, 2, seed=1)
        spec = _spec("rff_se", num_features=16, seed=1)
        gp = GP.fit(X, y, spec)
        other = _spec("rff_se", num_features=16, seed=2)
        with pytest.raises(ValueError, match="omega"):
            gp.with_spec(other)

    def test_same_seed_same_posterior(self):
        """Spec creation is deterministic in (num_features, seed): two specs
        built alike produce identical fits."""
        X, y, Xs, _ = make_gp_dataset(80, 2, seed=2)
        a = GP.fit(X, y, _spec("rff_matern52", num_features=32, seed=5))
        b = GP.fit(X, y, _spec("rff_matern52", num_features=32, seed=5))
        np.testing.assert_array_equal(np.asarray(a.state.u),
                                      np.asarray(b.state.u))

    def test_rff_backend_swap_is_valid(self):
        X, y, Xs, _ = make_gp_dataset(100, 2, seed=3)
        gp = GP.fit(X, y, _spec("rff_se", num_features=32, seed=0))
        mu_j, _ = gp.mean_var(Xs)
        mu_p, _ = gp.with_spec(backend="pallas").mean_var(Xs)
        np.testing.assert_allclose(np.asarray(mu_p), np.asarray(mu_j),
                                   rtol=1e-3, atol=1e-4)
