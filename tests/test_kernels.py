"""Per-kernel allclose tests: Pallas (interpret mode on CPU) vs pure-jnp ref.

Sweeps shapes (aligned + ragged) and dtypes, plus hypothesis property tests,
plus cross-validation of the kernel path against core.mercer (two independent
implementations of paper Eq. 19).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypcompat import given, settings, st  # hypothesis, or fixed examples

from repro.core import mercer
from repro.kernels import ops, ref


def _setup(N, p, n_max, kind="full", degree=None, seed=0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.uniform(-2, 2, size=(N, p)).astype(np.float32))
    eps = jnp.asarray(rng.uniform(0.3, 1.2, size=(p,)).astype(np.float32))
    rho = jnp.asarray(rng.uniform(1.5, 3.0, size=(p,)).astype(np.float32))
    idx = mercer.make_index_set(kind, n_max, p, degree)
    consts = ref.phi_consts(eps, rho)
    S = jnp.asarray(ref.one_hot_selection(idx, n_max))
    return X, eps, rho, idx, consts, S


class TestHermitePhi:
    @pytest.mark.parametrize(
        "N,p,n_max",
        [
            (8, 1, 1),      # degenerate: single eigenvalue
            (64, 1, 8),
            (100, 2, 6),    # ragged N
            (256, 3, 5),
            (300, 4, 4),    # ragged, multi-dim
            (512, 2, 33),   # n_max past any small unroll assumptions
        ],
    )
    def test_matches_ref(self, N, p, n_max):
        X, eps, rho, idx, consts, S = _setup(N, p, n_max)
        out = ops.hermite_phi(X, consts, S, n_max=n_max)
        expect = ref.ref_phi(X.T, consts, S, n_max)
        assert out.shape == (N, idx.shape[0])
        # rtol scales with recurrence depth: two independent f32 recurrences
        # accumulate ~ULP/step of drift in the pre-envelope magnitudes
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expect), rtol=4e-5 * max(4, n_max), atol=1e-5
        )

    def test_matches_core_mercer(self):
        """Kernel path == core/mercer.phi_nd (independent scan-based impl)."""
        N, p, n_max = 128, 3, 6
        X, eps, rho, idx, consts, S = _setup(N, p, n_max)
        params = mercer.SEKernelParams.create(eps, rho)
        out = ops.hermite_phi(X, consts, S, n_max=n_max)
        expect = mercer.phi_nd(X, jnp.asarray(idx), params, n_max)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-4, atol=1e-5)

    def test_truncated_index_set(self):
        N, p, n_max = 96, 3, 6
        X, eps, rho, idx, consts, S = _setup(N, p, n_max, kind="hyperbolic_cross", degree=8)
        out = ops.hermite_phi(X, consts, S, n_max=n_max)
        expect = ref.ref_phi(X.T, consts, S, n_max)
        assert out.shape[1] == idx.shape[0] < n_max**p
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-4, atol=1e-5)

    @given(
        N=st.integers(1, 130),
        p=st.integers(1, 3),
        n_max=st.integers(1, 9),
        seed=st.integers(0, 99),
    )
    @settings(max_examples=12, deadline=None)
    def test_property_random_shapes(self, N, p, n_max, seed):
        X, eps, rho, idx, consts, S = _setup(N, p, n_max, seed=seed)
        out = ops.hermite_phi(X, consts, S, n_max=n_max)
        expect = ref.ref_phi(X.T, consts, S, n_max)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-4, atol=1e-5)


class TestScaledGram:
    @pytest.mark.parametrize(
        "N,M", [(64, 16), (512, 128), (300, 100), (1024, 256), (100, 257)]
    )
    def test_matches_ref(self, N, M):
        rng = np.random.default_rng(1)
        Phi = jnp.asarray(rng.standard_normal((N, M)).astype(np.float32))
        d = jnp.asarray(np.geomspace(1.0, 1e-6, M).astype(np.float32))
        sig2 = jnp.float32(0.01)
        out = ops.scaled_gram(Phi, d, sig2)
        expect = ref.ref_scaled_gram(Phi, d, sig2)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        rng = np.random.default_rng(2)
        Phi = jnp.asarray(rng.standard_normal((256, 64)).astype(np.float32)).astype(dtype)
        d = jnp.ones((64,), jnp.float32)
        sig2 = jnp.float32(0.5)
        out = ops.scaled_gram(Phi, d, sig2)
        expect = ref.ref_scaled_gram(Phi.astype(jnp.float32), d, sig2)
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        assert out.dtype == jnp.float32  # f32 accumulation regardless of input
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=tol, atol=tol)

    def test_spd(self):
        rng = np.random.default_rng(3)
        Phi = jnp.asarray(rng.standard_normal((512, 96)).astype(np.float32))
        d = jnp.asarray(np.geomspace(1, 1e-4, 96).astype(np.float32))
        out = np.asarray(ops.scaled_gram(Phi, d, jnp.float32(0.1)))
        np.testing.assert_allclose(out, out.T, atol=1e-5)
        assert np.linalg.eigvalsh(out).min() >= 0.99  # >= I by construction


class TestDiagQuad:
    @pytest.mark.parametrize("N,M", [(64, 32), (256, 128), (100, 60), (513, 256)])
    def test_matches_ref(self, N, M):
        rng = np.random.default_rng(4)
        A = jnp.asarray(rng.standard_normal((N, M)).astype(np.float32))
        C0 = rng.standard_normal((M, M)).astype(np.float32)
        C = jnp.asarray(C0 @ C0.T / M)
        out = ops.diag_quad(A, C)
        expect = ref.ref_diag_quad(A, C)
        assert out.shape == (N,)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=2e-4, atol=2e-4)

    @given(N=st.integers(1, 70), M=st.integers(1, 40), seed=st.integers(0, 99))
    @settings(max_examples=10, deadline=None)
    def test_property_random_shapes(self, N, M, seed):
        rng = np.random.default_rng(seed)
        A = jnp.asarray(rng.standard_normal((N, M)).astype(np.float32))
        C = jnp.asarray(rng.standard_normal((M, M)).astype(np.float32))
        out = ops.diag_quad(A, C)
        expect = ref.ref_diag_quad(A, C)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=3e-4, atol=3e-4)


class TestEndToEndKernelFAGP:
    def test_kernel_pipeline_matches_dense_posterior(self):
        """Full kernel pipeline (phi -> gram -> solve -> diag_quad) reproduces
        the core FAGP posterior mean/variance."""
        from repro.core import fagp

        N, Ns, p, n_max = 200, 40, 2, 8
        X, eps, rho, idx, consts, S = _setup(N, p, n_max)
        Xs, *_ = _setup(Ns, p, n_max, seed=9)
        rng = np.random.default_rng(5)
        y = jnp.asarray(
            (np.sum(np.cos(np.asarray(X)), axis=1) + 0.05 * rng.standard_normal(N)).astype(np.float32)
        )
        params = mercer.SEKernelParams.create(eps, rho, noise=0.05)
        spec = fagp.GPSpec.create(n_max, eps=params.eps, rho=params.rho, noise=0.05)
        st_ = fagp.fit(X, y, spec)
        mu_ref, cov_ref = fagp.predict(st_, Xs)

        # kernel pipeline
        Phi = ops.hermite_phi(X, consts, S, n_max=n_max)
        sig2 = params.noise**2
        B = ops.scaled_gram(Phi, st_.sqrtlam, sig2)
        chol = jnp.linalg.cholesky(B)
        b = Phi.T @ y
        u = st_.sqrtlam * jax.scipy.linalg.cho_solve((chol, True), st_.sqrtlam * b) / sig2
        Phis = ops.hermite_phi(Xs, consts, S, n_max=n_max)
        mu = Phis @ u
        Binv = jax.scipy.linalg.cho_solve((chol, True), jnp.eye(B.shape[0]))
        var = ops.diag_quad(Phis * st_.sqrtlam[None, :], Binv)
        np.testing.assert_allclose(np.asarray(mu), np.asarray(mu_ref), rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(var), np.diag(np.asarray(cov_ref)), rtol=2e-3, atol=1e-5
        )
