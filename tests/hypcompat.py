"""`hypothesis` shim: property tests degrade to fixed-example parametrization.

`hypothesis` is not installable in every environment this repo runs in
(the CI container has it via the `test` extra; the offline container does
not).  Importing ``given / settings / st`` from here instead of from
`hypothesis` keeps the property tests as true property tests when the
library is present, and otherwise rewrites each ``@given`` into a
``pytest.mark.parametrize`` over a deterministic set of representative
examples: the corners of every strategy plus seeded random combinations.

Only the strategy constructors the test suite actually uses are shimmed
(``integers``, ``floats``, ``sampled_from``); extend as needed.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import math
    import random

    import pytest

    HAVE_HYPOTHESIS = False

    class _Examples:
        """A fixed, ordered set of representative draws for one strategy."""

        def __init__(self, values):
            self.values = list(values)

    class st:  # noqa: N801 - mirrors `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            lo, hi = int(min_value), int(max_value)
            mid = (lo + hi) // 2
            vals = sorted({lo, hi, mid, lo + (hi - lo) // 4, lo + 3 * (hi - lo) // 4})
            return _Examples(vals)

        @staticmethod
        def floats(min_value, max_value):
            lo, hi = float(min_value), float(max_value)
            mid = math.sqrt(lo * hi) if lo > 0 else 0.5 * (lo + hi)
            return _Examples([lo, mid, hi])

        @staticmethod
        def sampled_from(elements):
            return _Examples(list(elements))

    def given(**strategies):
        names = list(strategies)
        lists = [strategies[n].values for n in names]
        rng = random.Random(0)
        cases = [tuple(l[0] for l in lists), tuple(l[-1] for l in lists)]
        for _ in range(8):
            cases.append(tuple(rng.choice(l) for l in lists))
        seen, unique = set(), []
        for c in cases:
            if c not in seen:
                seen.add(c)
                unique.append(c)

        def deco(fn):
            # single-strategy @given: parametrize wants scalars, not
            # 1-tuples (a tuple value would reach the test as-is)
            cases = [c[0] for c in unique] if len(names) == 1 else unique
            return pytest.mark.parametrize(",".join(names), cases)(fn)

        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco
