"""Tests for fleet-scale batched hyperparameter optimization.

Pins the contracts of the gp_hyperopt tentpole:
  1. the masked NLML equals the NLML of the kept subset, and runs through
     the backend registry's moments hooks — value AND gradient agree
     between jnp and pallas, and the jaxpr of value_and_grad materializes
     no N x M intermediate for any registered expansion on either backend
     (the streaming-NLML sweep);
  2. the (B tenants x R restarts) lane engine: frozen lanes stop moving
     BIT-exactly while the step stays one executable (zero jit cache
     misses across mask/data/convergence churn), best-restart selection
     follows the final NLML, and a fleet run equals a loop of
     single-tenant runs EXACTLY (the scan-over-tenants construction);
  3. GPBank.optimize == loop of GP.optimize on both backends (the
     acceptance gate), and the heterogeneous bank it returns keeps every
     serving contract: per-slot hyperparameters in state()/mean_var(),
     update == fit_update, insert/evict with foreign hyperparameters,
     churn without recompiles;
  4. the router's staleness counters and reoptimize() hook.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.bank import BankRouter, GPBank
from repro.bank import bank as bank_mod
from repro.core import fagp
from repro.core.gp import GP, GPSpec
from repro.data import make_gp_dataset
from repro.optim import gp_hyperopt as gh

from test_streaming_fit import _has_nxm_intermediate, _iter_eqns


def _spec(expansion="hermite", p=2, n=5, backend="jnp", **kw):
    if expansion == "hermite":
        return GPSpec.create(n, eps=[0.8] * p, rho=2.0, noise=0.05,
                             backend=backend, **kw)
    return GPSpec.create_rff([0.8] * p, noise=0.05, kernel=expansion[4:],
                             num_features=16, seed=0, backend=backend, **kw)


def _fleet_data(B, N, p=2, seed=0):
    Xb = np.zeros((B, N, p), np.float32)
    yb = np.zeros((B, N), np.float32)
    for s in range(B):
        X, y, *_ = make_gp_dataset(N, p, seed=seed + s)
        Xb[s], yb[s] = np.asarray(X), np.asarray(y)
    return jnp.asarray(Xb), jnp.asarray(yb)


class TestMaskedNlml:
    def test_masked_equals_subset(self):
        N = 120
        X, y, *_ = make_gp_dataset(N, 2, seed=1)
        spec = _spec()
        keep = np.random.default_rng(2).uniform(size=N) > 0.35
        masked = float(fagp.nlml(X, y, spec,
                                 mask=jnp.asarray(keep.astype(np.float32))))
        subset = float(fagp.nlml(X[jnp.asarray(np.flatnonzero(keep))],
                                 y[jnp.asarray(np.flatnonzero(keep))], spec))
        assert masked == pytest.approx(subset, rel=1e-4, abs=1e-3)

    def test_mask_shape_validated(self):
        X, y, *_ = make_gp_dataset(16, 2, seed=0)
        with pytest.raises(ValueError, match="mask must be"):
            fagp.nlml(X, y, _spec(), mask=jnp.ones((4,)))

    def test_data_cotangents_survive_the_custom_vjp(self):
        """nlml stays differentiable through the DATA (X, y), not just the
        hyperparameters: the moments custom-VJP must propagate data
        cotangents (regression — an early draft returned zeros, silently
        corrupting input-side gradients)."""
        X, y, *_ = make_gp_dataset(80, 2, seed=0)
        spec = _spec(n=5)
        idx = jnp.asarray(spec.indices(2))

        def ref_nlml(X, y):
            # the fully-differentiable inline-moments path as the oracle
            exp = fagp.get_expansion(spec.expansion)
            N = X.shape[0]
            sig2 = spec.noise**2
            loglam = exp.log_eigenvalues(idx, spec)
            G, b = fagp._accumulate_moments(X, y, spec, idx, N)
            B, sqrtlam = fagp._assemble_scaled_system(G, loglam, sig2)
            chol = jnp.linalg.cholesky(B)
            bs = fagp._tscale(sqrtlam, b) / sig2
            w = jax.scipy.linalg.cho_solve((chol, True), bs)
            quad = jnp.sum(y * y) / sig2 - jnp.sum(bs * w)
            logdet = (2.0 * jnp.sum(jnp.log(jnp.diagonal(chol)))
                      + N * jnp.log(sig2))
            return 0.5 * (quad + logdet + N * jnp.log(2.0 * jnp.pi))

        g_ref_y = jax.grad(ref_nlml, argnums=1)(X, y)
        g_ref_X = jax.grad(ref_nlml, argnums=0)(X, y)
        for backend in ("jnp", "pallas"):
            sp = spec.replace(backend=backend)
            g_y = jax.grad(lambda yy: fagp.nlml(X, yy, sp))(y)
            g_X = jax.grad(lambda XX: fagp.nlml(XX, y, sp))(X)
            np.testing.assert_allclose(np.asarray(g_y), np.asarray(g_ref_y),
                                       atol=1e-2, rtol=1e-3)
            np.testing.assert_allclose(np.asarray(g_X), np.asarray(g_ref_X),
                                       atol=1e-2, rtol=1e-3)

    def test_backends_agree_value_and_grad(self):
        """The registry-dispatched NLML: the pallas moments hook computes
        the value, the streamed custom-VJP the gradient — both must match
        the jnp path."""
        X, y, *_ = make_gp_dataset(200, 2, seed=3)
        out = {}
        for backend in ("jnp", "pallas"):
            spec0 = _spec(backend=backend, n=6)

            def loss(log_eps):
                sp = dataclasses.replace(spec0, eps=jnp.exp(log_eps))
                return fagp.nlml(X, y, sp)

            out[backend] = jax.value_and_grad(loss)(jnp.zeros(2))
        np.testing.assert_allclose(
            float(out["pallas"][0]), float(out["jnp"][0]), rtol=1e-4
        )
        np.testing.assert_allclose(
            np.asarray(out["pallas"][1]), np.asarray(out["jnp"][1]),
            rtol=1e-3, atol=1e-2,
        )


class TestStreamingNlml:
    """The streaming-NLML sweep: optimizing hyperparameters never
    materializes the N x M feature matrix — value and gradient, every
    registered expansion, both backends."""

    N = 600

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    @pytest.mark.parametrize("expansion",
                             ["hermite", "rff_se", "rff_matern52"])
    def test_nlml_value_and_grad_have_no_nxm(self, expansion, backend):
        X, y, *_ = make_gp_dataset(self.N, 2, seed=0)
        spec = _spec(expansion, n=6, backend=backend, block_rows=64)
        M = spec.n_features(2)

        def loss(log_eps):
            sp = dataclasses.replace(spec, eps=jnp.exp(log_eps))
            mask = jnp.ones((X.shape[0],), jnp.float32)
            return fagp._nlml_core(X, y, sp, mask)

        fn = jax.value_and_grad(loss)
        assert not _has_nxm_intermediate(fn, (jnp.zeros(2),), self.N, M)

    def test_pallas_path_actually_runs_the_kernel(self):
        """Guard against the dispatch silently falling back to jnp: the
        pallas-backend NLML jaxpr must contain a pallas_call."""
        X, y, *_ = make_gp_dataset(self.N, 2, seed=0)
        spec = _spec(backend="pallas", n=6, block_rows=64)
        mask = jnp.ones((X.shape[0],), jnp.float32)
        jaxpr = jax.make_jaxpr(
            lambda X, y: fagp._nlml_core(X, y, spec, mask)
        )(X, y)
        names = {eqn.primitive.name for eqn in _iter_eqns(jaxpr.jaxpr)}
        assert "pallas_call" in names


class TestLaneEngine:
    def _setup(self, B=3, N=16, R=2):
        Xb, yb = _fleet_data(B, N)
        spec = _spec().replace(block_rows=N)
        idx = jnp.asarray(spec.indices(2))
        hp = gh._init_lanes(spec, B, R, 0, 0.3, None)
        ocfg = gh.adamw.AdamWConfig(lr=5e-2, weight_decay=0.0,
                                    clip_norm=None)
        ostate = gh.adamw.init(hp, ocfg)
        maskb = jnp.ones((B, N), jnp.float32)
        return Xb, yb, maskb, spec, idx, hp, ocfg, ostate

    def test_frozen_lanes_stop_moving_bitwise(self):
        """A frozen lane's parameters AND optimizer moments are carried
        through unchanged — not 'small updates', NO updates."""
        Xb, yb, maskb, spec, idx, hp, ocfg, ostate = self._setup()
        B, R = 3, 2
        prev = jnp.full((B, R), jnp.inf, jnp.float32)
        frozen = jnp.zeros((B, R), bool)
        # one live step to get nonzero optimizer moments
        hp, ostate, frozen, prev, _ = gh._lane_step(
            hp, ostate, frozen, prev, Xb, yb, maskb, spec, idx,
            jnp.float32(-jnp.inf), ocfg,
        )
        pattern = jnp.asarray(np.array([[True, False], [False, True],
                                        [True, True]]))
        hp2, ostate2, *_ = gh._lane_step(
            hp, ostate, pattern, prev, Xb, yb, maskb, spec, idx,
            jnp.float32(-jnp.inf), ocfg,
        )
        pat = np.asarray(pattern)
        for f in hp:
            moved = np.asarray(hp2[f]) != np.asarray(hp[f])
            moved = moved.reshape(pat.shape + (-1,)).any(axis=-1)
            assert not moved[pat].any()      # frozen lanes: bit-identical
            assert moved[~pat].all()         # live lanes: actually moved
            for k in ("m", "v"):
                m_moved = (np.asarray(ostate2["mu"][f][k])
                           != np.asarray(ostate["mu"][f][k]))
                m_moved = m_moved.reshape(pat.shape + (-1,)).any(axis=-1)
                assert not m_moved[pat].any()

    def test_step_executable_reused_across_churn(self):
        """Convergence patterns, row masks and data churn never recompile
        the lane step (shapes key the cache, values do not)."""
        Xb, yb, maskb, spec, idx, hp, ocfg, ostate = self._setup()
        B, R = 3, 2
        prev = jnp.full((B, R), jnp.inf, jnp.float32)
        frozen = jnp.zeros((B, R), bool)
        args = (hp, ostate, frozen, prev, Xb, yb, maskb, spec, idx,
                jnp.float32(-jnp.inf), ocfg)
        gh._lane_step(*args)
        size0 = gh._lane_step._cache_size()
        rng = np.random.default_rng(0)
        for _ in range(3):
            maskc = jnp.asarray(
                (rng.uniform(size=maskb.shape) > 0.3).astype(np.float32)
            )
            frozenc = jnp.asarray(rng.uniform(size=(B, R)) > 0.5)
            gh._lane_step(hp, ostate, frozenc, prev, Xb * 1.1, yb, maskc,
                          spec, idx, jnp.float32(1e-4), ocfg)
        assert gh._lane_step._cache_size() == size0

    def test_tol_freezes_and_early_exits(self):
        Xb, yb = _fleet_data(2, 16)
        res = gh.optimize_fleet(Xb, yb, _spec(), restarts=2, steps=50,
                                tol=1e9, seed=0)
        assert res.steps_run < 50
        assert res.frozen.all()

    def test_restart_selection_follows_final_nlml(self):
        Xb, yb = _fleet_data(2, 16)
        res = gh.optimize_fleet(Xb, yb, _spec(), restarts=3, steps=5,
                                seed=1)
        lane = np.asarray(res.lane_nlml)
        np.testing.assert_array_equal(np.asarray(res.best_restart),
                                      lane.argmin(axis=1))
        np.testing.assert_allclose(np.asarray(res.nlml), lane.min(axis=1))
        assert res.eps.shape == (2, 2) and res.noise.shape == (2,)

    def test_fleet_equals_loop_of_singles_exactly(self):
        """The parity construction: per-tenant lane math is bit-identical
        between a fleet run and single-tenant runs (scan over tenants,
        length-1 padded)."""
        B = 4
        Xb, yb = _fleet_data(B, 16)
        spec = _spec()
        res = gh.optimize_fleet(Xb, yb, spec, restarts=2, steps=6, seed=2)
        for t in range(B):
            one = gh.optimize_restarts(Xb[t], yb[t], spec, restarts=2,
                                       steps=6, seed=2)
            np.testing.assert_array_equal(np.asarray(res.eps[t]),
                                          np.asarray(one.eps[0]))
            np.testing.assert_array_equal(np.asarray(res.rho[t]),
                                          np.asarray(one.rho[0]))
            np.testing.assert_array_equal(np.asarray(res.noise[t]),
                                          np.asarray(one.noise[0]))
            np.testing.assert_array_equal(np.asarray(res.nlml[t]),
                                          np.asarray(one.nlml[0]))

    def test_gp_optimize_restarts_picks_best(self):
        X, y, *_ = make_gp_dataset(64, 2, seed=4)
        spec = _spec()
        multi = gh.optimize_restarts(X, y, spec, restarts=3, steps=8,
                                     seed=0)
        single = gh.optimize_restarts(X, y, spec, restarts=1, steps=8,
                                      seed=0)
        # restart 0 of the multi run is the single run's lane (the restart
        # axis is vmapped, so R=1 vs R=3 lower differently — agreement is
        # to batched-GEMM rounding, unlike the bit-exact tenant axis)
        np.testing.assert_allclose(float(multi.lane_nlml[0, 0]),
                                   float(single.nlml[0]), rtol=1e-3)
        assert float(multi.nlml[0]) <= float(multi.lane_nlml[0, 0]) + 1e-6
        gp = GP.optimize(X, y, spec, restarts=3, steps=8, seed=0)
        best = multi.spec_for(spec, 0)
        np.testing.assert_array_equal(np.asarray(gp.spec.eps),
                                      np.asarray(best.eps))


class TestGPBankOptimize:
    def _bank(self, B=3, N=16, backend="jnp", seed=0):
        Xb, yb = _fleet_data(B, N, seed=seed)
        spec = _spec(backend=backend)
        return GPBank.fit(Xb, yb, spec), Xb, yb, spec

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_bank_optimize_matches_gp_loop(self, backend):
        """The acceptance gate at test scale: GPBank.optimize selects the
        same hyperparameters as a loop of GP.optimize runs (exactly), and
        the refit bank serves the same posterior as GP.fit at the learned
        values."""
        bank, Xb, yb, spec = self._bank(backend=backend)
        opt = bank.optimize(Xb, yb, restarts=2, steps=6, seed=5)
        assert opt.hypers is not None
        rng = np.random.default_rng(1)
        Xq = jnp.asarray(rng.uniform(-1, 1, (6, 2)).astype(np.float32))
        for t in range(3):
            gp = GP.optimize(Xb[t], yb[t], spec, restarts=2, steps=6,
                             seed=5)
            st = opt.state(t)
            np.testing.assert_array_equal(np.asarray(st.spec.eps),
                                          np.asarray(gp.spec.eps))
            np.testing.assert_array_equal(np.asarray(st.spec.noise),
                                          np.asarray(gp.spec.noise))
            m1, v1 = gp.mean_var(Xq)
            m2, v2 = opt.mean_var([t] * 6, Xq)
            np.testing.assert_allclose(np.asarray(m2), np.asarray(m1),
                                       rtol=5e-3, atol=2e-4)
            np.testing.assert_allclose(np.asarray(v2), np.asarray(v1),
                                       rtol=5e-3, atol=2e-4)

    def test_optimize_subset_leaves_others_untouched(self):
        bank, Xb, yb, spec = self._bank()
        opt = bank.optimize(Xb[1:2], yb[1:2], tenant_ids=[1], restarts=2,
                            steps=5, seed=0)
        Xq = jnp.asarray(
            np.random.default_rng(2).uniform(-1, 1, (4, 2)).astype(np.float32)
        )
        m0a, v0a = bank.mean_var([0] * 4, Xq)
        m0b, v0b = opt.mean_var([0] * 4, Xq)
        np.testing.assert_allclose(np.asarray(m0b), np.asarray(m0a),
                                   atol=1e-6)
        # untouched tenants keep the bank spec's hyperparameters
        st0 = opt.state(0)
        np.testing.assert_array_equal(np.asarray(st0.spec.eps),
                                      np.asarray(spec.eps))
        assert float(opt.state(1).spec.noise) != float(spec.noise)

    def test_hetero_update_matches_fit_update(self):
        bank, Xb, yb, spec = self._bank()
        opt = bank.optimize(Xb, yb, restarts=2, steps=5, seed=3)
        rng = np.random.default_rng(4)
        Xk = jnp.asarray(rng.uniform(-1, 1, (2, 4, 2)).astype(np.float32))
        yk = jnp.asarray(rng.standard_normal((2, 4)).astype(np.float32))
        Xq = jnp.asarray(rng.uniform(-1, 1, (5, 2)).astype(np.float32))
        up = opt.update([0, 2], Xk, yk)
        for g, t in enumerate((0, 2)):
            st = fagp.fit_update(opt.state(t), Xk[g], yk[g])
            m1, v1 = fagp.predict_mean_var(st, Xq)
            m2, v2 = up.mean_var([t] * 5, Xq)
            np.testing.assert_allclose(np.asarray(m2), np.asarray(m1),
                                       atol=1e-5)
            np.testing.assert_allclose(np.asarray(v2), np.asarray(v1),
                                       atol=1e-5)

    def test_hetero_insert_evict_roundtrip(self):
        """A heterogeneous bank admits tenants fitted under THEIR OWN
        hyperparameters (structure still shared), serves them correctly,
        and evict resets the slot to the bank spec's prior."""
        bank, Xb, yb, spec = self._bank()
        opt = bank.optimize(Xb, yb, restarts=2, steps=5, seed=6)
        ev = opt.evict(1)
        X, y, *_ = make_gp_dataset(16, 2, seed=50)
        foreign = fagp.fit(X, y, spec.replace(
            eps=jnp.asarray([1.5, 0.4], jnp.float32),
            noise=jnp.asarray(0.3, jnp.float32),
        ))
        ins = ev.insert("f", foreign)
        Xq = jnp.asarray(
            np.random.default_rng(3).uniform(-1, 1, (5, 2)).astype(np.float32)
        )
        m1, v1 = fagp.predict_mean_var(foreign, Xq)
        m2, v2 = ins.mean_var(["f"] * 5, Xq)
        np.testing.assert_allclose(np.asarray(m2), np.asarray(m1),
                                   rtol=1e-4, atol=1e-5)
        # the returned state round-trips the foreign hyperparameters
        np.testing.assert_array_equal(np.asarray(ins.state("f").spec.eps),
                                      np.asarray(foreign.spec.eps))
        # structural mismatch still refused
        other = fagp.fit(X, y, spec.replace(n=4))
        with pytest.raises(ValueError, match="expansion structure"):
            ins.evict("f").insert("g", other)

    def test_hetero_churn_without_recompile(self):
        """The heterogeneous serving and slot-write executables are keyed
        on the stack shapes only — churn through a hetero bank adds no jit
        cache entries."""
        bank, Xb, yb, spec = self._bank(B=3)
        opt = bank.optimize(Xb, yb, restarts=2, steps=4, seed=7)
        Xq = jnp.asarray(
            np.random.default_rng(5).uniform(-1, 1, (4, 2)).astype(np.float32)
        )
        opt = opt.evict(2)
        X, y, *_ = make_gp_dataset(16, 2, seed=60)
        opt = opt.insert("warm", (X, y))
        opt.mean_var(["warm", 0, 1, 0], Xq)
        writes0 = bank_mod._write_slot._cache_size()
        serve0 = bank_mod._hetero_gathered_mean_var._cache_size()
        b = opt
        for r in range(3):
            Xn, yn, *_ = make_gp_dataset(16, 2, seed=70 + r)
            b = b.evict("warm" if r == 0 else f"t{r - 1}")
            b = b.insert(f"t{r}", (Xn, yn))
            mu, _ = b.mean_var([f"t{r}", 0, 1, f"t{r}"], Xq)
            assert np.all(np.isfinite(np.asarray(mu)))
        assert bank_mod._write_slot._cache_size() == writes0
        assert bank_mod._hetero_gathered_mean_var._cache_size() == serve0

    def test_optimize_validates_inputs(self):
        bank, Xb, yb, spec = self._bank()
        with pytest.raises(ValueError, match="one tenant id per data row"):
            bank.optimize(Xb, yb, tenant_ids=[0, 1])
        with pytest.raises(ValueError, match="duplicate tenant"):
            bank.optimize(Xb, yb, tenant_ids=[0, 0, 1])
        with pytest.raises(ValueError, match="mask must be"):
            bank.optimize(Xb, yb, mask=jnp.ones((2, 2)))


class TestRouterReopt:
    def test_stale_counting_and_reoptimize(self):
        Xb, yb = _fleet_data(3, 16)
        spec = _spec()
        bank = GPBank.fit(Xb, yb, spec)
        router = BankRouter(bank, ingest_chunk=4)
        rng = np.random.default_rng(8)
        for t, cnt in ((0, 5), (2, 2)):
            for _ in range(cnt):
                router.observe(t, rng.uniform(-1, 1, 2).astype(np.float32),
                               float(rng.standard_normal()))
        assert router.ingest() == 7
        assert router.stale_tenants(3) == [0]
        assert set(router.stale_tenants(1)) == {0, 2}
        router.reoptimize([0], Xb[:1], yb[:1], restarts=2, steps=4, seed=0)
        assert router.bank.hypers is not None
        assert router.stale_tenants(1) == [2]
        # the swapped-in bank serves through the router
        tk = router.submit(0, np.zeros(2, np.float32))
        assert np.isfinite(router.flush()[tk][0])

    def test_reoptimize_empty_is_noop(self):
        Xb, yb = _fleet_data(2, 16)
        bank = GPBank.fit(Xb, yb, _spec())
        router = BankRouter(bank)
        router.reoptimize([], Xb[:0], yb[:0])
        assert router.bank is bank
