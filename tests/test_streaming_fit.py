"""Tests for the streaming fused-fit engine and online incremental fitting.

Pins the three contracts of the tentpole:
  1. the fused phi+gram kernel (kernels/phi_gram) == materialize-then-reduce
     oracle, including row masks and ragged shapes;
  2. fit(backend='pallas') materializes NO N x M intermediate (jaxpr sweep)
     while agreeing with the jnp scan fit to f32 tolerance;
  3. fit_update (rank-k Cholesky update) == full refit, for both hybrid
     branches (sequential sweep for small k, refactorization for large k),
     and update-then-predict == refit-then-predict.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import fagp, mercer
from repro.data import make_gp_dataset
from repro.kernels import ops, ref


def _setup(N, p, n_max, seed=0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.uniform(-2, 2, size=(N, p)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal(N).astype(np.float32))
    eps = jnp.asarray(rng.uniform(0.3, 1.2, size=(p,)).astype(np.float32))
    rho = jnp.asarray(rng.uniform(1.5, 3.0, size=(p,)).astype(np.float32))
    idx = mercer.full_grid(n_max, p)
    consts = ref.phi_consts(eps, rho)
    S = jnp.asarray(ref.one_hot_selection(idx, n_max))
    M = idx.shape[0]
    d = jnp.asarray(np.geomspace(1.0, 1e-5, M).astype(np.float32))
    return X, y, consts, S, d


class TestFusedFitKernel:
    @pytest.mark.parametrize(
        "N,p,n_max",
        [
            (8, 1, 1),       # degenerate: single eigenvalue
            (100, 2, 6),     # ragged N
            (300, 3, 5),
            (513, 2, 9),     # ragged, off-pow2
            (1024, 4, 4),    # M = 256 = one full block
            (7, 1, 33),      # n_max past small unroll assumptions
        ],
    )
    def test_matches_materialized_oracle(self, N, p, n_max):
        X, y, consts, S, d = _setup(N, p, n_max)
        sig2 = jnp.float32(0.01)
        B, b = ops.fused_fit_moments(X, y, consts, S, d, sig2, n_max=n_max)
        Be, be = ref.ref_fused_fit_moments(X, y, consts, S, d, sig2, n_max)
        np.testing.assert_allclose(np.asarray(B), np.asarray(Be), rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(b), np.asarray(be), rtol=1e-3, atol=1e-3)

    def test_unscaled_moments(self):
        X, y, consts, S, d = _setup(200, 2, 5)
        G, b = ops.fused_fit_moments(
            X, y, consts, S, d, jnp.float32(1.0), n_max=5, scale=False
        )
        Ge, be = ref.ref_fused_fit_moments(
            X, y, consts, S, d, jnp.float32(1.0), 5, scale=False
        )
        np.testing.assert_allclose(np.asarray(G), np.asarray(Ge), rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(b), np.asarray(be), rtol=1e-3, atol=1e-3)

    def test_row_mask_excludes_rows(self):
        """Masked call == oracle on the kept subset (phi(0) != 0, so this
        exercises the in-kernel masking, not just zero padding)."""
        N = 150
        X, y, consts, S, d = _setup(N, 2, 6)
        keep = np.random.default_rng(3).uniform(size=N) > 0.4
        mask = jnp.asarray(keep.astype(np.float32))
        sig2 = jnp.float32(0.05)
        B, b = ops.fused_fit_moments(X, y, consts, S, d, sig2, mask, n_max=6)
        Be, be = ref.ref_fused_fit_moments(
            X[keep], y[keep], consts, S, d, sig2, 6
        )
        np.testing.assert_allclose(np.asarray(B), np.asarray(Be), rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(b), np.asarray(be), rtol=1e-3, atol=1e-3)

    @pytest.mark.parametrize("expansion",
                             ["hermite", "rff_se", "rff_matern52"])
    def test_backend_moments_parity_with_mask(self, expansion):
        """Registry contract used by core.distributed: jnp and pallas
        moments agree on a masked shard, for every registered expansion."""
        N, p, n = 220, 2, 6
        X, y, *_ = make_gp_dataset(N, p, seed=1)
        if expansion == "hermite":
            spec = fagp.GPSpec.create(n, eps=[0.8] * p, rho=2.0, noise=0.05)
        else:
            spec = fagp.GPSpec.create_rff(
                [0.8] * p, noise=0.05, kernel=expansion[4:], num_features=48,
                seed=2,
            )
        idx = jnp.asarray(spec.indices(p))
        mask = jnp.asarray(
            (np.random.default_rng(5).uniform(size=N) > 0.3).astype(np.float32)
        )
        out = {}
        for name in ("jnp", "pallas"):
            be = fagp.get_backend(name)
            aux = be.prepare(np.asarray(idx), spec)
            out[name] = be.moments(X, y, spec, idx, aux, 64, mask)
        np.testing.assert_allclose(
            np.asarray(out["pallas"][0]), np.asarray(out["jnp"][0]),
            rtol=1e-3, atol=1e-3,
        )
        np.testing.assert_allclose(
            np.asarray(out["pallas"][1]), np.asarray(out["jnp"][1]),
            rtol=1e-3, atol=1e-3,
        )


def _iter_eqns(jaxpr):
    """All equations of a jaxpr, recursing into sub-jaxprs (pjit, scan, ...)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            vals = v if isinstance(v, (list, tuple)) else [v]
            for item in vals:
                inner = getattr(item, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    yield from _iter_eqns(inner)
                elif hasattr(item, "eqns"):
                    yield from _iter_eqns(item)


def _has_nxm_intermediate(fn, args, N, M):
    jaxpr = jax.make_jaxpr(fn)(*args)
    for eqn in _iter_eqns(jaxpr.jaxpr):
        for var in eqn.outvars:
            shape = getattr(var.aval, "shape", ())
            # either orientation: an (N, M) Phi or its (M, N) transpose
            if len(shape) == 2 and max(shape) >= N and min(shape) >= M:
                return True
    return False


class TestNoMaterializedPhi:
    N, p, n = 600, 2, 6  # N well past any kernel block size; M = 36

    def _problem(self):
        X, y, *_ = make_gp_dataset(self.N, self.p, seed=0)
        params = mercer.SEKernelParams.create(
            jnp.full((self.p,), 0.8), jnp.full((self.p,), 2.0), 0.05
        )
        idx_np = mercer.full_grid(self.n, self.p)
        return X, y, params, idx_np

    def _spec(self, expansion, **kw):
        if expansion == "hermite":
            return fagp.GPSpec.create(
                self.n, eps=[0.8] * self.p, rho=2.0, noise=0.05, **kw
            )
        # R chosen so M = 2R > any kernel padding block won't hide an N x M
        return fagp.GPSpec.create_rff(
            [0.8] * self.p, noise=0.05, kernel=expansion[4:],
            num_features=32, seed=0, **kw,
        )

    @pytest.mark.parametrize("expansion",
                             ["hermite", "rff_se", "rff_matern52"])
    def test_streaming_fit_has_no_nxm(self, expansion):
        """The acceptance gate: no jaxpr intermediate of shape (>=N, >=M)
        anywhere in fit(backend='pallas', store_train=False) — for EVERY
        registered expansion (the RFF families fit streamed too)."""
        X, y, _, _ = self._problem()
        spec = self._spec(expansion, backend="pallas")
        idx = jnp.asarray(spec.indices(self.p))
        M = idx.shape[0]
        aux = fagp.get_backend("pallas").prepare(np.asarray(idx), spec)
        fn = lambda X, y: fagp._fit_pallas(X, y, spec, idx, aux).u
        assert not _has_nxm_intermediate(fn, (X, y), self.N, M)

    def test_checker_catches_materialized_path(self):
        """Sanity check of the checker itself: the materialized pipeline
        (hermite_phi -> scaled_gram) must trip it."""
        X, y, params, idx_np = self._problem()
        M = idx_np.shape[0]
        S = jnp.asarray(ref.one_hot_selection(idx_np, self.n))
        consts = ref.phi_consts(params.eps, params.rho)

        def materialized(X, y):
            Phi = ops.hermite_phi(X, consts, S, n_max=self.n)
            return ops.scaled_gram(Phi, jnp.ones((M,)), jnp.float32(0.01)), Phi.T @ y

        assert _has_nxm_intermediate(materialized, (X, y), self.N, M)

    @pytest.mark.parametrize("expansion",
                             ["hermite", "rff_se", "rff_matern52"])
    def test_jnp_scan_fit_has_no_nxm(self, expansion):
        """The jnp scan path holds the same O(M^2) bound (block_rows < N)."""
        X, y, _, _ = self._problem()
        spec = self._spec(expansion, block_rows=128)
        idx = jnp.asarray(spec.indices(self.p))
        M = idx.shape[0]
        fn = lambda X, y: fagp._fit(X, y, spec, idx).u
        assert not _has_nxm_intermediate(fn, (X, y), self.N, M)


class TestStreamingFitEngine:
    def test_pallas_fit_matches_jnp_fit(self):
        N, p, n = 700, 2, 8
        X, y, Xs, ys = make_gp_dataset(N, p, seed=2)
        spec = fagp.GPSpec.create(
            n, eps=jnp.full((p,), 0.8), rho=2.0, noise=0.05
        )
        st_j = fagp.fit(X, y, spec)
        st_p = fagp.fit(X, y, spec.replace(backend="pallas"))
        np.testing.assert_allclose(
            np.asarray(st_p.u), np.asarray(st_j.u), rtol=5e-3, atol=1e-4
        )
        np.testing.assert_allclose(
            np.asarray(st_p.chol), np.asarray(st_j.chol), rtol=5e-3, atol=1e-3
        )
        np.testing.assert_allclose(
            np.asarray(st_p.b), np.asarray(st_j.b), rtol=5e-3, atol=1e-3
        )

    def test_unknown_backend_raises(self):
        with pytest.raises(ValueError, match="unknown backend"):
            fagp.get_backend("cuda")

    def test_registry_lists_both(self):
        assert {"jnp", "pallas"} <= set(fagp.available_backends())


class TestFitUpdate:
    def _fitted(self, backend, store_train=False, N=400, p=2, n=8):
        X, y, Xs, ys = make_gp_dataset(N, p, seed=4)
        spec = fagp.GPSpec.create(
            n, eps=jnp.full((p,), 0.8), rho=2.0, noise=0.05,
            backend=backend, store_train=store_train,
        )
        return X, y, Xs, spec, fagp.fit(X, y, spec)

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    @pytest.mark.parametrize("k", [4, 64])  # sweep branch / refactor branch
    def test_update_equals_refit(self, backend, k):
        X, y, Xs, spec, st = self._fitted(backend)
        Xn, yn, *_ = make_gp_dataset(k, 2, seed=11)
        up = fagp.fit_update(st, Xn, yn)
        re = fagp.fit(
            jnp.concatenate([X, Xn]), jnp.concatenate([y, yn]), spec
        )
        np.testing.assert_allclose(
            np.asarray(up.u), np.asarray(re.u), rtol=5e-3, atol=1e-4
        )
        mu_u, var_u = fagp.predict_mean_var(up, Xs)
        mu_r, var_r = fagp.predict_mean_var(re, Xs)
        np.testing.assert_allclose(
            np.asarray(mu_u), np.asarray(mu_r), rtol=1e-3, atol=1e-4
        )
        np.testing.assert_allclose(
            np.asarray(var_u), np.asarray(var_r), rtol=5e-3, atol=1e-6
        )

    def test_sequential_updates_track_refit(self):
        """Several ingest rounds compound without drifting from the refit."""
        X, y, Xs, spec, st = self._fitted("jnp")
        Xacc, yacc = X, y
        for r in range(3):
            Xn, yn, *_ = make_gp_dataset(16, 2, seed=20 + r)
            st = fagp.fit_update(st, Xn, yn)
            Xacc = jnp.concatenate([Xacc, Xn])
            yacc = jnp.concatenate([yacc, yn])
        re = fagp.fit(Xacc, yacc, spec)
        np.testing.assert_allclose(
            np.asarray(st.u), np.asarray(re.u), rtol=1e-2, atol=1e-4
        )
        mu_u, _ = fagp.predict_mean_var(st, Xs)
        mu_r, _ = fagp.predict_mean_var(re, Xs)
        np.testing.assert_allclose(
            np.asarray(mu_u), np.asarray(mu_r), rtol=2e-3, atol=2e-4
        )

    def test_update_extends_stored_train_set(self):
        """store_train=True: Phi/y grow, and mode='paper' prediction on the
        updated state equals the refit's."""
        X, y, Xs, spec, st = self._fitted(
            "jnp", store_train=True, N=120, n=6
        )
        Xn, yn, *_ = make_gp_dataset(10, 2, seed=31)
        up = fagp.fit_update(st, Xn, yn)
        assert up.Phi.shape[0] == X.shape[0] + 10
        assert up.y.shape[0] == X.shape[0] + 10
        re = fagp.fit(
            jnp.concatenate([X, Xn]), jnp.concatenate([y, yn]), spec
        )
        # paper mode forms the N x N approximate inverse in f32; extra
        # rounding vs the fused path is expected (same tolerance as
        # test_fagp's paper-vs-fused comparison)
        mu_u, cov_u = fagp.predict(up, Xs[:9], mode="paper")
        mu_r, cov_r = fagp.predict(re, Xs[:9], mode="paper")
        np.testing.assert_allclose(
            np.asarray(mu_u), np.asarray(mu_r), atol=5e-3
        )
        np.testing.assert_allclose(
            np.asarray(cov_u), np.asarray(cov_r), atol=5e-3
        )

    def test_legacy_state_without_b_raises(self):
        _, _, _, _, st = self._fitted("jnp", N=64, n=4)
        legacy = dataclasses.replace(st, b=None)
        Xn, yn, *_ = make_gp_dataset(4, 2, seed=1)
        with pytest.raises(ValueError, match="fit_update"):
            fagp.fit_update(legacy, Xn, yn)


class TestServingLoop:
    def test_microbatched_equals_direct(self):
        from repro.launch.serve_gp import microbatched_mean_var

        N, p, n = 200, 2, 6
        X, y, Xs, ys = make_gp_dataset(N, p, seed=6)
        spec = fagp.GPSpec.create(
            n, eps=jnp.full((p,), 0.8), rho=2.0, noise=0.05
        )
        st = fagp.fit(X, y, spec)
        mu_d, var_d = fagp.predict_mean_var(st, Xs)
        mu_m, var_m, _ = microbatched_mean_var(st, Xs, microbatch=8)
        np.testing.assert_allclose(mu_m, np.asarray(mu_d), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(var_m, np.asarray(var_d), rtol=1e-5, atol=1e-7)

    def test_serve_gp_smoke(self):
        from repro.launch.serve_gp import serve_gp

        r = serve_gp(
            backend="jnp", n_train=96, p=1, n=6, rounds=2, update_size=16,
            queries=32, microbatch=16,
        )
        assert len(r["rounds"]) == 2
        assert r["rounds"][-1]["rows_absorbed"] == 96 + 2 * 16
        # posterior actually fits the cos target
        assert r["rounds"][-1]["rmse"] < 0.2
