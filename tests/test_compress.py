"""Property tests for error-feedback int8 gradient compression."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypcompat import given, settings, st  # hypothesis, or fixed examples

from repro.parallel import compress


class TestQuantize:
    @given(seed=st.integers(0, 50), scale=st.floats(1e-4, 1e3))
    @settings(max_examples=20, deadline=None)
    def test_roundtrip_error_bounded(self, seed, scale):
        rng = np.random.default_rng(seed)
        x = jnp.asarray((rng.standard_normal(1000) * scale).astype(np.float32))
        q, s = compress.quantize(x)
        y = compress.dequantize(q, s, x.shape)
        # per-block error <= blockmax/127/2 (round-to-nearest)
        blocks = np.pad(np.asarray(x), (0, (-1000) % compress.BLOCK)).reshape(-1, compress.BLOCK)
        bound = np.abs(blocks).max(axis=1, keepdims=True) / 127.0
        err = np.abs(np.pad(np.asarray(x - y), (0, (-1000) % compress.BLOCK)).reshape(-1, compress.BLOCK))
        assert np.all(err <= bound * 0.51 + 1e-9)

    def test_error_feedback_unbiased_over_steps(self):
        """Constant gradient + error feedback: mean applied update -> g."""
        g = jnp.asarray(np.linspace(-3e-3, 7e-3, 512).astype(np.float32))
        r = jnp.zeros_like(g)
        applied = []
        for _ in range(50):
            v = g + r
            q, s = compress.quantize(v)
            deq = compress.dequantize(q, s, g.shape)
            r = v - deq
            applied.append(np.asarray(deq))
        mean_applied = np.mean(applied, axis=0)
        np.testing.assert_allclose(mean_applied, np.asarray(g), atol=5e-6)

    def test_exactness_for_zero(self):
        q, s = compress.quantize(jnp.zeros((64,)))
        assert float(jnp.abs(compress.dequantize(q, s, (64,))).max()) == 0.0


class TestCompressedAllReduce:
    @pytest.mark.skipif(
        not hasattr(jax.sharding, "AxisType"),
        reason="mesh AxisType/shard_map API unavailable in this jax version",
    )
    def test_matches_mean_of_shards(self):
        """On a 1-device mesh the compressed all-reduce == dequantized value;
        residual carries the quantization error."""
        mesh = jax.make_mesh((1,), ("pod",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((128,)).astype(np.float32))}
        state = compress.CompressionState.init(g)

        def run(g, r):
            return compress.compress_allreduce(g, compress.CompressionState(r), "pod")

        with jax.set_mesh(mesh):
            out, new_state = jax.shard_map(
                run, mesh=mesh,
                in_specs=(jax.sharding.PartitionSpec(),) * 2,
                out_specs=(jax.sharding.PartitionSpec(),) * 2,
                check_vma=False,
            )(g, state.residual)
        q, s = compress.quantize(g["w"])
        expect = compress.dequantize(q, s, g["w"].shape)
        np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(expect), atol=1e-7)
        np.testing.assert_allclose(
            np.asarray(new_state.residual["w"]),
            np.asarray(g["w"] - expect), atol=1e-7,
        )
