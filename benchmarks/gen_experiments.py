"""Render EXPERIMENTS.md from experiments/dryrun/*.json + perf_log.md.

  PYTHONPATH=src python -m benchmarks.gen_experiments

Degrades gracefully when the experiments/ tree is absent (fresh checkout):
the section structure and regeneration instructions are emitted with empty
tables, so EXPERIMENTS.md always exists and always documents how to fill
itself in.
"""
from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DRY = ROOT / "experiments" / "dryrun"
BASE = ROOT / "experiments" / "dryrun_baseline"

_NO_CELLS = (
    "*(no dry-run cells recorded in `experiments/dryrun/` — regenerate with"
    " the command in §Regenerating)*"
)


def load(d: Path):
    if not d.exists():
        return []
    return [json.loads(f.read_text()) for f in sorted(d.glob("*.json"))]


def expansion_rows() -> str:
    """Render BENCH_expansions.json (the kernel-family trajectory started by
    the --expansion benchmark axis) as a table, or a placeholder."""
    path = ROOT / "BENCH_expansions.json"
    if not path.exists():
        return ("*(no `BENCH_expansions.json` yet — run any benchmark with "
                "`--expansion`, e.g. the commands above)*")
    try:
        rows = json.loads(path.read_text()).get("results", [])
    except json.JSONDecodeError:
        rows = []
    if not rows:
        return "*(BENCH_expansions.json present but empty)*"
    out = ["| bench | expansion | name | µs/call | derived |",
           "|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['bench']} | {r['expansion']} | {r['name']} "
            f"| {r['seconds'] * 1e6:.1f} | {r['derived']} |"
        )
    return "\n".join(out)


def optimize_rows() -> str:
    """Render BENCH_optimize.json (the fleet-hyperopt trajectory) as a
    table, or a placeholder."""
    path = ROOT / "BENCH_optimize.json"
    if not path.exists():
        return ("*(no `BENCH_optimize.json` yet — run "
                "`PYTHONPATH=src python -m benchmarks.gp_hyperopt`)*")
    try:
        d = json.loads(path.read_text())
    except json.JSONDecodeError:
        return "*(BENCH_optimize.json unreadable)*"
    rows = d.get("results", [])
    if not rows:
        return "*(BENCH_optimize.json present but empty)*"
    out = ["| name | seconds | derived |", "|---|---|---|"]
    for r in rows:
        out.append(f"| {r['name']} | {r['seconds']:.3f} | {r['derived']} |")
    par = d.get("parity_abs", {})
    if par:
        worst = max(
            (v for rec in par.values() for v in rec.values()), default=0.0
        )
        out.append("")
        out.append(
            f"Worst bank-vs-loop parity across "
            f"{sorted(par)}: **{worst:g}** (gate: ≤1e-5, asserted "
            f"in-benchmark and by `tools/check_bench.py`)."
        )
    return "\n".join(out)


def serve_rows() -> str:
    """Render BENCH_serve.json (the pipelined-serving trajectory) as a
    table + the gated claims, or a placeholder."""
    path = ROOT / "BENCH_serve.json"
    if not path.exists():
        return ("*(no `BENCH_serve.json` yet — run "
                "`PYTHONPATH=src python -m benchmarks.serve_latency`)*")
    try:
        d = json.loads(path.read_text())
    except json.JSONDecodeError:
        return "*(BENCH_serve.json unreadable)*"
    rows = d.get("results", [])
    if not rows:
        return "*(BENCH_serve.json present but empty)*"
    out = ["| name | seconds | derived |", "|---|---|---|"]
    for r in rows:
        out.append(f"| {r['name']} | {r['seconds']:.4f} | {r['derived']} |")
    lat = d.get("latency", {})
    out.append("")
    out.append(
        f"Pipelined-vs-sync sustained speedup: "
        f"**{d.get('speedup_pipelined_vs_sync', float('nan')):.2f}×** "
        f"(gate: ≥1.5, hard-failed by `tools/check_bench.py`); overall "
        f"p50 {lat.get('p50_s', float('nan')) * 1e3:.2f} ms / p99 "
        f"{lat.get('p99_s', float('nan')) * 1e3:.2f} ms over "
        f"{len(lat.get('tenants', {}))} tenants; "
        f"{d.get('timeouts', 0)} deadline expiries (all under an "
        f"impossible SLO by construction), "
        f"{d.get('dropped_non_expired', 0)} non-expired tickets dropped "
        f"(gate: 0)."
    )
    return "\n".join(out)


def lifecycle_rows() -> str:
    """Render BENCH_lifecycle.json (the tiered-serving trajectory) as a
    table + the gated claims, or a placeholder."""
    path = ROOT / "BENCH_lifecycle.json"
    if not path.exists():
        return ("*(no `BENCH_lifecycle.json` yet — run "
                "`PYTHONPATH=src python -m benchmarks.tenant_churn`)*")
    try:
        d = json.loads(path.read_text())
    except json.JSONDecodeError:
        return "*(BENCH_lifecycle.json unreadable)*"
    rows = d.get("results", [])
    if not rows:
        return "*(BENCH_lifecycle.json present but empty)*"
    out = ["| name | seconds | derived |", "|---|---|---|"]
    for r in rows:
        out.append(f"| {r['name']} | {r['seconds']:.4f} | {r['derived']} |")
    par = d.get("parity_abs", {})
    if par:
        worst = max(
            (v for rec in par.values() for v in rec.values()), default=0.0
        )
        out.append("")
        out.append(
            f"Worst paged-vs-resident / downdate-vs-refit parity across "
            f"{sorted(par)}: **{worst:g}** (gate: ≤1e-5, asserted "
            f"in-benchmark and by `tools/check_bench.py`)."
        )
    life = d.get("lifecycle", {}).get("jnp", {})
    if life:
        out.append(
            f"Lifecycle churn during the run: {life.get('warm_restores', 0)}"
            f" warm restores, {life.get('evictions', 0)} evictions, "
            f"{life.get('cold_saves', 0)} cold saves — all through the "
            f"recompile-free insert/evict path."
        )
    return "\n".join(out)


def shard_rows() -> str:
    """Render BENCH_shard.json (the sharded mega-bank trajectory) as a
    table + the gated claims, or a placeholder."""
    path = ROOT / "BENCH_shard.json"
    if not path.exists():
        return ("*(no `BENCH_shard.json` yet — run "
                "`PYTHONPATH=src python -m benchmarks.shard_scaling`)*")
    try:
        d = json.loads(path.read_text())
    except json.JSONDecodeError:
        return "*(BENCH_shard.json unreadable)*"
    rows = d.get("results", [])
    if not rows:
        return "*(BENCH_shard.json present but empty)*"
    out = ["| name | seconds | derived |", "|---|---|---|"]
    for r in rows:
        out.append(f"| {r['name']} | {r['seconds']:.4f} | {r['derived']} |")
    proj = d.get("projected_speedup", {})
    over = d.get("wall_overhead", {})
    par = d.get("parity_abs", {})
    worst = max((v for rec in par.values() for v in rec.values()),
                default=float("nan"))
    cfg = d.get("config", {})
    out.append("")
    out.append(
        f"Projected per-device speedup at S=8 (critical path "
        f"T_resident/T_slice on a {cfg.get('host_cores', '?')}-core host "
        f"exposing {cfg.get('devices', '?')} devices): "
        f"**{proj.get('serve_S8', float('nan')):.1f}× serving / "
        f"{proj.get('fit_S8', float('nan')):.1f}× fit** (gate: ≥2.5, "
        f"hard-failed by `tools/check_bench.py`); fused sharded wall "
        f"overhead {over.get('serve_S8', float('nan')):.2f}× at S=8 "
        f"(gate: ≤4.0 — S host devices time-slice this machine's core). "
        f"Worst sharded-vs-resident / sharded-vs-loop serving parity: "
        f"**{worst:g}** (gate: ≤1e-5, asserted in-benchmark)."
    )
    return "\n".join(out)


def obs_rows() -> str:
    """Render BENCH_obs.json (the telemetry-overhead trajectory) as a
    table + the gated claims, or a placeholder."""
    path = ROOT / "BENCH_obs.json"
    if not path.exists():
        return ("*(no `BENCH_obs.json` yet — run "
                "`PYTHONPATH=src python -m benchmarks.serve_latency`)*")
    try:
        d = json.loads(path.read_text())
    except json.JSONDecodeError:
        return "*(BENCH_obs.json unreadable)*"
    rows = d.get("results", [])
    if not rows:
        return "*(BENCH_obs.json present but empty)*"
    out = ["| name | seconds | derived |", "|---|---|---|"]
    for r in rows:
        out.append(f"| {r['name']} | {r['seconds']:.4f} | {r['derived']} |")
    series = d.get("metric_series", {})
    out.append("")
    out.append(
        f"Fully-instrumented (registry + tracer + armed watchdog) vs no-op"
        f" telemetry pass: **{d.get('overhead_ratio', float('nan')):.3f}×**"
        f" (gate: ≤1.05, hard-failed by `tools/check_bench.py`); "
        f"**{d.get('recompiles', 'n/a')} serving-path recompiles** across "
        f"the armed submit/observe/page/age churn lane (gate: 0). The "
        f"instrumented pass captured {d.get('trace_events', 0)} trace "
        f"events and {sum(series.values()) if series else 0} metric "
        f"series ({series.get('counters', 0)} counters, "
        f"{series.get('gauges', 0)} gauges, "
        f"{series.get('histograms', 0)} histograms)."
    )
    return "\n".join(out)


def vecchia_rows() -> str:
    """Render BENCH_vecchia.json (the nearest-neighbor-conditioning
    trajectory) as a table + the gated claims, or a placeholder."""
    path = ROOT / "BENCH_vecchia.json"
    if not path.exists():
        return ("*(no `BENCH_vecchia.json` yet — run "
                "`PYTHONPATH=src python -m benchmarks.vecchia`)*")
    try:
        d = json.loads(path.read_text())
    except json.JSONDecodeError:
        return "*(BENCH_vecchia.json unreadable)*"
    rows = d.get("results", [])
    if not rows:
        return "*(BENCH_vecchia.json present but empty)*"
    out = ["| name | seconds | derived |", "|---|---|---|"]
    for r in rows:
        out.append(f"| {r['name']} | {r['seconds']:.4f} | {r['derived']} |")
    acc = d.get("accuracy", {})
    agree = d.get("agreement", {})
    worst = max((v for rec in agree.values() for v in rec.values()),
                default=float("nan"))
    cfg = d.get("config", {})
    out.append("")
    out.append(
        f"Clustered-spatial accuracy at N={cfg.get('n_acc', '?')}: vecchia "
        f"(k={cfg.get('k', '?')}) RMSE {acc.get('vecchia_rmse', float('nan')):.4f} vs best "
        f"global expansion ({acc.get('best_global', '?')}) "
        f"{acc.get('best_global_rmse', float('nan')):.4f} — "
        f"**{acc.get('global_over_vecchia_rmse', float('nan')):.2f}× lower error** at "
        f"**{acc.get('vecchia_over_best_global_seconds', float('nan')):.2f}×** its serve "
        f"wall-clock (gates: ≥1.0 and ≤1.25, hard-failed by "
        f"`tools/check_bench.py`).  Worst vecchia-vs-exact prediction "
        f"disagreement at k=N−1 (both kernels): **{worst:g}** (gate: ≤1e-4, "
        f"asserted in-benchmark AND gated)."
    )
    return "\n".join(out)


def table(cells, mesh: str) -> str:
    rows = [
        "| arch | shape | kind | compute s | memory s | collective s | dominant "
        "| peak GiB/dev | useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3,
             "fit_10k": 4, "fit_8m": 5, "predict_1m": 6}
    for c in sorted(cells, key=lambda c: (c.get("arch", ""), order.get(c.get("shape", ""), 9))):
        if c.get("mesh") != mesh:
            continue
        if "skipped" in c:
            rows.append(f"| {c['arch']} | {c['shape']} | — | SKIP (full attention "
                        f"at 500k) | | | | | | |")
            continue
        if "error" in c:
            rows.append(f"| {c['arch']} | {c['shape']} | — | ERROR | | | | | | |")
            continue
        t = c["terms"]
        peak = c["memory"].get("peak_bytes_est", 0) / 2**30
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['kind']} "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | **{t['dominant']}** "
            f"| {peak:.1f} | {c.get('useful_ratio', 0):.3f} "
            f"| {c.get('roofline_fraction', 0):.4f} |"
        )
    return "\n".join(rows)


def dryrun_table(cells, mesh: str) -> str:
    rows = [
        "| arch | shape | per-dev FLOPs | per-dev HBM bytes | per-dev wire bytes "
        "| dominant collectives | args GiB | temps GiB | compile s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in sorted(cells, key=lambda c: (c.get("arch", ""), c.get("shape", ""))):
        if c.get("mesh") != mesh or "terms" not in c:
            continue
        pd = c["per_device"]
        colls = sorted(c["collectives"].items(), key=lambda kv: -kv[1]["wire_bytes"])
        cstr = "; ".join(f"{k}×{v['count']}" for k, v in colls[:2]) or "none"
        m = c["memory"]
        rows.append(
            f"| {c['arch']} | {c['shape']} | {pd['flops']:.2e} | {pd['bytes']:.2e} "
            f"| {pd['wire_bytes']:.2e} | {cstr} "
            f"| {m.get('argument_bytes', 0)/2**30:.2f} "
            f"| {m.get('temp_bytes', 0)/2**30:.2f} | {c.get('compile_s', 0):.0f} |"
        )
    return "\n".join(rows)


def summary(cells):
    ok = [c for c in cells if "terms" in c]
    skip = [c for c in cells if "skipped" in c]
    err = [c for c in cells if "error" in c]
    return len(ok), len(skip), len(err)


def before_after(base, now):
    """Hillclimbed cells: baseline vs final bound term."""
    def key(c):
        return (c.get("arch"), c.get("shape"), c.get("mesh"))

    bmap = {key(c): c for c in base if "terms" in c}
    rows = [
        "| cell | bound before (s) | bound after (s) | speedup | peak before → after (GiB) |",
        "|---|---|---|---|---|",
    ]
    targets = [
        ("fagp", "fit_8m", "16x16"), ("fagp", "predict_1m", "16x16"),
        ("zamba2-7b", "train_4k", "16x16"),
        ("deepseek-v3-671b", "decode_32k", "16x16"),
        ("mamba2-130m", "train_4k", "16x16"),
        ("qwen2-1.5b", "train_4k", "16x16"),
        ("qwen2.5-3b", "train_4k", "16x16"),
        ("smollm-360m", "train_4k", "16x16"),
        ("starcoder2-3b", "train_4k", "16x16"),
        ("llama-3.2-vision-11b", "train_4k", "16x16"),
    ]
    nmap = {key(c): c for c in now if "terms" in c}
    for t in targets:
        b, n = bmap.get(t), nmap.get(t)
        if not b or not n:
            continue
        bb, nb = b["terms"]["bound_s"], n["terms"]["bound_s"]
        bp = b["memory"].get("peak_bytes_est", 0) / 2**30
        np_ = n["memory"].get("peak_bytes_est", 0) / 2**30
        rows.append(f"| {t[0]}/{t[1]} | {bb:.3f} | {nb:.3f} | **{bb/nb:.1f}×** "
                    f"| {bp:.1f} → {np_:.1f} |")
    return "\n".join(rows)


def _table_or_placeholder(render, cells, mesh):
    # placeholder only when NOTHING was recorded for this mesh; cells that
    # exist but errored/skipped must still render as ERROR/SKIP rows
    if not any(c.get("mesh") == mesh for c in cells):
        return _NO_CELLS
    return render(cells, mesh)


def main():
    cells = load(DRY)
    base = load(BASE)
    n_ok, n_skip, n_err = summary(cells)
    perf_path = ROOT / "experiments" / "perf_log.md"
    perf_log = (
        perf_path.read_text() if perf_path.exists()
        else "*(no `experiments/perf_log.md` yet — hillclimb notes land "
             "there as §Perf iterations are run)*"
    )

    md = f"""# EXPERIMENTS

Reproduction + pod-scale systems build of **“Parallel Gaussian Process with
Kernel Approximation in CUDA”** (Carminati, 2024) in JAX for TPU v5e pods.
See `README.md` for the architecture and the paper→code map; this file
records the measurements.

## Reproduction vs the paper's claims

The paper's experiment (Fig. 1) times FAGP — eigensystem + posterior mean —
as n and p grow at N = 10⁴, CPU (Eigen) vs GPU (cuBLAS). Claims reproduced
here (CPU container; `python -m benchmarks.run`, see bench_output.txt):

1. **FAGP ≡ exact GP accuracy at a fraction of the cost** (the Joukov–Kulić
   foundation): identical RMSE at N=2000 with a **33× speedup**
   (`fagp_vs_exact`), growing with N exactly as O(N³) vs O(NM²) predicts.
2. **M = nᵖ blow-up** (the paper's stated limitation): visible in
   `fig1_time_vs_n_p` — e.g. p=3 fused time grows 3.2 ms → 30.9 ms from
   n=3 → n=7 (M: 27 → 343).
3. **Parallel GEMM formulation wins**: the paper's literal Eq. 11–12 GEMM
   chain (`mode="paper"`, what cuFAGP executes) vs our fused weight-space
   path on identical hardware: **6–19× fused speedup** — and on the
   production mesh the same GEMM schedule reaches the compute roofline
   (§Perf F1, fraction ≈ 1.0).
4. **Beyond the paper** — hyperbolic-cross/total-degree index sets:
   same RMSE as the full grid at p=4 with **34× fewer columns and ~160×
   less time** (`index_set_ablation`); hyperparameter learning via NLML
   gradients (the paper's declared future work, now `GP.optimize`) recovers
   the true noise to 3 decimal places (examples/hyperparam_learning.py);
   multi-output sessions share one M×M factorization across T tasks
   (`multi_output`, **6.3× over per-task fits at T=8** on this container).

All of the above run through the self-describing `GP` session facade
(`src/repro/core/gp.py`): one `GPSpec` merges the kernel hyperparameters
and the expansion choices, is baked into the state at fit time, and no call
site re-passes configuration (tests/test_gp_api.py pins the contracts).

## §Methodology (CPU-host dry-run, TPU v5e cost model)

* 512 virtual host devices (`--xla_force_host_platform_device_count=512`);
  meshes 16×16 (pod) and 2×16×16 (multi-pod). Every cell is
  `jit(...).lower().compile()` — sharding errors, layout mismatches and
  OOM-scale buffers surface exactly as they would on hardware.
* Hardware constants: **197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s ICI** per
  chip (v5e).
* `compiled.cost_analysis()` counts while-loop bodies ONCE (verified
  empirically), which would undercount every scanned layer stack by ~n_layers.
  Costs are therefore rebuilt from the compiled HLO text
  (`src/repro/roofline/hlo_cost.py`): dot/triangular-solve/cholesky FLOPs and
  collective payloads per computation, scaled by loop trip counts parsed
  from loop conditions; HBM bytes are an op-result-size proxy (fusion
  internals excluded). Known artifact: the CPU backend promotes bf16 dots
  to f32, inflating some byte/wire counts ≈2× vs a real TPU lowering —
  noted where material.
* `roofline_fraction` = (MODEL_FLOPS / peak / chips) / max(compute, memory,
  collective); MODEL_FLOPS = 6·N_active·D for LM cells (3× forward for
  train, 1× for prefill; decode counts one token), 2NM² + M³/3 for FAGP.

## §Dry-run

{n_ok} cells compiled OK, {n_skip} recorded SKIPs (long_500k × full-attention
archs — see `src/repro/configs/shapes.py`), {n_err} errors, across BOTH meshes
(16×16 = 256 chips; 2×16×16 = 512 chips, proving the 'pod' axis shards).
Per-cell JSON in `experiments/dryrun/` (baseline preserved in
`experiments/dryrun_baseline/`). Multi-pod (2×16×16) excerpt:

{_table_or_placeholder(dryrun_table, cells, "2x16x16")}

## §Roofline (single-pod 16×16, after §Perf optimizations)

{_table_or_placeholder(table, cells, "16x16")}

Reading of the dominant bottlenecks:

* **train_4k** cells are memory-term dominated on this cost model, chiefly
  saved-activation traffic; the scan-over-layers backward saves one
  (B,S,d) carry per layer, and XLA hoists a bf16→f32 convert of the whole
  stack (CPU-backend artifact ~2×). Seq-sharding the SSM residual (§Perf Z1)
  is the template fix, applied to ssm/hybrid.
* **decode** cells are memory-bound after §Perf D1 — reading the weights +
  KV/latent cache once per token is the floor; batch 128 amortizes poorly
  by construction of the assigned shape.
* **Low useful-ratio cells** (smollm 0.07, qwen2 0.16, whisper 0.06) share
  one cause: head counts (15/12/12) that do not divide the 16-way model
  axis ⇒ attention runs model-replicated. On a real deployment the mesh
  would be reshaped (e.g. 32×8); with the mesh fixed by the assignment we
  document the fraction instead.
* **fagp cells sit at fraction ≈ 1.0** (compute roofline) after §Perf F1 —
  the paper's workload is the best-mapped workload in the table, as it
  should be.

## §Perf — baseline → hillclimb results

Three cells selected per the protocol: worst roofline fraction
(deepseek-v3/decode_32k), most collective-bound (zamba2/train_4k), most
paper-representative (fagp/fit_8m + predict_1m). Summary:

{before_after(base, cells) if base and cells else _NO_CELLS}

{perf_log}

## Paper-faithful vs beyond-paper (algorithm level)

| variant | what it is | time (N=2000, p=3, n=7, CPU) |
|---|---|---|
| `mode="paper"` | literal Eq. 11–12 GEMM chain incl. N×N approximate inverse (what cuFAGP times) | 185.5 ms |
| `mode="fused"` (beyond-paper) | weight-space simplification, same math | 30.9 ms (6.0×) |
| + hyperbolic-cross (beyond-paper) | attacks the nᵖ blow-up itself | ~160× at p=4 vs full grid |

Both variants are validated equal to f32 tolerance (tests/test_fagp.py);
the roofline table above uses the optimized implementation, the baseline
numbers are preserved in `experiments/dryrun_baseline/`.

## §Streaming fused fit + online updates

`fit(backend="pallas")` runs the streaming fused-fit kernel
(`src/repro/kernels/phi_gram.py`): Hermite-feature tiles are generated in
VMEM inside the Gram accumulation, so the N×M feature matrix Phi is never
written to HBM — one HBM pass over X, O(M²) live memory in N (pinned by
`tests/test_streaming_fit.py`'s jaxpr sweep).  `fit_update` absorbs new
observations by a rank-k Cholesky update, O(k M²) with no pass over the
original N rows.  Measure both:

    PYTHONPATH=src python -c "from benchmarks import streaming_fit; streaming_fit.run()"
    PYTHONPATH=src python -m repro.launch.serve_gp --backend pallas

## §Fleet serving (GPBank)

The multi-tenant production shape: B independent small GPs resident on the
device as ONE stacked state (`src/repro/bank/bank.py::GPBank`), driven by
single batched executables — vmapped moments on the jnp backend, a bank
grid axis in the streaming fused kernel on the pallas backend
(`src/repro/kernels/phi_gram.py::bank_phi_gram_kernel`), and a serving
router that coalesces per-tenant queues into padded mixed-tenant
microbatches (`src/repro/bank/router.py::BankRouter`).  Batched-vs-loop
parity (≤1e-5 abs, both backends) is pinned in tests/test_gp_bank.py; the
bank-vs-loop speedup and the bank-size sweep come from:

    PYTHONPATH=src python -m benchmarks.gp_bank      # writes BENCH_gp_bank.json
    PYTHONPATH=src python -m repro.launch.serve_gp --fleet 64 --n-train 64

On this container the B=64 bank answers a mixed-tenant batch **25–36×
faster than a Python loop of single-model `mean_var` calls** over the
identical per-tenant sessions (jnp backend, run-to-run spread; ~9–10× on
pallas interpret), with identical results — the loop pays per-call
dispatch B times, the bank once, and the
bank serves variances from a per-slot B⁻¹ cache that is invalidated by
construction (every mutation returns a new immutable bank).
`BENCH_gp_bank.json` records the trajectory machine-readably; CI gates
every `BENCH_*.json` (schema + parity + timing ratios) with
`tools/check_bench.py` against the committed `BENCH_baselines.json`.

## §Asynchronous fleet serving (FleetEngine)

The serving loop itself, rebuilt as a pipeline
(`src/repro/bank/engine.py::FleetEngine`): admission with per-tenant
deadlines (expired tickets answered by the documented NaN/inf timeout
sentinel, never holding a seat in a padded block), queue-budget
backpressure at submit time, arrival-rate-driven power-of-two bucket
autotuning (up to `max_coalesce` microbatches fused per dispatch — the
bucket ladder is FIXED, so traffic churn never compiles a new serving
executable), a lean dispatch path that resolves the slot map + backend
function once per bank version, and dispatch-ahead harvesting with no
per-block barrier.  Per-tenant p50/p99 and sustained QPS come from the
engine's own `LatencyStats` (exactly `numpy.percentile`, pinned by
tests/test_serve_engine.py, alongside the property-based interleaving
and fault-injection battery):

    PYTHONPATH=src python -m benchmarks.serve_latency  # writes BENCH_serve.json
    PYTHONPATH=src python -m repro.launch.serve_gp --fleet 64 --engine pipelined

Current trajectory (acceptance shape B=64/microbatch=64; the speedup and
no-dropped-tickets claims are HARD gates in `tools/check_bench.py`):

{serve_rows()}

## §Tenant lifecycle (TieredBank)

The fleet made elastic (`src/repro/bank/lifecycle.py::TieredBank`): the
hot working set stays device-resident in a `GPBank`, everything else
lives as versioned per-tenant checkpoints
(`src/repro/checkpoint/gpstate.py` — the manifest carries the GPSpec
structure + expansion + an omega hash, so restoring into a mismatched
spec raises exactly like `with_spec`).  A cold tenant's first query
warm-restores it through the recompile-free `GPBank.insert` (LRU tenant
evicted to the cold tier); arbitrary paging churn compiles ZERO new
executables (pinned by tests/test_lifecycle.py with the same jit
cache-size mechanism as tests/test_gp_bank.py).  Sliding-window
forgetting ages drifted tenants via the batched rank-k Cholesky
*downdate* (the mirror of the rank-k update), falling back to a masked
refit on the retained window when a downdate loses positive
definiteness — `serve_fleet` wires this to `BankRouter` staleness so
drifted tenants get aged, then re-optimized:

    PYTHONPATH=src python -m benchmarks.tenant_churn  # writes BENCH_lifecycle.json
    PYTHONPATH=src python -m repro.launch.serve_gp --fleet 16 --capacity 8 \\
        --cold-dir /tmp/cold --window 40

Current trajectory (acceptance shape: 16 tenants through 8 hot slots;
paged-vs-resident and downdate-vs-refit parities are HARD gates in
`tools/check_bench.py`):

{lifecycle_rows()}

## §Sharded fleet (ShardedGPBank)

The mega-bank sharded across a device mesh
(`src/repro/bank/sharded.py::ShardedGPBank`): the stacked `FAGPState`'s
leading tenant axis splits over an S-way 'bank' mesh axis (2-D
`(bank, data)` meshes compose with the v2 row-sharded fit for large-N
tenants), and every serving / ingest / churn executable runs SHARD-LOCAL
— no cross-shard collective appears on the hot path, so per-device work
divides by S.  Tenants place round-robin at fit, least-loaded on insert;
`BankRouter.rebalance` migrates tenants off the fullest shard through the
same traced-slot executables (zero recompiles, pinned in
tests/test_shard_bank.py); `TieredBank` cold-restores land on the
least-loaded shard.  Dispatch buckets per shard, so one hot shard does
not pad-inflate the others:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.shard_scaling  # writes BENCH_shard.json
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m repro.launch.serve_gp --fleet 64 --shards 8

Current trajectory (acceptance shape B=1024 over a 1/2/4/8 shard sweep;
the projected-speedup, wall-overhead and parity claims are HARD gates in
`tools/check_bench.py`):

{shard_rows()}

## §Fleet telemetry (observability)

The serving stack instrumented end to end (`src/repro/obs/`, stdlib-only):
a metrics registry (counters / gauges / fixed-bucket histograms, one
Prometheus + JSON schema — `src/repro/obs/metrics.py`), Chrome-trace span
tracing over every pipeline stage (admit → coalesce → bucket-select →
dispatch → device-wait → harvest → expire, plus page-in / evict / age /
downdate / checkpoint and hyperopt progress — `src/repro/obs/trace.py`),
and a recompile watchdog that promotes the test suite's jit cache-size
idiom to a production guard over the sixteen serving-path executables (including the seven shard-local ones)
(`src/repro/obs/watchdog.py`).  Telemetry is strictly opt-in: every layer
defaults to no-op implementations whose record paths allocate NOTHING
(pinned with `tracemalloc` in tests/test_obs.py), and the fully-ON cost
is measured as its own benchmark lane:

    PYTHONPATH=src python -m benchmarks.serve_latency  # writes BENCH_obs.json too
    PYTHONPATH=src python -m repro.launch.serve_gp --fleet 64 \\
        --metrics-port 0 --trace-out trace.jsonl --watchdog warn

Current trajectory (overhead measured at the serving acceptance shape via
interleaved instrumented/null pairs; both claims are HARD gates in
`tools/check_bench.py`, and `tools/check_trace.py` validates the emitted
JSONL in CI):

{obs_rows()}

## §Hyperparameter optimization at fleet scale

The paper's declared future work ("a parallel implementation of the
optimization problem for hyperparameter learning"), taken to the fleet:
`GPBank.optimize` / `GP.optimize(..., restarts=R)` run ONE lane engine
(`src/repro/optim/gp_hyperopt.py`) over a (B tenants × R restarts)
parameter stack — one compiled AdamW step per iteration for the whole
fleet, per-restart convergence masks (frozen lanes stop moving bit-exactly,
zero recompiles), best-restart selection by final NLML, and a batched refit
of the winners into the stacked bank state (per-slot hyperparameters — the
bank becomes heterogeneous and serves each tenant under its own learned
values).  The NLML objective streams its moments through the backend
registry, so optimization never materializes the N×M feature matrix on
either backend (jaxpr sweep in `tests/test_gp_hyperopt.py`).  Per-tenant
lane math is bit-identical to a single-model run by construction, so the
benchmark ASSERTS ≤1e-5 parity in selected hyperparameters and NLML
against a Python loop of `GP.optimize` runs:

    PYTHONPATH=src python -m benchmarks.gp_hyperopt   # writes BENCH_optimize.json

Current trajectory (acceptance config B=64/R=4 on the jnp backend; pallas
runs reduced on CPU interpret):

{optimize_rows()}

## §Multi-output sessions

The first workload the session redesign unlocks: `GP.fit(X, Y, spec)` with
Y of shape (N, T) runs the streaming moment pass and the O(M³) Cholesky
once and solves the T mean-weight systems against the shared factor in one
batched triangular solve (per-task weights u of shape (M, T)).  Numerics
are pinned to agree with T independent fits to f32 tolerance
(tests/test_gp_api.py); the shared fraction of the per-task FLOPs and the
measured speedup come from:

    PYTHONPATH=src python -c "from benchmarks import multi_output; multi_output.run()"

## §Kernel expansions (capability × family matrix)

The expansion layer (`src/repro/core/expansions.py`) turns every capability
above — streaming fused fit, incremental update, multi-output, distributed
schedules, fleet banks — into a capability × kernel-family matrix: the
Hermite–Mercer eigen-expansion (the paper's), RFF–SE, and RFF–Matérn-5/2
all run through the same `GP`/`GPBank` entry points on both backends, with
the pallas streaming path pinned (jaxpr sweep, `tests/test_streaming_fit.py`)
to never materialize the N×M Phi for ANY of them.  Reconstruction bounds
(`tests/test_expansions.py`): geometric truncation for Hermite, Monte-Carlo
4/√R for the RFF families.  On this container RFF–Matérn-5/2 at M=100
matches the exact Matérn GP's RMSE at N=2000 with a **~60× speedup**
(`fagp_vs_exact --expansion rff_matern52`), and an RFF bank serves
mixed-tenant batches just like a Hermite one.  Numbers:

    PYTHONPATH=src python -m benchmarks.kernel_micro --expansion all
    PYTHONPATH=src python -m benchmarks.fagp_vs_exact --expansion all
    PYTHONPATH=src python -m benchmarks.gp_bank --expansion all

Current `BENCH_expansions.json` trajectory (merged rows; CI smoke keeps the
schema valid):

{expansion_rows()}

## §Vecchia (nearest-neighbor conditioning)

The second APPROXIMATION FAMILY behind the `GP` facade
(`src/repro/core/vecchia.py`, conditioning sets from the blocked streaming
top-k in `src/repro/kernels/knn.py`): where FAGP replaces the N×N kernel
inverse by a global low-rank feature system, Vecchia factorizes along the
data ordering and truncates every conditional to the k nearest points —
batched (B, k, k) Cholesky lanes, O(N·k³), never a Q×N distance matrix
(jaxpr sweep in `tests/test_vecchia.py`, same methodology as the streaming
fit).  `spec.approximation` selects the family through the
`core.approximation` protocol; capability refusals (vecchia has no
`predict`/`optimize`, fagp entry points refuse vecchia specs, `GPBank`
declines both ways) raise the structured `UnsupportedError` with
`(layer, capability, spec)`.  Convergence to `exact_gp` as k→N is pinned
for both reference kernels; the clustered short-lengthscale regime where
the locality wins is the benchmark:

    PYTHONPATH=src python -m benchmarks.vecchia   # writes BENCH_vecchia.json

Current trajectory (accuracy + agreement claims are HARD gates in
`tools/check_bench.py`):

{vecchia_rows()}

## §Regenerating

This file is GENERATED — edit `benchmarks/gen_experiments.py`, not this
file.  Full pipeline on a fresh checkout:

    # 1. dry-run every (arch × shape × mesh) cell -> experiments/dryrun/*.json
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
    # 2. (optional) preserve a baseline for §Perf before/after tables
    cp -r experiments/dryrun experiments/dryrun_baseline
    # 3. CPU benchmark CSVs referenced in the claims section
    PYTHONPATH=src python -m benchmarks.run
    # 4. render this file
    PYTHONPATH=src python -m benchmarks.gen_experiments

Sections stay structurally present with placeholder markers when their
input data is missing, so docs references remain stable.
"""
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print(f"EXPERIMENTS.md written: ok={n_ok} skip={n_skip} err={n_err}")


if __name__ == "__main__":
    main()
