"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time

import jax

__all__ = ["time_fn", "emit"]


def time_fn(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    """Median wall time of a jitted callable (blocks on output)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}")
