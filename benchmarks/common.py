"""Shared benchmark utilities: timing, CSV emission, and the merged
kernel-family trajectory file ``BENCH_expansions.json``.

Benchmarks that grew an ``--expansion`` axis (kernel_micro, fagp_vs_exact,
gp_bank) record their per-expansion rows through
:func:`record_expansion_result`; rows are merged by (bench, expansion,
name) so re-running one benchmark or one expansion updates its rows in
place and the file accumulates the whole capability x kernel-family matrix
(CI validates the schema every run)."""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax

__all__ = ["time_fn", "time_loop", "emit", "record_expansion_result",
           "EXPANSIONS_JSON", "expansion_names", "bench_spec",
           "cli_expansion"]


def expansion_names() -> list:
    """The registered kernel-expansion families — THE one list the
    ``--expansion all`` benchmark axes iterate.  A newly registered family
    appears here automatically but also needs a spec recipe in
    :func:`bench_spec` before the benchmarks can drive it."""
    from repro.core.expansions import available_expansions

    return available_expansions()


def bench_spec(expansion: str, p: int, *, n: int, num_features: int,
               backend: str = "jnp", seed: int = 0, noise: float = 0.05):
    """The one benchmark spec recipe per expansion family (shared by
    kernel_micro / fagp_vs_exact / gp_bank so a new family is wired up in
    exactly one place)."""
    from repro.core.gp import GPSpec

    if expansion == "hermite":
        return GPSpec.create(n, eps=[0.8] * p, rho=2.0, noise=noise,
                             backend=backend)
    if expansion.startswith("rff_"):
        return GPSpec.create_rff(
            [0.8] * p, noise=noise, kernel=expansion[4:],
            num_features=num_features, seed=seed, backend=backend,
        )
    raise ValueError(
        f"no benchmark spec recipe for expansion {expansion!r}; add one in "
        f"benchmarks/common.py::bench_spec"
    )


def cli_expansion(argv) -> str:
    """Parse the shared ``--expansion NAME|all`` benchmark flag."""
    if "--expansion" in argv:
        i = argv.index("--expansion") + 1
        if i >= len(argv):
            raise SystemExit(
                "usage: --expansion <hermite|rff_se|rff_matern52|...|all>"
            )
        return argv[i]
    return "hermite"


EXPANSIONS_JSON = Path(__file__).resolve().parents[1] / "BENCH_expansions.json"
_EXPANSIONS_SCHEMA = 1


def record_expansion_result(bench: str, expansion: str, name: str,
                            seconds: float, derived: str = "") -> None:
    """Merge one row into BENCH_expansions.json (read-modify-write keyed by
    (bench, expansion, name) so partial re-runs never drop other rows)."""
    payload = {"schema": _EXPANSIONS_SCHEMA, "results": []}
    if EXPANSIONS_JSON.exists():
        try:
            loaded = json.loads(EXPANSIONS_JSON.read_text())
            if loaded.get("schema") == _EXPANSIONS_SCHEMA:
                payload = loaded
        except (json.JSONDecodeError, AttributeError):
            pass  # malformed file: rewrite from scratch
    key = (bench, expansion, name)
    rows = [r for r in payload.get("results", [])
            if (r.get("bench"), r.get("expansion"), r.get("name")) != key]
    rows.append({"bench": bench, "expansion": expansion, "name": name,
                 "seconds": seconds, "derived": derived})
    payload["results"] = sorted(
        rows, key=lambda r: (r["bench"], r["expansion"], r["name"])
    )
    EXPANSIONS_JSON.write_text(json.dumps(payload, indent=2) + "\n")


def time_fn(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    """Median wall time of a jitted callable (blocks on output)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def time_loop(fn, *, warmup: int = 1, repeats: int = 3):
    """Best (min) wall time of an end-to-end HOST loop — serving loops
    block and convert internally, so unlike :func:`time_fn` there is no
    device future to wait on, and min-of-repeats is the stable statistic
    for a throughput ratio on a shared machine."""
    for _ in range(warmup):
        fn()
    best = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return best


def emit(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}")
