"""Paper Figure 1 analog: FAGP execution time vs eigenvalue count n and
input dimension p at fixed N.

The paper times (CPU Eigen vs GPU cuBLAS): eigensystem construction +
posterior mean.  Here the comparison is the paper-faithful GEMM chain
(mode='paper', what cuFAGP executes) vs the fused weight-space path
(beyond-paper), on the same device — the algorithmic speedup that survives
any hardware.  The n^p blow-up the paper reports is visible in the M column.

Paper scale is N=10^4, n up to 11, p in {1,2,4}; defaults are scaled down to
keep CPU CI runtime sane (--full restores paper scale).
"""
from __future__ import annotations

import sys

from repro.core.gp import GP, GPSpec
from repro.data import make_gp_dataset

from .common import emit, time_fn


def run(full: bool = False):
    N = 10_000 if full else 2_000
    ns = (3, 5, 7, 9, 11) if full else (3, 5, 7)
    ps = (1, 2, 4) if full else (1, 2, 3)
    for p in ps:
        X, y, Xs, ys = make_gp_dataset(N, p, seed=0)
        for n in ns:
            M = n**p
            if M > 20_000:
                continue
            spec = GPSpec.create(n, eps=[0.8] * p, rho=2.0, noise=0.05)

            def fit_and_mean(spec=spec):
                gp = GP.fit(X, y, spec)
                mu, _ = gp.mean_var(Xs)
                return mu

            t_fused = time_fn(fit_and_mean)
            emit(f"fig1/fused/p{p}/n{n}", t_fused, f"M={M};N={N}")

            if M <= 1_000:  # paper chain forms N x N — cap its cost
                spec_paper = spec.replace(store_train=True)

                def fit_and_mean_paper():
                    gp = GP.fit(X, y, spec_paper)
                    mu, _ = gp.predict(Xs, mode="paper")
                    return mu

                t_paper = time_fn(fit_and_mean_paper, iters=1)
                emit(f"fig1/paper/p{p}/n{n}", t_paper,
                     f"M={M};N={N};speedup_fused={t_paper / t_fused:.1f}x")


if __name__ == "__main__":
    run(full="--full" in sys.argv)
