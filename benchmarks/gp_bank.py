"""GPBank vs a Python loop of single-model calls, and a bank-size sweep.

The fleet-serving claim: B independent small GPs answered as ONE stacked
batched call beat B sequential single-model ``GP.mean_var`` calls, because
the loop pays per-call dispatch + kernel launch + solve setup B times.
Both sides serve the *identical* fitted states (the loop serves
``bank.state(t)``), so the comparison isolates serving cost; parity of the
results is asserted here (≤1e-5 abs) and pinned in tests/test_gp_bank.py.

Writes machine-readable ``BENCH_gp_bank.json`` next to the repo root (CI
runs ``--smoke`` and fails when the file is missing or malformed).  The
``--expansion`` axis reruns the bank-vs-loop comparison with the bank's
shared spec naming another kernel family (rff_se / rff_matern52) and
records the rows in ``BENCH_expansions.json``.

  PYTHONPATH=src python -m benchmarks.gp_bank [--smoke | --full]
      [--expansion hermite|rff_se|rff_matern52|all]
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.bank import GPBank
from repro.core.gp import GP
from repro.data import make_gp_dataset

from .common import (
    bench_spec, cli_expansion, emit, expansion_names,
    record_expansion_result, time_fn,
)

ROOT = Path(__file__).resolve().parents[1]
JSON_PATH = ROOT / "BENCH_gp_bank.json"

# the acceptance-criteria workload: B=64 small tenants, n=8, p=2 (M=64).
# Q_PER_TENANT=2 is the fleet-traffic shape the bank exists for: many
# tenants, a few queries each per flush (the router's microbatch) — the
# loop pays per-call dispatch B times regardless, the bank once.
B_MAIN, N_ROWS, P, N_MERCER = 64, 8, 2, 8
Q_PER_TENANT = 2


def _fleet_problem(B, n_rows, p, n, *, seed=0, backend="jnp",
                   expansion="hermite"):
    rng = np.random.default_rng(seed)
    # M = 2R = n^p matches the hermite bank's feature count
    spec = bench_spec(expansion, p, n=n, num_features=(n**p) // 2,
                      backend=backend, seed=seed)
    Xb = np.zeros((B, n_rows, p), np.float32)
    yb = np.zeros((B, n_rows), np.float32)
    for s in range(B):
        X, y, *_ = make_gp_dataset(n_rows, p, seed=seed + s)
        Xb[s], yb[s] = np.asarray(X), np.asarray(y)
    Q = B * Q_PER_TENANT
    Xq = rng.uniform(-1, 1, size=(Q, p)).astype(np.float32)
    tenants = rng.integers(0, B, Q)
    return spec, jnp.asarray(Xb), jnp.asarray(yb), jnp.asarray(Xq), tenants


def _loop_of_singles(sessions, tenants, Xq_np):
    """The baseline a bank replaces: per-tenant single-model calls in a
    Python loop (one gather of that tenant's query rows each)."""
    out_mu = np.zeros(len(tenants), np.float32)
    out_var = np.zeros(len(tenants), np.float32)
    for t, gp in sessions.items():
        rows = np.flatnonzero(tenants == t)
        if rows.size == 0:
            continue
        mu, var = gp.mean_var(jnp.asarray(Xq_np[rows]))
        out_mu[rows] = np.asarray(mu)
        out_var[rows] = np.asarray(var)
    return out_mu, out_var


def _bank_vs_loop(backend: str, *, B, n_rows, record, expansion="hermite"):
    spec, Xb, yb, Xq, tenants = _fleet_problem(
        B, n_rows, P, N_MERCER, backend=backend, expansion=expansion
    )
    bank = GPBank.fit(Xb, yb, spec)
    tenant_list = [int(t) for t in tenants]
    Xq_np = np.asarray(Xq)
    sessions = {t: GP.from_state(bank.state(t)) for t in bank.tenants}

    mu_b, var_b = bank.mean_var(tenant_list, Xq)
    mu_l, var_l = _loop_of_singles(sessions, tenants, Xq_np)
    parity = {
        "mean_abs": float(np.max(np.abs(np.asarray(mu_b) - mu_l))),
        "var_abs": float(np.max(np.abs(np.asarray(var_b) - var_l))),
    }
    assert parity["mean_abs"] <= 1e-5 and parity["var_abs"] <= 1e-5, parity

    t_bank = time_fn(lambda: bank.mean_var(tenant_list, Xq))
    t_loop = time_fn(lambda: _loop_of_singles(sessions, tenants, Xq_np))
    speedup = t_loop / t_bank
    tag = f"B={B};Q={len(tenant_list)};M={bank.n_features}"
    emit(f"gp_bank/{expansion}/{backend}-bank-mean_var", t_bank, tag)
    emit(f"gp_bank/{expansion}/{backend}-loop-of-singles", t_loop,
         f"{tag};speedup={speedup:.1f}x")
    record(f"{expansion}/{backend}-bank-mean_var", t_bank, tag)
    record(f"{expansion}/{backend}-loop-of-singles", t_loop, tag)
    record_expansion_result("gp_bank", expansion, f"{backend}-bank-mean_var",
                            t_bank, tag)
    record_expansion_result("gp_bank", expansion, f"{backend}-loop-of-singles",
                            t_loop, f"{tag};speedup={speedup:.1f}x")
    return parity, speedup


def _size_sweep(sizes, *, record):
    for B in sizes:
        spec, Xb, yb, Xq, tenants = _fleet_problem(B, N_ROWS, P, N_MERCER)
        bank = GPBank.fit(Xb, yb, spec)
        tenant_list = [int(t) for t in tenants]
        t_fit = time_fn(lambda: GPBank.fit(Xb, yb, spec).stack.u)
        t_q = time_fn(lambda: bank.mean_var(tenant_list, Xq))
        per_q = t_q / len(tenant_list)
        tag = f"B={B};per_query_us={per_q * 1e6:.1f}"
        emit(f"gp_bank/sweep-fit-B{B}", t_fit, tag)
        emit(f"gp_bank/sweep-mean_var-B{B}", t_q, tag)
        record(f"sweep-fit-B{B}", t_fit, tag)
        record(f"sweep-mean_var-B{B}", t_q, tag)


def run(full: bool = False, smoke: bool = False,
        expansion: str = "hermite"):
    results = []

    def record(name, seconds, derived=""):
        results.append(
            {"name": name, "seconds": seconds, "derived": derived}
        )

    B = 16 if smoke else B_MAIN
    backends = ["jnp"] if smoke else ["jnp", "pallas"]
    # parity/speedup keyed by "expansion/backend" so an --expansion all
    # sweep records every family instead of overwriting the last one
    parity = {}
    speedup = {}
    for exp_name in (expansion_names() if expansion == "all"
                     else [expansion]):
        for backend in backends:
            key = f"{exp_name}/{backend}"
            parity[key], speedup[key] = _bank_vs_loop(
                backend, B=B, n_rows=N_ROWS, record=record,
                expansion=exp_name,
            )
    if not smoke:
        _size_sweep([8, 32, 64, 128] if full else [8, 32, 64],
                    record=record)

    payload = {
        "schema": 1,
        "smoke": bool(smoke),
        "config": {"B": B, "n_rows": N_ROWS, "p": P, "n": N_MERCER,
                   "q_per_tenant": Q_PER_TENANT},
        "results": results,
        "parity_abs": parity,
        "speedup_bank_vs_loop": speedup,
    }
    JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    emit("gp_bank/json-written", 0.0, str(JSON_PATH.name))
    return payload


def main():
    run(full="--full" in sys.argv, smoke="--smoke" in sys.argv,
        expansion=cli_expansion(sys.argv))


if __name__ == "__main__":
    main()
