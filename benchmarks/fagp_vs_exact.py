"""FAGP vs exact GP: accuracy and time (the Joukov-Kulic comparison the
paper builds on — FAGP must match exact-GP accuracy while removing the
O(N^3) solve)."""
from __future__ import annotations

import sys

import numpy as np

from repro.core import exact_gp, mercer
from repro.core.gp import GP, GPSpec
from repro.data import make_gp_dataset

from .common import emit, time_fn


def run(full: bool = False):
    sizes = (500, 1000, 2000, 4000) if full else (500, 1000, 2000)
    p = 2
    for N in sizes:
        X, y, Xs, ys = make_gp_dataset(N, p, seed=1)
        params = mercer.SEKernelParams.create([0.8] * p, [2.0] * p, noise=0.05)

        t_exact = time_fn(lambda: exact_gp.predict(exact_gp.fit(X, y, params), Xs)[0],
                          iters=2)
        mu_e, _ = exact_gp.predict(exact_gp.fit(X, y, params), Xs)
        rmse_e = float(np.sqrt(np.mean((np.asarray(mu_e) - np.asarray(ys)) ** 2)))
        emit(f"fagp_vs_exact/exact/N{N}", t_exact, f"rmse={rmse_e:.4f}")

        spec = GPSpec.create(10, eps=[0.8] * p, rho=2.0, noise=0.05)
        t_fagp = time_fn(lambda: GP.fit(X, y, spec).mean_var(Xs)[0])
        mu_a, _ = GP.fit(X, y, spec).mean_var(Xs)
        rmse_a = float(np.sqrt(np.mean((np.asarray(mu_a) - np.asarray(ys)) ** 2)))
        emit(f"fagp_vs_exact/fagp/N{N}", t_fagp,
             f"rmse={rmse_a:.4f};M={10**p};speedup={t_exact / t_fagp:.1f}x")


if __name__ == "__main__":
    run(full="--full" in sys.argv)
