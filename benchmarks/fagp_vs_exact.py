"""FAGP vs exact GP: accuracy and time (the Joukov-Kulic comparison the
paper builds on — FAGP must match exact-GP accuracy while removing the
O(N^3) solve), per kernel expansion.

The ``--expansion`` axis compares each registered low-rank family against
ITS exact kernel (Hermite-Mercer and RFF-SE against the SE kernel,
RFF-Matern-5/2 against the exact Matern-5/2 form in core/exact_gp.py);
rows land in BENCH_expansions.json.

  PYTHONPATH=src python -m benchmarks.fagp_vs_exact [--full]
      [--expansion hermite|rff_se|rff_matern52|all]
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import exact_gp, mercer
from repro.core.gp import GP
from repro.data import make_gp_dataset

from .common import (
    bench_spec, cli_expansion, emit, expansion_names,
    record_expansion_result, time_fn,
)

# the exact-GP oracle each family is measured against; kept in sync with
# KernelExpansion.exact_kernel — unknown families must fail loudly, never
# silently score against the SE oracle
_EXACT_KERNEL = {"hermite": "se", "rff_se": "se", "rff_matern52": "matern52"}


def _run_expansion(expansion: str, full: bool, exact_cache: dict):
    sizes = (500, 1000, 2000, 4000) if full else (500, 1000, 2000)
    p = 2
    try:
        kernel = _EXACT_KERNEL[expansion]
    except KeyError:
        raise ValueError(
            f"no exact-GP oracle mapped for expansion {expansion!r}; add it "
            f"to benchmarks/fagp_vs_exact.py::_EXACT_KERNEL"
        ) from None
    for N in sizes:
        X, y, Xs, ys = make_gp_dataset(N, p, seed=1)
        params = mercer.SEKernelParams.create([0.8] * p, [2.0] * p, noise=0.05)

        # hermite and rff_se share the exact-SE baseline: the O(N^3) fit is
        # timed once per (kernel, N) across an --expansion all sweep
        if (kernel, N) not in exact_cache:
            t_exact = time_fn(
                lambda: exact_gp.predict(exact_gp.fit(X, y, params, kernel), Xs)[0],
                iters=2,
            )
            mu_e, _ = exact_gp.predict(exact_gp.fit(X, y, params, kernel), Xs)
            rmse_e = float(
                np.sqrt(np.mean((np.asarray(mu_e) - np.asarray(ys)) ** 2))
            )
            exact_cache[(kernel, N)] = (t_exact, rmse_e)
        t_exact, rmse_e = exact_cache[(kernel, N)]
        emit(f"fagp_vs_exact/exact-{kernel}/N{N}", t_exact, f"rmse={rmse_e:.4f}")
        record_expansion_result("fagp_vs_exact", expansion, f"exact/N{N}",
                                t_exact, f"rmse={rmse_e:.4f}")

        # M = 2R = 100 matches the hermite M = 10^2 column count
        spec = bench_spec(expansion, p, n=10, num_features=50)
        M = spec.n_features(p)
        t_fagp = time_fn(lambda: GP.fit(X, y, spec).mean_var(Xs)[0])
        mu_a, _ = GP.fit(X, y, spec).mean_var(Xs)
        rmse_a = float(np.sqrt(np.mean((np.asarray(mu_a) - np.asarray(ys)) ** 2)))
        derived = f"rmse={rmse_a:.4f};M={M};speedup={t_exact / t_fagp:.1f}x"
        emit(f"fagp_vs_exact/fagp-{expansion}/N{N}", t_fagp, derived)
        record_expansion_result("fagp_vs_exact", expansion, f"fagp/N{N}",
                                t_fagp, derived)


def run(full: bool = False, expansion: str = "hermite"):
    names = expansion_names() if expansion == "all" else [expansion]
    exact_cache = {}
    for name in names:
        _run_expansion(name, full, exact_cache)


if __name__ == "__main__":
    run(full="--full" in sys.argv, expansion=cli_expansion(sys.argv))
