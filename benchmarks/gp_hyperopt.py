"""GPBank.optimize vs a Python loop of single-model GP.optimize runs.

The fleet-optimization claim: learning hyperparameters for B independent
small GPs as ONE batched (B tenants x R restarts) lane run beats B
sequential ``GP.optimize`` calls, because the loop pays per-step dispatch
(one jitted step launch + AdamW apply + Python bookkeeping) B times per
iteration and the bank pays it once.  Both sides run the SAME lane engine
(``repro.optim.gp_hyperopt``), whose per-tenant math is bit-identical by
construction (restarts vmapped, tenants scanned) — so the selected
hyperparameters and NLML are asserted to match to <= 1e-5 abs (the
acceptance gate; in practice they match exactly).

The main configuration is the acceptance workload: B=64 tenants, R=4
restarts (jnp backend); the pallas backend runs a reduced configuration
because its kernels execute in interpret mode on CPU containers.  Writes
machine-readable ``BENCH_optimize.json`` at the repo root;
``tools/check_bench.py`` gates its schema and parity in CI.

  PYTHONPATH=src python -m benchmarks.gp_hyperopt [--smoke | --full]
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.bank import GPBank
from repro.core import fagp
from repro.core.gp import GP
from repro.data import make_gp_dataset

from .common import bench_spec, emit

ROOT = Path(__file__).resolve().parents[1]
JSON_PATH = ROOT / "BENCH_optimize.json"

# the acceptance-criteria workload: B=64 tenants x R=4 restarts, small
# tenants (n=6, p=2 -> M=36) — hyperparameter learning is the per-model
# hot loop (Franey et al., arXiv:1203.1269), so this is where the fleet
# axis pays off hardest
B_MAIN, R_MAIN, N_ROWS, P, N_MERCER, STEPS = 64, 4, 16, 2, 6, 30
SEED = 7
PARITY_MAX = 1e-5


def _fleet_problem(B, n_rows, p, n, *, seed=0, backend="jnp"):
    spec = bench_spec("hermite", p, n=n, num_features=(n**p) // 2,
                      backend=backend)
    Xb = np.zeros((B, n_rows, p), np.float32)
    yb = np.zeros((B, n_rows), np.float32)
    for s in range(B):
        X, y, *_ = make_gp_dataset(n_rows, p, seed=seed + s)
        Xb[s], yb[s] = np.asarray(X), np.asarray(y)
    return spec, jnp.asarray(Xb), jnp.asarray(yb)


def _time_once(fn):
    """One warmed timing of an expensive (already-jitted-inside) callable:
    optimization runs are seconds-long, so a single post-warmup pass is
    representative where ``time_fn``'s median-of-3 would triple the cost."""
    fn()  # warm every executable involved
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(jax.tree_util.tree_leaves(out.stack.u)[0]
                          if hasattr(out, "stack") else out)
    return time.perf_counter() - t0


def _bank_vs_loop(backend, *, B, R, steps, record):
    spec, Xb, yb = _fleet_problem(B, N_ROWS, P, N_MERCER, seed=SEED,
                                  backend=backend)
    bank = GPBank.fit(Xb, yb, spec)

    opt = bank.optimize(Xb, yb, restarts=R, steps=steps, seed=SEED)
    loop = [
        GP.optimize(Xb[t], yb[t], spec, restarts=R, steps=steps, seed=SEED)
        for t in range(B)
    ]

    # parity gate: selected hyperparameters and NLML, bank vs loop
    parity = {"eps": 0.0, "rho": 0.0, "noise": 0.0, "nlml": 0.0}
    for t in range(B):
        sb = opt.state(t).spec
        sl = loop[t].spec
        parity["eps"] = max(parity["eps"],
                            float(np.max(np.abs(sb.eps - sl.eps))))
        parity["rho"] = max(parity["rho"],
                            float(np.max(np.abs(sb.rho - sl.rho))))
        parity["noise"] = max(parity["noise"],
                              float(abs(sb.noise - sl.noise)))
        nb = float(fagp.nlml(Xb[t], yb[t], sb)) / N_ROWS
        nl = float(fagp.nlml(Xb[t], yb[t], sl)) / N_ROWS
        parity["nlml"] = max(parity["nlml"], abs(nb - nl))
    assert all(v <= PARITY_MAX for v in parity.values()), parity

    t_bank = _time_once(
        lambda: bank.optimize(Xb, yb, restarts=R, steps=steps, seed=SEED)
    )
    t0 = time.perf_counter()
    for t in range(B):
        GP.optimize(Xb[t], yb[t], spec, restarts=R, steps=steps, seed=SEED)
    t_loop = time.perf_counter() - t0
    speedup = t_loop / t_bank
    tag = f"B={B};R={R};steps={steps};M={bank.n_features}"
    emit(f"gp_hyperopt/{backend}-bank-optimize", t_bank, tag)
    emit(f"gp_hyperopt/{backend}-loop-of-optimize", t_loop,
         f"{tag};speedup={speedup:.1f}x")
    record(f"hermite/{backend}-bank-optimize", t_bank, tag)
    record(f"hermite/{backend}-loop-of-optimize", t_loop,
           f"{tag};speedup={speedup:.1f}x")
    return parity, speedup


def _restart_sweep(restarts_axis, *, record, B=16, steps=10):
    """--full extra: how bank-optimize cost scales with the restart axis
    (the lanes multiply, the dispatch count does not)."""
    spec, Xb, yb = _fleet_problem(B, N_ROWS, P, N_MERCER, seed=SEED)
    bank = GPBank.fit(Xb, yb, spec)
    for R in restarts_axis:
        t = _time_once(
            lambda: bank.optimize(Xb, yb, restarts=R, steps=steps,
                                  seed=SEED)
        )
        tag = f"B={B};R={R};steps={steps};per_lane_us={t / (B * R) * 1e6:.0f}"
        emit(f"gp_hyperopt/sweep-restarts-R{R}", t, tag)
        record(f"sweep-restarts-R{R}", t, tag)


def run(full: bool = False, smoke: bool = False):
    results = []

    def record(name, seconds, derived=""):
        results.append(
            {"name": name, "seconds": seconds, "derived": derived}
        )

    # jnp runs the acceptance configuration; pallas runs reduced (its
    # kernels interpret on CPU — the parity contract is identical)
    configs = (
        [("jnp", 8, 2, 10)] if smoke
        else [("jnp", B_MAIN, R_MAIN, STEPS), ("pallas", 8, 2, 10)]
    )
    parity = {}
    speedup = {}
    for backend, B, R, steps in configs:
        key = f"hermite/{backend}"
        parity[key], speedup[key] = _bank_vs_loop(
            backend, B=B, R=R, steps=steps, record=record
        )
    if full:
        _restart_sweep([1, 2, 4, 8], record=record)

    payload = {
        "schema": 1,
        "smoke": bool(smoke),
        "config": {"B": configs[0][1], "restarts": configs[0][2],
                   "steps": configs[0][3], "n_rows": N_ROWS, "p": P,
                   "n": N_MERCER},
        "results": results,
        "parity_abs": parity,
        "speedup_bank_vs_loop": speedup,
    }
    JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    emit("gp_hyperopt/json-written", 0.0, str(JSON_PATH.name))
    return payload


def main():
    run(full="--full" in sys.argv, smoke="--smoke" in sys.argv)


if __name__ == "__main__":
    main()
