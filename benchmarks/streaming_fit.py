"""Streaming fused fit vs materialized fit, and fit_update vs refit.

On this CPU container the Pallas kernels run in interpret mode, so wall
times measure the correctness path, not TPU performance (same caveat as
kernel_micro).  The architecturally meaningful columns are the derived
ones: ``phi_hbm_mb`` is the N x M intermediate the materialized path parks
in HBM and the streaming path never allocates, and ``flops_ratio`` is the
O(k M^2) update vs O(N M^2) refit work ratio.
"""
from __future__ import annotations

import sys

import numpy as np
import jax.numpy as jnp

from repro.core import mercer
from repro.core.gp import GP, GPSpec
from repro.data import make_gp_dataset
from repro.kernels import ops, ref

from .common import emit, time_fn


def run(full: bool = False):
    N, p, n_max = (8192, 3, 8) if full else (2048, 2, 6)
    X, y, Xs, ys = make_gp_dataset(N, p, seed=0)
    params = mercer.SEKernelParams.create(
        jnp.full((p,), 0.8), jnp.full((p,), 2.0), 0.05
    )
    idx_np = mercer.full_grid(n_max, p)
    idx = jnp.asarray(idx_np)
    M = idx_np.shape[0]
    consts = ref.phi_consts(params.eps, params.rho)
    S = jnp.asarray(ref.one_hot_selection(idx_np, n_max))
    loglam = mercer.log_eigenvalues_nd(idx, params)
    sqrtlam = jnp.exp(0.5 * loglam)
    sig2 = params.noise**2
    phi_mb = N * M * 4 / 2**20
    tag = f"N={N};M={M};phi_hbm_mb={phi_mb:.1f}"

    # --- streaming vs materialized fit statistics -------------------------
    t = time_fn(
        lambda: ops.fused_fit_moments(X, y, consts, S, sqrtlam, sig2, n_max=n_max)
    )
    emit("streaming_fit/fused-1pass", t, tag)

    def materialized():
        Phi = ops.hermite_phi(X, consts, S, n_max=n_max)  # N x M -> HBM
        return ops.scaled_gram(Phi, sqrtlam, sig2), Phi.T @ y

    t = time_fn(materialized)
    emit("streaming_fit/materialized-2pass", t, tag)

    spec_j = GPSpec.create(n_max, eps=params.eps, rho=params.rho, noise=0.05)
    t = time_fn(lambda: GP.fit(X, y, spec_j).state.u)
    emit("streaming_fit/jnp-scan-fit", t, tag)

    # --- fit_update vs refit ---------------------------------------------
    k = 256 if full else 64
    Xn, yn, *_ = make_gp_dataset(k, p, seed=7)
    gp = GP.fit(X, y, spec_j)
    t_up = time_fn(lambda: gp.update(Xn, yn).state.u)
    flops_ratio = (k * M * M) / (N * M * M)
    emit("streaming_fit/fit_update-rank-k", t_up,
         f"k={k};flops_ratio={flops_ratio:.3f}")
    Xc = jnp.concatenate([X, Xn])
    yc = jnp.concatenate([y, yn])
    t_re = time_fn(lambda: GP.fit(Xc, yc, spec_j).state.u)
    emit("streaming_fit/refit-full", t_re, f"k={k};speedup={t_re/t_up:.1f}x")


if __name__ == "__main__":
    run(full="--full" in sys.argv)
