"""Multi-output FAGP: T tasks sharing one M x M factorization.

The first new workload the self-describing `GP` session API unlocks: for
``y`` of shape (N, T) the fit runs the streaming moment pass and the O(M^3)
Cholesky ONCE, then solves the T mean-weight systems against the shared
factor in one batched triangular solve — vs T full fits for T independent
sessions.  ``shared_frac`` is the fraction of the per-task-fit FLOPs
(moments + factorization) that the multi-output fit amortizes; tests pin
the numerics to agree with per-task fits to f32 tolerance.
"""
from __future__ import annotations

import sys

import numpy as np
import jax.numpy as jnp

from repro.core.gp import GP, GPSpec
from repro.data import make_gp_dataset

from .common import emit, time_fn


def run(full: bool = False):
    N, p, n, T = (8192, 2, 8, 16) if full else (2048, 2, 6, 8)
    X, y, Xs, ys = make_gp_dataset(N, p, seed=0)
    rng = np.random.default_rng(1)
    # T related tasks: scaled/shifted copies of the target + fresh noise
    scales = jnp.asarray(rng.uniform(0.5, 2.0, size=(T,)).astype(np.float32))
    noise = jnp.asarray(rng.standard_normal((N, T)).astype(np.float32)) * 0.05
    Y = y[:, None] * scales[None, :] + noise

    spec = GPSpec.create(n, eps=[0.8] * p, rho=2.0, noise=0.05)
    M = spec.indices(p).shape[0]
    # moments (2NM^2) + factorization (M^3/3) run once instead of T times
    shared = N * M * M * 2 + M**3 / 3
    per_task = shared + 2 * M * M  # + one extra triangular solve pair
    tag = f"N={N};M={M};T={T};shared_frac={shared / per_task:.3f}"

    t_multi = time_fn(lambda: GP.fit(X, Y, spec).state.u)
    emit("multi_output/fit-shared-chol", t_multi, tag)

    def per_task_fits():
        return [GP.fit(X, Y[:, t], spec).state.u for t in range(T)]

    t_single = time_fn(per_task_fits, iters=2)
    emit("multi_output/fit-per-task", t_single,
         f"T={T};speedup_shared={t_single / t_multi:.1f}x")

    gp = GP.fit(X, Y, spec)
    t_pred = time_fn(lambda: gp.mean_var(Xs)[0])
    emit("multi_output/mean_var-T-tasks", t_pred, f"T={T};Nq={Xs.shape[0]}")


if __name__ == "__main__":
    run(full="--full" in sys.argv)
