"""Render the §Dry-run / §Roofline tables from experiments/dryrun/*.json."""
from __future__ import annotations

import json
import sys
from pathlib import Path

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_cells(d: Path = DRYRUN_DIR):
    cells = []
    for f in sorted(d.glob("*.json")):
        cells.append(json.loads(f.read_text()))
    return cells


def fmt_table(cells, mesh="16x16"):
    rows = []
    hdr = ("| arch | shape | kind | compute s | memory s | coll s | dominant | "
           "peak GiB/dev | useful ratio | roofline frac |")
    sep = "|" + "---|" * 10
    rows.append(hdr)
    rows.append(sep)
    for c in cells:
        if c.get("mesh") != mesh:
            continue
        if "skipped" in c:
            rows.append(f"| {c.get('arch','?')} | {c.get('shape','?')} | — | "
                        f"SKIP | | | | | | |")
            continue
        if "error" in c:
            rows.append(f"| {c['arch']} | {c['shape']} | — | ERROR | | | | | | |")
            continue
        t = c["terms"]
        peak = c["memory"].get("peak_bytes_est", 0) / 2**30
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['kind']} "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | {t['dominant']} "
            f"| {peak:.1f} | {c.get('useful_ratio', 0):.3f} "
            f"| {c.get('roofline_fraction', 0):.4f} |"
        )
    return "\n".join(rows)


def run(full: bool = False):
    cells = load_cells()
    ok = [c for c in cells if "terms" in c]
    if not ok:
        print("roofline/no-cells,0,run launch.dryrun first")
        return
    worst = min(ok, key=lambda c: c.get("roofline_fraction", 1.0))
    coll = max(ok, key=lambda c: c["terms"]["collective_s"] / max(c["terms"]["bound_s"], 1e-12))
    print(f"roofline/cells,{len(cells)},ok={len(ok)}")
    print(f"roofline/worst_fraction,{worst.get('roofline_fraction', 0):.5f},"
          f"{worst['arch']}/{worst['shape']}/{worst['mesh']}")
    print(f"roofline/most_collective,{coll['terms']['collective_s']:.4f},"
          f"{coll['arch']}/{coll['shape']}/{coll['mesh']}")


if __name__ == "__main__":
    if "--table" in sys.argv:
        mesh = "2x16x16" if "--multi" in sys.argv else "16x16"
        print(fmt_table(load_cells(), mesh))
    else:
        run()
