"""Elastic tenant lifecycle: paged serving, cold restores, and forgetting.

The lifecycle claim behind ``repro.bank.TieredBank`` (PR 7): a fleet
larger than the device can hold serves through a hot/cold tier WITHOUT
giving up the bank's batched serving economics or its numerics —

* **paged vs resident serving** — a working set that FITS the hot
  capacity serves through the tier at essentially the resident bank's
  QPS (the page-through wrapper is a dict touch per call once everyone
  is hot), and a query batch that pulls tenants out of the cold tier
  answers within 1e-5 of the never-evicted bank.  The parity is asserted
  here and recorded for ``tools/check_bench.py`` to gate HARD.
* **cold-restore latency** — seconds per evict + warm-restore cycle
  (checkpoint write, manifest-validated load, recompile-free
  ``GPBank.insert``): the page-in cost a cold tenant's first query pays.
* **downdate vs refit** — sliding-window forgetting via the batched
  rank-k Cholesky downdate against the semantically-identical refit on
  the retained window: the downdate touches O(k) rank-1 sweeps instead
  of re-factorizing W rows, and its posterior must match the refit to
  1e-5 (asserted + gated).

Everything lands in ``BENCH_lifecycle.json``.

  PYTHONPATH=src python -m benchmarks.tenant_churn [--smoke | --full]

Smoke and full keep the same acceptance shape (B=16 tenants through
capacity=8); full runs more queries, more paging cycles, and the pallas
backend too.
"""
from __future__ import annotations

import json
import sys
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.bank import GPBank, TieredBank
from repro.data import make_gp_dataset

from .common import bench_spec, emit, time_loop

ROOT = Path(__file__).resolve().parents[1]
JSON_PATH = ROOT / "BENCH_lifecycle.json"

# acceptance shape: 16 tenants served through 8 hot slots.  N=40 rows
# and noise=0.1 are the downdate-stable shapes the tests pin
# (tests/test_lifecycle.py::TestForgetting); k=6 keeps the max error
# across all 16 tenants at ~4e-6, 2.5x inside the 1e-5 parity gate
# (k=8 sits right at the gate at this fleet width).
B, N_ROWS, P, N_MERCER = 16, 40, 2, 6
CAPACITY = 8
K_FORGET = 6
MICROBATCH = 64


def _fleet(backend: str, *, seed: int = 0):
    spec = bench_spec("hermite", P, n=N_MERCER,
                      num_features=(N_MERCER ** P) // 2, backend=backend,
                      seed=seed, noise=0.1)
    Xb = np.zeros((B, N_ROWS, P), np.float32)
    yb = np.zeros((B, N_ROWS), np.float32)
    for s in range(B):
        X, y, *_ = make_gp_dataset(N_ROWS, P, seed=seed + s)
        Xb[s], yb[s] = np.asarray(X), np.asarray(y)
    return jnp.asarray(Xb), jnp.asarray(yb), spec


def _workload(nq: int, tenant_pool, *, seed: int = 0):
    """Query batches whose DISTINCT tenants fit a hot tier of CAPACITY
    slots (``ensure_hot`` refuses wider batches by design)."""
    rng = np.random.default_rng(seed)
    pool = list(tenant_pool)
    batches = []
    for lo in range(0, nq, MICROBATCH):
        q = min(MICROBATCH, nq - lo)
        ids = [pool[int(i)] for i in rng.integers(0, len(pool), q)]
        Xq = rng.uniform(-1, 1, size=(q, P)).astype(np.float32)
        batches.append((ids, jnp.asarray(Xq)))
    return batches


def _serve(front, batches):
    for ids, Xq in batches:
        mu, var = front.mean_var(ids, Xq)
    jax.block_until_ready((mu, var))


def run(full: bool = False, smoke: bool = False):
    nq = 1024 if smoke else (8192 if full else 4096)
    cycles = 16 if smoke else (96 if full else 48)
    repeats = 3 if smoke else 5
    backends = ["jnp", "pallas"] if full else ["jnp"]

    results = []

    def record(name, seconds, derived=""):
        results.append({"name": name, "seconds": seconds, "derived": derived})

    parity = {}
    qps = {}
    lifecycle = {}

    for backend in backends:
        Xb, yb, spec = _fleet(backend)
        resident = GPBank.fit(Xb, yb, spec)
        tmp = tempfile.TemporaryDirectory(prefix="tenant_churn_")
        tiered = TieredBank.fit(Xb, yb, spec, cold_dir=tmp.name,
                                capacity=CAPACITY)
        tag = f"B={B};cap={CAPACITY};nq={nq}"

        # -- parity: paged (evict -> cold -> warm-restore) vs resident ------
        # the verification batch deliberately spans both tiers, so every
        # answer it gets went through at least one page-in
        cold_ids = tiered.cold_tenants[:CAPACITY]
        vbatches = _workload(256, cold_ids, seed=7)
        mu_p, var_p, mu_r, var_r = [], [], [], []
        for ids, Xq in vbatches:
            mp, vp = tiered.mean_var(ids, Xq)
            mr, vr = resident.mean_var(ids, Xq)
            mu_p.append(np.asarray(mp)); var_p.append(np.asarray(vp))
            mu_r.append(np.asarray(mr)); var_r.append(np.asarray(vr))
        pkey = (f"paged_vs_resident/{backend}" if backend != "jnp"
                else "paged_vs_resident")
        parity[pkey] = {
            "mean_abs": float(np.max(np.abs(np.concatenate(mu_p)
                                            - np.concatenate(mu_r)))),
            "var_abs": float(np.max(np.abs(np.concatenate(var_p)
                                           - np.concatenate(var_r)))),
        }
        assert parity[pkey]["mean_abs"] <= 1e-5 \
            and parity[pkey]["var_abs"] <= 1e-5, parity[pkey]

        # -- serving QPS: working set fits the hot tier ---------------------
        hot_ids = tiered.hot_tenants
        batches = _workload(nq, hot_ids, seed=1)
        tiered.ensure_hot(hot_ids)            # steady state: no paging
        t_res = time_loop(lambda: _serve(resident, batches),
                          repeats=repeats)
        t_tier = time_loop(lambda: _serve(tiered, batches),
                          repeats=repeats)
        qps[f"resident/{backend}"] = nq / t_res
        qps[f"paged/{backend}"] = nq / t_tier
        emit(f"churn/{backend}-resident-serve", t_res, tag)
        emit(f"churn/{backend}-paged-serve", t_tier,
             f"{tag};overhead={t_tier / t_res:.2f}x")
        record(f"{backend}-resident-serve", t_res, tag)
        record(f"{backend}-paged-serve", t_tier, tag)

        # -- cold-restore latency: evict + warm-restore cycles --------------
        # every page_in below misses (the pool cycles over 2x capacity),
        # so each one pays a checkpoint write (the eviction) + a
        # manifest-validated load + the recompile-free insert
        pool = tiered.tenants
        t0 = time_loop(
            lambda: [tiered.page_in(pool[(i * 3 + 1) % len(pool)])
                     if not tiered.is_hot(pool[(i * 3 + 1) % len(pool)])
                     else None
                     for i in range(cycles)],
            warmup=1, repeats=repeats,
        )
        per_restore = t0 / cycles
        emit(f"churn/{backend}-cold-restore", per_restore,
             f"cycles={cycles}")
        record(f"{backend}-cold-restore", per_restore, f"cycles={cycles}")
        lifecycle[backend] = dict(tiered.stats)

        # -- forgetting: batched rank-k downdate vs window refit ------------
        ids = list(range(B))
        down, ok = resident.downdate(ids, Xb[:, :K_FORGET], yb[:, :K_FORGET])
        assert bool(np.all(ok)), "downdate lost PD at the bench shape"
        refit = resident.refit_window(ids, Xb[:, K_FORGET:],
                                      yb[:, K_FORGET:])
        fbatches = _workload(256, ids[:CAPACITY], seed=11)
        mu_d, var_d, mu_f, var_f = [], [], [], []
        for bids, Xq in fbatches:
            md, vd = down.mean_var(bids, Xq)
            mf, vf = refit.mean_var(bids, Xq)
            mu_d.append(np.asarray(md)); var_d.append(np.asarray(vd))
            mu_f.append(np.asarray(mf)); var_f.append(np.asarray(vf))
        fkey = (f"downdate_vs_refit/{backend}" if backend != "jnp"
                else "downdate_vs_refit")
        parity[fkey] = {
            "mean_abs": float(np.max(np.abs(np.concatenate(mu_d)
                                            - np.concatenate(mu_f)))),
            "var_abs": float(np.max(np.abs(np.concatenate(var_d)
                                           - np.concatenate(var_f)))),
        }
        assert parity[fkey]["mean_abs"] <= 1e-5 \
            and parity[fkey]["var_abs"] <= 1e-5, parity[fkey]

        t_down = time_loop(
            lambda: jax.block_until_ready(
                resident.downdate(ids, Xb[:, :K_FORGET],
                                  yb[:, :K_FORGET])[0].stack.chol
            ),
            repeats=repeats,
        )
        t_refit = time_loop(
            lambda: jax.block_until_ready(
                resident.refit_window(ids, Xb[:, K_FORGET:],
                                      yb[:, K_FORGET:]).stack.chol
            ),
            repeats=repeats,
        )
        ftag = f"B={B};k={K_FORGET};W={N_ROWS - K_FORGET}"
        emit(f"churn/{backend}-downdate", t_down, ftag)
        emit(f"churn/{backend}-refit-window", t_refit,
             f"{ftag};downdate_speedup={t_refit / t_down:.2f}x")
        record(f"{backend}-downdate", t_down, ftag)
        record(f"{backend}-refit-window", t_refit, ftag)

        tmp.cleanup()

    emit("churn/json-written", 0.0,
         f"paged_overhead={qps['resident/jnp'] / qps['paged/jnp']:.2f}x")

    payload = {
        "schema": 1,
        "smoke": bool(smoke),
        "config": {"B": B, "n_rows": N_ROWS, "p": P, "n": N_MERCER,
                   "capacity": CAPACITY, "k_forget": K_FORGET,
                   "queries": nq, "cycles": cycles,
                   "microbatch": MICROBATCH, "repeats": repeats},
        "results": results,
        "parity_abs": parity,
        "qps": qps,
        "lifecycle": lifecycle,
    }
    JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def main():
    run(full="--full" in sys.argv, smoke="--smoke" in sys.argv)


if __name__ == "__main__":
    main()
