"""Vecchia vs the global expansions and the exact GP — accuracy + wall-clock.

Two claims land in ``BENCH_vecchia.json`` (hard-gated by
tools/check_bench.py against BENCH_baselines.json):

* **clustered-spatial accuracy** — on the short-lengthscale clustered 2-D
  data of ``make_clustered_dataset`` (the regime the family exists for),
  vecchia (k=32) beats EVERY registered global expansion at matched
  hyperparameters and matched-or-lower serve wall-clock.  Recorded as
  ``accuracy.global_over_vecchia_rmse`` (gated >= 1.0) and
  ``accuracy.vecchia_over_best_global_seconds`` (gated <= 1.25).
* **exact-GP agreement** — at full conditioning sets (k = N-1, N = 256)
  vecchia prediction IS the exact GP for both reference kernels:
  ``agreement.<kernel>.mu_abs``/``var_abs`` gated <= 1e-4 (measured
  ~1e-6; both sides factorize the same matrix under different orders).

Plus the scaling sweep: vecchia vs exact (O(N k^3) vs O(N^3), exact
capped at N <= 5000) and vecchia vs RFF serve wall-clock at
N = 2000..20000.

  PYTHONPATH=src python -m benchmarks.vecchia [--smoke | --full]
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import exact_gp
from repro.core.gp import GP, GPSpec
from repro.core.mercer import SEKernelParams
from repro.data.gp_synthetic import make_clustered_dataset

from .common import emit, time_loop

ROOT = Path(__file__).resolve().parents[1]
JSON_PATH = ROOT / "BENCH_vecchia.json"

# the clustered-spatial workload (tests/test_vecchia.py pins the same
# shape at N=1500): bump length scale 0.15 -> eps = 1/(sqrt(2) * 0.15)
EPS = 4.714
NOISE = 0.02
K = 32
DATA_KW = dict(extent=6.0, length_scale=0.15, noise=0.02, n_bumps=120)
N_AGREE = 256
EXACT_MAX_N = 5000  # O(N^3)/O(N^2): keep the exact baseline tractable


def _data(N, seed=0):
    return make_clustered_dataset(N, seed=seed, **DATA_KW)


def _global_specs():
    """One matched-hyperparameter spec per registered global expansion."""
    eps = [EPS, EPS]
    return {
        "hermite": GPSpec.create(12, eps, noise=NOISE),
        "rff_se": GPSpec.create_rff(eps, noise=NOISE, num_features=256,
                                    seed=0),
        "rff_matern52": GPSpec.create_rff(eps, noise=NOISE,
                                          kernel="matern52",
                                          num_features=256, seed=0),
    }


def _vecchia_spec(k=K, kernel="se"):
    return GPSpec.create_vecchia([EPS, EPS], NOISE, kernel=kernel,
                                 neighbors=k)


def _fit_serve(spec, X, y, Xs):
    mu, var = GP.fit(X, y, spec).mean_var(Xs)
    jax.block_until_ready((mu, var))
    return mu


def _exact_fit_serve(X, y, Xs, kernel="se"):
    params = SEKernelParams(
        eps=jnp.asarray([EPS, EPS]), rho=jnp.asarray(2.0),
        noise=jnp.asarray(NOISE),
    )
    st = exact_gp.fit(X, y, params, kernel)
    mu, var = exact_gp.mean_var(st, Xs)
    jax.block_until_ready((mu, var))
    return mu, var


def run(full: bool = False, smoke: bool = False):
    n_acc = 4000 if smoke else (20000 if full else 10000)
    sweep = ([2000, 5000] if smoke
             else ([2000, 5000, 10000, 20000] if full else [2000, 5000,
                                                            10000]))
    repeats = 2 if smoke else 3

    results = []

    def record(name, seconds, derived=""):
        results.append({"name": name, "seconds": seconds, "derived": derived})
        emit(f"vecchia/{name}", seconds, derived)

    # -- clustered-spatial accuracy at matched hyperparameters --------------
    X, y, Xs, ys = _data(n_acc)

    def rmse(mu):
        return float(jnp.sqrt(jnp.mean((mu - ys) ** 2)))

    tag = f"N={n_acc};k={K}"
    mu_v = _fit_serve(_vecchia_spec(), X, y, Xs)
    t_v = time_loop(lambda: _fit_serve(_vecchia_spec(), X, y, Xs),
                    repeats=repeats)
    r_v = rmse(mu_v)
    record("vecchia-serve", t_v, f"{tag};rmse={r_v:.4f}")

    global_rmse, global_secs = {}, {}
    for name, spec in _global_specs().items():
        mu_g = _fit_serve(spec, X, y, Xs)
        t_g = time_loop(lambda: _fit_serve(spec, X, y, Xs), repeats=repeats)
        global_rmse[name] = rmse(mu_g)
        global_secs[name] = t_g
        record(f"{name}-serve", t_g,
               f"N={n_acc};rmse={global_rmse[name]:.4f}")

    best_global = min(global_rmse, key=global_rmse.get)
    accuracy = {
        "vecchia_rmse": r_v,
        "vecchia_seconds": t_v,
        "global_rmse": global_rmse,
        "best_global": best_global,
        "best_global_rmse": global_rmse[best_global],
        "global_over_vecchia_rmse": global_rmse[best_global] / r_v,
        "vecchia_over_best_global_seconds": t_v / global_secs[best_global],
    }
    assert accuracy["global_over_vecchia_rmse"] >= 1.0, accuracy
    assert accuracy["vecchia_over_best_global_seconds"] <= 1.25, accuracy

    # -- exact-GP agreement at full conditioning sets -----------------------
    Xa, ya, Xsa, _ = _data(N_AGREE, seed=0)
    agreement = {}
    for kernel in ("se", "matern52"):
        spec = _vecchia_spec(k=N_AGREE - 1, kernel=kernel)
        mu, var = GP.fit(Xa, ya, spec).mean_var(Xsa)
        mu_e, var_e = _exact_fit_serve(Xa, ya, Xsa, kernel)
        agreement[kernel] = {
            "mu_abs": float(jnp.max(jnp.abs(mu - mu_e))),
            "var_abs": float(jnp.max(jnp.abs(var - var_e))),
        }
        assert agreement[kernel]["mu_abs"] <= 1e-4, agreement
        assert agreement[kernel]["var_abs"] <= 1e-4, agreement
    record("agreement-checked", 0.0,
           f"N={N_AGREE};k={N_AGREE - 1};"
           f"max_mu_abs={max(a['mu_abs'] for a in agreement.values()):.2e}")

    # -- scaling sweep: vecchia vs exact vs RFF -----------------------------
    scaling = []
    rff_spec = _global_specs()["rff_se"]
    for N in sweep:
        Xn, yn, Xsn, _ = _data(N, seed=1)
        t_vn = time_loop(lambda: _fit_serve(_vecchia_spec(), Xn, yn, Xsn),
                         repeats=repeats)
        t_rn = time_loop(lambda: _fit_serve(rff_spec, Xn, yn, Xsn),
                         repeats=repeats)
        row = {"N": N, "vecchia_s": t_vn, "rff_se_s": t_rn}
        record(f"vecchia-serve-N{N}", t_vn, f"k={K}")
        record(f"rff_se-serve-N{N}", t_rn, "R=256")
        if N <= EXACT_MAX_N:
            t_en = time_loop(
                lambda: _exact_fit_serve(Xn, yn, Xsn), repeats=repeats
            )
            row["exact_s"] = t_en
            record(f"exact-serve-N{N}", t_en, "O(N^3)")
        else:
            record(f"exact-serve-N{N}", 0.0,
                   f"skipped: N > {EXACT_MAX_N} (O(N^3) baseline capped)")
        scaling.append(row)

    payload = {
        "schema": 1,
        "smoke": bool(smoke),
        "config": {"n_acc": n_acc, "k": K, "eps": EPS, "noise": NOISE,
                   "sweep": sweep, "n_agree": N_AGREE,
                   "exact_max_n": EXACT_MAX_N, "repeats": repeats,
                   "data": DATA_KW},
        "results": results,
        "accuracy": accuracy,
        "agreement": agreement,
        "scaling": scaling,
    }
    JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def main():
    run(full="--full" in sys.argv, smoke="--smoke" in sys.argv)


if __name__ == "__main__":
    main()
