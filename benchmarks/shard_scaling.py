"""Sharded mega-bank scaling: the tenant axis across a device mesh.

The sharding claim behind ``repro.bank.ShardedGPBank``: at fleet sizes a
single device cannot hold or serve fast enough, splitting the stacked
``FAGPState``'s leading tenant axis across an S-way 'bank' mesh divides
every serving and fit executable's work by S with ZERO cross-shard
collectives on the hot path — each device runs the identical shard-local
program on its B/S-tenant slice.  Parity is absolute: the sharded bank,
the resident bank, and a Python loop of single-model calls all serve the
same answers (asserted here ≤1e-5 abs, gated by ``tools/check_bench.py``).

This container is a single-core CPU host, so S host devices time-slice
one core and the fused sharded WALL time cannot beat the resident bank
(it is gated here as an overhead ratio instead: sharded wall / resident
wall ≤ 2.0 — sharding must not add dispatch bloat).  The SCALING claim is
measured as the per-device critical path: the wall time of the same
executable over a B/S-tenant slice — exactly what each device computes
concurrently on real parallel hardware — giving a projected speedup
``T_resident(B) / T_slice(B/S)`` (gated ≥2.5 at S=8 for both serving and
fit).  ``host_cores`` and the method note are recorded in the payload so
a reader can tell projected from measured numbers.

Also driven here: an engine-traced segment (``FleetEngine`` over the
sharded bank) recording sustained QPS and emitting the per-shard
``shard_dispatch`` / ``shard_ingest`` / ``rebalance`` trace events that
``tools/check_trace.py --expect`` pins in CI.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python -m benchmarks.shard_scaling [--smoke | --full]
      [--trace-out FILE]

(The flag is set automatically when absent — it must reach the process
before jax initializes its platform, which is why this module touches
``os.environ`` before any jax import.)
"""
from __future__ import annotations

import json
import os
import sys
from pathlib import Path

# must precede ANY jax import: the host platform device count is fixed at
# first jax initialization
_FLAG = "--xla_force_host_platform_device_count"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_FLAG}=8"
    ).strip()

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402
import numpy as np                                      # noqa: E402

from repro.bank import (                                # noqa: E402
    BankRouter, FleetEngine, GPBank, ShardedGPBank,
)
from repro.core.gp import GP                            # noqa: E402
from repro.data import make_gp_dataset                  # noqa: E402
from repro.launch.mesh import make_bank_mesh            # noqa: E402
from repro.obs import MetricsRegistry, Tracer           # noqa: E402

from .common import bench_spec, emit, time_fn           # noqa: E402

ROOT = Path(__file__).resolve().parents[1]
JSON_PATH = ROOT / "BENCH_shard.json"

# the acceptance shape: B=1024 small tenants (n=8, p=2 -> M=64) across a
# shard-count sweep; smoke keeps B (the ≥2.5x projected-speedup gate is a
# claim about THIS fleet size) and trims queries/engine traffic
B_MAIN, N_ROWS, P, N_MERCER = 1024, 8, 2, 8
SHARD_SWEEP = (1, 2, 4, 8)
PARITY_TENANTS = 64     # loop-of-singles parity subset (loop cost is O(B))


def _fleet_problem(B, nq, *, seed=0, backend="jnp"):
    rng = np.random.default_rng(seed)
    spec = bench_spec("hermite", P, n=N_MERCER,
                      num_features=(N_MERCER ** P) // 2, backend=backend)
    Xb = np.zeros((B, N_ROWS, P), np.float32)
    yb = np.zeros((B, N_ROWS), np.float32)
    for s in range(B):
        X, y, *_ = make_gp_dataset(N_ROWS, P, seed=seed + s)
        Xb[s], yb[s] = np.asarray(X), np.asarray(y)
    Xq = rng.uniform(-1, 1, size=(nq, P)).astype(np.float32)
    tenants = rng.integers(0, B, nq)
    return spec, jnp.asarray(Xb), jnp.asarray(yb), Xq, tenants


def _loop_of_singles(bank, tenants, Xq_np, subset):
    """Per-tenant single-model calls over the parity subset (the baseline
    a sharded bank replaces, served from the bank's own states)."""
    out_mu = np.full(len(tenants), np.nan, np.float32)
    out_var = np.full(len(tenants), np.nan, np.float32)
    for t in subset:
        rows = np.flatnonzero(tenants == t)
        if rows.size == 0:
            continue
        gp = GP.from_state(bank.state(int(t)))
        mu, var = gp.mean_var(jnp.asarray(Xq_np[rows]))
        out_mu[rows] = np.asarray(mu)
        out_var[rows] = np.asarray(var)
    return out_mu, out_var


def _max_abs(a, b):
    return float(np.max(np.abs(np.asarray(a) - np.asarray(b))))


def _engine_segment(sharded, *, nq, microbatch, tracer, metrics, seed=0):
    """Mixed-tenant traffic through the pipelined engine over the sharded
    bank: sustained QPS, plus the per-shard trace events CI pins."""
    import time as _time

    rng = np.random.default_rng(seed)
    B = len(sharded)
    router = BankRouter(sharded, microbatch=microbatch,
                        metrics=metrics, tracer=tracer)
    eng = FleetEngine(router, metrics=metrics, tracer=tracer)
    q_tenants = rng.integers(0, B, nq)
    Xq = rng.uniform(-1, 1, size=(nq, P)).astype(np.float32)
    # warm the dispatch path (compile outside the timed region)
    for i in range(microbatch):
        eng.submit(int(q_tenants[i]), Xq[i])
    eng.drain()
    t0 = _time.perf_counter()
    for i in range(nq):
        eng.submit(int(q_tenants[i]), Xq[i])
    eng.drain()
    qps = nq / (_time.perf_counter() - t0)
    # a short observation burst exercises the sharded ingest scatter
    for i in range(microbatch):
        t = int(q_tenants[i])
        eng.observe(t, Xq[i], np.float32(0.0))
    eng.ingest()
    # unbalance one shard, then rebalance (emits the 'rebalance' span and
    # bumps bank_rebalance_total)
    victims = [t for t in list(router.bank.tenants)
               if router.bank.shard_of(t) == 0][:2]
    for t in victims:
        router.bank = router.bank.evict(t)
    router.rebalance(threshold=1)
    return qps


def run(full: bool = False, smoke: bool = False, trace_out=None):
    results = []

    def record(name, seconds, derived=""):
        results.append(
            {"name": name, "seconds": seconds, "derived": derived}
        )

    B = B_MAIN
    nq = 512 if smoke else 2048
    spec, Xb, yb, Xq_np, tenants = _fleet_problem(B, nq)
    Xq = jnp.asarray(Xq_np)

    # -- resident baseline ---------------------------------------------------
    resident = GPBank.fit(Xb, yb, spec)
    tenant_list = [int(t) for t in tenants]
    t_fit_res = time_fn(lambda: GPBank.fit(Xb, yb, spec).stack.u)
    t_serve_res = time_fn(lambda: resident.mean_var(tenant_list, Xq))
    record("resident-fit", t_fit_res, f"B={B}")
    record("resident-mean_var", t_serve_res, f"B={B};nq={nq}")
    emit("shard/resident-fit", t_fit_res, f"B={B}")
    emit("shard/resident-mean_var", t_serve_res, f"B={B};nq={nq}")
    mu_res, var_res = resident.mean_var(tenant_list, Xq)

    parity = {}
    projected = {}
    overhead = {}
    sweep = SHARD_SWEEP if not smoke else (1, 8)
    for S in sweep:
        mesh = make_bank_mesh(S)
        sharded = ShardedGPBank.from_bank(resident, mesh)
        # fused wall: all S shard programs time-slice this host's core(s);
        # gated as an overhead ratio, not a speedup
        t_fit_sh = time_fn(
            lambda: ShardedGPBank.fit(Xb, yb, spec, mesh).stack.u
        )
        t_serve_sh = time_fn(lambda: sharded.mean_var(tenant_list, Xq))
        # per-device critical path: the SAME executables over the B/S
        # slice each device owns — what runs concurrently on real
        # parallel hardware
        Bs = B // S
        res_s = GPBank.fit(Xb[:Bs], yb[:Bs], spec)
        t_fit_slice = time_fn(
            lambda: GPBank.fit(Xb[:Bs], yb[:Bs], spec).stack.u
        )
        # each shard's dispatch sees ~nq/S of the mixed-tenant rows
        # (bucketed per shard): the slice serves that share from its
        # B/S-tenant bank
        nq_s = max(1, nq // S)
        slice_tenants = [t % Bs for t in tenant_list[:nq_s]]
        Xq_s = Xq[:nq_s]
        t_serve_slice = time_fn(
            lambda: res_s.mean_var(slice_tenants, Xq_s)
        )
        tag = f"B={B};S={S};nq={nq}"
        record(f"sharded-fit-S{S}", t_fit_sh, tag)
        record(f"sharded-mean_var-S{S}", t_serve_sh, tag)
        record(f"slice-fit-S{S}", t_fit_slice, f"B={Bs};S={S}")
        record(f"slice-mean_var-S{S}", t_serve_slice,
               f"B={Bs};S={S};nq={nq}")
        projected[f"fit_S{S}"] = t_fit_res / t_fit_slice
        projected[f"serve_S{S}"] = t_serve_res / t_serve_slice
        overhead[f"fit_S{S}"] = t_fit_sh / t_fit_res
        overhead[f"serve_S{S}"] = t_serve_sh / t_serve_res
        emit(f"shard/sharded-mean_var-S{S}", t_serve_sh,
             f"{tag};projected={projected[f'serve_S{S}']:.1f}x")

        if S == max(sweep):
            # -- parity: sharded vs resident (all queries) and vs a loop
            #    of single-model calls (subset of tenants, full coverage)
            mu_sh, var_sh = sharded.mean_var(tenant_list, Xq)
            parity["sharded_vs_resident"] = {
                "mean_abs": _max_abs(mu_sh, mu_res),
                "var_abs": _max_abs(var_sh, var_res),
            }
            subset = np.arange(PARITY_TENANTS)
            mu_l, var_l = _loop_of_singles(sharded, tenants, Xq_np, subset)
            rows = np.flatnonzero(np.isin(tenants, subset))
            parity["sharded_vs_loop"] = {
                "mean_abs": _max_abs(np.asarray(mu_sh)[rows], mu_l[rows]),
                "var_abs": _max_abs(np.asarray(var_sh)[rows], var_l[rows]),
            }
            for k, rec in parity.items():
                assert rec["mean_abs"] <= 1e-5 and rec["var_abs"] <= 1e-5, \
                    (k, rec)
            # the sharded FIT is a different lowering of the same moments
            # (per-shard accumulation order, data-axis psum tree), so its
            # agreement with the resident fit is f32-summation-order
            # limited — tracked under its own key with a 5e-5 gate, apart
            # from the exact serving parities above
            fitted_sh = ShardedGPBank.fit(Xb, yb, spec, mesh)
            mu_f, var_f = fitted_sh.mean_var(tenant_list, Xq)
            fit_agreement = {
                "mean_abs": _max_abs(mu_f, mu_res),
                "var_abs": _max_abs(var_f, var_res),
            }
            assert fit_agreement["mean_abs"] <= 5e-5, fit_agreement
            assert fit_agreement["var_abs"] <= 5e-5, fit_agreement

            # -- engine-driven traced segment over the largest mesh
            reg = MetricsRegistry()
            tracer = Tracer()
            qps = _engine_segment(
                sharded, nq=min(nq, 512), microbatch=64,
                tracer=tracer, metrics=reg, seed=1,
            )
            record(f"engine-sustained-S{S}", 1.0 / qps,
                   f"B={B};S={S};qps={qps:.0f}")
            if trace_out:
                n = tracer.write_jsonl(trace_out)
                emit("shard/trace-written", 0.0, f"{n} events")

    payload = {
        "schema": 1,
        "smoke": bool(smoke),
        "config": {
            "B": B, "n_rows": N_ROWS, "p": P, "n": N_MERCER, "nq": nq,
            "shard_sweep": list(sweep),
            "host_cores": os.cpu_count(),
            "devices": jax.device_count(),
        },
        "method": (
            "single-core host: 'projected_speedup' is the per-device "
            "critical path T_resident(B)/T_slice(B/S) — the wall time of "
            "the same executable over the B/S-tenant, nq/S-query slice "
            "each device runs concurrently on parallel hardware; "
            "'wall_overhead' is the fused sharded wall / resident wall on "
            "THIS host (S devices time-slicing one core) — gated ≤2.0 at "
            "S=1 (pure shard_map overhead) and ≤4.0 at S=8 (per-shard "
            "pow2 buckets pad the mixed-tenant load up to 2x)"
        ),
        "results": results,
        "parity_abs": parity,
        "fit_agreement_abs": fit_agreement,
        "projected_speedup": projected,
        "wall_overhead": overhead,
    }
    JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    emit("shard/json-written", 0.0, str(JSON_PATH.name))
    return payload


def main():
    argv = sys.argv[1:]
    trace_out = None
    if "--trace-out" in argv:
        trace_out = argv[argv.index("--trace-out") + 1]
    run(full="--full" in argv, smoke="--smoke" in argv,
        trace_out=trace_out)


if __name__ == "__main__":
    main()
