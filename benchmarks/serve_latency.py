"""Pipelined FleetEngine vs the synchronous router loop, with latency SLOs.

The serving claim behind ``repro.bank.FleetEngine``: the synchronous loop
(submit everything, ``BankRouter.flush``) pays the full ``GPBank.mean_var``
wrapper per microbatch and a host/device barrier per block, while the
engine admits, coalesces (arrival-rate-driven power-of-two buckets, up to
``max_coalesce`` microbatches fused per dispatch) and harvests without any
per-block barrier — so the same mixed-tenant workload sustains a >= 1.5x
higher query rate at the acceptance shape B=64 / microbatch=64 on this
container.  Both engines serve the IDENTICAL fitted bank; the pipelined
results are asserted here (<= 1e-5 abs) against direct ``GPBank.mean_var``
calls and the parity is recorded for ``tools/check_bench.py`` to gate.

Also measured and recorded in ``BENCH_serve.json``:

* per-tenant and overall p50/p99 latency from the engine's own
  ``LatencyStats`` (numpy.percentile semantics, pinned by
  tests/test_serve_engine.py),
* sustained QPS for both loops and their ratio
  (``speedup_pipelined_vs_sync`` — check_bench gates it >= 1.5 hard),
* deadline behavior: a burst submitted under an impossible SLO must
  expire with the timeout sentinel (counted in ``timeouts``), and NO
  ticket submitted without a deadline may be dropped
  (``dropped_non_expired`` — gated == 0 hard).

  PYTHONPATH=src python -m benchmarks.serve_latency [--smoke | --full]

Smoke and full runs keep the SAME acceptance shape (B=64, microbatch=64);
full runs more queries, more repeats, and the pallas backend too.
"""
from __future__ import annotations

import json
import sys
import tempfile
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.bank import BankRouter, FleetEngine, GPBank, TieredBank
from repro.data import make_gp_dataset
from repro.obs import MetricsRegistry, Tracer, serving_watchdog

from .common import bench_spec, emit, time_loop

ROOT = Path(__file__).resolve().parents[1]
JSON_PATH = ROOT / "BENCH_serve.json"
OBS_JSON_PATH = ROOT / "BENCH_obs.json"

# the acceptance shape: B=64 tenants, n=8, p=2 (M=64), microbatch=64
B, N_ROWS, P, N_MERCER = 64, 8, 2, 8
MICROBATCH = 64
MAX_IN_FLIGHT = 4
MAX_COALESCE = 4


def _fleet(backend: str, *, seed: int = 0):
    spec = bench_spec("hermite", P, n=N_MERCER, num_features=(N_MERCER**P)//2,
                      backend=backend, seed=seed)
    Xb = np.zeros((B, N_ROWS, P), np.float32)
    yb = np.zeros((B, N_ROWS), np.float32)
    for s in range(B):
        X, y, *_ = make_gp_dataset(N_ROWS, P, seed=seed + s)
        Xb[s], yb[s] = np.asarray(X), np.asarray(y)
    return GPBank.fit(jnp.asarray(Xb), jnp.asarray(yb), spec)


def _workload(nq: int, *, seed: int = 0):
    rng = np.random.default_rng(seed)
    Xq = rng.uniform(-1, 1, size=(nq, P)).astype(np.float32)
    tenants = [int(t) for t in rng.integers(0, B, nq)]
    return tenants, Xq


def _run_sync(bank, tenants, Xq):
    router = BankRouter(bank, microbatch=MICROBATCH)
    tickets = [router.submit(t, x) for t, x in zip(tenants, Xq)]
    return router.flush(), tickets


def _run_pipelined(bank, tenants, Xq):
    router = BankRouter(bank, microbatch=MICROBATCH)
    eng = FleetEngine(router, max_in_flight=MAX_IN_FLIGHT,
                      max_coalesce=MAX_COALESCE)
    tickets = [eng.submit(t, x) for t, x in zip(tenants, Xq)]
    return eng.drain(), tickets, eng


def _deadline_scenario(bank, *, nq: int = 256):
    """A burst submitted under an impossible SLO: every ticket must come
    back as the documented timeout sentinel — and a second, deadline-free
    burst right after must be served completely (expiry never blocks the
    queue)."""
    tenants, Xq = _workload(nq, seed=7)
    router = BankRouter(bank, microbatch=MICROBATCH)
    eng = FleetEngine(router, max_in_flight=MAX_IN_FLIGHT,
                      max_coalesce=MAX_COALESCE, auto_pump=False,
                      default_slo_s=1e-9)
    doomed = [eng.submit(t, x) for t, x in zip(tenants, Xq)]
    time.sleep(0.002)  # let every deadline lapse before dispatch
    out = eng.drain()
    timeouts = sum(out[t].timed_out for t in doomed)
    live = [eng.submit(t, x, deadline_s=60.0)
            for t, x in zip(tenants, Xq)]
    out = eng.drain()
    served_after = sum(out[t].ok for t in live)
    return timeouts, nq, served_after


# churn shape for the zero-recompile gate: 16 tenants paged through 8 hot
# slots, window = the seeded row count so every aged round forgets exactly
# the rows observed that round (2/tenant) — the downdate/refit buckets are
# identical between the warmup rounds and the armed rounds by construction
CHURN_B, CHURN_CAP, CHURN_ROWS, CHURN_MERCER = 16, 8, 40, 6
CHURN_OBS_PER_TENANT = 2
CHURN_AGED = list(range(CHURN_CAP))  # fixed list -> fixed group bucket


def _obs_pass(bank, tenants, Xq, *, metrics=None, tracer=None,
              watchdog=None):
    router = BankRouter(bank, microbatch=MICROBATCH,
                        metrics=metrics, tracer=tracer)
    eng = FleetEngine(router, max_in_flight=MAX_IN_FLIGHT,
                      max_coalesce=MAX_COALESCE, metrics=metrics,
                      tracer=tracer, watchdog=watchdog)
    tickets = [eng.submit(t, x) for t, x in zip(tenants, Xq)]
    return eng.drain(), tickets, eng


def _churn_fleet(cold_dir, *, metrics=None, tracer=None, seed: int = 0):
    spec = bench_spec("hermite", P, n=CHURN_MERCER,
                      num_features=(CHURN_MERCER ** P) // 2,
                      backend="jnp", seed=seed, noise=0.1)
    Xb = np.zeros((CHURN_B, CHURN_ROWS, P), np.float32)
    yb = np.zeros((CHURN_B, CHURN_ROWS), np.float32)
    for s in range(CHURN_B):
        X, y, *_ = make_gp_dataset(CHURN_ROWS, P, seed=seed + s)
        Xb[s], yb[s] = np.asarray(X), np.asarray(y)
    return TieredBank.fit(
        jnp.asarray(Xb), jnp.asarray(yb), spec, cold_dir=cold_dir,
        capacity=CHURN_CAP, window=CHURN_ROWS,
        metrics=metrics, tracer=tracer,
    )


def _churn_round(eng, tb, rng, *, queries: int = 64):
    """One full lifecycle round: mixed-tenant queries (page-ins included —
    the fleet is 2x the hot capacity), per-tenant observation ingest, and
    a sliding-window age of a fixed tenant subset."""
    tks = [
        eng.submit(int(rng.integers(0, CHURN_B)),
                   rng.uniform(-1, 1, P).astype(np.float32))
        for _ in range(queries)
    ]
    out = eng.drain()
    assert all(out[t].ok for t in tks)
    for t in range(CHURN_B):
        for _ in range(CHURN_OBS_PER_TENANT):
            eng.observe(t, rng.uniform(-1, 1, P).astype(np.float32),
                        float(rng.normal()))
    eng.ingest()
    tb.adopt(eng.router.bank)
    aged = tb.age(CHURN_AGED)
    eng.router.bank = tb.bank
    return aged


def run_obs(full: bool = False, smoke: bool = False,
            trace_out: str | None = None):
    """The telemetry lanes behind ``BENCH_obs.json``:

    * **overhead** — the acceptance-shape pipelined workload (B=64,
      microbatch=64) timed twice: once wired to the shared null
      registry/tracer (the default every serving entrypoint gets) and
      once fully instrumented (live :class:`MetricsRegistry`, a
      recording :class:`Tracer`, and an ARMED recompile watchdog
      checking every pump).  ``overhead_ratio`` = instrumented / null
      wall time — ``tools/check_bench.py`` gates it <= 1.05 HARD.
    * **churn watchdog** — a tiered fleet (16 tenants through 8 hot
      slots, sliding window) runs full submit/observe/page/age rounds
      with every serving executable registered; after two identical
      warmup rounds the watchdog arms, and the armed rounds must mint
      exactly ZERO new executables (``recompiles`` — gated == 0 HARD).

    ``trace_out`` additionally dumps every recorded span (pipeline
    stages + lifecycle) as Chrome-trace JSONL.
    """
    nq = 4096
    repeats = 12 if smoke else 20

    results = []

    def record(name, seconds, derived=""):
        results.append({"name": name, "seconds": seconds, "derived": derived})

    # -- overhead lane: instrumented vs null, identical workload ------------
    bank = _fleet("jnp")
    tenants, Xq = _workload(nq)
    tag = f"B={B};mb={MICROBATCH};nq={nq}"
    # warm EVERY rung of the coalesce ladder before anything is timed or
    # armed: an armed repeat must never be the first to visit a rung.
    # One fresh engine per rung — a cold arrival EWMA makes the pending
    # count alone pick the bucket, so each rung is actually dispatched
    # (a long-lived warmer's arrival-rate heuristic skips rungs)
    probe = FleetEngine(BankRouter(bank, microbatch=MICROBATCH),
                        max_in_flight=MAX_IN_FLIGHT,
                        max_coalesce=MAX_COALESCE, auto_pump=False)
    for rung in probe.buckets:
        e2 = FleetEngine(
            BankRouter(bank, microbatch=MICROBATCH),
            max_in_flight=MAX_IN_FLIGHT, max_coalesce=MAX_COALESCE,
            auto_pump=False,
        )
        for _ in range(rung):
            e2.submit(0, np.zeros(P, np.float32))
        e2.pump(max_blocks=1)
        e2.drain()
    _obs_pass(bank, tenants, Xq)                      # warm the full path

    reg = MetricsRegistry()
    tracer = Tracer()
    wd = serving_watchdog(mode="count", metrics=reg)
    _obs_pass(bank, tenants, Xq, metrics=reg, tracer=tracer, watchdog=wd)
    wd.arm()
    # INTERLEAVED ABBA pairs, median-of-ratios: each null/instrumented
    # pair runs back to back (same machine-noise environment) and the
    # pair order alternates every repeat, so linear load drift within
    # the run cancels instead of always taxing whichever lane runs
    # second; the MEDIAN across pair ratios then shrugs off scheduler
    # spikes that make a min-of-two-separate-blocks estimate flap
    # around a ~1% true overhead
    # the registry holds engine collectors via weakrefs, so the last
    # instrumented engine must stay alive until after the snapshot below
    # or its unflushed counter deltas die with it
    keep: dict = {}

    def _null():
        t0 = time.perf_counter()
        _obs_pass(bank, tenants, Xq)
        return time.perf_counter() - t0

    def _inst():
        t0 = time.perf_counter()
        out = _obs_pass(bank, tenants, Xq, metrics=reg, tracer=tracer,
                        watchdog=wd)
        dt = time.perf_counter() - t0
        keep["eng"] = out[2]
        return dt

    ratios, nulls, insts = [], [], []
    for i in range(repeats):
        if i & 1:
            dt_inst = _inst()
            dt_null = _null()
        else:
            dt_null = _null()
            dt_inst = _inst()
        nulls.append(dt_null)
        insts.append(dt_inst)
        ratios.append(dt_inst / dt_null)
    t_null, t_inst = min(nulls), min(insts)
    # two independent upward-robust estimates of the same true ratio —
    # the median of per-pair ratios and the ratio of per-lane floors —
    # agree when the machine is quiet and diverge under load bursts;
    # report the smaller (a burst can only inflate either one)
    overhead = float(min(np.median(ratios), t_inst / t_null))
    emit("serve/obs-null", t_null, tag)
    emit("serve/obs-instrumented", t_inst,
         f"{tag};overhead={overhead:.3f}x")
    record("obs-null", t_null, tag)
    record("obs-instrumented", t_inst, f"{tag};overhead={overhead:.3f}x")
    snap = reg.snapshot()
    assert snap["counters"].get("serve_admitted_total", 0) > 0
    del keep["eng"]
    overhead_recompiles = wd.recompiles               # armed lane: 0

    # -- churn lane: zero new executables across page/age lifecycle ---------
    rng = np.random.default_rng(5)
    with tempfile.TemporaryDirectory(prefix="bench_obs_") as tmp:
        tb = _churn_fleet(tmp, metrics=reg, tracer=tracer)
        router = BankRouter(tb.bank, microbatch=8,
                            metrics=reg, tracer=tracer)
        cwd = serving_watchdog(mode="count", metrics=reg)
        # auto_pump=False: bucket choice follows pending depth alone, so
        # the armed rounds replay exactly the warmup rounds' shapes
        eng = FleetEngine(router, max_in_flight=2, tiered=tb,
                          auto_pump=False,
                          metrics=reg, tracer=tracer, watchdog=cwd)
        # warm every rung of the coalesce ladder once THROUGH the engine
        # dispatch path (a fresh throwaway engine per rung: its arrival
        # EWMA starts cold, so pending count alone picks the bucket —
        # the long-lived engine's arrival-rate heuristic would skip
        # rungs), then two full churn rounds (the second reaches the
        # steady-state downdate shapes the armed rounds repeat); the
        # refit-fallback lane is warmed explicitly — it only fires on
        # lost positive definiteness, which the armed rounds must not
        # have to pay for
        hot0 = tb.hot_tenants[0]
        for rung in eng.buckets:
            e2 = FleetEngine(BankRouter(tb.bank, microbatch=8),
                             max_in_flight=2, auto_pump=False)
            for _ in range(rung):
                e2.submit(hot0, np.zeros(P, np.float32))
            e2.pump(max_blocks=1)
            e2.drain()
        for _ in range(2):
            _churn_round(eng, tb, rng)
        fb = 1 if CHURN_CAP <= 1 else CHURN_CAP
        slots = np.arange(fb, dtype=np.int32)
        tb._bank._refit_at_slots(
            jnp.asarray(slots),
            jnp.zeros((fb, CHURN_ROWS, P), jnp.float32),
            jnp.zeros((fb, CHURN_ROWS), jnp.float32),
            jnp.zeros((fb, CHURN_ROWS), jnp.float32),
        )
        cwd.arm()
        cwd.recompiles, cwd.events = 0, []
        t0 = time.perf_counter()
        rounds = 2 if smoke else 4
        forgot = 0
        for _ in range(rounds):
            forgot += _churn_round(eng, tb, rng)["forgotten_rows"]
        cwd.check("churn")
        t_churn = time.perf_counter() - t0
        recompiles = cwd.recompiles
    assert forgot == rounds * CHURN_CAP * CHURN_OBS_PER_TENANT, forgot
    emit("serve/obs-churn-watchdog", t_churn,
         f"rounds={rounds};recompiles={recompiles};forgot={forgot}")
    record("obs-churn-watchdog", t_churn,
           f"rounds={rounds};recompiles={recompiles}")

    if trace_out:
        n = tracer.write_jsonl(trace_out)
        emit("serve/obs-trace-written", 0.0, f"events={n};path={trace_out}")

    payload = {
        "schema": 1,
        "smoke": bool(smoke),
        "config": {"B": B, "microbatch": MICROBATCH, "queries": nq,
                   "repeats": repeats, "churn_B": CHURN_B,
                   "churn_capacity": CHURN_CAP, "churn_rounds": rounds},
        "results": results,
        "overhead_ratio": overhead,
        "recompiles": recompiles + overhead_recompiles,
        "trace_events": len(tracer),
        "metric_series": {
            "counters": len(snap["counters"]),
            "gauges": len(snap["gauges"]),
            "histograms": len(snap["histograms"]),
        },
    }
    OBS_JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    emit("serve/obs-json-written", 0.0,
         f"overhead={overhead:.3f}x;recompiles={payload['recompiles']}")
    return payload


def run(full: bool = False, smoke: bool = False,
        trace_out: str | None = None):
    nq = 2048 if smoke else (8192 if full else 4096)
    repeats = 3 if smoke else 5
    backends = ["jnp", "pallas"] if full else ["jnp"]

    results = []

    def record(name, seconds, derived=""):
        results.append({"name": name, "seconds": seconds, "derived": derived})

    parity = {}
    qps = {}
    latency = {}
    timeouts_total = 0
    dropped_non_expired = 0

    for backend in backends:
        bank = _fleet(backend)
        tenants, Xq = _workload(nq)
        tag = f"B={B};mb={MICROBATCH};nq={nq}"

        # parity + drop accounting on a verification pass (un-timed)
        out_p, tks_p, eng0 = _run_pipelined(bank, tenants, Xq)
        mu_d, var_d = bank.mean_var(tenants, jnp.asarray(Xq))
        mu_p = np.array([out_p[t].mu for t in tks_p], np.float32)
        var_p = np.array([out_p[t].var for t in tks_p], np.float32)
        pkey = (f"pipelined_vs_direct/{backend}" if backend != "jnp"
                else "pipelined_vs_direct")
        parity[pkey] = {
            "mean_abs": float(np.max(np.abs(np.asarray(mu_d) - mu_p))),
            "var_abs": float(np.max(np.abs(np.asarray(var_d) - var_p))),
        }
        assert parity[pkey]["mean_abs"] <= 1e-5 \
            and parity[pkey]["var_abs"] <= 1e-5, parity[pkey]
        # no deadline was set, so every ticket must be served
        dropped_non_expired += sum(
            1 for t in tks_p if t not in out_p or not out_p[t].ok
        )

        t_sync = time_loop(lambda: _run_sync(bank, tenants, Xq),
                           repeats=repeats)
        t_pipe = time_loop(lambda: _run_pipelined(bank, tenants, Xq),
                           repeats=repeats)
        qps[f"sync/{backend}"] = nq / t_sync
        qps[f"pipelined/{backend}"] = nq / t_pipe
        emit(f"serve/{backend}-sync-loop", t_sync, tag)
        emit(f"serve/{backend}-pipelined", t_pipe,
             f"{tag};speedup={t_sync / t_pipe:.2f}x")
        record(f"{backend}-sync-loop", t_sync, tag)
        record(f"{backend}-pipelined", t_pipe, tag)

        if backend == "jnp":
            # latency observability from a fresh, metered engine pass
            _, _, eng = _run_pipelined(bank, tenants, Xq)
            m = eng.metrics()
            per_t = {
                str(t): {"p50_s": v["p50_s"], "p99_s": v["p99_s"],
                         "count": v["count"]}
                for t, v in m["tenants"].items()
            }
            latency = {
                "p50_s": m["overall"]["p50_s"],
                "p99_s": m["overall"]["p99_s"],
                "sustained_qps": m["overall"]["sustained_qps"],
                "bucket_uses": {str(k): v
                                for k, v in m["bucket_uses"].items()},
                "tenants": per_t,
            }
            record("pipelined-p50", m["overall"]["p50_s"], tag)
            record("pipelined-p99", m["overall"]["p99_s"], tag)

            n_timed_out, n_doomed, served_after = _deadline_scenario(bank)
            assert n_timed_out == n_doomed, (n_timed_out, n_doomed)
            assert served_after == n_doomed, served_after
            timeouts_total += n_timed_out
            emit(f"serve/{backend}-deadline-expiry", 0.0,
                 f"expired={n_timed_out}/{n_doomed};served_after="
                 f"{served_after}")

    speedup = qps["pipelined/jnp"] / qps["sync/jnp"]
    emit("serve/json-written", 0.0,
         f"speedup={speedup:.2f}x;dropped={dropped_non_expired}")

    payload = {
        "schema": 1,
        "smoke": bool(smoke),
        "config": {"B": B, "n_rows": N_ROWS, "p": P, "n": N_MERCER,
                   "microbatch": MICROBATCH, "queries": nq,
                   "max_in_flight": MAX_IN_FLIGHT,
                   "max_coalesce": MAX_COALESCE, "repeats": repeats},
        "results": results,
        "parity_abs": parity,
        "qps": qps,
        "speedup_pipelined_vs_sync": speedup,
        "latency": latency,
        "timeouts": timeouts_total,
        "dropped_non_expired": dropped_non_expired,
    }
    JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    run_obs(full=full, smoke=smoke, trace_out=trace_out)
    return payload


def main():
    trace_out = None
    if "--trace-out" in sys.argv:
        i = sys.argv.index("--trace-out") + 1
        if i >= len(sys.argv):
            raise SystemExit("usage: --trace-out FILE")
        trace_out = sys.argv[i]
    full, smoke = "--full" in sys.argv, "--smoke" in sys.argv
    if "--obs-only" in sys.argv:
        run_obs(full=full, smoke=smoke, trace_out=trace_out)
        return
    run(full=full, smoke=smoke, trace_out=trace_out)


if __name__ == "__main__":
    main()
