"""Pipelined FleetEngine vs the synchronous router loop, with latency SLOs.

The serving claim behind ``repro.bank.FleetEngine``: the synchronous loop
(submit everything, ``BankRouter.flush``) pays the full ``GPBank.mean_var``
wrapper per microbatch and a host/device barrier per block, while the
engine admits, coalesces (arrival-rate-driven power-of-two buckets, up to
``max_coalesce`` microbatches fused per dispatch) and harvests without any
per-block barrier — so the same mixed-tenant workload sustains a >= 1.5x
higher query rate at the acceptance shape B=64 / microbatch=64 on this
container.  Both engines serve the IDENTICAL fitted bank; the pipelined
results are asserted here (<= 1e-5 abs) against direct ``GPBank.mean_var``
calls and the parity is recorded for ``tools/check_bench.py`` to gate.

Also measured and recorded in ``BENCH_serve.json``:

* per-tenant and overall p50/p99 latency from the engine's own
  ``LatencyStats`` (numpy.percentile semantics, pinned by
  tests/test_serve_engine.py),
* sustained QPS for both loops and their ratio
  (``speedup_pipelined_vs_sync`` — check_bench gates it >= 1.5 hard),
* deadline behavior: a burst submitted under an impossible SLO must
  expire with the timeout sentinel (counted in ``timeouts``), and NO
  ticket submitted without a deadline may be dropped
  (``dropped_non_expired`` — gated == 0 hard).

  PYTHONPATH=src python -m benchmarks.serve_latency [--smoke | --full]

Smoke and full runs keep the SAME acceptance shape (B=64, microbatch=64);
full runs more queries, more repeats, and the pallas backend too.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.bank import BankRouter, FleetEngine, GPBank
from repro.data import make_gp_dataset

from .common import bench_spec, emit, time_loop

ROOT = Path(__file__).resolve().parents[1]
JSON_PATH = ROOT / "BENCH_serve.json"

# the acceptance shape: B=64 tenants, n=8, p=2 (M=64), microbatch=64
B, N_ROWS, P, N_MERCER = 64, 8, 2, 8
MICROBATCH = 64
MAX_IN_FLIGHT = 4
MAX_COALESCE = 4


def _fleet(backend: str, *, seed: int = 0):
    spec = bench_spec("hermite", P, n=N_MERCER, num_features=(N_MERCER**P)//2,
                      backend=backend, seed=seed)
    Xb = np.zeros((B, N_ROWS, P), np.float32)
    yb = np.zeros((B, N_ROWS), np.float32)
    for s in range(B):
        X, y, *_ = make_gp_dataset(N_ROWS, P, seed=seed + s)
        Xb[s], yb[s] = np.asarray(X), np.asarray(y)
    return GPBank.fit(jnp.asarray(Xb), jnp.asarray(yb), spec)


def _workload(nq: int, *, seed: int = 0):
    rng = np.random.default_rng(seed)
    Xq = rng.uniform(-1, 1, size=(nq, P)).astype(np.float32)
    tenants = [int(t) for t in rng.integers(0, B, nq)]
    return tenants, Xq


def _run_sync(bank, tenants, Xq):
    router = BankRouter(bank, microbatch=MICROBATCH)
    tickets = [router.submit(t, x) for t, x in zip(tenants, Xq)]
    return router.flush(), tickets


def _run_pipelined(bank, tenants, Xq):
    router = BankRouter(bank, microbatch=MICROBATCH)
    eng = FleetEngine(router, max_in_flight=MAX_IN_FLIGHT,
                      max_coalesce=MAX_COALESCE)
    tickets = [eng.submit(t, x) for t, x in zip(tenants, Xq)]
    return eng.drain(), tickets, eng


def _deadline_scenario(bank, *, nq: int = 256):
    """A burst submitted under an impossible SLO: every ticket must come
    back as the documented timeout sentinel — and a second, deadline-free
    burst right after must be served completely (expiry never blocks the
    queue)."""
    tenants, Xq = _workload(nq, seed=7)
    router = BankRouter(bank, microbatch=MICROBATCH)
    eng = FleetEngine(router, max_in_flight=MAX_IN_FLIGHT,
                      max_coalesce=MAX_COALESCE, auto_pump=False,
                      default_slo_s=1e-9)
    doomed = [eng.submit(t, x) for t, x in zip(tenants, Xq)]
    time.sleep(0.002)  # let every deadline lapse before dispatch
    out = eng.drain()
    timeouts = sum(out[t].timed_out for t in doomed)
    live = [eng.submit(t, x, deadline_s=60.0)
            for t, x in zip(tenants, Xq)]
    out = eng.drain()
    served_after = sum(out[t].ok for t in live)
    return timeouts, nq, served_after


def run(full: bool = False, smoke: bool = False):
    nq = 2048 if smoke else (8192 if full else 4096)
    repeats = 3 if smoke else 5
    backends = ["jnp", "pallas"] if full else ["jnp"]

    results = []

    def record(name, seconds, derived=""):
        results.append({"name": name, "seconds": seconds, "derived": derived})

    parity = {}
    qps = {}
    latency = {}
    timeouts_total = 0
    dropped_non_expired = 0

    for backend in backends:
        bank = _fleet(backend)
        tenants, Xq = _workload(nq)
        tag = f"B={B};mb={MICROBATCH};nq={nq}"

        # parity + drop accounting on a verification pass (un-timed)
        out_p, tks_p, eng0 = _run_pipelined(bank, tenants, Xq)
        mu_d, var_d = bank.mean_var(tenants, jnp.asarray(Xq))
        mu_p = np.array([out_p[t].mu for t in tks_p], np.float32)
        var_p = np.array([out_p[t].var for t in tks_p], np.float32)
        pkey = (f"pipelined_vs_direct/{backend}" if backend != "jnp"
                else "pipelined_vs_direct")
        parity[pkey] = {
            "mean_abs": float(np.max(np.abs(np.asarray(mu_d) - mu_p))),
            "var_abs": float(np.max(np.abs(np.asarray(var_d) - var_p))),
        }
        assert parity[pkey]["mean_abs"] <= 1e-5 \
            and parity[pkey]["var_abs"] <= 1e-5, parity[pkey]
        # no deadline was set, so every ticket must be served
        dropped_non_expired += sum(
            1 for t in tks_p if t not in out_p or not out_p[t].ok
        )

        t_sync = time_loop(lambda: _run_sync(bank, tenants, Xq),
                           repeats=repeats)
        t_pipe = time_loop(lambda: _run_pipelined(bank, tenants, Xq),
                           repeats=repeats)
        qps[f"sync/{backend}"] = nq / t_sync
        qps[f"pipelined/{backend}"] = nq / t_pipe
        emit(f"serve/{backend}-sync-loop", t_sync, tag)
        emit(f"serve/{backend}-pipelined", t_pipe,
             f"{tag};speedup={t_sync / t_pipe:.2f}x")
        record(f"{backend}-sync-loop", t_sync, tag)
        record(f"{backend}-pipelined", t_pipe, tag)

        if backend == "jnp":
            # latency observability from a fresh, metered engine pass
            _, _, eng = _run_pipelined(bank, tenants, Xq)
            m = eng.metrics()
            per_t = {
                str(t): {"p50_s": v["p50_s"], "p99_s": v["p99_s"],
                         "count": v["count"]}
                for t, v in m["tenants"].items()
            }
            latency = {
                "p50_s": m["overall"]["p50_s"],
                "p99_s": m["overall"]["p99_s"],
                "sustained_qps": m["overall"]["sustained_qps"],
                "bucket_uses": {str(k): v
                                for k, v in m["bucket_uses"].items()},
                "tenants": per_t,
            }
            record("pipelined-p50", m["overall"]["p50_s"], tag)
            record("pipelined-p99", m["overall"]["p99_s"], tag)

            n_timed_out, n_doomed, served_after = _deadline_scenario(bank)
            assert n_timed_out == n_doomed, (n_timed_out, n_doomed)
            assert served_after == n_doomed, served_after
            timeouts_total += n_timed_out
            emit(f"serve/{backend}-deadline-expiry", 0.0,
                 f"expired={n_timed_out}/{n_doomed};served_after="
                 f"{served_after}")

    speedup = qps["pipelined/jnp"] / qps["sync/jnp"]
    emit("serve/json-written", 0.0,
         f"speedup={speedup:.2f}x;dropped={dropped_non_expired}")

    payload = {
        "schema": 1,
        "smoke": bool(smoke),
        "config": {"B": B, "n_rows": N_ROWS, "p": P, "n": N_MERCER,
                   "microbatch": MICROBATCH, "queries": nq,
                   "max_in_flight": MAX_IN_FLIGHT,
                   "max_coalesce": MAX_COALESCE, "repeats": repeats},
        "results": results,
        "parity_abs": parity,
        "qps": qps,
        "speedup_pipelined_vs_sync": speedup,
        "latency": latency,
        "timeouts": timeouts_total,
        "dropped_non_expired": dropped_non_expired,
    }
    JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def main():
    run(full="--full" in sys.argv, smoke="--smoke" in sys.argv)


if __name__ == "__main__":
    main()
