"""Beyond-paper ablation: multi-index truncation vs the paper's full grid.

The paper's limitation is M = n^p.  Total-degree and hyperbolic-cross index
sets exploit the product eigenvalue decay to keep accuracy at far smaller M —
this table shows M, fit+predict time, and test RMSE for each set at p = 4.
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core.gp import GP, GPSpec
from repro.data import make_gp_dataset

from .common import emit, time_fn


def run(full: bool = False):
    N = 10_000 if full else 3_000
    p, n = 4, 7
    X, y, Xs, ys = make_gp_dataset(N, p, seed=2)
    base = GPSpec.create(n, eps=[0.7] * p, rho=2.0, noise=0.05)
    settings = [
        ("full", None),
        ("total_degree", n - 1),
        ("total_degree", 4),
        ("hyperbolic_cross", 2 * n),
        ("hyperbolic_cross", n),
    ]
    for kind, degree in settings:
        spec = base.replace(index_set=kind, degree=degree)
        M = spec.indices(p).shape[0]
        if M > 6_000 and not full:
            emit(f"index_set/{kind}-{degree}/SKIPPED", 0.0, f"M={M}")
            continue

        def work():
            gp = GP.fit(X, y, spec)
            mu, _ = gp.mean_var(Xs)
            return mu

        t = time_fn(work, iters=2)
        mu = work()
        rmse = float(np.sqrt(np.mean((np.asarray(mu) - np.asarray(ys)) ** 2)))
        emit(f"index_set/{kind}-{degree}", t, f"M={M};rmse={rmse:.4f}")


if __name__ == "__main__":
    run(full="--full" in sys.argv)
