"""Benchmark harness: one module per paper table/figure + extras.

Prints ``name,us_per_call,derived`` CSV lines.  Modules with a
machine-readable trajectory additionally write ``BENCH_<name>.json`` at
the repo root (today: ``BENCH_gp_bank.json`` from benchmarks/gp_bank.py;
CI validates its shape every run).

  PYTHONPATH=src python -m benchmarks.run [--full]
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    full = "--full" in sys.argv
    from . import (
        fagp_vs_exact,
        fig1_time_vs_n_p,
        gp_bank,
        gp_hyperopt,
        index_set_ablation,
        kernel_micro,
        multi_output,
        roofline_table,
        serve_latency,
        streaming_fit,
        tenant_churn,
        vecchia,
    )

    modules = [
        ("fig1_time_vs_n_p", fig1_time_vs_n_p),      # paper Fig. 1
        ("fagp_vs_exact", fagp_vs_exact),            # Joukov-Kulic baseline claim
        ("index_set_ablation", index_set_ablation),  # beyond-paper truncations
        ("kernel_micro", kernel_micro),              # Pallas kernels
        ("streaming_fit", streaming_fit),            # fused 1-pass fit; fit_update
        ("multi_output", multi_output),              # shared-Cholesky T-task fit
        ("gp_bank", gp_bank),                        # fleet bank vs loop of singles
        ("gp_hyperopt", gp_hyperopt),                # fleet hyperopt vs loop
        ("serve_latency", serve_latency),            # pipelined engine vs sync
        ("tenant_churn", tenant_churn),              # tiered paging + forgetting
        ("vecchia", vecchia),                        # NN conditioning vs globals
        ("roofline_table", roofline_table),          # dry-run summary
    ]
    failed = 0
    for name, mod in modules:
        print(f"# --- {name} ---")
        try:
            mod.run(full=full)
        except Exception:
            failed += 1
            print(f"{name}/ERROR,0,")
            traceback.print_exc()
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
