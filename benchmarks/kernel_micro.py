"""Pallas kernel microbenchmarks vs jnp references, per kernel expansion.

On this CPU container the kernels run in interpret mode, so wall times
measure the *correctness* path, not TPU performance — the numbers that
matter for TPU are the roofline terms in EXPERIMENTS.md.  Reported here so
regressions in kernel shape handling show up in CI.

The ``--expansion`` axis sweeps the registered kernel families through the
generic feature kernel (``ops.expansion_phi``) and the streaming fused-fit
kernel (``ops.fused_fit_moments`` with the expansion's tile builder);
per-expansion rows land in ``BENCH_expansions.json`` (schema validated by
CI) so the bench trajectory records kernel-family numbers.

  PYTHONPATH=src python -m benchmarks.kernel_micro [--full]
      [--expansion hermite|rff_se|rff_matern52|all]
"""
from __future__ import annotations

import sys

import numpy as np
import jax.numpy as jnp

from repro.core import expansions
from repro.kernels import ops, ref

from .common import (
    bench_spec, cli_expansion, emit, expansion_names,
    record_expansion_result, time_fn,
)


def _run_expansion(expansion: str, full: bool):
    N, p, n_max = (4096, 3, 8) if full else (1024, 2, 6)
    num_features = (n_max**p) // 2  # match the hermite M for fair rows
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.uniform(-1, 1, (N, p)).astype(np.float32))
    spec = bench_spec(expansion, p, n=n_max, num_features=num_features)
    exp = expansions.get_expansion(expansion)
    idx = jnp.asarray(spec.indices(p))
    M = idx.shape[0]
    aux = exp.pallas_prepare(np.asarray(idx), spec)
    consts = exp.tile_consts(spec)
    table = exp.tile_table(aux, spec)
    tile = exp.tile_fn()
    tag = f"N={N};M={M}"

    def rec(name, seconds):
        emit(f"kernel/{name}/{expansion}", seconds, tag)
        record_expansion_result("kernel_micro", expansion, name, seconds, tag)

    t = time_fn(lambda: ops.expansion_phi(X, consts, table, n_max=spec.n,
                                          tile_fn=tile))
    rec("phi/pallas-interp", t)
    t = time_fn(lambda: exp.features(X, idx, spec))
    rec("phi/jnp-ref", t)

    Phi = ops.expansion_phi(X, consts, table, n_max=spec.n, tile_fn=tile)
    d = jnp.exp(0.5 * exp.log_eigenvalues(idx, spec))
    sig2 = jnp.float32(0.01)
    t = time_fn(lambda: ops.fused_fit_moments(
        X, X[:, 0], consts, table, d, sig2, n_max=spec.n, tile_fn=tile))
    rec("fused-fit/pallas-interp", t)
    t = time_fn(lambda: ops.scaled_gram(Phi, d, sig2))
    rec("gram/pallas-interp", t)
    t = time_fn(lambda: ref.ref_scaled_gram(Phi, d, sig2))
    rec("gram/jnp-ref", t)

    C = jnp.eye(M, dtype=jnp.float32)
    t = time_fn(lambda: ops.diag_quad(Phi, C))
    rec("diag_quad/pallas-interp", t)
    t = time_fn(lambda: ref.ref_diag_quad(Phi, C))
    rec("diag_quad/jnp-ref", t)


def run(full: bool = False, expansion: str = "hermite"):
    names = expansion_names() if expansion == "all" else [expansion]
    for name in names:
        _run_expansion(name, full)


if __name__ == "__main__":
    run(full="--full" in sys.argv, expansion=cli_expansion(sys.argv))
