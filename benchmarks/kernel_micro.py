"""Pallas kernel microbenchmarks vs jnp references.

On this CPU container the kernels run in interpret mode, so wall times
measure the *correctness* path, not TPU performance — the numbers that
matter for TPU are the roofline terms in EXPERIMENTS.md.  Reported here so
regressions in kernel shape handling show up in CI.
"""
from __future__ import annotations

import sys

import numpy as np
import jax.numpy as jnp

from repro.core import mercer
from repro.kernels import ops, ref

from .common import emit, time_fn


def run(full: bool = False):
    N, p, n_max = (4096, 3, 8) if full else (1024, 2, 6)
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.uniform(-1, 1, (N, p)).astype(np.float32))
    eps = jnp.full((p,), 0.8, jnp.float32)
    rho = jnp.full((p,), 2.0, jnp.float32)
    idx = mercer.full_grid(n_max, p)
    M = idx.shape[0]
    consts = ref.phi_consts(eps, rho)
    S = jnp.asarray(ref.one_hot_selection(idx, n_max))

    t = time_fn(lambda: ops.hermite_phi(X, consts, S, n_max=n_max))
    emit("kernel/hermite_phi/pallas-interp", t, f"N={N};M={M}")
    t = time_fn(lambda: ref.ref_phi(X.T, consts, S, n_max))
    emit("kernel/hermite_phi/jnp-ref", t, f"N={N};M={M}")

    Phi = ops.hermite_phi(X, consts, S, n_max=n_max)
    d = jnp.asarray(np.geomspace(1, 1e-5, M).astype(np.float32))
    sig2 = jnp.float32(0.01)
    t = time_fn(lambda: ops.scaled_gram(Phi, d, sig2))
    emit("kernel/gram/pallas-interp", t, f"N={N};M={M}")
    t = time_fn(lambda: ref.ref_scaled_gram(Phi, d, sig2))
    emit("kernel/gram/jnp-ref", t, f"N={N};M={M}")

    C = jnp.eye(M, dtype=jnp.float32)
    t = time_fn(lambda: ops.diag_quad(Phi, C))
    emit("kernel/diag_quad/pallas-interp", t, f"N={N};M={M}")
    t = time_fn(lambda: ref.ref_diag_quad(Phi, C))
    emit("kernel/diag_quad/jnp-ref", t, f"N={N};M={M}")


if __name__ == "__main__":
    run(full="--full" in sys.argv)
