#!/usr/bin/env python3
"""CI perf-regression gate over the BENCH trajectory.

Validates every ``BENCH_*.json`` the benchmarks write (schema + row
structure), asserts the recorded PARITY metrics against the tolerance
committed in ``BENCH_baselines.json`` (hard failures — parity is a
correctness claim), and compares recorded timings against the committed
baseline values (soft warnings by default — shared CI runners have noisy
clocks; ``--strict-timing`` hardens them for dedicated hardware).

Replaces the per-benchmark inline heredoc validators that used to live in
``.github/workflows/ci.yml``: one gate, one committed baseline file, one
place to add the next benchmark's schema.

  python tools/check_bench.py                    # every BENCH_*.json present
  python tools/check_bench.py BENCH_gp_bank.json # specific files
  python tools/check_bench.py --require BENCH_gp_bank.json ...
                                                 # missing file = failure
Exit code 1 on any hard failure (missing required file, malformed schema,
parity above tolerance); timing regressions print WARN lines.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
BASELINES = ROOT / "BENCH_baselines.json"

_ROW_FIELDS = {
    "BENCH_gp_bank.json": {"name", "seconds", "derived"},
    "BENCH_optimize.json": {"name", "seconds", "derived"},
    "BENCH_serve.json": {"name", "seconds", "derived"},
    "BENCH_obs.json": {"name", "seconds", "derived"},
    "BENCH_lifecycle.json": {"name", "seconds", "derived"},
    "BENCH_shard.json": {"name", "seconds", "derived"},
    "BENCH_vecchia.json": {"name", "seconds", "derived"},
    "BENCH_expansions.json": {"bench", "expansion", "name", "seconds",
                              "derived"},
}
_GENERIC_ROW_FIELDS = {"name", "seconds"}


def _field_at(payload, dotted: str):
    """Resolve a possibly-nested payload field by dotted path
    (``"qps.pipelined/jnp"`` -> payload["qps"]["pipelined/jnp"])."""
    cur = payload
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def _flat_parity(d, prefix=""):
    """parity_abs entries are floats (gp_bank) or nested dicts of floats
    (optimize: per-metric); flatten to {dotted-key: float}."""
    out = {}
    for k, v in d.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(_flat_parity(v, key + "."))
        else:
            out[key] = float(v)
    return out


def _check_structure(name: str, payload, errors: list) -> None:
    if payload.get("schema") != 1:
        errors.append(f"{name}: schema != 1 (got {payload.get('schema')!r})")
        return
    rows = payload.get("results")
    if not isinstance(rows, list) or not rows:
        errors.append(f"{name}: no results rows")
        return
    want = _ROW_FIELDS.get(name, _GENERIC_ROW_FIELDS)
    for r in rows:
        if not isinstance(r, dict) or not want <= set(r):
            errors.append(f"{name}: malformed row {r!r} (need {sorted(want)})")
            return
        if not isinstance(r["seconds"], (int, float)):
            errors.append(f"{name}: non-numeric seconds in {r!r}")
            return


def check_file(path: Path, rules: dict, cfg: dict, errors: list,
               warnings: list) -> None:
    name = path.name
    try:
        payload = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        errors.append(f"{name}: unreadable ({e})")
        return
    _check_structure(name, payload, errors)
    if any(e.startswith(name) for e in errors):
        return

    # -- parity: a correctness claim, gated hard ----------------------------
    # EVERY recorded parity value is gated (a benchmark re-run with another
    # --expansion axis rewrites the key set, so the gate follows the file);
    # ``parity_keys`` additionally names records that must exist, and
    # ``parity_nonempty`` requires at least one.
    parity_max = float(cfg.get("parity_max_abs", 1e-5))
    flat = _flat_parity(payload.get("parity_abs", {}))
    for k, v in flat.items():
        if not (v <= parity_max):       # catches NaN too
            errors.append(
                f"{name}: parity {k} = {v:g} exceeds {parity_max:g}"
            )
    if rules.get("parity_nonempty") and not flat:
        errors.append(f"{name}: no parity records at all")
    for key in rules.get("parity_keys", []):
        if not any(k.split(".")[0] == key for k in flat):
            errors.append(f"{name}: missing parity record {key!r}")

    # -- gated scalar fields: recorded claims, not timings ------------------
    # ``min_fields``/``max_fields`` hard-gate dotted payload fields against
    # committed thresholds (e.g. the serving speedup claim, or "no
    # non-expired ticket was ever dropped") — these are semantic claims
    # like parity, NOT machine-speed numbers, so they fail hard.
    for dotted, lo in rules.get("min_fields", {}).items():
        v = _field_at(payload, dotted)
        if not isinstance(v, (int, float)) or not (v >= float(lo)):
            errors.append(
                f"{name}: field {dotted} = {v!r} below required minimum "
                f"{lo:g}"
            )
    for dotted, hi in rules.get("max_fields", {}).items():
        v = _field_at(payload, dotted)
        if not isinstance(v, (int, float)) or not (v <= float(hi)):
            errors.append(
                f"{name}: field {dotted} = {v!r} above allowed maximum "
                f"{hi:g}"
            )

    # -- required families (the expansions trajectory) ----------------------
    fams_want = set(rules.get("families", []))
    if fams_want:
        fams = {r.get("expansion") for r in payload["results"]}
        missing = fams_want - fams
        if missing:
            errors.append(f"{name}: missing families {sorted(missing)}")

    # -- timings: ratio vs committed baseline, soft by default --------------
    # a baseline entry is a bare seconds value, or {"seconds": s,
    # "derived": tag} to pin the workload config — a row whose derived tag
    # differs (e.g. the nightly's non-smoke shapes vs the smoke baseline)
    # is skipped rather than spuriously warned about
    ratio_warn = float(cfg.get("timing_ratio_warn", 4.0))
    by_name = {}
    for r in payload["results"]:
        key = (f"{r['bench']}/{r['expansion']}/{r['name']}"
               if "bench" in r else r["name"])
        by_name[key] = (float(r["seconds"]), r.get("derived", ""))
    for tname, base in rules.get("timings", {}).items():
        want_tag = None
        if isinstance(base, dict):
            want_tag = base.get("derived")
            base = float(base["seconds"])
        hit = by_name.get(tname)
        if hit is None:
            warnings.append(
                f"{name}: baseline timing {tname!r} not in this run "
                f"(smoke subset?)"
            )
            continue
        now, tag = hit
        if want_tag is not None and not tag.startswith(want_tag):
            continue  # different workload config than the baseline pinned
        if base > 0 and now / base > ratio_warn:
            warnings.append(
                f"{name}: {tname} took {now * 1e3:.2f} ms vs baseline "
                f"{base * 1e3:.2f} ms ({now / base:.1f}x > {ratio_warn:g}x)"
            )


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*",
                    help="BENCH files to check (default: every BENCH_*.json)")
    ap.add_argument("--baselines", default=str(BASELINES))
    ap.add_argument("--require", nargs="*", default=[],
                    help="file names whose absence is a hard failure")
    ap.add_argument("--strict-timing", action="store_true",
                    help="treat timing-ratio warnings as failures")
    args = ap.parse_args()

    base_path = Path(args.baselines)
    if not base_path.exists():
        print(f"BENCH CHECK FAILED: no baselines file at {base_path}")
        return 1
    cfg = json.loads(base_path.read_text())
    per_file = cfg.get("files", {})

    if args.files:
        paths = [ROOT / f if not Path(f).is_absolute() else Path(f)
                 for f in args.files]
    else:
        paths = sorted(
            p for p in ROOT.glob("BENCH_*.json") if p.name != base_path.name
        )

    errors: list = []
    warnings: list = []
    for req in args.require:
        if not (ROOT / req).exists() and req not in {p.name for p in paths
                                                     if p.exists()}:
            errors.append(f"required file missing: {req}")
    seen = set()
    for p in paths:
        if p.name in seen or p.name == base_path.name:
            continue
        seen.add(p.name)
        if not p.exists():
            errors.append(f"missing file: {p.name}")
            continue
        check_file(p, per_file.get(p.name, {}), cfg, errors, warnings)

    for w in warnings:
        print(f"WARN: {w}")
    if args.strict_timing and warnings:
        errors.extend(warnings)
    if errors:
        print("BENCH CHECK FAILED:")
        for e in errors:
            print("  " + e)
        return 1
    print(f"bench check OK: {len(seen)} file(s) validated"
          + (f", {len(warnings)} timing warning(s)" if warnings else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
