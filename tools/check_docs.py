#!/usr/bin/env python3
"""Docs reference checker: code references in README.md / EXPERIMENTS.md
must resolve.

Checked reference forms (inside backticks):
  `path/to/file.py`            -> file must exist
  `path/to/file.py::symbol`    -> file must exist AND contain `symbol`
  `dir/`                       -> directory must exist
  `python -m pkg.mod ...`      -> module must resolve under src/ (or be a
                                  top-level script dir like benchmarks/)

Run from anywhere:  python tools/check_docs.py
Exit code 1 on any dangling reference (CI gate).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DOCS = ["README.md", "EXPERIMENTS.md"]

# runtime-generated artifacts: docs may reference them before they exist
ALLOW_MISSING_PREFIXES = ("experiments/",)


def allowed_missing(rel: str) -> bool:
    return rel.startswith(ALLOW_MISSING_PREFIXES)

PATHLIKE = re.compile(r"`([A-Za-z0-9_./-]+\.(?:py|md|toml|yml|txt))(?:::([A-Za-z0-9_.]+))?`")
DIRLIKE = re.compile(r"`([A-Za-z0-9_./-]+/)`")
MODLIKE = re.compile(r"`(?:PYTHONPATH=src )?python -m ([A-Za-z0-9_.]+)")


def module_path(mod: str) -> Path | None:
    for base in (ROOT / "src", ROOT):
        p = base / Path(*mod.split("."))
        if p.with_suffix(".py").exists() or (p / "__init__.py").exists() \
                or (p / "__main__.py").exists() or (p / "run.py").exists():
            return p
    return None


def check(doc: str) -> list[str]:
    text = (ROOT / doc).read_text()
    errors = []
    for m in PATHLIKE.finditer(text):
        rel, symbol = m.group(1), m.group(2)
        path = ROOT / rel
        if not path.exists():
            if not allowed_missing(rel):
                errors.append(f"{doc}: `{m.group(0)[1:-1]}` — missing file {rel}")
            continue
        if symbol:
            leaf = symbol.rsplit(".", 1)[-1]
            if leaf not in path.read_text():
                errors.append(f"{doc}: `{m.group(0)[1:-1]}` — {rel} has no '{leaf}'")
    for m in DIRLIKE.finditer(text):
        rel = m.group(1)
        if "/" in rel.rstrip("/") or rel in ("src/", "tests/", "benchmarks/", "examples/"):
            if not (ROOT / rel).exists() and not allowed_missing(rel):
                errors.append(f"{doc}: `{rel}` — missing directory")
    for m in MODLIKE.finditer(text):
        mod = m.group(1)
        if module_path(mod) is None:
            errors.append(f"{doc}: `python -m {mod}` — module not found under src/ or repo root")
    return errors


def main() -> int:
    missing_docs = [d for d in DOCS if not (ROOT / d).exists()]
    errors = [f"missing doc: {d}" for d in missing_docs]
    for doc in DOCS:
        if doc not in missing_docs:
            errors.extend(check(doc))
    if errors:
        print("DOCS CHECK FAILED:")
        for e in errors:
            print("  " + e)
        return 1
    print(f"docs check OK: all code references in {', '.join(DOCS)} resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
