#!/usr/bin/env python3
"""CI validator for Chrome-trace JSONL files written by --trace-out.

Every line must be a standalone JSON object carrying the span schema
(``name``/``ph``/``ts``/``pid``/``tid``), with ``ph`` either ``"X"``
(complete span, requires numeric ``dur >= 0``) or ``"i"`` (instant).
Complete spans on one ``(pid, tid)`` track must nest properly — a span
that starts inside another must end inside it too; overlapping
half-open spans mean the tracer emitted garbage timestamps and the
chrome://tracing / Perfetto render would be misleading.

  python tools/check_trace.py trace.jsonl
  python tools/check_trace.py trace.jsonl --expect dispatch harvest

``--expect`` names stages that must appear at least once — CI uses it
to prove the smoke run exercised the full pipeline, not just that the
file parses.  Exit code 1 on any violation.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

SPAN_SCHEMA_KEYS = ("name", "ph", "ts", "pid", "tid")


def check_trace(path: Path, expect=(), errors=None) -> list:
    """Validate one JSONL trace file; returns the error list."""
    errors = [] if errors is None else errors
    try:
        lines = path.read_text().splitlines()
    except OSError as e:
        errors.append(f"{path.name}: unreadable ({e})")
        return errors
    if not lines:
        errors.append(f"{path.name}: empty trace")
        return errors

    seen_names = set()
    # per-(pid,tid) list of (start, end) complete spans, in file order
    tracks: dict = {}
    for i, line in enumerate(lines, 1):
        try:
            ev = json.loads(line)
        except json.JSONDecodeError as e:
            errors.append(f"{path.name}:{i}: not JSON ({e})")
            continue
        if not isinstance(ev, dict):
            errors.append(f"{path.name}:{i}: not an object")
            continue
        missing = [k for k in SPAN_SCHEMA_KEYS if k not in ev]
        if missing:
            errors.append(f"{path.name}:{i}: missing keys {missing}")
            continue
        if ev["ph"] not in ("X", "i"):
            errors.append(f"{path.name}:{i}: unknown phase {ev['ph']!r}")
            continue
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            errors.append(f"{path.name}:{i}: bad ts {ev['ts']!r}")
            continue
        seen_names.add(ev["name"])
        if ev["ph"] == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{path.name}:{i}: X span with bad dur "
                              f"{dur!r}")
                continue
            tracks.setdefault((ev["pid"], ev["tid"]), []).append(
                (float(ev["ts"]), float(ev["ts"]) + float(dur), i,
                 ev["name"])
            )

    # nesting: on each track, any two spans either nest or are disjoint.
    # spans arrive in completion order; a sort by (start, -end) puts
    # parents before children, after which a stack walk finds overlaps.
    for (pid, tid), spans in tracks.items():
        spans.sort(key=lambda s: (s[0], -s[1]))
        stack: list = []
        for t0, t1, lineno, sname in spans:
            while stack and stack[-1][1] <= t0:
                stack.pop()
            if stack and t1 > stack[-1][1]:
                errors.append(
                    f"{path.name}:{lineno}: span {sname!r} "
                    f"[{t0:.0f},{t1:.0f}] overlaps {stack[-1][3]!r} "
                    f"[{stack[-1][0]:.0f},{stack[-1][1]:.0f}] on track "
                    f"pid={pid} tid={tid} without nesting"
                )
                break
            stack.append((t0, t1, lineno, sname))

    for name in expect:
        if name not in seen_names:
            errors.append(f"{path.name}: expected stage {name!r} never "
                          f"traced (saw {sorted(seen_names)})")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+", help="JSONL trace files")
    ap.add_argument("--expect", nargs="*", default=[],
                    help="span names that must appear at least once")
    args = ap.parse_args()

    errors: list = []
    total = 0
    for f in args.files:
        p = Path(f)
        if not p.exists():
            errors.append(f"missing file: {f}")
            continue
        check_trace(p, expect=args.expect, errors=errors)
        total += 1
    if errors:
        print("TRACE CHECK FAILED:")
        for e in errors:
            print("  " + e)
        return 1
    print(f"trace check OK: {total} file(s) validated")
    return 0


if __name__ == "__main__":
    sys.exit(main())
