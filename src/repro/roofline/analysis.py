"""HLO parsing + roofline term computation (TPU v5e constants)."""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

__all__ = ["Hardware", "HW", "collective_bytes", "roofline_terms", "analyze_compiled"]


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12      # bf16 FLOP/s per chip
    hbm_bw: float = 819e9           # B/s per chip
    ici_bw: float = 50e9            # B/s per link (effective, per chip)


HW = Hardware()

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)

# effective wire bytes per device / result bytes, ring algorithms
_WIRE_FACTOR = {
    "all-reduce": 2.0,          # reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-collective-type {result_bytes, wire_bytes, count} from HLO text.

    '-start' ops are counted; their '-done' twins are not (same tensor)."""
    out: Dict[str, dict] = {}
    seen_done = 0
    for m in _COLL_RE.finditer(hlo_text):
        shape_s, op = m.group(1), m.group(2)
        whole = m.group(0)
        if "-done(" in whole:
            seen_done += 1
            continue
        b = _shape_bytes(shape_s)
        rec = out.setdefault(op, {"bytes": 0.0, "wire_bytes": 0.0, "count": 0})
        rec["bytes"] += b
        rec["wire_bytes"] += b * _WIRE_FACTOR[op]
        rec["count"] += 1
    return out


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   wire_bytes_per_dev: float, hw: Hardware = HW) -> Dict[str, float]:
    compute = flops_per_dev / hw.peak_flops
    memory = bytes_per_dev / hw.hbm_bw
    collective = wire_bytes_per_dev / hw.ici_bw
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    terms["dominant"] = max(
        ("compute", compute), ("memory", memory), ("collective", collective),
        key=lambda kv: kv[1],
    )[0]
    terms["bound_s"] = max(compute, memory, collective)
    return terms


def analyze_compiled(compiled, n_chips: int, *, model_flops: float | None = None,
                     hw: Hardware = HW) -> dict:
    """Full per-cell roofline record from a compiled executable.

    FLOP/byte/collective totals come from the scan-aware HLO parse
    (hlo_cost.hlo_costs); xla's cost_analysis() is recorded alongside for
    reference but counts while-loop bodies once (see module docstring)."""
    from .hlo_cost import hlo_costs

    ca = compiled.cost_analysis() or {}
    hc = hlo_costs(compiled.as_text())
    flops = float(hc["flops"])
    byts = float(hc["bytes"])
    colls = hc["collectives"]
    wire = float(hc["wire_bytes"])
    terms = roofline_terms(flops, byts, wire, hw)

    ma = compiled.memory_analysis()
    mem = {}
    if ma is not None:
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes_est": int(
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes
            ),
        }

    rec = {
        "per_device": {"flops": flops, "bytes": byts, "wire_bytes": wire},
        "collectives": colls,
        "terms": terms,
        "memory": mem,
        "n_chips": n_chips,
        "xla_cost_analysis_scan_once": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
        },
    }
    if model_flops is not None:
        hlo_global = flops * n_chips
        rec["model_flops"] = model_flops
        rec["useful_ratio"] = model_flops / hlo_global if hlo_global else 0.0
        rec["roofline_fraction"] = (
            (model_flops / hw.peak_flops / n_chips) / terms["bound_s"]
            if terms["bound_s"] > 0 else 0.0
        )
    return rec
