"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh) cell, all in seconds:

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bw_per_chip
    collective = effective_wire_bytes_per_device / ICI_bw_per_chip

cost_analysis() reports per-device (per-SPMD-program) numbers, so per-chip
division is already done.  collective bytes are NOT in cost_analysis: we
parse the post-optimization HLO text and sum result-shape bytes of every
collective op, scaled by its ring-algorithm wire factor (all-reduce moves
~2x its payload per device; all-gather/reduce-scatter/all-to-all ~1x).
"""
from .analysis import (
    HW,
    Hardware,
    analyze_compiled,
    collective_bytes,
    roofline_terms,
)

__all__ = [
    "HW", "Hardware", "analyze_compiled", "collective_bytes", "roofline_terms",
]
