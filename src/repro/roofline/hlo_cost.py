"""Scan-aware cost extraction from compiled HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE regardless of its
trip count (verified empirically — see EXPERIMENTS.md §Methodology), which
undercounts every scanned layer stack by ~n_layers.  This module rebuilds
FLOP / byte / collective totals from the HLO text itself:

  * split the module into named computations;
  * per computation: matmul FLOPs from `dot(` ops (output size x contracting
    size x 2 — elementwise FLOPs are negligible next to dots for these
    models), HBM byte proxy from op result sizes + entry parameters, and
    collective payload bytes;
  * build the call graph (while bodies/conditions, fusions, calls,
    conditionals) and multiply each computation's cost by the product of
    enclosing while trip counts (parsed from the loop condition's compare
    constant).

Validated against analytic 6*N*D for the dense LMs (test_roofline.py).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

__all__ = ["hlo_costs"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# header lines sit at column 0 and may contain nested tuple types in args
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]"
    r"(?:\{[^}]*\})?))\s+([\w\-]+)\("
)
_CALL_ATTR = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_WIRE_FACTOR = {
    "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}


def _dims(shape_str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None, []
    dt = m.group(1)
    dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
    return dt, dims


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(txt: str) -> Dict[str, list[str]]:
    comps: Dict[str, list[str]] = {}
    cur = None
    for line in txt.splitlines():
        m = _COMP_HDR.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
                continue
            comps[cur].append(line)
    return comps


_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _dot_flops(line: str, result_shape: str, symtab: dict) -> float:
    """2 x |output| x |contraction| for a dot op.  Final HLO operand refs are
    bare names, so the lhs shape comes from the computation's symbol table."""
    _, out_dims = _dims(result_shape)
    out_n = 1
    for d in out_dims:
        out_n *= d
    cd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    if cd is None:
        return 0.0
    args = line[line.index("("):]
    names = _OPERAND_RE.findall(args)
    lhs_shape = symtab.get(names[0]) if names else None
    if lhs_shape is None:
        return 0.0
    _, lhs_dims = _dims(lhs_shape)
    contract = 1
    for i in (int(x) for x in cd.group(1).split(",") if x):
        if i < len(lhs_dims):
            contract *= lhs_dims[i]
    return 2.0 * out_n * contract


def hlo_costs(txt: str) -> dict:
    comps = _split_computations(txt)

    # per-computation raw costs + call edges
    raw = {}
    edges = defaultdict(set)           # parent -> {child}
    while_of = {}                      # body/cond comp -> trip count
    fusion_internal = set()            # comps whose ops never touch HBM
    for name, lines in comps.items():
        flops = byts = 0.0
        colls: Dict[str, dict] = {}
        symtab = {}
        for line in lines:
            m = _OP_RE.match(line)
            if m:
                symtab[m.group(1)] = m.group(2)
        for line in lines:
            if "fusion(" in line:
                for callee in _CALL_ATTR.findall(line):
                    fusion_internal.add(callee)
            m = _OP_RE.match(line)
            if m:
                _, result_shape, op = m.groups()
                rb = _shape_bytes(result_shape)
                if op not in ("parameter", "get-tuple-element", "tuple",
                              "bitcast", "constant"):
                    byts += rb
                if op == "dot":
                    flops += _dot_flops(line, result_shape, symtab)
                elif op == "custom-call":
                    # CPU backend: linalg as lapack FFI custom-calls
                    tgt = re.search(r'custom_call_target="([^"]+)"', line)
                    tname = tgt.group(1) if tgt else ""
                    _, dims = _dims(result_shape)
                    if "trsm" in tname and len(dims) >= 2:
                        batch = 1
                        for d in dims[:-2]:
                            batch *= d
                        flops += batch * dims[-2] * dims[-2] * dims[-1]
                    elif "potrf" in tname and len(dims) >= 2:
                        batch = 1
                        for d in dims[:-2]:
                            batch *= d
                        flops += batch * dims[-1] ** 3 / 3.0
                    elif "gemm" in tname or "matmul" in tname:
                        # conservatively: |out| x shared-dim unknown -> skip
                        pass
                elif op == "triangular-solve":
                    # result (..., M, N) vs M x M triangle: ~M^2 N MACs
                    _, dims = _dims(result_shape)
                    if len(dims) >= 2:
                        batch = 1
                        for d in dims[:-2]:
                            batch *= d
                        flops += batch * dims[-2] * dims[-2] * dims[-1]
                elif op == "cholesky":
                    _, dims = _dims(result_shape)
                    if len(dims) >= 2:
                        batch = 1
                        for d in dims[:-2]:
                            batch *= d
                        flops += batch * dims[-1] ** 3 / 3.0
                base = op
                for suffix in ("-start", "-done"):
                    if base.endswith(suffix):
                        base = base[: -len(suffix)]
                if base in _COLLECTIVES and not op.endswith("-done"):
                    rec = colls.setdefault(
                        base, {"bytes": 0.0, "wire_bytes": 0.0, "count": 0}
                    )
                    rec["bytes"] += rb
                    rec["wire_bytes"] += rb * _WIRE_FACTOR[base]
                    rec["count"] += 1
            for callee in _CALL_ATTR.findall(line):
                edges[name].add(callee)
            bm = _BRANCHES.search(line)
            if bm:
                for c in bm.group(1).split(","):
                    edges[name].add(c.strip().lstrip("%"))
            if "while(" in line:
                body = re.search(r"body=%?([\w\.\-]+)", line)
                cond = re.search(r"condition=%?([\w\.\-]+)", line)
                if body and cond:
                    trip = 1
                    consts = [
                        int(c) for c in _CONST_RE.findall(
                            "\n".join(comps.get(cond.group(1), []))
                        )
                    ]
                    if consts:
                        trip = max(consts)
                    while_of[body.group(1)] = trip
                    while_of[cond.group(1)] = trip
        raw[name] = {"flops": flops, "bytes": byts, "colls": colls}

    # multipliers: product of enclosing while trip counts, via DFS from ENTRY
    entry = None
    for name in comps:
        if "main" in name:
            entry = name
            break
    if entry is None:
        entry = next(iter(comps))

    mult = defaultdict(float)

    def visit(name, m):
        if m <= mult[name]:
            return
        mult[name] = m
        for child in edges[name]:
            visit(child, m * while_of.get(child, 1))

    visit(entry, 1.0)

    total = {"flops": 0.0, "bytes": 0.0}
    colls_total: Dict[str, dict] = {}
    for name, r in raw.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        total["flops"] += r["flops"] * m
        if name not in fusion_internal:   # fusion internals never touch HBM
            total["bytes"] += r["bytes"] * m
        for op, rec in r["colls"].items():
            t = colls_total.setdefault(
                op, {"bytes": 0.0, "wire_bytes": 0.0, "count": 0}
            )
            t["bytes"] += rec["bytes"] * m
            t["wire_bytes"] += rec["wire_bytes"] * m
            t["count"] += int(rec["count"] * m)
    total["collectives"] = colls_total
    total["wire_bytes"] = sum(r["wire_bytes"] for r in colls_total.values())
    total["n_while"] = len(while_of) // 2
    return total
