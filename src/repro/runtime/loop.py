"""Fault-tolerant training loop.

Production behaviors, all exercised by tests/test_runtime.py:

* **checkpoint/restart**: resumes exactly from the latest checkpoint (data
  batches are a pure function of step, so the resumed run is bit-identical
  modulo optimizer nondeterminism — asserted in tests);
* **preemption handling**: SIGTERM/SIGINT set a flag; the loop finishes the
  current step, writes a final checkpoint, and exits cleanly;
* **async checkpointing**: serialization overlaps subsequent steps;
* **straggler detection**: per-step wall times are recorded; steps slower
  than ``straggler_factor``x the running median are counted and logged —
  on real pods this feeds the replace-slow-host policy;
* **elastic restore**: shardings are recomputed for the *current* mesh at
  restore (see checkpoint/), so a restart may change device count.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro import checkpoint, optim

__all__ = ["TrainLoopConfig", "train_loop"]


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    straggler_factor: float = 1.5
    handle_signals: bool = True
    async_ckpt: bool = True


def train_loop(
    train_step: Callable,          # (params, opt_state, batch) -> (params, opt_state, metrics)
    params: Any,
    opt_state: Any,
    batch_fn: Callable[[int], Any],
    cfg: TrainLoopConfig,
    *,
    shardings: tuple | None = None,  # (param_shardings, opt_shardings) for elastic restore
    log_fn: Callable[[str], None] = print,
):
    start_step = 0
    ckpt = None
    if cfg.ckpt_dir:
        ckpt = checkpoint.AsyncCheckpointer(cfg.ckpt_dir)
        last = checkpoint.latest_step(cfg.ckpt_dir)
        if last is not None:
            state_like = {"params": params, "opt": opt_state}
            sh = (
                {"params": shardings[0], "opt": shardings[1]}
                if shardings is not None else None
            )
            start_step, tree = checkpoint.restore(
                cfg.ckpt_dir, state_like, shardings=sh
            )
            params, opt_state = tree["params"], tree["opt"]
            log_fn(f"[restore] resumed from step {start_step}")

    preempted = {"flag": False}
    old_handlers = {}
    if cfg.handle_signals:
        def _handler(signum, frame):
            preempted["flag"] = True
            log_fn(f"[preempt] signal {signum}: checkpoint at end of step")

        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                old_handlers[sig] = signal.signal(sig, _handler)
            except ValueError:  # non-main thread (tests)
                pass

    step_times: list[float] = []
    stragglers = 0
    history = []
    step = start_step
    try:
        while step < cfg.steps:
            t0 = time.perf_counter()
            batch = batch_fn(step)
            params, opt_state, metrics = train_step(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            step_times.append(dt)
            med = float(np.median(step_times[-50:]))
            if len(step_times) > 5 and dt > cfg.straggler_factor * med:
                stragglers += 1
                log_fn(f"[straggler] step {step}: {dt:.3f}s vs median {med:.3f}s")
            step += 1
            if step % cfg.log_every == 0 or step == cfg.steps:
                history.append(
                    {"step": step, "loss": float(metrics["loss"]),
                     "grad_norm": float(metrics.get("grad_norm", np.nan)),
                     "sec_per_step": dt}
                )
                log_fn(f"[step {step}] loss={history[-1]['loss']:.4f} "
                       f"gnorm={history[-1]['grad_norm']:.3f} {dt:.3f}s/step")
            want_ckpt = ckpt and (
                step % cfg.ckpt_every == 0 or step == cfg.steps or preempted["flag"]
            )
            if want_ckpt:
                state = {"params": params, "opt": opt_state}
                if cfg.async_ckpt and not preempted["flag"] and step != cfg.steps:
                    ckpt.save(step, state)
                else:
                    ckpt.wait()
                    checkpoint.save(cfg.ckpt_dir, step, state)
            if preempted["flag"]:
                log_fn(f"[preempt] exiting cleanly at step {step}")
                break
    finally:
        if ckpt:
            ckpt.wait()
        for sig, h in old_handlers.items():
            signal.signal(sig, h)

    return params, opt_state, {
        "history": history,
        "final_step": step,
        "stragglers": stragglers,
        "preempted": preempted["flag"],
        "median_step_s": float(np.median(step_times)) if step_times else None,
    }
