"""GPBank — a fleet of independent GP sessions served as one batched model.

The production analogue of the paper's "cheap posterior on an accelerator"
claim is not one GP but *fleets* of small independent GPs — one per sensor,
user, task, or region — served concurrently.  A Python loop of single-model
calls pays per-call dispatch, per-call kernel launch, and per-call H2D
latency B times; a bank pays them once.

``GPBank`` keeps B fitted sessions resident on the device as ONE stacked
:class:`~repro.core.fagp.FAGPState`:

* leading bank axis on ``chol`` (C, M, M), ``u`` (C, M), ``b`` (C, M),
  ``lam``/``sqrtlam`` (C, M) — the per-tenant factorizations;
* one shared static :class:`~repro.core.fagp.GPSpec` (index set, Mercer
  depth n, backend, hyperparameters) — so every tenant shares one feature
  map and one compiled executable per entry point.

Capacity is fixed at construction: the stack always holds ``capacity``
slots, of which some are *active* (hold a fitted tenant) and the rest hold
the prior state (chol = I, u = b = 0 — a valid "no data yet" posterior).
Membership churn (:meth:`insert` / :meth:`evict`) writes slot leaves with a
*traced* slot index through module-level jitted helpers, so adding or
removing tenants NEVER recompiles the serving executable — the executables
are keyed only on the stack's (capacity, M) shapes.

Entry points (all single compiled calls over the whole fleet):

* :meth:`GPBank.fit`      — B datasets -> B factorizations: one batched
  moment accumulation (``FitBackend.bank_moments``: vmapped scan on the jnp
  backend; a bank grid axis in the streaming fused Pallas kernel on the
  pallas backend) + one batched Cholesky.  Ragged per-tenant N is expressed
  with per-slot row masks on a fixed (B, N, p) stack.
* :meth:`GPBank.mean_var` — a *mixed-tenant* query batch: row q is answered
  by tenant ``tenant_ids[q]``'s posterior, via gather from the stack
  (``FitBackend.bank_mean_var``).
* :meth:`GPBank.update`   — batched rank-k Cholesky ingest for several
  tenants at once (vmapped ``_update_arrays``), scattered back into the
  stack.

* :meth:`GPBank.optimize` — fleet-scale batched hyperparameter learning:
  the (B tenants x R restarts) lane engine (``repro.optim.gp_hyperopt``)
  optimizes every tenant's NLML at once and refits the winners back into
  the stack.  The result is a *heterogeneous* bank: per-slot
  (eps, rho, noise) overlay (``GPBank.hypers``), per-slot eigenvalue rows
  (already stacked), and a serving path that featurizes each query row
  under its own slot's hyperparameters.  Homogeneous banks
  (``hypers is None``) keep every fast path exactly as before.

``bank.router.BankRouter`` turns per-tenant query/observation queues into
the padded fixed-shape batches these entry points want (and tracks
per-tenant staleness for periodic re-optimization).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Hashable, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fagp
from repro.core.expansions import get_expansion
from repro.core.fagp import FAGPState, GPSpec
from repro.core.gp import GP
from repro.core.mercer import SEKernelParams

__all__ = ["GPBank"]


# ---------------------------------------------------------------------------
# Module-level jitted kernels.  Deliberately NOT methods: their jit caches
# are keyed on (capacity, M, Q, k) shapes only, so membership churn and
# arbitrary tenant mixes reuse one executable — pinned by
# tests/test_gp_bank.py via _cache_size().
# ---------------------------------------------------------------------------


@jax.jit
def _bank_solve(G, b, loglam, sig2):
    """Batched fit epilogue: raw moments (C, M, M)/(C, M) -> stacked
    (lam, sqrtlam, chol, u).  The scaled system keeps its one home
    (fagp._assemble_scaled_system), vmapped over slots; the Cholesky and the
    mean-weight solves batch natively."""
    Bm, sqrtlam = jax.vmap(
        lambda Gs: fagp._assemble_scaled_system(Gs, loglam, sig2)
    )(G)
    chol = jnp.linalg.cholesky(Bm)
    u = jax.vmap(
        lambda c, d, bs: fagp._solve_mean_weights(c, d, bs, sig2)
    )(chol, sqrtlam, b)
    lam = jnp.broadcast_to(jnp.exp(loglam), sqrtlam.shape)
    return lam, sqrtlam, chol, u


def _bank_update_scatter_impl(chol_s, u_s, b_s, sqrtlam_s, noise_g, slots,
                              Phi_g, y_g, mask_g):
    """Gather slot states, apply the rank-k update per group row, scatter
    back.  Padded rows (mask 0) zero their feature row, which makes the
    rank-1 sweep an identity for them — ragged ingest is a masking detail,
    not a shape change.  A *fully*-masked group (the router's group-axis
    shape padding) writes its gathered values back verbatim: the identity
    sweep is exact only up to sqrt rounding, and an untouched tenant must
    not drift by ulps per serving round.  ``noise_g`` (G,) is per group —
    heterogeneous banks carry per-slot noise; homogeneous banks broadcast
    the shared value.

    Jitted twice below: the plain variant, and a buffer-donating variant
    for pipelined serving loops that own their bank exclusively
    (dispatch-ahead ingest reuses the old stack's device memory instead
    of doubling it; donation is a no-op on backends without support,
    e.g. CPU)."""
    Phi_g = Phi_g * mask_g[..., None]
    y_g = y_g * mask_g
    ch, bb, uu = jax.vmap(
        lambda c, bm, d, s, P, y: fagp._update_arrays(c, bm, d, s, P, y)
    )(chol_s[slots], b_s[slots], sqrtlam_s[slots], noise_g, Phi_g, y_g)
    real = jnp.max(mask_g, axis=1) > 0                  # (G,) any live row?
    ch = jnp.where(real[:, None, None], ch, chol_s[slots])
    uu = jnp.where(real[:, None], uu, u_s[slots])
    bb = jnp.where(real[:, None], bb, b_s[slots])
    return (chol_s.at[slots].set(ch), u_s.at[slots].set(uu),
            b_s.at[slots].set(bb))


_bank_update_scatter = jax.jit(_bank_update_scatter_impl)
_bank_update_scatter_donated = jax.jit(
    _bank_update_scatter_impl, donate_argnums=(0, 1, 2)
)

# relative positive-definiteness guard for the rank-1 downdate sweep: a
# pivot whose downdated square drops below this fraction of its original
# square is declared lost (f32 eps is ~1.2e-7; anything this small is
# noise-dominated and the refit fallback takes over)
_DOWNDATE_TOL = 1e-6


def _chol_rank1_downdate(L: jax.Array, w: jax.Array):
    """Cholesky of L L^T - w w^T, O(M^2) — the mirror of
    ``fagp._chol_rank1_update``'s LINPACK sweep with hyperbolic instead of
    Givens rotations.  Unlike additions, downdates can LOSE positive
    definiteness (w outside the column space, or f32 cancellation);
    returns ``(L', ok)`` where ``ok=False`` flags a pivot that went
    nonpositive — the caller must discard L' and refit from retained data.
    A zero w (masked row) is an exact identity: r = |Lkk|, c = 1, s = 0."""
    M = L.shape[0]
    ar = jnp.arange(M)

    def step(carry, k):
        L, w, ok = carry
        Lkk = L[k, k]
        wk = w[k]
        r2 = Lkk * Lkk - wk * wk
        ok = ok & (r2 > _DOWNDATE_TOL * Lkk * Lkk)
        r = jnp.sqrt(jnp.maximum(r2, jnp.float32(1e-30)))
        c = r / Lkk
        s = wk / Lkk
        col = L[:, k]
        below = ar > k
        newcol = jnp.where(below, (col - s * w) / c, col).at[k].set(r)
        w = jnp.where(below, c * w - s * newcol, w)
        return (L.at[:, k].set(newcol), w, ok), None

    (L, _, ok), _ = jax.lax.scan(step, (L, w, jnp.bool_(True)), ar)
    return L, ok


def _downdate_arrays(chol, b, sqrtlam, noise, Phi_rm, y_rm):
    """Array-level rank-K downdate core: (chol, b) -> (chol', b', u', ok).

    Removes K previously-absorbed rows from the factorization —
    B' = B - sum_k v_k v_k^T with v_k = D phi_k / sigma — via sequential
    rank-1 hyperbolic sweeps (there is no safe refactorization shortcut:
    forming B' by subtraction and re-Cholesky-ing silently NaNs on lost
    positive definiteness, while the sweep detects it per pivot).  ``ok``
    is False when ANY sweep lost a pivot; the outputs are then garbage by
    contract and the caller falls back to a masked refit from the retained
    window."""
    sig2 = noise**2
    W = Phi_rm * sqrtlam[None, :] / noise

    def one(carry, w):
        L, ok = carry
        L2, ok2 = _chol_rank1_downdate(L, w)
        return (L2, ok & ok2), None

    (chol, ok), _ = jax.lax.scan(one, (chol, jnp.bool_(True)), W)
    b = b - Phi_rm.T @ y_rm
    u = fagp._solve_mean_weights(chol, sqrtlam, b, sig2)
    return chol, b, u, ok


@jax.jit
def _bank_downdate_scatter(chol_s, u_s, b_s, sqrtlam_s, noise_g, slots,
                           Phi_g, y_g, mask_g):
    """The downdate mirror of ``_bank_update_scatter``: gather slot
    states, remove the masked rank-k rows per group, scatter back.  Groups
    that lost positive definiteness (and fully-masked padding groups)
    write their gathered values back VERBATIM — a failed downdate must
    leave the slot untouched so the refit fallback starts from consistent
    state.  Returns the stacked leaves plus a (G,) ``ok`` flag per group
    (padding groups report ok: nothing to remove succeeded trivially)."""
    Phi_g = Phi_g * mask_g[..., None]
    y_g = y_g * mask_g
    ch, bb, uu, ok = jax.vmap(_downdate_arrays)(
        chol_s[slots], b_s[slots], sqrtlam_s[slots], noise_g, Phi_g, y_g
    )
    real = jnp.max(mask_g, axis=1) > 0
    good = ok & real
    ch = jnp.where(good[:, None, None], ch, chol_s[slots])
    uu = jnp.where(good[:, None], uu, u_s[slots])
    bb = jnp.where(good[:, None], bb, b_s[slots])
    return (chol_s.at[slots].set(ch), u_s.at[slots].set(uu),
            b_s.at[slots].set(bb), ok | ~real)


@jax.jit
def _bank_refit_scatter(chol_s, u_s, b_s, lam_s, sqrtlam_s, slots,
                        Xg, yg, maskg, eps_g, rho_g, noise_g, spec, idx):
    """Masked refit of selected slots from retained window data, scattered
    back into the stack — the fallback leg of sliding-window forgetting
    (and a general repair path).  Rides ``_bank_hetero_refit`` so every
    group refits under its own slot's hyperparameters (identical to the
    shared values in a homogeneous bank).  Fully-masked padding groups
    write their gathered values back verbatim, so the group axis can be
    padded to a fixed shape bucket without touching real slots."""
    lam, sqrtlam, chol, u, b = _bank_hetero_refit(
        Xg, yg, maskg, eps_g, rho_g, noise_g, spec, idx
    )
    real = jnp.max(maskg, axis=1) > 0
    chol = jnp.where(real[:, None, None], chol, chol_s[slots])
    u = jnp.where(real[:, None], u, u_s[slots])
    b = jnp.where(real[:, None], b, b_s[slots])
    lam = jnp.where(real[:, None], lam, lam_s[slots])
    sqrtlam = jnp.where(real[:, None], sqrtlam, sqrtlam_s[slots])
    return (chol_s.at[slots].set(chol), u_s.at[slots].set(u),
            b_s.at[slots].set(b), lam_s.at[slots].set(lam),
            sqrtlam_s.at[slots].set(sqrtlam))


@jax.jit
def _write_slot(chol_s, u_s, b_s, lam_s, sqrtlam_s, slot, chol, u, b, lam,
                sqrtlam):
    """Write one tenant's leaves at a *traced* slot index: insert/evict of
    any slot hit the same executable.  Writes the eigenvalue rows too —
    identical to the shared values in a homogeneous bank, per-tenant in a
    heterogeneous one (after :meth:`GPBank.optimize`)."""
    return (chol_s.at[slot].set(chol), u_s.at[slot].set(u),
            b_s.at[slot].set(b), lam_s.at[slot].set(lam),
            sqrtlam_s.at[slot].set(sqrtlam))


@jax.jit
def _hetero_gathered_mean_var(stack, binv, slots, Xq, eps_s, rho_s):
    """Mixed-tenant serving under PER-SLOT hyperparameters: query row q is
    featurized under slot ``slots[q]``'s own (eps, rho) — one vmapped jnp
    feature map per row (per-row feature constants rule out the shared
    backend kernel launch; correctness-first fallback, one executable per
    (Q, p) shape), then the same gathered posterior as the homogeneous
    path."""
    spec = stack.spec

    def row(x, e, r):
        sp = dataclasses.replace(spec, eps=e, rho=r)
        return fagp._features(x[None], stack.idx, sp)[0]

    Phis = jax.vmap(row)(Xq, eps_s[slots], rho_s[slots])
    return fagp._bank_gathered_posterior(
        binv, stack.u, stack.sqrtlam, slots, Phis
    )


@jax.jit
def _hetero_group_features(stack, Xg, eps_g, rho_g):
    """(G, k, M) update-group features, each group under its own slot's
    hyperparameters."""
    spec = stack.spec

    def grp(X, e, r):
        sp = dataclasses.replace(spec, eps=e, rho=r)
        return fagp._features(X, stack.idx, sp)

    return jax.vmap(grp)(Xg, eps_g, rho_g)


@jax.jit
def _bank_hetero_refit(Xb, yb, maskb, eps_b, rho_b, noise_b, spec, idx):
    """Batched refit of B tenants, each under ITS OWN hyperparameters (the
    epilogue of :meth:`GPBank.optimize`): per-tenant streamed moments
    through the backend registry hook (vmapped — the pallas fused kernel
    batches via its grid, the jnp scan via vmap; no N x M Phi either way),
    then the batched scaled solve.  Returns stacked
    (lam, sqrtlam, chol, u, b)."""

    def one(X, y, m, e, r, s):
        sp = dataclasses.replace(spec, eps=e, rho=r, noise=s)
        loglam = get_expansion(sp.expansion).log_eigenvalues(idx, sp)
        G, b = fagp._moments_via_registry(sp, X, y, m)
        Bm, sqrtlam = fagp._assemble_scaled_system(G, loglam, s * s)
        chol = jnp.linalg.cholesky(Bm)
        u = fagp._solve_mean_weights(chol, sqrtlam, b, s * s)
        return jnp.exp(loglam), sqrtlam, chol, u, b

    return jax.vmap(one)(Xb, yb, maskb, eps_b, rho_b, noise_b)


def _fallback_bank_moments(backend):
    """vmap of the single-model moments for backends that do not declare a
    native bank_moments."""
    def f(Xb, yb, spec, idx, aux, block_rows, maskb):
        one = lambda X, y, m: backend.moments(
            X, y, spec, idx, aux, block_rows, m
        )
        return jax.vmap(one)(Xb, yb, maskb)
    return f


def _fallback_bank_mean_var(backend):
    """Gathered posterior on top of the backend's feature map, for backends
    that do not declare a native bank_mean_var."""
    return fagp._gathered_bank_mean_var(backend.features)


def _bank_spec(spec: GPSpec) -> GPSpec:
    """Normalize a spec for bank use: banks are a serving structure and
    never store per-tenant training features, so ``store_train`` is
    downgraded — otherwise every unstacked ``state(t)`` would carry a spec
    claiming stored features while holding ``Phi=None``, and paper-mode
    prediction's 'refit with store_train=True' guidance would loop."""
    return spec.replace(store_train=False) if spec.store_train else spec


def _prior_leaves(loglam: jax.Array, count: int) -> dict:
    """The per-slot leaves of the 'no data yet' state — chol = I,
    u = b = 0, spec eigenvalues — a valid prior posterior (zero mean,
    prior variance).  The ONE definition of an empty slot: ``create``
    builds whole banks from it and ``fit`` pads reserved capacity with it,
    so the fully-masked-slot == fresh-slot invariant cannot drift."""
    M = loglam.shape[0]
    return {
        "lam": jnp.broadcast_to(jnp.exp(loglam), (count, M)),
        "sqrtlam": jnp.broadcast_to(jnp.exp(0.5 * loglam), (count, M)),
        "chol": jnp.broadcast_to(jnp.eye(M, dtype=jnp.float32),
                                 (count, M, M)),
        "u": jnp.zeros((count, M), jnp.float32),
        "b": jnp.zeros((count, M), jnp.float32),
    }


def _check_single_task_with_b(state: FAGPState, who: str) -> None:
    if state.u.ndim != 1:
        raise ValueError(
            f"{who}: multi-output states (T={state.n_tasks}) cannot join a "
            f"bank; banks batch over tenants, one task each"
        )
    if state.b is None:
        raise ValueError(
            f"{who}: state lacks the raw moment vector b (produced by a "
            f"pre-PR-1 fit path); refit before inserting"
        )


def _check_bankable(state: FAGPState, spec: GPSpec, who: str) -> None:
    """A state can join a HOMOGENEOUS bank iff it was factorized under the
    bank's shared spec (structure AND hyperparameters, including any RFF
    spectral draws) and is single-output with the raw moment vector
    present."""
    fagp._check_spec_regenerates_idx(state, spec)
    try:
        fagp._check_hypers_match(state, spec, who)
    except ValueError as e:
        raise ValueError(
            f"{e}; a bank shares one feature map and one eigenvalue "
            f"scaling across all tenants — refit the tenant under the "
            f"bank spec"
        ) from None
    _check_single_task_with_b(state, who)


def _check_bankable_hetero(state: FAGPState, spec: GPSpec, who: str) -> None:
    """A heterogeneous bank (per-slot hyperparameters, produced by
    :meth:`GPBank.optimize`) admits any tenant sharing the bank's expansion
    STRUCTURE — eps/rho/noise may differ per slot, but the expansion
    family, truncation and any RFF spectral draws stay bank-wide (they
    define the shared index table and, for RFF, the shared base
    frequencies)."""
    if state.spec is None:
        raise ValueError(
            f"{who}: state has no baked GPSpec; attach one with "
            f"state.with_spec(spec) before inserting"
        )
    for f in fagp._STRUCTURAL_FIELDS:
        if getattr(state.spec, f) != getattr(spec, f):
            raise ValueError(
                f"{who}: spec/state mismatch: state was fitted with "
                f"{state.spec.describe()} but the bank holds "
                f"{spec.describe()}; even a heterogeneous bank shares one "
                f"expansion structure — refit the tenant"
            )
    if not fagp._leaf_equal(state.spec.omega, spec.omega):
        raise ValueError(
            f"{who}: omega differs from the bank's spectral draws; the "
            f"RFF base frequencies are bank structure even in a "
            f"heterogeneous bank — refit the tenant under the bank's draws"
        )
    fagp._check_spec_regenerates_idx(state, state.spec)
    _check_single_task_with_b(state, who)


@dataclasses.dataclass(frozen=True)
class GPBank:
    """A fixed-capacity bank of independent GP sessions (see module doc).

    Construct with :meth:`fit`, :meth:`create`, or :meth:`from_states`; the
    default constructor is internal.  Instances are immutable — mutating
    methods return a new ``GPBank`` sharing the device stack buffers that
    did not change.

    stack:   stacked FAGPState — bank axis on chol/u/b/lam/sqrtlam,
             shared idx/params/spec.
    active:  (capacity,) host-side bool mask of occupied slots.
    slots:   tenant id -> slot index (host-side; insertion order preserved).
    hypers:  None for a homogeneous bank (every tenant shares the spec's
             eps/rho/noise — all fast paths unchanged), or per-slot stacked
             hyperparameters (eps (C, p), rho (C, p), noise (C,)) once
             :meth:`optimize` has learned per-tenant values.  Heterogeneous
             serving featurizes each query row under its own slot's
             hyperparameters (``_hetero_gathered_mean_var``).
    """

    stack: FAGPState
    active: np.ndarray
    slots: Mapping[Hashable, int]
    hypers: Optional[SEKernelParams] = None

    # -- constructors -------------------------------------------------------

    @classmethod
    def create(cls, spec: GPSpec, capacity: int) -> "GPBank":
        """An empty bank: every slot holds the prior state (chol = I,
        u = b = 0 — zero mean, prior variance)."""
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        spec = _bank_spec(spec)
        fagp._check_backend_support(spec)
        idx = jnp.asarray(spec.indices(spec.p))
        loglam = get_expansion(spec.expansion).log_eigenvalues(idx, spec)
        stack = FAGPState(
            idx=idx, params=spec.params, Phi=None, y=None, spec=spec,
            **_prior_leaves(loglam, capacity),
        )
        return cls(stack=stack, active=np.zeros(capacity, bool), slots={})

    @classmethod
    def fit(
        cls,
        Xb: jax.Array,
        yb: jax.Array,
        spec: GPSpec,
        *,
        mask: Optional[jax.Array] = None,
        tenant_ids: Optional[Sequence[Hashable]] = None,
        capacity: Optional[int] = None,
    ) -> "GPBank":
        """Fit B independent GPs in one batched pass.

        Xb: (B, N, p) stacked inputs; yb: (B, N) stacked targets;
        mask: (B, N) row validity — tenants with fewer than N real rows pad
        to N and mask the padding (ragged N).  ``tenant_ids`` default to
        ``range(B)``; ``capacity`` (>= B) reserves extra prior slots for
        later :meth:`insert` without reshaping the stack.
        """
        Xb = jnp.asarray(Xb)
        yb = jnp.asarray(yb)
        if Xb.ndim != 3 or yb.ndim != 2 or yb.shape != Xb.shape[:2]:
            raise ValueError(
                f"GPBank.fit wants Xb (B, N, p) and yb (B, N); got "
                f"{Xb.shape} and {yb.shape}"
            )
        B, N, p = Xb.shape
        spec = _bank_spec(spec)
        fagp._check_p(spec, p)
        cap = B if capacity is None else int(capacity)
        if cap < B:
            raise ValueError(f"capacity {cap} < number of tenants {B}")
        if tenant_ids is None:
            tenant_ids = range(B)
        tenant_ids = list(tenant_ids)
        if len(tenant_ids) != B or len(set(tenant_ids)) != B:
            raise ValueError(
                f"tenant_ids must be {B} distinct ids, got {tenant_ids!r}"
            )
        if mask is None:
            mask = jnp.ones((B, N), Xb.dtype)
        else:
            mask = jnp.asarray(mask).astype(Xb.dtype)
            if mask.shape != (B, N):
                raise ValueError(
                    f"mask must be (B, N) = {(B, N)}, got {mask.shape}"
                )
        backend = fagp._check_backend_support(spec)
        idx_np = spec.indices(p)
        idx = jnp.asarray(idx_np)
        aux = backend.prepare(idx_np, spec)
        moments = backend.bank_moments or _fallback_bank_moments(backend)
        # small tenants: never let a scan-based moments hook pad each
        # slot's few rows up to the default serving block
        block_rows = min(spec.block_rows, max(1, N))
        G, b = moments(Xb, yb, spec, idx, aux, block_rows, mask)
        loglam = get_expansion(spec.expansion).log_eigenvalues(idx, spec)
        lam, sqrtlam, chol, u = _bank_solve(G, b, loglam, spec.noise**2)
        if cap > B:
            # reserved slots get the prior leaves directly — never pay the
            # O(N M^2) moment pass or the M^3 Cholesky for an empty slot
            prior = _prior_leaves(loglam, cap - B)
            lam = jnp.concatenate([lam, prior["lam"]])
            sqrtlam = jnp.concatenate([sqrtlam, prior["sqrtlam"]])
            chol = jnp.concatenate([chol, prior["chol"]])
            u = jnp.concatenate([u, prior["u"]])
            b = jnp.concatenate([b, prior["b"]])
        stack = FAGPState(
            idx=idx, lam=lam, sqrtlam=sqrtlam, chol=chol, u=u,
            params=spec.params, Phi=None, y=None, b=b, spec=spec,
        )
        active = np.zeros(cap, bool)
        active[:B] = True
        return cls(stack=stack, active=active,
                   slots={t: s for s, t in enumerate(tenant_ids)})

    @classmethod
    def from_states(
        cls,
        states: Mapping[Hashable, Any],
        *,
        capacity: Optional[int] = None,
    ) -> "GPBank":
        """Stack already-fitted sessions (``GP`` or ``FAGPState``) into a
        bank.  All must share one structural spec and one hyperparameter
        set (the bank's shared feature map)."""
        if not states:
            raise ValueError("from_states needs at least one state")
        items = [
            (t, s.state if isinstance(s, GP) else s) for t, s in states.items()
        ]
        spec = items[0][1].spec
        if spec is None:
            raise ValueError(
                "from_states: first state has no baked GPSpec; attach one "
                "with state.with_spec(spec)"
            )
        spec = _bank_spec(spec)
        for t, st in items:
            _check_bankable(st, spec, f"from_states(tenant {t!r})")
        B = len(items)
        cap = B if capacity is None else int(capacity)
        if cap < B:
            raise ValueError(f"capacity {cap} < number of states {B}")
        bank = cls.create(spec, cap)
        stacked = {
            f: jnp.stack([getattr(st, f) for _, st in items])
            for f in ("lam", "sqrtlam", "chol", "u", "b")
        }
        pad = {
            f: jnp.concatenate([stacked[f], getattr(bank.stack, f)[B:]])
            for f in stacked
        }
        stack = dataclasses.replace(bank.stack, **pad)
        active = np.zeros(cap, bool)
        active[:B] = True
        return cls(stack=stack, active=active,
                   slots={t: s for s, (t, _) in enumerate(items)})

    # -- introspection ------------------------------------------------------

    @property
    def spec(self) -> GPSpec:
        return self.stack.spec

    @property
    def capacity(self) -> int:
        return self.stack.u.shape[0]

    @property
    def n_features(self) -> int:
        return self.stack.idx.shape[0]

    @property
    def tenants(self) -> list:
        return list(self.slots)

    def __len__(self) -> int:
        return len(self.slots)

    def __contains__(self, tenant: Hashable) -> bool:
        return tenant in self.slots

    def slot_of(self, tenant: Hashable) -> int:
        try:
            return self.slots[tenant]
        except KeyError:
            raise KeyError(
                f"tenant {tenant!r} is not in this bank (tenants: "
                f"{self.tenants!r})"
            ) from None

    def state(self, tenant: Hashable) -> FAGPState:
        """The tenant's session, unstacked — a normal single-model
        FAGPState usable with every ``fagp``/``GP`` entry point.  In a
        heterogeneous bank the returned state's spec carries the tenant's
        OWN learned hyperparameters."""
        s = self.slot_of(tenant)
        st = dataclasses.replace(
            self.stack,
            lam=self.stack.lam[s], sqrtlam=self.stack.sqrtlam[s],
            chol=self.stack.chol[s], u=self.stack.u[s], b=self.stack.b[s],
        )
        if self.hypers is not None:
            sp = self.spec.replace(
                eps=self.hypers.eps[s], rho=self.hypers.rho[s],
                noise=self.hypers.noise[s],
            )
            st = dataclasses.replace(st, spec=sp, params=sp.params)
        return st

    def _stacked_hypers(self) -> SEKernelParams:
        """Per-slot hyperparameters, materialized: the overlay when
        heterogeneous, the shared spec values broadcast when not."""
        if self.hypers is not None:
            return self.hypers
        sp = self.spec
        C = self.capacity
        return SEKernelParams(
            eps=jnp.broadcast_to(sp.eps, (C,) + sp.eps.shape),
            rho=jnp.broadcast_to(sp.rho, (C,) + sp.rho.shape),
            noise=jnp.broadcast_to(jnp.asarray(sp.noise, jnp.float32), (C,)),
        )

    def states(self) -> dict:
        """All tenants' sessions, unstacked (tenant -> FAGPState)."""
        return {t: self.state(t) for t in self.slots}

    @property
    def _binv(self) -> jax.Array:
        """Per-slot B^{-1} serving cache (C, M, M).  Lazily computed and
        memoized on the instance: GPBank is immutable and every mutating
        method returns a *new* bank, so the cache can never go stale.
        Mutations that know which slots they touched carry the cache
        forward with only those rows refreshed (``_carry_binv_into``)."""
        cached = self.__dict__.get("_binv_cache")
        if cached is None:
            cached = fagp._bank_binv(self.stack.chol)
            object.__setattr__(self, "_binv_cache", cached)
        return cached

    def _carry_binv_into(self, new: "GPBank", slots: jax.Array) -> None:
        """Incremental cache maintenance: a mutation touched only ``slots``
        (possibly one), so if this bank already paid for the full cache,
        refresh those rows and hand the rest forward instead of making the
        next query recompute B^{-1} for the whole capacity."""
        cached = self.__dict__.get("_binv_cache")
        if cached is not None:
            slots = jnp.atleast_1d(slots)
            rows = fagp._bank_binv(new.stack.chol[slots])
            object.__setattr__(
                new, "_binv_cache", cached.at[slots].set(rows)
            )

    def _slots_for(self, tenant_ids) -> jax.Array:
        if isinstance(tenant_ids, (str, bytes)) or not hasattr(
            tenant_ids, "__iter__"
        ):
            raise TypeError(
                "tenant_ids must be a sequence of tenant ids, one per row "
                f"(got a scalar {tenant_ids!r}); for a single-tenant batch "
                "pass [tenant] * len(Xq)"
            )
        return jnp.asarray(
            np.fromiter(
                (self.slot_of(t) for t in tenant_ids), np.int32,
            )
        )

    # -- the batched pipeline ----------------------------------------------

    @staticmethod
    def result_ready(*arrays) -> bool:
        """Have these dispatched results landed?  ``mean_var`` returns
        device arrays that are *futures* under JAX's asynchronous
        dispatch; a pipelined serving loop (``repro.bank.FleetEngine``)
        polls this to harvest completed blocks without ever blocking on
        an unfinished one.  Arrays without readiness introspection (older
        jax, concrete numpy inputs) report ready — the harvest then
        degrades to a blocking conversion, never to a wrong answer."""
        return all(
            ready() for a in arrays
            if (ready := getattr(a, "is_ready", None)) is not None
        )

    def mean_var(self, tenant_ids, Xq: jax.Array):
        """Posterior mean and marginal variance for a MIXED-tenant query
        batch: row q of ``Xq`` (Q, p) is answered by ``tenant_ids[q]``'s
        posterior.  One compiled call for the whole fleet."""
        Xq = jnp.asarray(Xq)
        slots = self._slots_for(tenant_ids)
        if slots.shape[0] != Xq.shape[0]:
            raise ValueError(
                f"one tenant id per query row: got {slots.shape[0]} ids "
                f"for {Xq.shape[0]} rows"
            )
        backend = fagp._check_backend_support(self.spec)
        if self.hypers is not None:
            return _hetero_gathered_mean_var(
                self.stack, self._binv, slots, Xq,
                self.hypers.eps, self.hypers.rho,
            )
        aux = fagp._backend_aux(backend, self.stack.idx, self.spec)
        fn = backend.bank_mean_var or _fallback_bank_mean_var(backend)
        return fn(self.stack, self._binv, slots, Xq, aux)

    def update(self, tenant_ids, Xk: jax.Array, yk: jax.Array,
               mask: Optional[jax.Array] = None) -> "GPBank":
        """Batched rank-k ingest: group g absorbs (Xk[g], yk[g]) into tenant
        ``tenant_ids[g]``'s factorization — vmapped rank-k Cholesky update,
        scattered back into the stack.  ``mask`` (G, k) zeroes padded rows
        (ragged ingest).  Tenants must be distinct within one call (the
        scatter would race); the router serializes duplicates into rounds."""
        Xk = jnp.asarray(Xk)
        yk = jnp.asarray(yk)
        if Xk.ndim != 3 or yk.shape != Xk.shape[:2]:
            raise ValueError(
                f"GPBank.update wants Xk (G, k, p) and yk (G, k); got "
                f"{Xk.shape} and {yk.shape}"
            )
        ids = list(tenant_ids)
        if len(set(ids)) != len(ids):
            raise ValueError(
                f"duplicate tenant in one update batch ({ids!r}): the "
                f"scattered writes would collide — split into rounds "
                f"(BankRouter.ingest does this)"
            )
        if len(ids) != Xk.shape[0]:
            raise ValueError(
                f"one tenant id per update group: got {len(ids)} ids for "
                f"{Xk.shape[0]} groups"
            )
        return self._update_at_slots(self._slots_for(ids), Xk, yk, mask)

    def _update_at_slots(self, slots: jax.Array, Xk: jax.Array,
                         yk: jax.Array,
                         mask: Optional[jax.Array] = None,
                         donate: bool = False) -> "GPBank":
        """Slot-addressed core of :meth:`update`.  Also the router's
        fixed-shape entry: a fully-masked group is an exact identity update
        (zeroed feature rows make every rank-1 sweep a no-op), so the
        router pads the group axis to a shape bucket with masked groups
        aimed at distinct unused slots — bounding the number of compiled
        update executables by log2(capacity) instead of one per distinct
        tenant-mix size.  Slots must be distinct (scatter would race).

        ``donate=True`` routes through the buffer-donating executable:
        the pre-update chol/u/b stack buffers are handed to XLA for reuse
        — THIS bank (and any older bank sharing those buffers) must not
        be touched afterwards.  Reserved for serving loops that own their
        bank exclusively (``BankRouter(donate_updates=True)``)."""
        G, k, p = Xk.shape
        fagp._check_p(self.spec, p)
        if mask is None:
            mask = jnp.ones((G, k), Xk.dtype)
        else:
            mask = jnp.asarray(mask).astype(Xk.dtype)
            if mask.shape != (G, k):
                raise ValueError(
                    f"mask must be (G, k) = {(G, k)}, got {mask.shape} — a "
                    f"broadcastable mask would silently drop rows from "
                    f"every group"
                )
        backend = fagp._check_backend_support(self.spec)
        if self.hypers is not None:
            Phi_g = _hetero_group_features(
                self.stack, Xk, self.hypers.eps[slots],
                self.hypers.rho[slots],
            )
            noise_g = self.hypers.noise[slots]
        else:
            aux = fagp._backend_aux(backend, self.stack.idx, self.spec)
            Phi_g = backend.features(
                Xk.reshape(G * k, p), self.spec, self.stack.idx, aux,
            ).reshape(G, k, -1)
            noise_g = jnp.broadcast_to(
                jnp.asarray(self.stack.params.noise, jnp.float32), (G,)
            )
        scatter = (_bank_update_scatter_donated if donate
                   else _bank_update_scatter)
        chol, u, b = scatter(
            self.stack.chol, self.stack.u, self.stack.b, self.stack.sqrtlam,
            noise_g, slots, Phi_g, yk, mask,
        )
        stack = dataclasses.replace(self.stack, chol=chol, u=u, b=b)
        new = dataclasses.replace(self, stack=stack)
        self._carry_binv_into(new, slots)
        return new

    # -- sliding-window forgetting (rank-k downdate + refit fallback) -------

    def downdate(self, tenant_ids, Xk: jax.Array, yk: jax.Array,
                 mask: Optional[jax.Array] = None):
        """Batched rank-k FORGET: group g removes previously-absorbed rows
        (Xk[g], yk[g]) from tenant ``tenant_ids[g]``'s factorization — the
        mirror of :meth:`update` via hyperbolic rank-1 downdate sweeps.
        ``mask`` (G, k) zeroes padded rows.  Tenants must be distinct
        within one call (the scatter would race).

        Returns ``(bank, ok)`` where ``ok`` is a host (G,) bool array:
        groups whose downdate lost positive definiteness kept their slot
        UNCHANGED (ok False) — re-factorize them from retained data with
        :meth:`refit_window`.  ``TieredBank.age`` drives both legs."""
        Xk = jnp.asarray(Xk)
        yk = jnp.asarray(yk)
        if Xk.ndim != 3 or yk.shape != Xk.shape[:2]:
            raise ValueError(
                f"GPBank.downdate wants Xk (G, k, p) and yk (G, k); got "
                f"{Xk.shape} and {yk.shape}"
            )
        ids = list(tenant_ids)
        if len(set(ids)) != len(ids):
            raise ValueError(
                f"duplicate tenant in one downdate batch ({ids!r}): the "
                f"scattered writes would collide — split into rounds"
            )
        if len(ids) != Xk.shape[0]:
            raise ValueError(
                f"one tenant id per downdate group: got {len(ids)} ids "
                f"for {Xk.shape[0]} groups"
            )
        return self._downdate_at_slots(self._slots_for(ids), Xk, yk, mask)

    def _downdate_at_slots(self, slots: jax.Array, Xk: jax.Array,
                           yk: jax.Array,
                           mask: Optional[jax.Array] = None):
        """Slot-addressed core of :meth:`downdate` — the fixed-shape entry
        for ``TieredBank.age``'s bucketed group axis (fully-masked padding
        groups on distinct slots are exact identity writes and report
        ok)."""
        G, k, p = Xk.shape
        fagp._check_p(self.spec, p)
        if mask is None:
            mask = jnp.ones((G, k), Xk.dtype)
        else:
            mask = jnp.asarray(mask).astype(Xk.dtype)
            if mask.shape != (G, k):
                raise ValueError(
                    f"mask must be (G, k) = {(G, k)}, got {mask.shape}"
                )
        backend = fagp._check_backend_support(self.spec)
        if self.hypers is not None:
            Phi_g = _hetero_group_features(
                self.stack, Xk, self.hypers.eps[slots],
                self.hypers.rho[slots],
            )
            noise_g = self.hypers.noise[slots]
        else:
            aux = fagp._backend_aux(backend, self.stack.idx, self.spec)
            Phi_g = backend.features(
                Xk.reshape(G * k, p), self.spec, self.stack.idx, aux,
            ).reshape(G, k, -1)
            noise_g = jnp.broadcast_to(
                jnp.asarray(self.stack.params.noise, jnp.float32), (G,)
            )
        chol, u, b, ok = _bank_downdate_scatter(
            self.stack.chol, self.stack.u, self.stack.b, self.stack.sqrtlam,
            noise_g, slots, Phi_g, yk, mask,
        )
        stack = dataclasses.replace(self.stack, chol=chol, u=u, b=b)
        new = dataclasses.replace(self, stack=stack)
        self._carry_binv_into(new, slots)
        return new, np.asarray(ok)

    def refit_window(self, tenant_ids, Xw: jax.Array, yw: jax.Array,
                     mask: Optional[jax.Array] = None) -> "GPBank":
        """Re-factorize ``tenant_ids`` from scratch on their RETAINED
        window data (Xw (G, W, p), yw (G, W), mask (G, W) for ragged
        windows) — each under its own slot's hyperparameters, per-slot
        eigenvalue rows rewritten.  The fallback for downdates that lost
        positive definiteness, and the exact semantic reference the
        downdate is gated against (<= 1e-5, benchmarks/tenant_churn.py)."""
        Xw = jnp.asarray(Xw)
        yw = jnp.asarray(yw)
        if Xw.ndim != 3 or yw.shape != Xw.shape[:2]:
            raise ValueError(
                f"GPBank.refit_window wants Xw (G, W, p) and yw (G, W); "
                f"got {Xw.shape} and {yw.shape}"
            )
        ids = list(tenant_ids)
        if len(set(ids)) != len(ids):
            raise ValueError(
                f"duplicate tenant in one refit batch ({ids!r})"
            )
        if len(ids) != Xw.shape[0]:
            raise ValueError(
                f"one tenant id per refit group: got {len(ids)} ids for "
                f"{Xw.shape[0]} groups"
            )
        return self._refit_at_slots(self._slots_for(ids), Xw, yw, mask)

    def _refit_at_slots(self, slots: jax.Array, Xw: jax.Array,
                        yw: jax.Array,
                        mask: Optional[jax.Array] = None) -> "GPBank":
        """Slot-addressed core of :meth:`refit_window` (fixed-shape entry;
        fully-masked padding groups leave their slots untouched)."""
        G, W, p = Xw.shape
        fagp._check_p(self.spec, p)
        if mask is None:
            mask = jnp.ones((G, W), Xw.dtype)
        else:
            mask = jnp.asarray(mask).astype(Xw.dtype)
            if mask.shape != (G, W):
                raise ValueError(
                    f"mask must be (G, W) = {(G, W)}, got {mask.shape}"
                )
        hyp = self._stacked_hypers()
        spec_r = self.spec.replace(
            block_rows=min(self.spec.block_rows, max(1, W))
        )
        st = self.stack
        chol, u, b, lam, sqrtlam = _bank_refit_scatter(
            st.chol, st.u, st.b, st.lam, st.sqrtlam, slots,
            Xw, yw, mask, hyp.eps[slots], hyp.rho[slots], hyp.noise[slots],
            spec_r, st.idx,
        )
        stack = dataclasses.replace(st, chol=chol, u=u, b=b, lam=lam,
                                    sqrtlam=sqrtlam)
        new = dataclasses.replace(self, stack=stack)
        self._carry_binv_into(new, slots)
        return new

    # -- membership churn (never recompiles: fixed capacity, traced slot) ---

    def insert(self, tenant: Hashable, source) -> "GPBank":
        """Add a tenant into a free slot.  ``source`` is a fitted ``GP`` /
        ``FAGPState`` sharing the bank's spec, or an ``(X, y)`` tuple to be
        fitted under it.  Raises when full or when the id is taken."""
        if tenant in self.slots:
            raise ValueError(f"tenant {tenant!r} already in the bank")
        free = np.flatnonzero(~self.active)
        if free.size == 0:
            raise ValueError(
                f"bank is full ({self.capacity} slots); evict a tenant or "
                f"rebuild with a larger capacity"
            )
        if isinstance(source, tuple):
            X, y = source
            st = fagp.fit(jnp.asarray(X), jnp.asarray(y), self.spec)
        else:
            st = source.state if isinstance(source, GP) else source
        if self.hypers is None:
            _check_bankable(st, self.spec, f"insert({tenant!r})")
        else:
            _check_bankable_hetero(st, self.spec, f"insert({tenant!r})")
        slot = int(free[0])
        chol, u, b, lam, sqrtlam = _write_slot(
            self.stack.chol, self.stack.u, self.stack.b, self.stack.lam,
            self.stack.sqrtlam, jnp.int32(slot), st.chol, st.u, st.b,
            st.lam, st.sqrtlam,
        )
        stack = dataclasses.replace(self.stack, chol=chol, u=u, b=b,
                                    lam=lam, sqrtlam=sqrtlam)
        hypers = self.hypers
        if hypers is not None:
            hp = st.spec  # guaranteed by _check_bankable_hetero
            hypers = SEKernelParams(
                eps=hypers.eps.at[slot].set(hp.eps),
                rho=hypers.rho.at[slot].set(hp.rho),
                noise=hypers.noise.at[slot].set(hp.noise),
            )
        active = self.active.copy()
        active[slot] = True
        slots = dict(self.slots)
        slots[tenant] = slot
        new = dataclasses.replace(self, stack=stack, active=active,
                                  slots=slots, hypers=hypers)
        self._carry_binv_into(new, jnp.int32(slot))
        return new

    def evict(self, tenant: Hashable) -> "GPBank":
        """Remove a tenant; its slot is reset to the prior state (under the
        bank spec's own hyperparameters) and becomes reusable by the next
        :meth:`insert` — same executable either way."""
        slot = self.slot_of(tenant)
        loglam = get_expansion(self.spec.expansion).log_eigenvalues(
            self.stack.idx, self.spec
        )
        prior = _prior_leaves(loglam, 1)
        chol, u, b, lam, sqrtlam = _write_slot(
            self.stack.chol, self.stack.u, self.stack.b, self.stack.lam,
            self.stack.sqrtlam, jnp.int32(slot), prior["chol"][0],
            prior["u"][0], prior["b"][0], prior["lam"][0],
            prior["sqrtlam"][0],
        )
        stack = dataclasses.replace(self.stack, chol=chol, u=u, b=b,
                                    lam=lam, sqrtlam=sqrtlam)
        hypers = self.hypers
        if hypers is not None:
            sp = self.spec
            hypers = SEKernelParams(
                eps=hypers.eps.at[slot].set(sp.eps),
                rho=hypers.rho.at[slot].set(sp.rho),
                noise=hypers.noise.at[slot].set(
                    jnp.asarray(sp.noise, jnp.float32)
                ),
            )
        active = self.active.copy()
        active[slot] = False
        slots = {t: s for t, s in self.slots.items() if t != tenant}
        new = dataclasses.replace(self, stack=stack, active=active,
                                  slots=slots, hypers=hypers)
        self._carry_binv_into(new, jnp.int32(slot))
        return new

    # -- fleet-scale hyperparameter optimization ----------------------------

    def optimize(
        self,
        Xb: jax.Array,
        yb: jax.Array,
        *,
        tenant_ids: Optional[Sequence[Hashable]] = None,
        mask: Optional[jax.Array] = None,
        restarts: int = 4,
        steps: int = 100,
        lr: float = 5e-2,
        tol: Optional[float] = None,
        jitter: float = 0.3,
        seed: int = 0,
        callback=None,
        metrics=None,
        tracer=None,
    ) -> "GPBank":
        """Learn per-tenant hyperparameters for the whole fleet in one
        batched run, then refit the winners back into the stacked state.

        Runs the (B tenants x R restarts) lane engine
        (``repro.optim.gp_hyperopt.optimize_fleet``): every restart of every
        tenant is stepped by ONE compiled AdamW step per iteration — a
        Python loop of per-tenant ``GP.optimize`` runs pays per-step
        dispatch B times and lands on EXACTLY the same hyperparameters (the
        per-tenant lane math is bit-identical by construction; the <= 1e-5
        parity gate is asserted in benchmarks/gp_hyperopt.py).

        Xb (B, N, p) / yb (B, N) carry each tenant's training data in the
        row order of ``tenant_ids`` (default: every active tenant in
        insertion order); ``mask`` (B, N) expresses ragged per-tenant N.
        ``restarts`` log-space jittered inits per tenant, best selected by
        final NLML; ``tol`` freezes converged lanes (no recompiles).

        Returns a new HETEROGENEOUS bank: the optimized slots hold
        factorizations under their own learned (eps, rho, noise) — per-slot
        eigenvalue rows were already stacked — and serving gathers each
        query row's features under its slot's hyperparameters.  A bank that
        is already heterogeneous re-optimizes starting from each tenant's
        current values.

        ``metrics`` / ``tracer`` (``repro.obs``) forward to
        ``optimize_fleet``, which reports per-round progress through the
        existing callback contract (composed with any user ``callback``).
        """
        from repro.optim.gp_hyperopt import optimize_fleet

        Xb = jnp.asarray(Xb)
        yb = jnp.asarray(yb)
        if Xb.ndim != 3 or yb.ndim != 2 or yb.shape != Xb.shape[:2]:
            raise ValueError(
                f"GPBank.optimize wants Xb (B, N, p) and yb (B, N); got "
                f"{Xb.shape} and {yb.shape}"
            )
        B, N, p = Xb.shape
        fagp._check_p(self.spec, p)
        if tenant_ids is None:
            tenant_ids = self.tenants
        ids = list(tenant_ids)
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate tenant in optimize batch ({ids!r})")
        if len(ids) != B:
            raise ValueError(
                f"one tenant id per data row: got {len(ids)} ids for {B} "
                f"rows"
            )
        slots = self._slots_for(ids)
        if mask is not None:
            mask = jnp.asarray(mask).astype(Xb.dtype)
            if mask.shape != (B, N):
                raise ValueError(
                    f"mask must be (B, N) = {(B, N)}, got {mask.shape}"
                )
        init = None
        if self.hypers is not None:
            init = {
                "eps": self.hypers.eps[slots],
                "rho": self.hypers.rho[slots],
                "noise": self.hypers.noise[slots],
            }
        res = optimize_fleet(
            Xb, yb, self.spec, mask=mask, restarts=restarts, steps=steps,
            lr=lr, tol=tol, jitter=jitter, seed=seed, init=init,
            callback=callback, metrics=metrics, tracer=tracer,
        )
        maskb = (jnp.ones((B, N), Xb.dtype) if mask is None else mask)
        spec_r = self.spec.replace(
            block_rows=min(self.spec.block_rows, max(1, N))
        )
        lam, sqrtlam, chol, u, b = _bank_hetero_refit(
            Xb, yb, maskb, res.eps, res.rho, res.noise, spec_r,
            self.stack.idx,
        )
        st = self.stack
        stack = dataclasses.replace(
            st,
            lam=st.lam.at[slots].set(lam),
            sqrtlam=st.sqrtlam.at[slots].set(sqrtlam),
            chol=st.chol.at[slots].set(chol),
            u=st.u.at[slots].set(u),
            b=st.b.at[slots].set(b),
        )
        hyp = self._stacked_hypers()
        hyp = SEKernelParams(
            eps=hyp.eps.at[slots].set(res.eps),
            rho=hyp.rho.at[slots].set(res.rho),
            noise=hyp.noise.at[slots].set(res.noise),
        )
        new = dataclasses.replace(self, stack=stack, hypers=hyp)
        self._carry_binv_into(new, slots)
        return new
