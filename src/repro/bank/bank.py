"""GPBank — a fleet of independent GP sessions served as one batched model.

The production analogue of the paper's "cheap posterior on an accelerator"
claim is not one GP but *fleets* of small independent GPs — one per sensor,
user, task, or region — served concurrently.  A Python loop of single-model
calls pays per-call dispatch, per-call kernel launch, and per-call H2D
latency B times; a bank pays them once.

``GPBank`` keeps B fitted sessions resident on the device as ONE stacked
:class:`~repro.core.fagp.FAGPState`:

* leading bank axis on ``chol`` (C, M, M), ``u`` (C, M), ``b`` (C, M),
  ``lam``/``sqrtlam`` (C, M) — the per-tenant factorizations;
* one shared static :class:`~repro.core.fagp.GPSpec` (index set, Mercer
  depth n, backend, hyperparameters) — so every tenant shares one feature
  map and one compiled executable per entry point.

Capacity is fixed at construction: the stack always holds ``capacity``
slots, of which some are *active* (hold a fitted tenant) and the rest hold
the prior state (chol = I, u = b = 0 — a valid "no data yet" posterior).
Membership churn (:meth:`insert` / :meth:`evict`) writes slot leaves with a
*traced* slot index through module-level jitted helpers, so adding or
removing tenants NEVER recompiles the serving executable — the executables
are keyed only on the stack's (capacity, M) shapes.

Entry points (all single compiled calls over the whole fleet):

* :meth:`GPBank.fit`      — B datasets -> B factorizations: one batched
  moment accumulation (``FitBackend.bank_moments``: vmapped scan on the jnp
  backend; a bank grid axis in the streaming fused Pallas kernel on the
  pallas backend) + one batched Cholesky.  Ragged per-tenant N is expressed
  with per-slot row masks on a fixed (B, N, p) stack.
* :meth:`GPBank.mean_var` — a *mixed-tenant* query batch: row q is answered
  by tenant ``tenant_ids[q]``'s posterior, via gather from the stack
  (``FitBackend.bank_mean_var``).
* :meth:`GPBank.update`   — batched rank-k Cholesky ingest for several
  tenants at once (vmapped ``_update_arrays``), scattered back into the
  stack.

``bank.router.BankRouter`` turns per-tenant query/observation queues into
the padded fixed-shape batches these entry points want.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Hashable, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fagp
from repro.core.expansions import get_expansion
from repro.core.fagp import FAGPState, GPSpec
from repro.core.gp import GP

__all__ = ["GPBank"]


# ---------------------------------------------------------------------------
# Module-level jitted kernels.  Deliberately NOT methods: their jit caches
# are keyed on (capacity, M, Q, k) shapes only, so membership churn and
# arbitrary tenant mixes reuse one executable — pinned by
# tests/test_gp_bank.py via _cache_size().
# ---------------------------------------------------------------------------


@jax.jit
def _bank_solve(G, b, loglam, sig2):
    """Batched fit epilogue: raw moments (C, M, M)/(C, M) -> stacked
    (lam, sqrtlam, chol, u).  The scaled system keeps its one home
    (fagp._assemble_scaled_system), vmapped over slots; the Cholesky and the
    mean-weight solves batch natively."""
    Bm, sqrtlam = jax.vmap(
        lambda Gs: fagp._assemble_scaled_system(Gs, loglam, sig2)
    )(G)
    chol = jnp.linalg.cholesky(Bm)
    u = jax.vmap(
        lambda c, d, bs: fagp._solve_mean_weights(c, d, bs, sig2)
    )(chol, sqrtlam, b)
    lam = jnp.broadcast_to(jnp.exp(loglam), sqrtlam.shape)
    return lam, sqrtlam, chol, u


@jax.jit
def _bank_update_scatter(chol_s, u_s, b_s, sqrtlam_s, noise, slots,
                         Phi_g, y_g, mask_g):
    """Gather slot states, apply the rank-k update per group row, scatter
    back.  Padded rows (mask 0) zero their feature row, which makes the
    rank-1 sweep an identity for them — ragged ingest is a masking detail,
    not a shape change.  A *fully*-masked group (the router's group-axis
    shape padding) writes its gathered values back verbatim: the identity
    sweep is exact only up to sqrt rounding, and an untouched tenant must
    not drift by ulps per serving round."""
    Phi_g = Phi_g * mask_g[..., None]
    y_g = y_g * mask_g
    ch, bb, uu = jax.vmap(
        lambda c, bm, d, P, y: fagp._update_arrays(c, bm, d, noise, P, y)
    )(chol_s[slots], b_s[slots], sqrtlam_s[slots], Phi_g, y_g)
    real = jnp.max(mask_g, axis=1) > 0                  # (G,) any live row?
    ch = jnp.where(real[:, None, None], ch, chol_s[slots])
    uu = jnp.where(real[:, None], uu, u_s[slots])
    bb = jnp.where(real[:, None], bb, b_s[slots])
    return (chol_s.at[slots].set(ch), u_s.at[slots].set(uu),
            b_s.at[slots].set(bb))


@jax.jit
def _write_slot(chol_s, u_s, b_s, slot, chol, u, b):
    """Write one tenant's leaves at a *traced* slot index: insert/evict of
    any slot hit the same executable."""
    return (chol_s.at[slot].set(chol), u_s.at[slot].set(u),
            b_s.at[slot].set(b))


def _fallback_bank_moments(backend):
    """vmap of the single-model moments for backends that do not declare a
    native bank_moments."""
    def f(Xb, yb, spec, idx, aux, block_rows, maskb):
        one = lambda X, y, m: backend.moments(
            X, y, spec, idx, aux, block_rows, m
        )
        return jax.vmap(one)(Xb, yb, maskb)
    return f


def _fallback_bank_mean_var(backend):
    """Gathered posterior on top of the backend's feature map, for backends
    that do not declare a native bank_mean_var."""
    return fagp._gathered_bank_mean_var(backend.features)


def _bank_spec(spec: GPSpec) -> GPSpec:
    """Normalize a spec for bank use: banks are a serving structure and
    never store per-tenant training features, so ``store_train`` is
    downgraded — otherwise every unstacked ``state(t)`` would carry a spec
    claiming stored features while holding ``Phi=None``, and paper-mode
    prediction's 'refit with store_train=True' guidance would loop."""
    return spec.replace(store_train=False) if spec.store_train else spec


def _prior_leaves(loglam: jax.Array, count: int) -> dict:
    """The per-slot leaves of the 'no data yet' state — chol = I,
    u = b = 0, spec eigenvalues — a valid prior posterior (zero mean,
    prior variance).  The ONE definition of an empty slot: ``create``
    builds whole banks from it and ``fit`` pads reserved capacity with it,
    so the fully-masked-slot == fresh-slot invariant cannot drift."""
    M = loglam.shape[0]
    return {
        "lam": jnp.broadcast_to(jnp.exp(loglam), (count, M)),
        "sqrtlam": jnp.broadcast_to(jnp.exp(0.5 * loglam), (count, M)),
        "chol": jnp.broadcast_to(jnp.eye(M, dtype=jnp.float32),
                                 (count, M, M)),
        "u": jnp.zeros((count, M), jnp.float32),
        "b": jnp.zeros((count, M), jnp.float32),
    }


def _check_bankable(state: FAGPState, spec: GPSpec, who: str) -> None:
    """A state can join a bank iff it was factorized under the bank's shared
    spec (structure AND hyperparameters, including any RFF spectral draws)
    and is single-output with the raw moment vector present."""
    fagp._check_spec_regenerates_idx(state, spec)
    try:
        fagp._check_hypers_match(state, spec, who)
    except ValueError as e:
        raise ValueError(
            f"{e}; a bank shares one feature map and one eigenvalue "
            f"scaling across all tenants — refit the tenant under the "
            f"bank spec"
        ) from None
    if state.u.ndim != 1:
        raise ValueError(
            f"{who}: multi-output states (T={state.n_tasks}) cannot join a "
            f"bank; banks batch over tenants, one task each"
        )
    if state.b is None:
        raise ValueError(
            f"{who}: state lacks the raw moment vector b (produced by a "
            f"pre-PR-1 fit path); refit before inserting"
        )


@dataclasses.dataclass(frozen=True)
class GPBank:
    """A fixed-capacity bank of independent GP sessions (see module doc).

    Construct with :meth:`fit`, :meth:`create`, or :meth:`from_states`; the
    default constructor is internal.  Instances are immutable — mutating
    methods return a new ``GPBank`` sharing the device stack buffers that
    did not change.

    stack:   stacked FAGPState — bank axis on chol/u/b/lam/sqrtlam,
             shared idx/params/spec.
    active:  (capacity,) host-side bool mask of occupied slots.
    slots:   tenant id -> slot index (host-side; insertion order preserved).
    """

    stack: FAGPState
    active: np.ndarray
    slots: Mapping[Hashable, int]

    # -- constructors -------------------------------------------------------

    @classmethod
    def create(cls, spec: GPSpec, capacity: int) -> "GPBank":
        """An empty bank: every slot holds the prior state (chol = I,
        u = b = 0 — zero mean, prior variance)."""
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        spec = _bank_spec(spec)
        fagp._check_backend_support(spec)
        idx = jnp.asarray(spec.indices(spec.p))
        loglam = get_expansion(spec.expansion).log_eigenvalues(idx, spec)
        stack = FAGPState(
            idx=idx, params=spec.params, Phi=None, y=None, spec=spec,
            **_prior_leaves(loglam, capacity),
        )
        return cls(stack=stack, active=np.zeros(capacity, bool), slots={})

    @classmethod
    def fit(
        cls,
        Xb: jax.Array,
        yb: jax.Array,
        spec: GPSpec,
        *,
        mask: Optional[jax.Array] = None,
        tenant_ids: Optional[Sequence[Hashable]] = None,
        capacity: Optional[int] = None,
    ) -> "GPBank":
        """Fit B independent GPs in one batched pass.

        Xb: (B, N, p) stacked inputs; yb: (B, N) stacked targets;
        mask: (B, N) row validity — tenants with fewer than N real rows pad
        to N and mask the padding (ragged N).  ``tenant_ids`` default to
        ``range(B)``; ``capacity`` (>= B) reserves extra prior slots for
        later :meth:`insert` without reshaping the stack.
        """
        Xb = jnp.asarray(Xb)
        yb = jnp.asarray(yb)
        if Xb.ndim != 3 or yb.ndim != 2 or yb.shape != Xb.shape[:2]:
            raise ValueError(
                f"GPBank.fit wants Xb (B, N, p) and yb (B, N); got "
                f"{Xb.shape} and {yb.shape}"
            )
        B, N, p = Xb.shape
        spec = _bank_spec(spec)
        fagp._check_p(spec, p)
        cap = B if capacity is None else int(capacity)
        if cap < B:
            raise ValueError(f"capacity {cap} < number of tenants {B}")
        if tenant_ids is None:
            tenant_ids = range(B)
        tenant_ids = list(tenant_ids)
        if len(tenant_ids) != B or len(set(tenant_ids)) != B:
            raise ValueError(
                f"tenant_ids must be {B} distinct ids, got {tenant_ids!r}"
            )
        if mask is None:
            mask = jnp.ones((B, N), Xb.dtype)
        else:
            mask = jnp.asarray(mask).astype(Xb.dtype)
            if mask.shape != (B, N):
                raise ValueError(
                    f"mask must be (B, N) = {(B, N)}, got {mask.shape}"
                )
        backend = fagp._check_backend_support(spec)
        idx_np = spec.indices(p)
        idx = jnp.asarray(idx_np)
        aux = backend.prepare(idx_np, spec)
        moments = backend.bank_moments or _fallback_bank_moments(backend)
        # small tenants: never let a scan-based moments hook pad each
        # slot's few rows up to the default serving block
        block_rows = min(spec.block_rows, max(1, N))
        G, b = moments(Xb, yb, spec, idx, aux, block_rows, mask)
        loglam = get_expansion(spec.expansion).log_eigenvalues(idx, spec)
        lam, sqrtlam, chol, u = _bank_solve(G, b, loglam, spec.noise**2)
        if cap > B:
            # reserved slots get the prior leaves directly — never pay the
            # O(N M^2) moment pass or the M^3 Cholesky for an empty slot
            prior = _prior_leaves(loglam, cap - B)
            lam = jnp.concatenate([lam, prior["lam"]])
            sqrtlam = jnp.concatenate([sqrtlam, prior["sqrtlam"]])
            chol = jnp.concatenate([chol, prior["chol"]])
            u = jnp.concatenate([u, prior["u"]])
            b = jnp.concatenate([b, prior["b"]])
        stack = FAGPState(
            idx=idx, lam=lam, sqrtlam=sqrtlam, chol=chol, u=u,
            params=spec.params, Phi=None, y=None, b=b, spec=spec,
        )
        active = np.zeros(cap, bool)
        active[:B] = True
        return cls(stack=stack, active=active,
                   slots={t: s for s, t in enumerate(tenant_ids)})

    @classmethod
    def from_states(
        cls,
        states: Mapping[Hashable, Any],
        *,
        capacity: Optional[int] = None,
    ) -> "GPBank":
        """Stack already-fitted sessions (``GP`` or ``FAGPState``) into a
        bank.  All must share one structural spec and one hyperparameter
        set (the bank's shared feature map)."""
        if not states:
            raise ValueError("from_states needs at least one state")
        items = [
            (t, s.state if isinstance(s, GP) else s) for t, s in states.items()
        ]
        spec = items[0][1].spec
        if spec is None:
            raise ValueError(
                "from_states: first state has no baked GPSpec; attach one "
                "with state.with_spec(spec)"
            )
        spec = _bank_spec(spec)
        for t, st in items:
            _check_bankable(st, spec, f"from_states(tenant {t!r})")
        B = len(items)
        cap = B if capacity is None else int(capacity)
        if cap < B:
            raise ValueError(f"capacity {cap} < number of states {B}")
        bank = cls.create(spec, cap)
        stacked = {
            f: jnp.stack([getattr(st, f) for _, st in items])
            for f in ("lam", "sqrtlam", "chol", "u", "b")
        }
        pad = {
            f: jnp.concatenate([stacked[f], getattr(bank.stack, f)[B:]])
            for f in stacked
        }
        stack = dataclasses.replace(bank.stack, **pad)
        active = np.zeros(cap, bool)
        active[:B] = True
        return cls(stack=stack, active=active,
                   slots={t: s for s, (t, _) in enumerate(items)})

    # -- introspection ------------------------------------------------------

    @property
    def spec(self) -> GPSpec:
        return self.stack.spec

    @property
    def capacity(self) -> int:
        return self.stack.u.shape[0]

    @property
    def n_features(self) -> int:
        return self.stack.idx.shape[0]

    @property
    def tenants(self) -> list:
        return list(self.slots)

    def __len__(self) -> int:
        return len(self.slots)

    def __contains__(self, tenant: Hashable) -> bool:
        return tenant in self.slots

    def slot_of(self, tenant: Hashable) -> int:
        try:
            return self.slots[tenant]
        except KeyError:
            raise KeyError(
                f"tenant {tenant!r} is not in this bank (tenants: "
                f"{self.tenants!r})"
            ) from None

    def state(self, tenant: Hashable) -> FAGPState:
        """The tenant's session, unstacked — a normal single-model
        FAGPState usable with every ``fagp``/``GP`` entry point."""
        s = self.slot_of(tenant)
        return dataclasses.replace(
            self.stack,
            lam=self.stack.lam[s], sqrtlam=self.stack.sqrtlam[s],
            chol=self.stack.chol[s], u=self.stack.u[s], b=self.stack.b[s],
        )

    def states(self) -> dict:
        """All tenants' sessions, unstacked (tenant -> FAGPState)."""
        return {t: self.state(t) for t in self.slots}

    @property
    def _binv(self) -> jax.Array:
        """Per-slot B^{-1} serving cache (C, M, M).  Lazily computed and
        memoized on the instance: GPBank is immutable and every mutating
        method returns a *new* bank, so the cache can never go stale.
        Mutations that know which slots they touched carry the cache
        forward with only those rows refreshed (``_carry_binv_into``)."""
        cached = self.__dict__.get("_binv_cache")
        if cached is None:
            cached = fagp._bank_binv(self.stack.chol)
            object.__setattr__(self, "_binv_cache", cached)
        return cached

    def _carry_binv_into(self, new: "GPBank", slots: jax.Array) -> None:
        """Incremental cache maintenance: a mutation touched only ``slots``
        (possibly one), so if this bank already paid for the full cache,
        refresh those rows and hand the rest forward instead of making the
        next query recompute B^{-1} for the whole capacity."""
        cached = self.__dict__.get("_binv_cache")
        if cached is not None:
            slots = jnp.atleast_1d(slots)
            rows = fagp._bank_binv(new.stack.chol[slots])
            object.__setattr__(
                new, "_binv_cache", cached.at[slots].set(rows)
            )

    def _slots_for(self, tenant_ids) -> jax.Array:
        if isinstance(tenant_ids, (str, bytes)) or not hasattr(
            tenant_ids, "__iter__"
        ):
            raise TypeError(
                "tenant_ids must be a sequence of tenant ids, one per row "
                f"(got a scalar {tenant_ids!r}); for a single-tenant batch "
                "pass [tenant] * len(Xq)"
            )
        return jnp.asarray(
            np.fromiter(
                (self.slot_of(t) for t in tenant_ids), np.int32,
            )
        )

    # -- the batched pipeline ----------------------------------------------

    def mean_var(self, tenant_ids, Xq: jax.Array):
        """Posterior mean and marginal variance for a MIXED-tenant query
        batch: row q of ``Xq`` (Q, p) is answered by ``tenant_ids[q]``'s
        posterior.  One compiled call for the whole fleet."""
        Xq = jnp.asarray(Xq)
        slots = self._slots_for(tenant_ids)
        if slots.shape[0] != Xq.shape[0]:
            raise ValueError(
                f"one tenant id per query row: got {slots.shape[0]} ids "
                f"for {Xq.shape[0]} rows"
            )
        backend = fagp._check_backend_support(self.spec)
        aux = fagp._backend_aux(backend, self.stack.idx, self.spec)
        fn = backend.bank_mean_var or _fallback_bank_mean_var(backend)
        return fn(self.stack, self._binv, slots, Xq, aux)

    def update(self, tenant_ids, Xk: jax.Array, yk: jax.Array,
               mask: Optional[jax.Array] = None) -> "GPBank":
        """Batched rank-k ingest: group g absorbs (Xk[g], yk[g]) into tenant
        ``tenant_ids[g]``'s factorization — vmapped rank-k Cholesky update,
        scattered back into the stack.  ``mask`` (G, k) zeroes padded rows
        (ragged ingest).  Tenants must be distinct within one call (the
        scatter would race); the router serializes duplicates into rounds."""
        Xk = jnp.asarray(Xk)
        yk = jnp.asarray(yk)
        if Xk.ndim != 3 or yk.shape != Xk.shape[:2]:
            raise ValueError(
                f"GPBank.update wants Xk (G, k, p) and yk (G, k); got "
                f"{Xk.shape} and {yk.shape}"
            )
        ids = list(tenant_ids)
        if len(set(ids)) != len(ids):
            raise ValueError(
                f"duplicate tenant in one update batch ({ids!r}): the "
                f"scattered writes would collide — split into rounds "
                f"(BankRouter.ingest does this)"
            )
        if len(ids) != Xk.shape[0]:
            raise ValueError(
                f"one tenant id per update group: got {len(ids)} ids for "
                f"{Xk.shape[0]} groups"
            )
        return self._update_at_slots(self._slots_for(ids), Xk, yk, mask)

    def _update_at_slots(self, slots: jax.Array, Xk: jax.Array,
                         yk: jax.Array,
                         mask: Optional[jax.Array] = None) -> "GPBank":
        """Slot-addressed core of :meth:`update`.  Also the router's
        fixed-shape entry: a fully-masked group is an exact identity update
        (zeroed feature rows make every rank-1 sweep a no-op), so the
        router pads the group axis to a shape bucket with masked groups
        aimed at distinct unused slots — bounding the number of compiled
        update executables by log2(capacity) instead of one per distinct
        tenant-mix size.  Slots must be distinct (scatter would race)."""
        G, k, p = Xk.shape
        fagp._check_p(self.spec, p)
        if mask is None:
            mask = jnp.ones((G, k), Xk.dtype)
        else:
            mask = jnp.asarray(mask).astype(Xk.dtype)
            if mask.shape != (G, k):
                raise ValueError(
                    f"mask must be (G, k) = {(G, k)}, got {mask.shape} — a "
                    f"broadcastable mask would silently drop rows from "
                    f"every group"
                )
        backend = fagp._check_backend_support(self.spec)
        aux = fagp._backend_aux(backend, self.stack.idx, self.spec)
        Phi_g = backend.features(
            Xk.reshape(G * k, p), self.spec, self.stack.idx, aux,
        ).reshape(G, k, -1)
        chol, u, b = _bank_update_scatter(
            self.stack.chol, self.stack.u, self.stack.b, self.stack.sqrtlam,
            self.stack.params.noise, slots, Phi_g, yk, mask,
        )
        stack = dataclasses.replace(self.stack, chol=chol, u=u, b=b)
        new = dataclasses.replace(self, stack=stack)
        self._carry_binv_into(new, slots)
        return new

    # -- membership churn (never recompiles: fixed capacity, traced slot) ---

    def insert(self, tenant: Hashable, source) -> "GPBank":
        """Add a tenant into a free slot.  ``source`` is a fitted ``GP`` /
        ``FAGPState`` sharing the bank's spec, or an ``(X, y)`` tuple to be
        fitted under it.  Raises when full or when the id is taken."""
        if tenant in self.slots:
            raise ValueError(f"tenant {tenant!r} already in the bank")
        free = np.flatnonzero(~self.active)
        if free.size == 0:
            raise ValueError(
                f"bank is full ({self.capacity} slots); evict a tenant or "
                f"rebuild with a larger capacity"
            )
        if isinstance(source, tuple):
            X, y = source
            st = fagp.fit(jnp.asarray(X), jnp.asarray(y), self.spec)
        else:
            st = source.state if isinstance(source, GP) else source
        _check_bankable(st, self.spec, f"insert({tenant!r})")
        slot = int(free[0])
        chol, u, b = _write_slot(
            self.stack.chol, self.stack.u, self.stack.b,
            jnp.int32(slot), st.chol, st.u, st.b,
        )
        stack = dataclasses.replace(self.stack, chol=chol, u=u, b=b)
        active = self.active.copy()
        active[slot] = True
        slots = dict(self.slots)
        slots[tenant] = slot
        new = dataclasses.replace(self, stack=stack, active=active,
                                  slots=slots)
        self._carry_binv_into(new, jnp.int32(slot))
        return new

    def evict(self, tenant: Hashable) -> "GPBank":
        """Remove a tenant; its slot is reset to the prior state and becomes
        reusable by the next :meth:`insert` — same executable either way."""
        slot = self.slot_of(tenant)
        M = self.n_features
        chol, u, b = _write_slot(
            self.stack.chol, self.stack.u, self.stack.b,
            jnp.int32(slot), jnp.eye(M, dtype=jnp.float32),
            jnp.zeros((M,), jnp.float32), jnp.zeros((M,), jnp.float32),
        )
        stack = dataclasses.replace(self.stack, chol=chol, u=u, b=b)
        active = self.active.copy()
        active[slot] = False
        slots = {t: s for t, s in self.slots.items() if t != tenant}
        new = dataclasses.replace(self, stack=stack, active=active,
                                  slots=slots)
        self._carry_binv_into(new, jnp.int32(slot))
        return new
