"""TieredBank — elastic tenant lifecycle in front of a fixed-capacity bank.

A :class:`~repro.bank.GPBank` is a *cache*: ``capacity`` device-resident
slots, full stop.  The ROADMAP north-star (millions of tenants) needs an
elastic *store*: the working set stays hot on the device, everything else
lives as versioned checkpoints on disk, and membership churn moves O(M^2)
summary statistics — never raw training rows — between the tiers (the
compact-summary structure of PAPERS.md, arXiv 1305.5826).

``TieredBank`` fronts a ``GPBank`` with exactly that:

* **Cold tier** — per-tenant versioned checkpoints through
  :mod:`repro.checkpoint.gpstate`: each save lands as
  ``<cold_dir>/<tenant>/step_<version>`` with a manifest carrying the
  GPSpec structure + expansion + omega hash; restoring into a bank with a
  mismatched spec raises exactly like ``with_spec`` does.  Heterogeneous
  hyperparameters ride along (the unstacked state's spec carries its
  slot's own eps/rho/noise), so a tenant that was optimized, evicted and
  warm-restored serves under the hyperparameters it learned.
* **Hot/cold paging** — :meth:`mean_var` / :meth:`update` on a cold
  tenant warm-restore it through the existing recompile-free
  ``GPBank.insert`` (jitted slot write with a *traced* index), evicting
  the least-recently-used hot tenant to the cold tier when the bank is
  full.  Arbitrary paging churn compiles ZERO new executables — pinned by
  tests/test_lifecycle.py with the same ``_cache_size`` mechanism as
  tests/test_gp_bank.py.
* **Sliding-window forgetting** — :meth:`age` removes each tenant's rows
  beyond the newest ``window`` via the batched rank-k Cholesky *downdate*
  (``GPBank.downdate``, the mirror of PR 1's rank-k update), falling back
  to a masked refit from the retained window (``GPBank.refit_window``)
  for any tenant whose downdate lost positive definiteness.  Both legs
  run on power-of-two shape buckets (group axis padded with fully-masked
  identity groups), so forgetting churn is also compile-stable.
  ``serve_fleet`` wires this to ``BankRouter``'s staleness counters:
  drifted tenants get aged, then re-optimized.

The bank reference is owned here between external swaps: a serving stack
that mutates the bank elsewhere (``BankRouter.ingest`` /
``reoptimize``) hands the new bank back via :meth:`adopt` —
``FleetEngine`` does this automatically when constructed with
``tiered=``.
"""
from __future__ import annotations

import dataclasses
import urllib.parse
from collections import OrderedDict
from pathlib import Path
from typing import Hashable, Iterable, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import gpstate
from repro.core import fagp

from ..obs import metrics as obs_metrics
from ..obs.trace import NULL_TRACER
from .bank import GPBank

__all__ = ["TieredBank"]


def _tenant_key(tenant: Hashable) -> str:
    """Filesystem-safe, reversible directory name for a tenant id.  The
    cold tier must enumerate its tenants from disk alone, so ids are
    restricted to the round-trippable types (int, str)."""
    if isinstance(tenant, bool):
        raise TypeError("bool tenant ids cannot live in a cold tier")
    if isinstance(tenant, (int, np.integer)):
        return f"i{int(tenant)}"
    if isinstance(tenant, str):
        return "s" + urllib.parse.quote(tenant, safe="")
    raise TypeError(
        f"cold-tier tenant ids must be int or str (got "
        f"{type(tenant).__name__}): the tier is enumerated from directory "
        f"names, which must round-trip"
    )


def _tenant_from_key(key: str) -> Hashable:
    if key.startswith("i"):
        return int(key[1:])
    if key.startswith("s"):
        return urllib.parse.unquote(key[1:])
    raise ValueError(f"not a tenant key: {key!r}")


def _pow2_bucket(n: int, cap: int) -> int:
    return min(cap, 1 << max(0, n - 1).bit_length())


class TieredBank:
    """See module docstring.  Not thread-safe; one instance per serving
    loop, and between :meth:`adopt` calls it assumes it is the only
    writer of its bank.

    bank:     the hot tier (any constructed ``GPBank``).
    cold_dir: root of the cold tier (created if missing).  A directory
              that already holds checkpoints contributes its tenants as
              cold immediately — the tier is durable across processes.
    window:   sliding-window length W; 0 disables forgetting.  With
              W > 0, rows ingested through :meth:`update` /
              :meth:`record_rows` are tracked per tenant (host-side), and
              :meth:`age` downdates everything older than the newest W
              rows.  Window buffers ride cold checkpoints as ``extra``
              arrays, so paging preserves forgetting state.
    metrics:  a :class:`repro.obs.MetricsRegistry`; the tier registers a
              scrape-time collector mirroring its ``stats`` dict into
              ``lifecycle_*_total`` counters plus hot/cold tenant-count
              gauges.  The ``stats`` dict stays the canonical in-process
              surface.  Default: no-op.
    tracer:   a :class:`repro.obs.Tracer`; checkpoint save/restore,
              evict-to-cold, and age/downdate/refit emit spans.
              Default: no-op.
    """

    def __init__(self, bank: GPBank, cold_dir, *, window: int = 0,
                 metrics: Optional[obs_metrics.MetricsRegistry] = None,
                 tracer=None):
        if window < 0:
            raise ValueError(f"window must be >= 0, got {window}")
        self._bank = bank
        self.cold_dir = Path(cold_dir)
        self.cold_dir.mkdir(parents=True, exist_ok=True)
        self.window = int(window)
        self._lru: OrderedDict = OrderedDict((t, None) for t in bank.slots)
        self._cold: set = set()
        for p in self.cold_dir.iterdir():
            if p.is_dir() and gpstate.latest_version(p) is not None:
                t = _tenant_from_key(p.name)
                if t not in bank.slots:
                    self._cold.add(t)
        # per-tenant absorbed rows, oldest first: [(x (p,), y), ...] —
        # the forgetting bookkeeping (window > 0 only)
        self._rows: dict = {}
        # lifecycle counters (observability + benchmark surface)
        self.stats = {
            "cold_saves": 0, "warm_restores": 0, "evictions": 0,
            "downdated_rows": 0, "refit_fallbacks": 0,
        }
        self.registry = obs_metrics.NULL if metrics is None else metrics
        self.tracer = NULL_TRACER if tracer is None else tracer
        self._published: dict = {}
        if not isinstance(self.registry, obs_metrics.NullRegistry):
            self.registry.add_collector(self._publish)

    def _publish(self) -> None:
        """Registry collector: mirror the ``stats`` dict into
        ``lifecycle_*_total`` counters (as deltas) and tier sizes into
        gauges — runs at scrape/snapshot time, never on a paging path."""
        reg = self.registry
        pub = self._published
        for key, total in self.stats.items():
            delta = total - pub.get(key, 0)
            if delta:
                reg.counter(f"lifecycle_{key}_total",
                            "TieredBank.stats mirror").inc(delta)
                pub[key] = total
        reg.gauge("lifecycle_hot_tenants",
                  "tenants resident in the hot bank").set(
                      len(self._bank.slots))
        reg.gauge("lifecycle_cold_tenants",
                  "tenants living only as cold checkpoints").set(
                      len(self._cold))

    # -- constructors --------------------------------------------------------

    @classmethod
    def fit(
        cls,
        Xb,
        yb,
        spec,
        *,
        cold_dir,
        capacity: Optional[int] = None,
        window: int = 0,
        tenant_ids: Optional[Sequence[Hashable]] = None,
        mask=None,
        metrics: Optional[obs_metrics.MetricsRegistry] = None,
        tracer=None,
    ) -> "TieredBank":
        """Fit B tenants into a tiered store with ``capacity`` hot slots:
        the first ``capacity`` tenants stay device-resident, the rest are
        fitted in batched chunks (same executable: the tenant axis is
        padded to the hot capacity with fully-masked slots) and written
        straight to the cold tier.  Window buffers are seeded from the fit
        rows, so :meth:`age` counts them."""
        Xb = jnp.asarray(Xb)
        yb = jnp.asarray(yb)
        B, N, p = Xb.shape
        ids = list(range(B)) if tenant_ids is None else list(tenant_ids)
        if len(ids) != B:
            raise ValueError(f"need {B} tenant ids, got {len(ids)}")
        cap = B if capacity is None else int(capacity)
        if cap < 1:
            raise ValueError(f"capacity must be >= 1, got {cap}")
        hot_n = min(cap, B)
        mask = None if mask is None else jnp.asarray(mask)

        def seg(lo, hi):
            m = None if mask is None else mask[lo:hi]
            return Xb[lo:hi], yb[lo:hi], m

        Xh, yh, mh = seg(0, hot_n)
        bank = GPBank.fit(Xh, yh, spec, mask=mh, tenant_ids=ids[:hot_n],
                          capacity=cap)
        tb = cls(bank, cold_dir, window=window, metrics=metrics,
                 tracer=tracer)
        if window:
            tb._seed_rows(ids[:hot_n], Xh, yh, mh)
        # remaining tenants: chunked batched fits through a scratch bank,
        # each chunk padded to hot_n tenants (one executable), then saved
        # cold.  The scratch bank is discarded; only checkpoints remain.
        for lo in range(hot_n, B, hot_n):
            hi = min(lo + hot_n, B)
            Xc, yc, mc = seg(lo, hi)
            n_real = hi - lo
            if n_real < hot_n:     # pad the tenant axis with masked slots
                padm = jnp.zeros((hot_n - n_real, N), Xb.dtype)
                mc = jnp.ones((n_real, N), Xb.dtype) if mc is None else mc
                mc = jnp.concatenate([mc, padm])
                Xc = jnp.concatenate(
                    [Xc, jnp.zeros((hot_n - n_real, N, p), Xb.dtype)]
                )
                yc = jnp.concatenate(
                    [yc, jnp.zeros((hot_n - n_real, N), yb.dtype)]
                )
            scratch = GPBank.fit(Xc, yc, spec,
                                 mask=mc, tenant_ids=range(hot_n))
            for j in range(n_real):
                t = ids[lo + j]
                rows_extra = None
                if window:
                    rows = tb._rows_from(Xc[j], yc[j],
                                         None if mc is None else mc[j])
                    rows_extra = tb._rows_extra(rows)
                gpstate.save_state(tb._cold_path(t), scratch.state(j),
                                   extra=rows_extra)
                tb._cold.add(t)
                tb.stats["cold_saves"] += 1
        return tb

    # -- introspection -------------------------------------------------------

    @property
    def bank(self) -> GPBank:
        """The hot tier.  Serving stacks read this; anything that swaps
        the bank elsewhere must hand the result back via :meth:`adopt`."""
        return self._bank

    @property
    def spec(self):
        return self._bank.spec

    @property
    def capacity(self) -> int:
        return self._bank.capacity

    @property
    def hot_tenants(self) -> list:
        return self._bank.tenants

    @property
    def cold_tenants(self) -> list:
        return sorted(self._cold, key=repr)

    @property
    def tenants(self) -> list:
        return self.hot_tenants + self.cold_tenants

    def __len__(self) -> int:
        return len(self._bank.slots) + len(self._cold)

    def __contains__(self, tenant: Hashable) -> bool:
        return tenant in self._bank.slots or tenant in self._cold

    def is_hot(self, tenant: Hashable) -> bool:
        return tenant in self._bank.slots

    def version(self, tenant: Hashable) -> Optional[int]:
        """Newest cold-tier version of ``tenant`` (None when never
        saved)."""
        return gpstate.latest_version(self._cold_path(tenant))

    def _cold_path(self, tenant: Hashable) -> Path:
        return self.cold_dir / _tenant_key(tenant)

    # -- window bookkeeping (host-side) --------------------------------------

    @staticmethod
    def _rows_from(X, y, mask) -> list:
        X = np.asarray(X, np.float32)
        y = np.asarray(y, np.float32)
        keep = (np.ones(len(y), bool) if mask is None
                else np.asarray(mask) > 0)
        return [(X[i].copy(), float(y[i])) for i in np.flatnonzero(keep)]

    @staticmethod
    def _rows_extra(rows: list) -> Optional[dict]:
        if not rows:
            return None
        return {
            "win_x": np.stack([x for x, _ in rows]).astype(np.float32),
            "win_y": np.asarray([y for _, y in rows], np.float32),
        }

    def _seed_rows(self, ids, Xb, yb, mask) -> None:
        for j, t in enumerate(ids):
            self._rows[t] = self._rows_from(
                Xb[j], yb[j], None if mask is None else mask[j]
            )

    def record_rows(self, tenant: Hashable, X, y, mask=None) -> None:
        """Append absorbed rows to ``tenant``'s window bookkeeping without
        touching the factorization — for rows that were ingested through
        an external path (``BankRouter.ingest``; ``FleetEngine`` calls
        this from its tiered ingest).  No-op when ``window == 0``."""
        if not self.window:
            return
        self._rows.setdefault(tenant, []).extend(
            self._rows_from(np.atleast_2d(np.asarray(X, np.float32)),
                            np.atleast_1d(np.asarray(y, np.float32)), mask)
        )

    # -- cold tier: save / evict / restore -----------------------------------

    def save(self, tenant: Hashable) -> int:
        """Checkpoint a HOT tenant to the cold tier without evicting it
        (versioned: every save appends history).  Returns the version."""
        with self.tracer.span("checkpoint_save", tenant=str(tenant)):
            st = self._bank.state(tenant)  # hetero spec rides along
            ver = gpstate.save_state(
                self._cold_path(tenant), st,
                extra=self._rows_extra(self._rows.get(tenant, [])),
            )
        self.stats["cold_saves"] += 1
        return ver

    def evict_to_cold(self, tenant: Hashable) -> int:
        """Save ``tenant``'s current state as a new cold version, then
        free its hot slot (``GPBank.evict`` — recompile-free).  Returns
        the version written."""
        with self.tracer.span("evict_to_cold", tenant=str(tenant)):
            ver = self.save(tenant)
            self._bank = self._bank.evict(tenant)
        self._lru.pop(tenant, None)
        self._cold.add(tenant)
        self.stats["evictions"] += 1
        return ver

    def _evict_victim(self, pinned: frozenset) -> None:
        for t in self._lru:            # oldest-touched first
            if t not in pinned:
                self.evict_to_cold(t)
                return
        raise RuntimeError(
            f"cannot page in: all {self.capacity} hot slots are pinned "
            f"(pending or in-flight work); raise the capacity or drain "
            f"first"
        )

    def page_in(self, tenant: Hashable, *,
                pinned: Iterable[Hashable] = ()) -> None:
        """Warm-restore a cold tenant into a hot slot, evicting the LRU
        unpinned tenant to the cold tier if the bank is full.  The restore
        rides the recompile-free ``GPBank.insert`` (jitted traced-slot
        write): arbitrary paging churn compiles nothing new.  The
        checkpoint manifest is validated against the bank's spec structure
        BEFORE any array loads — a stale checkpoint from a different
        expansion/truncation/omega raises, like ``with_spec``."""
        if tenant in self._bank.slots:
            return
        if tenant not in self._cold:
            raise KeyError(
                f"tenant {tenant!r} is in neither tier (hot: "
                f"{self.hot_tenants!r}; {len(self._cold)} cold)"
            )
        with self.tracer.span("checkpoint_restore", tenant=str(tenant)):
            _, st, extra = gpstate.load_state(
                self._cold_path(tenant), like_spec=self._bank.spec,
            )
        if self._bank.hypers is None and any(
            not fagp._leaf_equal(getattr(st.spec, f),
                                 getattr(self._bank.spec, f))
            for f in ("eps", "rho", "noise")
        ):
            # a tenant that learned its own hyperparameters (PR 5) cannot
            # join a homogeneous bank; promote the bank to heterogeneous
            # (per-slot overlay materialized once).  One-time serving-path
            # recompile — warm both paths up front if churn must stay
            # compile-free.
            self._bank = dataclasses.replace(
                self._bank, hypers=self._bank._stacked_hypers()
            )
        if bool(np.all(self._bank.active)):     # no free slot: make one
            self._evict_victim(frozenset(pinned) | {tenant})
        self._bank = self._bank.insert(tenant, st)
        self._cold.discard(tenant)
        self._lru[tenant] = None
        self._lru.move_to_end(tenant)
        if self.window and "win_x" in extra:
            self._rows[tenant] = self._rows_from(
                extra["win_x"], extra["win_y"], None
            )
        self.stats["warm_restores"] += 1

    def ensure_hot(self, tenants, *,
                   pinned: Iterable[Hashable] = ()) -> None:
        """Page in every cold tenant in ``tenants`` (deduplicated, first
        appearance first).  All of them are implicitly pinned — a batch
        can never evict one of its own members to admit another."""
        want = list(dict.fromkeys(tenants))
        if len(want) > self.capacity:
            raise ValueError(
                f"batch touches {len(want)} distinct tenants but only "
                f"{self.capacity} hot slots exist; split the batch"
            )
        pin = frozenset(pinned) | set(want)
        for t in want:
            if t not in self._bank.slots:
                self.page_in(t, pinned=pin)

    def adopt(self, bank: GPBank) -> None:
        """Hand back a bank that was swapped outside this tier (router
        ingest / reoptimize).  Membership metadata is re-synced
        defensively; per-tenant window buffers key on tenant ids, so they
        survive any swap that keeps ids stable."""
        self._bank = bank
        for t in list(self._lru):
            if t not in bank.slots:
                del self._lru[t]
        for t in bank.slots:
            if t not in self._lru:
                self._lru[t] = None

    def _touch(self, tenants) -> None:
        for t in dict.fromkeys(tenants):
            if t in self._lru:
                self._lru.move_to_end(t)

    # -- serving (page-through wrappers) -------------------------------------

    def mean_var(self, tenant_ids, Xq):
        """Mixed-tenant ``mean_var`` over BOTH tiers: cold tenants are
        warm-restored first (members of the batch are pinned against each
        other), then one batched hot call answers everything."""
        ids = list(tenant_ids)
        self.ensure_hot(ids)
        self._touch(ids)
        return self._bank.mean_var(ids, Xq)

    def update(self, tenant_ids, Xk, yk, mask=None) -> GPBank:
        """Batched rank-k ingest over both tiers: cold tenants page in,
        then one ``GPBank.update`` absorbs every group.  Absorbed rows
        enter the window bookkeeping (mask-aware).  Returns the new hot
        bank (also adopted internally)."""
        ids = list(tenant_ids)
        self.ensure_hot(ids)
        self._touch(ids)
        self._bank = self._bank.update(ids, Xk, yk, mask)
        if self.window:
            Xk = np.asarray(Xk)
            yk = np.asarray(yk)
            for g, t in enumerate(ids):
                self._rows.setdefault(t, []).extend(self._rows_from(
                    Xk[g], yk[g], None if mask is None else np.asarray(mask)[g]
                ))
        return self._bank

    def insert(self, tenant: Hashable, source) -> None:
        """Admit a NEW tenant (id unknown to both tiers), evicting the LRU
        hot tenant to the cold tier when the bank is full.  ``source`` is
        anything ``GPBank.insert`` takes; (X, y) tuples additionally seed
        the window bookkeeping."""
        if tenant in self:
            raise ValueError(f"tenant {tenant!r} already in the tier")
        _tenant_key(tenant)            # fail before mutating on bad ids
        if bool(np.all(self._bank.active)):
            self._evict_victim(frozenset({tenant}))
        self._bank = self._bank.insert(tenant, source)
        self._lru[tenant] = None
        self._lru.move_to_end(tenant)
        if self.window and isinstance(source, tuple):
            X, y = source
            self._rows[tenant] = self._rows_from(X, y, None)

    # -- sliding-window forgetting -------------------------------------------

    def age(self, tenant_ids=None) -> dict:
        """Forget everything older than each tenant's newest ``window``
        rows: one bucketed batched rank-k downdate for every over-window
        tenant, then one bucketed masked refit from the retained window
        for any group whose downdate lost positive definiteness.  Cold
        tenants in ``tenant_ids`` are paged in first (aging is a
        factorization rewrite).  Returns
        ``{"aged": [...], "forgotten_rows": n, "refit": [...]}``."""
        out = {"aged": [], "forgotten_rows": 0, "refit": []}
        if not self.window:
            return out
        cands = list(dict.fromkeys(
            self.tenants if tenant_ids is None else tenant_ids
        ))
        over = [t for t in cands
                if len(self._rows.get(t, ())) > self.window]
        if not over:
            return out
        with self.tracer.span("age", tenants=len(over)):
            return self._age_over(over, out)

    def _age_over(self, over: list, out: dict) -> dict:
        self.ensure_hot(over)
        self._touch(over)
        W = self.window
        p = self.spec.p
        excess = {t: self._rows[t][:-W] for t in over}
        kmax = _pow2_bucket(max(len(r) for r in excess.values()),
                            1 << 30)
        G = len(over)
        bucket = _pow2_bucket(G, self.capacity)
        slots = [self._bank.slot_of(t) for t in over]
        Xg = np.zeros((bucket, kmax, p), np.float32)
        yg = np.zeros((bucket, kmax), np.float32)
        mg = np.zeros((bucket, kmax), np.float32)
        for g, t in enumerate(over):
            rows = excess[t]
            for i, (x, yv) in enumerate(rows):
                Xg[g, i], yg[g, i], mg[g, i] = x, yv, 1.0
        used = set(slots)
        free = (s for s in range(self.capacity) if s not in used)
        for _ in range(bucket - G):    # identity padding on distinct slots
            slots.append(next(free))
        with self.tracer.span("downdate", groups=bucket):
            bank, ok = self._bank._downdate_at_slots(
                jnp.asarray(np.asarray(slots, np.int32)),
                jnp.asarray(Xg), jnp.asarray(yg), jnp.asarray(mg),
            )
        self._bank = bank
        failed = [t for g, t in enumerate(over) if not ok[g]]
        if failed:
            # refit the survivors' factorizations from their retained
            # window (ragged: tenants keep exactly W rows here, but stay
            # mask-general), same bucketing discipline
            Gf = len(failed)
            fbucket = _pow2_bucket(Gf, self.capacity)
            fslots = [self._bank.slot_of(t) for t in failed]
            Xw = np.zeros((fbucket, W, p), np.float32)
            yw = np.zeros((fbucket, W), np.float32)
            mw = np.zeros((fbucket, W), np.float32)
            for g, t in enumerate(failed):
                rows = self._rows[t][-W:]
                for i, (x, yv) in enumerate(rows):
                    Xw[g, i], yw[g, i], mw[g, i] = x, yv, 1.0
            fused = set(fslots)
            ffree = (s for s in range(self.capacity) if s not in fused)
            for _ in range(fbucket - Gf):
                fslots.append(next(ffree))
            with self.tracer.span("refit", groups=fbucket):
                self._bank = self._bank._refit_at_slots(
                    jnp.asarray(np.asarray(fslots, np.int32)),
                    jnp.asarray(Xw), jnp.asarray(yw), jnp.asarray(mw),
                )
            self.stats["refit_fallbacks"] += Gf
        for t in over:
            self._rows[t] = self._rows[t][-W:]
        n_forgot = sum(len(r) for r in excess.values())
        self.stats["downdated_rows"] += n_forgot
        out.update(aged=over, forgotten_rows=n_forgot, refit=failed)
        return out
