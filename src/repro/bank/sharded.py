"""Bank-axis sharding: one GPBank fleet spread across a device mesh.

The stacked ``FAGPState``'s leading capacity axis is embarrassingly
parallel — every slot owns an independent (chol, u, b) factorization — so
a ``bank`` mesh axis shards it with ZERO cross-shard collectives on the
serving hot path (Chen et al.'s parallel low-rank GP regression distributes
exactly this Gram/weights summary structure across workers).

Design:

  * ``ShardedGPBank`` mirrors :class:`~repro.bank.bank.GPBank`'s public
    surface (fit / mean_var / update / downdate / refit_window / insert /
    evict / state / slots ...) so ``BankRouter``, ``FleetEngine`` and
    ``TieredBank`` drive it unchanged.  Slots stay GLOBAL ids; shard
    ``slot // shard_capacity`` owns local row ``slot % shard_capacity``.
  * Every batched executable is a module-level jit (mesh static) wrapping
    ONE ``shard_map`` whose body reuses the resident bank's array cores
    (``_bank_update_scatter_impl``, ``_bank_downdate_scatter``,
    ``_bank_refit_scatter``, ``fagp._bank_gathered_posterior``) on the
    shard-local leaves — the math has one home, this module only places it.
  * Mixed-shard batches are grouped host-side: rows/groups are packed per
    shard and padded to a shared pow2 rung (``per-shard microbatch
    buckets``), so one hot shard never pad-inflates the others and the
    executable count stays O(log capacity) — exactly the resident bank's
    zero-recompile contract, per shard.
  * ``insert``/``evict``/``rebalance`` ride one traced-global-slot write
    executable (a masked ``axis_index`` write per shard), so membership
    churn — including cross-shard moves — never recompiles.
  * The serving B^{-1} cache is maintained EAGERLY: every mutating
    executable refreshes the touched rows shard-locally, so serving never
    pays a full-capacity recompute and the cache never leaves its shard.
  * Composes with the v2 row-sharding of ``core.distributed`` as a 2-D
    ``(bank, data)`` mesh: ``fit`` additionally shards the N row axis over
    ``data`` and combines shard-partial moments with one psum over 'data'
    (fit-only; serving stays collective-free).

Spec-local rebuild glue (``spec_local`` / ``omega_args``) is shared with
the v2 schedules via ``core.shardspec`` — the same leaves-in, spec-out
discipline keeps outer tracers from leaking into shard_map bodies.

Homogeneous banks only: per-slot hyperparameter overlays
(:meth:`GPBank.optimize`) serve through per-row featurization that has no
shard-local fast path yet — convert with :meth:`ShardedGPBank.to_bank`
first.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Hashable, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import fagp, shardspec
from repro.core.expansions import get_expansion
from repro.core.fagp import FAGPState, GPSpec
from repro.core.gp import GP
from repro.core.mercer import SEKernelParams

from . import bank as bank_mod
from .bank import (
    GPBank,
    _bank_solve,
    _bank_spec,
    _check_bankable,
    _prior_leaves,
)

__all__ = ["ShardedGPBank"]


def _bank_axis_specs(mesh) -> tuple:
    """(P('bank'), P()) pair for a mesh whose first axis is 'bank' — any
    extra axes (the v2 'data' axis) replicate bank-stacked leaves."""
    if "bank" not in mesh.axis_names:
        raise ValueError(
            f"sharded bank needs a mesh axis named 'bank'; got axes "
            f"{mesh.axis_names!r}"
        )
    return P("bank"), P()


def _leaf_sharding(mesh) -> NamedSharding:
    return NamedSharding(mesh, P("bank"))


def _pow2(n: int) -> int:
    return 1 << (max(1, int(n)) - 1).bit_length()


# ---------------------------------------------------------------------------
# host-side per-shard grouping (the padding policy in one place)
# ---------------------------------------------------------------------------


def _group_rows(gslots: np.ndarray, C_l: int, S: int, cap=None):
    """Pack mixed-shard rows into the (S, Q_s) per-shard layout.

    Returns ``(lslots (S*Q_s,) int32, pos (n,) int64, Q_s)`` where row i of
    the caller's batch lands at flat position ``pos[i]`` and padding rows
    aim at local slot 0 (their results are discarded, duplicate gathers
    are safe).  ``Q_s`` is the pow2 rung of the busiest shard — the
    per-shard microbatch bucket (optionally capped, for scatter callers
    whose padding needs untargeted slots)."""
    n = len(gslots)
    shard = gslots // C_l
    counts = np.bincount(shard, minlength=S)
    Qs = _pow2(counts.max()) if n else 1
    if cap is not None:
        Qs = min(int(cap), Qs)
    order = np.argsort(shard, kind="stable")
    start = np.searchsorted(shard[order], np.arange(S))
    ranks = np.empty(n, np.int64)
    ranks[order] = np.arange(n) - start[shard[order]]
    pos = shard.astype(np.int64) * Qs + ranks
    lslots = np.zeros(S * Qs, np.int32)
    lslots[pos] = (gslots % C_l).astype(np.int32)
    return lslots, pos, Qs


def _group_slots(gslots: np.ndarray, C_l: int, S: int):
    """Per-shard grouping for scatter ops (update/downdate/refit): slots
    must be DISTINCT within a shard, so padding groups aim at the lowest
    local slots not targeted by a real group in that shard (fully-masked
    groups are exact identity writes, active or not)."""
    lslots, pos, Qs = _group_rows(gslots, C_l, S, cap=C_l)
    used = [set() for _ in range(S)]
    for g, l in zip(gslots // C_l, gslots % C_l):
        used[g].add(int(l))
    for s in range(S):
        fill = (l for l in range(C_l) if l not in used[s])
        n_real = len(used[s])
        for j in range(n_real, Qs):
            lslots[s * Qs + j] = next(fill)
    return lslots, pos, Qs


# ---------------------------------------------------------------------------
# batched shard-local executables (module-level: compiled once per shape)
# ---------------------------------------------------------------------------


def _binv_rows(chol_rows):
    """(G, M, M) Cholesky rows -> B^{-1} rows (the eager cache refresh)."""
    eye = jnp.eye(chol_rows.shape[-1], dtype=chol_rows.dtype)
    return jax.vmap(
        lambda c: jax.scipy.linalg.cho_solve((c, True), eye)
    )(chol_rows)


@partial(jax.jit, static_argnames=("mesh",))
def _sh_binv(chol, mesh):
    sh, rep = _bank_axis_specs(mesh)
    return shardspec.shard_map(_binv_rows, mesh, (sh,), sh)(chol)


@partial(jax.jit, static_argnames=("mesh", "backend", "block_rows"))
def _sh_fit(Xb, yb, maskb, spec, idx, aux, mesh, backend, block_rows):
    """Batched fit, slots sharded over 'bank' and (optionally) rows over
    'data': per-shard moments through the backend registry, one psum over
    the data axes (fit-only — O(M^2) per slot, independent of N), then the
    shared solve epilogue replicated per data shard."""
    bk = fagp.get_backend(backend)
    moments = bk.bank_moments or bank_mod._fallback_bank_moments(bk)
    exp = get_expansion(spec.expansion)
    data_axes = tuple(a for a in mesh.axis_names if a != "bank")
    omega_t = shardspec.omega_args(spec)
    sh, rep = _bank_axis_specs(mesh)
    row_sh = P("bank", *data_axes) if data_axes else sh

    def body(X_l, y_l, m_l, idx_, eps, rho, noise, aux_l, *omega_l):
        s_loc = shardspec.spec_local(
            spec, eps, rho, omega_l[0] if omega_l else None
        )
        G, b = moments(X_l, y_l, s_loc, idx_, aux_l, block_rows, m_l)
        if data_axes:
            G = jax.lax.psum(G, data_axes)
            b = jax.lax.psum(b, data_axes)
        loglam = exp.log_eigenvalues(idx_, s_loc)
        return _bank_solve(G, b, loglam, noise**2) + (b,)

    aux_specs = jax.tree_util.tree_map(lambda _: rep, aux)
    in_specs = (row_sh, row_sh, row_sh, rep, rep, rep, rep, aux_specs) + \
        (rep,) * len(omega_t)
    return shardspec.shard_map(body, mesh, in_specs, (sh,) * 5)(
        Xb, yb, maskb, idx, spec.eps, spec.rho,
        jnp.asarray(spec.noise, jnp.float32), aux, *omega_t,
    )


@partial(jax.jit, static_argnames=("mesh",))
def _sh_mean_var(binv, u_s, sqrtlam_s, lslots, Xq, spec, idx, mesh):
    """Mixed-tenant serving on per-shard packed queries: featurize and
    gather the posterior entirely shard-locally — zero collectives."""
    exp = get_expansion(spec.expansion)
    omega_t = shardspec.omega_args(spec)
    sh, rep = _bank_axis_specs(mesh)

    def body(binv_l, u_l, sq_l, sl_l, Xq_l, idx_, eps, rho, *omega_l):
        s_loc = shardspec.spec_local(
            spec, eps, rho, omega_l[0] if omega_l else None
        )
        Phis = exp.features(Xq_l, idx_, s_loc)
        return fagp._bank_gathered_posterior(binv_l, u_l, sq_l, sl_l, Phis)

    in_specs = (sh, sh, sh, sh, sh, rep, rep, rep) + (rep,) * len(omega_t)
    return shardspec.shard_map(body, mesh, in_specs, (sh, sh))(
        binv, u_s, sqrtlam_s, lslots, Xq, idx, spec.eps, spec.rho, *omega_t,
    )


@partial(jax.jit, static_argnames=("mesh",))
def _sh_update_scatter(chol_s, u_s, b_s, sqrtlam_s, binv, lslots, Xg, yg,
                       maskg, spec, idx, mesh):
    """Per-shard rank-k update scatter + eager B^{-1} row refresh.  The
    body is the resident ``_bank_update_scatter_impl`` on local leaves —
    fully-masked per-shard padding groups are exact identity writes."""
    exp = get_expansion(spec.expansion)
    omega_t = shardspec.omega_args(spec)
    sh, rep = _bank_axis_specs(mesh)

    def body(chol_l, u_l, b_l, sq_l, binv_l, sl_l, Xg_l, yg_l, mg_l,
             idx_, eps, rho, noise, *omega_l):
        s_loc = shardspec.spec_local(
            spec, eps, rho, omega_l[0] if omega_l else None
        )
        G, k, p = Xg_l.shape
        Phi_g = exp.features(Xg_l.reshape(G * k, p), idx_, s_loc)
        Phi_g = Phi_g.reshape(G, k, -1)
        noise_g = jnp.broadcast_to(noise, (G,))
        chol_l, u_l, b_l = bank_mod._bank_update_scatter_impl(
            chol_l, u_l, b_l, sq_l, noise_g, sl_l, Phi_g, yg_l, mg_l,
        )
        binv_l = binv_l.at[sl_l].set(_binv_rows(chol_l[sl_l]))
        return chol_l, u_l, b_l, binv_l

    in_specs = (sh, sh, sh, sh, sh, sh, sh, sh, sh, rep, rep, rep, rep) + \
        (rep,) * len(omega_t)
    return shardspec.shard_map(body, mesh, in_specs, (sh,) * 4)(
        chol_s, u_s, b_s, sqrtlam_s, binv, lslots, Xg, yg, maskg,
        idx, spec.eps, spec.rho, jnp.asarray(spec.noise, jnp.float32),
        *omega_t,
    )


@partial(jax.jit, static_argnames=("mesh",))
def _sh_downdate_scatter(chol_s, u_s, b_s, sqrtlam_s, binv, lslots, Xg, yg,
                         maskg, spec, idx, mesh):
    """Per-shard rank-k downdate mirror (rides the resident
    ``_bank_downdate_scatter``); returns the per-group ok flags in the
    packed per-shard order."""
    exp = get_expansion(spec.expansion)
    omega_t = shardspec.omega_args(spec)
    sh, rep = _bank_axis_specs(mesh)

    def body(chol_l, u_l, b_l, sq_l, binv_l, sl_l, Xg_l, yg_l, mg_l,
             idx_, eps, rho, noise, *omega_l):
        s_loc = shardspec.spec_local(
            spec, eps, rho, omega_l[0] if omega_l else None
        )
        G, k, p = Xg_l.shape
        Phi_g = exp.features(Xg_l.reshape(G * k, p), idx_, s_loc)
        Phi_g = Phi_g.reshape(G, k, -1)
        noise_g = jnp.broadcast_to(noise, (G,))
        chol_l, u_l, b_l, ok = bank_mod._bank_downdate_scatter(
            chol_l, u_l, b_l, sq_l, noise_g, sl_l, Phi_g, yg_l, mg_l,
        )
        binv_l = binv_l.at[sl_l].set(_binv_rows(chol_l[sl_l]))
        return chol_l, u_l, b_l, binv_l, ok

    in_specs = (sh, sh, sh, sh, sh, sh, sh, sh, sh, rep, rep, rep, rep) + \
        (rep,) * len(omega_t)
    return shardspec.shard_map(body, mesh, in_specs, (sh,) * 5)(
        chol_s, u_s, b_s, sqrtlam_s, binv, lslots, Xg, yg, maskg,
        idx, spec.eps, spec.rho, jnp.asarray(spec.noise, jnp.float32),
        *omega_t,
    )


@partial(jax.jit, static_argnames=("mesh",))
def _sh_refit_scatter(chol_s, u_s, b_s, lam_s, sqrtlam_s, binv, lslots,
                      Xg, yg, maskg, spec, idx, mesh):
    """Per-shard masked window refit (rides the resident
    ``_bank_refit_scatter`` under the shared hyperparameters)."""
    omega_t = shardspec.omega_args(spec)
    sh, rep = _bank_axis_specs(mesh)

    def body(chol_l, u_l, b_l, lam_l, sq_l, binv_l, sl_l, Xg_l, yg_l, mg_l,
             idx_, eps, rho, noise, *omega_l):
        s_loc = shardspec.spec_local(
            spec, eps, rho, omega_l[0] if omega_l else None
        )
        G = Xg_l.shape[0]
        eps_g = jnp.broadcast_to(eps, (G,) + eps.shape)
        rho_g = jnp.broadcast_to(rho, (G,) + rho.shape)
        noise_g = jnp.broadcast_to(noise, (G,))
        chol_l, u_l, b_l, lam_l, sq_l = bank_mod._bank_refit_scatter(
            chol_l, u_l, b_l, lam_l, sq_l, sl_l, Xg_l, yg_l, mg_l,
            eps_g, rho_g, noise_g,
            dataclasses.replace(s_loc, noise=noise), idx_,
        )
        binv_l = binv_l.at[sl_l].set(_binv_rows(chol_l[sl_l]))
        return chol_l, u_l, b_l, lam_l, sq_l, binv_l

    in_specs = (sh,) * 10 + (rep, rep, rep, rep) + (rep,) * len(omega_t)
    return shardspec.shard_map(body, mesh, in_specs, (sh,) * 6)(
        chol_s, u_s, b_s, lam_s, sqrtlam_s, binv, lslots, Xg, yg, maskg,
        idx, spec.eps, spec.rho, jnp.asarray(spec.noise, jnp.float32),
        *omega_t,
    )


@partial(jax.jit, static_argnames=("mesh",))
def _sh_write_slot(chol_s, u_s, b_s, lam_s, sqrtlam_s, binv, gslot,
                   chol, u, b, lam, sqrtlam, mesh):
    """Write one tenant's leaves at a *traced* GLOBAL slot: the owning
    shard applies the write, every other shard rewrites its own row
    verbatim — insert/evict/rebalance of any slot on any shard hit this
    one executable.  The written slot's B^{-1} row refreshes in place."""
    sh, rep = _bank_axis_specs(mesh)

    def body(chol_l, u_l, b_l, lam_l, sq_l, binv_l, gs, *new):
        C_l = chol_l.shape[0]
        me = jax.lax.axis_index("bank")
        loc = gs % C_l
        mine = (gs // C_l) == me

        def wr(leaf, val):
            row = jax.lax.dynamic_index_in_dim(leaf, loc, 0, keepdims=False)
            upd = jnp.where(mine, val, row)
            return jax.lax.dynamic_update_index_in_dim(leaf, upd, loc, 0)

        chol_l = wr(chol_l, new[0])
        u_l = wr(u_l, new[1])
        b_l = wr(b_l, new[2])
        lam_l = wr(lam_l, new[3])
        sq_l = wr(sq_l, new[4])
        row = jax.lax.dynamic_index_in_dim(chol_l, loc, 0, keepdims=False)
        binv_l = wr(binv_l, _binv_rows(row[None])[0])
        return chol_l, u_l, b_l, lam_l, sq_l, binv_l

    in_specs = (sh,) * 6 + (rep,) * 6
    return shardspec.shard_map(body, mesh, in_specs, (sh,) * 6)(
        chol_s, u_s, b_s, lam_s, sqrtlam_s, binv, gslot,
        chol, u, b, lam, sqrtlam,
    )


@jax.jit
def _sh_read_slot(chol_s, u_s, b_s, lam_s, sqrtlam_s, gslot):
    """Gather one slot's leaves at a *traced* global index — the unstack
    path (``state``/``rebalance``) stays zero-recompile across slots and
    shards.  Cross-shard by nature; never on the serving hot path."""
    rd = lambda a: jax.lax.dynamic_index_in_dim(a, gslot, 0, keepdims=False)
    return rd(chol_s), rd(u_s), rd(b_s), rd(lam_s), rd(sqrtlam_s)


# ---------------------------------------------------------------------------
# the sharded bank
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardedGPBank:
    """A :class:`GPBank` whose capacity axis is sharded over a mesh's
    'bank' axis (see module doc).  Public surface mirrors ``GPBank`` —
    the router, engine and tiered lifecycle drive either interchangeably.

    stack:  stacked FAGPState, leaves device-sharded P('bank').
    mesh:   the device mesh (first axis 'bank'; extra axes are the v2
            data axes, used by fit only).
    binv:   eagerly-maintained per-slot B^{-1} cache, sharded alongside.
    active: (capacity,) host bool mask.
    slots:  tenant -> GLOBAL slot (shard = slot // shard_capacity).
    hypers: always None — sharded banks are homogeneous (see module doc).
    """

    stack: FAGPState
    mesh: Any
    binv: jax.Array
    active: np.ndarray
    slots: Mapping[Hashable, int]
    hypers: Optional[SEKernelParams] = None

    def __post_init__(self):
        if self.hypers is not None:
            raise ValueError(
                "ShardedGPBank is homogeneous-only: per-slot hyperparameter"
                " overlays (GPBank.optimize) have no shard-local serving "
                "path yet — convert with to_bank() first"
            )
        if not shardspec.has_shard_map():  # pragma: no cover - ancient jax
            raise RuntimeError("this jax build lacks shard_map")

    # -- constructors -------------------------------------------------------

    @classmethod
    def create(cls, spec: GPSpec, capacity: int, mesh) -> "ShardedGPBank":
        """An empty sharded bank: every slot holds the prior state."""
        res = GPBank.create(spec, cls._check_capacity(capacity, mesh))
        return cls.from_bank(res, mesh)

    @classmethod
    def fit(
        cls,
        Xb: jax.Array,
        yb: jax.Array,
        spec: GPSpec,
        mesh,
        *,
        mask: Optional[jax.Array] = None,
        tenant_ids: Optional[Sequence[Hashable]] = None,
        capacity: Optional[int] = None,
    ) -> "ShardedGPBank":
        """Fit B independent GPs in one sharded batched pass (same data
        contract as :meth:`GPBank.fit`).  Tenants place round-robin across
        shards (tenant i -> shard i mod S), packed from each shard's lowest
        local slot; reserved capacity pads with masked rows that factorize
        to exactly the prior leaves."""
        Xb = np.asarray(Xb, np.float32)
        yb = np.asarray(yb, np.float32)
        if Xb.ndim != 3 or yb.ndim != 2 or yb.shape != Xb.shape[:2]:
            raise ValueError(
                f"ShardedGPBank.fit wants Xb (B, N, p) and yb (B, N); got "
                f"{Xb.shape} and {yb.shape}"
            )
        B, N, p = Xb.shape
        S = int(mesh.shape["bank"])
        cap = (-(-B // S) * S) if capacity is None else int(capacity)
        cap = cls._check_capacity(cap, mesh)
        if cap < B:
            raise ValueError(f"capacity {cap} < number of tenants {B}")
        C_l = cap // S
        if tenant_ids is None:
            tenant_ids = range(B)
        tenant_ids = list(tenant_ids)
        if len(tenant_ids) != B or len(set(tenant_ids)) != B:
            raise ValueError(
                f"tenant_ids must be {B} distinct ids, got {tenant_ids!r}"
            )
        spec = _bank_spec(spec)
        fagp._check_p(spec, p)
        if mask is None:
            mask = np.ones((B, N), np.float32)
        else:
            mask = np.asarray(mask, np.float32)
            if mask.shape != (B, N):
                raise ValueError(
                    f"mask must be (B, N) = {(B, N)}, got {mask.shape}"
                )
        # round-robin placement: tenant i -> global slot (i%S)*C_l + i//S
        gslots = (np.arange(B) % S) * C_l + np.arange(B) // S
        # pad the row axis to the data-axis quantum (2-D mesh fits only)
        dsize = int(np.prod([
            mesh.shape[a] for a in mesh.axis_names if a != "bank"
        ]))
        N_pad = -(-N // dsize) * dsize
        Xf = np.zeros((cap, N_pad, p), np.float32)
        yf = np.zeros((cap, N_pad), np.float32)
        mf = np.zeros((cap, N_pad), np.float32)
        Xf[gslots, :N] = Xb
        yf[gslots, :N] = yb
        mf[gslots, :N] = mask
        backend = fagp._check_backend_support(spec)
        idx_np = spec.indices(p)
        idx = jnp.asarray(idx_np)
        aux = backend.prepare(idx_np, spec)
        block_rows = min(spec.block_rows, max(1, N))
        data_axes = tuple(a for a in mesh.axis_names if a != "bank")
        row_shd = NamedSharding(
            mesh, P("bank", *data_axes) if data_axes else P("bank")
        )
        put = lambda a: jax.device_put(a, row_shd)
        lam, sqrtlam, chol, u, b = _sh_fit(
            put(Xf), put(yf), put(mf), spec, idx, aux, mesh,
            spec.backend, block_rows,
        )
        stack = FAGPState(
            idx=idx, lam=lam, sqrtlam=sqrtlam, chol=chol, u=u,
            params=spec.params, Phi=None, y=None, b=b, spec=spec,
        )
        active = np.zeros(cap, bool)
        active[gslots] = True
        return cls(
            stack=stack, mesh=mesh, binv=_sh_binv(chol, mesh),
            active=active,
            slots={t: int(s) for t, s in zip(tenant_ids, gslots)},
        )

    @classmethod
    def from_bank(cls, bank: GPBank, mesh, *,
                  pad_capacity: bool = False) -> "ShardedGPBank":
        """Shard a resident bank in place: slots keep their global ids
        (shard = slot // shard_capacity).  ``pad_capacity`` rounds the
        capacity up to a shard multiple with prior slots instead of
        raising."""
        if bank.hypers is not None:
            raise ValueError(
                "cannot shard a heterogeneous bank (per-slot overlays have "
                "no shard-local serving path yet)"
            )
        S = int(mesh.shape["bank"])
        cap = bank.capacity
        if cap % S and pad_capacity:
            bigger = GPBank.create(bank.spec, -(-cap // S) * S)
            leaves = {
                f: jnp.concatenate([
                    getattr(bank.stack, f), getattr(bigger.stack, f)[cap:],
                ])
                for f in ("lam", "sqrtlam", "chol", "u", "b")
            }
            stack = dataclasses.replace(bank.stack, **leaves)
            active = np.zeros(bigger.capacity, bool)
            active[:cap] = bank.active
            bank = GPBank(stack=stack, active=active, slots=dict(bank.slots))
            cap = bank.capacity
        cap = cls._check_capacity(cap, mesh)
        shd = _leaf_sharding(mesh)
        leaves = {
            f: jax.device_put(getattr(bank.stack, f), shd)
            for f in ("lam", "sqrtlam", "chol", "u", "b")
        }
        stack = dataclasses.replace(bank.stack, **leaves)
        return cls(
            stack=stack, mesh=mesh, binv=_sh_binv(stack.chol, mesh),
            active=bank.active.copy(), slots=dict(bank.slots),
        )

    def to_bank(self) -> GPBank:
        """Gather the shards back into a single-device resident bank."""
        leaves = {
            f: jnp.asarray(np.asarray(getattr(self.stack, f)))
            for f in ("lam", "sqrtlam", "chol", "u", "b")
        }
        stack = dataclasses.replace(self.stack, **leaves)
        return GPBank(stack=stack, active=self.active.copy(),
                      slots=dict(self.slots))

    @staticmethod
    def _check_capacity(capacity: int, mesh) -> int:
        S = int(mesh.shape.get("bank", 0))
        if S < 1:
            raise ValueError(
                f"mesh needs a 'bank' axis; got {mesh.axis_names!r}"
            )
        if capacity < 1 or capacity % S:
            raise ValueError(
                f"capacity must be a positive multiple of the bank axis "
                f"size {S}, got {capacity}"
            )
        return int(capacity)

    # -- introspection ------------------------------------------------------

    @property
    def spec(self) -> GPSpec:
        return self.stack.spec

    @property
    def capacity(self) -> int:
        return self.stack.u.shape[0]

    @property
    def n_features(self) -> int:
        return self.stack.idx.shape[0]

    @property
    def n_shards(self) -> int:
        return int(self.mesh.shape["bank"])

    @property
    def shard_capacity(self) -> int:
        return self.capacity // self.n_shards

    @property
    def tenants(self) -> list:
        return list(self.slots)

    def __len__(self) -> int:
        return len(self.slots)

    def __contains__(self, tenant: Hashable) -> bool:
        return tenant in self.slots

    def slot_of(self, tenant: Hashable) -> int:
        try:
            return self.slots[tenant]
        except KeyError:
            raise KeyError(
                f"tenant {tenant!r} is not in this bank (tenants: "
                f"{self.tenants!r})"
            ) from None

    def shard_of(self, tenant: Hashable) -> int:
        """Which shard owns this tenant's slot."""
        return self.slot_of(tenant) // self.shard_capacity

    def shard_occupancy(self) -> np.ndarray:
        """(S,) active-tenant count per shard (host-side, no sync)."""
        return self.active.reshape(self.n_shards, -1).sum(axis=1)

    def state(self, tenant: Hashable) -> FAGPState:
        """The tenant's session, unstacked (traced-slot gather — paging any
        slot on any shard out is one executable)."""
        s = self.slot_of(tenant)
        st = self.stack
        chol, u, b, lam, sqrtlam = _sh_read_slot(
            st.chol, st.u, st.b, st.lam, st.sqrtlam, jnp.int32(s)
        )
        return dataclasses.replace(
            st, lam=lam, sqrtlam=sqrtlam, chol=chol, u=u, b=b
        )

    def states(self) -> dict:
        return {t: self.state(t) for t in self.slots}

    def _stacked_hypers(self) -> SEKernelParams:
        sp = self.spec
        C = self.capacity
        return SEKernelParams(
            eps=jnp.broadcast_to(sp.eps, (C,) + sp.eps.shape),
            rho=jnp.broadcast_to(sp.rho, (C,) + sp.rho.shape),
            noise=jnp.broadcast_to(jnp.asarray(sp.noise, jnp.float32), (C,)),
        )

    @property
    def _binv(self) -> jax.Array:
        """The serving cache — eager in a sharded bank (every mutating
        executable refreshes its touched rows shard-locally)."""
        return self.binv

    def _slots_np(self, tenant_ids) -> np.ndarray:
        if isinstance(tenant_ids, (str, bytes)) or not hasattr(
            tenant_ids, "__iter__"
        ):
            raise TypeError(
                "tenant_ids must be a sequence of tenant ids, one per row "
                f"(got a scalar {tenant_ids!r}); for a single-tenant batch "
                "pass [tenant] * len(Xq)"
            )
        return np.fromiter(
            (self.slot_of(t) for t in tenant_ids), np.int64,
        )

    _slots_for = _slots_np

    @staticmethod
    def result_ready(*arrays) -> bool:
        """See :meth:`GPBank.result_ready` (one definition)."""
        return GPBank.result_ready(*arrays)

    # -- the batched pipeline ----------------------------------------------

    def _packed_mean_var(self, gslots: np.ndarray, Xq: np.ndarray):
        """Serving core on global slots: per-shard pack, one shard-local
        executable, results in PACKED order plus the position map — the
        engine unpacks host-side at harvest (no device reorder on the hot
        path)."""
        S, C_l = self.n_shards, self.shard_capacity
        lslots, pos, Qs = _group_rows(gslots, C_l, S)
        Xp = np.zeros((S * Qs, Xq.shape[1]), np.float32)
        Xp[pos] = Xq
        shd = _leaf_sharding(self.mesh)
        mu, var = _sh_mean_var(
            self.binv, self.stack.u, self.stack.sqrtlam,
            jax.device_put(lslots, shd), jax.device_put(Xp, shd),
            self.spec, self.stack.idx, self.mesh,
        )
        return mu, var, pos

    def mean_var(self, tenant_ids, Xq: jax.Array):
        """Posterior mean and marginal variance for a mixed-tenant query
        batch (same contract as :meth:`GPBank.mean_var`); one shard-local
        compiled call plus a gather back to row order."""
        Xq = np.asarray(Xq, np.float32)
        gslots = self._slots_np(tenant_ids)
        if gslots.shape[0] != Xq.shape[0]:
            raise ValueError(
                f"one tenant id per query row: got {gslots.shape[0]} ids "
                f"for {Xq.shape[0]} rows"
            )
        mu, var, pos = self._packed_mean_var(gslots, Xq)
        unpack = jnp.asarray(pos, jnp.int32)
        return mu[unpack], var[unpack]

    # -- ingest / forgetting ------------------------------------------------

    def update(self, tenant_ids, Xk, yk, mask=None) -> "ShardedGPBank":
        """Batched rank-k ingest (same contract as :meth:`GPBank.update`)."""
        Xk = np.asarray(Xk, np.float32)
        yk = np.asarray(yk, np.float32)
        if Xk.ndim != 3 or yk.shape != Xk.shape[:2]:
            raise ValueError(
                f"update wants Xk (G, k, p) and yk (G, k); got "
                f"{Xk.shape} and {yk.shape}"
            )
        ids = list(tenant_ids)
        if len(set(ids)) != len(ids):
            raise ValueError(
                f"duplicate tenant in one update batch ({ids!r}): the "
                f"scattered writes would collide — split into rounds "
                f"(BankRouter.ingest does this)"
            )
        if len(ids) != Xk.shape[0]:
            raise ValueError(
                f"one tenant id per update group: got {len(ids)} ids for "
                f"{Xk.shape[0]} groups"
            )
        return self._update_at_slots(self._slots_np(ids), Xk, yk, mask)

    def _group_scatter_args(self, slots, Xg, yg, mask):
        """Shared host-side prep for the scatter ops: per-shard grouping
        with pow2 rung padding; padding groups fully masked on distinct
        untargeted slots."""
        Xg = np.asarray(Xg, np.float32)
        yg = np.asarray(yg, np.float32)
        G, k, p = Xg.shape
        fagp._check_p(self.spec, p)
        if mask is None:
            mask = np.ones((G, k), np.float32)
        else:
            mask = np.asarray(mask, np.float32)
            if mask.shape != (G, k):
                raise ValueError(
                    f"mask must be (G, k) = {(G, k)}, got {mask.shape}"
                )
        gslots = np.asarray(slots, np.int64).reshape(-1)
        S, C_l = self.n_shards, self.shard_capacity
        lslots, pos, Qs = _group_slots(gslots, C_l, S)
        Xp = np.zeros((S * Qs, k, p), np.float32)
        yp = np.zeros((S * Qs, k), np.float32)
        mp = np.zeros((S * Qs, k), np.float32)
        Xp[pos] = Xg
        yp[pos] = yg
        mp[pos] = mask
        shd = _leaf_sharding(self.mesh)
        put = lambda a: jax.device_put(a, shd)
        return put(lslots), put(Xp), put(yp), put(mp), pos

    def _update_at_slots(self, slots, Xk, yk, mask=None,
                         donate: bool = False) -> "ShardedGPBank":
        """Slot-addressed core of :meth:`update` (global slots; the
        router's fixed-shape entry).  ``donate`` is accepted for router
        compatibility and ignored — the sharded scatter carries the eager
        B^{-1} refresh in the same executable, and donation is a no-op on
        the host-platform devices this mode targets."""
        lslots, Xp, yp, mp, _ = self._group_scatter_args(slots, Xk, yk, mask)
        st = self.stack
        chol, u, b, binv = _sh_update_scatter(
            st.chol, st.u, st.b, st.sqrtlam, self.binv, lslots, Xp, yp, mp,
            self.spec, st.idx, self.mesh,
        )
        stack = dataclasses.replace(st, chol=chol, u=u, b=b)
        return dataclasses.replace(self, stack=stack, binv=binv)

    def downdate(self, tenant_ids, Xk, yk, mask=None):
        """Batched rank-k forget (same contract as
        :meth:`GPBank.downdate`): returns ``(bank, ok)``."""
        Xk = np.asarray(Xk, np.float32)
        yk = np.asarray(yk, np.float32)
        if Xk.ndim != 3 or yk.shape != Xk.shape[:2]:
            raise ValueError(
                f"downdate wants Xk (G, k, p) and yk (G, k); got "
                f"{Xk.shape} and {yk.shape}"
            )
        ids = list(tenant_ids)
        if len(set(ids)) != len(ids):
            raise ValueError(
                f"duplicate tenant in one downdate batch ({ids!r}): the "
                f"scattered writes would collide — split into rounds"
            )
        if len(ids) != Xk.shape[0]:
            raise ValueError(
                f"one tenant id per downdate group: got {len(ids)} ids "
                f"for {Xk.shape[0]} groups"
            )
        return self._downdate_at_slots(self._slots_np(ids), Xk, yk, mask)

    def _downdate_at_slots(self, slots, Xk, yk, mask=None):
        lslots, Xp, yp, mp, pos = self._group_scatter_args(
            slots, Xk, yk, mask
        )
        st = self.stack
        chol, u, b, binv, ok = _sh_downdate_scatter(
            st.chol, st.u, st.b, st.sqrtlam, self.binv, lslots, Xp, yp, mp,
            self.spec, st.idx, self.mesh,
        )
        stack = dataclasses.replace(st, chol=chol, u=u, b=b)
        new = dataclasses.replace(self, stack=stack, binv=binv)
        return new, np.asarray(ok)[pos]

    def refit_window(self, tenant_ids, Xw, yw, mask=None) -> "ShardedGPBank":
        """Window refit fallback (same contract as
        :meth:`GPBank.refit_window`)."""
        Xw = np.asarray(Xw, np.float32)
        yw = np.asarray(yw, np.float32)
        if Xw.ndim != 3 or yw.shape != Xw.shape[:2]:
            raise ValueError(
                f"refit_window wants Xw (G, W, p) and yw (G, W); got "
                f"{Xw.shape} and {yw.shape}"
            )
        ids = list(tenant_ids)
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate tenant in one refit batch ({ids!r})")
        if len(ids) != Xw.shape[0]:
            raise ValueError(
                f"one tenant id per refit group: got {len(ids)} ids for "
                f"{Xw.shape[0]} groups"
            )
        return self._refit_at_slots(self._slots_np(ids), Xw, yw, mask)

    def _refit_at_slots(self, slots, Xw, yw, mask=None) -> "ShardedGPBank":
        lslots, Xp, yp, mp, _ = self._group_scatter_args(slots, Xw, yw, mask)
        W = Xp.shape[1]
        spec_r = self.spec.replace(
            block_rows=min(self.spec.block_rows, max(1, W))
        )
        st = self.stack
        chol, u, b, lam, sqrtlam, binv = _sh_refit_scatter(
            st.chol, st.u, st.b, st.lam, st.sqrtlam, self.binv, lslots,
            Xp, yp, mp, spec_r, st.idx, self.mesh,
        )
        stack = dataclasses.replace(st, chol=chol, u=u, b=b, lam=lam,
                                    sqrtlam=sqrtlam)
        return dataclasses.replace(self, stack=stack, binv=binv)

    # -- membership churn (traced slot: zero recompiles per shard) ----------

    def _free_slot_on(self, shard: int) -> Optional[int]:
        C_l = self.shard_capacity
        free = np.flatnonzero(~self.active[shard * C_l:(shard + 1) * C_l])
        return None if free.size == 0 else shard * C_l + int(free[0])

    def _placement_shard(self) -> int:
        """Least-loaded shard with a free slot (ties -> lowest id) — the
        placement policy; ``TieredBank`` cold-restores inherit it through
        :meth:`insert`."""
        occ = self.shard_occupancy()
        order = np.lexsort((np.arange(self.n_shards), occ))
        C_l = self.shard_capacity
        for s in order:
            if occ[s] < C_l:
                return int(s)
        raise ValueError(
            f"bank is full ({self.capacity} slots); evict a tenant or "
            f"rebuild with a larger capacity"
        )

    def _write(self, gslot: int, leaves) -> FAGPState:
        st = self.stack
        chol, u, b, lam, sqrtlam, binv = _sh_write_slot(
            st.chol, st.u, st.b, st.lam, st.sqrtlam, self.binv,
            jnp.int32(gslot), leaves["chol"], leaves["u"], leaves["b"],
            leaves["lam"], leaves["sqrtlam"], self.mesh,
        )
        stack = dataclasses.replace(st, chol=chol, u=u, b=b, lam=lam,
                                    sqrtlam=sqrtlam)
        return stack, binv

    def insert(self, tenant: Hashable, source) -> "ShardedGPBank":
        """Add a tenant on the least-loaded shard (same source contract as
        :meth:`GPBank.insert`; one traced-slot executable regardless of
        slot or shard)."""
        if tenant in self.slots:
            raise ValueError(f"tenant {tenant!r} already in the bank")
        shard = self._placement_shard()
        slot = self._free_slot_on(shard)
        if isinstance(source, tuple):
            X, y = source
            st = fagp.fit(jnp.asarray(X), jnp.asarray(y), self.spec)
        else:
            st = source.state if isinstance(source, GP) else source
        _check_bankable(st, self.spec, f"insert({tenant!r})")
        stack, binv = self._write(slot, {
            "chol": st.chol, "u": st.u, "b": st.b, "lam": st.lam,
            "sqrtlam": st.sqrtlam,
        })
        active = self.active.copy()
        active[slot] = True
        slots = dict(self.slots)
        slots[tenant] = slot
        return dataclasses.replace(self, stack=stack, binv=binv,
                                   active=active, slots=slots)

    def evict(self, tenant: Hashable) -> "ShardedGPBank":
        """Remove a tenant; its slot resets to the prior state — same
        executable as :meth:`insert`."""
        slot = self.slot_of(tenant)
        loglam = get_expansion(self.spec.expansion).log_eigenvalues(
            self.stack.idx, self.spec
        )
        prior = _prior_leaves(loglam, 1)
        stack, binv = self._write(slot, {f: prior[f][0] for f in prior})
        active = self.active.copy()
        active[slot] = False
        slots = {t: s for t, s in self.slots.items() if t != tenant}
        return dataclasses.replace(self, stack=stack, binv=binv,
                                   active=active, slots=slots)

    # -- cross-shard rebalancing -------------------------------------------

    def rebalance(self, max_moves: Optional[int] = None):
        """Move tenants from the fullest shards to the emptiest until the
        occupancy spread is <= 1 (or ``max_moves`` is hit).  Each move is
        one traced-slot gather plus two traced-slot writes — zero new
        executables however the fleet churned.  Deterministic: donor is
        the fullest shard (ties -> lowest id), the migrant its
        highest-numbered occupied local slot.

        Returns ``(bank, moves)``."""
        bank = self
        moves = 0
        C_l = self.shard_capacity
        while max_moves is None or moves < max_moves:
            occ = bank.shard_occupancy()
            donor = int(np.lexsort((np.arange(len(occ)), -occ))[0])
            recv = int(np.lexsort((np.arange(len(occ)), occ))[0])
            if occ[donor] - occ[recv] <= 1:
                break
            local = np.flatnonzero(bank.active[donor * C_l:(donor + 1) * C_l])
            src = donor * C_l + int(local[-1])
            tenant = next(t for t, s in bank.slots.items() if s == src)
            dst = bank._free_slot_on(recv)
            st = bank.stack
            chol, u, b, lam, sqrtlam = _sh_read_slot(
                st.chol, st.u, st.b, st.lam, st.sqrtlam, jnp.int32(src)
            )
            stack, binv = bank._write(dst, {
                "chol": chol, "u": u, "b": b, "lam": lam,
                "sqrtlam": sqrtlam,
            })
            bank = dataclasses.replace(bank, stack=stack, binv=binv)
            loglam = get_expansion(bank.spec.expansion).log_eigenvalues(
                bank.stack.idx, bank.spec
            )
            prior = _prior_leaves(loglam, 1)
            stack, binv = bank._write(src, {f: prior[f][0] for f in prior})
            active = bank.active.copy()
            active[src] = False
            active[dst] = True
            slots = dict(bank.slots)
            slots[tenant] = dst
            bank = dataclasses.replace(bank, stack=stack, binv=binv,
                                       active=active, slots=slots)
            moves += 1
        return bank, moves

    # -- unsupported resident-only surface ---------------------------------

    def optimize(self, *a, **k):
        raise NotImplementedError(
            "fleet hyperparameter optimization produces a heterogeneous "
            "bank, which has no shard-local serving path yet — "
            "to_bank().optimize(...) and re-shard after"
        )
