"""BankRouter — per-tenant queues coalesced into fixed-shape fleet batches.

A serving frontend for :class:`~repro.bank.GPBank`: callers enqueue work
addressed to individual tenants; the router coalesces everything pending
into *padded mixed-tenant microbatches* of one fixed shape, so the whole
fleet is served by exactly one compiled executable per (microbatch, p)
shape — no matter how many tenants exist or how unevenly traffic is
distributed across them.

Two paths:

* **Queries** — :meth:`submit` enqueues a query row for a tenant and
  returns a ticket; :meth:`flush` packs all pending rows (arrival order)
  into (microbatch, p) blocks, pads the tail by *repeating the last real
  row* (same shapes, results discarded), answers each block with one
  ``GPBank.mean_var`` call, and returns ``ticket -> (mu, var)``.  Results
  are keyed by ticket, so interleaved multi-tenant traffic keeps its
  per-caller association no matter how the batcher reorders rows.
* **Observations** — :meth:`observe` enqueues an (x, y) pair for a tenant;
  :meth:`ingest` groups pending observations by tenant, pads each group to
  a fixed chunk of ``ingest_chunk`` rows (row-masked, so padding is
  mathematically inert), and absorbs them with batched
  ``GPBank.update`` calls.  A tenant with more than one chunk pending is
  scheduled across *rounds* (distinct-tenant batches), because two updates
  to one factorization cannot commute within a single scattered write.

The router owns the bank reference: :meth:`ingest` replaces it with the
updated (immutable) bank, and subsequent :meth:`flush` calls serve the new
posterior.

It also tracks per-tenant *staleness* (rows absorbed since the tenant's
hyperparameters were last optimized): :meth:`stale_tenants` names the
tenants due for re-optimization and :meth:`reoptimize` runs one batched
``GPBank.optimize`` over them and swaps the heterogeneous result in — the
periodic re-optimization hook ``serve_fleet`` drives.
"""
from __future__ import annotations

from typing import Hashable, Optional

import jax.numpy as jnp
import numpy as np

from ..obs import metrics as obs_metrics
from ..obs.trace import NULL_TRACER
from .bank import GPBank

__all__ = ["BankRouter"]


class BankRouter:
    """See module docstring.  Not thread-safe; one router per serving loop.

    ``metrics=`` / ``tracer=`` (``repro.obs``) light up telemetry:
    counters for flushed blocks, ingested rows/rounds and reoptimized
    tenants, and spans around flush, each ingest round, and reoptimize —
    recorded at block/round granularity, never per row.  Both default to
    no-ops."""

    def __init__(self, bank: GPBank, *, microbatch: int = 64,
                 ingest_chunk: int = 16, donate_updates: bool = False,
                 metrics: Optional[obs_metrics.MetricsRegistry] = None,
                 tracer=None):
        if microbatch < 1 or ingest_chunk < 1:
            raise ValueError("microbatch and ingest_chunk must be >= 1")
        reg = obs_metrics.NULL if metrics is None else metrics
        self.registry = reg
        self.tracer = NULL_TRACER if tracer is None else tracer
        self._c_flush_blocks = reg.counter(
            "router_flush_blocks_total", "padded blocks served by flush")
        self._c_ingest_rows = reg.counter(
            "router_ingest_rows_total", "observation rows absorbed")
        self._c_ingest_rounds = reg.counter(
            "router_ingest_rounds_total", "distinct-tenant update rounds")
        self._c_reopt_rounds = reg.counter(
            "router_reopt_rounds_total", "batched reoptimize calls")
        self._c_reopt_tenants = reg.counter(
            "router_reopt_tenants_total", "tenants reoptimized")
        self._c_rebalance = reg.counter(
            "bank_rebalance_total", "cross-shard tenant moves applied by "
            "rebalance")
        reg.add_collector(self._publish_shards)
        self.bank = bank
        self.microbatch = int(microbatch)
        self.ingest_chunk = int(ingest_chunk)
        # donate the pre-update stack buffers into each ingest round's
        # scattered write (device memory reuse for dispatch-ahead serving).
        # Only safe when this router's bank is the ONLY live reference to
        # those buffers — FleetEngine owns its bank exclusively and opts
        # in; anything holding older bank versions must leave this off.
        self.donate_updates = bool(donate_updates)
        self._pending: list[tuple[int, Hashable, np.ndarray]] = []
        self._observations: dict[Hashable, list[tuple[np.ndarray, float]]] = {}
        self._next_ticket = 0
        # rows absorbed per tenant since its hyperparameters were last
        # (re)optimized — the staleness signal for periodic re-optimization
        self._since_reopt: dict[Hashable, int] = {}

    # -- shard placement awareness ------------------------------------------

    @property
    def _sharded(self) -> bool:
        return getattr(self.bank, "mesh", None) is not None

    def shard_backlogs(self) -> np.ndarray:
        """(S,) pending query rows per shard (empty array when the bank is
        not sharded) — the router-side load signal that pairs with the
        bank's occupancy for placement decisions."""
        if not self._sharded:
            return np.zeros(0, np.int64)
        depth = np.zeros(self.bank.n_shards, np.int64)
        for _, tenant, _ in self._pending:
            if tenant in self.bank.slots:
                depth[self.bank.shard_of(tenant)] += 1
        return depth

    def _publish_shards(self) -> None:
        """Scrape-time collector: per-shard occupancy and backlog gauges
        (registered only while the bank is sharded)."""
        if not self._sharded:
            return
        occ = self.bank.shard_occupancy()
        backlog = self.shard_backlogs()
        for s in range(self.bank.n_shards):
            self.registry.gauge(
                "bank_shard_occupancy", "active tenants on this shard",
                shard=s,
            ).set(int(occ[s]))
            self.registry.gauge(
                "bank_shard_backlog", "pending query rows bound for this "
                "shard", shard=s,
            ).set(int(backlog[s]))

    def rebalance(self, *, threshold: int = 2,
                  max_moves: Optional[int] = None) -> int:
        """Even out per-shard occupancy when the spread reaches
        ``threshold``: swap in a rebalanced bank
        (:meth:`~repro.bank.ShardedGPBank.rebalance` — traced-slot moves,
        zero recompiles) and count the moves.  No-op on resident banks and
        balanced fleets; returns the number of tenants moved."""
        if not self._sharded:
            return 0
        occ = self.bank.shard_occupancy()
        if int(occ.max()) - int(occ.min()) < max(1, int(threshold)):
            return 0
        with self.tracer.span("rebalance", spread=int(occ.max() - occ.min())):
            self.bank, moves = self.bank.rebalance(max_moves=max_moves)
        self._c_rebalance.inc(moves)
        return moves

    # -- query path ---------------------------------------------------------

    def submit(self, tenant: Hashable, x) -> int:
        """Enqueue one query row for ``tenant``; returns a ticket redeemed
        by the next :meth:`flush`."""
        self.bank.slot_of(tenant)  # fail fast on unknown tenants
        x = np.asarray(x, np.float32).reshape(-1)
        if x.shape[0] != self.bank.spec.p:
            raise ValueError(
                f"query row has p={x.shape[0]}, bank serves p="
                f"{self.bank.spec.p}"
            )
        ticket = self._next_ticket
        self._next_ticket += 1
        self._pending.append((ticket, tenant, x))
        return ticket

    @property
    def pending(self) -> int:
        return len(self._pending)

    def take(self, k: int) -> list:
        """Pop up to ``k`` pending query entries in arrival order — the
        dispatch feed for an external pipelined engine
        (:class:`~repro.bank.FleetEngine`).  Entries are opaque
        ``(ticket, tenant, x)`` triples meant to round-trip through
        :meth:`requeue` / ``_pack_block``."""
        k = max(0, int(k))
        taken, self._pending = self._pending[:k], self._pending[k:]
        return taken

    def requeue(self, entries) -> None:
        """Push taken entries back to the FRONT of the queue (a dispatch
        failed before its results existed) — arrival order is preserved,
        every ticket stays redeemable."""
        self._pending = list(entries) + self._pending

    def _pack_block(self, block, size: int):
        """Pad a taken block to ``size`` rows by repeating the last real
        row (fixed shapes; padded results are discarded).  Returns
        (tenant list, (size, p) float32 array) — the ONE packing used by
        :meth:`flush` and the engine's dispatch path."""
        pad = size - len(block)
        tenants = [t for _, t, _ in block] + [block[-1][1]] * pad
        Xq = np.stack([x for _, _, x in block] + [block[-1][2]] * pad)
        return tenants, Xq

    def flush(self) -> dict:
        """Serve every pending query; returns ``ticket -> (mu, var)``
        (floats).  Pending rows are packed in arrival order into fixed
        (microbatch, p) blocks — one executable regardless of the tenant
        mix — and the padded tail's results are discarded.

        If a block fails mid-flush (e.g. a queued tenant was evicted from a
        bank swapped in behind the router's back), the WHOLE backlog —
        served blocks included, since queries are idempotent reads whose
        results would otherwise be discarded with the exception — is
        restored to the queue before the error propagates, so every ticket
        stays redeemable by a later flush once the caller repairs the
        bank."""
        if not self._pending:
            return {}
        todo, self._pending = self._pending, []
        out: dict[int, tuple[float, float]] = {}
        mb = self.microbatch
        with self.tracer.span("flush", rows=len(todo)):
            for lo in range(0, len(todo), mb):
                block = todo[lo : lo + mb]
                tenants, Xq = self._pack_block(block, mb)
                try:
                    mu, var = self.bank.mean_var(tenants, jnp.asarray(Xq))
                except Exception:
                    self._pending = todo + self._pending
                    raise
                mu = np.asarray(mu)
                var = np.asarray(var)
                for i, (ticket, _, _) in enumerate(block):
                    out[ticket] = (float(mu[i]), float(var[i]))
                self._c_flush_blocks.inc()
        return out

    # -- ingest path --------------------------------------------------------

    def observe(self, tenant: Hashable, x, y) -> None:
        """Enqueue one observation (x, y) for ``tenant``; absorbed by the
        next :meth:`ingest`."""
        self.bank.slot_of(tenant)
        x = np.asarray(x, np.float32).reshape(-1)
        if x.shape[0] != self.bank.spec.p:
            raise ValueError(
                f"observation row has p={x.shape[0]}, bank serves p="
                f"{self.bank.spec.p}"
            )
        self._observations.setdefault(tenant, []).append((x, float(y)))

    def ingest(self) -> int:
        """Absorb every pending observation through batched
        ``GPBank.update`` rounds; returns the number of rows absorbed.
        Each round is a distinct-tenant batch: per-tenant chunks are padded
        to ``ingest_chunk`` rows and row-masked, and tenants with several
        chunks pending are spread across successive rounds.  The group
        axis is padded to a power-of-two bucket with fully-masked identity
        groups aimed at distinct unused slots, so at most log2(capacity)
        update executables ever exist no matter how the tenant mix varies
        round to round.

        If a round fails (e.g. a queued tenant was evicted from a bank
        swapped in behind the router's back), the current round's rows and
        everything still queued are restored to the observation queue
        before the error propagates — earlier rounds stay absorbed (their
        updates already landed), nothing is silently dropped."""
        if not self._observations:
            return 0
        queues = {t: list(rows) for t, rows in self._observations.items()}
        self._observations = {}
        k = self.ingest_chunk
        absorbed = 0
        p = self.bank.spec.p
        while queues:
            slots, Xg, yg, mg = [], [], [], []
            taken: dict[Hashable, list] = {}
            round_span = self.tracer.span("ingest", tenants=len(queues))
            round_span.__enter__()
            try:
                for t in list(queues):
                    rows, rest = queues[t][:k], queues[t][k:]
                    if rest:
                        queues[t] = rest
                    else:
                        del queues[t]
                    taken[t] = rows
                    X = np.zeros((k, p), np.float32)
                    y = np.zeros((k,), np.float32)
                    m = np.zeros((k,), np.float32)
                    for i, (x, yv) in enumerate(rows):
                        X[i], y[i], m[i] = x, yv, 1.0
                    slots.append(self.bank.slot_of(t))
                    Xg.append(X)
                    yg.append(y)
                    mg.append(m)
                # pad the group axis to a shape bucket (masked identity
                # groups on distinct unused slots — GPBank._update_at_slots).
                # A sharded bank pads per shard internally (its microbatch
                # buckets are per-shard), so global padding would only
                # inflate the busiest shard's rung.
                G = len(slots)
                if self._sharded:
                    shard_groups = np.bincount(
                        np.asarray(slots) // self.bank.shard_capacity,
                        minlength=self.bank.n_shards,
                    )
                    for s in np.flatnonzero(shard_groups):
                        self.tracer.instant(
                            "shard_ingest", shard_id=int(s),
                            groups=int(shard_groups[s]),
                        )
                else:
                    bucket = min(self.bank.capacity,
                                 1 << (G - 1).bit_length())
                    if bucket > G:
                        used = set(slots)
                        free = (s for s in range(self.bank.capacity)
                                if s not in used)
                        for _ in range(bucket - G):
                            slots.append(next(free))
                            Xg.append(np.zeros((k, p), np.float32))
                            yg.append(np.zeros((k,), np.float32))
                            mg.append(np.zeros((k,), np.float32))
                self.bank = self.bank._update_at_slots(
                    jnp.asarray(np.array(slots, np.int32)),
                    jnp.asarray(np.stack(Xg)), jnp.asarray(np.stack(yg)),
                    jnp.asarray(np.stack(mg)),
                    donate=self.donate_updates,
                )
            except Exception:
                for t, rows in taken.items():
                    queues[t] = rows + queues.get(t, [])
                for t, rows in queues.items():
                    self._observations[t] = rows + self._observations.get(
                        t, []
                    )
                raise
            finally:
                round_span.__exit__(None, None, None)
            round_rows = sum(len(rows) for rows in taken.values())
            absorbed += round_rows
            self._c_ingest_rounds.inc()
            self._c_ingest_rows.inc(round_rows)
            for t, rows in taken.items():
                self._since_reopt[t] = self._since_reopt.get(t, 0) + len(rows)
        return absorbed

    # -- staleness + periodic re-optimization -------------------------------

    def stale_tenants(self, min_rows: int, *, retain=()) -> list:
        """Tenants that absorbed at least ``min_rows`` observations since
        their hyperparameters were last optimized (insertion order) — the
        candidates for the next :meth:`reoptimize` round.

        Counters for tenants no longer in the bank are dropped here, so an
        id evicted and later re-inserted starts fresh instead of
        inheriting its previous life's count.  (An evict + same-id
        re-insert that happens entirely between two router calls is
        indistinguishable from the tenant never leaving — swap banks
        through a fresh router if that distinction matters.)

        ``retain`` names tenants whose counters survive even while absent
        from the bank: a :class:`~repro.bank.TieredBank` pages tenants to
        a cold tier and back, and a cold tenant's drift record must not
        reset just because it was evicted for capacity (pass
        ``retain=tiered.tenants``).  Retained-but-cold tenants are still
        never RETURNED as stale — they are not servable until paged in."""
        keep = set(retain)
        self._since_reopt = {
            t: c for t, c in self._since_reopt.items()
            if t in self.bank.slots or t in keep
        }
        return [
            t for t in self.bank.slots
            if self._since_reopt.get(t, 0) >= min_rows
        ]

    def reoptimize(self, tenant_ids, Xb, yb, mask=None, **kw) -> None:
        """Re-learn hyperparameters for ``tenant_ids`` (typically
        :meth:`stale_tenants`) from their accumulated data and swap the
        optimized bank in behind the router: one batched
        ``GPBank.optimize`` run (``**kw`` forwards restarts/steps/lr/tol/
        seed), staleness counters reset on success.  The serving loop
        (``repro.launch.serve_gp.serve_fleet``) calls this every few
        rounds so drifting tenants do not serve stale lengthscales
        forever."""
        ids = list(tenant_ids)
        if not ids:
            return
        if self.registry is not obs_metrics.NULL:
            kw.setdefault("metrics", self.registry)
        if self.tracer is not NULL_TRACER:
            kw.setdefault("tracer", self.tracer)
        with self.tracer.span("reopt", tenants=len(ids)):
            self.bank = self.bank.optimize(
                Xb, yb, tenant_ids=ids, mask=mask, **kw
            )
        self._c_reopt_rounds.inc()
        self._c_reopt_tenants.inc(len(ids))
        for t in ids:
            self._since_reopt[t] = 0
