"""FleetEngine — pipelined, latency-bounded serving on top of BankRouter.

The synchronous loop (``BankRouter.flush``) serializes host and device:
pack a microbatch, dispatch it, *block* for the result, convert, repeat —
device idles while Python packs, host idles while XLA executes.  The
engine removes every per-tick barrier:

* **Dispatch-ahead** — :meth:`pump` packs a padded block and calls
  ``GPBank.mean_var`` *without blocking*: JAX dispatch is asynchronous, so
  the returned device arrays are futures.  Up to ``max_in_flight`` blocks
  ride the device queue while the host packs the next one;
  :meth:`harvest` collects blocks whose results have landed
  (``GPBank.result_ready``) and only ever blocks when asked to
  (``wait=True``).  Ingest can additionally donate the old stack buffers
  into the update (``BankRouter(donate_updates=True)``) so dispatch-ahead
  updates reuse device memory instead of doubling it.
* **Admission + deadlines** — :meth:`submit` enforces a queue budget
  (``QueueFull`` when ``pending + in-flight`` rows exceed it: shed load at
  the door, not after paying for padding) and stamps each ticket with a
  deadline (per-call ``deadline_s``, else the tenant's SLO in ``slo_s``,
  else ``default_slo_s``).  A ticket that expires before dispatch is
  answered with the documented timeout sentinel — ``mu = NaN``,
  ``var = inf``, ``timed_out=True`` (:data:`TIMEOUT_MU` /
  :data:`TIMEOUT_VAR`) — immediately, and never holds a seat in a padded
  block or stalls tickets behind it.  Once a ticket is dispatched its
  result is always delivered; deadlines gate admission to the device, not
  result delivery.
* **Bucket autotuning** — instead of one fixed microbatch, the dispatched
  block size is chosen per block from the *observed arrival rate* (EWMA of
  inter-submit gaps) times the EWMA block service time, rounded up to a
  power of two: light traffic gets small low-latency blocks, heavy
  traffic gets large amortizing ones — up to ``max_coalesce``
  microbatches fused into ONE dispatch when the arrival rate sustains it
  (per-dispatch host overhead is the dominant serving cost at these
  shapes, so coalescing is where the pipelined throughput win comes
  from).  When a fleet-wide SLO is configured the bucket is additionally
  capped so a ticket does not wait out its whole deadline just filling a
  block.  The bucket set is FIXED (powers of two up to
  ``microbatch * max_coalesce``), so at most ``log2`` -many serving
  executables ever exist no matter how traffic churns — the same
  shape-bucketing contract as the router's ingest group axis, pinned by
  jit cache-miss counts in ``tests/test_serve_engine.py``.
* **Lean dispatch** — the engine does not pay ``GPBank.mean_var``'s
  public-API toll (per-row tenant validation, backend re-resolution,
  redundant conversions) per block: it resolves the slot map, backend
  function and auxiliaries ONCE per bank version (the cache is keyed on
  the bank's object identity, so ingest/reoptimize swaps invalidate it
  automatically) and dispatches the underlying jitted executable
  directly.
* **Latency observability** — every completed ticket records its
  submit→harvest latency per tenant into a BOUNDED reservoir
  (:class:`LatencyStats`); :meth:`metrics` reports per-tenant and overall
  p50/p99 (exactly ``numpy.percentile`` over the reservoir), timeout
  counts, bucket usage, and sustained queries/s over the engine's
  lifetime.  Passing ``metrics=`` / ``tracer=`` / ``watchdog=``
  (``repro.obs``) additionally lights up fleet telemetry: pipeline-stage
  spans at block granularity (bucket_select, coalesce, dispatch,
  device_wait, harvest, expire, page_in; per-query admit events sampled
  1-in-256 so tracing cannot blow the latency budget), registry counters
  and gauges flushed through a scrape-time collector (the serving loop
  never pays per-event registry costs beyond one histogram record per
  block), and a :class:`~repro.obs.RecompileWatchdog` check per pump so
  a shape leak past the bucket ladder is reported at the block where it
  compiled.  All three default to no-ops costing one attribute lookup.

Failure containment matches the router's contract: a dispatch that raises
mid-flight requeues its block at the FRONT of the router backlog before
the error propagates — every ticket stays redeemable and the bank state is
untouched (queries are reads; a failed ingest round restores its rows via
``BankRouter.ingest``).

Not thread-safe; one engine per serving loop, and the engine assumes it is
the only writer of its router's bank.
"""
from __future__ import annotations

import dataclasses
import math
import random
import time
from collections import Counter, deque
from typing import Callable, Hashable, Mapping, NamedTuple, Optional

import numpy as np

from ..core import fagp
from ..obs import metrics as obs_metrics
from ..obs.trace import NULL_TRACER, NullTracer
from . import bank as bank_mod
from .bank import GPBank
from .router import BankRouter

__all__ = [
    "FleetEngine", "LatencyStats", "QueueFull", "TicketResult",
    "TIMEOUT_MU", "TIMEOUT_VAR",
]

# The documented deadline-timeout sentinel: deterministic, impossible to
# mistake for a real posterior (real variances are finite, real means are
# finite), and carried next to an explicit ``timed_out`` flag.
TIMEOUT_MU = float("nan")
TIMEOUT_VAR = float("inf")


class QueueFull(RuntimeError):
    """Admission refused: queue depth (pending + in-flight rows) is at the
    engine's ``queue_budget``.  Backpressure happens at :meth:`submit`
    time so overload sheds load instead of growing an unbounded backlog."""


class TicketResult(NamedTuple):
    """One redeemed ticket.  ``timed_out`` results carry the sentinel
    values (``mu = NaN``, ``var = inf``); completed results carry the
    posterior and the submit→harvest latency.  (A NamedTuple, not a
    dataclass: one is constructed per served query on the harvest hot
    path, and tuple construction is several times cheaper.)"""

    mu: float
    var: float
    timed_out: bool = False
    latency_s: float = 0.0

    @property
    def ok(self) -> bool:
        return not self.timed_out


class LatencyStats:
    """Per-tenant latency samples + timeout counters, BOUNDED memory.

    Each tenant's samples live in a uniform reservoir (Vitter's
    Algorithm R) capped at ``bound`` entries: up to the bound every
    sample is retained and percentiles are EXACT; past it each new
    sample replaces a uniformly random slot with probability
    ``bound / n``, so the buffer stays a uniform random sample of the
    WHOLE stream and ``percentiles()`` returns the classical
    reservoir-sample estimator (unbiased order-statistic probabilities,
    error ~O(1/sqrt(bound)) in rank).  Under sustained traffic memory is
    O(tenants x bound) forever, instead of growing per served query.

    Percentiles are computed with ``numpy.percentile`` (linear
    interpolation — the reference semantics the unit tests pin), over
    COMPLETED tickets only; timeouts are counted separately so an SLO
    breach cannot hide inside a rosy p99.  ``counts`` tracks the TRUE
    per-tenant totals regardless of the bound; ``samples`` maps tenant
    -> current reservoir contents (arrival order below the bound).
    """

    def __init__(self, *, bound: int = 4096, seed: int = 0) -> None:
        if bound < 1:
            raise ValueError("bound must be >= 1")
        self.bound = int(bound)
        self.samples: dict[Hashable, list] = {}
        self.counts: Counter = Counter()
        self.timeouts: Counter = Counter()
        self._rng = random.Random(seed)

    def record(self, tenant: Hashable, seconds: float) -> None:
        buf = self.samples.get(tenant)
        if buf is None:
            buf = self.samples[tenant] = []
        n = self.counts[tenant]
        self.counts[tenant] = n + 1
        if n < self.bound:
            buf.append(float(seconds))
        else:
            j = self._rng.randrange(n + 1)
            if j < self.bound:
                buf[j] = float(seconds)

    def record_timeout(self, tenant: Hashable) -> None:
        self.timeouts[tenant] += 1

    def count(self, tenant: Hashable) -> int:
        """TRUE number of recorded samples (not capped at the bound)."""
        return int(self.counts[tenant])

    def percentiles(self, tenant: Optional[Hashable] = None,
                    qs=(50.0, 99.0)) -> tuple:
        """(p50, p99, ...) seconds for one tenant (or pooled over all when
        ``tenant`` is None); NaNs when no samples.  Exact while every
        reservoir is below its bound; the reservoir estimator above."""
        if tenant is None:
            vals = [s for lst in self.samples.values() for s in lst]
        else:
            vals = self.samples.get(tenant, [])
        if not vals:
            return tuple(float("nan") for _ in qs)
        return tuple(float(v) for v in np.percentile(np.asarray(vals),
                                                     list(qs)))


@dataclasses.dataclass
class _InFlight:
    """One dispatched block: its tickets and the un-harvested device
    arrays (JAX futures)."""

    entries: list           # [(ticket, tenant, x), ...] — real rows only
    mu: object              # device array, (bucket,)
    var: object             # device array, (bucket,)
    bucket: int
    t_dispatch: float
    # sharded dispatch: entry i's result sits at packed position order[i]
    # (per-shard packed layout); None for resident banks (identity)
    order: object = None


def _pow2_buckets(microbatch: int, max_coalesce: int = 1) -> tuple:
    """The fixed bucket ladder: powers of two below ``microbatch``, then
    ``microbatch`` itself, then its power-of-two multiples up to
    ``microbatch * max_coalesce`` — one compiled serving executable per
    rung, and never a new one no matter how traffic churns."""
    out = []
    b = 1
    while b < microbatch:
        out.append(b)
        b *= 2
    top = microbatch * max(1, int(max_coalesce))
    b = microbatch
    while b < top:
        out.append(b)
        b *= 2
    out.append(b)
    return tuple(out)


class FleetEngine:
    """See module docstring.

    router:        the :class:`BankRouter` whose bank this engine serves.
                   The engine owns the router's queues; drive ALL traffic
                   through the engine once it exists.
    max_in_flight: dispatch-ahead depth — blocks riding the device queue
                   before :meth:`pump` stops dispatching.
    queue_budget:  admission bound on pending + in-flight rows.
    max_coalesce:  how many microbatches the autotuner may fuse into one
                   dispatch under sustained load (rounded up to a power
                   of two; 1 = never exceed the router's microbatch).
    default_slo_s: deadline stamped on tickets with no explicit
                   ``deadline_s`` and no per-tenant SLO (None = no
                   deadline).
    slo_s:         per-tenant deadline overrides (tenant -> seconds).
    auto_pump:     dispatch opportunistically from :meth:`submit` once a
                   bucketful is waiting (the steady-state pipelining
                   mode); disable for manual pump/harvest control.
    tiered:        a :class:`~repro.bank.TieredBank` fronting the router's
                   bank with a cold tier.  With it, :meth:`submit` /
                   :meth:`observe` accept COLD tenants: the engine pages
                   them in through the tier (recompile-free warm restore;
                   the LRU victim goes to the cold tier) and swaps the
                   restored bank into the router.  In-flight blocks are
                   never stalled by a page-in — banks are immutable, so
                   already-dispatched futures keep computing against the
                   pre-swap stack while new dispatches see the new one
                   (the dispatch cache is keyed on bank identity).
                   Tenants with pending or in-flight work are pinned
                   against eviction.  :meth:`ingest` additionally feeds
                   absorbed rows into the tier's sliding-window
                   bookkeeping.
    clock:         injectable monotonic clock (tests drive deadlines
                   deterministically with a fake one).
    metrics:       a :class:`repro.obs.MetricsRegistry`; the engine
                   registers a scrape-time collector flushing its
                   counters/gauges (admitted, completed, expired,
                   queue-full rejections, page-ins, per-bucket dispatch
                   counts, queue depth, in-flight rows, latency
                   quantiles) into it.  Default: the no-op NULL registry.
    tracer:        a :class:`repro.obs.Tracer`; pipeline stages emit
                   spans at block granularity plus 1-in-64-sampled
                   per-query ``admit`` events.  Default: no-op.
    watchdog:      a :class:`repro.obs.RecompileWatchdog`; checked after
                   every pump so a serving-path recompile is reported at
                   the block that caused it.  Default: None (no checks).
    """

    def __init__(
        self,
        router: BankRouter,
        *,
        max_in_flight: int = 4,
        queue_budget: int = 4096,
        max_coalesce: int = 4,
        default_slo_s: Optional[float] = None,
        slo_s: Optional[Mapping[Hashable, float]] = None,
        auto_pump: bool = True,
        tiered=None,
        clock: Callable[[], float] = time.perf_counter,
        metrics: Optional[obs_metrics.MetricsRegistry] = None,
        tracer=None,
        watchdog=None,
    ):
        if max_in_flight < 1:
            raise ValueError("max_in_flight must be >= 1")
        if queue_budget < 1:
            raise ValueError("queue_budget must be >= 1")
        self.router = router
        self.max_in_flight = int(max_in_flight)
        self.queue_budget = int(queue_budget)
        self.default_slo_s = default_slo_s
        self.slo_s = dict(slo_s or {})
        self.auto_pump = bool(auto_pump)
        self.tiered = tiered
        if tiered is not None and tiered.bank is not router.bank:
            tiered.adopt(router.bank)
        self._clock = clock
        self.stats = LatencyStats()
        self.buckets = _pow2_buckets(router.microbatch, max_coalesce)
        self.bucket_uses: Counter = Counter()
        # lean-dispatch cache: (bank identity, slot map, dispatch fn) —
        # rebuilt whenever the router's bank is swapped (ingest/reopt)
        self._dcache: Optional[tuple] = None
        self._in_flight: deque[_InFlight] = deque()
        self._rows_in_flight = 0
        # auto-pump threshold, refreshed whenever the autotune signal
        # moves (block completion / dispatch) — submit() is the per-query
        # hot path and only does an int compare against it
        self._pump_threshold = router.microbatch
        # ticket -> (tenant, t_submit, absolute deadline)
        self._meta: dict[int, tuple] = {}
        self._done: dict[int, TicketResult] = {}
        # EWMAs: arrival rate (tickets/s) and block service time (s)
        self._arrival_rate = 0.0
        self._last_submit: Optional[float] = None
        self._service_ewma = 0.0
        self._alpha = 0.2
        # lifetime counters for sustained-QPS reporting
        self._completed = 0
        self._expired = 0
        self._t_first_submit: Optional[float] = None
        self._t_last_harvest: Optional[float] = None
        # -- telemetry (repro.obs) -----------------------------------------
        # plain ints on the hot path; the registry sees them through a
        # scrape-time collector (_publish), so per-event cost is zero
        reg = obs_metrics.NULL if metrics is None else metrics
        self.registry = reg
        self.tracer = NULL_TRACER if tracer is None else tracer
        self.watchdog = watchdog
        self._trace_on = not isinstance(self.tracer, NullTracer)
        self._n_admitted = 0
        self._n_queue_full = 0
        self._n_page_ins = 0
        self._published: dict = {}       # series key -> last flushed total
        self._h_block_service = reg.histogram(
            "serve_block_service_seconds",
            "dispatch->harvest wall time per padded block",
        )
        if not isinstance(reg, obs_metrics.NullRegistry):
            reg.add_collector(self._publish)

    # -- introspection ------------------------------------------------------

    @property
    def in_flight_blocks(self) -> int:
        return len(self._in_flight)

    @property
    def in_flight_rows(self) -> int:
        return self._rows_in_flight

    @property
    def depth(self) -> int:
        """Current queue depth: rows waiting + rows on the device."""
        return self.router.pending + self.in_flight_rows

    # -- admission ----------------------------------------------------------

    def _page_in(self, tenant: Hashable) -> None:
        """Warm-restore a cold tenant through the tier and swap the
        restored bank into the router.  Tenants with pending or in-flight
        work (queries AND queued observations) are pinned — evicting one
        would fail its eventual dispatch/ingest.  Never stalls in-flight
        blocks: their futures hold the old immutable stack."""
        with self.tracer.span("page_in", tenant=str(tenant)):
            self._page_in_inner(tenant)
        self._n_page_ins += 1

    def _page_in_inner(self, tenant: Hashable) -> None:
        t = self.tiered

        def pins():
            p = {m[0] for m in self._meta.values()}
            p.update(self.router._observations)
            return p

        t.adopt(self.router.bank)
        try:
            t.page_in(tenant, pinned=pins())
        except RuntimeError:
            # every hot slot pinned.  All engine pins are SOFT: queued
            # observations can be absorbed now (early ingest), and
            # pending/in-flight queries can be run to completion — their
            # results go back into the done-buffer, so every ticket stays
            # redeemable by the next harvest.  In-flight blocks are never
            # cancelled; they complete against the old immutable stack.
            # (This fallback fires only at full pin coverage — normal
            # paging never waits on in-flight work.)
            if self.router._observations:
                self.ingest()
            if self.router.pending or self._in_flight:
                # NB: harvest() swaps self._done for a fresh dict, so the
                # drain must complete before the buffer is looked up
                redeemed = self.drain()
                self._done.update(redeemed)
            t.adopt(self.router.bank)
            t.page_in(tenant, pinned=pins())
        self.router.bank = t.bank

    def submit(self, tenant: Hashable, x, *,
               deadline_s: Optional[float] = None) -> int:
        """Enqueue one query row; returns a ticket redeemed by a later
        :meth:`harvest` / :meth:`drain`.  Raises :class:`QueueFull` when
        the queue budget is exhausted (backpressure — nothing is
        enqueued).  With a :attr:`tiered` store, a cold tenant is paged
        in here (before admission charges anything)."""
        pending = len(self.router._pending)
        if pending + self._rows_in_flight >= self.queue_budget:
            self._n_queue_full += 1
            raise QueueFull(
                f"queue depth {pending + self._rows_in_flight} is at the "
                f"budget ({self.queue_budget}); harvest or raise the budget"
            )
        if self.tiered is not None and tenant not in self.router.bank.slots:
            self._page_in(tenant)
        now = self._clock()
        ticket = self.router.submit(tenant, x)
        # admit telemetry: a plain int plus a 1-in-256-sampled trace
        # event — submit is the per-query hot path and the overhead gate
        # in BENCH_obs.json (<=1.05x) rules out a full span per query
        self._n_admitted += 1
        if self._trace_on and not (self._n_admitted & 255):
            self.tracer.instant("admit", tenant=str(tenant), depth=pending)
        if deadline_s is None:
            deadline_s = self.slo_s.get(tenant, self.default_slo_s)
        deadline = math.inf if deadline_s is None else now + float(deadline_s)
        self._meta[ticket] = (tenant, now, deadline)
        last = self._last_submit
        if last is not None:
            gap = now - last
            self._arrival_rate = (
                self._alpha / gap + (1.0 - self._alpha) * self._arrival_rate
                if gap > 1e-9 else self._arrival_rate
            )
        else:
            self._t_first_submit = now
        self._last_submit = now
        if (pending + 1 >= self._pump_threshold
                and self.auto_pump
                and len(self._in_flight) < self.max_in_flight):
            self.pump(max_blocks=1)
        return ticket

    def observe(self, tenant: Hashable, x, y) -> None:
        """Enqueue one observation (delegates to the router; a cold
        tenant is paged in first when a :attr:`tiered` store exists)."""
        if self.tiered is not None and tenant not in self.router.bank.slots:
            self._page_in(tenant)
        self.router.observe(tenant, x, y)

    def ingest(self) -> int:
        """Absorb pending observations (``BankRouter.ingest``: batched,
        bucketed, failure-restoring — and donating old stack buffers when
        the router was built with ``donate_updates=True``).  With a
        :attr:`tiered` store, absorbed rows also enter the tier's
        sliding-window bookkeeping (so :meth:`TieredBank.age` can forget
        them later) and the updated bank is adopted back — even on a
        mid-ingest failure, the rows earlier rounds DID absorb are
        recorded before the error propagates."""
        if self.tiered is None:
            return self.router.ingest()
        before = {
            t: list(rows) for t, rows in self.router._observations.items()
        }
        try:
            return self.router.ingest()
        finally:
            # rows absorbed = queued-before minus restored-after (a failed
            # round restores its own and all still-queued rows in order,
            # so what remains is a suffix of what was there)
            after = self.router._observations
            for t, rows in before.items():
                absorbed = rows[: len(rows) - len(after.get(t, []))]
                if absorbed:
                    self.tiered.record_rows(
                        t, np.stack([x for x, _ in absorbed]),
                        np.asarray([yv for _, yv in absorbed], np.float32),
                    )
            self.tiered.adopt(self.router.bank)

    # -- bucket autotuning --------------------------------------------------

    def _target_bucket(self) -> int:
        """The arrival-rate-driven block size: expected arrivals over one
        block service time, rounded up to the fixed power-of-two ladder.
        When a fleet-wide SLO is configured the estimate is capped at the
        rows that arrive in HALF the SLO, so a ticket never spends its
        whole deadline waiting for its block to fill.  Before any signal
        exists (cold start) the router's microbatch is used — the
        historical fixed behavior."""
        est = self._arrival_rate * self._service_ewma
        if est <= 0.0:
            return self.router.microbatch
        if self.default_slo_s is not None:
            est = min(est, self._arrival_rate * self.default_slo_s * 0.5)
        for b in self.buckets:
            if b >= est:
                return b
        return self.buckets[-1]

    def _dispatch_bucket(self) -> int:
        """The padded size actually dispatched: the arrival-driven target,
        grown to cover a backlog that has already accumulated (fusing up
        to ``max_coalesce`` microbatches into one call — per-dispatch host
        overhead dominates at serving shapes, so draining a deep queue in
        few large blocks is the main throughput lever)."""
        want = max(self._target_bucket(), self.router.pending)
        for b in self.buckets:
            if b >= want:
                return b
        return self.buckets[-1]

    # -- dispatch-ahead -----------------------------------------------------

    def _dispatcher(self):
        """The lean per-bank dispatch closure: slot map + backend function
        + auxiliaries resolved ONCE per bank version (keyed on the bank's
        object identity — ingest/reoptimize swap in a new bank object and
        invalidate the cache).  ``GPBank.mean_var`` re-resolves all of
        this and validates per row on every call; at serving block rates
        that wrapper costs more than the executable itself."""
        bank = self.router.bank
        if self._dcache is not None and self._dcache[0] is bank:
            return self._dcache[1], self._dcache[2]
        sm = dict(bank.slots)
        stack, binv = bank.stack, bank._binv
        if getattr(bank, "mesh", None) is not None:
            # sharded bank: per-shard packed serving.  The call returns
            # (mu, var, order) — results land in packed per-shard order
            # and the harvest path unpacks host-side, so the hot path
            # never pays a cross-shard device reorder.
            tracer = self.tracer
            C_l = bank.shard_capacity
            S = bank.n_shards

            def call(slots, Xq):
                gslots = slots.astype(np.int64)
                per_shard = np.bincount(gslots // C_l, minlength=S)
                for s in np.flatnonzero(per_shard):
                    tracer.instant("shard_dispatch", shard_id=int(s),
                                   rows=int(per_shard[s]))
                return bank._packed_mean_var(gslots, Xq)
        elif bank.hypers is not None:
            eps_s, rho_s = bank.hypers.eps, bank.hypers.rho

            def call(slots, Xq):
                return bank_mod._hetero_gathered_mean_var(
                    stack, binv, slots, Xq, eps_s, rho_s
                )
        else:
            backend = fagp._check_backend_support(bank.spec)
            aux = fagp._backend_aux(backend, stack.idx, bank.spec)
            fn = (backend.bank_mean_var
                  or bank_mod._fallback_bank_mean_var(backend))

            def call(slots, Xq):
                return fn(stack, binv, slots, Xq, aux)

        self._dcache = (bank, sm, call)
        return sm, call

    def _dispatch(self, entries: list, bucket: int):
        """Pack ``entries`` into one padded ``bucket``-row block and
        dispatch it WITHOUT blocking; returns (mu, var) device futures.
        Raises (e.g. ``KeyError`` for a tenant evicted from a swapped
        bank) without side effects — the caller requeues."""
        sm, call = self._dispatcher()
        if getattr(self.router.bank, "mesh", None) is not None:
            # sharded: dispatch real rows only — the bank pads per shard
            # (its microbatch buckets are per-shard), so padding to the
            # global bucket here would just inflate the busiest shard
            tenants = [t for _, t, _ in entries]
            Xq = np.stack([x for _, _, x in entries])
        else:
            tenants, Xq = self.router._pack_block(entries, bucket)
        slots = np.array([sm[t] for t in tenants], np.int32)
        out = call(slots, Xq)
        return out if len(out) == 3 else out + (None,)

    def _expire(self, ticket: int, tenant: Hashable, t_submit: float,
                now: float) -> None:
        with self.tracer.span("expire"):
            self.stats.record_timeout(tenant)
            self._expired += 1
            self._done[ticket] = TicketResult(
                TIMEOUT_MU, TIMEOUT_VAR, timed_out=True,
                latency_s=now - t_submit,
            )

    def pump(self, max_blocks: Optional[int] = None) -> int:
        """Dispatch pending queries as padded blocks WITHOUT blocking on
        their results; returns the number of blocks dispatched.  Stops at
        ``max_in_flight`` in-flight blocks.  Deadline-expired tickets are
        answered with the timeout sentinel here, at dispatch time — they
        never occupy a padded seat or delay live tickets.  On a dispatch
        failure the block's live entries are requeued at the front of the
        router backlog before the error propagates."""
        dispatched = 0
        tr = self.tracer
        while (self.router.pending
               and len(self._in_flight) < self.max_in_flight
               and (max_blocks is None or dispatched < max_blocks)):
            with tr.span("bucket_select"):
                bucket = self._dispatch_bucket()
            entries = []
            now = self._clock()
            with tr.span("coalesce"):
                while len(entries) < bucket and self.router.pending:
                    for e in self.router.take(bucket - len(entries)):
                        tenant, t_sub, deadline = self._meta[e[0]]
                        if now > deadline:
                            del self._meta[e[0]]
                            self._expire(e[0], tenant, t_sub, now)
                        else:
                            entries.append(e)
            if not entries:       # the whole backlog had expired
                continue
            try:
                with tr.span("dispatch", bucket=bucket, rows=len(entries)):
                    mu, var, order = self._dispatch(entries, bucket)
            except Exception:
                self.router.requeue(entries)
                raise
            self._in_flight.append(
                _InFlight(entries, mu, var, bucket, now, order)
            )
            self._rows_in_flight += len(entries)
            self.bucket_uses[bucket] += 1
            dispatched += 1
        if dispatched:
            self._pump_threshold = self._target_bucket()
            if self.watchdog is not None:
                self.watchdog.check("pump")
        return dispatched

    # -- result harvest -----------------------------------------------------

    def _collect(self, blk: _InFlight) -> dict:
        with self.tracer.span("device_wait", bucket=blk.bucket):
            mu = np.asarray(blk.mu)   # blocks iff the result hasn't landed
            var = np.asarray(blk.var)
        now = self._clock()
        self._t_last_harvest = now
        service = now - blk.t_dispatch
        self._h_block_service.record(service)
        self._service_ewma = (
            service if self._service_ewma == 0.0
            else self._alpha * service
            + (1.0 - self._alpha) * self._service_ewma
        )
        self._rows_in_flight -= len(blk.entries)
        self._pump_threshold = self._target_bucket()
        mu_l = mu.tolist()      # one bulk conversion, not Q float() calls
        var_l = var.tolist()
        out = {}
        for i, (ticket, tenant, _) in enumerate(blk.entries):
            _, t_sub, _ = self._meta.pop(ticket)
            lat = now - t_sub
            self.stats.record(tenant, lat)
            j = i if blk.order is None else int(blk.order[i])
            out[ticket] = TicketResult(mu_l[j], var_l[j], False, lat)
        self._completed += len(blk.entries)
        return out

    def harvest(self, *, wait: bool = False) -> dict:
        """Collect results: every timeout sentinel recorded so far, plus
        every in-flight block whose device arrays have landed (FIFO; an
        unfinished head stops the scan so ticket results never arrive out
        of dispatch order).  ``wait=True`` additionally blocks for the
        head block (then keeps collecting whatever else finished).
        Returns ``ticket -> TicketResult``."""
        out, self._done = self._done, {}
        first = True
        while self._in_flight:
            blk = self._in_flight[0]
            if not ((wait and first)
                    or GPBank.result_ready(blk.mu, blk.var)):
                break
            self._in_flight.popleft()
            with self.tracer.span("harvest", bucket=blk.bucket):
                out.update(self._collect(blk))
            first = False
        return out

    def drain(self) -> dict:
        """Pump + harvest until every ticket is answered (the pipelined
        replacement for ``BankRouter.flush``): packing of block k+1
        overlaps the device execution of block k, with no per-block
        barrier anywhere.  Returns ``ticket -> TicketResult``."""
        out: dict[int, TicketResult] = {}
        while self.router.pending or self._in_flight or self._done:
            if (self.router.pending
                    and len(self._in_flight) < self.max_in_flight):
                self.pump(max_blocks=1)
                out.update(self.harvest(wait=False))
            else:
                out.update(self.harvest(wait=True))
        return out

    # -- observability ------------------------------------------------------

    def _publish(self) -> None:
        """Flush plain-int hot-path counters into the metrics registry.
        Runs as a registry collector (i.e. at scrape/snapshot time, on
        the scraper's thread), so the serving loop never pays per-event
        registry costs.  Counters are flushed as deltas against the last
        published totals; gauges are overwritten."""
        reg = self.registry
        pub = self._published

        def flush(name, help, total, **labels):
            key = (name, tuple(sorted(labels.items())))
            delta = total - pub.get(key, 0)
            if delta:
                reg.counter(name, help, **labels).inc(delta)
                pub[key] = total

        flush("serve_admitted_total", "tickets admitted", self._n_admitted)
        flush("serve_completed_total", "tickets completed", self._completed)
        flush("serve_expired_total", "tickets answered with the timeout "
              "sentinel", self._expired)
        flush("serve_queue_full_total", "admissions refused (backpressure)",
              self._n_queue_full)
        flush("serve_page_ins_total", "cold tenants paged in through the "
              "tier", self._n_page_ins)
        for bucket, n in self.bucket_uses.items():
            flush("serve_dispatch_blocks_total", "padded blocks dispatched",
                  n, bucket=bucket)
        reg.gauge("serve_queue_depth",
                  "rows waiting + rows on the device").set(self.depth)
        reg.gauge("serve_in_flight_rows",
                  "rows riding the device queue").set(self._rows_in_flight)
        reg.gauge("serve_in_flight_blocks",
                  "blocks riding the device queue").set(
                      len(self._in_flight))
        reg.gauge("serve_arrival_rate",
                  "EWMA arrival rate, tickets/s").set(self._arrival_rate)
        reg.gauge("serve_service_ewma_seconds",
                  "EWMA block service time").set(self._service_ewma)
        # latency quantiles from the bounded reservoir (the Prometheus
        # client-side-summary pattern — a streaming per-query histogram
        # would cost ~140ns/query on the harvest path, which the <=1.05x
        # overhead gate does not leave room for)
        p50, p99 = self.stats.percentiles(None)
        reg.gauge("serve_latency_seconds", "submit->harvest latency "
                  "(reservoir quantile)", quantile="0.5").set(p50)
        reg.gauge("serve_latency_seconds", "submit->harvest latency "
                  "(reservoir quantile)", quantile="0.99").set(p99)
        if self.watchdog is not None:
            flush("serve_recompiles_total", "serving-path executables "
                  "compiled after watchdog arm", self.watchdog.recompiles)

    def metrics(self) -> dict:
        """Latency + throughput snapshot.

        ``tenants``:  per-tenant {count, p50_s, p99_s, timeouts}
                      (percentiles over completed tickets, exactly
                      ``numpy.percentile``).
        ``overall``:  pooled percentiles, completed/expired counts, and
                      ``sustained_qps`` = completed tickets / (last
                      harvest - first submit).
        ``bucket_uses``: dispatch counts per autotuned bucket size.
        ``registry``:    the metrics-registry snapshot — engine, tier,
                         router and optimizer series in one schema (empty
                         sections when no registry was wired in).
        """
        tenants = {}
        ids = set(self.stats.samples) | set(self.stats.timeouts)
        for t in ids:
            p50, p99 = self.stats.percentiles(t)
            tenants[t] = {
                "count": self.stats.count(t),
                "p50_s": p50,
                "p99_s": p99,
                "timeouts": int(self.stats.timeouts.get(t, 0)),
            }
        p50, p99 = self.stats.percentiles(None)
        span = None
        if self._t_first_submit is not None \
                and self._t_last_harvest is not None:
            span = self._t_last_harvest - self._t_first_submit
        qps = (self._completed / span) if span and span > 0 else float("nan")
        return {
            "tenants": tenants,
            "overall": {
                "completed": self._completed,
                "expired": self._expired,
                "p50_s": p50,
                "p99_s": p99,
                "sustained_qps": qps,
            },
            "bucket_uses": dict(self.bucket_uses),
            "registry": self.registry.snapshot(),
        }
