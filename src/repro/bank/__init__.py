"""Batched multi-tenant GP serving: a bank of sessions + serving frontends.

``GPBank`` keeps B independent fitted GP sessions device-resident as one
stacked ``FAGPState`` and drives fit / mixed-tenant mean_var / rank-k
update (and its forgetting mirror, rank-k downdate) for the whole fleet
with single batched executables; ``BankRouter`` coalesces per-tenant query
and observation queues into the padded fixed-shape batches the bank wants;
``FleetEngine`` pipelines the router — dispatch-ahead blocks, per-tenant
deadlines with the documented timeout sentinel, queue-budget backpressure,
arrival-rate bucket autotuning, and p50/p99/QPS observability.
``TieredBank`` makes the fleet elastic: versioned per-tenant checkpoints
form a cold tier, cold tenants warm-restore on demand through the
recompile-free insert path, and sliding-window forgetting ages drifted
tenants via the batched downdate.  See ``bank.bank``, ``bank.engine`` and
``bank.lifecycle`` for the design notes.
"""
from .bank import GPBank
from .engine import (
    TIMEOUT_MU, TIMEOUT_VAR, FleetEngine, LatencyStats, QueueFull,
    TicketResult,
)
from .lifecycle import TieredBank
from .router import BankRouter
from .sharded import ShardedGPBank

__all__ = [
    "GPBank", "BankRouter", "FleetEngine", "LatencyStats", "QueueFull",
    "ShardedGPBank", "TicketResult", "TieredBank", "TIMEOUT_MU",
    "TIMEOUT_VAR",
]
