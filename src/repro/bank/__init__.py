"""Batched multi-tenant GP serving: a bank of sessions + a serving router.

``GPBank`` keeps B independent fitted GP sessions device-resident as one
stacked ``FAGPState`` and drives fit / mixed-tenant mean_var / rank-k
update for the whole fleet with single batched executables;
``BankRouter`` coalesces per-tenant query and observation queues into the
padded fixed-shape batches the bank wants.  See ``bank.bank`` for the
design notes.
"""
from .bank import GPBank
from .router import BankRouter

__all__ = ["GPBank", "BankRouter"]
