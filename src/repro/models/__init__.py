"""Model zoo: the 10 assigned architectures as one composable layer library."""
from types import SimpleNamespace

from . import config, encdec, layers, lm, mla, moe, ssm
from .config import ModelConfig


def get_model(cfg: ModelConfig) -> SimpleNamespace:
    """Family dispatch: uniform (init_params, loss_fn, prefill, decode_step,
    init_cache) API for every architecture."""
    mod = encdec if cfg.family == "audio" else lm
    return SimpleNamespace(
        init_params=lambda key: mod.init_params(key, cfg),
        loss_fn=lambda params, batch: mod.loss_fn(params, batch, cfg),
        prefill=lambda params, batch, cache_len=None: mod.prefill(
            params, batch, cfg, cache_len=cache_len
        ),
        decode_step=lambda params, batch, cache: mod.decode_step(params, batch, cache, cfg),
        init_cache=lambda B, S: mod.init_cache(cfg, B, S),
        cfg=cfg,
    )
