"""Whisper-style encoder-decoder backbone (audio family).

The conv/mel frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (B, enc_len, d).  Everything downstream is real:
sinusoidal-position bidirectional encoder, learned-position causal decoder
with cross-attention, LayerNorm (with bias), 2-matrix GELU MLPs, tied
embedding/output head — matching the Whisper architecture (arXiv:2212.04356).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import layers
from .config import ModelConfig

__all__ = ["init_params", "loss_fn", "prefill", "decode_step", "init_cache"]


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _ln_init(d):
    return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def _ln(x, p, eps):
    return layers.layernorm(x, p["w"], p["b"], eps)


def sinusoids(length: int, channels: int):
    """Whisper's fixed sinusoidal positions."""
    log_timescale = np.log(10000.0) / (channels // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(channels // 2))
    t = np.arange(length)[:, None] * inv[None, :]
    return jnp.asarray(
        np.concatenate([np.sin(t), np.cos(t)], axis=1), jnp.float32
    )


def _enc_block_init(key, cfg, dt):
    ks = jax.random.split(key, 2)
    return {
        "ln1": _ln_init(cfg.d_model),
        "attn": layers.attn_init(ks[0], cfg, dt),
        "ln2": _ln_init(cfg.d_model),
        "mlp": layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt, gated=False),
    }


def _dec_block_init(key, cfg, dt):
    ks = jax.random.split(key, 3)
    return {
        "ln1": _ln_init(cfg.d_model),
        "self_attn": layers.attn_init(ks[0], cfg, dt),
        "ln2": _ln_init(cfg.d_model),
        "cross_attn": layers.attn_init(ks[1], cfg, dt, cross=True),
        "ln3": _ln_init(cfg.d_model),
        "mlp": layers.mlp_init(ks[2], cfg.d_model, cfg.d_ff, dt, gated=False),
    }


def init_params(key, cfg: ModelConfig):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 6)
    return {
        "tok_emb": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), jnp.float32) * 0.02).astype(dt),
        "dec_pos": (jax.random.normal(ks[1], (cfg.max_seq, cfg.d_model), jnp.float32) * 0.01).astype(dt),
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(k, cfg, dt))(
            jax.random.split(ks[2], cfg.n_enc_layers)
        ),
        "dec_blocks": jax.vmap(lambda k: _dec_block_init(k, cfg, dt))(
            jax.random.split(ks[3], cfg.n_layers)
        ),
        "ln_enc": _ln_init(cfg.d_model),
        "ln_dec": _ln_init(cfg.d_model),
    }


def encode(params, frames, cfg: ModelConfig):
    """frames (B, enc_len, d) [stub frontend output] -> (B, enc_len, d)."""
    from repro.parallel import hints

    x = frames.astype(_dtype(cfg)) + sinusoids(frames.shape[1], cfg.d_model).astype(
        _dtype(cfg)
    )

    def body(h, lp):
        if cfg.sp_residual and hints.sp_enabled():
            h = hints.constrain(h, ("dp", "model", None))
        a = layers.attn_apply(
            lp["attn"], _ln(h, lp["ln1"], cfg.norm_eps), cfg, causal=False, use_rope=False
        )
        h = h + a
        h = h + layers.mlp_apply(lp["mlp"], _ln(h, lp["ln2"], cfg.norm_eps), "gelu")
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return _ln(x, params["ln_enc"], cfg.norm_eps)


def _decode_full(params, tokens, enc_out, cfg, *, collect_kv: bool = False):
    from repro.parallel import hints

    B, S = tokens.shape
    x = jnp.take(params["tok_emb"], tokens, axis=0).astype(_dtype(cfg))
    x = x + params["dec_pos"][:S][None, :, :].astype(x.dtype)

    def body(h, lp):
        if cfg.sp_residual and hints.sp_enabled():
            h = hints.constrain(h, ("dp", "model", None))
        a, (sk, sv) = layers.attn_apply(
            lp["self_attn"], _ln(h, lp["ln1"], cfg.norm_eps), cfg,
            causal=True, use_rope=False, return_kv=True,
        )
        h = h + a
        c, (ck, cv) = layers.attn_apply(
            lp["cross_attn"], _ln(h, lp["ln2"], cfg.norm_eps), cfg,
            kv_x=enc_out, causal=False, use_rope=False, return_kv=True,
        )
        h = h + c
        h = h + layers.mlp_apply(lp["mlp"], _ln(h, lp["ln3"], cfg.norm_eps), "gelu")
        return h, (sk, sv, ck, cv) if collect_kv else None

    if cfg.remat and not collect_kv:
        body = jax.checkpoint(body, prevent_cse=False)
    x, kv = jax.lax.scan(body, x, params["dec_blocks"])
    return _ln(x, params["ln_dec"], cfg.norm_eps), kv


def loss_fn(params, batch, cfg: ModelConfig):
    """batch: frames (B, enc_len, d), tokens (B, S)."""
    from .lm import xent_chunked

    from repro.parallel import hints as _h

    tokens = batch["tokens"]
    B, S = tokens.shape
    with _h.sp_scope(True):
        enc_out = encode(params, batch["frames"], cfg)
        h, _ = _decode_full(params, tokens, enc_out, cfg)
    h = _h.constrain(h, ("dp", None, None))
    labels = jnp.concatenate([tokens[:, 1:], jnp.zeros((B, 1), tokens.dtype)], axis=1)
    mask = jnp.concatenate(
        [jnp.ones((B, S - 1), jnp.float32), jnp.zeros((B, 1), jnp.float32)], axis=1
    )
    loss_sum, count = xent_chunked(h, params["tok_emb"], labels, mask, cfg.logits_chunk)
    loss = loss_sum / jnp.maximum(count, 1.0)
    return loss, {"loss": loss, "tokens": count}


def init_cache(cfg: ModelConfig, B: int, S: int):
    dt = _dtype(cfg)
    K, Dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "self_k": jnp.zeros((cfg.n_layers, B, S, K, Dh), dt),
        "self_v": jnp.zeros((cfg.n_layers, B, S, K, Dh), dt),
        "cross_k": jnp.zeros((cfg.n_layers, B, cfg.enc_len, K, Dh), dt),
        "cross_v": jnp.zeros((cfg.n_layers, B, cfg.enc_len, K, Dh), dt),
    }


def prefill(params, batch, cfg: ModelConfig, cache_len: int | None = None):
    tokens = batch["tokens"]
    B, S = tokens.shape
    Scap = cache_len or S
    enc_out = encode(params, batch["frames"], cfg)
    h, kv = _decode_full(params, tokens, enc_out, cfg, collect_kv=True)
    sk, sv, ck, cv = kv
    logits = (h[:, -1, :] @ params["tok_emb"].T).astype(jnp.float32)
    dt = _dtype(cfg)
    pad = [(0, 0), (0, 0), (0, Scap - S), (0, 0), (0, 0)]
    cache = {
        "self_k": jnp.pad(sk, pad).astype(dt),
        "self_v": jnp.pad(sv, pad).astype(dt),
        "cross_k": ck.astype(dt),
        "cross_v": cv.astype(dt),
    }
    return logits, cache


def decode_step(params, batch, cache, cfg: ModelConfig):
    """One decoder token against cached self/cross KV."""
    token, pos = batch["token"], batch["pos"]
    x = jnp.take(params["tok_emb"], token, axis=0).astype(_dtype(cfg))
    x = x + jnp.take(params["dec_pos"], jnp.full((1,), pos), axis=0)[None, :, :].astype(x.dtype)[:, 0:1]

    def body(h, inp):
        lp, sk, sv, ck, cv = inp
        a, sk, sv = layers.attn_decode(
            lp["self_attn"], _ln(h, lp["ln1"], cfg.norm_eps), cfg, sk, sv, pos,
            use_rope=False,
        )
        h = h + a
        c, _, _ = layers.attn_decode(
            lp["cross_attn"], _ln(h, lp["ln2"], cfg.norm_eps), cfg, ck, cv, pos,
            cross=True,
        )
        h = h + c
        h = h + layers.mlp_apply(lp["mlp"], _ln(h, lp["ln3"], cfg.norm_eps), "gelu")
        return h, (sk, sv)

    x, (sk, sv) = jax.lax.scan(
        body, x,
        (params["dec_blocks"], cache["self_k"], cache["self_v"],
         cache["cross_k"], cache["cross_v"]),
    )
    h = _ln(x, params["ln_dec"], cfg.norm_eps)
    logits = (h[:, 0, :] @ params["tok_emb"].T).astype(jnp.float32)
    return logits, dict(cache, self_k=sk, self_v=sv)
