"""Unified model configuration covering all assigned architecture families.

One dataclass parameterizes: dense decoder LMs (llama/qwen/starcoder style),
MoE (olmoe, deepseek-v3 w/ MLA+MTP), SSM (mamba2 SSD), hybrid (zamba2),
encoder-decoder audio (whisper, stub frontend) and VLM (llama-3.2-vision,
stub vision tower).  Exact per-arch values live in ``repro/configs/<id>.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads

    # attention
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0      # 0 = full attention
    logit_softcap: float = 0.0

    # norms / activations / embeddings
    norm_eps: float = 1e-5
    act: str = "silu"            # silu | gelu
    mlp_gated: bool = True       # False -> 2-matrix MLP w/ bias (starcoder2, whisper)
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    capacity_factor: float = 1.25
    moe_layer_start: int = 0     # deepseek: first k layers use a dense FFN
    router_aux_coef: float = 0.001

    # MLA (deepseek-v3)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mtp_depth: int = 0           # multi-token-prediction extra blocks

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    ssm_ngroups: int = 1

    # hybrid (zamba2): shared attention block applied before each scan group
    hybrid_groups: int = 0       # number of (shared-attn + mamba-group) segments
    hybrid_group_len: int = 0    # mamba layers per segment
    hybrid_tail: int = 0         # trailing mamba layers after the last segment

    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_len: int = 0             # precomputed frame count from the stub frontend
    max_seq: int = 0             # learned-position capacity (audio family only)

    # vlm (llama-3.2-vision): one gated cross-attn layer per `cross_every`
    # self-attn layers; image patch embeddings come precomputed (stub tower)
    cross_every: int = 0
    n_img_tokens: int = 0

    # numerics / compile scalability
    dtype: str = "bfloat16"
    remat: bool = True
    logits_chunk: int = 1024     # sequence chunking of the softmax-xent
    scan_layers: bool = True

    # distribution hints (consumed by parallel/sharding.py)
    fsdp: bool = False           # additionally shard params over the data axis
    # sequence-parallel SSM: mamba blocks are per-token apart from the O(1)
    # state recurrence, so shard the residual's seq axis over 'model' with
    # REPLICATED (fsdp-only) mamba weights — removes the 2-AR/layer Megatron
    # pattern entirely (§Perf iteration Z1)
    ssm_seq_parallel: bool = True
    # sequence-parallel residual stream for attention archs (SPerf V1):
    # pins the remat/scan carry seq-sharded over 'model', shrinking the
    # saved activation stacks by model_size at the price of per-layer
    # (all-gather, reduce-scatter) pairs around attention/MLP
    sp_residual: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # --- derived sizes -----------------------------------------------------
    @property
    def d_inner(self) -> int:    # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def active_param_count(self) -> int:
        """Params touched per token: MoE counts top_k + shared experts only
        (MODEL_FLOPS = 6 * N_active * D for the roofline's useful-FLOPs line)."""
        if not self.n_experts:
            return self.param_count()
        active = dataclasses.replace(
            self,
            n_experts=self.top_k,
            # router still sees all experts; its params are negligible
        )
        return active.param_count()

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS in the roofline)."""
        d, f, V = self.d_model, self.d_ff, self.vocab
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense",):
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
                 + self.n_heads * self.head_dim * d
            mlp = (3 if self.mlp_gated else 2) * d * f
            return emb + self.n_layers * (attn + mlp) + d
        if self.family == "moe" and not self.use_mla:
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
                 + self.n_heads * self.head_dim * d
            moe = self.n_experts * 3 * d * self.d_expert + d * self.n_experts \
                + self.n_shared_experts * 3 * d * self.d_expert
            return emb + self.n_layers * (attn + moe) + d
        if self.use_mla:
            H = self.n_heads
            attn = d * self.q_lora_rank \
                 + self.q_lora_rank * H * (self.qk_nope_dim + self.qk_rope_dim) \
                 + d * (self.kv_lora_rank + self.qk_rope_dim) \
                 + self.kv_lora_rank * H * (self.qk_nope_dim + self.v_head_dim) \
                 + H * self.v_head_dim * d
            dense_ffn = 3 * d * f
            moe = self.n_experts * 3 * d * self.d_expert + d * self.n_experts \
                + self.n_shared_experts * 3 * d * self.d_expert
            n_moe = self.n_layers - self.moe_layer_start
            total = emb + self.moe_layer_start * (attn + dense_ffn) \
                  + n_moe * (attn + moe) + d
            if self.mtp_depth:
                total += self.mtp_depth * (attn + moe + 2 * d)
            return total
        if self.family == "ssm":
            din, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            G = self.ssm_ngroups
            blk = d * (2 * din + 2 * G * N + H) \
                + self.ssm_conv * (din + 2 * G * N) \
                + din * d + 2 * H + din
            return emb + self.n_layers * blk + d
        if self.family == "hybrid":
            din, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            G = self.ssm_ngroups
            blk = d * (2 * din + 2 * G * N + H) \
                + self.ssm_conv * (din + 2 * G * N) \
                + din * d + 2 * H + din
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
                 + self.n_heads * self.head_dim * d + 3 * d * f
            n_mamba = self.hybrid_groups * self.hybrid_group_len + self.hybrid_tail
            return emb + n_mamba * blk + attn + d
        if self.family == "audio":
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
                 + self.n_heads * self.head_dim * d
            mlp = 2 * d * f  # whisper MLP is 2-matrix gelu
            enc = self.n_enc_layers * (attn + mlp)
            dec = self.n_layers * (2 * attn + mlp)
            return emb + enc + dec + d
        if self.family == "vlm":
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
                 + self.n_heads * self.head_dim * d
            mlp = 3 * d * f
            n_cross = self.n_layers // (self.cross_every + 1) if self.cross_every else 0
            n_self = self.n_layers - n_cross
            return emb + n_self * (attn + mlp) + n_cross * (attn + mlp + d) + d
        raise ValueError(self.family)
