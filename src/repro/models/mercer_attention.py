"""Mercer-feature linear attention — the paper's kernel expansion applied
to attention (beyond-paper bridge module, see DESIGN.md §Arch-applicability).

Softmax attention weights are a Gaussian kernel in disguise:

    exp(q·k) = e^{|q|²/2} · exp(-|q-k|²/2) · e^{|k|²/2}

and the e^{|q|²/2} factor cancels in the softmax normalization.  Replacing
the Gaussian kernel with its truncated Mercer expansion (paper Eqs. 5-6,
tensor-product over head dims with a total-degree index set — the same
truncation study as the GP core) makes attention LINEAR in sequence length:

    out(q) = φ(q)ᵀ S_v / φ(q)ᵀ s_1,
    S_v = Σ_k λ·φ(k) e^{|k|²/2} v_kᵀ   (running prefix sums when causal)

Features here use degree ≤ 2 (constant + per-dim linear + pairwise terms):
M = 1 + d + d(d+1)/2 features per head — O(S·M·d) total, no S×S matrix.
This is deterministic (unlike Performer's random features) and inherits
the paper's accuracy-vs-M tradeoff knob.  Quality degrades for large |q|,
|k| (higher-degree terms truncated), so inputs are RMS-normalized; see
test_mercer_attention.py for the approximation-error envelope.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["mercer_features_deg2", "mercer_linear_attention"]


def _normalize(x, target_norm: float):
    n = jnp.linalg.norm(x, axis=-1, keepdims=True)
    return x * (target_norm / jnp.maximum(n, 1e-6))


def mercer_features_deg2(x):
    """Degree-≤2 tensor-product expansion of exp(-|x-y|²/2) features.

    exp(-|x-y|²/2) = e^{-|x|²/2} e^{-|y|²/2} e^{x·y}; expanding e^{x·y} to
    second order gives features (per d-dim vector x):
        φ(x) = e^{-|x|²/2} · [1, x_j, x_i x_j / √(1+δ_ij)]
    which is exactly the n≤3 Mercer tensor-product truncated at total
    degree 2 (Hermite H_0, H_1, H_2 recombined).  Returns (..., M) with
    M = 1 + d + d(d+1)/2.
    """
    d = x.shape[-1]
    env = jnp.exp(-0.5 * jnp.sum(x * x, axis=-1, keepdims=True))
    ones = jnp.ones_like(env)
    lin = x
    outer = x[..., :, None] * x[..., None, :]
    iu = np.triu_indices(d)
    scale = jnp.asarray(np.where(iu[0] == iu[1], 1.0, np.sqrt(2.0)), x.dtype)
    quad = outer[..., iu[0], iu[1]] * scale / jnp.sqrt(2.0) * jnp.sqrt(2.0)
    quad = quad / jnp.sqrt(2.0)  # 1/sqrt(2!) Taylor factor, off-diag x sqrt2
    feats = jnp.concatenate([ones, lin, quad], axis=-1)
    return feats * env


def mercer_linear_attention(q, k, v, *, causal: bool = True,
                            target_norm: float = 1.0):
    """q,k (B,S,H,D), v (B,S,H,Dv) -> (B,S,H,Dv) in O(S·M) (no S×S matrix).

    Inputs are norm-clamped to keep the degree-2 truncation accurate
    (||x|| ≤ ~1.5 gives <2% kernel error; see tests)."""
    q = _normalize(q.astype(jnp.float32), target_norm)
    k = _normalize(k.astype(jnp.float32), target_norm)
    fq = mercer_features_deg2(q)                      # (B,S,H,M)
    fk = mercer_features_deg2(k)
    # e^{|k|^2/2} with normalized k is constant and cancels; keep general:
    kw = jnp.exp(0.5 * jnp.sum(k * k, axis=-1, keepdims=True))
    fk = fk * kw
    if causal:
        Sv = jnp.cumsum(fk[..., :, None] * v.astype(jnp.float32)[..., None, :],
                        axis=1)                       # (B,S,H,M,Dv)
        s1 = jnp.cumsum(fk, axis=1)                   # (B,S,H,M)
        num = jnp.einsum("bshm,bshmd->bshd", fq, Sv)
        den = jnp.einsum("bshm,bshm->bsh", fq, s1)
    else:
        Sv = jnp.einsum("bshm,bshd->bhmd", fk, v.astype(jnp.float32))
        s1 = jnp.sum(fk, axis=1)                      # (B,H,M)
        num = jnp.einsum("bshm,bhmd->bshd", fq, Sv)
        den = jnp.einsum("bshm,bhm->bsh", fq, s1)
    return (num / jnp.maximum(den[..., None], 1e-9)).astype(v.dtype)
