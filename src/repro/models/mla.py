"""Multi-head Latent Attention (MLA) — DeepSeek-V3 (arXiv:2412.19437).

Q and KV both pass through low-rank latents; only the (kv_lora + rope_dim)
latent per token is cached at decode time.  Decode uses the *absorbed* form:
q is projected into the KV-latent space so attention scores are computed
directly against the cached latent — the per-head K/V expansion never
materializes for the 32k-long cache.  Train/prefill use the standard
expanded form (matches the training cost structure).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense_init, rmsnorm, rope, update_cache

__all__ = ["mla_init", "mla_apply", "mla_decode"]


def mla_init(key, cfg, dtype):
    d, H = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], d, qr, dtype),
        "q_ln": jnp.ones((qr,), jnp.float32),
        "wq_b": dense_init(ks[1], qr, H * (dn + dr), dtype),
        "wkv_a": dense_init(ks[2], d, kvr + dr, dtype),
        "kv_ln": jnp.ones((kvr,), jnp.float32),
        "wkv_b": dense_init(ks[3], kvr, H * (dn + dv), dtype),
        "wo": dense_init(ks[4], H * dv, d, dtype, scale=1.0 / np.sqrt(H * dv)),
    }


def _q_proj(p, x, cfg):
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = rmsnorm(x @ p["wq_a"], p["q_ln"], cfg.norm_eps)
    q = (cq @ p["wq_b"]).reshape(B, S, H, dn + dr)
    return q[..., :dn], q[..., dn:]                     # (B,S,H,dn), (B,S,H,dr)


def _kv_latent(p, x, cfg):
    kvr, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    ckv_full = x @ p["wkv_a"]                           # (B, S, kvr+dr)
    ckv = rmsnorm(ckv_full[..., :kvr], p["kv_ln"], cfg.norm_eps)
    k_rope = ckv_full[..., kvr:][:, :, None, :]         # (B, S, 1, dr)
    return ckv, k_rope


def mla_apply(p, x, cfg, *, positions=None):
    """Full-sequence MLA (train / prefill), causal. x (B, S, d).

    Expanded form: concat(nope, rope) per head turns MLA into a plain
    causal GQA call (K == H), so the chunked online-softmax path in
    layers.gqa_attention applies unchanged."""
    from .layers import gqa_attention

    B, S, _ = x.shape
    H, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    pos = positions if positions is not None else jnp.arange(S)

    q_nope, q_rope = _q_proj(p, x, cfg)
    q_rope = rope(q_rope, pos, cfg.rope_theta)
    ckv, k_rope = _kv_latent(p, x, cfg)
    k_rope = rope(k_rope, pos, cfg.rope_theta)          # (B, S, 1, dr)

    kv = (ckv @ p["wkv_b"]).reshape(B, S, H, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]

    q = jnp.concatenate([q_nope, q_rope], axis=-1)                  # (B,S,H,dn+dr)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, dr))], axis=-1
    )
    out = gqa_attention(q, k, v, causal=True)                       # Dv != Dqk ok
    return out.reshape(B, S, H * dv) @ p["wo"]


def mla_prefill_cache(p, x, cfg, *, positions=None):
    """The decode cache: roped k_rope + normalized latent, (B, S, kvr + dr)."""
    S = x.shape[1]
    pos = positions if positions is not None else jnp.arange(S)
    ckv, k_rope = _kv_latent(p, x, cfg)
    k_rope = rope(k_rope, pos, cfg.rope_theta)[:, :, 0, :]
    return jnp.concatenate([ckv, k_rope], axis=-1)


def mla_decode(p, x, cfg, cache, pos):
    """Absorbed-form single-token decode. x (B, 1, d); cache (B, S, kvr+dr).

    scores_h = q_nope_h^T W_UK_h ckv + q_rope_h^T k_rope   per head h,
    out_h    = W_UV_h^T (probs @ ckv)

    Sharding schedule (§Perf D1): the cache is seq-sharded over 'model' and
    NEVER moves; q (a few MB) is replicated over 'model' instead, attention
    runs S-local per shard, and the context is combined with tiny
    partial-sum all-reduces.  Without the explicit pins XLA resolves the
    head-vs-seq sharding conflict by all-gathering the multi-GB cache every
    einsum (155 GiB/step for deepseek-v3 at 32k).
    """
    from repro.parallel import hints

    B = x.shape[0]
    H, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank

    q_nope, q_rope = _q_proj(p, x, cfg)                 # (B,1,H,dn), (B,1,H,dr)
    q_nope = hints.constrain(q_nope, ("dp", None, None, None))
    q_rope = hints.constrain(q_rope, ("dp", None, None, None))
    posv = jnp.full((B, 1), pos, jnp.int32)
    q_rope = rope(q_rope, posv, cfg.rope_theta)

    ckv_new, k_rope_new = _kv_latent(p, x, cfg)         # (B,1,kvr), (B,1,1,dr)
    k_rope_new = rope(k_rope_new, posv, cfg.rope_theta)[:, :, 0, :]
    new_entry = jnp.concatenate([ckv_new, k_rope_new], axis=-1)[:, :, None, :]
    cache = update_cache(cache[:, :, None, :], new_entry, pos)[:, :, 0, :]

    ckv_c, k_rope_c = cache[..., :kvr], cache[..., kvr:]      # (B,S,kvr), (B,S,dr)
    wkv_b = p["wkv_b"].reshape(kvr, H, dn + dv)
    w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]             # (kvr,H,dn),(kvr,H,dv)

    q_abs = jnp.einsum("bqhd,khd->bqhk", q_nope, w_uk)        # (B,1,H,kvr)
    q_abs = hints.constrain(q_abs, ("dp", None, None, None))
    scale = 1.0 / np.sqrt(dn + dr)
    logits = (
        jnp.einsum("bqhk,bsk->bhqs", q_abs.astype(jnp.float32), ckv_c.astype(jnp.float32))
        + jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                     k_rope_c.astype(jnp.float32))
    ) * scale
    logits = hints.constrain(logits, ("dp", None, None, "model"))  # S-local
    spos = jnp.arange(cache.shape[1])
    logits = jnp.where((spos <= pos)[None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhqs,bsk->bqhk", probs, ckv_c.astype(jnp.float32))  # latent ctx
    ctx = hints.constrain(ctx, ("dp", None, None, None))      # partial-sum AR (MBs)
    out = jnp.einsum("bqhk,khd->bqhd", ctx.astype(x.dtype), w_uv)         # (B,1,H,dv)
    return out.reshape(B, 1, H * dv) @ p["wo"], cache
