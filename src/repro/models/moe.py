"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

Scalable formulation (no (T, E, C) one-hot): the expert assignment is turned
into an (E, C) table of token ids via a sort-based within-expert ranking —
O(Tk log Tk) — then experts run as one batched einsum over the (E, C, d)
gathered buffer.

Two execution paths:

* ``moe_apply`` — single-shard dense path (smoke tests, small runs).
* ``moe_apply_sharded`` — production expert parallelism via shard_map:
  tokens stay sharded over (pod, data) and *replicated* over 'model'; each
  model shard dispatches/computes only its E/model_size experts locally and
  the combine is ONE psum over 'model' — the same collective volume as a
  Megatron TP MLP ((T_local, d) all-reduce), with zero all-to-alls and a
  fully local gather.  Expert weights are additionally sharded over 'data'
  on d_model (FSDP) and all-gathered at use inside the shard (the backward
  pass reduce-scatters automatically).

Includes: shared experts (deepseek-v3), switch-style load-balance aux loss,
capacity_factor overflow dropping (dropped tokens keep the shared/residual
path only).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.parallel import hints
from .layers import dense_init

__all__ = ["moe_init", "moe_apply", "moe_apply_sharded", "moe_dispatch", "capacity"]


def capacity(T: int, cfg) -> int:
    c = int(np.ceil(cfg.capacity_factor * T * cfg.top_k / cfg.n_experts))
    return max(8, int(np.ceil(c / 8) * 8))


def moe_init(key, cfg, dtype):
    d, fe, E = cfg.d_model, cfg.d_expert, cfg.n_experts
    ks = jax.random.split(key, 8)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "wg": (jax.random.normal(ks[1], (E, d, fe), jnp.float32) / np.sqrt(d)).astype(dtype),
        "wu": (jax.random.normal(ks[2], (E, d, fe), jnp.float32) / np.sqrt(d)).astype(dtype),
        "wd": (jax.random.normal(ks[3], (E, fe, d), jnp.float32) / np.sqrt(fe)).astype(dtype),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * fe
        p["shared_wg"] = dense_init(ks[4], d, fs, dtype)
        p["shared_wu"] = dense_init(ks[5], d, fs, dtype)
        p["shared_wd"] = dense_init(ks[6], fs, d, dtype, scale=1.0 / np.sqrt(fs))
    return p


def _expert_ranks(e_flat: jax.Array, n_assign: int) -> jax.Array:
    """rank of each assignment within its expert group (sort-based, O(n log n))."""
    order = jnp.argsort(e_flat, stable=True)
    sorted_e = e_flat[order]
    idx = jnp.arange(n_assign, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]]
    )
    group_start = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, idx, 0))
    rank_sorted = idx - group_start
    return jnp.zeros_like(e_flat).at[order].set(rank_sorted)


def _route(p, x, cfg):
    """Router probabilities, top-k, renormalized gates, aux loss."""
    E, k = cfg.n_experts, cfg.top_k
    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                                # (T, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)                 # renormalize
    frac_tokens = jnp.mean(
        jnp.sum(jax.nn.one_hot(topi, E, dtype=jnp.float32), axis=1), axis=0
    )
    frac_probs = jnp.mean(probs, axis=0)
    aux = cfg.router_aux_coef * E * jnp.sum(frac_tokens * frac_probs)
    return topv, topi, aux


def _dispatch_tables(topi, topv, T, k, C, e_lo, n_local, dtype):
    """(E_local*C,) token/gate tables for experts in [e_lo, e_lo+n_local).

    Ranks are computed over ALL assignments (global capacity semantics), so
    every shard computing this on the same tokens agrees on drops."""
    n_assign = T * k
    e_flat = topi.reshape(-1).astype(jnp.int32)
    t_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    w_flat = topv.reshape(-1).astype(dtype)
    rank = _expert_ranks(e_flat, n_assign)
    local = (e_flat >= e_lo) & (e_flat < e_lo + n_local)
    keep = (rank < C) & local
    dest = jnp.where(keep, (e_flat - e_lo) * C + rank, n_local * C)     # last = drop
    token_for_slot = jnp.full((n_local * C,), T, jnp.int32)             # T = pad row
    token_for_slot = token_for_slot.at[dest].set(t_flat, mode="drop")
    w_for_slot = jnp.zeros((n_local * C,), dtype).at[dest].set(w_flat, mode="drop")
    return token_for_slot, w_for_slot


def _expert_ffn(x, token_for_slot, w_for_slot, wg, wu, wd, T, d, C):
    """Gather -> batched expert einsum -> weighted scatter-combine."""
    E_l = wg.shape[0]
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xe = x_pad[token_for_slot].reshape(E_l, C, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) * jnp.einsum(
        "ecd,edf->ecf", xe, wu
    )
    ye = jnp.einsum("ecf,efd->ecd", h, wd).reshape(E_l * C, d)
    y = jnp.zeros((T + 1, d), x.dtype)
    return y.at[token_for_slot].add(ye * w_for_slot[:, None])[:T]


def moe_apply(p, x, cfg):
    """Single-shard path. x: (T, d) -> (y (T, d), aux_loss scalar)."""
    T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = capacity(T, cfg)
    topv, topi, aux = _route(p, x, cfg)
    token_for_slot, w_for_slot = _dispatch_tables(topi, topv, T, k, C, 0, E, x.dtype)
    y = _expert_ffn(x, token_for_slot, w_for_slot, p["wg"], p["wu"], p["wd"], T, d, C)
    if "shared_wg" in p:
        g = jax.nn.silu(x @ p["shared_wg"]) * (x @ p["shared_wu"])
        y = y + g @ p["shared_wd"]
    return y, aux


def moe_apply_sharded(p, x, cfg):
    """Expert-parallel path under an active mesh (see module docstring).

    x: (T, d) GLOBAL flattened tokens, sharded P(dp, None).  Experts live
    E/model_size per shard; tokens are replicated over 'model', so dispatch
    and gather are local and the combine is one psum over 'model'."""
    shard_map = jax.shard_map

    mesh = hints.active_mesh()
    dp = hints.dp_axes(mesh)
    msize = mesh.shape["model"]
    dsize = mesh.shape["data"]
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    E_local = E // msize
    T_l = T // dp_size
    C = capacity(T_l, cfg)
    fsdp = cfg.fsdp and d % dsize == 0

    p_specs = {
        "router": P(None, None),
        "wg": P("model", "data", None) if fsdp else P("model", None, None),
        "wu": P("model", "data", None) if fsdp else P("model", None, None),
        "wd": P("model", None, "data") if fsdp else P("model", None, None),
    }
    if "shared_wg" in p:
        p_specs.update(
            shared_wg=P(None, "model"), shared_wu=P(None, "model"),
            shared_wd=P("model", None),
        )

    # serve mode (§Perf D1): when tokens-per-expert is tiny (decode), moving
    # the 11B expert weights through FSDP all-gathers costs ~GBs per layer
    # per step.  Instead: gather the (tiny) tokens over 'data', keep weights
    # sharded, contract each shard's d_model slice, and psum the small
    # routed activations — weights never move.
    T_g = T_l * dsize                       # tokens per pod row after gather
    serve_mode = fsdp and (T_g * k) // max(E, 1) <= 64
    C_g = capacity(T_g, cfg)

    def inner(pl, x_l):
        wg, wu, wd = pl["wg"], pl["wu"], pl["wd"]
        e_lo = jax.lax.axis_index("model") * E_local
        if serve_mode:
            x_g = jax.lax.all_gather(x_l, "data", axis=0, tiled=True)  # (T_g, d)
            topv, topi, aux = _route(pl, x_g, cfg)
            tok, w = _dispatch_tables(topi, topv, T_g, k, C_g, e_lo, E_local,
                                      x_g.dtype)
            dloc = d // dsize
            j0 = jax.lax.axis_index("data") * dloc
            x_pad = jnp.concatenate([x_g, jnp.zeros((1, d), x_g.dtype)], axis=0)
            xe = x_pad[tok].reshape(E_local, C_g, d)
            xg = jax.lax.dynamic_slice_in_dim(xe, j0, dloc, axis=2)
            gh = jax.lax.psum(jnp.einsum("ecd,edf->ecf", xg, wg), "data")
            uh = jax.lax.psum(jnp.einsum("ecd,edf->ecf", xg, wu), "data")
            h = jax.nn.silu(gh) * uh
            ye = jnp.einsum("ecf,efd->ecd", h, wd).reshape(E_local * C_g, dloc)
            y_p = jnp.zeros((T_g + 1, dloc), x_g.dtype)
            y_p = y_p.at[tok].add(ye * w[:, None])[:T_g]
            y_full = jax.lax.all_gather(y_p, "data", axis=1, tiled=True)
            t0 = jax.lax.axis_index("data") * T_l
            y = jax.lax.dynamic_slice_in_dim(y_full, t0, T_l, axis=0)
        else:
            if fsdp:  # manual ZeRO-3: gather weights at use
                wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
                wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
                wd = jax.lax.all_gather(wd, "data", axis=2, tiled=True)
            topv, topi, aux = _route(pl, x_l, cfg)
            tok, w = _dispatch_tables(topi, topv, T_l, k, C, e_lo, E_local,
                                      x_l.dtype)
            y = _expert_ffn(x_l, tok, w, wg, wu, wd, T_l, d, C)
        if "shared_wg" in pl:
            g = jax.nn.silu(x_l @ pl["shared_wg"]) * (x_l @ pl["shared_wu"])
            y = y + g @ pl["shared_wd"]        # partial over 'model' (TP on fs)
        y = jax.lax.psum(y, "model")           # ONE combine collective
        return y, aux[None]

    y, aux = shard_map(
        inner, mesh=mesh,
        in_specs=(p_specs, P(dp, None)),
        out_specs=(P(dp, None), P(dp)),
        check_vma=False,
    )({k_: p[k_] for k_ in p_specs}, x)
    return y, jnp.mean(aux)


def moe_dispatch(p, x, cfg):
    """Pick the execution path: shard_map EP when a mesh is active and the
    expert count divides the model axis; dense otherwise."""
    mesh = hints.active_mesh()
    if mesh is not None and cfg.n_experts % mesh.shape["model"] == 0:
        dp_size = int(np.prod([mesh.shape[a] for a in hints.dp_axes(mesh)]))
        if x.shape[0] % dp_size == 0:
            return moe_apply_sharded(p, x, cfg)
    return moe_apply(p, x, cfg)
