"""Mamba2 / SSD (state-space duality) blocks — arXiv:2405.21060.

Chunked block-decomposition of the SSD recurrence: quadratic attention-like
intra-chunk term (MXU-friendly batched matmuls) + a sequential inter-chunk
state recurrence (lax.scan over l/chunk steps carrying the (h, p, n) state).
This is the TPU-native formulation: all heavy ops are dense einsums; the only
sequential dependency is the tiny per-chunk state.

Includes the full mamba2 block (in_proj -> causal depthwise conv -> SSD ->
gated RMSNorm -> out_proj) plus O(1)-state single-token decode — which is
why the SSM/hybrid archs run the 500k-context decode cell that quadratic
attention archs skip.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense_init, rmsnorm

__all__ = ["mamba_init", "mamba_apply", "mamba_decode", "ssd_chunked"]


def _segsum(x):
    """x (..., q) -> (..., q, q): S[i, j] = sum_{k=j+1..i} x_k (i >= j), -inf else."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    i = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    return jnp.where(i >= j, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, init_state=None):
    """SSD: y_t = C_t^T S_t,  S_t = exp(dt_t A) S_{t-1} + dt_t B_t x_t^T.

    x (b, l, h, p), dt (b, l, h) [post-softplus], A (h,) negative,
    B, C (b, l, g, n) with h % g == 0.  Returns (y (b, l, h, p),
    final_state (b, h, p, n)).
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g

    # pad to a chunk multiple; dt=0 padding is exact (decay 1, no state update)
    l_orig = l
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l = l + pad
    nc = l // chunk

    def chunked(t, width):  # (b, l, ...) -> (b, nc, chunk, ...)
        return t.reshape(b, nc, chunk, *t.shape[2:])

    xc = chunked(x, p)                                  # (b,c,q,h,p)
    dtc = chunked(dt, None)                             # (b,c,q,h)
    Bc = jnp.repeat(chunked(B, n), rep, axis=3)         # (b,c,q,h,n)
    Cc = jnp.repeat(chunked(C, n), rep, axis=3)

    dA = dtc * A[None, None, None, :]                   # (b,c,q,h)
    dA_cs = jnp.cumsum(dA, axis=2)                      # (b,c,q,h)
    xdt = xc * dtc[..., None]

    # 1) intra-chunk (quadratic within chunk, like masked attention)
    L = jnp.exp(_segsum(jnp.moveaxis(dA, 2, 3)))        # (b,c,h,q,q)
    scores = jnp.einsum("bcqhn,bcshn->bchqs", Cc, Bc)   # (b,c,h,q,s)
    y_diag = jnp.einsum("bchqs,bcshp->bcqhp", scores * L, xdt)

    # 2) per-chunk outgoing states
    decay_out = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)    # (b,c,q,h)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Bc, decay_out, xdt)

    # 3) inter-chunk recurrence (sequential over nc chunks)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])           # (b,c,h)
    s0 = (
        init_state
        if init_state is not None
        else jnp.zeros((b, h, p, n), x.dtype)
    )

    def step(carry, inp):
        s_prev = carry
        dec, s_new = inp                                 # (b,h), (b,h,p,n)
        s = s_prev * dec[..., None, None] + s_new
        return s, s_prev

    final_state, states_prev = jax.lax.scan(
        step,
        s0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)),
    )
    states_prev = jnp.moveaxis(states_prev, 0, 1)        # (b,c,h,p,n)

    # 4) inter-chunk contribution
    y_off = jnp.einsum(
        "bcqhn,bchpn,bcqh->bcqhp", Cc, states_prev, jnp.exp(dA_cs)
    )
    y = (y_diag + y_off).reshape(b, l, h, p)[:, :l_orig]
    return y, final_state


# --------------------------------------------------------------------------
# Full mamba2 block
# --------------------------------------------------------------------------


def mamba_init(key, cfg, dtype):
    """The canonical fused in_proj/conv are SPLIT into per-role params
    (z | x | BC | dt and conv_x | conv_BC): the role boundaries are not
    aligned to tensor-parallel shard boundaries, and a depthwise conv
    factorizes exactly across the channel split, so splitting costs nothing
    and makes the d_inner/head axes cleanly shardable over 'model'."""
    d = cfg.d_model
    din, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    g, K = cfg.ssm_ngroups, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    dt = np.exp(
        np.random.RandomState(0).uniform(np.log(1e-3), np.log(1e-1), size=(h,))
    )
    return {
        "in_z": dense_init(ks[0], d, din, dtype),
        "in_x": dense_init(ks[1], d, din, dtype),
        "in_BC": dense_init(ks[2], d, 2 * g * n, dtype),
        "in_dt": dense_init(ks[3], d, h, dtype),
        "conv_x_w": (jax.random.normal(ks[4], (K, din), jnp.float32) / np.sqrt(K)).astype(dtype),
        "conv_x_b": jnp.zeros((din,), dtype),
        "conv_BC_w": (jax.random.normal(ks[5], (K, 2 * g * n), jnp.float32) / np.sqrt(K)).astype(dtype),
        "conv_BC_b": jnp.zeros((2 * g * n,), dtype),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.asarray(dt + np.log(-np.expm1(-dt)), jnp.float32),  # inv softplus
        "norm_w": jnp.ones((din,), jnp.float32),
        "out_proj": dense_init(ks[2], din, d, dtype, scale=1.0 / np.sqrt(din)),
    }


def _causal_depthwise_conv(xBC, w, b):
    """(b, l, ch) causal depthwise conv, kernel K (static unroll over K taps)."""
    K = w.shape[0]
    out = xBC * w[K - 1][None, None, :]
    for k in range(1, K):
        shifted = jnp.pad(xBC, ((0, 0), (k, 0), (0, 0)))[:, : xBC.shape[1], :]
        out = out + shifted * w[K - 1 - k][None, None, :]
    return out + b[None, None, :]


def _ssm_mode(cfg) -> str:
    """'sp_tp': Megatron-SP (seq-sharded residual, channel/head-sharded
    interior, AG at entry + RS at exit — §Perf Z2); 'sp_only': replicated
    weights, everything seq-sharded (heads don't divide the model axis);
    'off': no mesh active."""
    from repro.parallel import hints

    mesh = hints.active_mesh()
    if mesh is None or not cfg.ssm_seq_parallel:
        return "off"
    # hybrid archs interleave attention blocks that need the full sequence;
    # seq-sharding their mamba interiors pays resharding on every boundary,
    # which only amortizes when the backward stacks shrink too — so hybrid
    # applies Z1 during training only (pure SSM keeps it everywhere:
    # mamba2 prefill improved 0.92 s -> 0.35 s with it).
    if cfg.family == "hybrid" and not hints.sp_enabled():
        return "off"
    # NOTE (§Perf Z2, REFUTED): the Megatron-SP variant ('sp_tp': TP weights
    # + AG-entry/RS-exit) compiled to full-seq all-reduces instead of
    # reduce-scatters at the out_proj exit (XLA does not fuse AR+DS across
    # the bf16<->f32 converts on this toolchain), regressing zamba2 train
    # collectives 7.46 s -> 20.1 s.  Pure sequence sharding with replicated
    # (FSDP-only) SSM weights is the winning scheme; set REPRO_SSM_TP=1 to
    # re-measure the refuted variant.
    import os

    msize = mesh.shape.get("model", 1)
    if (os.environ.get("REPRO_SSM_TP") == "1"
            and cfg.ssm_heads % msize == 0 and cfg.d_inner % msize == 0):
        return "sp_tp"
    return "sp_only"


def _act(t, cfg, role: str):
    """Mode-dependent sharding pin for (b, l, ch...) activations."""
    from repro.parallel import hints

    mode = _ssm_mode(cfg)
    if mode == "off":
        return t
    tail = (None,) * (t.ndim - 3)
    if mode == "sp_only":
        if role == "bc":
            return hints.constrain(t, ("dp", "model", None) + tail)
        return hints.constrain(t, ("dp", "model", None) + tail)
    # sp_tp
    if role == "chan":      # z / x / dt: channel- or head-sharded, full seq
        return hints.constrain(t, ("dp", None, "model") + tail)
    if role == "bc":        # B/C: tiny, every head shard needs all of it
        return hints.constrain(t, ("dp", None, None) + tail)
    if role == "seq":       # residual exit: back to seq-sharded
        return hints.constrain(t, ("dp", "model", None) + tail)
    raise ValueError(role)


def _project(p, u, cfg):
    """u (b, l, d) -> z (b,l,din), x_conv (b,l,din), BC_conv (b,l,2gn),
    dt_raw (b,l,h); conv+silu applied (depthwise conv factorizes exactly
    across the x | BC split)."""
    z = _act(u @ p["in_z"], cfg, "chan")
    xc = _act(jax.nn.silu(
        _causal_depthwise_conv(u @ p["in_x"], p["conv_x_w"], p["conv_x_b"])
    ), cfg, "chan")
    bc = _act(jax.nn.silu(
        _causal_depthwise_conv(u @ p["in_BC"], p["conv_BC_w"], p["conv_BC_b"])
    ), cfg, "bc")
    return z, xc, bc, _act(u @ p["in_dt"], cfg, "chan")


def _split_heads(xc, bc, cfg):
    b, l, _ = xc.shape
    n, h, g = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_ngroups
    x = xc.reshape(b, l, h, cfg.ssm_headdim)
    B = bc[..., : g * n].reshape(b, l, g, n)
    C = bc[..., g * n :].reshape(b, l, g, n)
    return x, B, C


def mamba_apply(p, u, cfg, *, return_state: bool = False, init_state=None):
    """Full-sequence mamba2 block. u (b, l, d) -> (b, l, d)."""
    b, l, d = u.shape
    din = cfg.d_inner
    z, xc, bc, dt_raw = _project(p, u, cfg)
    x, B, C = _split_heads(xc, bc, cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])      # (b,l,h)
    A = -jnp.exp(p["A_log"])                                             # (h,)
    y, state = ssd_chunked(
        x, dt.astype(u.dtype), A.astype(u.dtype), B, C, cfg.ssm_chunk,
        init_state=init_state,
    )
    y = y + x * p["D"].astype(u.dtype)[None, None, :, None]
    y = _act(y.reshape(b, l, din), cfg, "chan")
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = _act(y @ p["out_proj"], cfg, "seq")   # sp_tp: partial-sum -> RS
    if return_state:
        return out, state
    return out


def mamba_decode(p, u, cfg, conv_x_state, conv_BC_state, ssm_state):
    """Single-token decode. u (b, 1, d); conv_*_state (b, K-1, ch);
    ssm_state (b, h, p, n).  O(1) in context length."""
    b = u.shape[0]
    din, n, h, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_ngroups
    pdim, K = cfg.ssm_headdim, cfg.ssm_conv
    z = u @ p["in_z"]
    dt_raw = u @ p["in_dt"]

    win_x = jnp.concatenate([conv_x_state, u @ p["in_x"]], axis=1)       # (b, K, din)
    xc = jax.nn.silu(jnp.einsum("bkc,kc->bc", win_x, p["conv_x_w"]) + p["conv_x_b"])
    conv_x_state = win_x[:, 1:, :]
    win_bc = jnp.concatenate([conv_BC_state, u @ p["in_BC"]], axis=1)    # (b, K, 2gn)
    bc = jax.nn.silu(jnp.einsum("bkc,kc->bc", win_bc, p["conv_BC_w"]) + p["conv_BC_b"])
    conv_BC_state = win_bc[:, 1:, :]

    x, B, C = _split_heads(xc[:, None, :], bc[:, None, :], cfg)          # l = 1
    x, B, C = x[:, 0], B[:, 0], C[:, 0]                                  # (b,h,p),(b,g,n)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (b,h)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None, :]).astype(u.dtype)                        # (b,h)
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=1)                                      # (b,h,n)
    Ch = jnp.repeat(C, rep, axis=1)
    xdt = x * dt.astype(u.dtype)[..., None]                              # (b,h,p)
    ssm_state = ssm_state * dA[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", xdt, Bh
    )
    y = jnp.einsum("bhpn,bhn->bhp", ssm_state, Ch) + x * p["D"].astype(u.dtype)[None, :, None]
    y = y.reshape(b, 1, din)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], conv_x_state, conv_BC_state, ssm_state
