"""Shared layer library: norms, RoPE, GQA attention (+cache), MLPs.

Conventions:
* params are plain nested dicts of jnp arrays; leaf *names* carry the
  sharding semantics (parallel/sharding.py maps names -> PartitionSpecs);
* activations flow in cfg.dtype (bf16); softmax/norm internals in f32;
* attention shapes: q (B, Sq, H, D), k/v (B, Skv, K, D) with H % K == 0.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "dense_init", "norm_init", "rmsnorm", "layernorm", "rope",
    "gqa_attention", "attn_init", "attn_apply", "attn_decode",
    "mlp_init", "mlp_apply", "update_cache",
]


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def norm_init(d: int, dtype=jnp.float32):
    return jnp.ones((d,), dtype)


def rmsnorm(x, w, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w.astype(x.dtype)


def layernorm(x, w, b, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * w.astype(x.dtype) + b.astype(x.dtype)


def rope(x, positions, theta: float):
    """Rotary embedding over the full head dim. x: (B, S, H, D); positions (S,)
    or (B, S)."""
    B, S, H, D = x.shape
    half = D // 2
    freqs = jnp.exp(
        -jnp.arange(0, half, dtype=jnp.float32) * (np.log(theta) / half)
    )  # (half,)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[None, :, None] * freqs[None, None, :]
        ang = ang[:, :, None, :]                                  # (1, S, 1, half)
    else:
        ang = positions.astype(jnp.float32)[:, :, None] * freqs[None, None, :]
        ang = ang[:, :, None, :]                                  # (B, S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


FLASH_MIN_SQ = 2048     # full-seq paths switch to chunked attention above this
Q_CHUNK = 512
KV_CHUNK = 1024


def _mask_logits(logits, q_start, kv_start, causal, window, kv_valid_len):
    """logits (..., qc, kc); positions are chunk offsets (static or traced)."""
    qc, kc = logits.shape[-2], logits.shape[-1]
    qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 0)
    spos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 1)
    mask = None
    if causal:
        mask = spos <= qpos
        if window > 0:
            mask = mask & (spos > qpos - window)
    if kv_valid_len is not None:
        valid = spos < kv_valid_len
        mask = valid if mask is None else (mask & valid)
    if mask is not None:
        shape = (1,) * (logits.ndim - 2) + (qc, kc)
        logits = jnp.where(mask.reshape(shape), logits, -1e30)
    return logits


def _attention_simple(qg, k, v, *, causal, window, q_offset, kv_valid_len, softcap):
    B, Sq, K, G, D = qg.shape
    Skv = k.shape[1]
    scale = 1.0 / np.sqrt(D)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = _mask_logits(logits, q_offset, 0, causal, window, kv_valid_len)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out


def _attention_flash(qg, k, v, *, causal, window, kv_valid_len, softcap,
                     q_chunk=Q_CHUNK, kv_chunk=KV_CHUNK):
    """Chunked online-softmax attention (pure XLA, TPU-friendly).

    Never materializes the (Sq, Skv) score matrix: python loop over q chunks
    (static causal/window chunk-skipping => near-optimal FLOPs) with a
    lax.scan over kv chunks carrying the running (max, denom, acc).
    Requires q_offset == 0 (full-sequence paths only).
    """
    B, Sq, K, G, D = qg.shape
    Skv = k.shape[1]
    Dv = v.shape[-1]
    scale = 1.0 / np.sqrt(D)

    # pad kv to a chunk multiple; padded keys masked via kv_valid_len
    pad = (-Skv) % kv_chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_valid_len is None:
            kv_valid_len = Skv
    n_q = Sq // q_chunk

    outs = []
    for iq in range(n_q):
        q_i = qg[:, iq * q_chunk : (iq + 1) * q_chunk].astype(jnp.float32) * scale
        q_lo = iq * q_chunk
        # static kv range intersecting the causal/window band of this q chunk
        kv_hi = min(k.shape[1], q_lo + q_chunk) if causal else k.shape[1]
        kv_lo = 0
        if causal and window > 0:
            kv_lo = max(0, (q_lo - window + 1) // kv_chunk * kv_chunk)
        n_kv = -(-(kv_hi - kv_lo) // kv_chunk)
        k_i = jax.lax.slice_in_dim(k, kv_lo, kv_lo + n_kv * kv_chunk, axis=1)
        v_i = jax.lax.slice_in_dim(v, kv_lo, kv_lo + n_kv * kv_chunk, axis=1)
        k_i = k_i.reshape(B, n_kv, kv_chunk, K, D)
        v_i = v_i.reshape(B, n_kv, kv_chunk, K, Dv)

        def body(carry, inp):
            m, l, acc = carry
            jkv, k_c, v_c = inp
            kv_start = kv_lo + jkv * kv_chunk
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_i, k_c.astype(jnp.float32))
            if softcap > 0.0:
                s = softcap * jnp.tanh(s / softcap)
            s = _mask_logits(s, q_lo, kv_start, causal, window, kv_valid_len)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p, v_c.astype(jnp.float32))
            acc = acc * corr[..., None] + pv
            return (m_new, l, acc), None

        init = (
            jnp.full((B, K, G, q_chunk), -jnp.inf, jnp.float32),
            jnp.zeros((B, K, G, q_chunk), jnp.float32),
            jnp.zeros((B, K, G, q_chunk, Dv), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(
            body, init,
            (jnp.arange(n_kv), jnp.moveaxis(k_i, 1, 0), jnp.moveaxis(v_i, 1, 0)),
        )
        out_i = acc / jnp.maximum(l, 1e-30)[..., None]            # (B,K,G,qc,Dv)
        outs.append(jnp.einsum("bkgqd->bqkgd", out_i))
    return jnp.concatenate(outs, axis=1).astype(v.dtype)


def gqa_attention(
    q, k, v, *,
    causal: bool,
    window: int = 0,
    q_offset=0,
    kv_valid_len=None,
    softcap: float = 0.0,
):
    """Grouped-query attention. q (B,Sq,H,D), k/v (B,Skv,K,D) -> (B,Sq,H,D).

    q_offset: absolute position of q[0] (for causal masking of decode steps
    against a cache; may be a traced scalar).
    kv_valid_len: mask out cache positions >= this length (traced ok).
    Dispatches to chunked online-softmax attention for long full sequences
    (O(Sq*chunk) memory instead of O(Sq*Skv)).
    """
    B, Sq, H, D = q.shape
    _, Skv, K, _ = k.shape
    G = H // K
    qg = q.reshape(B, Sq, K, G, D)
    use_flash = (
        Sq >= FLASH_MIN_SQ
        and Sq % Q_CHUNK == 0
        and isinstance(q_offset, int) and q_offset == 0
    )
    if use_flash:
        out = _attention_flash(
            qg, k, v, causal=causal, window=window,
            kv_valid_len=kv_valid_len, softcap=softcap,
        )
    else:
        out = _attention_simple(
            qg, k, v, causal=causal, window=window, q_offset=q_offset,
            kv_valid_len=kv_valid_len, softcap=softcap,
        )
    return out.reshape(B, Sq, H, v.shape[-1])


# --------------------------------------------------------------------------
# Standard GQA attention layer (dense / moe / hybrid / audio / vlm families)
# --------------------------------------------------------------------------


def attn_init(key, cfg, dtype, *, cross: bool = False, d_kv_in: int | None = None):
    d, H, K, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    d_kv_in = d_kv_in or d
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, H * Dh, dtype),
        "wk": dense_init(ks[1], d_kv_in, K * Dh, dtype),
        "wv": dense_init(ks[2], d_kv_in, K * Dh, dtype),
        "wo": dense_init(ks[3], H * Dh, d, dtype, scale=1.0 / np.sqrt(H * Dh)),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * Dh,), dtype)
        p["bk"] = jnp.zeros((K * Dh,), dtype)
        p["bv"] = jnp.zeros((K * Dh,), dtype)
    return p


def _project_qkv(p, x, kv_x, cfg):
    B, S, _ = x.shape
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = kv_x @ p["wk"]
    v = kv_x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, kv_x.shape[1], K, Dh)
    v = v.reshape(B, kv_x.shape[1], K, Dh)
    return q, k, v


def attn_apply(
    p, x, cfg, *,
    positions=None,
    causal: bool = True,
    kv_x=None,
    use_rope: bool = True,
    return_kv: bool = False,
):
    """Full-sequence attention (train / prefill). kv_x != None -> cross-attn."""
    kv_src = kv_x if kv_x is not None else x
    q, k, v = _project_qkv(p, x, kv_src, cfg)
    if use_rope:
        pos = positions if positions is not None else jnp.arange(x.shape[1])
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos if kv_x is None else jnp.arange(kv_src.shape[1]), cfg.rope_theta)
    out = gqa_attention(
        q, k, v, causal=causal, window=cfg.sliding_window, softcap=cfg.logit_softcap
    )
    out = out.reshape(*x.shape[:2], -1) @ p["wo"]
    if return_kv:
        return out, (k, v)
    return out


def update_cache(cache, new, pos):
    """Write `new` (B, 1, K, D) into `cache` (B, S, K, D) at position `pos`."""
    return jax.lax.dynamic_update_slice_in_dim(cache, new.astype(cache.dtype), pos, axis=1)


def attn_decode(p, x, cfg, cache_k, cache_v, pos, *, use_rope: bool = True,
                cross: bool = False):
    """Single-token decode. x (B, 1, d); cache (B, S, K, D). Returns
    (out, new_cache_k, new_cache_v)."""
    B = x.shape[0]
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, 1, H, Dh)
    if cross:
        # cross-attn: cache holds the (fixed) encoder KV; no update, no rope
        out = gqa_attention(q, cache_k, cache_v, causal=False)
        return out.reshape(B, 1, -1) @ p["wo"], cache_k, cache_v
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    k = k.reshape(B, 1, K, Dh)
    v = v.reshape(B, 1, K, Dh)
    if use_rope:
        posv = jnp.full((B, 1), pos, jnp.int32)
        q = rope(q, posv, cfg.rope_theta)
        k = rope(k, posv, cfg.rope_theta)
    cache_k = update_cache(cache_k, k, pos)
    cache_v = update_cache(cache_v, v, pos)
    out = gqa_attention(
        q, cache_k, cache_v, causal=True, window=cfg.sliding_window,
        q_offset=pos, kv_valid_len=pos + 1, softcap=cfg.logit_softcap,
    )
    return out.reshape(B, 1, -1) @ p["wo"], cache_k, cache_v


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------


def mlp_init(key, d: int, f: int, dtype, *, gated: bool = True):
    ks = jax.random.split(key, 3)
    if gated:
        return {
            "wg": dense_init(ks[0], d, f, dtype),
            "wu": dense_init(ks[1], d, f, dtype),
            "wd": dense_init(ks[2], f, d, dtype, scale=1.0 / np.sqrt(f)),
        }
    return {
        "w1": dense_init(ks[0], d, f, dtype),
        "b1": jnp.zeros((f,), dtype),
        "w2": dense_init(ks[1], f, d, dtype, scale=1.0 / np.sqrt(f)),
        "b2": jnp.zeros((d,), dtype),
    }


def mlp_apply(p, x, act: str = "silu"):
    if "wg" in p:
        g = x @ p["wg"]
        g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
        return (g * (x @ p["wu"])) @ p["wd"]
    h = jax.nn.gelu(x @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]
