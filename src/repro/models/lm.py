"""Decoder-only LM assembly for all assigned families except audio (enc-dec).

Families:
  dense  — llama/qwen/starcoder style (GQA, RoPE, gated MLP)
  moe    — olmoe (GQA + top-k MoE FFN)
  moe+MLA— deepseek-v3 (MLA attention, shared+routed experts, MTP head)
  ssm    — mamba2 (attention-free SSD)
  hybrid — zamba2 (mamba2 backbone + ONE shared attention block reused)
  vlm    — llama-3.2-vision (self blocks + gated cross-attn to image embeds)

All layer stacks run under jax.lax.scan with stacked parameters (compile
time and HLO size independent of depth) and optional per-layer remat.
Three entry points per family: loss_fn (train), prefill, decode_step.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import layers, mla, moe, ssm
from .config import ModelConfig

__all__ = ["init_params", "loss_fn", "forward", "prefill", "decode_step", "init_cache"]

Pytree = Any


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Block init / apply (kind-dispatched; homogeneous within each scan stack)
# ---------------------------------------------------------------------------


def _block_init(key, cfg: ModelConfig, kind: str):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if kind == "dense":
        return {
            "ln1": layers.norm_init(d), "attn": layers.attn_init(ks[0], cfg, dt),
            "ln2": layers.norm_init(d),
            "mlp": layers.mlp_init(ks[1], d, cfg.d_ff, dt, gated=cfg.mlp_gated),
        }
    if kind == "moe":
        return {
            "ln1": layers.norm_init(d), "attn": layers.attn_init(ks[0], cfg, dt),
            "ln2": layers.norm_init(d), "moe": moe.moe_init(ks[1], cfg, dt),
        }
    if kind == "mla_dense":
        return {
            "ln1": layers.norm_init(d), "attn": mla.mla_init(ks[0], cfg, dt),
            "ln2": layers.norm_init(d), "mlp": layers.mlp_init(ks[1], d, cfg.d_ff, dt),
        }
    if kind == "mla_moe":
        return {
            "ln1": layers.norm_init(d), "attn": mla.mla_init(ks[0], cfg, dt),
            "ln2": layers.norm_init(d), "moe": moe.moe_init(ks[1], cfg, dt),
        }
    if kind == "mamba":
        return {"ln1": layers.norm_init(d), "ssm": ssm.mamba_init(ks[0], cfg, dt)}
    if kind == "cross":
        return {
            "ln1": layers.norm_init(d),
            "attn": layers.attn_init(ks[0], cfg, dt, cross=True),
            "gate_attn": jnp.zeros((1,), jnp.float32),
            "ln2": layers.norm_init(d),
            "mlp": layers.mlp_init(ks[1], d, cfg.d_ff, dt),
            "gate_mlp": jnp.zeros((1,), jnp.float32),
        }
    raise ValueError(kind)


def _stack_init(key, cfg, kind, n):
    return jax.vmap(lambda k: _block_init(k, cfg, kind))(jax.random.split(key, n))


def _block_apply(p, x, cfg, kind, *, img=None):
    """Full-sequence block. Returns (x, aux)."""
    from repro.parallel import hints

    aux = jnp.zeros((), jnp.float32)
    if (kind == "mamba" and cfg.ssm_seq_parallel and x.ndim == 3
            and (cfg.family == "ssm" or hints.sp_enabled())):
        # sequence-parallel SSM (§Perf Z1): per-token work shards over
        # 'model' on the seq axis; weights are replicated over 'model'.
        # Hybrid archs scope this to training (see ssm._ssm_mode).
        x = hints.constrain(x, ("dp", "model", None))
    if kind in ("dense", "moe"):
        x = x + layers.attn_apply(p["attn"], layers.rmsnorm(x, p["ln1"], cfg.norm_eps), cfg)
        h = layers.rmsnorm(x, p["ln2"], cfg.norm_eps)
        if kind == "dense":
            x = x + layers.mlp_apply(p["mlp"], h, cfg.act)
        else:
            B, S, d = h.shape
            y, aux = moe.moe_dispatch(p["moe"], h.reshape(B * S, d), cfg)
            x = x + y.reshape(B, S, d)
        return x, aux
    if kind in ("mla_dense", "mla_moe"):
        x = x + mla.mla_apply(p["attn"], layers.rmsnorm(x, p["ln1"], cfg.norm_eps), cfg)
        h = layers.rmsnorm(x, p["ln2"], cfg.norm_eps)
        if kind == "mla_dense":
            x = x + layers.mlp_apply(p["mlp"], h, cfg.act)
        else:
            B, S, d = h.shape
            y, aux = moe.moe_dispatch(p["moe"], h.reshape(B * S, d), cfg)
            x = x + y.reshape(B, S, d)
        return x, aux
    if kind == "mamba":
        x = x + ssm.mamba_apply(p["ssm"], layers.rmsnorm(x, p["ln1"], cfg.norm_eps), cfg)
        return x, aux
    if kind == "cross":
        h = layers.rmsnorm(x, p["ln1"], cfg.norm_eps)
        a = layers.attn_apply(p["attn"], h, cfg, kv_x=img, causal=False, use_rope=False)
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * a
        h = layers.rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * layers.mlp_apply(p["mlp"], h, cfg.act)
        return x, aux
    raise ValueError(kind)


def _scan_stack(x, stacked, cfg, kind, *, img=None):
    """Run x through a stack of identical blocks via lax.scan (+remat)."""
    from repro.parallel import hints

    def body(carry, lp):
        h, aux = carry
        if cfg.sp_residual and hints.sp_enabled() and h.ndim == 3:
            # §Perf V1: the saved-for-backward carry stack is seq-sharded
            h = hints.constrain(h, ("dp", "model", None))
        h, a = _block_apply(lp, h, cfg, kind, img=img)
        return (h, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stacked)
    return x, aux


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig) -> Pytree:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 12)
    p: Dict[str, Any] = {
        "tok_emb": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), jnp.float32) * 0.02).astype(dt),
        "final_norm": layers.norm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (
            jax.random.normal(ks[1], (cfg.vocab, cfg.d_model), jnp.float32) * 0.02
        ).astype(dt)

    fam = cfg.family
    if fam == "dense":
        p["blocks"] = _stack_init(ks[2], cfg, "dense", cfg.n_layers)
    elif fam == "moe" and not cfg.use_mla:
        p["blocks"] = _stack_init(ks[2], cfg, "moe", cfg.n_layers)
    elif cfg.use_mla:
        nd = cfg.moe_layer_start
        p["dense_blocks"] = _stack_init(ks[2], cfg, "mla_dense", nd)
        p["moe_blocks"] = _stack_init(ks[3], cfg, "mla_moe", cfg.n_layers - nd)
        if cfg.mtp_depth:
            p["mtp_proj"] = layers.dense_init(ks[4], 2 * cfg.d_model, cfg.d_model, dt)
            p["mtp_norm_h"] = layers.norm_init(cfg.d_model)
            p["mtp_norm_e"] = layers.norm_init(cfg.d_model)
            p["mtp_blocks"] = _stack_init(ks[5], cfg, "mla_moe", cfg.mtp_depth)
    elif fam == "ssm":
        p["blocks"] = _stack_init(ks[2], cfg, "mamba", cfg.n_layers)
    elif fam == "hybrid":
        G, L, T = cfg.hybrid_groups, cfg.hybrid_group_len, cfg.hybrid_tail
        grouped = jax.vmap(lambda k: _stack_init(k, cfg, "mamba", L))(
            jax.random.split(ks[2], G)
        )
        p["mamba_groups"] = grouped                      # (G, L, ...)
        p["shared_attn"] = _block_init(ks[3], cfg, "dense")  # ONE reused block
        if T:
            p["mamba_tail"] = _stack_init(ks[4], cfg, "mamba", T)
    elif fam == "vlm":
        n_cross = cfg.n_layers // (cfg.cross_every + 1)
        n_self_per = cfg.cross_every
        p["cross_blocks"] = _stack_init(ks[2], cfg, "cross", n_cross)
        p["self_groups"] = jax.vmap(lambda k: _stack_init(k, cfg, "dense", n_self_per))(
            jax.random.split(ks[3], n_cross)
        )                                                 # (G, per, ...)
    else:
        raise ValueError(fam)
    return p


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------


def forward(params, batch, cfg: ModelConfig):
    """Token (+image) inputs -> final hidden states (B, S, d), aux loss."""
    from repro.parallel import hints

    tokens = batch["tokens"]
    x = jnp.take(params["tok_emb"], tokens, axis=0)
    x = hints.constrain(x.astype(_dtype(cfg)), ("dp", None, None))
    aux = jnp.zeros((), jnp.float32)
    fam = cfg.family

    if fam in ("dense", "ssm") or (fam == "moe" and not cfg.use_mla):
        kind = {"dense": "dense", "ssm": "mamba", "moe": "moe"}[fam]
        x, aux = _scan_stack(x, params["blocks"], cfg, kind)
    elif cfg.use_mla:
        x, a1 = _scan_stack(x, params["dense_blocks"], cfg, "mla_dense")
        x, a2 = _scan_stack(x, params["moe_blocks"], cfg, "mla_moe")
        aux = a1 + a2
    elif fam == "hybrid":
        def group(carry, gp):
            h, aux = carry
            h, _ = _block_apply(params["shared_attn"], h, cfg, "dense")
            h, a = _scan_stack(h, gp, cfg, "mamba")
            return (h, aux + a), None

        (x, aux), _ = jax.lax.scan(group, (x, aux), params["mamba_groups"])
        if cfg.hybrid_tail:
            x, a = _scan_stack(x, params["mamba_tail"], cfg, "mamba")
            aux = aux + a
    elif fam == "vlm":
        img = batch["img"].astype(_dtype(cfg))

        def group(carry, gp):
            h, aux = carry
            cp, sp = gp
            h, _ = _block_apply(cp, h, cfg, "cross", img=img)
            h, a = _scan_stack(h, sp, cfg, "dense")
            return (h, aux + a), None

        (x, aux), _ = jax.lax.scan(
            group, (x, aux), (params["cross_blocks"], params["self_groups"])
        )
    else:
        raise ValueError(fam)
    return layers.rmsnorm(x, params["final_norm"], cfg.norm_eps), aux


def _unembed(params, cfg):
    return params["tok_emb"] if cfg.tie_embeddings else params["lm_head"]


def xent_chunked(h, emb_out, labels, mask, chunk: int):
    """Chunked softmax cross-entropy over the sequence axis; never holds a
    full (B, S, V) logits tensor. Returns (sum_loss, sum_count)."""
    B, S, d = h.shape
    chunk = min(chunk, S)
    nch = S // chunk
    rem = S - nch * chunk

    def one(hc, lc, mc):
        logits = (hc @ emb_out.T).astype(jnp.float32)             # (B, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * mc), jnp.sum(mc)

    def body(carry, xs):
        l, c = carry
        hc, lc, mc = xs
        dl, dc = one(hc, lc, mc)
        return (l + dl, c + dc), None

    hs = h[:, : nch * chunk].reshape(B, nch, chunk, d).transpose(1, 0, 2, 3)
    ls = labels[:, : nch * chunk].reshape(B, nch, chunk).transpose(1, 0, 2)
    ms = mask[:, : nch * chunk].reshape(B, nch, chunk).transpose(1, 0, 2)
    body = jax.checkpoint(body, prevent_cse=False)
    (loss, count), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hs, ls, ms)
    )
    if rem:
        dl, dc = one(h[:, nch * chunk :], labels[:, nch * chunk :], mask[:, nch * chunk :])
        loss, count = loss + dl, count + dc
    return loss, count


def loss_fn(params, batch, cfg: ModelConfig):
    """Next-token LM loss (teacher forcing). batch: tokens (B,S) [+img]."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    labels = jnp.concatenate([tokens[:, 1:], jnp.zeros((B, 1), tokens.dtype)], axis=1)
    mask = jnp.concatenate(
        [jnp.ones((B, S - 1), jnp.float32), jnp.zeros((B, 1), jnp.float32)], axis=1
    )
    from repro.parallel import hints as _hints
    with _hints.sp_scope(True):
        h, aux = forward(params, batch, cfg)
    h = _hints.constrain(h, ("dp", None, None))
    emb_out = _unembed(params, cfg)
    loss_sum, count = xent_chunked(h, emb_out, labels, mask, cfg.logits_chunk)
    loss = loss_sum / jnp.maximum(count, 1.0)

    if cfg.use_mla and cfg.mtp_depth and "mtp_blocks" in params:
        # depth-1 multi-token prediction: predict t+2 from [h_t ; emb(t+1)]
        emb_next = jnp.take(params["tok_emb"], labels, axis=0).astype(h.dtype)
        cat = jnp.concatenate(
            [
                layers.rmsnorm(h, params["mtp_norm_h"], cfg.norm_eps),
                layers.rmsnorm(emb_next, params["mtp_norm_e"], cfg.norm_eps),
            ],
            axis=-1,
        )
        hm = cat @ params["mtp_proj"]
        hm, a = _scan_stack(hm, params["mtp_blocks"], cfg, "mla_moe")
        aux = aux + a
        labels2 = jnp.concatenate([labels[:, 1:], jnp.zeros((B, 1), labels.dtype)], axis=1)
        mask2 = jnp.concatenate([mask[:, 1:], jnp.zeros((B, 1), jnp.float32)], axis=1)
        l2, c2 = xent_chunked(hm, emb_out, labels2, mask2, cfg.logits_chunk)
        loss = loss + 0.1 * l2 / jnp.maximum(c2, 1.0)

    loss = loss + aux
    return loss, {"loss": loss, "aux": aux, "tokens": count}


# ---------------------------------------------------------------------------
# KV / state caches, prefill and decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, B: int, S: int) -> Pytree:
    """Zeroed cache pytree for a context capacity of S tokens."""
    dt = _dtype(cfg)
    K, Dh = cfg.n_kv_heads, cfg.head_dim
    fam = cfg.family
    if fam == "dense" or (fam == "moe" and not cfg.use_mla):
        return {
            "k": jnp.zeros((cfg.n_layers, B, S, K, Dh), dt),
            "v": jnp.zeros((cfg.n_layers, B, S, K, Dh), dt),
        }
    if cfg.use_mla:
        width = cfg.kv_lora_rank + cfg.qk_rope_dim
        return {
            "latent_dense": jnp.zeros((cfg.moe_layer_start, B, S, width), dt),
            "latent_moe": jnp.zeros((cfg.n_layers - cfg.moe_layer_start, B, S, width), dt),
        }
    if fam == "ssm":
        gn2 = 2 * cfg.ssm_ngroups * cfg.ssm_state
        return {
            "conv_x": jnp.zeros((cfg.n_layers, B, cfg.ssm_conv - 1, cfg.d_inner), dt),
            "conv_BC": jnp.zeros((cfg.n_layers, B, cfg.ssm_conv - 1, gn2), dt),
            "ssm": jnp.zeros(
                (cfg.n_layers, B, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state), dt
            ),
        }
    if fam == "hybrid":
        G, L, T = cfg.hybrid_groups, cfg.hybrid_group_len, cfg.hybrid_tail
        gn2 = 2 * cfg.ssm_ngroups * cfg.ssm_state
        c = {
            "attn_k": jnp.zeros((G, B, S, K, Dh), dt),
            "attn_v": jnp.zeros((G, B, S, K, Dh), dt),
            "conv_x": jnp.zeros((G, L, B, cfg.ssm_conv - 1, cfg.d_inner), dt),
            "conv_BC": jnp.zeros((G, L, B, cfg.ssm_conv - 1, gn2), dt),
            "ssm": jnp.zeros((G, L, B, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state), dt),
        }
        if T:
            c["conv_x_tail"] = jnp.zeros((T, B, cfg.ssm_conv - 1, cfg.d_inner), dt)
            c["conv_BC_tail"] = jnp.zeros((T, B, cfg.ssm_conv - 1, gn2), dt)
            c["ssm_tail"] = jnp.zeros(
                (T, B, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state), dt
            )
        return c
    if fam == "vlm":
        G = cfg.n_layers // (cfg.cross_every + 1)
        per = cfg.cross_every
        return {
            "k": jnp.zeros((G, per, B, S, K, Dh), dt),
            "v": jnp.zeros((G, per, B, S, K, Dh), dt),
            "img_k": jnp.zeros((G, B, cfg.n_img_tokens, K, Dh), dt),
            "img_v": jnp.zeros((G, B, cfg.n_img_tokens, K, Dh), dt),
        }
    raise ValueError(fam)


def _dense_block_decode(p, x, cfg, ck, cv, pos):
    a, ck, cv = layers.attn_decode(
        p["attn"], layers.rmsnorm(x, p["ln1"], cfg.norm_eps), cfg, ck, cv, pos
    )
    x = x + a
    x = x + layers.mlp_apply(p["mlp"], layers.rmsnorm(x, p["ln2"], cfg.norm_eps), cfg.act)
    return x, ck, cv


def _moe_block_decode(p, x, cfg, ck, cv, pos):
    a, ck, cv = layers.attn_decode(
        p["attn"], layers.rmsnorm(x, p["ln1"], cfg.norm_eps), cfg, ck, cv, pos
    )
    x = x + a
    h = layers.rmsnorm(x, p["ln2"], cfg.norm_eps)
    B, S, d = h.shape
    y, _ = moe.moe_dispatch(p["moe"], h.reshape(B * S, d), cfg)
    return x + y.reshape(B, S, d), ck, cv


def _mla_block_decode(p, x, cfg, latent, pos, kind):
    a, latent = mla.mla_decode(
        p["attn"], layers.rmsnorm(x, p["ln1"], cfg.norm_eps), cfg, latent, pos
    )
    x = x + a
    h = layers.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if kind == "mla_dense":
        x = x + layers.mlp_apply(p["mlp"], h, cfg.act)
    else:
        B, S, d = h.shape
        y, _ = moe.moe_dispatch(p["moe"], h.reshape(B * S, d), cfg)
        x = x + y.reshape(B, S, d)
    return x, latent


def _mamba_block_decode(p, x, cfg, conv_x, conv_BC, sstate):
    y, conv_x, conv_BC, sstate = ssm.mamba_decode(
        p["ssm"], layers.rmsnorm(x, p["ln1"], cfg.norm_eps), cfg, conv_x, conv_BC, sstate
    )
    return x + y, conv_x, conv_BC, sstate


def decode_step(params, batch, cache, cfg: ModelConfig):
    """One serve step: batch {'token': (B,1) int32, 'pos': scalar int32}.
    Returns (logits (B, vocab), new_cache)."""
    token, pos = batch["token"], batch["pos"]
    x = jnp.take(params["tok_emb"], token, axis=0).astype(_dtype(cfg))
    fam = cfg.family

    if fam == "dense" or (fam == "moe" and not cfg.use_mla):
        dec = _dense_block_decode if fam == "dense" else _moe_block_decode

        def body(x, inp):
            lp, ck, cv = inp
            x, ck, cv = dec(lp, x, cfg, ck, cv, pos)
            return x, (ck, cv)

        x, (ck, cv) = jax.lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
        cache = {"k": ck, "v": cv}
    elif cfg.use_mla:
        def body_d(x, inp):
            lp, lat = inp
            x, lat = _mla_block_decode(lp, x, cfg, lat, pos, "mla_dense")
            return x, lat

        def body_m(x, inp):
            lp, lat = inp
            x, lat = _mla_block_decode(lp, x, cfg, lat, pos, "mla_moe")
            return x, lat

        x, lat_d = jax.lax.scan(body_d, x, (params["dense_blocks"], cache["latent_dense"]))
        x, lat_m = jax.lax.scan(body_m, x, (params["moe_blocks"], cache["latent_moe"]))
        cache = {"latent_dense": lat_d, "latent_moe": lat_m}
    elif fam == "ssm":
        def body(x, inp):
            lp, cx, cbc, sstate = inp
            x, cx, cbc, sstate = _mamba_block_decode(lp, x, cfg, cx, cbc, sstate)
            return x, (cx, cbc, sstate)

        x, (cx, cbc, sstate) = jax.lax.scan(
            body, x, (params["blocks"], cache["conv_x"], cache["conv_BC"], cache["ssm"])
        )
        cache = {"conv_x": cx, "conv_BC": cbc, "ssm": sstate}
    elif fam == "hybrid":
        def inner(x, li):
            lp, cx_, cb_, ss_ = li
            x, cx_, cb_, ss_ = _mamba_block_decode(lp, x, cfg, cx_, cb_, ss_)
            return x, (cx_, cb_, ss_)

        def group(x, inp):
            gp, ak, av, cx, cb, sstate = inp
            x, ak, av = _dense_block_decode(params["shared_attn"], x, cfg, ak, av, pos)
            x, (cx, cb, sstate) = jax.lax.scan(inner, x, (gp, cx, cb, sstate))
            return x, (ak, av, cx, cb, sstate)

        x, (ak, av, cx, cb, sstate) = jax.lax.scan(
            group, x,
            (params["mamba_groups"], cache["attn_k"], cache["attn_v"],
             cache["conv_x"], cache["conv_BC"], cache["ssm"]),
        )
        cache = dict(cache, attn_k=ak, attn_v=av, conv_x=cx, conv_BC=cb, ssm=sstate)
        if cfg.hybrid_tail:
            x, (ctx_, ctb_, st) = jax.lax.scan(
                inner, x,
                (params["mamba_tail"], cache["conv_x_tail"], cache["conv_BC_tail"],
                 cache["ssm_tail"]),
            )
            cache = dict(cache, conv_x_tail=ctx_, conv_BC_tail=ctb_, ssm_tail=st)
    elif fam == "vlm":
        def group(x, inp):
            cp, sp, ik, iv, ck, cv = inp
            h = layers.rmsnorm(x, cp["ln1"], cfg.norm_eps)
            a, _, _ = layers.attn_decode(cp["attn"], h, cfg, ik, iv, pos, cross=True)
            x = x + jnp.tanh(cp["gate_attn"]).astype(x.dtype) * a
            h = layers.rmsnorm(x, cp["ln2"], cfg.norm_eps)
            x = x + jnp.tanh(cp["gate_mlp"]).astype(x.dtype) * layers.mlp_apply(cp["mlp"], h, cfg.act)

            def inner(x, li):
                lp, k_, v_ = li
                x, k_, v_ = _dense_block_decode(lp, x, cfg, k_, v_, pos)
                return x, (k_, v_)

            x, (ck, cv) = jax.lax.scan(inner, x, (sp, ck, cv))
            return x, (ck, cv)

        x, (ck, cv) = jax.lax.scan(
            group, x,
            (params["cross_blocks"], params["self_groups"],
             cache["img_k"], cache["img_v"], cache["k"], cache["v"]),
        )
        cache = dict(cache, k=ck, v=cv)
    else:
        raise ValueError(fam)

    h = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = (h[:, 0, :] @ _unembed(params, cfg).T).astype(jnp.float32)
    return logits, cache


def prefill(params, batch, cfg: ModelConfig, cache_len: int | None = None):
    """Forward over the prompt, building the decode cache.

    For attention families the cache is filled with the prompt KV; for SSM
    families the final recurrent state is the cache.  Returns
    (last-token logits (B, vocab), cache).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    Scap = cache_len or S
    h, _ = forward(params, batch, cfg)
    logits = (h[:, -1, :] @ _unembed(params, cfg).T).astype(jnp.float32)

    # Rebuild caches with a dedicated (non-scanned) pass per family.  For the
    # dry-run's cost model this is the faithful prefill workload: forward +
    # cache construction.
    cache = init_cache(cfg, B, Scap)
    fam = cfg.family
    dt = _dtype(cfg)
    x = jnp.take(params["tok_emb"], tokens, axis=0).astype(dt)

    if fam == "dense" or (fam == "moe" and not cfg.use_mla):
        kind = "dense" if fam == "dense" else "moe"

        def body(carry, lp):
            h = carry
            hn = layers.rmsnorm(h, lp["ln1"], cfg.norm_eps)
            a, (k, v) = layers.attn_apply(lp["attn"], hn, cfg, return_kv=True)
            h = h + a
            hn = layers.rmsnorm(h, lp["ln2"], cfg.norm_eps)
            if kind == "dense":
                h = h + layers.mlp_apply(lp["mlp"], hn, cfg.act)
            else:
                Bv, Sv, dv = hn.shape
                y, _ = moe.moe_dispatch(lp["moe"], hn.reshape(Bv * Sv, dv), cfg)
                h = h + y.reshape(Bv, Sv, dv)
            return h, (k, v)

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        _, (ks_, vs_) = jax.lax.scan(body, x, params["blocks"])
        pad = [(0, 0), (0, 0), (0, Scap - S), (0, 0), (0, 0)]
        cache = {"k": jnp.pad(ks_, pad).astype(dt), "v": jnp.pad(vs_, pad).astype(dt)}
    elif cfg.use_mla:
        def mk(blocks, xin):
            def body(carry, lp):
                h = carry
                hn = layers.rmsnorm(h, lp["ln1"], cfg.norm_eps)
                lat = mla.mla_prefill_cache(lp["attn"], hn, cfg)
                h = h + mla.mla_apply(lp["attn"], hn, cfg)
                hn = layers.rmsnorm(h, lp["ln2"], cfg.norm_eps)
                if "mlp" in lp:
                    h = h + layers.mlp_apply(lp["mlp"], hn, cfg.act)
                else:
                    Bv, Sv, dv = hn.shape
                    y, _ = moe.moe_dispatch(lp["moe"], hn.reshape(Bv * Sv, dv), cfg)
                    h = h + y.reshape(Bv, Sv, dv)
                return h, lat

            if cfg.remat:
                body = jax.checkpoint(body, prevent_cse=False)
            return jax.lax.scan(body, xin, blocks)

        x1, lat_d = mk(params["dense_blocks"], x)
        _, lat_m = mk(params["moe_blocks"], x1)
        pad = [(0, 0), (0, 0), (0, Scap - S), (0, 0)]
        cache = {
            "latent_dense": jnp.pad(lat_d, pad).astype(dt),
            "latent_moe": jnp.pad(lat_m, pad).astype(dt),
        }
    elif fam in ("ssm", "hybrid"):
        # SSM prefill: run blocks returning final states (O(1) cache).
        cache = _ssm_prefill_cache(params, x, cfg, cache, Scap)
    elif fam == "vlm":
        img = batch["img"].astype(dt)

        def group(carry, gp):
            h = carry
            cp, sp = gp
            hn = layers.rmsnorm(h, cp["ln1"], cfg.norm_eps)
            a, (ik, iv) = layers.attn_apply(
                cp["attn"], hn, cfg, kv_x=img, causal=False, use_rope=False,
                return_kv=True,
            )
            h = h + jnp.tanh(cp["gate_attn"]).astype(h.dtype) * a
            hn = layers.rmsnorm(h, cp["ln2"], cfg.norm_eps)
            h = h + jnp.tanh(cp["gate_mlp"]).astype(h.dtype) * layers.mlp_apply(cp["mlp"], hn, cfg.act)

            def inner(carry2, lp):
                h2 = carry2
                hn2 = layers.rmsnorm(h2, lp["ln1"], cfg.norm_eps)
                a2, (k, v) = layers.attn_apply(lp["attn"], hn2, cfg, return_kv=True)
                h2 = h2 + a2
                h2 = h2 + layers.mlp_apply(
                    lp["mlp"], layers.rmsnorm(h2, lp["ln2"], cfg.norm_eps), cfg.act
                )
                return h2, (k, v)

            if cfg.remat:
                inner = jax.checkpoint(inner, prevent_cse=False)
            h, (k, v) = jax.lax.scan(inner, h, sp)
            return h, (ik, iv, k, v)

        _, (ik, iv, ks_, vs_) = jax.lax.scan(
            group, x, (params["cross_blocks"], params["self_groups"])
        )
        pad = [(0, 0), (0, 0), (0, 0), (0, Scap - S), (0, 0), (0, 0)]
        cache = {
            "k": jnp.pad(ks_, pad).astype(dt),
            "v": jnp.pad(vs_, pad).astype(dt),
            "img_k": ik.astype(dt),
            "img_v": iv.astype(dt),
        }
    return logits, cache


def _ssm_prefill_cache(params, x, cfg, cache, Scap):
    dt = _dtype(cfg)
    din, n, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups
    Kc = cfg.ssm_conv - 1

    def mamba_with_state(lp, h):
        hn = layers.rmsnorm(h, lp["ln1"], cfg.norm_eps)
        sp = lp["ssm"]
        z = hn @ sp["in_z"]
        raw_x = hn @ sp["in_x"]
        raw_bc = hn @ sp["in_BC"]
        conv_x_tail = raw_x[:, -Kc:, :]
        conv_BC_tail = raw_bc[:, -Kc:, :]
        xc = jax.nn.silu(ssm._causal_depthwise_conv(raw_x, sp["conv_x_w"], sp["conv_x_b"]))
        bc = jax.nn.silu(ssm._causal_depthwise_conv(raw_bc, sp["conv_BC_w"], sp["conv_BC_b"]))
        xs, B_, C_ = ssm._split_heads(xc, bc, cfg)
        dtv = jax.nn.softplus((hn @ sp["in_dt"]).astype(jnp.float32) + sp["dt_bias"])
        A = -jnp.exp(sp["A_log"])
        y, state = ssm.ssd_chunked(
            xs, dtv.astype(h.dtype), A.astype(h.dtype), B_, C_, cfg.ssm_chunk
        )
        y = y + xs * sp["D"].astype(h.dtype)[None, None, :, None]
        y = y.reshape(*h.shape[:2], din)
        y = layers.rmsnorm(y * jax.nn.silu(z), sp["norm_w"], cfg.norm_eps)
        return h + y @ sp["out_proj"], conv_x_tail, conv_BC_tail, state

    if cfg.family == "ssm":
        def body(carry, lp):
            h = carry
            h, cx, cb, state = mamba_with_state(lp, h)
            return h, (cx, cb, state)

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        _, (cxs, cbs, states) = jax.lax.scan(body, x, params["blocks"])
        return {"conv_x": cxs.astype(dt), "conv_BC": cbs.astype(dt), "ssm": states.astype(dt)}

    # hybrid
    S = x.shape[1]

    def group(carry, inp):
        h = carry
        gp = inp
        hn = layers.rmsnorm(h, params["shared_attn"]["ln1"], cfg.norm_eps)
        a, (k, v) = layers.attn_apply(params["shared_attn"]["attn"], hn, cfg, return_kv=True)
        h = h + a
        h = h + layers.mlp_apply(
            params["shared_attn"]["mlp"],
            layers.rmsnorm(h, params["shared_attn"]["ln2"], cfg.norm_eps), cfg.act,
        )

        def inner(carry2, lp):
            h2 = carry2
            h2, cx, cb, state = mamba_with_state(lp, h2)
            return h2, (cx, cb, state)

        if cfg.remat:
            inner = jax.checkpoint(inner, prevent_cse=False)
        h, (cxs, cbs, states) = jax.lax.scan(inner, h, gp)
        return h, (k, v, cxs, cbs, states)

    h, (ks_, vs_, cxs, cbs, states) = jax.lax.scan(group, x, params["mamba_groups"])
    pad = [(0, 0), (0, 0), (0, Scap - S), (0, 0), (0, 0)]
    out = {
        "attn_k": jnp.pad(ks_, pad).astype(dt),
        "attn_v": jnp.pad(vs_, pad).astype(dt),
        "conv_x": cxs.astype(dt),
        "conv_BC": cbs.astype(dt),
        "ssm": states.astype(dt),
    }
    if cfg.hybrid_tail:
        def inner(carry2, lp):
            h2 = carry2
            h2, cx, cb, state = mamba_with_state(lp, h2)
            return h2, (cx, cb, state)

        if cfg.remat:
            inner = jax.checkpoint(inner, prevent_cse=False)
        _, (ctx_, ctb_, st) = jax.lax.scan(inner, h, params["mamba_tail"])
        out["conv_x_tail"] = ctx_.astype(dt)
        out["conv_BC_tail"] = ctb_.astype(dt)
        out["ssm_tail"] = st.astype(dt)
    return out
