"""Jitted public wrappers around the Pallas kernels.

Handles: padding to tile multiples, transposition to the kernel layouts,
interpret-mode resolution (CPU -> interpret=True so the kernel body runs in
Python; TPU -> compiled), and jnp fallbacks for tiny shapes where kernel
tiling overhead is not worth it.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .diag_quad import diag_quad_kernel
from .gram import scaled_gram_kernel
from .hermite_phi import hermite_phi_kernel, phi_tile
from .phi_gram import bank_phi_gram_kernel, phi_gram_kernel

__all__ = [
    "expansion_phi", "hermite_phi", "scaled_gram", "diag_quad",
    "fused_fit_moments", "bank_fused_fit_moments", "resolve_interpret",
]


def resolve_interpret(interpret: bool | None) -> bool:
    """interpret=None -> run in interpret mode unless actually on TPU."""
    if interpret is not None:
        return interpret
    if os.environ.get("REPRO_PALLAS_INTERPRET"):
        return os.environ["REPRO_PALLAS_INTERPRET"] != "0"
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("n_max", "block_n", "block_m", "interpret", "tile_fn"),
)
def expansion_phi(
    X: jax.Array,            # (N, p)
    consts: jax.Array,       # small global table (Hermite: (p, 3))
    S: jax.Array,            # (K, M) per-column table (Hermite: one-hot)
    *,
    n_max: int,
    block_n: int = 256,
    block_m: int = 256,
    interpret: bool | None = None,
    tile_fn=phi_tile,
) -> jax.Array:
    """Phi_(X): (N, M) expansion feature matrix via the fused Pallas kernel,
    generic over the expansion's ``tile_fn`` (a module-level function so the
    jit cache stays keyed on stable identities).

    Padded feature columns may hold garbage for non-Hermite tiles (an RFF
    column with a zero table row is cos(0) = 1, not 0) — they are sliced
    away here before anything downstream can read them."""
    N, _ = X.shape
    M = S.shape[1]
    interp = resolve_interpret(interpret)
    block_n = min(block_n, max(8, 1 << (N - 1).bit_length()))
    block_m = min(block_m, max(128, 1 << (M - 1).bit_length()))
    Xt = _pad_to(X.T.astype(jnp.float32), 1, block_n)
    Sp = _pad_to(S.astype(jnp.float32), 1, block_m)
    out = hermite_phi_kernel(
        Xt, consts, Sp, n_max=n_max, block_n=block_n, block_m=block_m,
        interpret=interp, tile_fn=tile_fn,
    )
    return out[:N, :M]


def hermite_phi(
    X: jax.Array,            # (N, p)
    consts: jax.Array,       # (p, 3) from ref.phi_consts
    S: jax.Array,            # (p*n_max, M) one-hot from ref.one_hot_selection
    *,
    n_max: int,
    block_n: int = 256,
    block_m: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Phi_(X) for the Hermite-Mercer expansion (the historical name; now a
    thin wrapper over the generic :func:`expansion_phi`)."""
    return expansion_phi(
        X, consts, S, n_max=n_max, block_n=block_n, block_m=block_m,
        interpret=interpret, tile_fn=phi_tile,
    )


@functools.partial(
    jax.jit,
    static_argnames=("n_max", "block_m", "block_k", "scale", "interpret",
                     "tile_fn"),
)
def fused_fit_moments(
    X: jax.Array,            # (N, p)
    y: jax.Array,            # (N,)
    consts: jax.Array,       # small global table (Hermite: (p, 3))
    S: jax.Array,            # (K, M) per-column table (Hermite: one-hot)
    sqrtlam: jax.Array,      # (M,)  ignored when scale=False
    sig2: jax.Array,         # scalar; ignored when scale=False
    mask: jax.Array | None = None,  # (N,) row validity; None = all valid
    *,
    n_max: int,
    block_m: int = 256,
    block_k: int = 256,
    scale: bool = True,
    interpret: bool | None = None,
    tile_fn=phi_tile,
) -> tuple[jax.Array, jax.Array]:
    """Streaming fused fit statistics: Phi is generated tile-by-tile inside
    the Gram contraction and never written to HBM (kernels/phi_gram),
    generic over the expansion's ``tile_fn``.

    scale=True  -> (B, b) with B = I + D Phi^T Phi D / sig2  (the fit solve)
    scale=False -> (G, b) with G = Phi^T Phi  (raw moments, e.g. for the
                   distributed per-shard partial sums that are psum'd first)

    ``mask`` excludes rows (e.g. shard padding) from both statistics.
    """
    N, p = X.shape
    M = S.shape[1]
    interp = resolve_interpret(interpret)
    block_k = min(block_k, max(8, 1 << (N - 1).bit_length()))
    block_m = min(block_m, max(128, 1 << (M - 1).bit_length()))
    Xt = _pad_to(X.T.astype(jnp.float32), 1, block_k)
    Sp = _pad_to(S.astype(jnp.float32), 1, block_m)
    d = _pad_to(sqrtlam.reshape(1, -1).astype(jnp.float32), 1, block_m)
    yp = _pad_to(y.reshape(1, -1).astype(jnp.float32), 1, block_k)
    if mask is None:
        mask = jnp.ones((1, N), jnp.float32)
    else:
        mask = mask.reshape(1, -1).astype(jnp.float32)
    mask = _pad_to(mask, 1, block_k)
    B, b = phi_gram_kernel(
        Xt, consts, Sp, d, jnp.asarray(sig2, jnp.float32).reshape(1, 1),
        yp, mask, n_max=n_max, block_m=block_m, block_k=block_k,
        scale=scale, interpret=interp, tile_fn=tile_fn,
    )
    # padded feature columns are garbage in general (zero for the Hermite
    # one-hot, cos(0)=1 for RFF) but live entirely in rows/cols >= M of the
    # outputs; the slice below removes every trace of them
    return B[:M, :M], b[0, :M]


@functools.partial(
    jax.jit,
    static_argnames=("n_max", "block_m", "block_k", "interpret", "tile_fn"),
)
def bank_fused_fit_moments(
    Xb: jax.Array,           # (B, N, p) per-slot inputs (N = padded row cap)
    yb: jax.Array,           # (B, N)    per-slot targets
    consts: jax.Array,       # small global table (shared spec)
    S: jax.Array,            # (K, M) per-column table (shared spec)
    mask: jax.Array | None = None,  # (B, N) per-slot row validity (ragged N)
    *,
    n_max: int,
    block_m: int = 256,
    block_k: int = 256,
    interpret: bool | None = None,
    tile_fn=phi_tile,
) -> tuple[jax.Array, jax.Array]:
    """Raw fit moments for a whole bank of B independent GPs in ONE kernel
    launch: G (B, M, M) with G_s = Phi_s^T Phi_s and b (B, M) with
    b_s = Phi_s^T y_s.  The bank axis is a leading grid dimension of the
    streaming fused kernel (kernels/phi_gram.bank_phi_gram_kernel), so the
    Hermite-feature tiles of different slots are generated in VMEM one tile
    at a time — B separate N x M Phi matrices never exist in HBM.

    ``mask`` rows with 0.0 are excluded from both statistics, which is how
    ragged per-tenant N is expressed on a fixed (B, N, p) stack.
    """
    nbank, N, p = Xb.shape
    M = S.shape[1]
    interp = resolve_interpret(interpret)
    block_k = min(block_k, max(8, 1 << (N - 1).bit_length()))
    block_m = min(block_m, max(128, 1 << (M - 1).bit_length()))
    Xt = _pad_to(jnp.swapaxes(Xb, 1, 2).astype(jnp.float32), 2, block_k)
    Sp = _pad_to(S.astype(jnp.float32), 1, block_m)
    yp = _pad_to(yb.reshape(nbank, 1, N).astype(jnp.float32), 2, block_k)
    if mask is None:
        mask = jnp.ones((nbank, 1, N), jnp.float32)
    else:
        mask = mask.reshape(nbank, 1, N).astype(jnp.float32)
    mask = _pad_to(mask, 2, block_k)
    G, b = bank_phi_gram_kernel(
        Xt, consts, Sp, yp, mask, n_max=n_max, block_m=block_m,
        block_k=block_k, interpret=interp, tile_fn=tile_fn,
    )
    # padded feature columns only touch rows/cols >= M; sliced away here
    return G[:, :M, :M], b[:, 0, :M]


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "interpret"))
def scaled_gram(
    Phi: jax.Array,          # (N, M)
    sqrtlam: jax.Array,      # (M,)
    sig2: jax.Array,         # scalar
    *,
    block_m: int = 256,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """B = I + D Phi^T Phi D / sig2 in one fused HBM pass over Phi."""
    N, M = Phi.shape
    interp = resolve_interpret(interpret)
    block_m = min(block_m, max(128, 1 << (M - 1).bit_length()))
    block_k = min(block_k, max(8, 1 << (N - 1).bit_length()))
    # zero-padding rows of Phi adds nothing to the Gram sum; zero-padded
    # columns of d produce identity rows/cols that are sliced away.
    Phip = _pad_to(_pad_to(Phi, 0, block_k), 1, block_m)
    d = _pad_to(sqrtlam.reshape(1, -1).astype(jnp.float32), 1, block_m)
    out = scaled_gram_kernel(
        Phip, d, jnp.asarray(sig2, jnp.float32).reshape(1, 1),
        block_m=block_m, block_k=block_k, interpret=interp,
    )
    return out[:M, :M]


@functools.partial(jax.jit, static_argnames=("block_n", "block_m", "interpret"))
def diag_quad(
    A: jax.Array,            # (N, M)
    C: jax.Array,            # (M, M)
    *,
    block_n: int = 256,
    block_m: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """diag(A C A^T): (N,) predictive variances without the N x N matrix."""
    N, M = A.shape
    interp = resolve_interpret(interpret)
    block_n = min(block_n, max(8, 1 << (N - 1).bit_length()))
    block_m = min(block_m, max(128, 1 << (M - 1).bit_length()))
    Ap = _pad_to(_pad_to(A, 0, block_n), 1, block_m)
    Cp = _pad_to(_pad_to(C, 0, block_m), 1, block_m)
    out = diag_quad_kernel(
        Ap, Cp, block_n=block_n, block_m=block_m, interpret=interp
    )
    return out[0, :N]
