"""Pallas TPU kernels for the FAGP hot spots (validated in interpret mode).

hermite_phi — fused Mercer feature construction (paper Eq. 19)
gram        — fused scaled Gram  B = I + D Phi^T Phi D / sig2
phi_gram    — streaming fused fit: feature tiles generated inside the Gram
              accumulation (Phi never in HBM); B and b in one pass
diag_quad   — predictive-variance diagonal without the N* x N* covariance
"""
from . import diag_quad, gram, hermite_phi, ops, phi_gram, ref
from .ops import hermite_phi as hermite_phi_op            # noqa: F401
from .ops import diag_quad as diag_quad_op                # noqa: F401
from .ops import scaled_gram as scaled_gram_op            # noqa: F401
from .ops import fused_fit_moments as fused_fit_moments_op  # noqa: F401
