"""Pallas TPU kernels for the FAGP hot spots (validated in interpret mode).

hermite_phi — fused Mercer feature construction (paper Eq. 19)
gram        — fused scaled Gram  B = I + D Phi^T Phi D / sig2
diag_quad   — predictive-variance diagonal without the N* x N* covariance
"""
from . import diag_quad, gram, hermite_phi, ops, ref
from .ops import hermite_phi as hermite_phi_op  # noqa: F401
from .ops import diag_quad as diag_quad_op      # noqa: F401
from .ops import scaled_gram as scaled_gram_op  # noqa: F401
