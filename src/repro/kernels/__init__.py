"""Pallas TPU kernels for the FAGP hot spots (validated in interpret mode).

The feature kernels are generic over a KernelExpansion's tile builder
(``tile_fn``) — see core/expansions.py for the registry.

hermite_phi — fused feature construction (generic kernel + the Hermite tile
              for paper Eq. 19)
rff_phi     — random-Fourier-feature tile builder (RFF-SE / RFF-Matern)
gram        — fused scaled Gram  B = I + D Phi^T Phi D / sig2
phi_gram    — streaming fused fit: feature tiles generated inside the Gram
              accumulation (Phi never in HBM); B and b in one pass
diag_quad   — predictive-variance diagonal without the N* x N* covariance
knn         — blocked streaming top-k neighbor search (the Vecchia
              conditioning-set builder; no N x N distance matrix)
"""
from . import diag_quad, gram, hermite_phi, knn, ops, phi_gram, ref, rff_phi
from .ops import expansion_phi as expansion_phi_op        # noqa: F401
from .ops import hermite_phi as hermite_phi_op            # noqa: F401
from .ops import diag_quad as diag_quad_op                # noqa: F401
from .ops import scaled_gram as scaled_gram_op            # noqa: F401
from .ops import fused_fit_moments as fused_fit_moments_op  # noqa: F401
