"""Pallas TPU kernel: diagonal of a quadratic form, var_i = a_i^T C a_i.

Used for FAGP predictive variances: var = diag((Phi* D) B^{-1} (Phi* D)^T).
The paper's CUDA code materializes the full N* x N* covariance and reads its
diagonal; this kernel never forms the off-diagonal entries — an O(N*) output
instead of O(N*^2) memory — while streaming C in (TK, TL) tiles.

Grid: (N/TN, M/TK, M/TL), output block (1, TN) revisited across (k, l):
    out[i] += rowsum( (A_ik @ C_kl) * A_il )
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["diag_quad_kernel"]


def _diag_quad_body(a1_ref, c_ref, a2_ref, o_ref):
    k, l = pl.program_id(1), pl.program_id(2)

    @pl.when((k == 0) & (l == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    t = jnp.dot(a1_ref[...], c_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] += jnp.sum(t * a2_ref[...], axis=1)[None, :]


def diag_quad_kernel(
    A: jax.Array,         # (N, M)
    C: jax.Array,         # (M, M)
    *,
    block_n: int = 256,
    block_m: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Raw pallas_call; returns (1, N). Requires N % block_n == M % block_m == 0."""
    N, M = A.shape
    grid = (N // block_n, M // block_m, M // block_m)
    return pl.pallas_call(
        _diag_quad_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_m), lambda i, k, l: (i, k)),
            pl.BlockSpec((block_m, block_m), lambda i, k, l: (k, l)),
            pl.BlockSpec((block_n, block_m), lambda i, k, l: (i, l)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i, k, l: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, N), jnp.float32),
        interpret=interpret,
    )(A, C, A)
