"""Blocked k-nearest-neighbor search — the Vecchia conditioning-set builder.

The Vecchia approximation (``core/vecchia.py``) needs, for every query (or
every training row), the indices of its k nearest training points.  The
naive route materializes the full Q x N pairwise-distance matrix — exactly
the N-sized intermediate this repo's streaming paths exist to avoid.  Here
the queries are processed in blocks of ``block_q`` (``lax.map``) and,
inside each query block, the training set streams through in blocks of
``block_t`` (``lax.scan``) while a running top-k of squared distances is
merged with ``jax.lax.top_k`` on the concatenated ``(block_q, k +
block_t)`` candidate set.  Peak live memory is O(block_q * (k + block_t))
— never Q x N — pinned by a jaxpr sweep in tests/test_vecchia.py exactly
like the streaming-fit memory claims.

``ordered_topk`` adds the Vecchia ordering constraint: row i may only
condition on rows j < i (so the product of conditionals telescopes to the
exact joint at full conditioning sets).  Rows with fewer than k admissible
candidates come back with +inf distance in the spare slots; the caller
masks on finiteness.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["knn_search", "ordered_topk", "sq_dists"]


def sq_dists(Xq: jax.Array, Xt: jax.Array) -> jax.Array:
    """Squared euclidean distances (Bq, Bt) between two point blocks."""
    q2 = jnp.sum(Xq * Xq, axis=1)[:, None]
    t2 = jnp.sum(Xt * Xt, axis=1)[None, :]
    return jnp.maximum(q2 + t2 - 2.0 * (Xq @ Xt.T), 0.0)


def _train_blocks(Xt: jax.Array, block_t: int):
    """Pad the training set to a whole number of blocks; returns
    (Xtb (nblk, block_t, p), jb (nblk, block_t) global row indices)."""
    N = Xt.shape[0]
    nblk = max(1, -(-N // block_t))
    pad = nblk * block_t - N
    Xtp = jnp.pad(Xt, ((0, pad), (0, 0)))
    jb = jnp.arange(nblk * block_t, dtype=jnp.int32)
    return Xtp.reshape(nblk, block_t, -1), jb.reshape(nblk, block_t)


def _scan_topk(Xq, Xtb, jb, k: int, n_train: int, iq=None):
    """Streamed top-k over pre-blocked training data for ONE query block.

    Xq (Bq, p); Xtb (nblk, Bt, p); jb (nblk, Bt) global training indices
    (padding rows have jb >= n_train and are never selected).  ``iq``
    (Bq,) global query row indices, if given, restricts candidates to
    j < iq — the Vecchia ordered-conditioning constraint.  Returns
    (dists (Bq, k) ascending, idx (Bq, k)); inadmissible slots hold +inf.
    """
    Bq = Xq.shape[0]
    init = (
        jnp.full((Bq, k), jnp.inf, Xq.dtype),
        jnp.zeros((Bq, k), jnp.int32),
    )

    def step(carry, blk):
        best_d, best_i = carry
        Xt_i, j_i = blk
        d = sq_dists(Xq, Xt_i)                                # (Bq, Bt)
        bad = j_i[None, :] >= n_train
        if iq is not None:
            bad = bad | (j_i[None, :] >= iq[:, None])
        d = jnp.where(bad, jnp.inf, d)
        cand_d = jnp.concatenate([best_d, d], axis=1)         # (Bq, k+Bt)
        cand_i = jnp.concatenate(
            [best_i, jnp.broadcast_to(j_i[None, :], d.shape)], axis=1
        )
        neg, pos = jax.lax.top_k(-cand_d, k)
        return (-neg, jnp.take_along_axis(cand_i, pos, axis=1)), None

    (best_d, best_i), _ = jax.lax.scan(step, init, (Xtb, jb))
    return best_d, best_i


def _query_blocks(Xq: jax.Array, block_q: int):
    Q = Xq.shape[0]
    nblk = max(1, -(-Q // block_q))
    pad = nblk * block_q - Q
    return jnp.pad(Xq, ((0, pad), (0, 0))).reshape(nblk, block_q, -1)


@partial(jax.jit, static_argnames=("k", "block_q", "block_t"))
def knn_search(Xq: jax.Array, Xt: jax.Array, k: int, *,
               block_q: int = 128, block_t: int = 512):
    """For each query row, the k nearest training rows.

    Returns (dists (Q, k), idx (Q, k)): squared distances ascending and the
    matching global training indices.  No Q x N distance matrix is ever
    formed (see module docstring).
    """
    Q, N = Xq.shape[0], Xt.shape[0]
    if k < 1 or k > N:
        raise ValueError(f"knn_search needs 1 <= k <= N={N}, got k={k}")
    block_q = max(1, min(block_q, Q))
    block_t = max(1, min(block_t, N))
    Xtb, jb = _train_blocks(Xt, block_t)
    d, i = jax.lax.map(
        lambda Xqi: _scan_topk(Xqi, Xtb, jb, k, N), _query_blocks(Xq, block_q)
    )
    return d.reshape(-1, k)[:Q], i.reshape(-1, k)[:Q]


@partial(jax.jit, static_argnames=("k", "block_q", "block_t"))
def ordered_topk(X: jax.Array, k: int, *,
                 block_q: int = 128, block_t: int = 512):
    """Vecchia conditioning sets under the natural ordering: for each row
    i, the (up to) k nearest rows among j < i.

    Returns (idx (N, k), mask (N, k) float32): ``mask[i, s] == 1`` marks a
    valid neighbor; rows i < k have spare slots masked 0 (their index is
    clamped to 0 so gathers stay in bounds).
    """
    N = X.shape[0]
    if k < 1 or k > N:
        raise ValueError(f"ordered_topk needs 1 <= k <= N={N}, got k={k}")
    block_q = max(1, min(block_q, N))
    block_t = max(1, min(block_t, N))
    Xtb, jb = _train_blocks(X, block_t)
    Xqb = _query_blocks(X, block_q)
    nqb = Xqb.shape[0]
    iqb = jnp.arange(nqb * block_q, dtype=jnp.int32).reshape(nqb, block_q)
    d, i = jax.lax.map(
        lambda args: _scan_topk(args[0], Xtb, jb, k, N, iq=args[1]),
        (Xqb, iqb),
    )
    d = d.reshape(-1, k)[:N]
    i = i.reshape(-1, k)[:N]
    mask = jnp.isfinite(d)
    return jnp.where(mask, i, 0), mask.astype(X.dtype)
