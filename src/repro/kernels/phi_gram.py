"""Pallas TPU kernel: streaming fused fit — Phi is never written to HBM.

The materialized fit path (hermite_phi -> scaled_gram) makes two HBM passes
and parks an N x M intermediate in HBM between them — exactly the memory
wall the paper's decomposed kernel is supposed to avoid (the M x M system
is small; the N x M feature matrix is not).  This kernel fuses feature
construction INTO the Gram accumulation: each (TK, TI) / (TK, TJ) tile of
Phi is regenerated in VMEM from the corresponding (p, TK) tile of X via the
expansion's tile builder (``tile_fn`` — hermite_phi.phi_tile for the
Hermite-Mercer expansion, rff_phi.rff_tile for the random-Fourier
families), contracted on the MXU, and discarded.  HBM traffic: read X and y once, write B (M x M) and
b (M) once.  Peak live memory is O(M^2) in N — the same asymptotic as the
jnp scan path, but in one fused pass.

The trade is recompute for bandwidth: each X tile's features are rebuilt
2 * M/TI times (once per output block row/column).  The tile builder is
O(p * n_max) VPU work per element (Hermite) or one (TK, p) x (p, TM)
contraction plus a cosine (RFF) vs the O(TI) MXU work of the Gram
contraction it feeds, so for M >= ~256 the MXU stays the bottleneck.

Outputs (one fused pallas_call):
    B = I + D (Phi^T Phi) D / sig2    (M, M)   [or plain G when scale=False]
    b = Phi^T y                        (1, M)

Grid: (M/TI, M/TJ, N/TK), K innermost.  The B block (TI, TJ) accumulates
across K (canonical revisiting matmul); the b block (1, TI) accumulates
only on the j == 0 face so each row tile of Phi contributes exactly once.
Padded rows are masked inside the kernel (phi(0) != 0, so zero-padding X
alone would corrupt the Gram).

Bank variant (``bank_phi_gram_kernel``): one extra *leading* grid axis
walks the slots of a GP bank — grid (B, M/TI, M/TJ, N/TK) — so B
independent small datasets produce B Gram/moment pairs in ONE kernel
launch.  Each slot's (p, TK) X tile regenerates its own Phi tiles in VMEM
exactly as the single-model kernel does (any tile_fn); at no point do B
separate N x M feature matrices exist anywhere.  Per-slot row masks make ragged
per-tenant N a masking detail rather than a shape change.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .hermite_phi import phi_tile

__all__ = ["phi_gram_kernel", "bank_phi_gram_kernel"]


def _phi_gram_body(
    xt_ref, consts_ref, si_ref, sj_ref, di_ref, dj_ref, sig2_ref, y_ref,
    mask_ref, o_ref, b_ref, *, p: int, n_max: int, nk: int, scale: bool,
    tile_fn,
):
    i, j = pl.program_id(0), pl.program_id(1)
    k = pl.program_id(2)

    mask = mask_ref[0, :][None, :]                     # (1, TK)
    # (TK, TI) and (TK, TJ) tiles of Phi, built in VMEM and discarded
    phi_i = tile_fn(xt_ref[...], consts_ref[...], si_ref[...],
                    p=p, n_max=n_max) * mask.T
    phi_j = tile_fn(xt_ref[...], consts_ref[...], sj_ref[...],
                    p=p, n_max=n_max) * mask.T

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        phi_i, phi_j, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when((j == 0) & (k == 0))
    def _init_b():
        b_ref[...] = jnp.zeros_like(b_ref)

    @pl.when(j == 0)
    def _acc_b():
        # (1, TI) += y_k @ Phi_k_i  (y already zero-padded past N)
        b_ref[...] += jax.lax.dot_general(
            y_ref[...], phi_i, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    if scale:
        @pl.when(k == nk - 1)
        def _epilogue():
            ti, tj = o_ref.shape
            di = di_ref[0, :][:, None]                 # (TI, 1)
            dj = dj_ref[0, :][None, :]                 # (1, TJ)
            acc = o_ref[...] * (di * dj / sig2_ref[0, 0])
            rows = i * ti + jax.lax.broadcasted_iota(jnp.int32, (ti, tj), 0)
            cols = j * tj + jax.lax.broadcasted_iota(jnp.int32, (ti, tj), 1)
            o_ref[...] = acc + jnp.where(rows == cols, 1.0, 0.0).astype(acc.dtype)


def phi_gram_kernel(
    Xt: jax.Array,        # (p, N) transposed inputs, f32
    consts: jax.Array,    # small global table (Hermite: (p, 3))
    S: jax.Array,         # (K, M) per-column table (Hermite: one-hot), f32
    d: jax.Array,         # (1, M)  sqrt(lambda) scaling
    sig2: jax.Array,      # (1, 1)  noise variance
    y: jax.Array,         # (1, N)  targets, zero-padded past the true N
    mask: jax.Array,      # (1, N)  1.0 on valid rows, 0.0 on padding
    *,
    n_max: int,
    block_m: int = 256,
    block_k: int = 256,
    scale: bool = True,
    interpret: bool = False,
    tile_fn=phi_tile,
):
    """Raw pallas_call; returns (B (M, M), b (1, M)).  Requires
    N % block_k == 0 and M % block_m == 0 (ops.fused_fit_moments pads).
    Generic over the expansion's ``tile_fn`` (see kernels/hermite_phi)."""
    p, N = Xt.shape
    M = S.shape[1]
    nk = N // block_k
    grid = (M // block_m, M // block_m, nk)
    return pl.pallas_call(
        functools.partial(
            _phi_gram_body, p=p, n_max=n_max, nk=nk, scale=scale,
            tile_fn=tile_fn,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, block_k), lambda i, j, k: (0, k)),
            pl.BlockSpec(consts.shape, lambda i, j, k: (0, 0)),
            pl.BlockSpec((S.shape[0], block_m), lambda i, j, k: (0, i)),
            pl.BlockSpec((S.shape[0], block_m), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, block_m), lambda i, j, k: (0, i)),
            pl.BlockSpec((1, block_m), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, block_k), lambda i, j, k: (0, k)),
            pl.BlockSpec((1, block_k), lambda i, j, k: (0, k)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_m), lambda i, j, k: (i, j)),
            pl.BlockSpec((1, block_m), lambda i, j, k: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, M), jnp.float32),
            jax.ShapeDtypeStruct((1, M), jnp.float32),
        ],
        interpret=interpret,
    )(Xt, consts, S, S, d, d, sig2, y, mask)


def _bank_phi_gram_body(
    xt_ref, consts_ref, si_ref, sj_ref, y_ref, mask_ref, o_ref, b_ref,
    *, p: int, n_max: int, tile_fn,
):
    j, k = pl.program_id(2), pl.program_id(3)

    mask = mask_ref[0, 0, :][None, :]                  # (1, TK)
    xt = xt_ref[0]                                     # (p, TK) this slot's rows
    phi_i = tile_fn(xt, consts_ref[...], si_ref[...],
                    p=p, n_max=n_max) * mask.T
    phi_j = tile_fn(xt, consts_ref[...], sj_ref[...],
                    p=p, n_max=n_max) * mask.T

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        phi_i, phi_j, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[None]

    @pl.when((j == 0) & (k == 0))
    def _init_b():
        b_ref[...] = jnp.zeros_like(b_ref)

    @pl.when(j == 0)
    def _acc_b():
        # (1, TI) += (mask * y)_k @ Phi_k_i — y is masked as well as Phi so
        # a non-binary mask weights b exactly like the jnp scan path
        # (_block_scan_moments masks both factors); for the binary
        # row-validity masks the bank emits, the two are identical
        b_ref[...] += jax.lax.dot_general(
            y_ref[0] * mask, phi_i, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )[None]


def bank_phi_gram_kernel(
    Xt: jax.Array,        # (B, p, N) per-slot transposed inputs, f32
    consts: jax.Array,    # small global table (shared spec)
    S: jax.Array,         # (K, M) per-column table (shared spec)
    y: jax.Array,         # (B, 1, N) per-slot targets, zero-padded
    mask: jax.Array,      # (B, 1, N) per-slot row validity (ragged N)
    *,
    n_max: int,
    block_m: int = 256,
    block_k: int = 256,
    interpret: bool = False,
    tile_fn=phi_tile,
):
    """Raw pallas_call for a whole bank: returns the *unscaled* moments
    (G (B, M, M), b (B, 1, M)) — G_s = Phi_s^T Phi_s, b_s = Phi_s^T y_s —
    in one launch.  The scaled system B = I + D G D / sig2 is assembled
    outside (its one home, ``fagp._assemble_scaled_system``, vmapped over
    slots).  Requires N % block_k == 0 and M % block_m == 0
    (ops.bank_fused_fit_moments pads)."""
    nbank, p, N = Xt.shape
    M = S.shape[1]
    grid = (nbank, M // block_m, M // block_m, N // block_k)
    return pl.pallas_call(
        functools.partial(_bank_phi_gram_body, p=p, n_max=n_max,
                          tile_fn=tile_fn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, p, block_k), lambda s, i, j, k: (s, 0, k)),
            pl.BlockSpec(consts.shape, lambda s, i, j, k: (0, 0)),
            pl.BlockSpec((S.shape[0], block_m), lambda s, i, j, k: (0, i)),
            pl.BlockSpec((S.shape[0], block_m), lambda s, i, j, k: (0, j)),
            pl.BlockSpec((1, 1, block_k), lambda s, i, j, k: (s, 0, k)),
            pl.BlockSpec((1, 1, block_k), lambda s, i, j, k: (s, 0, k)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_m, block_m), lambda s, i, j, k: (s, i, j)),
            pl.BlockSpec((1, 1, block_m), lambda s, i, j, k: (s, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nbank, M, M), jnp.float32),
            jax.ShapeDtypeStruct((nbank, 1, M), jnp.float32),
        ],
        interpret=interpret,
    )(Xt, consts, S, S, y, mask)
