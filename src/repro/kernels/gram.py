"""Pallas TPU kernel: fused scaled Gram matrix  B = I + D (Phi^T Phi) D / sig2.

The paper's hot loop computes Phi^T Sigma_n^{-1} Phi with a cuBLAS GEMM and
then adds Lambda^{-1} in a second pass.  Here the Gram contraction, the
symmetric sqrt(lambda) scaling, the 1/sigma^2 noise scaling, and the unit
diagonal are fused into one kernel: Phi is read from HBM exactly once and
the (M, M) output is written exactly once.

Grid: (M/TI, M/TJ, N/TK) with the K (row/N) axis innermost ("arbitrary"),
accumulating into the output block across K steps — the canonical Pallas
matmul revisiting pattern.  f32 accumulation regardless of input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["scaled_gram_kernel"]


def _gram_body(phi_i_ref, phi_j_ref, di_ref, dj_ref, sig2_ref, o_ref, *, nk: int):
    # program_id must be read outside pl.when branches (the interpret-mode
    # HLO path cannot substitute it inside cond sub-jaxprs)
    i, j = pl.program_id(0), pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (TI, TJ) += Phi_k_i^T @ Phi_k_j   (f32 accumulation on the MXU)
    o_ref[...] += jax.lax.dot_general(
        phi_i_ref[...], phi_j_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        ti, tj = o_ref.shape
        di = di_ref[0, :][:, None]                     # (TI, 1)
        dj = dj_ref[0, :][None, :]                     # (1, TJ)
        acc = o_ref[...] * (di * dj / sig2_ref[0, 0])
        rows = i * ti + jax.lax.broadcasted_iota(jnp.int32, (ti, tj), 0)
        cols = j * tj + jax.lax.broadcasted_iota(jnp.int32, (ti, tj), 1)
        o_ref[...] = acc + jnp.where(rows == cols, 1.0, 0.0).astype(acc.dtype)


def scaled_gram_kernel(
    Phi: jax.Array,       # (N, M)
    d: jax.Array,         # (1, M)  sqrt(lambda) scaling
    sig2: jax.Array,      # (1, 1)  noise variance
    *,
    block_m: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Raw pallas_call. Requires M % block_m == 0 and N % block_k == 0."""
    N, M = Phi.shape
    nk = N // block_k
    grid = (M // block_m, M // block_m, nk)
    return pl.pallas_call(
        functools.partial(_gram_body, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_k, block_m), lambda i, j, k: (k, i)),
            pl.BlockSpec((block_k, block_m), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_m), lambda i, j, k: (0, i)),
            pl.BlockSpec((1, block_m), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_m), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, M), jnp.float32),
        interpret=interpret,
    )(Phi, Phi, d, d, sig2)
