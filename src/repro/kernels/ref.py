"""Pure-jnp oracles for the Pallas kernels (independent implementations).

Deliberately written in the most direct/naive jnp form — no scans, no
blocking — so kernel bugs cannot hide behind shared code.  Tests assert
allclose(kernel, ref) across shape/dtype sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ref_phi", "ref_scaled_gram", "ref_diag_quad", "ref_fused_fit_moments",
    "one_hot_selection", "phi_consts",
]


def phi_consts(eps: jax.Array, rho: jax.Array) -> jax.Array:
    """(p, 3) table of [beta, delta2, z_scale=rho*beta] per input dimension."""
    beta = (1.0 + (2.0 * eps / rho) ** 2) ** 0.25
    delta2 = 0.5 * rho**2 * (beta**2 - 1.0)
    return jnp.stack([beta, delta2, rho * beta], axis=-1).astype(jnp.float32)


def one_hot_selection(idx: np.ndarray, n_max: int) -> np.ndarray:
    """(p*n_max, M) one-hot matrix S with S[j*n_max + d, m] = [idx[m, j] == d]."""
    M, p = idx.shape
    S = np.zeros((p * n_max, M), np.float32)
    for j in range(p):
        S[j * n_max + idx[:, j], np.arange(M)] = 1.0
    return S


def ref_phi(Xt: jax.Array, consts: jax.Array, S: jax.Array, n_max: int) -> jax.Array:
    """Oracle for hermite_phi_kernel: (p, N), (p, 3), (p*n_max, M) -> (N, M)."""
    p, N = Xt.shape
    out = jnp.ones((N, S.shape[1]), jnp.float32)
    for j in range(p):
        beta, delta2, zscale = consts[j, 0], consts[j, 1], consts[j, 2]
        x = Xt[j]
        z = zscale * x
        psis = [jnp.sqrt(beta) * jnp.ones_like(z)]
        if n_max > 1:
            psis.append(z * jnp.sqrt(2.0) * psis[0])
        for i in range(2, n_max):
            psis.append(
                z * jnp.sqrt(2.0 / i) * psis[-1] - jnp.sqrt((i - 1.0) / i) * psis[-2]
            )
        feats = jnp.stack(psis, axis=-1) * jnp.exp(-delta2 * x * x)[:, None]  # (N, n_max)
        out = out * (feats @ S[j * n_max : (j + 1) * n_max])
    return out


def ref_scaled_gram(Phi: jax.Array, d: jax.Array, sig2) -> jax.Array:
    """Oracle for scaled_gram_kernel: I + D (Phi^T Phi) D / sig2."""
    M = Phi.shape[1]
    d = d.reshape(-1)
    G = Phi.astype(jnp.float32).T @ Phi.astype(jnp.float32)
    return jnp.eye(M, dtype=jnp.float32) + d[:, None] * G * d[None, :] / sig2


def ref_fused_fit_moments(X, y, consts, S, d, sig2, n_max: int, scale=True):
    """Oracle for the streaming fused fit: materializes Phi (the very thing
    the kernel avoids), then reduces.  Returns (B, b) or (G, b)."""
    Phi = ref_phi(X.T.astype(jnp.float32), consts, S, n_max)
    b = Phi.T @ y.astype(jnp.float32)
    if not scale:
        return Phi.T @ Phi, b
    return ref_scaled_gram(Phi, d, sig2), b


def ref_diag_quad(A: jax.Array, C: jax.Array) -> jax.Array:
    """Oracle for diag_quad_kernel: diag(A C A^T), shape (N,)."""
    A = A.astype(jnp.float32)
    return jnp.einsum("nk,kl,nl->n", A, C.astype(jnp.float32), A)
