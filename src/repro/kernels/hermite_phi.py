"""Pallas TPU kernel: fused Mercer eigenfunction feature construction.

Computes Phi_(X) (paper Eq. 19) — the N x M tensor-product Hermite feature
matrix — in a single HBM pass: read X once (N x p), write Phi once (N x M),
with the per-dimension Hermite recurrence, Gaussian envelope, and
multi-index tensor-product combine all fused in VMEM.

TPU adaptation of the paper's CUDA eigenfunction evaluation:

* The CUDA code evaluates eigenfunctions with one thread per (sample, index)
  pair.  On TPU we tile (rows x multi-indices) into VMEM blocks and express
  the *gather* `feats[:, idx[m, j]]` as a small one-hot **matmul**
  `feats @ S_j` — dynamic gathers are VPU-hostile, while an
  (TN, n_max) @ (n_max, TM) contraction runs on the MXU.  n_max <= 64, so
  the extra FLOPs are negligible next to the saved HBM traffic of a
  materialized (N, p, n_max) intermediate.
* The Hermite recurrence is unrolled at trace time (n_max is static), using
  the gamma-scaled form (see core/mercer.py) so magnitudes stay f32-safe.

Grid: (N/TN, M/TM).  Block shapes: X^T (p, TN) [X stored transposed so the
lane dimension is the 128-aligned row axis], S (p*n_max, TM), out (TN, TM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["hermite_phi_kernel", "hermite_phi", "phi_tile"]


def phi_tile(xt, consts, s, *, p: int, n_max: int):
    """One (TN, TM) tile of Phi from in-VMEM values.

    xt: (p, TN) input rows for this tile; consts: (p, 3); s: (p*n_max, TM)
    one-hot selection.  Shared by hermite_phi_kernel and the streaming
    fused-fit kernel (phi_gram), which generates these tiles on the fly
    instead of materializing Phi in HBM.
    """
    out = None
    for j in range(p):
        beta = consts[j, 0]
        delta2 = consts[j, 1]
        zscale = consts[j, 2]
        xj = xt[j, :][None, :]                          # (1, TN)
        z = zscale * xj
        env = jnp.exp(-delta2 * xj * xj)                # (1, TN)

        # gamma-scaled Hermite recurrence, unrolled (n_max static):
        #   psi_1 = sqrt(beta); psi_2 = sqrt(2) z psi_1
        #   psi_{i+1} = z sqrt(2/i) psi_i - sqrt((i-1)/i) psi_{i-1}
        psi_prev = jnp.sqrt(beta) * jnp.ones_like(z)
        rows = [psi_prev]
        if n_max > 1:
            psi_cur = z * np.sqrt(2.0) * psi_prev
            rows.append(psi_cur)
            for i in range(2, n_max):
                nxt = z * np.float32(np.sqrt(2.0 / i)) * psi_cur \
                    - np.float32(np.sqrt((i - 1.0) / i)) * psi_prev
                psi_prev, psi_cur = psi_cur, nxt
                rows.append(nxt)
        feats = jnp.concatenate(rows, axis=0) * env     # (n_max, TN)

        s_j = s[j * n_max : (j + 1) * n_max, :]         # (n_max, TM) one-hot
        # (TN, TM) <- feats^T @ S_j  : MXU-friendly "gather"
        sel = jax.lax.dot_general(
            feats, s_j, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        out = sel if out is None else out * sel
    return out


def _phi_body(xt_ref, consts_ref, s_ref, o_ref, *, p: int, n_max: int):
    """One (TN, TM) output tile of Phi."""
    out = phi_tile(xt_ref[...], consts_ref[...], s_ref[...], p=p, n_max=n_max)
    o_ref[...] = out.astype(o_ref.dtype)


def hermite_phi_kernel(
    Xt: jax.Array,        # (p, N) transposed inputs, f32
    consts: jax.Array,    # (p, 3): [beta, delta2, rho*beta] per dim
    S: jax.Array,         # (p*n_max, M) one-hot selection, f32
    *,
    n_max: int,
    block_n: int = 256,
    block_m: int = 256,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Raw pallas_call. Requires N % block_n == 0 and M % block_m == 0
    (ops.hermite_phi pads/unpads)."""
    p, N = Xt.shape
    M = S.shape[1]
    grid = (N // block_n, M // block_m)
    return pl.pallas_call(
        functools.partial(_phi_body, p=p, n_max=n_max),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, block_n), lambda i, j: (0, i)),
            pl.BlockSpec((p, 3), lambda i, j: (0, 0)),
            pl.BlockSpec((p * n_max, block_m), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, M), out_dtype),
        interpret=interpret,
    )(Xt, consts, S)
