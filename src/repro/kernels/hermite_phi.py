"""Pallas TPU kernel: fused expansion feature construction.

Computes Phi_(X) — the N x M feature matrix of a kernel expansion — in a
single HBM pass: read X once (N x p), write Phi once (N x M), with the
per-tile feature construction fused in VMEM.  Historically this module was
Hermite-only (paper Eq. 19); the kernel is now generic over a *tile
builder* ``tile_fn(xt, consts, table, *, p, n_max) -> (TN, TM)`` so every
registered ``KernelExpansion`` (Hermite-Mercer, RFF-SE, RFF-Matern) runs
through the same grid/BlockSpec machinery:

* ``consts``: a small global table replicated to every tile (Hermite: the
  (p, 3) [beta, delta2, rho*beta] rows; RFF: unused placeholder).
* ``table``: a (K, M) per-column table blocked along the feature axis
  (Hermite: the (p*n_max, M) one-hot selection S; RFF: stacked scaled
  frequencies + phase rows — see ``kernels.rff_phi``).

TPU adaptation of the paper's CUDA eigenfunction evaluation:

* The CUDA code evaluates eigenfunctions with one thread per (sample, index)
  pair.  On TPU we tile (rows x features) into VMEM blocks and express the
  *gather* `feats[:, idx[m, j]]` as a small one-hot **matmul**
  `feats @ S_j` — dynamic gathers are VPU-hostile, while an
  (TN, n_max) @ (n_max, TM) contraction runs on the MXU.  n_max <= 64, so
  the extra FLOPs are negligible next to the saved HBM traffic of a
  materialized (N, p, n_max) intermediate.
* The Hermite recurrence is unrolled at trace time (n_max is static), in
  its gamma-scaled form.  The recurrence itself lives in ONE place —
  ``core.mercer.hermite_psi_rows`` — shared with the jnp reference path
  (``mercer.eigenfunctions_1d``), so the two implementations cannot drift.

Grid: (N/TN, M/TM).  Block shapes: X^T (p, TN) [X stored transposed so the
lane dimension is the 128-aligned row axis], table (K, TM), out (TN, TM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.mercer import hermite_psi_rows

__all__ = ["hermite_phi_kernel", "hermite_phi", "phi_tile"]


def phi_tile(xt, consts, s, *, p: int, n_max: int):
    """One (TN, TM) tile of the Hermite-Mercer Phi from in-VMEM values.

    xt: (p, TN) input rows for this tile; consts: (p, 3); s: (p*n_max, TM)
    one-hot selection.  Shared by hermite_phi_kernel and the streaming
    fused-fit kernel (phi_gram), which generates these tiles on the fly
    instead of materializing Phi in HBM.  The scaled recurrence is
    ``core.mercer.hermite_psi_rows`` — its one home.
    """
    out = None
    for j in range(p):
        beta = consts[j, 0]
        delta2 = consts[j, 1]
        zscale = consts[j, 2]
        xj = xt[j, :][None, :]                          # (1, TN)
        z = zscale * xj
        env = jnp.exp(-delta2 * xj * xj)                # (1, TN)

        rows = hermite_psi_rows(z, beta, n_max)         # n_max x (1, TN)
        feats = jnp.concatenate(rows, axis=0) * env     # (n_max, TN)

        s_j = s[j * n_max : (j + 1) * n_max, :]         # (n_max, TM) one-hot
        # (TN, TM) <- feats^T @ S_j  : MXU-friendly "gather"
        sel = jax.lax.dot_general(
            feats, s_j, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        out = sel if out is None else out * sel
    return out


def _phi_body(xt_ref, consts_ref, s_ref, o_ref, *, p: int, n_max: int,
              tile_fn):
    """One (TN, TM) output tile of Phi."""
    out = tile_fn(xt_ref[...], consts_ref[...], s_ref[...], p=p, n_max=n_max)
    o_ref[...] = out.astype(o_ref.dtype)


def hermite_phi_kernel(
    Xt: jax.Array,        # (p, N) transposed inputs, f32
    consts: jax.Array,    # small global table (Hermite: (p, 3))
    S: jax.Array,         # (K, M) per-column table (Hermite: one-hot)
    *,
    n_max: int,
    block_n: int = 256,
    block_m: int = 256,
    out_dtype=jnp.float32,
    interpret: bool = False,
    tile_fn=phi_tile,
) -> jax.Array:
    """Raw pallas_call, generic over the expansion's ``tile_fn``.  Requires
    N % block_n == 0 and M % block_m == 0 (ops.expansion_phi pads/unpads)."""
    p, N = Xt.shape
    M = S.shape[1]
    grid = (N // block_n, M // block_m)
    return pl.pallas_call(
        functools.partial(_phi_body, p=p, n_max=n_max, tile_fn=tile_fn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, block_n), lambda i, j: (0, i)),
            pl.BlockSpec(consts.shape, lambda i, j: (0, 0)),
            pl.BlockSpec((S.shape[0], block_m), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, M), out_dtype),
        interpret=interpret,
    )(Xt, consts, S)
