"""Pallas tile builder for random-Fourier-feature (RFF) expansions.

The RFF feature map of a stationary kernel k with spectral measure S(w) is

    phi_m(x) = cos(w_r x + phase_m),   m = 0..2R-1,
    r = m mod R,  phase_m = 0 for the cos half, -pi/2 for the sin half
    (cos(z - pi/2) = sin(z)),  lambda_m = 1/R,

so that Phi diag(lambda) Phi^T is the Monte-Carlo estimate
(1/R) sum_r [cos(w_r x)cos(w_r x') + sin(w_r x)sin(w_r x')] -> k(x, x').

Tile contract (see kernels/hermite_phi.py): the per-column table stacks the
scaled frequency matrix W (p, M) over the phase row (1, M), giving a
(p+1, M) table blocked along the feature axis; the global ``consts`` table
is unused (a (1, 1) placeholder keeps the shared kernel signature).  One
(TK, TM) tile of Phi is then a single MXU contraction xt^T @ W_block plus a
VPU cosine — O(p) VMEM state per column, no N x M intermediate anywhere,
which is exactly what lets the streaming fused-fit kernel (phi_gram) run
RFF fits without materializing Phi.

The frequencies themselves are *data* (they carry the lengthscale scaling
sqrt(2) * eps, differentiable for NLML learning) and are built outside the
kernel by the RFF ``KernelExpansion`` (core/expansions.py) from the base
draws stored in ``GPSpec.omega``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rff_tile", "rff_consts_placeholder"]


def rff_consts_placeholder() -> jax.Array:
    """RFF needs no global constant table; this keeps the kernel signature
    shared with the Hermite tile (consts is replicated to every tile)."""
    return jnp.zeros((1, 1), jnp.float32)


def rff_tile(xt, consts, table, *, p: int, n_max: int):
    """One (TK, TM) tile of the RFF Phi from in-VMEM values.

    xt: (p, TK) input rows for this tile; consts: unused placeholder;
    table: (p + 1, TM) block of [W; phase] — W rows are the sqrt(2)*eps-
    scaled spectral frequencies for these feature columns.  ``n_max`` is
    part of the shared tile signature and unused here (no recurrence).
    """
    w = table[:p, :]                                    # (p, TM)
    phase = table[p : p + 1, :]                         # (1, TM)
    z = jax.lax.dot_general(
        xt, w, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                   # (TK, TM)
    return jnp.cos(z + phase)
