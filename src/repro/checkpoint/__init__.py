"""Fault-tolerant checkpointing: atomic, async-capable, reshard-on-restore.

* Arbitrary pytrees are flattened to path-keyed npz (bf16 stored as a u16
  view with a dtype manifest — numpy has no native bf16).
* Writes go to ``<dir>/tmp.<step>.<pid>`` then ``os.replace`` to
  ``step_<n>`` — a crash mid-write never corrupts the latest checkpoint,
  and a killed writer's staging leftovers are ignored and reaped by the
  next ``latest_step``/``restore`` once the pid is verifiably gone.
* ``restore`` returns host arrays; pass ``shardings`` to place them onto the
  *current* mesh — sharding is recomputed from the logical rules at restore
  time, never baked into the file, which is what makes restarts elastic
  (restore onto a different device count / mesh shape just works).
* ``AsyncCheckpointer`` overlaps serialization with the next train steps.
* ``gpstate`` layers versioned, spec-validated GP-session serialization on
  top (``GP.save``/``GP.load`` and the ``TieredBank`` cold tier): the
  manifest carries the GPSpec structure + an omega hash, and restoring
  into a mismatched spec raises like ``with_spec`` does.
"""
from .gpstate import load_state, save_state
from .store import AsyncCheckpointer, latest_step, restore, save

__all__ = [
    "save", "restore", "latest_step", "AsyncCheckpointer",
    "save_state", "load_state",
]
