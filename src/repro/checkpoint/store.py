from __future__ import annotations

import json
import os
import re
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from repro.obs import metrics as obs_metrics

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer"]

_SEP = "/"
_STEP_RE = re.compile(r"^step_(\d+)$")
_TMP_RE = re.compile(r"^tmp\.(\d+)\.(\d+)$")


def _sweep_stale_tmp(ckpt_dir: Path) -> None:
    """Remove ``tmp.<step>.<pid>`` staging dirs whose writer died mid-write.

    A killed writer (crash, OOM, SIGKILL) leaves its staging dir behind;
    the atomic ``os.replace`` never ran, so the dir is garbage — but a
    LIVE writer's staging dir must not be touched.  Our own pid is always
    skipped (an ``AsyncCheckpointer`` worker thread may be mid-write), and
    other pids are only reaped when the process is verifiably gone.

    Every reaped dir counts into ``checkpoint_stale_tmp_reaped_total`` on
    the process-default metrics registry (``repro.obs``) — crash recovery
    should be visible to operators, not silent."""
    reaped = 0
    for p in ckpt_dir.iterdir():
        m = _TMP_RE.match(p.name)
        if m is None or not p.is_dir():
            continue
        pid = int(m.group(2))
        if pid == os.getpid():
            continue
        try:
            os.kill(pid, 0)          # signal 0: existence probe only
        except ProcessLookupError:
            shutil.rmtree(p, ignore_errors=True)
            reaped += 1
        except PermissionError:
            pass                     # pid alive under another user
    if reaped:
        obs_metrics.get_default().counter(
            "checkpoint_stale_tmp_reaped_total",
            "dead writers' staging dirs reaped",
        ).inc(reaped)


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(
            str(k.key) if isinstance(k, jax.tree_util.DictKey) else str(k.idx)
            if isinstance(k, jax.tree_util.SequenceKey) else str(k)
            for k in path
        )
        out[key] = leaf
    return out, treedef


def save(ckpt_dir: str | Path, step: int, tree: Any, *, metadata: dict | None = None):
    """Atomic checkpoint write: <dir>/step_<n>/{arrays.npz, manifest.json}."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f"tmp.{step}.{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    flat, _ = _flatten(tree)
    arrays, dtypes = {}, {}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        dtypes[key] = str(arr.dtype)
        if arr.dtype == jax.numpy.bfloat16:
            arr = arr.view(np.uint16)
        arrays[key.replace("/", "__")] = arr
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "manifest.json").write_text(
        json.dumps({"step": step, "dtypes": dtypes, "metadata": metadata or {}})
    )
    final = ckpt_dir / f"step_{step:010d}"
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    """Highest committed step in ``ckpt_dir`` (None when there is none).
    Stray ``tmp.*`` staging dirs from killed writers are ignored — only the
    atomically-renamed ``step_<n>`` dirs count — and verifiably-dead
    writers' leftovers are reaped on the way."""
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    _sweep_stale_tmp(ckpt_dir)
    steps = [
        int(m.group(1))
        for p in ckpt_dir.iterdir()
        if p.is_dir() and (m := _STEP_RE.match(p.name)) is not None
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, like: Any, *, step: int | None = None,
            shardings: Any = None):
    """Restore into the structure of ``like``. Returns (step, tree).

    shardings: optional pytree of NamedShardings (matching ``like``) — leaves
    are device_put onto the CURRENT mesh, implementing elastic restore."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)          # also reaps dead-writer tmp dirs
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    elif ckpt_dir.exists():
        _sweep_stale_tmp(ckpt_dir)
    d = ckpt_dir / f"step_{step:010d}"
    manifest = json.loads((d / "manifest.json").read_text())
    with np.load(d / "arrays.npz") as z:
        arrays = {k: z[k] for k in z.files}

    flat_like, treedef = _flatten(like)
    leaves = []
    sh_flat = None
    if shardings is not None:
        sh_map, _ = _flatten(shardings)
        sh_flat = sh_map
    for key in flat_like:
        arr = arrays[key.replace("/", "__")]
        want = manifest["dtypes"][key]
        if want == "bfloat16":
            arr = arr.view(jax.numpy.bfloat16)
        if sh_flat is not None:
            leaves.append(jax.device_put(arr, sh_flat[key]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves
    )
    return manifest["step"], tree


class AsyncCheckpointer:
    """Overlap checkpoint serialization with training (one in flight)."""

    def __init__(self, ckpt_dir: str | Path):
        self.ckpt_dir = Path(ckpt_dir)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        """Block until the in-flight write (if any) finishes.  A worker
        that failed raises its ORIGINAL exception here — a silent worker
        death would let training run on believing its state is durable.
        The error is raised exactly once (a later ``wait`` is clean), and
        ``save`` calls ``wait`` first, so a failure can never be skipped
        by simply scheduling the next checkpoint."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Any, *, metadata: dict | None = None):
        self.wait()
        # device_get on the main thread (jax arrays are not thread-safe to
        # fetch concurrently with dispatch), serialize off-thread
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, metadata=metadata)
            except BaseException as e:  # surfaced on next wait()
                try:
                    e.add_note(f"async checkpoint of step {step} failed")
                except AttributeError:
                    pass
                # count at FAILURE time, not at the next wait(): operators
                # watching checkpoint_async_failures_total see the event
                # even while training hasn't hit its next sync point yet
                obs_metrics.get_default().counter(
                    "checkpoint_async_failures_total",
                    "async checkpoint worker failures",
                ).inc()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
