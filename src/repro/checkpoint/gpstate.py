"""Versioned, spec-validated (de)serialization of fitted GP sessions.

A fitted :class:`~repro.core.fagp.FAGPState` is O(M^2) summary statistics
(chol/u/b) plus the hyperparameter leaves of its baked
:class:`~repro.core.fagp.GPSpec` — small enough to page between device,
disk and machines (the compact-summary structure of PAPERS.md, arXiv
1305.5826).  This module writes that state through the generic atomic
checkpoint store (:mod:`repro.checkpoint.store`) with a manifest carrying
the spec's STRUCTURE — expansion family, truncation, and a sha256 of any
RFF spectral draws — so a restore into an incompatible spec raises exactly
like ``FAGPState.with_spec`` does today, instead of silently serving a
factorization under the wrong feature map.

Layout per version: ``<dir>/step_<version>/{arrays.npz, manifest.json}``
(the store's atomic-rename format); ``save_state`` auto-increments the
version so every save is durable history, and ``latest_step``/``restore``
semantics (including dead-writer tmp reaping) come for free.

Consumers: ``GP.save``/``GP.load`` (single sessions) and the cold tier of
:class:`~repro.bank.TieredBank` (per-tenant paging, with window buffers
riding along as ``extra`` arrays).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from pathlib import Path
from typing import Optional

import numpy as np

from repro.core import fagp
from repro.core.approximation import get_approximation
from repro.core.fagp import FAGPState, GPSpec

from . import store

__all__ = ["save_state", "load_state", "spec_manifest", "omega_hash"]

FORMAT = "repro.gpstate"
FORMAT_VERSION = 1

# the FAGP family's state leaves (b is guaranteed: bank-less pre-PR-1
# states without it are rejected at save time, like banks do).  Kept as a
# module constant for the tests that pin the on-disk layout; the live
# source of truth is each family's ``ckpt_leaf_names`` hook.
_LEAVES = fagp._CKPT_LEAVES

# manifest keys added with the approximation protocol (PR 10); manifests
# written before it lack them and load with these defaults — i.e. every
# old checkpoint IS an "fagp" checkpoint, bit-exactly
_SPEC_MANIFEST_DEFAULTS = {
    "approximation": "fagp",
    "kernel": None,
    "neighbors": None,
}


def omega_hash(omega) -> Optional[str]:
    """sha256 over the RFF spectral draws (shape + f32 payload); None for
    deterministic expansions.  Cheap manifest-level identity for the
    bank-structure check that ``_check_bankable_hetero`` does by value."""
    if omega is None:
        return None
    arr = np.ascontiguousarray(np.asarray(omega, np.float32))
    h = hashlib.sha256()
    h.update(repr(arr.shape).encode())
    h.update(arr.tobytes())
    return h.hexdigest()


def spec_manifest(spec: GPSpec) -> dict:
    """The JSON-safe structural description of a spec: everything needed
    to rebuild it at load time except the hyperparameter arrays (those are
    data leaves in the npz)."""
    return {
        "approximation": spec.approximation,
        "expansion": spec.expansion,
        "n": int(spec.n),
        "index_set": spec.index_set,
        "degree": None if spec.degree is None else int(spec.degree),
        "block_rows": int(spec.block_rows),
        "store_train": bool(spec.store_train),
        "backend": spec.backend,
        "omega_sha256": omega_hash(spec.omega),
        "kernel": spec.kernel,
        "neighbors": None if spec.neighbors is None else int(spec.neighbors),
    }


def _check_compatible(meta: dict, spec: GPSpec, who: str) -> None:
    """Raise unless the checkpoint's structural manifest matches ``spec``
    — the serialized mirror of the with_spec / bank-admission checks."""
    ms = meta["spec"]
    for f in fagp._STRUCTURAL_FIELDS:
        have = ms.get(f, _SPEC_MANIFEST_DEFAULTS.get(f)) if (
            f in _SPEC_MANIFEST_DEFAULTS
        ) else ms[f]
        if have != getattr(spec, f):
            raise ValueError(
                f"{who}: checkpoint/spec mismatch: checkpoint was saved "
                f"with {f}={have!r} but the target spec has "
                f"{f}={getattr(spec, f)!r}; structural choices are frozen "
                f"into the factorization — refit instead of restoring"
            )
    if ms["omega_sha256"] != omega_hash(spec.omega):
        raise ValueError(
            f"{who}: checkpoint/spec mismatch: the RFF spectral draws "
            f"(omega) differ from the target spec's; the base frequencies "
            f"are structural — refit under the target draws"
        )


def save_state(
    ckpt_dir: str | Path,
    state: FAGPState,
    *,
    step: Optional[int] = None,
    extra: Optional[dict] = None,
) -> int:
    """Serialize one fitted session; returns the version written.

    ``step=None`` auto-increments past the directory's latest version.
    ``extra`` is an optional dict of host/device arrays stored alongside
    the state (e.g. the cold tier's sliding-window buffers) and returned
    verbatim by :func:`load_state`.
    """
    spec = state.spec
    if spec is None:
        raise ValueError(
            "save_state needs a spec-carrying state (fit() bakes one in); "
            "attach one with state.with_spec(spec) first"
        )
    ap = get_approximation(spec.approximation)
    if step is None:
        last = store.latest_step(ckpt_dir)
        step = 0 if last is None else last + 1
    tree = {
        "leaves": ap.ckpt_leaves(state),
        "hypers": {"eps": spec.eps, "rho": spec.rho, "noise": spec.noise},
    }
    if spec.omega is not None:
        tree["omega"] = spec.omega
    # stored-training-data sidecar (FAGP's store_train path; families whose
    # leaves ARE the training data, like vecchia, never set it)
    has_train = (
        getattr(state, "Phi", None) is not None and state.y is not None
    )
    if has_train:
        tree["train"] = {"Phi": state.Phi, "y": state.y}
    extra = dict(extra or {})
    if extra:
        tree["extra"] = extra
    meta = {
        "format": FORMAT,
        "format_version": FORMAT_VERSION,
        "spec": spec_manifest(spec),
        "p": int(spec.p),
        "has_train": bool(has_train),
        "extra_keys": sorted(extra),
        **ap.ckpt_meta(state),
    }
    store.save(ckpt_dir, step, tree, metadata=meta)
    return step


def _read_manifest(ckpt_dir: Path, step: int) -> dict:
    d = ckpt_dir / f"step_{step:010d}"
    if not d.is_dir():
        raise FileNotFoundError(f"no checkpoint version {step} under {ckpt_dir}")
    return json.loads((d / "manifest.json").read_text())


def load_state(
    ckpt_dir: str | Path,
    *,
    step: Optional[int] = None,
    like_spec: Optional[GPSpec] = None,
    require_hypers_match: bool = False,
) -> tuple[int, FAGPState, dict]:
    """Restore one session; returns ``(version, state, extra)``.

    The spec is rebuilt from the manifest + saved hyperparameter leaves —
    bit-exact round trip, omega included.  ``like_spec`` validates the
    checkpoint against a target spec's STRUCTURE before any array loads
    (mismatch raises, like ``with_spec``); ``require_hypers_match=True``
    additionally requires the eps/rho/noise leaves to equal the target's
    (homogeneous-bank admission; a heterogeneous bank leaves it off).
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = store.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    manifest = _read_manifest(ckpt_dir, step)
    meta = manifest["metadata"]
    if meta.get("format") != FORMAT:
        raise ValueError(
            f"{ckpt_dir} step {step} is not a {FORMAT} checkpoint "
            f"(format={meta.get('format')!r})"
        )
    if like_spec is not None:
        _check_compatible(meta, like_spec, "load_state")

    ms = meta["spec"]
    # manifests written before the approximation protocol carry no family
    # tag: they ARE fagp checkpoints and load bit-exactly as such
    ap = get_approximation(
        ms.get("approximation", _SPEC_MANIFEST_DEFAULTS["approximation"])
    )

    # rebuild a like-tree with the manifest's structure; restore() takes
    # array shapes from the npz, so placeholders carry structure only
    z = np.zeros(0, np.float32)
    like: dict = {
        "leaves": {f: z for f in ap.ckpt_leaf_names()},
        "hypers": {"eps": z, "rho": z, "noise": z},
    }
    if meta["spec"]["omega_sha256"] is not None:
        like["omega"] = z
    if meta["has_train"]:
        like["train"] = {"Phi": z, "y": z}
    if meta["extra_keys"]:
        like["extra"] = {k: z for k in meta["extra_keys"]}
    _, tree = store.restore(ckpt_dir, like, step=step)

    spec = GPSpec(
        eps=tree["hypers"]["eps"], rho=tree["hypers"]["rho"],
        noise=tree["hypers"]["noise"], n=ms["n"],
        index_set=ms["index_set"], degree=ms["degree"],
        block_rows=ms["block_rows"], store_train=ms["store_train"],
        backend=ms["backend"], expansion=ms["expansion"],
        omega=tree.get("omega"),
        approximation=ap.name,
        kernel=ms.get("kernel", _SPEC_MANIFEST_DEFAULTS["kernel"]),
        neighbors=ms.get("neighbors", _SPEC_MANIFEST_DEFAULTS["neighbors"]),
    )
    if like_spec is not None and require_hypers_match:
        for f in fagp._HYPER_FIELDS:
            if not fagp._leaf_equal(getattr(spec, f), getattr(like_spec, f)):
                raise ValueError(
                    f"load_state: checkpoint hyperparameter {f} differs "
                    f"from the target spec's; the target shares one "
                    f"feature map and eigenvalue scaling — refit the "
                    f"session under it (or restore into a heterogeneous "
                    f"bank)"
                )
    state = ap.ckpt_rebuild(spec, tree["leaves"], tree.get("train"))
    extra = {
        k: np.asarray(v) for k, v in tree.get("extra", {}).items()
    }
    return step, state, extra


def latest_version(ckpt_dir: str | Path) -> Optional[int]:
    """The newest saved version under ``ckpt_dir`` (None when empty)."""
    return store.latest_step(ckpt_dir)
