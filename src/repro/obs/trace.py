"""Lightweight span tracing for the serving pipeline.

Usage::

    tracer = Tracer()
    with tracer.span("dispatch", tenant=7, bucket=64):
        ...
    tracer.write_jsonl("trace.jsonl")         # one event per line
    json.dump(tracer.to_chrome(), fh)         # chrome://tracing / Perfetto

Each ``span`` emits ONE Chrome-trace *complete* event (``"ph": "X"``) at
exit, stamped from ``time.perf_counter_ns`` (monotonic — wall-clock
adjustments can never produce negative durations).  ``instant`` emits a
zero-duration marker (``"ph": "i"``) for point events like hyperopt
progress callbacks.  Events carry the emitting thread id, so the
dispatcher thread and the caller thread render as separate tracks and
nesting is well-defined per track.

The buffer is bounded (default 1M events ≈ a few hundred MB of JSON at
most); past the bound events are dropped and counted in
:attr:`Tracer.dropped` rather than growing without limit — the same
policy the bounded ``LatencyStats`` reservoir follows.

:data:`NULL_TRACER` is the no-op default: ``span(...)`` returns a shared
singleton whose ``__enter__``/``__exit__`` do nothing, so instrumented
code costs one method call when tracing is off.  Hot-path call sites pass
no kwargs (kwargs would build a dict even for the null tracer); per-block
sites may attach bucket/tenant attributes freely.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Optional

__all__ = ["Tracer", "NullTracer", "NULL_TRACER", "SPAN_SCHEMA_KEYS"]

# required keys of every emitted event — tools/check_trace.py validates
# emitted JSONL against exactly this contract
SPAN_SCHEMA_KEYS = ("name", "ph", "ts", "pid", "tid")

_PID = os.getpid()


class _Span:
    """Context manager recording one complete event on exit.  The buffer
    holds compact tuples ``("X", name, t0_ns, t1_ns, tid, args)`` — the
    JSON dict is only built at export time, keeping the record path to
    two clock reads, one tuple, and one list append under the lock."""

    __slots__ = ("_tracer", "_name", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: Optional[dict]):
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter_ns()
        tr = self._tracer
        with tr._lock:
            if len(tr._events) < tr._limit:
                tr._events.append(
                    ("X", self._name, self._t0, t1,
                     threading.get_ident(), self._args)
                )
            else:
                tr.dropped += 1
        return False


class Tracer:
    """Buffering Chrome-trace emitter.  Thread-safe: spans may close on
    the dispatcher thread while the caller thread opens new ones."""

    def __init__(self, *, limit: int = 1_000_000) -> None:
        self._events: list = []
        self._lock = threading.Lock()
        self._limit = int(limit)
        self.dropped = 0

    # -- recording ----------------------------------------------------------

    def span(self, name: str, **args) -> _Span:
        """Open a span; the event is emitted when the ``with`` block
        exits.  Keyword arguments become Chrome-trace ``args``."""
        return _Span(self, name, args or None)

    def instant(self, name: str, **args) -> None:
        """Emit a zero-duration point event (``ph: "i"``)."""
        t = time.perf_counter_ns()
        with self._lock:
            if len(self._events) < self._limit:
                self._events.append(
                    ("i", name, t, t, threading.get_ident(), args or None)
                )
            else:
                self.dropped += 1

    # -- export -------------------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def events(self) -> list:
        """The buffered events as Chrome-trace JSON dicts (built here, at
        export time — the record path only stores tuples)."""
        with self._lock:
            raw = list(self._events)
        out = []
        for ph, name, t0, t1, tid, args in raw:
            ev = {"name": name, "ph": ph, "ts": t0 // 1000, "pid": _PID,
                  "tid": tid}
            if ph == "X":
                ev["dur"] = (t1 - t0) // 1000
            else:
                ev["s"] = "t"              # instant scope: thread
            if args:
                ev["args"] = args
            out.append(ev)
        return out

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0

    def to_chrome(self) -> dict:
        """The Chrome ``traceEvents`` envelope — ``json.dump`` the result
        and load it in chrome://tracing or https://ui.perfetto.dev."""
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def write_jsonl(self, path) -> int:
        """Write one event per line (the format ci validates with
        ``tools/check_trace.py``); returns the number of events
        written."""
        evs = self.events()
        with open(path, "w") as fh:
            for ev in evs:
                fh.write(json.dumps(ev, separators=(",", ":")))
                fh.write("\n")
        return len(evs)


class _NullSpan:
    """Shared do-nothing span."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """No-op tracer: ``span`` hands back a shared singleton, ``instant``
    returns immediately.  The default everywhere."""

    __slots__ = ()
    dropped = 0

    def span(self, name: str, **args) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, **args) -> None:
        pass

    def __len__(self) -> int:
        return 0

    def events(self) -> list:
        return []

    def clear(self) -> None:
        pass

    def to_chrome(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def write_jsonl(self, path) -> int:
        with open(path, "w"):
            pass
        return 0


NULL_TRACER = NullTracer()
