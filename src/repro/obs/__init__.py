"""Zero-dependency fleet telemetry: metrics, span tracing, recompile
watchdog, exporters.

Everything here is stdlib-only except :func:`serving_watchdog`, which
lazily imports the jitted serving executables it guards.  The serving
stack takes ``metrics=``/``tracer=``/``watchdog=`` keyword arguments and
defaults to the no-op implementations, so telemetry is strictly opt-in
and costs one attribute lookup per instrumented site when off.
"""
from .export import MetricsServer, start_metrics_server
from .metrics import (
    Counter,
    DEFAULT_LATENCY_BUCKETS,
    Gauge,
    Histogram,
    MetricsRegistry,
    NULL,
    NullRegistry,
    get_default,
    set_default,
)
from .trace import NULL_TRACER, NullTracer, SPAN_SCHEMA_KEYS, Tracer
from .watchdog import RecompileError, RecompileWatchdog, serving_watchdog

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NullRegistry",
    "NULL",
    "get_default",
    "set_default",
    "DEFAULT_LATENCY_BUCKETS",
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "SPAN_SCHEMA_KEYS",
    "RecompileError",
    "RecompileWatchdog",
    "serving_watchdog",
    "MetricsServer",
    "start_metrics_server",
]
