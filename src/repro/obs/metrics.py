"""Process-local metrics: counters, gauges, and fixed-bucket histograms.

The serving stack (FleetEngine / BankRouter / TieredBank / GPBank.optimize)
is instrumented against this registry.  Design constraints, in order:

* **Cheap when off.** Telemetry defaults to :data:`NULL` (a
  :class:`NullRegistry`): every instrument it hands out is a shared
  singleton whose record methods are empty — an instrumented call site
  costs one attribute lookup and one no-op call, and allocates NOTHING
  (pinned by tests/test_obs.py with ``tracemalloc``).
* **Cheap when on.** Instruments are resolved ONCE at construction time
  (``self._c_admitted = registry.counter(...)``), never looked up per
  event; recording is O(1) under one registry-wide lock — an integer add
  for counters/gauges, a ``bisect`` into a fixed bucket ladder for
  histograms.  No allocation on the record path.
* **One schema.** :meth:`MetricsRegistry.snapshot` returns a
  JSON-serializable dict and :meth:`MetricsRegistry.render_prometheus`
  the text exposition format — the same series names either way, so the
  ``/metrics`` endpoint, ``FleetEngine.metrics()["counters"]`` and
  ``BENCH_obs.json`` all agree.

Zero third-party dependencies (stdlib only): the checkpoint store and the
kernel-free host layers import this module freely, in any environment.
"""
from __future__ import annotations

import threading
import weakref
from bisect import bisect_left
from functools import partial
from typing import Optional

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "NullRegistry",
    "NULL", "get_default", "set_default", "DEFAULT_LATENCY_BUCKETS",
]

# upper bounds (seconds, inclusive — Prometheus ``le`` semantics) for
# latency-shaped histograms: 10µs .. 10s log ladder, +Inf implicit
DEFAULT_LATENCY_BUCKETS = (
    1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3,
    1e-2, 2.5e-2, 5e-2, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


def _series(name: str, labels: tuple) -> str:
    """The canonical series key: ``name`` or ``name{k="v",...}`` — shared
    by snapshot() and render_prometheus() so both views line up."""
    if not labels:
        return name
    inner = ",".join(
        f'{k}="{str(v).replace(chr(92), chr(92) * 2).replace(chr(34), chr(92) + chr(34))}"'
        for k, v in labels
    )
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonically increasing count.  ``inc`` is the only mutator."""

    __slots__ = ("name", "labels", "help", "value", "_lock")

    def __init__(self, name: str, labels: tuple, help: str,
                 lock: threading.Lock):
        self.name = name
        self.labels = labels
        self.help = help
        self.value = 0
        self._lock = lock

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n

    @property
    def series(self) -> str:
        return _series(self.name, self.labels)


class Gauge:
    """A value that goes up and down (queue depth, in-flight rows)."""

    __slots__ = ("name", "labels", "help", "value", "_lock")

    def __init__(self, name: str, labels: tuple, help: str,
                 lock: threading.Lock):
        self.name = name
        self.labels = labels
        self.help = help
        self.value = 0.0
        self._lock = lock

    def set(self, v: float) -> None:
        with self._lock:
            self.value = v

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n

    def dec(self, n: float = 1.0) -> None:
        with self._lock:
            self.value -= n

    @property
    def series(self) -> str:
        return _series(self.name, self.labels)


class Histogram:
    """Fixed-bucket histogram (Prometheus cumulative-``le`` semantics:
    bucket i counts observations ``<= bounds[i]``; the last, implicit
    bucket is +Inf).  The bucket ladder is FIXED at creation — recording
    is one ``bisect`` plus an integer add, no allocation."""

    __slots__ = ("name", "labels", "help", "bounds", "counts", "sum",
                 "count", "_lock")

    def __init__(self, name: str, labels: tuple, help: str,
                 lock: threading.Lock, bounds: tuple = DEFAULT_LATENCY_BUCKETS):
        if not bounds or list(bounds) != sorted(bounds):
            raise ValueError(f"histogram bounds must be sorted: {bounds!r}")
        self.name = name
        self.labels = labels
        self.help = help
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(bounds) + 1)      # +1: the +Inf bucket
        self.sum = 0.0
        self.count = 0
        self._lock = lock

    def record(self, v: float) -> None:
        i = bisect_left(self.bounds, v)
        with self._lock:
            self.counts[i] += 1
            self.sum += v
            self.count += 1

    def record_many(self, vals) -> None:
        """Bulk record under ONE lock acquisition (harvest records a whole
        block's worth at once).  ``map`` over a pre-bound C ``bisect``
        keeps the per-value cost ~135ns."""
        counts = self.counts
        bl = partial(bisect_left, self.bounds)
        with self._lock:
            n = 0
            for i in map(bl, vals):
                counts[i] += 1
                n += 1
            self.sum += sum(vals)
            self.count += n

    @property
    def series(self) -> str:
        return _series(self.name, self.labels)


class MetricsRegistry:
    """Get-or-create instrument factory + exporter.  One lock guards both
    the instrument table and every record (records are single integer
    ops; a striped-lock design would buy nothing at serving rates)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: dict = {}          # (name, labels) -> instrument
        self._kinds: dict = {}            # name -> class (conflict guard)
        self._collectors: list = []

    def add_collector(self, fn) -> None:
        """Register a zero-arg callable invoked before every
        ``snapshot()``/``render_prometheus()``.  This is how the engine /
        router / tier flush their plain-int hot-path counters into the
        registry: the serving loop pays NOTHING per event, and scrapes
        are always fresh (the Prometheus client-library collector
        pattern).

        Bound methods are held via ``weakref.WeakMethod``: a registry
        outlives the engines that register against it, and a strong ref
        here would pin every dead engine (and its bank) forever.  A
        collector whose owner is collected is dropped silently — its
        counter totals up to the last scrape remain; deltas it never
        flushed are lost with it.  Plain functions/closures are held
        strongly (nothing else owns them)."""
        try:
            ref = weakref.WeakMethod(fn)
        except TypeError:
            ref = lambda: fn
        with self._lock:
            self._collectors.append(ref)

    def _collect(self) -> None:
        with self._lock:
            refs = list(self._collectors)
        dead = []
        for ref in refs:
            fn = ref()
            if fn is None:
                dead.append(ref)
            else:
                fn()
        if dead:
            with self._lock:
                self._collectors = [
                    r for r in self._collectors if r not in dead
                ]

    def _get(self, cls, name: str, help: str, labels: dict, **kw):
        key = (name, tuple(sorted(labels.items())))
        with self._lock:
            have = self._kinds.get(name)
            if have is not None and have is not cls:
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{have.__name__}, not {cls.__name__}"
                )
            inst = self._metrics.get(key)
            if inst is None:
                inst = cls(name, key[1], help, self._lock, **kw)
                self._metrics[key] = inst
                self._kinds[name] = cls
            elif kw.get("bounds") and inst.bounds != tuple(
                float(b) for b in kw["bounds"]
            ):
                raise ValueError(
                    f"histogram {name!r} re-registered with different "
                    f"buckets"
                )
            return inst

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  buckets: tuple = DEFAULT_LATENCY_BUCKETS,
                  **labels) -> Histogram:
        return self._get(Histogram, name, help, labels, bounds=buckets)

    # -- exporters ----------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-serializable view: ``{"counters": {series: int},
        "gauges": {series: float}, "histograms": {series: {"buckets":
        {"le": count (cumulative)}, "sum": s, "count": n}}}``."""
        self._collect()
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        with self._lock:
            items = list(self._metrics.values())
        for m in items:
            if isinstance(m, Counter):
                out["counters"][m.series] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][m.series] = m.value
            else:
                cum, buckets = 0, {}
                for b, c in zip(m.bounds, m.counts):
                    cum += c
                    buckets[repr(b)] = cum
                buckets["+Inf"] = cum + m.counts[-1]
                out["histograms"][m.series] = {
                    "buckets": buckets, "sum": m.sum, "count": m.count,
                }
        return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition (version 0.0.4): ``# HELP``/
        ``# TYPE`` once per metric name, one line per series; histograms
        expand to cumulative ``_bucket{le=...}`` + ``_sum`` + ``_count``
        exactly as the exposition format specifies."""
        self._collect()
        with self._lock:
            items = list(self._metrics.values())
        by_name: dict = {}
        for m in items:
            by_name.setdefault(m.name, []).append(m)
        lines = []
        for name, series in by_name.items():
            kind = {Counter: "counter", Gauge: "gauge",
                    Histogram: "histogram"}[type(series[0])]
            if series[0].help:
                lines.append(f"# HELP {name} {series[0].help}")
            lines.append(f"# TYPE {name} {kind}")
            for m in series:
                if isinstance(m, Histogram):
                    cum = 0
                    for b, c in zip(m.bounds, m.counts):
                        cum += c
                        lines.append(
                            f"{_series(name + '_bucket', m.labels + (('le', repr(b)),))} {cum}"
                        )
                    lines.append(
                        f"{_series(name + '_bucket', m.labels + (('le', '+Inf'),))} {cum + m.counts[-1]}"
                    )
                    lines.append(f"{_series(name + '_sum', m.labels)} {m.sum}")
                    lines.append(
                        f"{_series(name + '_count', m.labels)} {m.count}"
                    )
                else:
                    lines.append(f"{m.series} {m.value}")
        return "\n".join(lines) + "\n"


class _NullInstrument:
    """The one no-op instrument: every record method is empty, every call
    returns immediately, nothing is ever allocated."""

    __slots__ = ()

    def inc(self, n=1):
        pass

    def dec(self, n=1):
        pass

    def set(self, v):
        pass

    def record(self, v):
        pass

    def record_many(self, vals):
        pass


_NULL_INSTRUMENT = _NullInstrument()


class NullRegistry(MetricsRegistry):
    """The default registry: hands out the shared no-op instrument, so
    instrumented code paths cost one attribute lookup + one empty call
    when telemetry is off.  ``snapshot()``/``render_prometheus()`` report
    nothing."""

    def __init__(self) -> None:
        super().__init__()

    def counter(self, name, help="", **labels):
        return _NULL_INSTRUMENT

    def gauge(self, name, help="", **labels):
        return _NULL_INSTRUMENT

    def histogram(self, name, help="", buckets=DEFAULT_LATENCY_BUCKETS,
                  **labels):
        return _NULL_INSTRUMENT


NULL = NullRegistry()

# process default: what module-level instrumentation (the checkpoint
# store's crash-recovery counters) records against when nobody wired an
# explicit registry through.  serve_gp sets this to its live registry.
_default: MetricsRegistry = NULL


def get_default() -> MetricsRegistry:
    return _default


def set_default(registry: Optional[MetricsRegistry]) -> MetricsRegistry:
    """Install ``registry`` as the process default (None restores the
    no-op NULL).  Returns the previous default so callers can restore
    it."""
    global _default
    prev = _default
    _default = NULL if registry is None else registry
    return prev
