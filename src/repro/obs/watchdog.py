"""Runtime recompile watchdog.

Every PR since the bucketed-router work pins the zero-recompile invariant
in tests via the jitted-function ``_cache_size()`` idiom: warm the pow2
ladder, snapshot cache sizes, churn, assert nothing grew.  This module
promotes that idiom to a *production* guard: register the serving-path
executables, :meth:`RecompileWatchdog.arm` after warmup, and
:meth:`RecompileWatchdog.check` at block granularity — a serving-path
call that silently compiled a new executable (a shape leak past the
bucket ladder, a dtype drift, an accidental weak-type promotion) is
surfaced immediately instead of as a mystery latency spike.

Modes: ``"raise"`` (RecompileError — for tests and benches proving the
invariant), ``"warn"`` (``warnings.warn`` once per growth event — the
serving default), ``"count"`` (silent; read :attr:`recompiles`).  All
modes count, and the count lands in the metrics registry when one is
wired through (``serve_recompiles_total``).
"""
from __future__ import annotations

import warnings
from typing import Callable, Optional

__all__ = ["RecompileError", "RecompileWatchdog", "serving_watchdog"]


class RecompileError(RuntimeError):
    """A registered executable compiled after the watchdog was armed."""


def _cache_size(fn) -> int:
    return int(fn._cache_size())


class RecompileWatchdog:
    """Snapshots per-executable jit cache sizes and reports growth.

    ``register`` wants the *jitted callable* (anything exposing
    ``_cache_size()``, i.e. the module-level ``jax.jit`` products the
    bank keeps); ``arm()`` re-baselines after warmup so legitimate
    first-compiles of the bucket ladder are not reported; ``check()``
    compares and, per mode, raises / warns / counts.
    """

    def __init__(self, *, mode: str = "warn", counter=None) -> None:
        if mode not in ("raise", "warn", "count"):
            raise ValueError(f"mode must be raise|warn|count, got {mode!r}")
        self.mode = mode
        self._fns: dict = {}            # name -> jitted fn
        self._baseline: dict = {}       # name -> cache size at arm()
        self._counter = counter         # obs.metrics Counter (or None)
        self.recompiles = 0             # total growth observed since arm()
        self.events: list = []          # (context, {name: growth}) log

    def register(self, name: str, fn: Callable) -> "RecompileWatchdog":
        if not hasattr(fn, "_cache_size"):
            raise TypeError(
                f"{name!r}: object has no _cache_size() — register the "
                f"jax.jit product itself, not a wrapper"
            )
        self._fns[name] = fn
        self._baseline[name] = _cache_size(fn)
        return self

    def arm(self) -> "RecompileWatchdog":
        """Re-baseline every registered executable (call after warmup —
        compiles before arm() are expected, growth after is a leak)."""
        for name, fn in self._fns.items():
            self._baseline[name] = _cache_size(fn)
        return self

    def sizes(self) -> dict:
        return {name: _cache_size(fn) for name, fn in self._fns.items()}

    def check(self, context: str = "") -> dict:
        """Compare cache sizes against the armed baseline.  Returns
        ``{name: growth}`` for executables that grew (and advances the
        baseline so each compile is reported once)."""
        grew = {}
        for name, fn in self._fns.items():
            size = _cache_size(fn)
            base = self._baseline[name]
            if size > base:
                grew[name] = size - base
                self._baseline[name] = size
        if grew:
            n = sum(grew.values())
            self.recompiles += n
            self.events.append((context, grew))
            if self._counter is not None:
                self._counter.inc(n)
            msg = (f"recompile detected ({context or 'serving path'}): "
                   + ", ".join(f"{k} +{v}" for k, v in sorted(grew.items())))
            if self.mode == "raise":
                raise RecompileError(msg)
            if self.mode == "warn":
                warnings.warn(msg, RuntimeWarning, stacklevel=2)
        return grew


def serving_watchdog(*, mode: str = "warn", metrics=None,
                     watchdog: Optional[RecompileWatchdog] = None
                     ) -> RecompileWatchdog:
    """A watchdog pre-registered with every serving-path executable the
    stack dispatches through: the bank's scatter/gather kernels, the
    posterior kernels, and the hyperopt lane step.  Imports lazily so
    ``repro.obs`` itself stays importable without jax."""
    from ..bank import bank as bank_mod
    from ..bank import sharded as sharded_mod
    from ..core import fagp
    from ..optim import gp_hyperopt

    counter = None
    if metrics is not None:
        counter = metrics.counter(
            "serve_recompiles_total",
            "serving-path executables compiled after watchdog arm",
        )
    wd = watchdog or RecompileWatchdog(mode=mode, counter=counter)
    for name, fn in (
        ("bank_write_slot", bank_mod._write_slot),
        ("bank_update_scatter", bank_mod._bank_update_scatter),
        ("bank_update_scatter_donated", bank_mod._bank_update_scatter_donated),
        ("bank_gathered_posterior", fagp._bank_gathered_posterior),
        ("hetero_gathered_mean_var", bank_mod._hetero_gathered_mean_var),
        ("bank_downdate_scatter", bank_mod._bank_downdate_scatter),
        ("bank_refit_scatter", bank_mod._bank_refit_scatter),
        ("hyperopt_lane_step", gp_hyperopt._lane_step),
        ("hyperopt_lane_values", gp_hyperopt._lane_values),
        ("bank_shard_mean_var", sharded_mod._sh_mean_var),
        ("bank_shard_update_scatter", sharded_mod._sh_update_scatter),
        ("bank_shard_downdate_scatter", sharded_mod._sh_downdate_scatter),
        ("bank_shard_refit_scatter", sharded_mod._sh_refit_scatter),
        ("bank_shard_write_slot", sharded_mod._sh_write_slot),
        ("bank_shard_read_slot", sharded_mod._sh_read_slot),
        ("bank_shard_binv", sharded_mod._sh_binv),
    ):
        wd.register(name, fn)
    return wd
