"""Stdlib-only exporters: a Prometheus/JSON HTTP endpoint for a live
registry.

``serve_gp --metrics-port 9100`` starts this next to the serving loop:

* ``GET /metrics``       → Prometheus text exposition (version 0.0.4)
* ``GET /metrics.json``  → :meth:`MetricsRegistry.snapshot` as JSON

The server runs on a daemon thread (it never outlives the process) and
reads the registry under its lock, so scrapes are consistent snapshots
even while the dispatcher thread is recording.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

__all__ = ["start_metrics_server", "MetricsServer"]


class MetricsServer:
    """Handle on a running exporter: ``.port`` (useful with port 0),
    ``.url``, ``.shutdown()``."""

    def __init__(self, httpd: ThreadingHTTPServer, thread: threading.Thread):
        self._httpd = httpd
        self._thread = thread
        self.port = httpd.server_address[1]
        self.url = f"http://{httpd.server_address[0]}:{self.port}/metrics"

    def shutdown(self) -> None:
        self._httpd.shutdown()
        self._thread.join(timeout=5.0)
        self._httpd.server_close()


def start_metrics_server(registry, port: int = 0,
                         host: str = "127.0.0.1") -> MetricsServer:
    """Expose ``registry`` over HTTP; ``port=0`` binds an ephemeral port
    (read it back from the returned handle — tests do)."""

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):
            if self.path in ("/", "/metrics"):
                body = registry.render_prometheus().encode()
                ctype = "text/plain; version=0.0.4; charset=utf-8"
            elif self.path == "/metrics.json":
                body = json.dumps(registry.snapshot(), indent=2).encode()
                ctype = "application/json"
            else:
                self.send_response(404)
                self.end_headers()
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, fmt, *args):   # keep scrapes out of stderr
            pass

    httpd = ThreadingHTTPServer((host, port), Handler)
    httpd.daemon_threads = True
    thread = threading.Thread(target=httpd.serve_forever,
                              name="repro-metrics", daemon=True)
    thread.start()
    return MetricsServer(httpd, thread)
