"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; multi_pod adds the leading 'pod' axis
    (2 pods = 512 chips).  Gradient sync across 'pod' is a pure all-reduce;
    FSDP/TP stay inside a pod (axes 'data'/'model')."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=axis_types)


def make_local_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many devices this host exposes (tests)."""
    axis_types = (jax.sharding.AxisType.Auto,) * 2
    return jax.make_mesh((data, model), ("data", "model"), axis_types=axis_types)
