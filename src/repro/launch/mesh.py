"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "make_bank_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; multi_pod adds the leading 'pod' axis
    (2 pods = 512 chips).  Gradient sync across 'pod' is a pure all-reduce;
    FSDP/TP stay inside a pod (axes 'data'/'model')."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=axis_types)


def make_local_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many devices this host exposes (tests)."""
    axis_types = (jax.sharding.AxisType.Auto,) * 2
    return jax.make_mesh((data, model), ("data", "model"), axis_types=axis_types)


def make_bank_mesh(bank: int, data: int = 1) -> jax.sharding.Mesh:
    """(bank, data) mesh for the sharded GP fleet: 'bank' splits the tenant
    axis across devices (``ShardedGPBank``), 'data' optionally row-shards
    large-N fits inside each bank shard.  Built on the plain ``Mesh``
    constructor so it works on every jax this repo supports (the
    AxisType/make_mesh API used above is newer); on CPU, export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before the first
    jax import to expose multiple host devices."""
    n = bank * data
    devs = jax.devices()
    if len(devs) < n:
        raise ValueError(
            f"make_bank_mesh(bank={bank}, data={data}) wants {n} devices; "
            f"only {len(devs)} visible (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count=N before jax starts)"
        )
    import numpy as np
    grid = np.asarray(devs[:n], dtype=object).reshape(bank, data)
    return jax.sharding.Mesh(grid, ("bank", "data"))
