"""GP serving loops: one session, or a whole fleet through the bank router.

Two production shapes of the paper's workload:

* ``serve_gp``    — ONE fitted session serves microbatched ``mean_var``
  queries while new observations stream in (``GP.update`` rank-k ingest).
* ``serve_fleet`` — MANY small independent sessions (one per tenant)
  served concurrently: the sessions live device-resident in a
  :class:`~repro.bank.GPBank` (one stacked state, one executable for the
  whole fleet) and traffic flows through a :class:`~repro.bank.BankRouter`
  that coalesces per-tenant query/observation queues into padded
  mixed-tenant microbatches.  By default the router is driven by the
  pipelined :class:`~repro.bank.FleetEngine` (``engine="pipelined"``):
  dispatch-ahead blocks with no per-tick ``block_until_ready``, per-tenant
  deadlines answered with the documented timeout sentinel, queue-budget
  backpressure, arrival-rate-autotuned microbatch buckets, and per-tenant
  p50/p99 + sustained-QPS metrics in the returned history.
  ``engine="sync"`` keeps the strict coalesce -> dispatch -> block ->
  respond loop (the baseline ``benchmarks/serve_latency.py`` beats).

Both loops speak self-describing sessions: the spec (index set, backend,
block size) is baked in at fit time, so neither the query path nor the
ingest path re-passes configuration.

  PYTHONPATH=src python -m repro.launch.serve_gp --backend pallas \\
      --n-train 2048 --p 2 --n 8 --rounds 4 --update-size 64 \\
      --queries 512 --microbatch 128
  PYTHONPATH=src python -m repro.launch.serve_gp --fleet 64 --n-train 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.bank import (
    BankRouter, FleetEngine, GPBank, ShardedGPBank, TieredBank,
)
from repro.core import fagp
from repro.core.gp import GP, GPSpec
from repro.data import make_gp_dataset
from repro.obs import (
    NULL,
    NULL_TRACER,
    MetricsRegistry,
    Tracer,
    serving_watchdog,
    start_metrics_server,
)
from repro.obs import metrics as obs_metrics

__all__ = ["serve_gp", "serve_fleet", "microbatched_mean_var"]


def microbatched_mean_var(gp, Xs, *, microbatch: int):
    """``mean_var`` in fixed-size microbatches (padded tail).

    ``gp`` is a :class:`GP` session (a spec-carrying :class:`FAGPState` is
    also accepted and wrapped).  Returns (mu, var, per_batch_seconds).
    Every call sees the same (B, p) shape, so the serving path compiles
    exactly once per state shape.  Padding and microbatch slicing happen
    once, up front, outside the timed region — ``per_batch_seconds``
    measures only ``mean_var``.
    """
    if isinstance(gp, fagp.FAGPState):
        gp = GP.from_state(gp)
    Nq = Xs.shape[0]
    nb = max(1, (Nq + microbatch - 1) // microbatch)
    pad = nb * microbatch - Nq
    Xp = jnp.pad(Xs, ((0, pad), (0, 0)))
    blocks = [
        jax.lax.dynamic_slice_in_dim(Xp, i * microbatch, microbatch)
        for i in range(nb)
    ]
    jax.block_until_ready(blocks)
    mus, variances, times = [], [], []
    for blk in blocks:
        t0 = time.perf_counter()
        mu, var = gp.mean_var(blk)
        jax.block_until_ready((mu, var))
        times.append(time.perf_counter() - t0)
        mus.append(np.asarray(mu))
        variances.append(np.asarray(var))
    mu = np.concatenate(mus)[:Nq]
    var = np.concatenate(variances)[:Nq]
    return mu, var, times


def serve_gp(
    *,
    backend: str = "jnp",
    n_train: int = 2048,
    p: int = 2,
    n: int = 8,
    rounds: int = 4,
    update_size: int = 64,
    queries: int = 512,
    microbatch: int = 128,
    noise: float = 0.05,
    seed: int = 0,
) -> dict:
    spec = GPSpec.create(
        n, eps=jnp.full((p,), 0.8), rho=2.0, noise=noise, backend=backend,
    )
    # n_train initial rows + rounds * update_size streamed rows, one pool
    total = n_train + rounds * update_size
    X_all, y_all, Xs, ys = make_gp_dataset(total, p, noise=noise, seed=seed)
    X0, y0 = X_all[:n_train], y_all[:n_train]

    t0 = time.perf_counter()
    gp = GP.fit(X0, y0, spec)
    jax.block_until_ready(gp.state.u)
    t_fit = time.perf_counter() - t0

    Xq = Xs[:queries] if queries <= Xs.shape[0] else Xs
    ysq = np.asarray(ys)[: Xq.shape[0]]

    history = []
    for r in range(rounds):
        lo = n_train + r * update_size
        Xn, yn = X_all[lo : lo + update_size], y_all[lo : lo + update_size]
        t0 = time.perf_counter()
        gp = gp.update(Xn, yn)
        jax.block_until_ready(gp.state.u)
        t_update = time.perf_counter() - t0

        mu, var, times = microbatched_mean_var(gp, Xq, microbatch=microbatch)
        rmse = float(np.sqrt(np.mean((mu - ysq) ** 2)))
        times.sort()
        history.append({
            "round": r,
            "rows_absorbed": int(lo + update_size),
            "update_s": t_update,
            "predict_p50_s": times[len(times) // 2],
            "queries_per_s": Xq.shape[0] / sum(times),
            "rmse": rmse,
        })
    return {"fit_s": t_fit, "rounds": history, "M": gp.n_features}


def serve_fleet(
    *,
    backend: str = "jnp",
    tenants: int = 64,
    n_train: int = 64,
    p: int = 2,
    n: int = 8,
    rounds: int = 4,
    queries_per_round: int = 512,
    observations_per_round: int = 128,
    microbatch: int = 64,
    ingest_chunk: int = 16,
    noise: float = 0.05,
    seed: int = 0,
    reopt_every: int = 0,
    reopt_min_rows: int = 16,
    reopt_steps: int = 25,
    reopt_restarts: int = 2,
    engine: str = "pipelined",
    max_in_flight: int = 4,
    queue_budget: int = 4096,
    slo_s: float | None = None,
    capacity: int | None = None,
    cold_dir: str | None = None,
    window: int = 0,
    shards: int = 0,
    metrics=None,
    tracer=None,
    watchdog=None,
) -> dict:
    """Serve a fleet of ``tenants`` small independent GPs concurrently.

    Each tenant observes its own shifted copy of the synthetic target.
    Every round, mixed-tenant query traffic (uniformly random tenant per
    query) flows through the serving frontend in padded microbatches, and
    per-tenant observation streams are absorbed with batched
    ``GPBank.update`` rounds.  Reported per round: ingest time, query
    wall time, fleet-wide queries/s, timeout count, and RMSE against each
    tenant's own target; the returned dict additionally carries the
    engine's cumulative latency metrics (per-tenant p50/p99, sustained
    QPS, bucket usage) when ``engine="pipelined"``.

    ``engine`` selects the serving frontend: ``"pipelined"`` (default)
    drives a :class:`~repro.bank.FleetEngine` — queries dispatch ahead
    while the host packs the next block, expired tickets (``slo_s``) get
    the timeout sentinel instead of a seat in a padded block, and the
    block size autotunes to the arrival rate; ``"sync"`` is the strict
    submit-all / flush / block loop.

    ``reopt_every > 0`` additionally re-optimizes STALE tenants every that
    many rounds: tenants that absorbed >= ``reopt_min_rows`` observations
    since their last optimization are re-fit with one batched
    ``GPBank.optimize`` run over their accumulated data
    (``router.reoptimize``) — the bank becomes heterogeneous and each
    tenant serves under its own learned hyperparameters.

    ``cold_dir`` turns the fleet ELASTIC (pipelined engine only): the
    bank becomes a :class:`~repro.bank.TieredBank` with ``capacity`` hot
    slots (default: all tenants resident) fronting versioned per-tenant
    checkpoints under ``cold_dir`` — traffic to cold tenants warm-restores
    them through the engine, evicting LRU tenants back to disk, with zero
    new executables across the churn.  ``window > 0`` additionally ages
    drifted tenants before re-optimization: everything older than each
    stale tenant's newest ``window`` rows is forgotten via the batched
    rank-k Cholesky downdate (masked-refit fallback on lost positive
    definiteness), so re-learned hyperparameters track the CURRENT regime
    instead of averaging over the tenant's whole history.

    ``shards > 0`` shards the fleet's tenant axis across a ``shards``-way
    'bank' device mesh (:class:`~repro.bank.ShardedGPBank`): every serving
    and ingest executable runs shard-local with no cross-shard collectives,
    the router tracks per-shard occupancy/backlog, and paged-in tenants
    land on the least-loaded shard.  Needs ``shards`` visible devices (on
    CPU export ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    before jax starts) and is homogeneous-only — incompatible with
    ``reopt_every`` (per-tenant learned hyperparameters).

    ``metrics`` / ``tracer`` / ``watchdog`` (``repro.obs``) thread fleet
    telemetry through every stage: the router, the pipelined engine, the
    tiered lifecycle, and stale-tenant re-optimization all emit into the
    same registry and trace buffer.  All three default to the shared null
    objects (zero overhead); pass real instances (or use the
    ``--metrics-port`` / ``--trace-out`` CLI flags) to turn them on.
    """
    rng = np.random.default_rng(seed)
    spec = GPSpec.create(
        n, eps=jnp.full((p,), 0.8), rho=2.0, noise=noise, backend=backend,
    )
    # per-tenant pools: tenant t sees the target shifted by its own offset
    offsets = rng.uniform(-1.0, 1.0, size=tenants).astype(np.float32)
    total = n_train + rounds * max(
        1, observations_per_round // max(1, tenants)
    ) + observations_per_round
    Xb = np.zeros((tenants, n_train, p), np.float32)
    yb = np.zeros((tenants, n_train), np.float32)
    pools = []
    for t in range(tenants):
        X_all, y_all, _, _ = make_gp_dataset(
            total, p, noise=noise, seed=seed + t
        )
        y_all = jnp.asarray(np.asarray(y_all) + offsets[t])
        Xb[t] = np.asarray(X_all[:n_train])
        yb[t] = np.asarray(y_all[:n_train])
        pools.append((np.asarray(X_all), np.asarray(y_all)))

    if engine not in ("pipelined", "sync"):
        raise ValueError(
            f"engine must be 'pipelined' or 'sync', got {engine!r}"
        )
    if cold_dir is not None and engine != "pipelined":
        raise ValueError(
            "a tiered fleet (cold_dir) needs the pipelined engine: the "
            "sync router fail-fasts on cold tenants instead of paging"
        )
    if (capacity is not None or window) and cold_dir is None:
        raise ValueError(
            "capacity/window need a cold tier; pass cold_dir"
        )
    if shards and reopt_every:
        raise ValueError(
            "a sharded fleet is homogeneous-only (one spec across all "
            "shards); per-tenant re-optimization (reopt_every) needs the "
            "resident bank"
        )
    metrics = NULL if metrics is None else metrics
    tracer = NULL_TRACER if tracer is None else tracer
    t0 = time.perf_counter()
    tiered = None
    if cold_dir is not None:
        tiered = TieredBank.fit(
            jnp.asarray(Xb), jnp.asarray(yb), spec, cold_dir=cold_dir,
            capacity=capacity, window=window,
            metrics=metrics, tracer=tracer,
        )
        bank = tiered.bank
    else:
        bank = GPBank.fit(jnp.asarray(Xb), jnp.asarray(yb), spec)
    if shards:
        from repro.launch.mesh import make_bank_mesh
        bank = ShardedGPBank.from_bank(
            bank, make_bank_mesh(shards), pad_capacity=True
        )
        if tiered is not None:
            tiered.adopt(bank)
    jax.block_until_ready(bank.stack.u)
    t_fit = time.perf_counter() - t0

    router = BankRouter(bank, microbatch=microbatch,
                        ingest_chunk=ingest_chunk,
                        metrics=metrics, tracer=tracer)
    eng = None
    if engine == "pipelined":
        eng = FleetEngine(
            router, max_in_flight=max_in_flight,
            queue_budget=queue_budget, default_slo_s=slo_s,
            tiered=tiered,
            metrics=metrics, tracer=tracer, watchdog=watchdog,
        )
    consumed = [n_train] * tenants
    history = []
    for r in range(rounds):
        # -- ingest: each tenant streams a few fresh observations ----------
        front = eng if eng is not None else router
        for _ in range(observations_per_round):
            t = int(rng.integers(0, tenants))
            X_all, y_all = pools[t]
            i = consumed[t] % X_all.shape[0]
            consumed[t] += 1
            front.observe(t, X_all[i], y_all[i])
        t0 = time.perf_counter()
        absorbed = front.ingest()
        jax.block_until_ready(router.bank.stack.u)
        t_ingest = time.perf_counter() - t0

        # -- periodic re-optimization of stale tenants ---------------------
        t_reopt, n_reopt, n_aged = 0.0, 0, 0
        if reopt_every and (r + 1) % reopt_every == 0:
            # cold tenants keep their drift counters (retain=) — paging a
            # tenant out for capacity must not reset its staleness
            stale = (router.stale_tenants(reopt_min_rows,
                                          retain=tiered.tenants)
                     if tiered is not None
                     else router.stale_tenants(reopt_min_rows))
            if stale and tiered is not None and window:
                # age BEFORE re-optimizing: forget rows outside each stale
                # tenant's sliding window (batched downdate + refit
                # fallback) so the re-learned hyperparameters fit the
                # current regime, then re-optimize on the retained window
                tiered.adopt(router.bank)
                aged = tiered.age(stale)
                router.bank = tiered.bank
                n_aged = aged["forgotten_rows"]
            if stale:
                # row axis padded to the FIXED pool size (masked): a
                # max-consumed row count would grow every reopt round and
                # retrace the lane executables each time.  (The tenant
                # axis still varies with the stale set — bounded by the
                # distinct stale-set sizes, not by round count.)
                n_max = window if (tiered is not None and window) else total
                Xo = np.zeros((len(stale), n_max, p), np.float32)
                yo = np.zeros((len(stale), n_max), np.float32)
                mo = np.zeros((len(stale), n_max), np.float32)
                for i, t in enumerate(stale):
                    if tiered is not None and window:
                        # aged fleet: learn from the RETAINED window only
                        # (the forgotten rows are gone from the
                        # factorization — the hypers should follow)
                        for j, (xr, yr) in enumerate(tiered._rows[t]):
                            Xo[i, j], yo[i, j], mo[i, j] = xr, yr, 1.0
                        continue
                    X_all, y_all = pools[t]
                    rows = min(consumed[t], X_all.shape[0])
                    Xo[i, :rows] = X_all[:rows]
                    yo[i, :rows] = y_all[:rows]
                    mo[i, :rows] = 1.0
                t0 = time.perf_counter()
                router.reoptimize(
                    stale, jnp.asarray(Xo), jnp.asarray(yo),
                    mask=jnp.asarray(mo), restarts=reopt_restarts,
                    steps=reopt_steps, seed=seed,
                )
                jax.block_until_ready(router.bank.stack.u)
                t_reopt = time.perf_counter() - t0
                n_reopt = len(stale)
                if tiered is not None:
                    tiered.adopt(router.bank)

        # -- queries: mixed-tenant traffic through the frontend ------------
        q_tenants = rng.integers(0, tenants, queries_per_round)
        Xq = rng.uniform(-1.0, 1.0, size=(queries_per_round, p)).astype(
            np.float32
        )
        timeouts = 0
        if eng is not None:
            # pipelined: submission itself dispatches blocks ahead
            # (auto_pump), drain() overlaps packing with device execution
            t0 = time.perf_counter()
            tickets = [
                eng.submit(int(t), Xq[i]) for i, t in enumerate(q_tenants)
            ]
            results = eng.drain()
            t_query = time.perf_counter() - t0
            served = {
                tk: i for i, tk in enumerate(tickets)
                if not results[tk].timed_out
            }
            timeouts = len(tickets) - len(served)
            mu = np.array([results[tk].mu for tk in served])
            truth = (np.sum(np.cos(Xq), axis=1)
                     + offsets[q_tenants])[list(served.values())]
        else:
            tickets = [
                router.submit(int(t), Xq[i])
                for i, t in enumerate(q_tenants)
            ]
            t0 = time.perf_counter()
            results = router.flush()
            t_query = time.perf_counter() - t0
            mu = np.array([results[tk][0] for tk in tickets])
            # RMSE of each query against its own tenant's (noise-free)
            # Eq. 21 target sum_j cos(x_j) + offset_t
            truth = np.sum(np.cos(Xq), axis=1) + offsets[q_tenants]
        rmse = float(np.sqrt(np.mean((mu - truth) ** 2)))
        nb = max(1, (queries_per_round + microbatch - 1) // microbatch)
        history.append({
            "round": r,
            "rows_absorbed": absorbed,
            "ingest_s": t_ingest,
            "query_s": t_query,
            # one aggregate flush/drain is timed, so this is a
            # per-microbatch MEAN (serve_gp's predict_p50_s is a true
            # per-block median)
            "query_mean_s": t_query / nb,
            "queries_per_s": queries_per_round / t_query,
            "rmse": rmse,
            "timeouts": timeouts,
            "reopt_s": t_reopt,
            "reopt_tenants": n_reopt,
            "aged_rows": n_aged,
        })
    out = {
        "fit_s": t_fit,
        "tenants": tenants,
        "rounds": history,
        "M": bank.n_features,
        "engine": engine,
    }
    if shards:
        out["shards"] = shards
        out["shard_occupancy"] = [
            int(c) for c in router.bank.shard_occupancy()
        ]
    if eng is not None:
        out["latency"] = eng.metrics()
    elif metrics is not NULL:
        out["telemetry"] = metrics.snapshot()
    if tiered is not None:
        out["lifecycle"] = dict(
            tiered.stats, capacity=tiered.capacity,
            hot=len(tiered.hot_tenants), cold=len(tiered.cold_tenants),
        )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="jnp",
                    choices=fagp.available_backends())
    ap.add_argument("--fleet", type=int, default=0, metavar="B",
                    help="serve a bank of B tenants instead of one session")
    ap.add_argument("--n-train", type=int, default=2048)
    ap.add_argument("--p", type=int, default=2)
    ap.add_argument("--n", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--update-size", type=int, default=64)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--microbatch", type=int, default=128)
    ap.add_argument("--reopt-every", type=int, default=0, metavar="K",
                    help="re-optimize stale tenants every K serving rounds")
    ap.add_argument("--engine", default="pipelined",
                    choices=["pipelined", "sync"],
                    help="fleet serving frontend (pipelined FleetEngine "
                         "vs the strict synchronous loop)")
    ap.add_argument("--max-in-flight", type=int, default=4,
                    help="dispatch-ahead depth of the pipelined engine")
    ap.add_argument("--slo", type=float, default=None, metavar="SECONDS",
                    help="per-ticket deadline; expired tickets get the "
                         "timeout sentinel instead of a device slot")
    ap.add_argument("--capacity", type=int, default=None, metavar="C",
                    help="hot slots in a tiered fleet (< --fleet pages "
                         "the rest to the cold tier); needs --cold-dir")
    ap.add_argument("--cold-dir", default=None, metavar="DIR",
                    help="cold-tier checkpoint directory (enables the "
                         "TieredBank lifecycle; pipelined engine only)")
    ap.add_argument("--shards", type=int, default=0, metavar="S",
                    help="shard the fleet's tenant axis across an S-way "
                         "'bank' device mesh (needs S visible devices; on "
                         "CPU set XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=S before launch)")
    ap.add_argument("--window", type=int, default=0, metavar="W",
                    help="sliding-window length: before each reopt, "
                         "forget rows older than each stale tenant's "
                         "newest W (rank-k downdate); needs --cold-dir")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve Prometheus text at http://127.0.0.1:PORT"
                         "/metrics while the fleet runs (0 = ephemeral "
                         "port; fleet mode only)")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="write pipeline spans as Chrome-trace JSONL to "
                         "FILE on exit (load in chrome://tracing or "
                         "ui.perfetto.dev; fleet mode only)")
    ap.add_argument("--watchdog", default=None,
                    choices=["warn", "raise", "count"],
                    help="arm the recompile watchdog over the serving "
                         "executables (fleet mode only)")
    args = ap.parse_args()
    if args.fleet:
        obs_on = (args.metrics_port is not None or args.trace_out
                  or args.watchdog)
        reg = MetricsRegistry() if obs_on else None
        tracer = Tracer() if args.trace_out else None
        wd = (serving_watchdog(mode=args.watchdog, metrics=reg)
              if args.watchdog else None)
        server = None
        if reg is not None:
            # store.py counters (stale-tmp sweeps, async-checkpoint
            # failures) publish to the process default — point it here so
            # one scrape sees the whole fleet
            obs_metrics.set_default(reg)
        if args.metrics_port is not None:
            server = start_metrics_server(reg, port=args.metrics_port)
            print(f"metrics: {server.url}")
        try:
            r = serve_fleet(
                backend=args.backend, tenants=args.fleet,
                n_train=args.n_train, p=args.p, n=args.n,
                rounds=args.rounds,
                queries_per_round=args.queries,
                observations_per_round=args.update_size,
                microbatch=args.microbatch, reopt_every=args.reopt_every,
                engine=args.engine, max_in_flight=args.max_in_flight,
                slo_s=args.slo, capacity=args.capacity,
                cold_dir=args.cold_dir, window=args.window,
                shards=args.shards,
                metrics=reg, tracer=tracer, watchdog=wd,
            )
        finally:
            if tracer is not None and args.trace_out:
                n = tracer.write_jsonl(args.trace_out)
                print(f"trace: {n} events -> {args.trace_out}")
            if server is not None:
                server.shutdown()
            if reg is not None:
                obs_metrics.set_default(NULL)
        print(
            f"fleet of {r['tenants']} fitted in {r['fit_s']*1e3:.1f} ms "
            f"(M={r['M']} each; {r['engine']} engine)"
        )
        if "shards" in r:
            print(
                f"sharded across {r['shards']} devices; occupancy "
                f"{r['shard_occupancy']}"
            )
        for h in r["rounds"]:
            reopt = (
                f"; reopt {h['reopt_tenants']} tenants "
                f"{h['reopt_s']*1e3:.1f} ms" if h["reopt_tenants"] else ""
            )
            print(
                f"round {h['round']}: ingest {h['rows_absorbed']} rows "
                f"{h['ingest_s']*1e3:.1f} ms; query mean "
                f"{h['query_mean_s']*1e3:.2f} ms/microbatch; "
                f"{h['queries_per_s']:.0f} q/s; rmse {h['rmse']:.4f}"
                f"{'; ' + str(h['timeouts']) + ' timeouts' if h['timeouts'] else ''}"
                f"{reopt}"
            )
        if "latency" in r:
            o = r["latency"]["overall"]
            print(
                f"engine: p50 {o['p50_s']*1e3:.2f} ms, p99 "
                f"{o['p99_s']*1e3:.2f} ms per ticket; sustained "
                f"{o['sustained_qps']:.0f} q/s; {o['expired']} expired; "
                f"buckets {sorted(r['latency']['bucket_uses'].items())}"
            )
        if "lifecycle" in r:
            lc = r["lifecycle"]
            print(
                f"lifecycle: {lc['hot']}/{lc['capacity']} hot, "
                f"{lc['cold']} cold; {lc['warm_restores']} restores, "
                f"{lc['evictions']} evictions, {lc['cold_saves']} saves; "
                f"{lc['downdated_rows']} rows forgotten "
                f"({lc['refit_fallbacks']} refit fallbacks)"
            )
        return
    r = serve_gp(
        backend=args.backend, n_train=args.n_train, p=args.p, n=args.n,
        rounds=args.rounds, update_size=args.update_size,
        queries=args.queries, microbatch=args.microbatch,
    )
    print(f"initial fit {r['fit_s']*1e3:.1f} ms (M={r['M']})")
    for h in r["rounds"]:
        print(
            f"round {h['round']}: N={h['rows_absorbed']} "
            f"ingest {h['update_s']*1e3:.1f} ms; "
            f"predict p50 {h['predict_p50_s']*1e3:.2f} ms/microbatch; "
            f"{h['queries_per_s']:.0f} q/s; rmse {h['rmse']:.4f}"
        )


if __name__ == "__main__":
    main()
