"""GP serving loop: microbatched posterior queries + online observation ingest.

The production shape of the paper's workload: a fitted GP session serves
``mean_var`` queries while new observations stream in.  Queries are served
in fixed-size microbatches (one compiled shape, padded tail) so latency is
bounded and there is exactly one XLA executable per backend; observations
are absorbed with ``GP.update`` — a rank-k Cholesky update, O(k M^2) per
ingest batch, never a refit over the accumulated N.

The whole loop speaks the self-describing ``GP`` facade: the spec (index
set, backend, block size) is baked into the session at fit time, so neither
the query path nor the ingest path re-passes configuration.

  PYTHONPATH=src python -m repro.launch.serve_gp --backend pallas \\
      --n-train 2048 --p 2 --n 8 --rounds 4 --update-size 64 \\
      --queries 512 --microbatch 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fagp
from repro.core.gp import GP, GPSpec
from repro.data import make_gp_dataset

__all__ = ["serve_gp", "microbatched_mean_var"]


def microbatched_mean_var(gp, Xs, *, microbatch: int):
    """``mean_var`` in fixed-size microbatches (padded tail).

    ``gp`` is a :class:`GP` session (a spec-carrying :class:`FAGPState` is
    also accepted and wrapped).  Returns (mu, var, per_batch_seconds).
    Every call sees the same (B, p) shape, so the serving path compiles
    exactly once per state shape."""
    if isinstance(gp, fagp.FAGPState):
        gp = GP.from_state(gp)
    Nq = Xs.shape[0]
    nb = max(1, (Nq + microbatch - 1) // microbatch)
    pad = nb * microbatch - Nq
    Xp = jnp.pad(Xs, ((0, pad), (0, 0)))
    mus, vars, times = [], [], []
    for i in range(nb):
        blk = jax.lax.dynamic_slice_in_dim(Xp, i * microbatch, microbatch)
        t0 = time.perf_counter()
        mu, var = gp.mean_var(blk)
        jax.block_until_ready((mu, var))
        times.append(time.perf_counter() - t0)
        mus.append(np.asarray(mu))
        vars.append(np.asarray(var))
    mu = np.concatenate(mus)[:Nq]
    var = np.concatenate(vars)[:Nq]
    return mu, var, times


def serve_gp(
    *,
    backend: str = "jnp",
    n_train: int = 2048,
    p: int = 2,
    n: int = 8,
    rounds: int = 4,
    update_size: int = 64,
    queries: int = 512,
    microbatch: int = 128,
    noise: float = 0.05,
    seed: int = 0,
) -> dict:
    spec = GPSpec.create(
        n, eps=jnp.full((p,), 0.8), rho=2.0, noise=noise, backend=backend,
    )
    # n_train initial rows + rounds * update_size streamed rows, one pool
    total = n_train + rounds * update_size
    X_all, y_all, Xs, ys = make_gp_dataset(total, p, noise=noise, seed=seed)
    X0, y0 = X_all[:n_train], y_all[:n_train]

    t0 = time.perf_counter()
    gp = GP.fit(X0, y0, spec)
    jax.block_until_ready(gp.state.u)
    t_fit = time.perf_counter() - t0

    Xq = Xs[:queries] if queries <= Xs.shape[0] else Xs
    ysq = np.asarray(ys)[: Xq.shape[0]]

    history = []
    for r in range(rounds):
        lo = n_train + r * update_size
        Xn, yn = X_all[lo : lo + update_size], y_all[lo : lo + update_size]
        t0 = time.perf_counter()
        gp = gp.update(Xn, yn)
        jax.block_until_ready(gp.state.u)
        t_update = time.perf_counter() - t0

        mu, var, times = microbatched_mean_var(gp, Xq, microbatch=microbatch)
        rmse = float(np.sqrt(np.mean((mu - ysq) ** 2)))
        times.sort()
        history.append({
            "round": r,
            "rows_absorbed": int(lo + update_size),
            "update_s": t_update,
            "predict_p50_s": times[len(times) // 2],
            "queries_per_s": Xq.shape[0] / sum(times),
            "rmse": rmse,
        })
    return {"fit_s": t_fit, "rounds": history, "M": gp.n_features}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="jnp",
                    choices=fagp.available_backends())
    ap.add_argument("--n-train", type=int, default=2048)
    ap.add_argument("--p", type=int, default=2)
    ap.add_argument("--n", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--update-size", type=int, default=64)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--microbatch", type=int, default=128)
    args = ap.parse_args()
    r = serve_gp(
        backend=args.backend, n_train=args.n_train, p=args.p, n=args.n,
        rounds=args.rounds, update_size=args.update_size,
        queries=args.queries, microbatch=args.microbatch,
    )
    print(f"initial fit {r['fit_s']*1e3:.1f} ms (M={r['M']})")
    for h in r["rounds"]:
        print(
            f"round {h['round']}: N={h['rows_absorbed']} "
            f"ingest {h['update_s']*1e3:.1f} ms; "
            f"predict p50 {h['predict_p50_s']*1e3:.2f} ms/microbatch; "
            f"{h['queries_per_s']:.0f} q/s; rmse {h['rmse']:.4f}"
        )


if __name__ == "__main__":
    main()
