import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (no mismatched collectives),
  * the per-device memory footprint (memory_analysis),
  * the FLOP/byte/collective composition (cost_analysis + HLO parse),
and records a JSON blob consumed by EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch fagp --shape fit_8m
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro import optim
from repro.configs import ARCHS, fagp as fagp_cfg
from repro.configs.shapes import SHAPES, input_specs, supports
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.models import get_model
from repro.parallel import hints, sharding
from repro.roofline import analyze_compiled

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _tokens_of(shape_name: str) -> int:
    s = SHAPES[shape_name]
    return s.batch * (s.seq if s.kind in ("train", "prefill") else 1)


def run_lm_cell(arch_id: str, shape_name: str, multi_pod: bool) -> dict:
    cfg = ARCHS[arch_id].CONFIG
    if not supports(cfg, shape_name):
        return {"skipped": "long_500k requires sub-quadratic context handling; "
                           f"{arch_id} is full-attention (DESIGN.md §Arch-applicability)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    model = get_model(cfg)
    spec = SHAPES[shape_name]

    params_av = jax.eval_shape(lambda: model.init_params(jax.random.key(0)))
    p_sh = sharding.param_shardings(
        params_av, cfg, mesh, serving=spec.kind != "train"
    )

    t0 = time.time()
    with jax.set_mesh(mesh), hints.activate(mesh):
        if spec.kind == "train":
            ocfg = optim.AdamWConfig(lr=1e-4, state_dtype="bfloat16")
            opt_av = jax.eval_shape(lambda: optim.init(params_av, ocfg))
            o_sh = sharding.opt_state_shardings(opt_av, params_av, cfg, mesh)
            batch = input_specs(cfg, shape_name)
            b_sh = sharding.batch_shardings(batch, mesh)
            step = make_train_step(model, ocfg)
            lowered = jax.jit(
                step, in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),
            ).lower(params_av, opt_av, batch)
        elif spec.kind == "prefill":
            batch = input_specs(cfg, shape_name)
            b_sh = sharding.batch_shardings(batch, mesh)
            cache_av = jax.eval_shape(lambda: model.init_cache(spec.batch, spec.seq))
            c_sh = sharding.cache_shardings(cache_av, cfg, mesh)
            step = make_prefill_step(model)
            lowered = jax.jit(
                step, in_shardings=(p_sh, b_sh), out_shardings=(None, c_sh),
            ).lower(params_av, batch)
        else:  # decode
            batch, cache_av = input_specs(cfg, shape_name)
            b_sh = sharding.batch_shardings(batch, mesh)
            c_sh = sharding.cache_shardings(cache_av, cfg, mesh)
            step = make_decode_step(model)
            lowered = jax.jit(
                step, in_shardings=(p_sh, b_sh, c_sh),
                out_shardings=(None, c_sh), donate_argnums=(2,),
            ).lower(params_av, batch, cache_av)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    tokens = _tokens_of(shape_name)
    n_active = cfg.active_param_count()
    model_flops = (3 if spec.kind == "train" else 1) * 2.0 * n_active * tokens
    rec = analyze_compiled(compiled, n_chips, model_flops=model_flops)
    rec.update(
        arch=arch_id, shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16",
        kind=spec.kind, tokens=tokens,
        params_total=cfg.param_count(), params_active=n_active,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
    )
    return rec


def run_fagp_cell(shape_name: str, multi_pod: bool) -> dict:
    from repro.core import distributed as dgp

    wl = fagp_cfg.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    with jax.set_mesh(mesh), hints.activate(mesh):
        if wl.kind == "fit":
            lowered = dgp.lower_fit(wl, mesh)
        else:
            lowered = dgp.lower_predict(wl, mesh)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    M = wl.cfg.indices(wl.p).shape[0]
    # useful FLOPs: fit = 2 N M^2 (Gram) + (2/3) M^3 (Cholesky) + phi build;
    # predict = 2 N M^2 (solve+var) + 2 N M (mean)
    if wl.kind == "fit":
        model_flops = 2.0 * wl.N * M * M + (2.0 / 3.0) * M**3
    else:
        model_flops = 2.0 * wl.N * M * M + 2.0 * wl.N * M
    rec = analyze_compiled(compiled, n_chips, model_flops=model_flops)
    rec.update(
        arch="fagp", shape=shape_name, mesh="2x16x16" if multi_pod else "16x16",
        kind=wl.kind, N=wl.N, p=wl.p, M=int(M),
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
    )
    return rec


def run_cell(arch_id: str, shape_name: str, multi_pod: bool) -> dict:
    try:
        if arch_id == "fagp":
            return run_fagp_cell(shape_name, multi_pod)
        return run_lm_cell(arch_id, shape_name, multi_pod)
    except Exception as e:  # a failure here is a bug in the system
        return {
            "arch": arch_id, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-2000:],
        }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    archs = list(ARCHS) + ["fagp"] if args.arch == "all" else args.arch.split(",")
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_err = 0
    for arch_id in archs:
        shape_names = (
            list(fagp_cfg.SHAPES) if arch_id == "fagp" else list(SHAPES)
        ) if args.shape == "all" else args.shape.split(",")
        for shape_name in shape_names:
            for multi_pod in meshes:
                mesh_tag = "2x16x16" if multi_pod else "16x16"
                cell = f"{arch_id}__{shape_name}__{mesh_tag}"
                t0 = time.time()
                rec = run_cell(arch_id, shape_name, multi_pod)
                dt = time.time() - t0
                (out / f"{cell}.json").write_text(json.dumps(rec, indent=1))
                if "error" in rec:
                    n_err += 1
                    status = "ERROR " + rec["error"][:120]
                elif "skipped" in rec:
                    n_skip += 1
                    status = "SKIP"
                else:
                    n_ok += 1
                    t = rec["terms"]
                    status = (
                        f"ok  dom={t['dominant']:<10} "
                        f"c/m/coll(ms)={1e3*t['compute_s']:.2f}/"
                        f"{1e3*t['memory_s']:.2f}/{1e3*t['collective_s']:.2f} "
                        f"peakGB={rec['memory'].get('peak_bytes_est', 0)/2**30:.2f}"
                    )
                print(f"[{dt:7.1f}s] {cell:<55} {status}", flush=True)
    print(f"\nDONE ok={n_ok} skip={n_skip} err={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
