"""Serving launcher: batched prefill + greedy decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \\
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import get_model


def serve(arch_id: str, *, smoke: bool, batch: int, prompt_len: int, gen: int,
          seed: int = 0, greedy: bool = True):
    mod = ARCHS[arch_id]
    cfg = mod.SMOKE if smoke else mod.CONFIG
    model = get_model(cfg)
    params = model.init_params(jax.random.key(seed))
    rng = np.random.default_rng(seed)
    cap = prompt_len + gen
    batch_in = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, size=(batch, prompt_len)), jnp.int32)}
    if cfg.family == "audio":
        batch_in["frames"] = jnp.asarray(
            rng.standard_normal((batch, cfg.enc_len, cfg.d_model)), jnp.bfloat16)
    if cfg.family == "vlm":
        batch_in["img"] = jnp.asarray(
            rng.standard_normal((batch, cfg.n_img_tokens, cfg.d_model)), jnp.bfloat16)

    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len=cap))
    decode = jax.jit(model.decode_step, donate_argnums=(2,))

    t0 = time.perf_counter()
    logits, cache = jax.block_until_ready(prefill(params, batch_in))
    t_prefill = time.perf_counter() - t0

    out_tokens = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(gen):
        out_tokens.append(np.asarray(tok))
        logits, cache = decode(
            params, {"token": tok, "pos": jnp.asarray(prompt_len + i, jnp.int32)},
            cache,
        )
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    toks = np.concatenate(out_tokens, axis=1)
    return {
        "generated": toks,
        "prefill_s": t_prefill,
        "decode_s_per_token": t_decode / gen,
        "tokens_per_s": batch * gen / t_decode,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    r = serve(args.arch, smoke=args.smoke, batch=args.batch,
              prompt_len=args.prompt_len, gen=args.gen)
    print(f"prefill {r['prefill_s']*1e3:.1f} ms; "
          f"decode {r['decode_s_per_token']*1e3:.2f} ms/tok; "
          f"{r['tokens_per_s']:.1f} tok/s; sample row: {r['generated'][0][:16]}")


if __name__ == "__main__":
    main()
