"""Training launcher.

CPU-runnable end-to-end (smoke configs by default); the same code path
lowers to the production mesh when more devices are present.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \\
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro import optim
from repro.configs import ARCHS
from repro.data import TokenStream
from repro.launch.steps import make_train_step
from repro.models import get_model
from repro.parallel import hints, sharding
from repro.runtime import TrainLoopConfig, train_loop


def build(arch_id: str, *, smoke: bool, batch: int, seq: int, lr: float,
          mesh=None, seed: int = 0):
    mod = ARCHS[arch_id]
    cfg = mod.SMOKE if smoke else mod.CONFIG
    model = get_model(cfg)
    params = model.init_params(jax.random.key(seed))
    ocfg = optim.AdamWConfig(lr=optim.warmup_cosine(lr, 20, 10_000))
    opt_state = optim.init(params, ocfg)
    step_fn = make_train_step(model, ocfg)
    extras = {}
    if cfg.family == "audio":
        rng = np.random.default_rng(seed)
        extras["frames"] = jax.numpy.asarray(
            rng.standard_normal((batch, cfg.enc_len, cfg.d_model)).astype(np.float32)
        ).astype(jax.numpy.bfloat16)
    if cfg.family == "vlm":
        rng = np.random.default_rng(seed)
        extras["img"] = jax.numpy.asarray(
            rng.standard_normal((batch, cfg.n_img_tokens, cfg.d_model)).astype(np.float32)
        ).astype(jax.numpy.bfloat16)
    stream = TokenStream(vocab=cfg.vocab, seq=seq, global_batch=batch, seed=seed)

    p_sh = o_sh = None
    if mesh is not None:
        p_sh = sharding.param_shardings(params, cfg, mesh)
        o_sh = sharding.opt_state_shardings(opt_state, params, cfg, mesh)
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(opt_state, o_sh)
        step_fn = jax.jit(step_fn, in_shardings=(p_sh, o_sh, None),
                          out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    return cfg, model, params, opt_state, step_fn, stream, extras, (p_sh, o_sh)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg, model, params, opt_state, step_fn, stream, extras, sh = build(
        args.arch, smoke=args.smoke, batch=args.batch, seq=args.seq, lr=args.lr
    )
    loop_cfg = TrainLoopConfig(
        steps=args.steps, ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir
    )
    params, opt_state, report = train_loop(
        step_fn, params, opt_state,
        lambda step: stream.batch(step, extras),
        loop_cfg,
    )
    h = report["history"]
    print(f"\narch={cfg.arch_id} steps={report['final_step']} "
          f"first_loss={h[0]['loss']:.4f} last_loss={h[-1]['loss']:.4f} "
          f"stragglers={report['stragglers']}")


if __name__ == "__main__":
    main()
