"""Step builders shared by train.py, serve.py and dryrun.py."""
from __future__ import annotations

import jax

from repro import optim

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step"]


def make_train_step(model, ocfg: optim.AdamWConfig):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, batch
        )
        params, opt_state, om = optim.apply_updates(params, grads, opt_state, ocfg)
        return params, opt_state, {**metrics, **om}

    return train_step


def make_prefill_step(model, cache_len=None):
    def prefill_step(params, batch):
        return model.prefill(params, batch, cache_len=cache_len)

    return prefill_step


def make_decode_step(model):
    def decode_step(params, batch, cache):
        return model.decode_step(params, batch, cache)

    return decode_step
