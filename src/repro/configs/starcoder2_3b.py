"""starcoder2-3b [dense] — GQA kv=2, RoPE, sliding window, 2-matrix GELU MLP
(arXiv:2402.19173).

30L d_model=3072, 24 heads / 2 kv, d_ff=12288, vocab=49152, window 4096.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_ff=12288,
    vocab=49152, qkv_bias=True, rope_theta=999999.44,
    sliding_window=4096, act="gelu", mlp_gated=False,
    tie_embeddings=True, fsdp=True, sp_residual=True,
)

SMOKE = ModelConfig(
    arch_id="starcoder2-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, qkv_bias=True, sliding_window=32, act="gelu",
    mlp_gated=False, tie_embeddings=True, logits_chunk=32,
)
