"""llama-3.2-vision-11b [vlm] — text backbone w/ gated cross-attn image
layers (hf:meta-llama/Llama-3.2-11B-Vision); vision tower is a STUB.

40 layers = 8 x (1 gated cross-attn + 4 self), d_model=4096, 32 heads /
8 kv, d_ff=14336, vocab=128256; image patch embeddings precomputed
(B, 1601, d_model).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=128256, rope_theta=500000.0,
    cross_every=4, n_img_tokens=1601, fsdp=True, sp_residual=True,
)

SMOKE = ModelConfig(
    arch_id="llama-vision-smoke", family="vlm",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, cross_every=2, n_img_tokens=16,
    logits_chunk=32,
)
