"""qwen2-1.5b [dense] — GQA kv=2, QKV bias (arXiv:2407.10671).

28L d_model=1536, 12 heads / 2 kv heads (head_dim 128), d_ff=8960,
vocab=151936, tied embeddings, rope theta 1e6.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-1.5b", family="dense",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab=151936, qkv_bias=True, rope_theta=1e6,
    tie_embeddings=True, sp_residual=True,
)

SMOKE = ModelConfig(
    arch_id="qwen2-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, qkv_bias=True, tie_embeddings=True,
    logits_chunk=32,
)
