"""Per-architecture configs (exact assigned sizes) + smoke variants.

``get_arch(id)`` returns the module for an assigned architecture;
``ARCHS`` lists all 10 LM-family ids (fagp is the paper's own workload).
"""
from . import (
    deepseek_v3_671b,
    fagp,
    llama32_vision_11b,
    mamba2_130m,
    olmoe_1b_7b,
    qwen2_1p5b,
    qwen2p5_3b,
    shapes,
    smollm_360m,
    starcoder2_3b,
    whisper_small,
    zamba2_7b,
)

ARCHS = {
    "mamba2-130m": mamba2_130m,
    "deepseek-v3-671b": deepseek_v3_671b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "qwen2-1.5b": qwen2_1p5b,
    "smollm-360m": smollm_360m,
    "starcoder2-3b": starcoder2_3b,
    "qwen2.5-3b": qwen2p5_3b,
    "whisper-small": whisper_small,
    "zamba2-7b": zamba2_7b,
    "llama-3.2-vision-11b": llama32_vision_11b,
}


def get_arch(arch_id: str):
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(ARCHS)}")
    return ARCHS[arch_id]
