"""qwen2.5-3b [dense] — GQA kv=2, QKV bias (hf:Qwen/Qwen2.5-3B).

36L d_model=2048, 16 heads / 2 kv (head_dim 128), d_ff=11008,
vocab=151936, tied, rope theta 1e6.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, head_dim=128,
    d_ff=11008, vocab=151936, qkv_bias=True, rope_theta=1e6,
    tie_embeddings=True, fsdp=True, sp_residual=True,
)

SMOKE = ModelConfig(
    arch_id="qwen2.5-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, qkv_bias=True, tie_embeddings=True,
    logits_chunk=32,
)
