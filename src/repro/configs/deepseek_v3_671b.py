"""deepseek-v3-671b [moe] — MLA + 1 shared/256 routed top-8 experts + MTP
(arXiv:2412.19437).

61L d_model=7168, 128 heads (MLA: q_lora 1536, kv_lora 512, nope 128,
rope 64, v 128), routed-expert FFN 2048 (the assigned d_ff), dense FFN 18432
for the first 3 layers (published config), vocab=129280, MTP depth 1.
FSDP + EP: the only way 671B params fit 512 chips.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=18432, vocab=129280,
    n_experts=256, n_shared_experts=1, top_k=8, d_expert=2048,
    moe_layer_start=3, capacity_factor=1.25,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    mtp_depth=1, fsdp=True,
)

SMOKE = ModelConfig(
    arch_id="deepseek-v3-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256,
    n_experts=8, n_shared_experts=1, top_k=2, d_expert=32,
    moe_layer_start=1, use_mla=True, q_lora_rank=32, kv_lora_rank=16,
    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    mtp_depth=1, logits_chunk=32, capacity_factor=8.0,
)
