"""mamba2-130m [ssm] — SSD, attention-free (arXiv:2405.21060).

24L d_model=768, vocab=50280, ssm_state=128; expand 2 -> d_inner 1536,
headdim 64 -> 24 SSD heads.  Runs long_500k (O(1)-state decode).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=12, n_kv_heads=12, d_ff=0,
    vocab=50280, tie_embeddings=True,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_conv=4,
    ssm_chunk=256, ssm_ngroups=1, fsdp=True,
)

SMOKE = ModelConfig(
    arch_id="mamba2-130m-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=256, tie_embeddings=True,
    ssm_state=16, ssm_expand=2, ssm_headdim=16, ssm_conv=4,
    ssm_chunk=16, ssm_ngroups=1, logits_chunk=32,
)
