"""zamba2-7b [hybrid] — Mamba2 backbone + ONE shared attention block reused
(arXiv:2411.15242).

81 mamba2 layers (d_model=3584, expand 2 -> d_inner 7168, headdim 64 ->
112 SSD heads, ssm_state=64) structured as 6 groups of 13 + tail of 3, with
the shared GQA(32h) attention+MLP block applied before each group (6 shared
invocations).  The published per-invocation LoRA deltas on the shared block
are simplified away (DESIGN.md §Arch-applicability).  Runs long_500k.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab=32000,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_conv=4,
    ssm_chunk=256, ssm_ngroups=1,
    hybrid_groups=6, hybrid_group_len=13, hybrid_tail=3,
    fsdp=True,
)

SMOKE = ModelConfig(
    arch_id="zamba2-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=256,
    ssm_state=16, ssm_expand=2, ssm_headdim=16, ssm_conv=4,
    ssm_chunk=16, ssm_ngroups=1,
    hybrid_groups=2, hybrid_group_len=2, hybrid_tail=1,
    logits_chunk=32,
)
