"""olmoe-1b-7b [moe] — 64 experts top-8, 1B active / 7B total (arXiv:2409.02060).

16L d_model=2048, 16 heads (kv=16), expert FFN 1024, vocab=50304.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1024,
    vocab=50304,
    n_experts=64, n_shared_experts=0, top_k=8, d_expert=1024,
    capacity_factor=1.25, fsdp=True,
)

SMOKE = ModelConfig(
    arch_id="olmoe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
    vocab=256, n_experts=8, n_shared_experts=0, top_k=2, d_expert=32,
    logits_chunk=32, capacity_factor=8.0,
)
