"""The paper's own workload as a first-class config: FAGP regression.

Paper scale (Fig. 1): N=10^4, p=4, n=11 -> M=n^p=14641 (full grid).
Production scale: N=2^23 rows sharded over (pod, data); the M=14641 feature
axis sharded over model.  ``shapes`` mirror the LM shape table with
fit/predict kinds consumed by launch/dryrun.py.
"""
import dataclasses

from repro.core.fagp import FAGPConfig


@dataclasses.dataclass(frozen=True)
class FAGPWorkload:
    name: str
    kind: str          # fit | predict
    N: int             # train rows (fit) / test rows (predict)
    p: int
    cfg: FAGPConfig


CONFIG = FAGPConfig(n=11, index_set="full", store_train=False)

SHAPES = {
    "fit_10k": FAGPWorkload("fit_10k", "fit", 10_240, 4, CONFIG),     # paper Fig.1
    "fit_8m": FAGPWorkload("fit_8m", "fit", 8_388_608, 4, CONFIG),    # pod scale
    "predict_1m": FAGPWorkload("predict_1m", "predict", 1_048_576, 4, CONFIG),
}

SMOKE = FAGPConfig(n=4, index_set="full", store_train=False)
