"""smollm-360m [dense] — llama-arch small (hf:HuggingFaceTB/SmolLM-360M).

32L d_model=960, 15 heads / 5 kv heads, d_ff=2560, vocab=49152, tied.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_ff=2560,
    vocab=49152, tie_embeddings=True, sp_residual=True,
)

SMOKE = ModelConfig(
    arch_id="smollm-smoke", family="dense",
    n_layers=2, d_model=60, n_heads=3, n_kv_heads=1, d_ff=128,
    vocab=256, tie_embeddings=True, logits_chunk=32,
)
