"""Assigned input-shape set + ShapeDtypeStruct builders (no allocation).

Every LM arch is exercised on the 4 assigned shapes; ``decode_*``/``long_*``
lower ``serve_step`` (one token against a seq_len KV cache), not train_step.
``long_500k`` requires sub-quadratic context handling: it runs only for the
SSM/hybrid archs (O(1)-state decode) and records an explicit SKIP for pure
full-attention archs (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import get_model
from repro.models.config import ModelConfig

__all__ = ["SHAPES", "input_specs", "supports", "ShapeSpec"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str       # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def supports(cfg: ModelConfig, shape_name: str) -> bool:
    """long_500k only for sub-quadratic (SSM/hybrid) archs."""
    if shape_name == "long_500k":
        return cfg.family in ("ssm", "hybrid")
    return True


def _extras(cfg: ModelConfig, B: int):
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.family == "audio":
        return {"frames": jax.ShapeDtypeStruct((B, cfg.enc_len, cfg.d_model), dt)}
    if cfg.family == "vlm":
        return {"img": jax.ShapeDtypeStruct((B, cfg.n_img_tokens, cfg.d_model), dt)}
    return {}


def input_specs(cfg: ModelConfig, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill -> {'tokens', ...extras};
    decode        -> ({'token', 'pos'}, cache_specs).
    """
    spec = SHAPES[shape_name]
    B, S = spec.batch, spec.seq
    if spec.kind in ("train", "prefill"):
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            **_extras(cfg, B),
        }
    # decode: token + pos + cache built abstractly (no allocation)
    model = get_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    batch = {
        "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    return batch, cache
