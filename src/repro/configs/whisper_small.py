"""whisper-small [audio] — enc-dec, conv frontend STUB (arXiv:2212.04356).

12 enc + 12 dec layers, d_model=768, 12 heads, d_ff=3072, vocab=51865.
input_specs feeds precomputed frame embeddings (B, 1500, 768); decoder uses
learned positions sized to the assigned 32k decode shape.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-small", family="audio",
    n_layers=12, n_enc_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865, enc_len=1500, max_seq=32768,
    act="gelu", mlp_gated=False, tie_embeddings=True, sp_residual=True,
)

SMOKE = ModelConfig(
    arch_id="whisper-smoke", family="audio",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, enc_len=32, max_seq=128,
    act="gelu", mlp_gated=False, tie_embeddings=True, logits_chunk=32,
)
