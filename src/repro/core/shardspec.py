"""Shared shard-local spec plumbing for every shard_map schedule.

Both the v2 row-sharding fit/predict (``core.distributed``) and the
bank-axis sharding (``bank.sharded``) rebuild a :class:`~repro.core.fagp.GPSpec`
from shard-local leaves inside a ``shard_map`` body, probe mesh sizes, and
thread the optional spectral-draw leaf as a ``*args`` tail.  This module is
the single home for that glue — a third copy-paste was the alternative.

It also owns the version-compat ``shard_map`` entry point: ``jax.shard_map``
(new jax, ``check_vma``) when present, else the long-stable
``jax.experimental.shard_map.shard_map`` (``check_rep``) — which is why the
bank sharding runs on every jax the repo supports, unlike the
``AxisType``/``jax.set_mesh`` machinery that gates the distributed tests.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .fagp import GPSpec

__all__ = [
    "shard_map", "has_shard_map", "spec_local", "omega_args", "mesh_size",
    "axis_size",
]


if hasattr(jax, "shard_map"):
    def shard_map(f, mesh, in_specs, out_specs):
        """Version-compat shard_map (new jax: top-level, check_vma)."""
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:  # jax < 0.6: experimental module, check_rep spelling
    try:
        from jax.experimental.shard_map import shard_map as _shard_map_impl

        def shard_map(f, mesh, in_specs, out_specs):
            """Version-compat shard_map (old jax: experimental, check_rep)."""
            return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                                   out_specs=out_specs, check_rep=False)
    except ImportError:  # pragma: no cover - ancient jax
        shard_map = None


def has_shard_map() -> bool:
    """True when this jax can run the repo's shard_map schedules."""
    return shard_map is not None


def spec_local(spec: GPSpec, eps, rho, omega) -> GPSpec:
    """Rebuild the spec from shard-local leaves inside a shard_map body —
    every data leaf is replaced, so no outer traced value leaks into the
    body through the closure."""
    return dataclasses.replace(
        spec, eps=eps, rho=rho, noise=jnp.asarray(0.0, jnp.float32),
        omega=omega,
    )


def omega_args(spec: GPSpec) -> tuple:
    """The spec's optional spectral-draw leaf as a *args tail (present only
    when the expansion carries one — keeps the hermite schedules byte-
    identical to before)."""
    return () if spec.omega is None else (spec.omega,)


def mesh_size(mesh) -> int:
    """Total chip count of a mesh (product over every axis)."""
    return int(np.prod(list(mesh.shape.values())))


def axis_size(mesh, axis: str, default: int = 1) -> int:
    """Size of one named mesh axis (``default`` when the axis is absent)."""
    return int(mesh.shape.get(axis, default))
