"""Core of the reproduction: Mercer-decomposed GP regression (FAGP).

Paper: Carminati (2024), "Parallel Gaussian Process with Kernel
Approximation in CUDA" — reimplemented TPU-natively in JAX.
"""
from . import exact_gp, fagp, mercer
from .fagp import FAGPConfig, FAGPState, fit, nlml, predict
from .mercer import (
    SEKernelParams,
    eigenvalues_1d,
    eigenfunctions_1d,
    eigenvalues_nd,
    log_eigenvalues_1d,
    log_eigenvalues_nd,
    full_grid,
    hyperbolic_cross,
    k_se_ard,
    make_index_set,
    phi_nd,
    total_degree,
)
