"""Core of the reproduction: Mercer-decomposed GP regression (FAGP).

Paper: Carminati (2024), "Parallel Gaussian Process with Kernel
Approximation in CUDA" — reimplemented TPU-natively in JAX.

The public session API is the `GP` facade (`core.gp`): one self-describing
object over fit/predict/update/nlml with the spec baked into the state.
"""
from . import exact_gp, expansions, fagp, gp, mercer
from .expansions import (
    KernelExpansion,
    available_expansions,
    get_expansion,
    register_expansion,
)
from .fagp import (
    FAGPConfig,
    FAGPState,
    GPSpec,
    fit,
    fit_update,
    nlml,
    predict,
    predict_mean_var,
)
from .gp import GP
from .mercer import (
    SEKernelParams,
    eigenvalues_1d,
    eigenfunctions_1d,
    eigenvalues_nd,
    log_eigenvalues_1d,
    log_eigenvalues_nd,
    full_grid,
    hyperbolic_cross,
    k_matern52_ard,
    k_se_ard,
    make_index_set,
    phi_nd,
    total_degree,
)
