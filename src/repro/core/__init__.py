"""Core of the reproduction: Mercer-decomposed GP regression (FAGP).

Paper: Carminati (2024), "Parallel Gaussian Process with Kernel
Approximation in CUDA" — reimplemented TPU-natively in JAX.

The public session API is the `GP` facade (`core.gp`): one self-describing
object over fit/predict/update/nlml with the spec baked into the state.
The approximation family behind the facade is pluggable
(`core.approximation`): `"fagp"` (the paper's decomposed kernel, default)
or `"vecchia"` (nearest-neighbor conditioning, `core.vecchia`).
"""
from . import approximation, exact_gp, expansions, fagp, gp, mercer, vecchia
from .approximation import (
    Approximation,
    UnsupportedError,
    available_approximations,
    get_approximation,
    register_approximation,
)
from .expansions import (
    KernelExpansion,
    available_expansions,
    get_expansion,
    register_expansion,
)
from .fagp import (
    FAGPConfig,
    FAGPState,
    GPSpec,
    fit,
    fit_update,
    nlml,
    predict,
    predict_mean_var,
)
from .gp import GP
from .vecchia import VecchiaState
from .mercer import (
    SEKernelParams,
    eigenvalues_1d,
    eigenfunctions_1d,
    eigenvalues_nd,
    log_eigenvalues_1d,
    log_eigenvalues_nd,
    full_grid,
    hyperbolic_cross,
    k_matern52_ard,
    k_se_ard,
    make_index_set,
    phi_nd,
    total_degree,
)
