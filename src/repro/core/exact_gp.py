"""Plain (exact) Gaussian-process regression — paper Eqs. 3-4.

This is the O(N^3) baseline FAGP is measured against (the comparison the
Joukov-Kulic formulation, and hence the paper, is built on).  Zero-mean GP
with the ARD SE kernel; Cholesky solve of (K + sigma^2 I).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .mercer import SEKernelParams, k_se_ard

__all__ = ["ExactGPState", "fit", "predict", "nlml"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ExactGPState:
    X: jax.Array          # (N, p) train inputs
    chol: jax.Array       # (N, N) lower Cholesky of K + sigma^2 I
    alpha: jax.Array      # (N,)   (K + sigma^2 I)^{-1} y
    params: SEKernelParams


@partial(jax.jit, static_argnames=())
def fit(X: jax.Array, y: jax.Array, params: SEKernelParams) -> ExactGPState:
    N = X.shape[0]
    K = k_se_ard(X, X, params.eps)
    Ky = K + (params.noise**2) * jnp.eye(N, dtype=K.dtype)
    chol = jnp.linalg.cholesky(Ky)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    return ExactGPState(X=X, chol=chol, alpha=alpha, params=params)


@jax.jit
def predict(state: ExactGPState, Xs: jax.Array):
    """Posterior mean (N*,) and covariance (N*, N*) at test inputs Xs."""
    Ks = k_se_ard(Xs, state.X, state.params.eps)          # (N*, N)
    mu = Ks @ state.alpha                                  # Eq. 3, m = 0
    V = jax.scipy.linalg.solve_triangular(state.chol, Ks.T, lower=True)  # (N, N*)
    Kss = k_se_ard(Xs, Xs, state.params.eps)
    cov = Kss - V.T @ V                                    # Eq. 4
    return mu, cov


@jax.jit
def nlml(X: jax.Array, y: jax.Array, params: SEKernelParams) -> jax.Array:
    """Exact negative log marginal likelihood (for hyperparameter baselines)."""
    N = X.shape[0]
    K = k_se_ard(X, X, params.eps)
    Ky = K + (params.noise**2) * jnp.eye(N, dtype=K.dtype)
    chol = jnp.linalg.cholesky(Ky)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    return (
        0.5 * jnp.dot(y, alpha)
        + jnp.sum(jnp.log(jnp.diagonal(chol)))
        + 0.5 * N * jnp.log(2.0 * jnp.pi)
    )
