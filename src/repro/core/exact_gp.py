"""Plain (exact) Gaussian-process regression — paper Eqs. 3-4.

This is the O(N^3) baseline FAGP is measured against (the comparison the
Joukov-Kulic formulation, and hence the paper, is built on).  Zero-mean GP
with a choice of reference kernel — the ARD SE kernel (default, the
paper's) or the ARD Matern-5/2 kernel (the exact form the ``rff_matern52``
expansion approximates; same eps parametrization, see
``mercer.k_matern52_ard``).  Cholesky solve of (K + sigma^2 I).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .mercer import SEKernelParams, k_matern52_ard, k_se_ard

__all__ = ["ExactGPState", "KERNELS", "fit", "predict", "mean_var", "nlml"]

# exact reference kernels by name; the KernelExpansion instances point at
# these via ``exact_kernel`` so the parity tests share one oracle table
KERNELS = {"se": k_se_ard, "matern52": k_matern52_ard}


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ExactGPState:
    X: jax.Array          # (N, p) train inputs
    chol: jax.Array       # (N, N) lower Cholesky of K + sigma^2 I
    alpha: jax.Array      # (N,)   (K + sigma^2 I)^{-1} y
    params: SEKernelParams
    kernel: str = dataclasses.field(
        default="se", metadata=dict(static=True)
    )


@partial(jax.jit, static_argnames=("kernel",))
def fit(X: jax.Array, y: jax.Array, params: SEKernelParams,
        kernel: str = "se") -> ExactGPState:
    N = X.shape[0]
    K = KERNELS[kernel](X, X, params.eps)
    Ky = K + (params.noise**2) * jnp.eye(N, dtype=K.dtype)
    chol = jnp.linalg.cholesky(Ky)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    return ExactGPState(X=X, chol=chol, alpha=alpha, params=params,
                        kernel=kernel)


@jax.jit
def predict(state: ExactGPState, Xs: jax.Array):
    """Posterior mean (N*,) and covariance (N*, N*) at test inputs Xs."""
    k = KERNELS[state.kernel]
    Ks = k(Xs, state.X, state.params.eps)                 # (N*, N)
    mu = Ks @ state.alpha                                  # Eq. 3, m = 0
    V = jax.scipy.linalg.solve_triangular(state.chol, Ks.T, lower=True)  # (N, N*)
    Kss = k(Xs, Xs, state.params.eps)
    cov = Kss - V.T @ V                                    # Eq. 4
    return mu, cov


@jax.jit
def mean_var(state: ExactGPState, Xs: jax.Array):
    """Posterior mean (N*,) and marginal variance (N*,) — the diagonal of
    :func:`predict`'s covariance without forming the N* x N* matrix.  Both
    reference kernels are unit-variance, so the prior diagonal is 1."""
    k = KERNELS[state.kernel]
    Ks = k(Xs, state.X, state.params.eps)                  # (N*, N)
    mu = Ks @ state.alpha
    V = jax.scipy.linalg.solve_triangular(state.chol, Ks.T, lower=True)
    var = jnp.maximum(1.0 - jnp.sum(V * V, axis=0), 0.0)
    return mu, var


@partial(jax.jit, static_argnames=("kernel",))
def nlml(X: jax.Array, y: jax.Array, params: SEKernelParams,
         kernel: str = "se") -> jax.Array:
    """Exact negative log marginal likelihood (for hyperparameter baselines)."""
    N = X.shape[0]
    K = KERNELS[kernel](X, X, params.eps)
    Ky = K + (params.noise**2) * jnp.eye(N, dtype=K.dtype)
    chol = jnp.linalg.cholesky(Ky)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    return (
        0.5 * jnp.dot(y, alpha)
        + jnp.sum(jnp.log(jnp.diagonal(chol)))
        + 0.5 * N * jnp.log(2.0 * jnp.pi)
    )
