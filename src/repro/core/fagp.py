"""Fast Approximate Gaussian Process (FAGP) — the paper's core technique.

GP regression with the Mercer-decomposed SE kernel (paper Eqs. 8-12):
the N x N kernel inverse is replaced, via the Woodbury identity, by the
inverse of the M x M matrix

    Lbar = Lambda^{-1} + Phi^T Sigma_n^{-1} Phi          (M = |index set|)

Two mathematically identical posterior evaluation paths are provided:

* ``mode="paper"`` — the literal GEMM chain of Eqs. 11-12, in the paper's
  operation order (forms the N x N approximate inverse, then W = N* x N).
  This is the *faithful baseline*: it is what cuFAGP times on the GPU.

* ``mode="fused"`` — beyond-paper algebraic simplification.  Substituting
  Lbar into Eqs. 11-12 collapses them to the weight-space form

      mu*    = Phi* u,            u = Lbar^{-1} Phi^T y / sigma^2
      Sigma* = Phi* Lbar^{-1} Phi*^T

  which avoids every N x N / N* x N intermediate (O(N M) -> O(M^2) memory,
  and ~N/M fewer FLOPs for the covariance).  Tests assert the two modes
  agree to f32 tolerance; EXPERIMENTS.md §Perf reports them separately.

Both paths share ``fit``, which accumulates the two sufficient statistics
G = Phi^T Phi and b = Phi^T y in a streaming scan over row blocks —
constant memory in N (beyond-paper; the paper materializes Phi whole).

Numerical form (beyond-paper, required for f32): lambda_n decays
geometrically and underflows f32 by column ~40, so Lbar = Lambda^{-1} + ...
cannot be formed directly.  We solve the symmetrically-scaled system

    B = I + D G D / sigma^2,      D = diag(sqrt(lambda))  (log-space)

with Lbar^{-1} = D B^{-1} D and logdet(Lbar) + logdet(Lambda) = logdet(B).
B has unit diagonal plus a PSD term (cond(B) bounded by 1 + ||DGD||/sig^2),
and columns whose sqrt(lambda) underflows contribute an identity row —
numerically inert, exactly as they should be.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .mercer import (
    IndexSetKind,
    SEKernelParams,
    log_eigenvalues_nd,
    make_index_set,
    phi_nd,
)

__all__ = ["FAGPConfig", "FAGPState", "build_features", "fit", "predict", "nlml"]


@dataclasses.dataclass(frozen=True)
class FAGPConfig:
    """Static configuration of the Mercer expansion.

    n:          eigenvalues per input dimension (paper's n).
    index_set:  'full' (paper; M = n^p) | 'total_degree' | 'hyperbolic_cross'.
    degree:     truncation parameter for the non-full sets (None = auto).
    block_rows: row-block size for the streaming Gram accumulation.
    store_train: keep (Phi, y) in the state — required for mode='paper'
                 prediction and for the cross-covariance term of Eq. 12.
    """

    n: int
    index_set: IndexSetKind = "full"
    degree: Optional[int] = None
    block_rows: int = 4096
    store_train: bool = True
    backend: str = "jnp"  # 'jnp' | 'pallas' (fused TPU kernels; interpret on CPU)

    def indices(self, p: int) -> np.ndarray:
        return make_index_set(self.index_set, self.n, p, self.degree)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FAGPState:
    """Fitted FAGP sufficient statistics (scaled-system form)."""

    idx: jax.Array            # (M, p) multi-index set (0-based degrees)
    lam: jax.Array            # (M,)   product eigenvalues (may underflow; info only)
    sqrtlam: jax.Array        # (M,)   exp(0.5 log lambda) — the scaling D
    chol: jax.Array           # (M, M) lower Cholesky of B = I + D G D / sigma^2
    u: jax.Array              # (M,)   Lbar^{-1} Phi^T y / sigma^2  (mean weights)
    params: SEKernelParams
    Phi: Optional[jax.Array]  # (N, M) train features   (store_train only)
    y: Optional[jax.Array]    # (N,)   train targets    (store_train only)


def build_features(X: jax.Array, params: SEKernelParams, idx: jax.Array, n_max: int) -> jax.Array:
    """Phi_(X) for an arbitrary multi-index set. (N, p) -> (N, M)."""
    return phi_nd(X, idx, params, n_max)


def _accumulate_moments(X, y, params, idx, n_max: int, block_rows: int):
    """Streaming G = Phi^T Phi, b = Phi^T y over row blocks (O(M^2) memory)."""
    N = X.shape[0]
    M = idx.shape[0]
    nblk = max(1, (N + block_rows - 1) // block_rows)
    pad = nblk * block_rows - N
    Xp = jnp.pad(X, ((0, pad), (0, 0)))
    yp = jnp.pad(y, (0, pad))
    mask = jnp.pad(jnp.ones((N,), X.dtype), (0, pad))

    Xb = Xp.reshape(nblk, block_rows, -1)
    yb = yp.reshape(nblk, block_rows)
    mb = mask.reshape(nblk, block_rows)

    def step(carry, blk):
        G, b = carry
        Xi, yi, mi = blk
        Phi_i = build_features(Xi, params, idx, n_max) * mi[:, None]
        G = G + Phi_i.T @ Phi_i
        b = b + Phi_i.T @ (yi * mi)
        return (G, b), None

    init = (jnp.zeros((M, M), X.dtype), jnp.zeros((M,), X.dtype))
    (G, b), _ = jax.lax.scan(step, init, (Xb, yb, mb))
    return G, b


@partial(jax.jit, static_argnames=("n_max", "block_rows", "store_train"))
def _fit(X, y, params, idx, n_max: int, block_rows: int, store_train: bool):
    sig2 = params.noise**2
    loglam = log_eigenvalues_nd(idx, params)
    sqrtlam = jnp.exp(0.5 * loglam)
    G, b = _accumulate_moments(X, y, params, idx, n_max, block_rows)
    M = idx.shape[0]
    B = jnp.eye(M, dtype=G.dtype) + (sqrtlam[:, None] * G * sqrtlam[None, :]) / sig2
    chol = jnp.linalg.cholesky(B)
    # u = Lbar^{-1} b / sig2 = D B^{-1} D b / sig2
    u = sqrtlam * jax.scipy.linalg.cho_solve((chol, True), sqrtlam * b) / sig2
    Phi = build_features(X, params, idx, n_max) if store_train else None
    return FAGPState(
        idx=idx, lam=jnp.exp(loglam), sqrtlam=sqrtlam, chol=chol, u=u,
        params=params, Phi=Phi, y=y if store_train else None,
    )


@partial(jax.jit, static_argnames=("n_max", "store_train"))
def _fit_pallas(X, y, params, idx, S, n_max: int, store_train: bool):
    """fit() on the fused Pallas kernels: one HBM pass builds Phi, a second
    fused pass produces B directly (gram + scaling + diagonal in one kernel)."""
    from repro.kernels import ops as kops
    from repro.kernels import ref as kref

    sig2 = params.noise**2
    loglam = log_eigenvalues_nd(idx, params)
    sqrtlam = jnp.exp(0.5 * loglam)
    consts = kref.phi_consts(params.eps, params.rho)
    Phi = kops.hermite_phi(X, consts, S, n_max=n_max)
    B = kops.scaled_gram(Phi, sqrtlam, sig2)
    chol = jnp.linalg.cholesky(B)
    b = Phi.T @ y
    u = sqrtlam * jax.scipy.linalg.cho_solve((chol, True), sqrtlam * b) / sig2
    return FAGPState(
        idx=idx, lam=jnp.exp(loglam), sqrtlam=sqrtlam, chol=chol, u=u,
        params=params, Phi=Phi if store_train else None,
        y=y if store_train else None,
    )


def fit(X: jax.Array, y: jax.Array, params: SEKernelParams, cfg: FAGPConfig) -> FAGPState:
    idx_np = cfg.indices(X.shape[1])
    idx = jnp.asarray(idx_np)
    if cfg.backend == "pallas":
        from repro.kernels import ref as kref

        S = jnp.asarray(kref.one_hot_selection(idx_np, cfg.n))
        return _fit_pallas(X, y, params, idx, S, cfg.n, cfg.store_train)
    return _fit(X, y, params, idx, cfg.n, cfg.block_rows, cfg.store_train)


# ---------------------------------------------------------------------------
# Prediction
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("n_max",))
def _predict_fused(state: FAGPState, Xs: jax.Array, n_max: int):
    """Beyond-paper weight-space path: no N-sized intermediates.

    Phi* Lbar^{-1} Phi*^T = (Phi* D) B^{-1} (Phi* D)^T via triangular solve.
    """
    Phis = build_features(Xs, state.params, state.idx, n_max)  # (N*, M)
    mu = Phis @ state.u
    PhisD = Phis * state.sqrtlam[None, :]
    V = jax.scipy.linalg.solve_triangular(state.chol, PhisD.T, lower=True)  # (M, N*)
    cov = V.T @ V
    return mu, cov


@partial(jax.jit, static_argnames=("n_max",))
def _predict_paper(state: FAGPState, Xs: jax.Array, n_max: int):
    """Literal Eqs. 11-12 GEMM chain in the paper's operation order.

    Requires store_train=True.  Forms the N x N approximate inverse
    (Sigma_n^{-1} - Sigma_n^{-1} Phi Lbar^{-1} Phi^T Sigma_n^{-1}) exactly as
    the CUDA implementation does, then W (N* x N), then mu*, Sigma*.
    """
    Phi, y = state.Phi, state.y
    N = Phi.shape[0]
    sig2 = state.params.noise**2
    Phis = build_features(Xs, state.params, state.idx, n_max)   # (N*, M)
    Lam = state.lam                                             # (M,)

    D = state.sqrtlam
    LbarinvPhiT = D[:, None] * jax.scipy.linalg.cho_solve(
        (state.chol, True), D[:, None] * Phi.T
    )  # Lbar^{-1} Phi^T = D B^{-1} D Phi^T,  (M, N)
    Kinv = jnp.eye(N, dtype=Phi.dtype) / sig2 - (Phi @ LbarinvPhiT) / (sig2 * sig2)
    PhisLam = Phis * Lam[None, :]                               # Phi* Lambda
    W = (PhisLam @ Phi.T) @ Kinv                                # (N*, N) — Eq. 11's W
    mu = W @ y
    cov = PhisLam @ Phis.T - (W @ Phi) @ (Lam[:, None] * Phis.T)  # Eq. 12
    return mu, cov


def predict(state: FAGPState, Xs: jax.Array, cfg: FAGPConfig, mode: str = "fused"):
    """Posterior mean (N*,) and covariance (N*, N*) at Xs."""
    if mode == "fused":
        return _predict_fused(state, Xs, cfg.n)
    if mode == "paper":
        if state.Phi is None:
            raise ValueError("mode='paper' requires FAGPConfig(store_train=True)")
        return _predict_paper(state, Xs, cfg.n)
    raise ValueError(f"unknown mode {mode!r}")


@partial(jax.jit, static_argnames=("n_max", "backend"))
def _predict_mean_var(state: FAGPState, Xs, S, n_max: int, backend: str):
    if backend == "pallas":
        from repro.kernels import ops as kops
        from repro.kernels import ref as kref

        consts = kref.phi_consts(state.params.eps, state.params.rho)
        Phis = kops.hermite_phi(Xs, consts, S, n_max=n_max)
        mu = Phis @ state.u
        M = state.chol.shape[0]
        Binv = jax.scipy.linalg.cho_solve((state.chol, True), jnp.eye(M, dtype=Phis.dtype))
        var = kops.diag_quad(Phis * state.sqrtlam[None, :], Binv)
        return mu, var
    Phis = build_features(Xs, state.params, state.idx, n_max)
    mu = Phis @ state.u
    PhisD = Phis * state.sqrtlam[None, :]
    V = jax.scipy.linalg.solve_triangular(state.chol, PhisD.T, lower=True)
    return mu, jnp.sum(V * V, axis=0)


def predict_mean_var(state: FAGPState, Xs: jax.Array, cfg: FAGPConfig):
    """Posterior mean and *marginal variance* (N*,) — the production serving
    path: never materializes the N* x N* covariance (kernels/diag_quad)."""
    S = None
    if cfg.backend == "pallas":
        from repro.kernels import ref as kref

        S = jnp.asarray(kref.one_hot_selection(np.asarray(state.idx), cfg.n))
    return _predict_mean_var(state, Xs, S, cfg.n, cfg.backend)


# ---------------------------------------------------------------------------
# Negative log marginal likelihood (paper's declared future work)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("n_max", "block_rows"))
def nlml(X, y, params: SEKernelParams, idx, n_max: int, block_rows: int = 4096):
    """NLML of the decomposed-kernel GP, O(N M^2 + M^3).

    Matrix determinant lemma + Woodbury on (Phi Lambda Phi^T + sigma^2 I):
        logdet = logdet(Lbar) + logdet(Lambda) + N log sigma^2
        quad   = (y^T y - b^T Lbar^{-1} b) / ... with b = Phi^T y / sigma^2
    Differentiable in (eps, rho, noise) for gradient-based hyperparameter
    learning (see examples/hyperparam_learning.py).
    """
    N = X.shape[0]
    sig2 = params.noise**2
    loglam = log_eigenvalues_nd(idx, params)
    sqrtlam = jnp.exp(0.5 * loglam)
    G, b = _accumulate_moments(X, y, params, idx, n_max, block_rows)
    M = idx.shape[0]
    B = jnp.eye(M, dtype=G.dtype) + (sqrtlam[:, None] * G * sqrtlam[None, :]) / sig2
    chol = jnp.linalg.cholesky(B)
    bs = sqrtlam * b / sig2                      # D b / sig2
    w = jax.scipy.linalg.cho_solve((chol, True), bs)
    # y^T Kinv y = y^T y/sig2 - b^T Lbar^{-1} b / sig2^2
    #            = y^T y/sig2 - (Db/sig2)^T B^{-1} (Db/sig2) = ... - dot(bs, w)
    quad = jnp.dot(y, y) / sig2 - jnp.dot(bs, w)
    # logdet(K) = logdet(B) + N log sig2   (determinant lemma, scaled form)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(chol))) + N * jnp.log(sig2)
    return 0.5 * (quad + logdet + N * jnp.log(2.0 * jnp.pi))
