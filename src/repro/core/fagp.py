"""Fast Approximate Gaussian Process (FAGP) — the paper's core technique.

GP regression with the Mercer-decomposed SE kernel (paper Eqs. 8-12):
the N x N kernel inverse is replaced, via the Woodbury identity, by the
inverse of the M x M matrix

    Lbar = Lambda^{-1} + Phi^T Sigma_n^{-1} Phi          (M = |index set|)

Two mathematically identical posterior evaluation paths are provided:

* ``mode="paper"`` — the literal GEMM chain of Eqs. 11-12, in the paper's
  operation order (forms the N x N approximate inverse, then W = N* x N).
  This is the *faithful baseline*: it is what cuFAGP times on the GPU.

* ``mode="fused"`` — beyond-paper algebraic simplification.  Substituting
  Lbar into Eqs. 11-12 collapses them to the weight-space form

      mu*    = Phi* u,            u = Lbar^{-1} Phi^T y / sigma^2
      Sigma* = Phi* Lbar^{-1} Phi*^T

  which avoids every N x N / N* x N intermediate (O(N M) -> O(M^2) memory,
  and ~N/M fewer FLOPs for the covariance).  Tests assert the two modes
  agree to f32 tolerance; EXPERIMENTS.md §Perf reports them separately.

Both paths share ``fit``, which accumulates the two sufficient statistics
G = Phi^T Phi and b = Phi^T y in one streaming pass — constant memory in N
(beyond-paper; the paper materializes Phi whole).  Execution is dispatched
through a small backend registry (``register_backend`` / ``get_backend``):

* ``backend="jnp"``    — scan over row blocks, pure XLA (any device);
* ``backend="pallas"`` — the streaming fused-fit kernel
  (``kernels/phi_gram``): Hermite-feature tiles are generated in VMEM inside
  the Gram accumulation, so Phi is never written to HBM.

The same registry serves ``predict_mean_var`` and the per-shard moment
accumulation in ``core.distributed``.  ``fit_update`` absorbs new
observations into a fitted state by a rank-k Cholesky update of B —
O(k M^2), no pass over the original N rows (the serving ingest path).

Numerical form (beyond-paper, required for f32): lambda_n decays
geometrically and underflows f32 by column ~40, so Lbar = Lambda^{-1} + ...
cannot be formed directly.  We solve the symmetrically-scaled system

    B = I + D G D / sigma^2,      D = diag(sqrt(lambda))  (log-space)

with Lbar^{-1} = D B^{-1} D and logdet(Lbar) + logdet(Lambda) = logdet(B).
B has unit diagonal plus a PSD term (cond(B) bounded by 1 + ||DGD||/sig^2),
and columns whose sqrt(lambda) underflows contribute an identity row —
numerically inert, exactly as they should be.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .mercer import (
    IndexSetKind,
    SEKernelParams,
    log_eigenvalues_nd,
    make_index_set,
    phi_nd,
)

__all__ = [
    "FAGPConfig",
    "FAGPState",
    "FitBackend",
    "available_backends",
    "build_features",
    "fit",
    "fit_update",
    "get_backend",
    "nlml",
    "predict",
    "predict_mean_var",
    "register_backend",
]


@dataclasses.dataclass(frozen=True)
class FAGPConfig:
    """Static configuration of the Mercer expansion.

    n:          eigenvalues per input dimension (paper's n).
    index_set:  'full' (paper; M = n^p) | 'total_degree' | 'hyperbolic_cross'.
    degree:     truncation parameter for the non-full sets (None = auto).
    block_rows: row-block size for the streaming Gram accumulation.
    store_train: keep (Phi, y) in the state — required for mode='paper'
                 prediction and for the cross-covariance term of Eq. 12.
    """

    n: int
    index_set: IndexSetKind = "full"
    degree: Optional[int] = None
    block_rows: int = 4096
    store_train: bool = True
    backend: str = "jnp"  # 'jnp' | 'pallas' (fused TPU kernels; interpret on CPU)

    def indices(self, p: int) -> np.ndarray:
        return make_index_set(self.index_set, self.n, p, self.degree)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FAGPState:
    """Fitted FAGP sufficient statistics (scaled-system form)."""

    idx: jax.Array            # (M, p) multi-index set (0-based degrees)
    lam: jax.Array            # (M,)   product eigenvalues (may underflow; info only)
    sqrtlam: jax.Array        # (M,)   exp(0.5 log lambda) — the scaling D
    chol: jax.Array           # (M, M) lower Cholesky of B = I + D G D / sigma^2
    u: jax.Array              # (M,)   Lbar^{-1} Phi^T y / sigma^2  (mean weights)
    params: SEKernelParams
    Phi: Optional[jax.Array]  # (N, M) train features   (store_train only)
    y: Optional[jax.Array]    # (N,)   train targets    (store_train only)
    b: Optional[jax.Array] = None  # (M,) raw moment Phi^T y — enables fit_update


def build_features(X: jax.Array, params: SEKernelParams, idx: jax.Array, n_max: int) -> jax.Array:
    """Phi_(X) for an arbitrary multi-index set. (N, p) -> (N, M)."""
    return phi_nd(X, idx, params, n_max)


def _accumulate_moments(X, y, params, idx, n_max: int, block_rows: int,
                        row_mask=None):
    """Streaming G = Phi^T Phi, b = Phi^T y over row blocks (O(M^2) memory)."""
    N = X.shape[0]
    M = idx.shape[0]
    nblk = max(1, (N + block_rows - 1) // block_rows)
    pad = nblk * block_rows - N
    Xp = jnp.pad(X, ((0, pad), (0, 0)))
    yp = jnp.pad(y, (0, pad))
    valid = jnp.ones((N,), X.dtype) if row_mask is None else row_mask.astype(X.dtype)
    mask = jnp.pad(valid, (0, pad))

    Xb = Xp.reshape(nblk, block_rows, -1)
    yb = yp.reshape(nblk, block_rows)
    mb = mask.reshape(nblk, block_rows)

    def step(carry, blk):
        G, b = carry
        Xi, yi, mi = blk
        Phi_i = build_features(Xi, params, idx, n_max) * mi[:, None]
        G = G + Phi_i.T @ Phi_i
        b = b + Phi_i.T @ (yi * mi)
        return (G, b), None

    init = (jnp.zeros((M, M), X.dtype), jnp.zeros((M,), X.dtype))
    (G, b), _ = jax.lax.scan(step, init, (Xb, yb, mb))
    return G, b


def _finish_fit(B, b, loglam, sqrtlam, sig2, idx, params, Phi, y):
    """Shared fit epilogue: M x M Cholesky solve -> FAGPState."""
    chol = jnp.linalg.cholesky(B)
    # u = Lbar^{-1} b / sig2 = D B^{-1} D b / sig2
    u = sqrtlam * jax.scipy.linalg.cho_solve((chol, True), sqrtlam * b) / sig2
    return FAGPState(
        idx=idx, lam=jnp.exp(loglam), sqrtlam=sqrtlam, chol=chol, u=u,
        params=params, Phi=Phi, y=y, b=b,
    )


@partial(jax.jit, static_argnames=("n_max", "block_rows", "store_train"))
def _fit(X, y, params, idx, n_max: int, block_rows: int, store_train: bool):
    sig2 = params.noise**2
    loglam = log_eigenvalues_nd(idx, params)
    sqrtlam = jnp.exp(0.5 * loglam)
    G, b = _accumulate_moments(X, y, params, idx, n_max, block_rows)
    M = idx.shape[0]
    B = jnp.eye(M, dtype=G.dtype) + (sqrtlam[:, None] * G * sqrtlam[None, :]) / sig2
    Phi = build_features(X, params, idx, n_max) if store_train else None
    return _finish_fit(B, b, loglam, sqrtlam, sig2, idx, params,
                       Phi, y if store_train else None)


@partial(jax.jit, static_argnames=("n_max", "store_train"))
def _fit_pallas(X, y, params, idx, S, n_max: int, store_train: bool):
    """fit() on the streaming fused Pallas kernel: feature tiles are
    generated on the fly inside the Gram accumulation (kernels/phi_gram), so
    Phi never exists in HBM and peak live memory is O(M^2) in N — one HBM
    pass over X instead of the materialized path's two passes plus an N x M
    intermediate.  (store_train=True additionally materializes Phi for
    mode='paper' prediction, reintroducing the N x M buffer by request.)"""
    from repro.kernels import ops as kops
    from repro.kernels import ref as kref

    sig2 = params.noise**2
    loglam = log_eigenvalues_nd(idx, params)
    sqrtlam = jnp.exp(0.5 * loglam)
    consts = kref.phi_consts(params.eps, params.rho)
    B, b = kops.fused_fit_moments(X, y, consts, S, sqrtlam, sig2, n_max=n_max)
    Phi = kops.hermite_phi(X, consts, S, n_max=n_max) if store_train else None
    return _finish_fit(B, b, loglam, sqrtlam, sig2, idx, params,
                       Phi, y if store_train else None)


# ---------------------------------------------------------------------------
# Backend registry — one dispatch point shared by fit / predict_mean_var /
# core.distributed (per-shard moments), so a new execution backend plugs in
# by registering one FitBackend instead of editing every call site.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FitBackend:
    """Execution backend for the FAGP hot paths.

    prepare:  (idx_np, n) -> static auxiliary carried to every call (e.g. the
              one-hot selection matrix for the Pallas kernels); None if unused.
    fit:      (X, y, params, idx, aux, cfg) -> FAGPState.
    features: (X, params, idx, aux, n_max) -> (N, M) feature matrix.
    mean_var: (state, Xs, aux, n_max) -> (mu, var), the serving path.
    moments:  (X, y, params, idx, aux, n_max, block_rows, mask) -> (G, b)
              raw sufficient statistics — the per-shard unit of work for
              core.distributed (partial sums, psum'd before the solve).
    """

    name: str
    prepare: Callable[[np.ndarray, int], Any]
    fit: Callable[..., "FAGPState"]
    features: Callable[..., jax.Array]
    mean_var: Callable[..., tuple]
    moments: Callable[..., tuple]


_BACKENDS: dict[str, FitBackend] = {}


def register_backend(backend: FitBackend) -> None:
    _BACKENDS[backend.name] = backend


def get_backend(name: str) -> FitBackend:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered: {available_backends()}"
        ) from None


def available_backends() -> list[str]:
    return sorted(_BACKENDS)


# prepare() results memoized per (idx array, backend, n): predict_mean_var /
# fit_update sit on the serving hot path, and rebuilding the one-hot
# selection matrix (plus the blocking device->host idx copy) per microbatch
# is pure waste.  Keyed by id() and validated by weakref so a recycled id
# can never alias a dead array.
_AUX_CACHE: dict = {}


def _backend_aux(backend: FitBackend, idx: jax.Array, n: int):
    import weakref

    key = (id(idx), backend.name, n)
    hit = _AUX_CACHE.get(key)
    if hit is not None and hit[0]() is idx:
        return hit[1]
    aux = backend.prepare(np.asarray(idx), n)
    try:
        ref = weakref.ref(idx)
    except TypeError:
        return aux
    if len(_AUX_CACHE) > 64:
        _AUX_CACHE.clear()
    _AUX_CACHE[key] = (ref, aux)
    return aux


# --- jnp backend (scan-streamed, pure XLA) ---------------------------------


@partial(jax.jit, static_argnames=("n_max",))
def _features_jit(X, params, idx, n_max: int):
    return build_features(X, params, idx, n_max)


def _jnp_features(X, params, idx, aux, n_max):
    return _features_jit(X, params, idx, n_max)


def _jnp_moments(X, y, params, idx, aux, n_max, block_rows, mask=None):
    return _accumulate_moments(X, y, params, idx, n_max, block_rows,
                               row_mask=mask)


def _jnp_fit(X, y, params, idx, aux, cfg: "FAGPConfig"):
    return _fit(X, y, params, idx, cfg.n, cfg.block_rows, cfg.store_train)


def _jnp_mean_var(state, Xs, aux, n_max):
    return _mean_var_jnp(state, Xs, n_max)


# --- pallas backend (fused TPU kernels; interpret mode on CPU) -------------


def _pallas_prepare(idx_np: np.ndarray, n: int):
    from repro.kernels import ref as kref

    return jnp.asarray(kref.one_hot_selection(idx_np, n))


def _pallas_features(X, params, idx, aux, n_max):
    from repro.kernels import ops as kops
    from repro.kernels import ref as kref

    consts = kref.phi_consts(params.eps, params.rho)
    return kops.hermite_phi(X, consts, aux, n_max=n_max)


def _pallas_moments(X, y, params, idx, aux, n_max, block_rows, mask=None):
    from repro.kernels import ops as kops
    from repro.kernels import ref as kref

    consts = kref.phi_consts(params.eps, params.rho)
    ones = jnp.ones((idx.shape[0],), jnp.float32)
    return kops.fused_fit_moments(
        X, y, consts, aux, ones, jnp.float32(1.0), mask,
        n_max=n_max, scale=False,
    )


def _pallas_fit(X, y, params, idx, aux, cfg: "FAGPConfig"):
    return _fit_pallas(X, y, params, idx, aux, cfg.n, cfg.store_train)


def _pallas_mean_var(state, Xs, aux, n_max):
    return _mean_var_pallas(state, Xs, aux, n_max)


register_backend(FitBackend(
    name="jnp", prepare=lambda idx_np, n: None, fit=_jnp_fit,
    features=_jnp_features, mean_var=_jnp_mean_var, moments=_jnp_moments,
))
register_backend(FitBackend(
    name="pallas", prepare=_pallas_prepare, fit=_pallas_fit,
    features=_pallas_features, mean_var=_pallas_mean_var,
    moments=_pallas_moments,
))


def fit(X: jax.Array, y: jax.Array, params: SEKernelParams, cfg: FAGPConfig) -> FAGPState:
    backend = get_backend(cfg.backend)
    idx_np = cfg.indices(X.shape[1])
    idx = jnp.asarray(idx_np)
    aux = backend.prepare(idx_np, cfg.n)
    return backend.fit(X, y, params, idx, aux, cfg)


# ---------------------------------------------------------------------------
# Online incremental fitting (rank-k update of the scaled system)
# ---------------------------------------------------------------------------


def _chol_rank1_update(L: jax.Array, w: jax.Array) -> jax.Array:
    """Cholesky of L L^T + w w^T, O(M^2) (LINPACK positive-update sweep).

    Column-sequential Givens-style sweep expressed as a scan with masked
    whole-column updates; additions are always well-posed (no downdates)."""
    M = L.shape[0]
    ar = jnp.arange(M)

    def step(carry, k):
        L, w = carry
        Lkk = L[k, k]
        wk = w[k]
        r = jnp.sqrt(Lkk * Lkk + wk * wk)
        c = r / Lkk
        s = wk / Lkk
        col = L[:, k]
        below = ar > k
        newcol = jnp.where(below, (col + s * w) / c, col).at[k].set(r)
        w = jnp.where(below, c * w - s * newcol, w)
        return (L.at[:, k].set(newcol), w), None

    (L, _), _ = jax.lax.scan(step, (L, w), ar)
    return L


@jax.jit
def _update_state(state: FAGPState, Phi_new: jax.Array, y_new: jax.Array):
    sig2 = state.params.noise**2
    # B_new = B + sum_k v_k v_k^T,  v_k = D phi_k / sigma  (rank-K update)
    W = Phi_new * state.sqrtlam[None, :] / state.params.noise
    K, M = W.shape
    if K * 8 <= M:
        # small K: sequential rank-1 sweeps, O(K M^2), beats refactorization
        chol, _ = jax.lax.scan(
            lambda L, w: (_chol_rank1_update(L, w), None), state.chol, W
        )
    else:
        # K comparable to M: the rank-1 sweep is K*M sequential latency-bound
        # steps; rebuilding the M x M factor is O(M^3/3) fully-parallel work
        # and still never touches the original N rows
        B = state.chol @ state.chol.T + W.T @ W
        chol = jnp.linalg.cholesky(B)
    b = state.b + Phi_new.T @ y_new
    u = state.sqrtlam * jax.scipy.linalg.cho_solve((chol, True), state.sqrtlam * b) / sig2
    return chol, b, u


def fit_update(
    state: FAGPState, X_new: jax.Array, y_new: jax.Array, cfg: FAGPConfig
) -> FAGPState:
    """Absorb new observations into a fitted state without refitting.

    Rank-k Cholesky update of B (O(k M^2)) plus a fresh M x M solve for the
    mean weights — no pass over the original N rows, so the serving loop can
    ingest observation microbatches at O(M^2) cost each (vs O(N M^2) refit).
    Exactly equivalent to refitting on the concatenated data (same math, up
    to f32 rounding); tests pin update-then-predict == refit-then-predict.
    """
    if state.b is None:
        raise ValueError("fit_update needs a state produced by fit() >= this "
                         "version (missing the raw moment vector b)")
    backend = get_backend(cfg.backend)
    aux = _backend_aux(backend, state.idx, cfg.n)
    Phi_new = backend.features(X_new, state.params, state.idx, aux, cfg.n)
    chol, b, u = _update_state(state, Phi_new, y_new)
    Phi = y = None
    if state.Phi is not None:
        Phi = jnp.concatenate([state.Phi, Phi_new], axis=0)
        y = jnp.concatenate([state.y, y_new], axis=0)
    return dataclasses.replace(state, chol=chol, b=b, u=u, Phi=Phi, y=y)


# ---------------------------------------------------------------------------
# Prediction
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("n_max",))
def _predict_fused(state: FAGPState, Xs: jax.Array, n_max: int):
    """Beyond-paper weight-space path: no N-sized intermediates.

    Phi* Lbar^{-1} Phi*^T = (Phi* D) B^{-1} (Phi* D)^T via triangular solve.
    """
    Phis = build_features(Xs, state.params, state.idx, n_max)  # (N*, M)
    mu = Phis @ state.u
    PhisD = Phis * state.sqrtlam[None, :]
    V = jax.scipy.linalg.solve_triangular(state.chol, PhisD.T, lower=True)  # (M, N*)
    cov = V.T @ V
    return mu, cov


@partial(jax.jit, static_argnames=("n_max",))
def _predict_paper(state: FAGPState, Xs: jax.Array, n_max: int):
    """Literal Eqs. 11-12 GEMM chain in the paper's operation order.

    Requires store_train=True.  Forms the N x N approximate inverse
    (Sigma_n^{-1} - Sigma_n^{-1} Phi Lbar^{-1} Phi^T Sigma_n^{-1}) exactly as
    the CUDA implementation does, then W (N* x N), then mu*, Sigma*.
    """
    Phi, y = state.Phi, state.y
    N = Phi.shape[0]
    sig2 = state.params.noise**2
    Phis = build_features(Xs, state.params, state.idx, n_max)   # (N*, M)
    Lam = state.lam                                             # (M,)

    D = state.sqrtlam
    LbarinvPhiT = D[:, None] * jax.scipy.linalg.cho_solve(
        (state.chol, True), D[:, None] * Phi.T
    )  # Lbar^{-1} Phi^T = D B^{-1} D Phi^T,  (M, N)
    Kinv = jnp.eye(N, dtype=Phi.dtype) / sig2 - (Phi @ LbarinvPhiT) / (sig2 * sig2)
    PhisLam = Phis * Lam[None, :]                               # Phi* Lambda
    W = (PhisLam @ Phi.T) @ Kinv                                # (N*, N) — Eq. 11's W
    mu = W @ y
    cov = PhisLam @ Phis.T - (W @ Phi) @ (Lam[:, None] * Phis.T)  # Eq. 12
    return mu, cov


def predict(state: FAGPState, Xs: jax.Array, cfg: FAGPConfig, mode: str = "fused"):
    """Posterior mean (N*,) and covariance (N*, N*) at Xs."""
    if mode == "fused":
        return _predict_fused(state, Xs, cfg.n)
    if mode == "paper":
        if state.Phi is None:
            raise ValueError("mode='paper' requires FAGPConfig(store_train=True)")
        return _predict_paper(state, Xs, cfg.n)
    raise ValueError(f"unknown mode {mode!r}")


@partial(jax.jit, static_argnames=("n_max",))
def _mean_var_pallas(state: FAGPState, Xs, S, n_max: int):
    from repro.kernels import ops as kops
    from repro.kernels import ref as kref

    consts = kref.phi_consts(state.params.eps, state.params.rho)
    Phis = kops.hermite_phi(Xs, consts, S, n_max=n_max)
    mu = Phis @ state.u
    M = state.chol.shape[0]
    Binv = jax.scipy.linalg.cho_solve((state.chol, True), jnp.eye(M, dtype=Phis.dtype))
    var = kops.diag_quad(Phis * state.sqrtlam[None, :], Binv)
    return mu, var


@partial(jax.jit, static_argnames=("n_max",))
def _mean_var_jnp(state: FAGPState, Xs, n_max: int):
    Phis = build_features(Xs, state.params, state.idx, n_max)
    mu = Phis @ state.u
    PhisD = Phis * state.sqrtlam[None, :]
    V = jax.scipy.linalg.solve_triangular(state.chol, PhisD.T, lower=True)
    return mu, jnp.sum(V * V, axis=0)


def predict_mean_var(state: FAGPState, Xs: jax.Array, cfg: FAGPConfig):
    """Posterior mean and *marginal variance* (N*,) — the production serving
    path: never materializes the N* x N* covariance (kernels/diag_quad)."""
    backend = get_backend(cfg.backend)
    aux = _backend_aux(backend, state.idx, cfg.n)
    return backend.mean_var(state, Xs, aux, cfg.n)


# ---------------------------------------------------------------------------
# Negative log marginal likelihood (paper's declared future work)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("n_max", "block_rows"))
def nlml(X, y, params: SEKernelParams, idx, n_max: int, block_rows: int = 4096):
    """NLML of the decomposed-kernel GP, O(N M^2 + M^3).

    Matrix determinant lemma + Woodbury on (Phi Lambda Phi^T + sigma^2 I):
        logdet = logdet(Lbar) + logdet(Lambda) + N log sigma^2
        quad   = (y^T y - b^T Lbar^{-1} b) / ... with b = Phi^T y / sigma^2
    Differentiable in (eps, rho, noise) for gradient-based hyperparameter
    learning (see examples/hyperparam_learning.py).
    """
    N = X.shape[0]
    sig2 = params.noise**2
    loglam = log_eigenvalues_nd(idx, params)
    sqrtlam = jnp.exp(0.5 * loglam)
    G, b = _accumulate_moments(X, y, params, idx, n_max, block_rows)
    M = idx.shape[0]
    B = jnp.eye(M, dtype=G.dtype) + (sqrtlam[:, None] * G * sqrtlam[None, :]) / sig2
    chol = jnp.linalg.cholesky(B)
    bs = sqrtlam * b / sig2                      # D b / sig2
    w = jax.scipy.linalg.cho_solve((chol, True), bs)
    # y^T Kinv y = y^T y/sig2 - b^T Lbar^{-1} b / sig2^2
    #            = y^T y/sig2 - (Db/sig2)^T B^{-1} (Db/sig2) = ... - dot(bs, w)
    quad = jnp.dot(y, y) / sig2 - jnp.dot(bs, w)
    # logdet(K) = logdet(B) + N log sig2   (determinant lemma, scaled form)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(chol))) + N * jnp.log(sig2)
    return 0.5 * (quad + logdet + N * jnp.log(2.0 * jnp.pi))
