"""Fast Approximate Gaussian Process (FAGP) — the paper's core technique.

GP regression with a *decomposed kernel* (paper Eqs. 8-12): the N x N
kernel inverse is replaced, via the Woodbury identity, by the inverse of
the M x M matrix

    Lbar = Lambda^{-1} + Phi^T Sigma_n^{-1} Phi          (M = feature count)

Public API (one self-describing session; see also ``core.gp.GP``):

    spec  = GPSpec.create(n=8, eps=[0.8, 0.8], noise=0.05)   # one frozen spec
    state = fit(X, y, spec)          # spec is baked into the state
    mu, var = predict_mean_var(state, Xs)   # nothing re-passed — ever
    state = fit_update(state, Xn, yn)
    loss = nlml(X, y, spec)

``GPSpec`` merges the kernel hyperparameters (differentiable data leaves:
``eps``/``rho``/``noise``, plus the RFF spectral draws ``omega``) with the
static expansion choices (hashable metadata, trigger recompilation when
changed).  ``fit`` bakes the spec into ``FAGPState``, so
``predict``/``fit_update``/``predict_mean_var`` derive the feature map,
backend and block size from the state — a caller can no longer fit with
``n=12`` and predict with ``n=10`` and silently get wrong features.
``state.with_spec(...)`` is the explicit escape hatch for swapping the
execution knobs (backend, block size) at serve time; structural changes
(expansion, n, index set, hyperparameters) are rejected because they are
frozen into the factorization.

The kernel decomposition itself is PLUGGABLE (``core.expansions``): the
spec names a registered :class:`~repro.core.expansions.KernelExpansion`
(``spec.expansion``), which supplies the static index table (its row count
IS M), the log weights, the jnp feature map, and the in-VMEM Pallas tile
builder.  ``hermite`` (the paper's Mercer eigen-expansion of the SE
kernel) is the default; ``rff_se`` and ``rff_matern52`` (random Fourier
features, spectral draws carried as spec data) ship as the second family —
every entry point below, both distributed schedules, and the bank are
expansion-generic.

Targets ``y`` may be ``(N,)`` or multi-output ``(N, T)``: all T tasks share
the one M x M Cholesky factorization (the expensive part) and get per-task
mean weights ``u`` of shape ``(M, T)`` from one batched triangular solve —
fitting T tasks costs one fit plus T - 1 extra GEMV-sized solves.

Two mathematically identical posterior evaluation paths are provided:

* ``mode="paper"`` — the literal GEMM chain of Eqs. 11-12, in the paper's
  operation order (forms the N x N approximate inverse, then W = N* x N).
  This is the *faithful baseline*: it is what cuFAGP times on the GPU.

* ``mode="fused"`` — beyond-paper algebraic simplification.  Substituting
  Lbar into Eqs. 11-12 collapses them to the weight-space form

      mu*    = Phi* u,            u = Lbar^{-1} Phi^T y / sigma^2
      Sigma* = Phi* Lbar^{-1} Phi*^T

  which avoids every N x N / N* x N intermediate (O(N M) -> O(M^2) memory,
  and ~N/M fewer FLOPs for the covariance).  Tests assert the two modes
  agree to f32 tolerance; EXPERIMENTS.md §Perf reports them separately.

Both paths share ``fit``, which accumulates the two sufficient statistics
G = Phi^T Phi and b = Phi^T y in one streaming pass — constant memory in N
(beyond-paper; the paper materializes Phi whole).  Execution is dispatched
through a registry of capability-declaring backends (``register_backend``
/ ``get_backend``); each backend implements fit/features/mean_var/moments
and declares what it ``supports`` so unsupported specs are refused with a
clear error up front instead of crashing deep inside kernel preparation:

* ``backend="jnp"``    — scan over row blocks, pure XLA (any device);
* ``backend="pallas"`` — the streaming fused-fit kernel
  (``kernels/phi_gram``): feature tiles are generated in VMEM inside the
  Gram accumulation by the expansion's tile builder, so Phi is never
  written to HBM — for ANY registered expansion.

The same registry serves ``predict_mean_var`` and the per-shard moment
accumulation in ``core.distributed``.  ``fit_update`` absorbs new
observations into a fitted state by a rank-k Cholesky update of B —
O(k M^2), no pass over the original N rows (the serving ingest path).

Numerical form (beyond-paper, required for f32): Mercer lambda_n decays
geometrically and underflows f32 by column ~40, so Lbar = Lambda^{-1} + ...
cannot be formed directly.  We solve the symmetrically-scaled system

    B = I + D G D / sigma^2,      D = diag(sqrt(lambda))  (log-space)

assembled in exactly one place (``_assemble_scaled_system``) and shared by
fit, nlml and the distributed schedules, with Lbar^{-1} = D B^{-1} D and
logdet(Lbar) + logdet(Lambda) = logdet(B).  B has unit diagonal plus a PSD
term (cond(B) bounded by 1 + ||DGD||/sig^2), and columns whose sqrt(lambda)
underflows contribute an identity row — numerically inert, exactly as they
should be.  (RFF weights are flat 1/R — the same scaled form degrades
gracefully to a plain normalized Gram.)

REMOVED (was deprecated for two releases): the split ``fit(X, y, params,
cfg)`` / ``predict(state, Xs, cfg)`` / ``nlml(X, y, params, idx, n_max)``
signatures that re-took configuration at every call site now raise
``TypeError``.  See README §Migration.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .approximation import (
    Approximation,
    UnsupportedError,
    get_approximation,
    register_approximation,
)
from .expansions import (
    available_expansions,
    get_expansion,
)
from .mercer import (
    IndexSetKind,
    SEKernelParams,
    make_index_set,
)

__all__ = [
    "FAGPConfig",
    "FAGPState",
    "FitBackend",
    "GPSpec",
    "available_backends",
    "available_expansions",
    "build_features",
    "fit",
    "fit_update",
    "get_backend",
    "get_expansion",
    "nlml",
    "predict",
    "predict_mean_var",
    "register_backend",
]


def _removed(old: str, new: str) -> None:
    raise TypeError(
        f"{old} was removed (deprecated two releases ago); {new}"
    )


@dataclasses.dataclass(frozen=True)
class FAGPConfig:
    """Static configuration of the Hermite-Mercer expansion.

    Retained as the static half of the legacy split API (workload tables in
    ``configs/fagp.py`` carry it without hyperparameters); it describes the
    ``hermite`` expansion only.  New code constructs a ``GPSpec`` and never
    passes an ``FAGPConfig`` to the fit / predict entry points — those
    signatures were removed this release.

    n:          eigenvalues per input dimension (paper's n).
    index_set:  'full' (paper; M = n^p) | 'total_degree' | 'hyperbolic_cross'.
    degree:     truncation parameter for the non-full sets (None = auto).
    block_rows: row-block size for the streaming Gram accumulation.
    store_train: keep (Phi, y) in the state — required for mode='paper'
                 prediction and for the cross-covariance term of Eq. 12.
    """

    n: int
    index_set: IndexSetKind = "full"
    degree: Optional[int] = None
    block_rows: int = 4096
    store_train: bool = True
    backend: str = "jnp"  # 'jnp' | 'pallas' (fused TPU kernels; interpret on CPU)

    def indices(self, p: int) -> np.ndarray:
        return make_index_set(self.index_set, self.n, p, self.degree)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("eps", "rho", "noise", "omega"),
    meta_fields=("n", "index_set", "degree", "block_rows", "store_train",
                 "backend", "expansion", "approximation", "kernel",
                 "neighbors"),
)
@dataclasses.dataclass(frozen=True)
class GPSpec:
    """The one self-describing specification of a GP session.

    Merges the kernel hyperparameters and the static expansion choices so a
    session is described by exactly one object, baked into ``FAGPState`` at
    fit time.

    Pytree layout: ``eps``/``rho``/``noise``/``omega`` are data leaves —
    ``nlml`` is differentiable through them (build the loss with
    ``dataclasses.replace(spec, eps=..., ...)``); everything else is static
    metadata and participates in jit cache keys.

    eps:    per-dimension inverse length scales, shape (p,). Paper's eps_j.
    rho:    per-dimension global scale factors, shape (p,). Paper's rho_j
            (Mercer Gaussian-measure scale; unused by the RFF families).
    noise:  observation noise std sigma_n (scalar).
    omega:  (R, p) eps-free spectral base draws for the RFF expansions
            (None for ``hermite``); drawn once at spec creation and frozen
            into the factorization like any other hyperparameter.
    expansion: registered :class:`~repro.core.expansions.KernelExpansion`
            name ('hermite' | 'rff_se' | 'rff_matern52' | plugins).
    n:      eigenvalues per input dimension (paper's n; hermite only).
    index_set / degree: multi-index truncation (hermite only; see
            ``mercer.make_index_set``).
    block_rows: row-block size for the streaming moment accumulation.
    store_train: keep (Phi, y) in the fitted state (needed for mode='paper').
    backend: execution backend name in the registry ('jnp' | 'pallas').
    approximation: registered approximation family behind the GP facade
            ('fagp' — this module, the paper's decomposed-kernel technique
            — or 'vecchia'; see ``core.approximation``).  The default keeps
            every pre-protocol spec, checkpoint and call site bit-exact.
    kernel / neighbors: the Vecchia family's structure (exact reference
            kernel name 'se' | 'matern52', conditioning-set size k); must
            stay None on 'fagp' specs, whose structure is the expansion.
    """

    eps: jax.Array
    rho: jax.Array
    noise: jax.Array
    n: int
    index_set: IndexSetKind = "full"
    degree: Optional[int] = None
    block_rows: int = 4096
    store_train: bool = False
    backend: str = "jnp"
    expansion: str = "hermite"
    omega: Optional[jax.Array] = None
    approximation: str = "fagp"
    kernel: Optional[str] = None
    neighbors: Optional[int] = None

    @staticmethod
    def create(
        n: int,
        eps,
        rho=2.0,
        noise=1e-2,
        *,
        index_set: IndexSetKind = "full",
        degree: Optional[int] = None,
        block_rows: int = 4096,
        store_train: bool = False,
        backend: str = "jnp",
        expansion: str = "hermite",
        num_features: Optional[int] = None,
        seed: int = 0,
        omega=None,
        approximation: str = "fagp",
        kernel: Optional[str] = None,
        neighbors: Optional[int] = None,
    ) -> "GPSpec":
        """Convenience constructor with scalar broadcasting: ``eps`` fixes
        p, scalars broadcast.  For non-deterministic expansions (the RFF
        families) the spectral base draws are drawn here from
        ``(num_features, seed)`` — or pass ``omega`` explicitly — and ride
        on the spec as a data leaf.  The spec is validated by its
        approximation family HERE (an unknown ``approximation`` name or a
        family-invalid field combination raises at construction, never at
        fit time)."""
        eps = jnp.atleast_1d(jnp.asarray(eps, jnp.float32))
        rho = jnp.broadcast_to(jnp.asarray(rho, jnp.float32), eps.shape)
        if omega is None:
            if num_features is not None and num_features < 1:
                raise ValueError(
                    f"num_features must be >= 1, got {num_features}"
                )
            omega = get_expansion(expansion).draw_spec_data(
                eps.shape[0], 256 if num_features is None else num_features,
                seed,
            )
            if omega is None and num_features is not None:
                # a deterministic expansion silently ignoring num_features
                # almost always means a forgotten expansion= argument
                raise ValueError(
                    f"expansion {expansion!r} draws no spectral data; "
                    f"num_features only applies to the RFF families — did "
                    f"you mean expansion='rff_se' / 'rff_matern52'?"
                )
        elif get_expansion(expansion).draw_spec_data(1, 1, 0) is None:
            raise ValueError(
                f"expansion {expansion!r} takes no omega (it draws no "
                f"spectral data)"
            )
        elif num_features is not None and np.shape(omega)[0] != num_features:
            raise ValueError(
                f"explicit omega has {np.shape(omega)[0]} rows but "
                f"num_features={num_features}"
            )
        spec = GPSpec(
            eps=eps, rho=rho, noise=jnp.asarray(noise, jnp.float32),
            n=int(n), index_set=index_set, degree=degree,
            block_rows=block_rows, store_train=store_train, backend=backend,
            expansion=expansion,
            omega=None if omega is None else jnp.asarray(omega, jnp.float32),
            approximation=approximation, kernel=kernel,
            neighbors=None if neighbors is None else int(neighbors),
        )
        get_approximation(approximation).validate(spec)
        return spec

    @staticmethod
    def create_rff(
        eps,
        noise=1e-2,
        *,
        kernel: str = "se",
        num_features: int = 256,
        seed: int = 0,
        rho=2.0,
        block_rows: int = 4096,
        store_train: bool = False,
        backend: str = "jnp",
    ) -> "GPSpec":
        """Sugar for the RFF families: ``kernel`` is 'se' or 'matern52',
        ``num_features`` is the number R of spectral frequencies (the
        feature count is M = 2R; Monte-Carlo error O(1/sqrt(R)))."""
        return GPSpec.create(
            1, eps, rho, noise, block_rows=block_rows,
            store_train=store_train, backend=backend,
            expansion=f"rff_{kernel}", num_features=num_features, seed=seed,
        )

    @staticmethod
    def create_vecchia(
        eps,
        noise=1e-2,
        *,
        kernel: str = "se",
        neighbors: int = 32,
        rho=2.0,
        block_rows: int = 4096,
        backend: str = "jnp",
    ) -> "GPSpec":
        """Sugar for the Vecchia nearest-neighbor family
        (``core.vecchia``): ``kernel`` names the exact reference oracle
        ('se' | 'matern52'), ``neighbors`` is the conditioning-set size k.
        The expansion fields are inert for this family."""
        return GPSpec.create(
            1, eps, rho, noise, block_rows=block_rows, backend=backend,
            approximation="vecchia", kernel=kernel, neighbors=neighbors,
        )

    @staticmethod
    def from_parts(params: SEKernelParams, cfg: FAGPConfig) -> "GPSpec":
        """Merge a legacy (params, cfg) pair into one (hermite) spec."""
        return GPSpec(
            eps=params.eps, rho=params.rho, noise=params.noise,
            n=cfg.n, index_set=cfg.index_set, degree=cfg.degree,
            block_rows=cfg.block_rows, store_train=cfg.store_train,
            backend=cfg.backend,
        )

    @property
    def p(self) -> int:
        return self.eps.shape[0]

    @property
    def params(self) -> SEKernelParams:
        return SEKernelParams(eps=self.eps, rho=self.rho, noise=self.noise)

    @property
    def cfg(self) -> FAGPConfig:
        return FAGPConfig(
            n=self.n, index_set=self.index_set, degree=self.degree,
            block_rows=self.block_rows, store_train=self.store_train,
            backend=self.backend,
        )

    def indices(self, p: Optional[int] = None) -> np.ndarray:
        """The expansion's static (M, w) index table — its row count is M."""
        return get_expansion(self.expansion).indices(self, p or self.p)

    def n_features(self, p: Optional[int] = None) -> int:
        return self.indices(p).shape[0]

    def replace(self, **overrides) -> "GPSpec":
        return dataclasses.replace(self, **overrides)

    def describe(self) -> str:
        """Short human-readable summary for error messages."""
        if self.approximation != "fagp":
            return (
                f"GPSpec(approximation={self.approximation!r}, "
                f"kernel={self.kernel!r}, neighbors={self.neighbors}, "
                f"p={self.p}, backend={self.backend!r})"
            )
        extra = (
            f"n={self.n}, index_set={self.index_set!r}, degree={self.degree}"
            if self.expansion == "hermite"
            else f"R={0 if self.omega is None else np.shape(self.omega)[0]}"
        )
        return (
            f"GPSpec(expansion={self.expansion!r}, {extra}, p={self.p}, "
            f"backend={self.backend!r}, store_train={self.store_train})"
        )


# spec fields frozen into the factorization: with_spec calls may not change
# these on a fitted state (idx, lam, chol all depend on them; for vecchia
# the kernel/neighbor structure likewise defines the session)
_STRUCTURAL_FIELDS = ("approximation", "expansion", "n", "index_set",
                      "degree", "kernel", "neighbors")
_HYPER_FIELDS = ("eps", "rho", "noise", "omega")


def _leaf_equal(a, b) -> bool:
    if a is None or b is None:
        return a is None and b is None
    a, b = np.asarray(a), np.asarray(b)
    # All device math is f32: a python-float leaf (f64 on the host, e.g.
    # noise=0.1) and its f32 device/checkpoint round-trip are the same
    # hyperparameter, so compare in the compute dtype.
    if a.dtype == np.float64:
        a = a.astype(np.float32)
    if b.dtype == np.float64:
        b = b.astype(np.float32)
    return a.shape == b.shape and np.array_equal(a, b)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FAGPState:
    """Fitted FAGP sufficient statistics (scaled-system form).

    Self-describing: ``spec`` carries everything a consumer needs to derive
    features, backend and block sizes — no call site re-passes configuration.
    """

    idx: jax.Array            # (M, w) expansion index table (static content)
    lam: jax.Array            # (M,)   expansion weights (may underflow; info only)
    sqrtlam: jax.Array        # (M,)   exp(0.5 log lambda) — the scaling D
    chol: jax.Array           # (M, M) lower Cholesky of B = I + D G D / sigma^2
    u: jax.Array              # (M,) or (M, T) mean weights Lbar^{-1} Phi^T y / sigma^2
    params: SEKernelParams
    Phi: Optional[jax.Array]  # (N, M) train features   (store_train only)
    y: Optional[jax.Array]    # (N,) or (N, T) train targets (store_train only)
    b: Optional[jax.Array] = None    # (M,) / (M, T) raw moment Phi^T y — fit_update
    spec: Optional[GPSpec] = None    # baked at fit time; None only on internal states

    @property
    def n_features(self) -> int:
        return self.idx.shape[0]

    @property
    def n_tasks(self) -> int:
        return 1 if self.u.ndim == 1 else self.u.shape[1]

    def with_spec(self, spec: Optional[GPSpec] = None, **overrides) -> "FAGPState":
        """Escape hatch: swap execution knobs (backend, block_rows) at serve
        time, or attach a spec to an internal spec-less state.

        Validates that the requested spec regenerates *exactly* the index
        table and hyperparameters this state was factorized with —
        structural changes (expansion, n, index_set, degree, eps, rho,
        noise, omega) are rejected because chol/u/lam are frozen functions
        of them.
        """
        if spec is None:
            if self.spec is None:
                raise ValueError(
                    "state has no baked spec to override; pass a full GPSpec: "
                    "state.with_spec(spec)"
                )
            spec = dataclasses.replace(self.spec, **overrides)
        elif overrides:
            raise TypeError("pass either a full spec or keyword overrides, not both")

        if self.spec is not None:
            for f in _STRUCTURAL_FIELDS:
                if getattr(spec, f) != getattr(self.spec, f):
                    raise ValueError(
                        f"spec/state mismatch: state was fitted with "
                        f"{self.spec.describe()} but the new spec has "
                        f"{f}={getattr(spec, f)!r}; structural choices are "
                        f"frozen into the factorization — refit instead"
                    )
        _check_spec_regenerates_idx(self, spec)
        _check_hypers_match(self, spec, "with_spec")
        if spec.store_train and self.Phi is None:
            raise ValueError(
                "with_spec cannot enable store_train on an already-fitted state "
                "(the training features were never stored); refit with "
                "store_train=True"
            )
        _check_backend_support(spec)
        return dataclasses.replace(self, spec=spec, params=spec.params)


def _check_hypers_match(state: "FAGPState", spec: "GPSpec", who: str) -> None:
    """Raise unless ``spec`` carries exactly the hyperparameter leaves
    (eps/rho/noise, plus any RFF spectral draws) the state was factorized
    with — the data half of every spec/state compatibility check (shared by
    ``FAGPState.with_spec`` and the bank's membership validation)."""
    for f in _HYPER_FIELDS:
        # spec-less states carry no omega record, so they compare as None:
        # a spec WITH spectral draws can never attach to one (we could not
        # verify the draws match the factorization), which also blocks the
        # cross-family aliasing where an RFF arange(2R) index table happens
        # to equal a 1-D hermite grid
        have = (
            getattr(state.spec, f) if state.spec is not None
            else getattr(state.params, f, None)
        )
        if not _leaf_equal(getattr(spec, f), have):
            raise ValueError(
                f"{who}: spec/state mismatch: {f} differs from the value "
                f"this state was fitted with; hyperparameters are frozen "
                f"into the factorization — refit (or fit_update) instead"
            )


def _check_spec_regenerates_idx(state: "FAGPState", spec: "GPSpec") -> None:
    """Raise unless ``spec`` regenerates exactly the index table baked into
    the state — the structural half of every spec/state compatibility
    check."""
    idx_np = np.asarray(state.idx)
    want = spec.indices()
    if want.shape != idx_np.shape or not np.array_equal(want, idx_np):
        fitted = state.spec.describe() if state.spec is not None else (
            f"an index table of shape {idx_np.shape}"
        )
        raise ValueError(
            f"spec/state mismatch: this state was fitted with {fitted}, but "
            f"{spec.describe()} generates a different index table; the "
            f"expansion structure is frozen into the factorization — refit "
            f"instead"
        )


def build_features(X: jax.Array, spec: GPSpec,
                   idx: Optional[jax.Array] = None) -> jax.Array:
    """Phi_(X) under the spec's expansion (jnp reference path).
    (N, p) -> (N, M).  ``idx`` defaults to the spec's own index table."""
    if idx is None:
        idx = jnp.asarray(spec.indices())
    return get_expansion(spec.expansion).features(X, idx, spec)


def _features(X: jax.Array, idx: jax.Array, spec: GPSpec) -> jax.Array:
    return get_expansion(spec.expansion).features(X, idx, spec)


def _tscale(d: jax.Array, v: jax.Array) -> jax.Array:
    """Scale the leading (M) axis of v by d, for v of shape (M,) or (M, T)."""
    return d[:, None] * v if v.ndim == 2 else d * v


def _row_weight(mi: jax.Array, v: jax.Array) -> jax.Array:
    """Apply a per-row mask/weight mi (N,) to v of shape (N,) or (N, T)."""
    return mi[:, None] * v if v.ndim == 2 else mi * v


def _assemble_scaled_system(G: jax.Array, loglam: jax.Array, sig2) -> tuple:
    """The single home of the f32 log-space scaled system (shared by fit,
    nlml and the distributed schedules):

        B = I + D G D / sigma^2,      D = diag(exp(0.5 log lambda))

    Returns (B, sqrtlam).  Assembling from log eigenvalues keeps columns
    whose lambda underflows f32 as inert identity rows instead of NaNs.
    """
    M = G.shape[0]
    sqrtlam = jnp.exp(0.5 * loglam)
    B = jnp.eye(M, dtype=G.dtype) + (sqrtlam[:, None] * G * sqrtlam[None, :]) / sig2
    return B, sqrtlam


def _solve_mean_weights(chol, sqrtlam, b, sig2):
    """u = Lbar^{-1} b / sig2 = D B^{-1} D b / sig2, batched over task
    columns when b is (M, T) — the T tasks share the one Cholesky factor."""
    return _tscale(
        sqrtlam, jax.scipy.linalg.cho_solve((chol, True), _tscale(sqrtlam, b))
    ) / sig2


def _block_scan_moments(X, y, feats_fn, M: int, block_rows: int,
                        row_mask=None, want_gram: bool = True):
    """The one home of the streaming row-block scaffolding (pad, reshape,
    mask, scan): G = Phi^T Phi and b = Phi^T y accumulated block by block,
    O(M^2) live memory.  ``feats_fn(Xi) -> (block, M)`` supplies the feature
    tiles (jnp reference or a Pallas kernel); ``want_gram=False`` skips the
    Gram GEMM when only b is needed.  y may be (N,) or (N, T)."""
    N = X.shape[0]
    nblk = max(1, (N + block_rows - 1) // block_rows)
    pad = nblk * block_rows - N
    Xp = jnp.pad(X, ((0, pad), (0, 0)))
    yp = jnp.pad(y, ((0, pad),) + ((0, 0),) * (y.ndim - 1))
    valid = jnp.ones((N,), X.dtype) if row_mask is None else row_mask.astype(X.dtype)
    mask = jnp.pad(valid, (0, pad))

    Xb = Xp.reshape(nblk, block_rows, -1)
    yb = yp.reshape((nblk, block_rows) + y.shape[1:])
    mb = mask.reshape(nblk, block_rows)

    def step(carry, blk):
        G, b = carry
        Xi, yi, mi = blk
        Phi_i = feats_fn(Xi) * mi[:, None]
        if want_gram:
            G = G + Phi_i.T @ Phi_i
        b = b + Phi_i.T @ _row_weight(mi, yi)
        return (G, b), None

    init = (jnp.zeros((M, M), X.dtype), jnp.zeros((M,) + y.shape[1:], X.dtype))
    (G, b), _ = jax.lax.scan(step, init, (Xb, yb, mb))
    return G, b


def _accumulate_moments(X, y, spec, idx, block_rows: int, row_mask=None):
    """Streaming G = Phi^T Phi, b = Phi^T y over row blocks (O(M^2) memory),
    under the spec's expansion.

    y may be (N,) or multi-output (N, T); b comes back (M,) or (M, T)."""
    return _block_scan_moments(
        X, y, lambda Xi: _features(Xi, idx, spec),
        idx.shape[0], block_rows, row_mask=row_mask,
    )


def _finish_fit(B, b, loglam, sqrtlam, sig2, idx, params, Phi, y):
    """Shared fit epilogue: M x M Cholesky solve -> FAGPState."""
    chol = jnp.linalg.cholesky(B)
    u = _solve_mean_weights(chol, sqrtlam, b, sig2)
    return FAGPState(
        idx=idx, lam=jnp.exp(loglam), sqrtlam=sqrtlam, chol=chol, u=u,
        params=params, Phi=Phi, y=y, b=b,
    )


@jax.jit
def _fit(X, y, spec: GPSpec, idx):
    """jnp-backend fit: the spec's static metadata keys the jit cache, its
    data leaves (eps/rho/noise/omega) are traced."""
    exp = get_expansion(spec.expansion)
    sig2 = spec.noise**2
    loglam = exp.log_eigenvalues(idx, spec)
    G, b = _accumulate_moments(X, y, spec, idx, spec.block_rows)
    B, sqrtlam = _assemble_scaled_system(G, loglam, sig2)
    Phi = _features(X, idx, spec) if spec.store_train else None
    return _finish_fit(B, b, loglam, sqrtlam, sig2, idx, spec.params,
                       Phi, y if spec.store_train else None)


def _pallas_streamed_bt(X, Y, consts, table, spec, tile):
    """Per-task moment vectors b = Phi^T Y for multi-output fits on the
    Pallas backend: feature tiles come from the expansion kernel one row
    block at a time, so only a (block_rows, M) tile is ever live."""
    from repro.kernels import ops as kops

    _, b = _block_scan_moments(
        X, Y,
        lambda Xi: kops.expansion_phi(Xi, consts, table, n_max=spec.n,
                                      tile_fn=tile),
        table.shape[1], spec.block_rows, want_gram=False,
    )
    return b


@jax.jit
def _fit_pallas(X, y, spec: GPSpec, idx, aux):
    """fit() on the streaming fused Pallas kernel: feature tiles are
    generated on the fly inside the Gram accumulation (kernels/phi_gram) by
    the expansion's tile builder, so Phi never exists in HBM and peak live
    memory is O(M^2) in N — one HBM pass over X instead of the materialized
    path's two passes plus an N x M intermediate.  (store_train=True
    additionally materializes Phi for mode='paper' prediction,
    reintroducing the N x M buffer by request.)

    Multi-output y (N, T): the shared scaled Gram B comes from the fused
    kernel exactly as in the single-output case; the per-task moment vectors
    are streamed block-wise through the expansion feature kernel.  Known
    cost: this is a SECOND pass over X that regenerates the feature tiles
    (still O(M T) live memory, never an N x M buffer) — teaching phi_gram
    to accumulate (M, T) moments in its one pass is the planned follow-up."""
    from repro.kernels import ops as kops

    exp = get_expansion(spec.expansion)
    sig2 = spec.noise**2
    loglam = exp.log_eigenvalues(idx, spec)
    sqrtlam = jnp.exp(0.5 * loglam)
    consts = exp.tile_consts(spec)
    table = exp.tile_table(aux, spec)
    tile = exp.tile_fn()
    y0 = y if y.ndim == 1 else y[:, 0]
    B, b = kops.fused_fit_moments(X, y0, consts, table, sqrtlam, sig2,
                                  n_max=spec.n, tile_fn=tile)
    if y.ndim == 2:
        b = _pallas_streamed_bt(X, y, consts, table, spec, tile)
    Phi = (kops.expansion_phi(X, consts, table, n_max=spec.n, tile_fn=tile)
           if spec.store_train else None)
    return _finish_fit(B, b, loglam, sqrtlam, sig2, idx, spec.params,
                       Phi, y if spec.store_train else None)


# ---------------------------------------------------------------------------
# Backend registry — capability-declaring plugins, one dispatch point shared
# by fit / predict_mean_var / core.distributed (per-shard moments).  A new
# execution backend plugs in by registering one FitBackend; ``supports``
# lets it refuse specs it cannot run with a clear error at the call boundary
# instead of crashing deep inside ``prepare`` or a kernel launch.
# ---------------------------------------------------------------------------


def _supports_everything(spec: "GPSpec") -> Optional[str]:
    return None


@dataclasses.dataclass(frozen=True)
class FitBackend:
    """Execution backend for the FAGP hot paths.  Every hook receives the
    session's ``GPSpec`` and resolves the feature map through the expansion
    registry — backends execute, expansions define the math.

    prepare:  (idx_np, spec) -> static auxiliary carried to every call
              (e.g. the Hermite one-hot selection for the Pallas kernels);
              None if unused.
    fit:      (X, y, idx, aux, spec) -> FAGPState (spec baked by the caller).
    features: (X, spec, idx, aux) -> (N, M) feature matrix.
    mean_var: (state, Xs, aux) -> (mu, var), the serving path.
    moments:  (X, y, spec, idx, aux, block_rows, mask) -> (G, b)
              raw sufficient statistics — the per-shard unit of work for
              core.distributed (partial sums, psum'd before the solve).
    supports: (spec) -> None if the backend can run the spec, else a short
              reason string surfaced in the ValueError raised at dispatch.

    Bank hooks (the multi-tenant fleet path, ``repro.bank.GPBank``) — both
    optional; ``bank.GPBank`` falls back to a vmap of the single-model
    entry points when a backend leaves them None:

    bank_moments:  (Xb (B,N,p), yb (B,N), spec, idx, aux,
                   block_rows, maskb (B,N)) -> (G (B,M,M), b (B,M)) — raw
                   fit moments for B independent datasets in one batched
                   call; per-slot row masks express ragged per-tenant N.
    bank_mean_var: (stack, binv (C,M,M), slots (Q,), Xq (Q,p), aux)
                   -> (mu, var) for a mixed-tenant query batch against a
                   stacked FAGPState (leading bank axis on
                   chol/u/b/lam/sqrtlam); ``binv`` is the per-slot B^{-1}
                   serving cache (``_bank_binv``), recomputed by GPBank
                   only when the stack changes.
    """

    name: str
    prepare: Callable[[np.ndarray, "GPSpec"], Any]
    fit: Callable[..., "FAGPState"]
    features: Callable[..., jax.Array]
    mean_var: Callable[..., tuple]
    moments: Callable[..., tuple]
    supports: Callable[["GPSpec"], Optional[str]] = _supports_everything
    bank_moments: Optional[Callable[..., tuple]] = None
    bank_mean_var: Optional[Callable[..., tuple]] = None


_BACKENDS: dict[str, FitBackend] = {}


def register_backend(backend: FitBackend) -> None:
    _BACKENDS[backend.name] = backend


def get_backend(name: str) -> FitBackend:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered: {available_backends()}"
        ) from None


def available_backends() -> list[str]:
    return sorted(_BACKENDS)


def _check_backend_support(spec: "GPSpec") -> FitBackend:
    """Resolve spec.expansion and spec.backend, validate the spec against
    the expansion, and enforce the backend's declared capabilities.

    Refusals are the structured :class:`UnsupportedError` shared with the
    approximation capability flags: a backend declining a spec (e.g. the
    pallas Hermite recurrence depth limit) raises with ``layer="backend"``
    and ``capability=spec.backend``; a non-FAGP spec reaching these entry
    points at all raises with ``layer="approximation"`` (route through
    ``core.gp.GP``, which dispatches by ``spec.approximation``)."""
    if spec.approximation != "fagp":
        raise UnsupportedError(
            f"the fagp module does not support {spec.describe()}: its "
            f"entry points run the 'fagp' family only — dispatch through "
            f"repro.core.gp.GP, which routes by spec.approximation",
            layer="approximation", capability="fagp", spec=spec,
        )
    get_expansion(spec.expansion).validate(spec)
    backend = get_backend(spec.backend)
    reason = backend.supports(spec)
    if reason is not None:
        raise UnsupportedError(
            f"backend {spec.backend!r} does not support {spec.describe()}: "
            f"{reason} (registered backends: {available_backends()})",
            layer="backend", capability=spec.backend, spec=spec,
        )
    return backend


# prepare() results memoized per (idx array, backend, expansion, n):
# predict_mean_var / fit_update sit on the serving hot path, and rebuilding
# the one-hot selection matrix (plus the blocking device->host idx copy) per
# microbatch is pure waste.  Keyed by id() and validated by weakref so a
# recycled id can never alias a dead array.
_AUX_CACHE: dict = {}


def _backend_aux(backend: FitBackend, idx: jax.Array, spec: "GPSpec"):
    import weakref

    key = (id(idx), backend.name, spec.expansion, spec.n)
    hit = _AUX_CACHE.get(key)
    if hit is not None and hit[0]() is idx:
        return hit[1]
    aux = backend.prepare(np.asarray(idx), spec)
    try:
        ref = weakref.ref(idx)
    except TypeError:
        return aux
    if len(_AUX_CACHE) > 64:
        _AUX_CACHE.clear()
    _AUX_CACHE[key] = (ref, aux)
    return aux


# --- jnp backend (scan-streamed, pure XLA) ---------------------------------


@jax.jit
def _features_jit(X, spec: GPSpec, idx):
    return _features(X, idx, spec)


def _jnp_features(X, spec, idx, aux):
    return _features_jit(X, spec, idx)


def _jnp_moments(X, y, spec, idx, aux, block_rows, mask=None):
    return _jnp_moments_jit(X, y, spec, idx, block_rows, mask)


@partial(jax.jit, static_argnames=("block_rows",))
def _jnp_moments_jit(X, y, spec, idx, block_rows, mask):
    return _accumulate_moments(X, y, spec, idx, block_rows, row_mask=mask)


def _jnp_fit(X, y, idx, aux, spec: "GPSpec"):
    return _fit(X, y, spec, idx)


def _jnp_mean_var(state, Xs, aux):
    return _mean_var_jnp(state, Xs)


# --- bank (multi-tenant) hooks ---------------------------------------------
# One stacked FAGPState holds B independent fitted sessions (leading bank
# axis on chol/u/b/lam/sqrtlam; idx/params/spec shared).  ``bank_moments``
# computes B fits' sufficient statistics in one batched call;
# ``bank_mean_var`` answers one padded mixed-tenant query batch by gathering
# each query row's slot state — both are single compiled executables
# regardless of how many tenants are in flight (see repro.bank).


@jax.jit
def _bank_binv(chol_s):
    """Per-slot B^{-1} (C, M, M) from the stacked Cholesky factors — the
    bank's serving cache.  Computed once per bank *version* (GPBank caches
    it until the next fit/update/insert/evict), so the per-query serving
    path below is pure gather + GEMV instead of Q tiny triangular solves
    (which are dispatch-bound: one LAPACK call per query row)."""
    M = chol_s.shape[-1]
    eye = jnp.eye(M, dtype=chol_s.dtype)
    return jax.vmap(lambda c: jax.scipy.linalg.cho_solve((c, True), eye))(
        chol_s
    )


@jax.jit
def _bank_gathered_posterior(binv_s, u_s, sqrtlam_s, slots, Phis):
    """Mixed-tenant posterior from a stacked state: query row q reads slot
    ``slots[q]``.  Shared by every backend's bank_mean_var — only the
    feature construction differs.  binv_s (C,M,M) from ``_bank_binv``,
    u_s (C,M), sqrtlam_s (C,M), slots (Q,), Phis (Q,M)
    -> (mu (Q,), var (Q,))."""
    mu = jnp.sum(Phis * u_s[slots], axis=1)
    PhisD = Phis * sqrtlam_s[slots]                      # (Q, M)
    var = jnp.einsum("qm,qmn,qn->q", PhisD, binv_s[slots], PhisD)
    return mu, var


@partial(jax.jit, static_argnames=("block_rows",))
def _jnp_bank_moments_jit(Xb, yb, spec, idx, block_rows, maskb):
    f = lambda X, y, m: _accumulate_moments(
        X, y, spec, idx, block_rows, row_mask=m
    )
    return jax.vmap(f)(Xb, yb, maskb)


def _jnp_bank_moments(Xb, yb, spec, idx, aux, block_rows, maskb=None):
    if maskb is None:
        maskb = jnp.ones(Xb.shape[:2], Xb.dtype)
    # banks hold SMALL tenants: never let the scan pad a slot's few rows up
    # to the default serving block (the pallas path clamps block_k likewise)
    block_rows = min(block_rows, max(1, Xb.shape[1]))
    return _jnp_bank_moments_jit(Xb, yb, spec, idx, block_rows, maskb)


def _gathered_bank_mean_var(features):
    """Build a ``bank_mean_var`` from a backend's feature map: the gathered
    serving path is backend-independent (one home, above) — only the
    feature construction differs.  Used for both built-in backends and as
    the fallback for third-party backends that declare no bank hooks."""
    def f(stack, binv, slots, Xq, aux):
        Phis = features(Xq, stack.spec, stack.idx, aux)
        return _bank_gathered_posterior(
            binv, stack.u, stack.sqrtlam, slots, Phis
        )
    return f


# --- pallas backend (fused TPU kernels; interpret mode on CPU) -------------


def _pallas_supports(spec: "GPSpec") -> Optional[str]:
    # the expansion owns the tile builder, so it owns the capability answer
    # (Hermite: unrolled recurrence depth; RFF: anything goes)
    return get_expansion(spec.expansion).pallas_supports(spec)


def _pallas_prepare(idx_np: np.ndarray, spec: "GPSpec"):
    return get_expansion(spec.expansion).pallas_prepare(idx_np, spec)


def _pallas_features(X, spec, idx, aux):
    from repro.kernels import ops as kops

    exp = get_expansion(spec.expansion)
    return kops.expansion_phi(
        X, exp.tile_consts(spec), exp.tile_table(aux, spec),
        n_max=spec.n, tile_fn=exp.tile_fn(),
    )


def _pallas_moments(X, y, spec, idx, aux, block_rows, mask=None):
    from repro.kernels import ops as kops

    exp = get_expansion(spec.expansion)
    ones = jnp.ones((idx.shape[0],), jnp.float32)
    return kops.fused_fit_moments(
        X, y, exp.tile_consts(spec), exp.tile_table(aux, spec), ones,
        jnp.float32(1.0), mask, n_max=spec.n, scale=False,
        tile_fn=exp.tile_fn(),
    )


def _pallas_fit(X, y, idx, aux, spec: "GPSpec"):
    return _fit_pallas(X, y, spec, idx, aux)


def _pallas_mean_var(state, Xs, aux):
    return _mean_var_pallas(state, Xs, aux)


def _pallas_bank_moments(Xb, yb, spec, idx, aux, block_rows, maskb=None):
    """One kernel launch for the whole bank: the bank axis is a leading
    grid dimension of the streaming fused kernel, so feature tiles for
    different tenants are generated in VMEM tile-by-tile — B separate
    N x M Phis never materialize (kernels/phi_gram.bank_phi_gram_kernel),
    whichever expansion the bank's shared spec names."""
    from repro.kernels import ops as kops

    exp = get_expansion(spec.expansion)
    return kops.bank_fused_fit_moments(
        Xb, yb, exp.tile_consts(spec), exp.tile_table(aux, spec), maskb,
        n_max=spec.n, tile_fn=exp.tile_fn(),
    )


register_backend(FitBackend(
    name="jnp", prepare=lambda idx_np, spec: None, fit=_jnp_fit,
    features=_jnp_features, mean_var=_jnp_mean_var, moments=_jnp_moments,
    bank_moments=_jnp_bank_moments,
    bank_mean_var=_gathered_bank_mean_var(_jnp_features),
))
register_backend(FitBackend(
    name="pallas", prepare=_pallas_prepare, fit=_pallas_fit,
    features=_pallas_features, mean_var=_pallas_mean_var,
    moments=_pallas_moments, supports=_pallas_supports,
    bank_moments=_pallas_bank_moments,
    bank_mean_var=_gathered_bank_mean_var(_pallas_features),
))


# ---------------------------------------------------------------------------
# Public entry points — spec-first.  The split (params, cfg) signatures were
# deprecated for two releases and now raise TypeError.
# ---------------------------------------------------------------------------


def _check_p(spec: GPSpec, p: int) -> None:
    if spec.p != p:
        raise ValueError(
            f"spec/input mismatch: {spec.describe()} was built for p={spec.p} "
            f"input dimensions but the data has p={p}"
        )


def fit(X: jax.Array, y: jax.Array, spec: GPSpec, cfg: Any = None) -> FAGPState:
    """Fit the FAGP posterior; the spec is baked into the returned state.

    y: (N,) targets, or (N, T) for T tasks sharing one factorization.
    """
    if cfg is not None or not isinstance(spec, GPSpec):
        _removed(
            "fit(X, y, params, cfg)",
            "merge them with GPSpec.from_parts(params, cfg) and call "
            "fit(X, y, spec)",
        )
    _check_p(spec, X.shape[1])
    backend = _check_backend_support(spec)
    idx_np = spec.indices(X.shape[1])
    idx = jnp.asarray(idx_np)
    aux = backend.prepare(idx_np, spec)
    state = backend.fit(X, y, idx, aux, spec)
    return dataclasses.replace(state, spec=spec)


def _require_spec(state: FAGPState, call: str) -> GPSpec:
    """Derive the session spec from the state (the only source of truth now
    that the deprecated cfg re-passing was removed)."""
    if state.spec is None:
        raise ValueError(
            f"this state has no baked GPSpec (produced by an internal "
            f"path); attach one with state.with_spec(spec) before calling "
            f"{call}"
        )
    return state.spec


# ---------------------------------------------------------------------------
# Online incremental fitting (rank-k update of the scaled system)
# ---------------------------------------------------------------------------


def _chol_rank1_update(L: jax.Array, w: jax.Array) -> jax.Array:
    """Cholesky of L L^T + w w^T, O(M^2) (LINPACK positive-update sweep).

    Column-sequential Givens-style sweep expressed as a scan with masked
    whole-column updates; additions are always well-posed (no downdates)."""
    M = L.shape[0]
    ar = jnp.arange(M)

    def step(carry, k):
        L, w = carry
        Lkk = L[k, k]
        wk = w[k]
        r = jnp.sqrt(Lkk * Lkk + wk * wk)
        c = r / Lkk
        s = wk / Lkk
        col = L[:, k]
        below = ar > k
        newcol = jnp.where(below, (col + s * w) / c, col).at[k].set(r)
        w = jnp.where(below, c * w - s * newcol, w)
        return (L.at[:, k].set(newcol), w), None

    (L, _), _ = jax.lax.scan(step, (L, w), ar)
    return L


def _update_arrays(chol, b, sqrtlam, noise, Phi_new, y_new):
    """Array-level rank-K update core: (chol, b) -> (chol', b', u').

    Shared by the single-session ``fit_update`` and the bank's batched
    update (``repro.bank``, vmapped over slots — every op here batches)."""
    sig2 = noise**2
    # B_new = B + sum_k v_k v_k^T,  v_k = D phi_k / sigma  (rank-K update)
    W = Phi_new * sqrtlam[None, :] / noise
    K, M = W.shape
    if K * 8 <= M:
        # small K: sequential rank-1 sweeps, O(K M^2), beats refactorization
        chol, _ = jax.lax.scan(
            lambda L, w: (_chol_rank1_update(L, w), None), chol, W
        )
    else:
        # K comparable to M: the rank-1 sweep is K*M sequential latency-bound
        # steps; rebuilding the M x M factor is O(M^3/3) fully-parallel work
        # and still never touches the original N rows
        B = chol @ chol.T + W.T @ W
        chol = jnp.linalg.cholesky(B)
    b = b + Phi_new.T @ y_new
    u = _solve_mean_weights(chol, sqrtlam, b, sig2)
    return chol, b, u


@jax.jit
def _update_state(state: FAGPState, Phi_new: jax.Array, y_new: jax.Array):
    return _update_arrays(state.chol, state.b, state.sqrtlam,
                          state.params.noise, Phi_new, y_new)


def fit_update(
    state: FAGPState, X_new: jax.Array, y_new: jax.Array, cfg: Any = None,
) -> FAGPState:
    """Absorb new observations into a fitted state without refitting.

    Rank-k Cholesky update of B (O(k M^2)) plus a fresh M x M solve for the
    mean weights — no pass over the original N rows, so the serving loop can
    ingest observation microbatches at O(M^2) cost each (vs O(N M^2) refit).
    Exactly equivalent to refitting on the concatenated data (same math, up
    to f32 rounding); tests pin update-then-predict == refit-then-predict.

    Everything (expansion, backend, block size) derives from the baked spec.
    """
    if cfg is not None:
        _removed(
            "fit_update(state, X_new, y_new, cfg)",
            "the spec is baked into the state — drop the cfg",
        )
    if state.b is None:
        raise ValueError("fit_update needs a state produced by fit() >= this "
                         "version (missing the raw moment vector b)")
    if y_new.ndim != state.u.ndim or (
        y_new.ndim == 2 and y_new.shape[1] != state.u.shape[1]
    ):
        raise ValueError(
            f"fit_update task mismatch: state holds "
            f"{state.n_tasks} task(s) but y_new has shape {y_new.shape}"
        )
    spec = _require_spec(state, "fit_update(state, X_new, y_new)")
    backend = _check_backend_support(spec)
    aux = _backend_aux(backend, state.idx, spec)
    Phi_new = backend.features(X_new, spec, state.idx, aux)
    chol, b, u = _update_state(state, Phi_new, y_new)
    Phi = y = None
    if state.Phi is not None:
        Phi = jnp.concatenate([state.Phi, Phi_new], axis=0)
        y = jnp.concatenate([state.y, y_new], axis=0)
    return dataclasses.replace(state, chol=chol, b=b, u=u, Phi=Phi, y=y)


# ---------------------------------------------------------------------------
# Prediction
# ---------------------------------------------------------------------------


@jax.jit
def _predict_fused(state: FAGPState, Xs: jax.Array):
    """Beyond-paper weight-space path: no N-sized intermediates.

    Phi* Lbar^{-1} Phi*^T = (Phi* D) B^{-1} (Phi* D)^T via triangular solve.
    """
    Phis = _features(Xs, state.idx, state.spec)  # (N*, M)
    mu = Phis @ state.u
    PhisD = Phis * state.sqrtlam[None, :]
    V = jax.scipy.linalg.solve_triangular(state.chol, PhisD.T, lower=True)  # (M, N*)
    cov = V.T @ V
    return mu, cov


@jax.jit
def _predict_paper(state: FAGPState, Xs: jax.Array):
    """Literal Eqs. 11-12 GEMM chain in the paper's operation order.

    Requires a state fitted with store_train=True.  Forms the N x N
    approximate inverse (Sigma_n^{-1} - Sigma_n^{-1} Phi Lbar^{-1} Phi^T
    Sigma_n^{-1}) exactly as the CUDA implementation does, then W (N* x N),
    then mu*, Sigma*.
    """
    Phi, y = state.Phi, state.y
    N = Phi.shape[0]
    sig2 = state.params.noise**2
    Phis = _features(Xs, state.idx, state.spec)                 # (N*, M)
    Lam = state.lam                                             # (M,)

    D = state.sqrtlam
    LbarinvPhiT = D[:, None] * jax.scipy.linalg.cho_solve(
        (state.chol, True), D[:, None] * Phi.T
    )  # Lbar^{-1} Phi^T = D B^{-1} D Phi^T,  (M, N)
    Kinv = jnp.eye(N, dtype=Phi.dtype) / sig2 - (Phi @ LbarinvPhiT) / (sig2 * sig2)
    PhisLam = Phis * Lam[None, :]                               # Phi* Lambda
    W = (PhisLam @ Phi.T) @ Kinv                                # (N*, N) — Eq. 11's W
    mu = W @ y
    cov = PhisLam @ Phis.T - (W @ Phi) @ (Lam[:, None] * Phis.T)  # Eq. 12
    return mu, cov


def predict(state: FAGPState, Xs: jax.Array, cfg: Any = None,
            mode: str = "fused"):
    """Posterior mean and covariance (N*, N*) at Xs.

    Mean is (N*,) or (N*, T) for multi-output states; the covariance is
    shared across tasks (one kernel, one noise level).  Everything derives
    from the spec baked into the state.
    """
    if cfg is not None:
        _removed(
            "predict(state, Xs, cfg)",
            "the spec is baked into the state — drop the cfg",
        )
    spec = _require_spec(state, "predict(state, Xs)")
    if mode == "fused":
        return _predict_fused(state, Xs)
    if mode == "paper":
        if state.Phi is None:
            raise ValueError(
                f"mode='paper' needs the training features stored in the "
                f"fitted state, but this state was fitted with "
                f"{spec.replace(store_train=False).describe()} — refit with a "
                f"spec that sets store_train=True"
            )
        return _predict_paper(state, Xs)
    raise ValueError(f"unknown mode {mode!r}")


@jax.jit
def _mean_var_pallas(state: FAGPState, Xs, aux):
    from repro.kernels import ops as kops

    spec = state.spec
    exp = get_expansion(spec.expansion)
    Phis = kops.expansion_phi(
        Xs, exp.tile_consts(spec), exp.tile_table(aux, spec),
        n_max=spec.n, tile_fn=exp.tile_fn(),
    )
    mu = Phis @ state.u
    M = state.chol.shape[0]
    Binv = jax.scipy.linalg.cho_solve((state.chol, True), jnp.eye(M, dtype=Phis.dtype))
    var = kops.diag_quad(Phis * state.sqrtlam[None, :], Binv)
    return mu, var


@jax.jit
def _mean_var_jnp(state: FAGPState, Xs):
    Phis = _features(Xs, state.idx, state.spec)
    mu = Phis @ state.u
    PhisD = Phis * state.sqrtlam[None, :]
    V = jax.scipy.linalg.solve_triangular(state.chol, PhisD.T, lower=True)
    return mu, jnp.sum(V * V, axis=0)


def predict_mean_var(state: FAGPState, Xs: jax.Array, cfg: Any = None):
    """Posterior mean and *marginal variance* (N*,) — the production serving
    path: never materializes the N* x N* covariance (kernels/diag_quad).

    Mean is (N*,) or (N*, T) for multi-output states; the variance is shared
    across tasks.  Expansion, backend and n_max derive from the baked spec."""
    if cfg is not None:
        _removed(
            "predict_mean_var(state, Xs, cfg)",
            "the spec is baked into the state — drop the cfg",
        )
    spec = _require_spec(state, "predict_mean_var(state, Xs)")
    backend = _check_backend_support(spec)
    aux = _backend_aux(backend, state.idx, spec)
    return backend.mean_var(state, Xs, aux)


# ---------------------------------------------------------------------------
# Negative log marginal likelihood (paper's declared future work)
#
# The NLML path runs through the backend registry's ``moments`` hooks — the
# same per-shard unit of work core.distributed sums — so evaluating (and
# optimizing) the marginal likelihood never materializes the N x M feature
# matrix on EITHER backend: the pallas hook streams tiles through the fused
# kernel, the jnp hook scans row blocks.  The hooks themselves are not
# differentiable (the pallas kernel has no AD rule), so the moments are
# wrapped in a custom VJP whose backward pass is the streamed jnp block
# scan differentiated through the expansion's feature map — also O(M^2)
# live memory (pinned by the jaxpr sweep in tests/test_gp_hyperopt.py).
# ---------------------------------------------------------------------------


def _moments_via_registry(spec: GPSpec, X, y, mask):
    """Raw (G, b) = (Phi^T Phi, Phi^T y) over the masked rows, dispatched
    through ``spec.backend``'s moments hook (value path; see
    ``_moments_diff`` for the differentiable wrapper)."""
    backend = get_backend(spec.backend)
    idx_np = spec.indices(X.shape[1])
    aux = backend.prepare(idx_np, spec)
    # never let the scan pad a small problem's rows up to the serving block
    block_rows = min(spec.block_rows, max(1, X.shape[0]))
    return backend.moments(X, y, spec, jnp.asarray(idx_np), aux,
                           block_rows, mask)


@jax.custom_vjp
def _moments_diff(spec: GPSpec, X, y, mask):
    return _moments_via_registry(spec, X, y, mask)


def _moments_diff_fwd(spec, X, y, mask):
    return _moments_via_registry(spec, X, y, mask), (spec, X, y, mask)


def _moments_diff_bwd(res, ct):
    """Streamed VJP into EVERY primal input — the spec's data leaves
    (eps/rho/noise/omega) AND the data (X, y, mask): the cotangent
    contraction <Gbar, Phi^T Phi> + <bbar, Phi^T y> is re-derived
    block-by-block through the jnp feature map, so the backward pass holds
    one (block_rows, M) tile at a time — never an N x M buffer.  Data
    cotangents matter to callers differentiating the NLML through the
    observations (input selection, sensitivity analysis) — dropping them
    would silently corrupt those gradients."""
    spec, X, y, mask = res
    Gbar, bbar = ct
    idx = jnp.asarray(spec.indices(X.shape[1]))
    block_rows = min(spec.block_rows, max(1, X.shape[0]))

    def contracted(spec_d, X_d, y_d, mask_d):
        G, b = _block_scan_moments(
            X_d, y_d, lambda Xi: _features(Xi, idx, spec_d),
            idx.shape[0], block_rows, row_mask=mask_d,
        )
        return jnp.sum(Gbar * G) + jnp.sum(bbar * b)

    return jax.grad(contracted, argnums=(0, 1, 2, 3))(spec, X, y, mask)


_moments_diff.defvjp(_moments_diff_fwd, _moments_diff_bwd)


def _nlml_core(X, y, spec: GPSpec, mask):
    """Traceable masked NLML: moments via the backend registry
    (differentiable through ``_moments_diff``), epilogue through the shared
    scaled system.  ``mask`` (N,) of 0/1 row weights makes padding rows
    mathematically invisible (N in the logdet/normalization terms is the
    mask sum) — the unit the (B tenants x R restarts) hyperparameter
    optimizer vmaps over (repro.optim.gp_hyperopt)."""
    exp = get_expansion(spec.expansion)
    idx = jnp.asarray(spec.indices(X.shape[1]))
    T = 1 if y.ndim == 1 else y.shape[1]
    sig2 = spec.noise**2
    loglam = exp.log_eigenvalues(idx, spec)
    G, b = _moments_diff(spec, X, y, mask)
    n_eff = jnp.sum(mask)
    B, sqrtlam = _assemble_scaled_system(G, loglam, sig2)
    chol = jnp.linalg.cholesky(B)
    bs = _tscale(sqrtlam, b) / sig2              # D b / sig2, per task column
    w = jax.scipy.linalg.cho_solve((chol, True), bs)
    # y^T Kinv y = y^T y/sig2 - b^T Lbar^{-1} b / sig2^2
    #            = y^T y/sig2 - (Db/sig2)^T B^{-1} (Db/sig2), summed over tasks
    quad = jnp.sum(_row_weight(mask, y) * y) / sig2 - jnp.sum(bs * w)
    # logdet(K) = logdet(B) + N log sig2   (determinant lemma, scaled form);
    # the T tasks share K, so the logdet terms appear once per task
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(chol))) + n_eff * jnp.log(sig2)
    return 0.5 * (quad + T * (logdet + n_eff * jnp.log(2.0 * jnp.pi)))


@jax.jit
def _nlml_jit(X, y, spec: GPSpec, mask):
    return _nlml_core(X, y, spec, mask)


def nlml(X, y, spec: GPSpec, idx=None, n_max: Optional[int] = None,
         block_rows: Optional[int] = None, *, mask=None):
    """NLML of the decomposed-kernel GP, O(N M^2 + M^3).

    Matrix determinant lemma + Woodbury on (Phi Lambda Phi^T + sigma^2 I),
    assembled through the same scaled system as ``fit``, with the moment
    accumulation dispatched through the spec's backend (registry moments
    hook — streamed on both backends).  Differentiable in the spec's (eps,
    rho, noise) leaves for gradient-based hyperparameter learning — for the
    RFF expansions the lengthscale gradient flows through the eps-scaled
    spectral frequencies (``GP.optimize``, examples/hyperparam_learning.py).
    For multi-output y (N, T) the tasks share one factorization and the
    result is the sum of the per-task NLMLs.

    mask: optional (N,) row validity — masked-out rows contribute nothing
    (the batched fleet optimizer expresses ragged per-tenant N this way).
    """
    if idx is not None or n_max is not None or not isinstance(spec, GPSpec):
        _removed(
            "nlml(X, y, params, idx, n_max)",
            "build a GPSpec and call nlml(X, y, spec)",
        )
    _check_p(spec, X.shape[1])
    _check_backend_support(spec)
    if block_rows is not None:
        spec = spec.replace(block_rows=block_rows)
    if mask is None:
        mask = jnp.ones((X.shape[0],), jnp.float32)
    else:
        mask = jnp.asarray(mask).astype(jnp.float32)
        if mask.shape != (X.shape[0],):
            raise ValueError(
                f"nlml mask must be (N,) = ({X.shape[0]},), got {mask.shape}"
            )
    return _nlml_jit(X, y, spec, mask)


# ---------------------------------------------------------------------------
# The registered approximation family — FAGP as one plugin behind the GP
# facade (core.approximation).  Everything above stays the module-level
# expert API; the protocol adapter below is what ``GP`` dispatches through,
# and what makes Vecchia (core.vecchia) a true sibling rather than a fork.
# ---------------------------------------------------------------------------


_CKPT_LEAVES = ("lam", "sqrtlam", "chol", "u", "b")


class _FagpApproximation(Approximation):
    """``spec.approximation == "fagp"``: the paper's decomposed-kernel
    family.  Full capability surface, including bank admission."""

    name = "fagp"
    capabilities = frozenset(
        {"fit", "predict", "mean_var", "update", "nlml", "optimize", "bank"}
    )
    state_type = FAGPState

    def validate(self, spec: "GPSpec") -> None:
        if spec.kernel is not None or spec.neighbors is not None:
            raise ValueError(
                f"kernel=/neighbors= are vecchia-only spec fields but "
                f"approximation='fagp'; the FAGP family's structure is its "
                f"expansion — use GPSpec.create_vecchia for the Vecchia "
                f"family ({spec.describe()})"
            )
        get_expansion(spec.expansion).validate(spec)

    def fit(self, X, y, spec):
        return fit(X, y, spec)

    def predict(self, state, Xs, *, mode: str = "fused"):
        return predict(state, Xs, mode=mode)

    def mean_var(self, state, Xs):
        return predict_mean_var(state, Xs)

    def update(self, state, X_new, y_new):
        return fit_update(state, X_new, y_new)

    def nlml(self, X, y, spec, *, mask=None):
        return nlml(X, y, spec, mask=mask)

    def optimize(self, X, y, spec, *, steps: int = 100, lr: float = 5e-2,
                 restarts: int = 1, tol: Optional[float] = None,
                 jitter: float = 0.3, seed: int = 0, callback=None):
        """Gradient NLML hyperparameter learning on the fleet lane engine
        (``repro.optim.gp_hyperopt``), then a fit at the learned
        hyperparameters — the body behind ``GP.optimize``."""
        from repro.optim import gp_hyperopt

        def cb(step, vals, hp):
            if callback is None:
                return
            r = int(np.argmin(vals[0]))
            lane = {f: leaf[0, r] for f, leaf in hp.items()}
            callback(
                step, float(vals[0, r]),
                dataclasses.replace(
                    spec,
                    eps=jnp.exp(lane["log_eps"]),
                    rho=jnp.exp(lane["log_rho"]),
                    noise=jnp.exp(lane["log_noise"]),
                ),
            )

        result = gp_hyperopt.optimize_restarts(
            X, y, spec, restarts=restarts, steps=steps, lr=lr, tol=tol,
            jitter=jitter, seed=seed, callback=cb,
        )
        return fit(X, y, result.spec_for(spec, 0))

    # -- checkpoint hooks (repro.checkpoint.gpstate) ------------------------

    def ckpt_leaf_names(self) -> tuple:
        return _CKPT_LEAVES

    def ckpt_leaves(self, state: FAGPState) -> dict:
        if state.b is None:
            raise ValueError(
                "save_state: state lacks the raw moment vector b (a "
                "pre-PR-1 fit path); refit before saving"
            )
        return {f: getattr(state, f) for f in _CKPT_LEAVES}

    def ckpt_meta(self, state: FAGPState) -> dict:
        return {"M": int(state.n_features), "n_tasks": int(state.n_tasks)}

    def ckpt_rebuild(self, spec, leaves: dict, train) -> FAGPState:
        train = train or {}
        return FAGPState(
            idx=jnp.asarray(spec.indices()),
            lam=leaves["lam"], sqrtlam=leaves["sqrtlam"],
            chol=leaves["chol"], u=leaves["u"], params=spec.params,
            Phi=train.get("Phi"), y=train.get("y"), b=leaves["b"],
            spec=spec,
        )


register_approximation(_FagpApproximation())

# importing the sibling family registers it; must come AFTER this module's
# definitions (vecchia pulls _STRUCTURAL_FIELDS etc. lazily, never at its
# module scope — see the layering note in core/vecchia.py)
from . import vecchia as _vecchia  # noqa: E402,F401  (registration import)
